"""Aggregation benchmark: host scatter loop vs compiled collective merge.

Times the Heroes block-wise merge (Eq. 5, basis mean + masked block
mean) on a synthetic multi-layer coefficient workload at growing cohort
sizes.  The host path is the per-client eager loop the engine used
before the collective backend (one ``at[ids].add`` scatter dispatch per
client per layer — O(K) dispatches per merge); the collective path
stacks dense zero-padded contributions on the host and merges the whole
cohort in ONE compiled call (``CollectiveMerger.merge_factorized``).
Writes ``BENCH_aggregation.json`` next to the repo root.

Usage:  PYTHONPATH=src python benchmarks/bench_aggregation.py [--fast]
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402


class _Spec:
    mode = "square"


def make_workload(k: int, p: int = 4, rank: int = 16, out: int = 32,
                  layers: int = 4, seed: int = 0):
    """K clients, each training a random width-w subset of P^2 blocks."""
    from repro.fl.client import ClientResult

    rng = np.random.default_rng(seed)
    nb = p * p
    names = [f"layer{i}" for i in range(layers)]
    prev = {
        name: {
            "basis": jax.numpy.asarray(
                rng.normal(size=(p, rank, out)).astype(np.float32)),
            "coeff": jax.numpy.asarray(
                rng.normal(size=(nb, rank, out)).astype(np.float32)),
        }
        for name in names
    }
    results, assigns = {}, {}
    for n in range(k):
        width = int(rng.integers(1, p + 1))
        m = width * width
        ids = np.sort(rng.choice(nb, size=m, replace=False))
        params = {
            name: {
                "basis": rng.normal(size=(p, rank, out)).astype(np.float32),
                "coeff": rng.normal(size=(m, rank, out)).astype(np.float32),
            }
            for name in names
        }
        results[n] = ClientResult(params, {}, 0.0, 0.0)
        assigns[n] = {"hidden_ids": ids}
    specs = {name: _Spec() for name in names}
    return prev, specs, results, assigns


def merge_host(prev, specs, results, assigns):
    """The pre-collective engine merge: per-layer eager scatter loop."""
    from repro.core import aggregation

    new = {}
    for name in specs:
        new[name] = {
            "basis": aggregation.aggregate_basis(
                [r.params[name]["basis"] for r in results.values()]),
            "coeff": aggregation.aggregate_coefficient(
                prev[name]["coeff"],
                [r.params[name]["coeff"] for r in results.values()],
                [np.asarray(assigns[n]["hidden_ids"]) for n in results],
            ),
        }
    return new


def _block(tree):
    jax.block_until_ready(jax.tree_util.tree_leaves(tree))


def bench(k: int, reps: int, warmup: int) -> dict:
    from repro.fl.engine.collective import CollectiveMerger

    prev, specs, results, assigns = make_workload(k)
    merger = CollectiveMerger()

    for fn in (lambda: merge_host(prev, specs, results, assigns),
               lambda: merger.merge_factorized(prev, specs, results,
                                               assigns)):
        for _ in range(warmup):
            _block(fn())

    t0 = time.perf_counter()
    for _ in range(reps):
        _block(merge_host(prev, specs, results, assigns))
    host_s = (time.perf_counter() - t0) / reps

    t0 = time.perf_counter()
    for _ in range(reps):
        _block(merger.merge_factorized(prev, specs, results, assigns))
    coll_s = (time.perf_counter() - t0) / reps

    return {"clients": k, "host_ms": host_s * 1e3,
            "collective_ms": coll_s * 1e3, "speedup": host_s / coll_s}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller cohorts / fewer reps (CI smoke)")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: repo-root "
                         "BENCH_aggregation.json)")
    args = ap.parse_args()
    cohorts = (10, 50) if args.fast else (10, 50, 200)
    reps = 3 if args.fast else 10

    results = []
    for k in cohorts:
        r = bench(k, reps=reps, warmup=2)
        results.append(r)
        print(f"K={k:4d}  host {r['host_ms']:8.1f} ms   "
              f"collective {r['collective_ms']:8.1f} ms   "
              f"speedup {r['speedup']:.1f}x")

    import common

    out = {
        "benchmark": "aggregation_host_vs_collective",
        "setup": {"layers": 4, "max_width": 4, "num_blocks": 16,
                  "rank": 16, "out": 32,
                  "devices": len(jax.devices()),
                  "reps": reps},
        "provenance": common.provenance(),
        "results": results,
    }
    path = Path(args.out) if args.out else \
        Path(__file__).resolve().parents[1] / "BENCH_aggregation.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
