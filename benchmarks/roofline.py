"""Roofline report: three terms per (arch x shape x mesh) from the dry-run.

Hardware model (TPU v5e): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

Sources: the dry-run JSONs (experiments/dryrun/*.json).  FLOPs /
HBM-traffic / collective bytes come from the loop-scaled HLO analysis
(repro.launch.hlo_analysis) — raw ``cost_analysis`` counts while bodies
once and is recorded only as a cross-check.  All quantities are
per-device (the HLO is the per-device SPMD program).
"""

from __future__ import annotations

import json
import sys
from pathlib import Path
from typing import Dict, List, Optional

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro import configs  # noqa: E402
from repro.configs.shapes import SHAPES  # noqa: E402

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def model_flops_per_device(arch: str, shape_name: str, devices: int) -> float:
    """Napkin MODEL_FLOPS: 6·N·D train, 2·N·D inference (N = active params,
    embeddings included), divided across chips."""
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        total = 6.0 * n * tokens
    elif shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        total = 2.0 * n * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n * shape.global_batch
    return total / devices


def load_records(suffix: str = "") -> List[dict]:
    recs = []
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        stem = p.stem
        parts = stem.split("__")
        extra = "__".join(parts[3:]) if len(parts) > 3 else ""
        if extra != suffix:
            continue
        recs.append(json.loads(p.read_text()))
    return recs


def roofline_row(rec: dict) -> Dict[str, object]:
    ls = rec.get("loop_scaled", {})
    flops = float(ls.get("dot_flops") or 0.0)
    traffic = float(ls.get("traffic_bytes") or 0.0)
    coll = float((ls.get("collective_bytes") or {}).get("total") or 0.0)
    t_c = flops / PEAK_FLOPS
    t_m = traffic / HBM_BW
    t_x = coll / ICI_BW
    # lower bound on the memory term: every live buffer touched once
    mem = rec.get("memory") or {}
    lb_bytes = (mem.get("argument_bytes") or 0) + (mem.get("output_bytes") or 0) \
        + (mem.get("temp_bytes") or 0)
    t_m_lb = lb_bytes / HBM_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec["arch"], rec["shape"], rec["devices"])
    ratio = mf / flops if flops else float("nan")
    peak = (rec.get("memory") or {}).get("peak_bytes")
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "compute_s": t_c,
        "memory_s": t_m,
        "memory_lb_s": t_m_lb,
        "collective_s": t_x,
        "dominant": dominant,
        "model_flops_dev": mf,
        "hlo_flops_dev": flops,
        "useful_ratio": ratio,
        "peak_gib": (peak or 0) / 2**30,
        "bound_frac": terms[dominant] / max(sum(terms.values()), 1e-30),
        "compile_s": rec.get("compile_s"),
    }


RECOMMEND = {
    "compute": "reduce redundant FLOPs (masked-block skipping, dispatch einsum "
               "elimination, factorized forward) or raise arithmetic intensity",
    "memory": "fuse/bf16-ify the streaming path, shrink the resident cache "
              "slice per device, or re-tile so the working set stays in VMEM",
    "collective": "re-shard to remove per-layer all-gathers (sequence-parallel "
                  "residual), batch small collectives, or overlap with compute",
}


def run(suffix: str = "") -> List[str]:
    rows = [roofline_row(r) for r in load_records(suffix)]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    out_csv = Path(__file__).resolve().parents[1] / "experiments" / (
        f"roofline{('_' + suffix) if suffix else ''}.csv")
    hdr = ("arch,shape,mesh,compute_s,memory_s,memory_lb_s,collective_s,"
           "dominant,model_flops_dev,hlo_flops_dev,useful_ratio,peak_gib")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"{r['arch']},{r['shape']},{r['mesh']},{r['compute_s']:.4e},"
            f"{r['memory_s']:.4e},{r['memory_lb_s']:.4e},"
            f"{r['collective_s']:.4e},{r['dominant']},"
            f"{r['model_flops_dev']:.3e},{r['hlo_flops_dev']:.3e},"
            f"{r['useful_ratio']:.3f},{r['peak_gib']:.2f}")
    out_csv.write_text("\n".join(lines) + "\n")
    bench_rows = []
    for r in rows:
        bench_rows.append(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
            f"{max(r['compute_s'], r['memory_s'], r['collective_s'])*1e6:.1f},"
            f"dominant={r['dominant']}")
    return bench_rows


def markdown_table(suffix: str = "", mesh: str = "16x16") -> str:
    rows = [roofline_row(r) for r in load_records(suffix) if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    md = ["| arch | shape | compute s | memory s | collective s | dominant | "
          "MODEL/HLO | peak GiB | what moves it |",
          "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        md.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['peak_gib']:.2f} | {RECOMMEND[r['dominant']]} |")
    return "\n".join(md)


if __name__ == "__main__":
    for line in run():
        print(line)
