"""Engine benchmark: batched cohort trainer vs sequential per-client loop.

Times repeated 10-client CNN rounds through the engine with the two
local-training backends.  The sequential backend pays one jit dispatch
per client per SGD step (tau * K dispatches/round); the cohort backend
stacks the cohort into one compiled vmap+scan call.  Writes
``BENCH_engine.json`` next to the repo root.

Usage:  PYTHONPATH=src python benchmarks/bench_engine.py [--fast]
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def bench(scheme: str, trainer: str, rounds: int, warmup: int) -> dict:
    from repro.fl import FLConfig, build_image_setup, build_runner

    model, px, py, test = build_image_setup(num_clients=10, seed=0)
    cfg = FLConfig(num_clients=10, clients_per_round=10, tau_fixed=10,
                   eval_every=10_000, estimate=(scheme == "heroes"),
                   trainer=trainer, seed=0)
    eng = build_runner(scheme, model, px, py, test, cfg=cfg)
    # warmup covers jit compilation; heroes needs more rounds because its
    # scheduler varies (width, tau) shapes until the bucketed cache fills
    for _ in range(warmup):
        eng.run_round()
    t0 = time.perf_counter()
    for _ in range(rounds):
        eng.run_round()
    dt = time.perf_counter() - t0
    return {"scheme": scheme, "trainer": trainer, "rounds": rounds,
            "total_s": dt, "per_round_s": dt / rounds}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer repeated rounds (CI smoke)")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: repo-root BENCH_engine.json)")
    args = ap.parse_args()
    rounds = 2 if args.fast else 10

    results = {}
    for scheme in ("fedavg", "heroes"):
        warmup = 1 if args.fast else (8 if scheme == "heroes" else 2)
        seq = bench(scheme, "sequential", rounds, warmup)
        coh = bench(scheme, "cohort", rounds, warmup)
        results[scheme] = {
            "sequential_per_round_s": seq["per_round_s"],
            "cohort_per_round_s": coh["per_round_s"],
            "speedup": seq["per_round_s"] / coh["per_round_s"],
            "rounds_timed": rounds,
            "warmup_rounds": warmup,
        }
        print(f"{scheme:8s} sequential {seq['per_round_s']*1e3:8.1f} ms/round   "
              f"cohort {coh['per_round_s']*1e3:8.1f} ms/round   "
              f"speedup {results[scheme]['speedup']:.2f}x")

    out = {
        "benchmark": "engine_cohort_vs_sequential",
        "setup": {"model": "cnn", "num_clients": 10, "clients_per_round": 10,
                  "tau": 10, "batch_size": 16},
        "results": results,
    }
    path = Path(args.out) if args.out else \
        Path(__file__).resolve().parents[1] / "BENCH_engine.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
