"""Engine benchmark: batched cohort trainer vs sequential per-client loop,
plus the mesh-sharded cohort round.

Times repeated CNN rounds through the engine.  The sequential backend
pays one jit dispatch per client per SGD step (tau * K dispatches per
round); the cohort backend stacks the cohort into one compiled
vmap+scan call; the *sharded* cohort lays the client axis out over the
local device mesh (``FLConfig.trainer_mesh_devices``) so the one call
runs data-parallel across devices.  The sharded comparison spawns
subprocesses because the forced host-device count must be set before
jax initialises.  Writes ``BENCH_engine.json`` next to the repo root.

Usage:  PYTHONPATH=src python benchmarks/bench_engine.py [--fast|--smoke]

``--fast`` trims the single-device comparisons (CI); ``--smoke`` trims
everything and still exercises the sharded-cohort shape (the 4-device
CI leg runs this).
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def bench(scheme: str, trainer: str, rounds: int, warmup: int) -> dict:
    from repro.fl import FLConfig, build_image_setup, build_runner

    model, px, py, test = build_image_setup(num_clients=10, seed=0)
    cfg = FLConfig(num_clients=10, clients_per_round=10, tau_fixed=10,
                   eval_every=10_000, estimate=(scheme == "heroes"),
                   trainer=trainer, seed=0)
    eng = build_runner(scheme, model, px, py, test, cfg=cfg)
    # warmup covers jit compilation; heroes needs more rounds because its
    # scheduler varies (width, tau) shapes until the bucketed cache fills
    for _ in range(warmup):
        eng.run_round()
    t0 = time.perf_counter()
    for _ in range(rounds):
        eng.run_round()
    dt = time.perf_counter() - t0
    return {"scheme": scheme, "trainer": trainer, "rounds": rounds,
            "total_s": dt, "per_round_s": dt / rounds}


def bench_cohort_rounds(task: str, clients: int, rounds: int,
                        warmup: int) -> dict:
    """Timed cohort-trainer rounds at the current device count (worker
    body for the sharded comparison; devices come from XLA_FLAGS)."""
    import jax

    from repro.fl import (FLConfig, build_image_setup, build_runner,
                          build_text_setup)

    if task == "rnn":
        model, px, py, test = build_text_setup(num_clients=clients, seed=0)
    else:
        model, px, py, test = build_image_setup(num_clients=clients, seed=0)
    cfg = FLConfig(num_clients=clients, clients_per_round=clients,
                   tau_fixed=10, eval_every=10_000, estimate=False,
                   trainer="cohort", seed=0)
    scheme = "fedavg"
    eng = build_runner(scheme, model, px, py, test, cfg=cfg)
    for _ in range(warmup):
        eng.run_round()
    t0 = time.perf_counter()
    for _ in range(rounds):
        eng.run_round()
    dt = time.perf_counter() - t0
    return {"scheme": scheme, "task": task,
            "devices": len(jax.local_devices()),
            "clients": clients, "rounds": rounds,
            "per_round_s": dt / rounds,
            "trainer_mesh": eng.trainer.mesh is not None}


def bench_sharded_cohort(task: str, clients: int, rounds: int, warmup: int,
                         devices: int = 4, repeats: int = 1) -> dict:
    """1-device vs N-device sharded cohort round, via subprocesses.

    ``repeats`` interleaves the two device counts (1, N, 1, N, ...) and
    reports the per-config *median* (plus the best) so slow-neighbor
    noise on shared CI boxes doesn't land entirely on one side of the
    ratio.
    """
    times = {1: [], devices: []}
    for _ in range(max(repeats, 1)):
        for ndev in (1, devices):
            env = {**os.environ, "XLA_FLAGS":
                   f"--xla_force_host_platform_device_count={ndev}"}
            cmd = [sys.executable, __file__, "--_cohort-worker",
                   "--task", task, "--clients", str(clients),
                   "--rounds", str(rounds), "--warmup", str(warmup)]
            r = subprocess.run(cmd, env=env, capture_output=True, text=True)
            if r.returncode != 0:
                raise RuntimeError(f"cohort worker ({ndev} devices) failed:"
                                   f"\n{r.stderr[-2000:]}")
            res = json.loads(r.stdout.strip().splitlines()[-1])
            assert res["devices"] == ndev, res
            times[ndev].append(res["per_round_s"])
    import statistics

    out = {f"{n}dev_per_round_s": statistics.median(t)
           for n, t in times.items()}
    out.update({
        "task": task, "clients": clients, "devices": devices, "tau": 10,
        "rounds": rounds, "repeats": max(repeats, 1),
        "speedup": out["1dev_per_round_s"] / out[f"{devices}dev_per_round_s"],
        "best_speedup": min(times[1]) / min(times[devices]),
    })
    return out


def _run_cohort_worker(task: str, clients: int, rounds: int, warmup: int,
                       script: str | None = None) -> dict:
    """One 1-device cohort-round measurement in a fresh process (the
    protocol every stored per-round baseline in BENCH_engine.json uses).
    ``script`` points at another checkout's bench_engine.py to time a
    different revision (the worker is self-contained: it inserts its own
    repo's ``src`` on sys.path)."""
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=1"}
    cmd = [sys.executable, script or __file__, "--_cohort-worker",
           "--task", task, "--clients", str(clients),
           "--rounds", str(rounds), "--warmup", str(warmup)]
    r = subprocess.run(cmd, env=env, capture_output=True, text=True)
    if r.returncode != 0:
        raise RuntimeError(f"cohort worker failed:\n{r.stderr[-2000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def bench_telemetry_overhead(path: Path, quick: bool, clients: int,
                             rounds: int,
                             baseline_root: str | None = None) -> dict:
    """Measure the no-op-recorder cost and merge a ``telemetry_overhead``
    entry into the existing ``BENCH_engine.json`` (read-modify-write).

    The engine's hot loops are instrumented; with telemetry off every
    call routes to the shared no-op recorder.  With ``baseline_root``
    (a checkout of the pre-instrumentation revision) the baseline is
    re-timed *interleaved* with the instrumented code in this session —
    the only comparison tight enough for a 2% bar; cross-session numbers
    drift ~10% with box load.  Without it, ratios fall back to the
    stored ``post_refactor_serverstate`` per-round baselines (noisy —
    treat as indicative only).
    """
    import statistics

    data = json.loads(path.read_text()) if path.exists() else {}
    stored = data.get("post_refactor_serverstate", {})
    repeats = 1 if quick else 3
    base_script = None
    if baseline_root:
        base_script = str(Path(baseline_root).resolve()
                          / "benchmarks" / "bench_engine.py")
        baseline_note = ("baseline re-timed interleaved from the "
                         "pre-instrumentation checkout at "
                         f"{baseline_root}")
    else:
        baseline_note = ("baseline from stored post_refactor_serverstate "
                         "(different session — noisy)")
    entry = {"note": "instrumented engine with telemetry='off' (no-op "
                     "recorder) vs the uninstrumented engine; ratio <= "
                     "1.02 = the default recorder is free; "
                     + baseline_note}
    for task in ("rnn", "cnn"):
        ours, theirs = [], []
        for _ in range(repeats):
            if base_script:  # interleave A/B within the session
                theirs.append(_run_cohort_worker(
                    task, clients, rounds, 2, base_script)["per_round_s"])
            ours.append(_run_cohort_worker(task, clients, rounds, 2)
                        ["per_round_s"])
        per_round = statistics.median(ours)
        cell = {"per_round_s": per_round, "clients": clients, "tau": 10,
                "rounds": rounds, "repeats": repeats,
                "protocol": "median-of-%d%s, 1 device, cohort trainer, "
                            "telemetry=off"
                            % (repeats,
                               " interleaved" if base_script else "")}
        if theirs:
            # paired per-repeat ratios: adjacent A/B workers share box
            # conditions, so the ratio cancels load drift that the raw
            # medians (each +-10-20% on a shared box) cannot
            pair = [o / t for o, t in zip(ours, theirs)]
            ref = statistics.median(theirs)
            cell["baseline_per_round_s"] = ref
            cell["overhead_vs_baseline"] = statistics.median(pair)
            cell["best_overhead_vs_baseline"] = min(ours) / min(theirs)
            cell["paired_ratios"] = pair
            print(f"telemetry-off {task}: {per_round*1e3:8.1f} ms/round   "
                  f"baseline {ref*1e3:8.1f} ms/round   paired-median "
                  f"{cell['overhead_vs_baseline']:.3f}x   best "
                  f"{cell['best_overhead_vs_baseline']:.3f}x")
        else:
            ref = stored.get(task, {}).get("per_round_s")
            if ref:
                cell["baseline_per_round_s"] = ref
                cell["overhead_vs_baseline"] = per_round / ref
                print(f"telemetry-off {task}: {per_round*1e3:8.1f} ms/round"
                      f"   baseline {ref*1e3:8.1f} ms/round   "
                      f"ratio {per_round/ref:.3f}x")
            else:
                print(f"telemetry-off {task}: {per_round*1e3:8.1f} ms/round"
                      "   (no stored baseline)")
        entry[task] = cell
    data["telemetry_overhead"] = entry
    import common

    data["provenance"] = common.provenance()
    path.write_text(json.dumps(data, indent=2) + "\n")
    print(f"wrote {path}")
    return entry


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer repeated rounds (CI smoke)")
    ap.add_argument("--smoke", action="store_true",
                    help="minimal rounds incl. the sharded-cohort shape")
    ap.add_argument("--telemetry-only", action="store_true",
                    help="only (re)measure the no-op telemetry overhead "
                         "and merge it into the existing BENCH_engine.json")
    ap.add_argument("--baseline-root", default=None,
                    help="checkout of the pre-instrumentation revision to "
                         "re-time interleaved as the overhead baseline")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: repo-root BENCH_engine.json)")
    ap.add_argument("--_cohort-worker", action="store_true",
                    dest="cohort_worker", help=argparse.SUPPRESS)
    ap.add_argument("--task", choices=("cnn", "rnn"), default="rnn")
    ap.add_argument("--clients", type=int, default=24)
    ap.add_argument("--rounds", type=int, default=0)
    ap.add_argument("--warmup", type=int, default=2)
    args = ap.parse_args()

    if args.cohort_worker:
        res = bench_cohort_rounds(args.task, args.clients,
                                  args.rounds or 5, args.warmup)
        print(json.dumps(res))
        return

    if args.telemetry_only:
        path = Path(args.out) if args.out else \
            Path(__file__).resolve().parents[1] / "BENCH_engine.json"
        bench_telemetry_overhead(path, args.fast or args.smoke,
                                 args.clients, args.rounds or 5,
                                 baseline_root=args.baseline_root)
        return

    quick = args.fast or args.smoke
    rounds = 2 if quick else 10

    results = {}
    for scheme in ("fedavg", "heroes"):
        warmup = 1 if quick else (8 if scheme == "heroes" else 2)
        seq = bench(scheme, "sequential", rounds, warmup)
        coh = bench(scheme, "cohort", rounds, warmup)
        results[scheme] = {
            "sequential_per_round_s": seq["per_round_s"],
            "cohort_per_round_s": coh["per_round_s"],
            "speedup": seq["per_round_s"] / coh["per_round_s"],
            "rounds_timed": rounds,
            "warmup_rounds": warmup,
        }
        print(f"{scheme:8s} sequential {seq['per_round_s']*1e3:8.1f} ms/round   "
              f"cohort {coh['per_round_s']*1e3:8.1f} ms/round   "
              f"speedup {results[scheme]['speedup']:.2f}x")

    # warmup 2 even in smoke mode: round 1 compiles the cohort step,
    # round 2 the merge — timing them would swamp the 2-3 timed rounds.
    # The rnn (char-LM) cohort is the shape where device sharding pays on
    # the 2-core CI box: its sequence scan of small matmuls starves XLA's
    # intra-op threading, so the client axis is the only parallelism
    # left.  The cnn step already threads well intra-op there, so its
    # device speedup is modest until real multi-core/accelerator hosts;
    # the full run records both.
    sh_rounds = args.rounds or (3 if quick else 5)
    sharded = {}
    # --fast (the 1-device CI leg) skips the sharded comparison — the
    # 4-device leg runs it via --smoke
    for task in (() if args.fast and not args.smoke
                 else ("rnn",) if quick else ("rnn", "cnn")):
        sh = bench_sharded_cohort(task, args.clients, sh_rounds, warmup=2,
                                  repeats=1 if quick else 3)
        sharded[task] = sh
        print(f"sharded-cohort {task} {sh['clients']} clients: "
              f"1dev {sh['1dev_per_round_s']*1e3:8.1f} ms/round   "
              f"{sh['devices']}dev "
              f"{sh[str(sh['devices']) + 'dev_per_round_s']*1e3:8.1f}"
              f" ms/round   speedup {sh['speedup']:.2f}x "
              f"(best {sh['best_speedup']:.2f}x)")

    import common

    out = {
        "benchmark": "engine_cohort_vs_sequential",
        "setup": {"model": "cnn", "num_clients": 10, "clients_per_round": 10,
                  "tau": 10, "batch_size": 16},
        "provenance": common.provenance(),
        "results": results,
    }
    if sharded:
        out["sharded_cohort"] = sharded
    path = Path(args.out) if args.out else \
        Path(__file__).resolve().parents[1] / "BENCH_engine.json"
    # full rewrites keep previously merged sections (stored baselines,
    # telemetry overhead) — they are reference points, not rerun here
    if path.exists():
        try:
            old = json.loads(path.read_text())
            for k in ("post_refactor_serverstate", "telemetry_overhead"):
                if k in old and k not in out:
                    out[k] = old[k]
        except (ValueError, OSError):
            pass
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
