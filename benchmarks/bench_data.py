"""Data-pipeline benchmark: streaming loader vs materialize-everything.

Three measurements, written to ``BENCH_data.json``:

  * setup    — building the per-client shards: ShardViews over one
               global array vs the legacy per-client copies.
  * loader   — host batch throughput of ``ClientDataLoader.draw_round``
               (the exact cohort-trainer draw + gather) over both shard
               kinds, in gathered MB/s.
  * rounds   — end-to-end ``run_scheme`` cohort rounds at 20+ sampled
               clients with streaming vs materialized shards (the
               acceptance bar: streaming must not be slower).

Usage:  PYTHONPATH=src python benchmarks/bench_data.py [--fast] [--out F]
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

NUM_CLIENTS = 24
K = 20  # sampled clients per round (the "20+ clients" criterion)


def bench_setup(streaming: bool, reps: int) -> float:
    from repro.fl import build_image_setup

    t0 = time.perf_counter()
    for _ in range(reps):
        build_image_setup(num_clients=NUM_CLIENTS, seed=0,
                          streaming=streaming)
    return (time.perf_counter() - t0) / reps


def bench_loader(streaming: bool, rounds: int) -> dict:
    from repro.data import ClientDataLoader, load_dataset, partition_dataset

    ds = load_dataset("synthetic_image", seed=0)
    parts = partition_dataset(ds, "dirichlet", NUM_CLIENTS, 0, gamma_pct=40.0)
    loader = ClientDataLoader.from_dataset(ds, parts, streaming=streaming)
    tau, bs = 10, 16
    # warmup one pass
    for n in range(NUM_CLIENTS):
        loader.draw_round(n, seed=0, rnd=0, tau=tau, batch_size=bs,
                          estimate=True)
    nbytes = 0
    t0 = time.perf_counter()
    for r in range(1, rounds + 1):
        for n in range(NUM_CLIENTS):
            xs, ys, est = loader.draw_round(n, seed=0, rnd=r, tau=tau,
                                            batch_size=bs, estimate=True)
            nbytes += xs.nbytes + ys.nbytes + est[0].nbytes + est[1].nbytes
    dt = time.perf_counter() - t0
    return {"gathered_mb": nbytes / 1e6, "seconds": dt,
            "mb_per_s": nbytes / 1e6 / dt}


def bench_rounds(streaming: bool, rounds: int, warmup: int) -> float:
    from repro.fl import FLConfig, build_image_setup, build_runner

    model, px, py, test = build_image_setup(num_clients=NUM_CLIENTS, seed=0,
                                            streaming=streaming)
    cfg = FLConfig(num_clients=NUM_CLIENTS, clients_per_round=K, tau_fixed=5,
                   eval_every=10_000, estimate=False, trainer="cohort",
                   seed=0)
    eng = build_runner("fedavg", model, px, py, test, cfg=cfg)
    for _ in range(warmup):
        eng.run_round()
    t0 = time.perf_counter()
    for _ in range(rounds):
        eng.run_round()
    return (time.perf_counter() - t0) / rounds


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer repetitions (CI smoke)")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: repo-root BENCH_data.json)")
    args = ap.parse_args()
    reps = 2 if args.fast else 5
    loader_rounds = 5 if args.fast else 40
    e2e_rounds = 2 if args.fast else 10
    warmup = 1 if args.fast else 3

    import common

    results = {
        "config": {"num_clients": NUM_CLIENTS, "clients_per_round": K,
                   "fast": args.fast},
        "provenance": common.provenance(),
        "setup": {
            "streaming_s": bench_setup(True, reps),
            "materialized_s": bench_setup(False, reps),
        },
        "loader": {
            "streaming": bench_loader(True, loader_rounds),
            "materialized": bench_loader(False, loader_rounds),
        },
        # interleaved best-of-2 per mode: the first end-to-end run in a
        # process pays one-time pool/compile warmup that would otherwise
        # bias whichever mode runs first
        "rounds": {
            "streaming_per_round_s": min(
                bench_rounds(True, e2e_rounds, warmup) for _ in range(2)),
            "materialized_per_round_s": min(
                bench_rounds(False, e2e_rounds, warmup) for _ in range(2)),
        },
    }
    r = results["rounds"]
    r["ratio_streaming_over_materialized"] = (
        r["streaming_per_round_s"] / r["materialized_per_round_s"])
    out = Path(args.out) if args.out else \
        Path(__file__).resolve().parents[1] / "BENCH_data.json"
    out.write_text(json.dumps(results, indent=2))
    print(json.dumps(results, indent=2))
    if r["ratio_streaming_over_materialized"] > 1.15:
        print("WARNING: streaming pipeline >15% slower than materialized",
              file=sys.stderr)


if __name__ == "__main__":
    main()
