"""Rank-space vs materialize client compute through the cohort hot loop.

Times full cohort-trainer rounds (the one compiled vmap+scan call per
round) with ``FLConfig.forward_impl`` pinned to ``materialize`` vs
``rank_space`` at widths 1..3 on the cnn and rnn models — every client
in the cohort is forced to the same width so each round isolates one
(model, width, impl) cell.  Same protocol as BENCH_engine: repeats are
*interleaved* (mat, rank, mat, rank, ...) and the per-impl median is
reported, so slow-neighbor noise on shared boxes doesn't land on one
side of the ratio.

Alongside the timings the static FLOPs model is recorded for every
width: per-layer ``apply_flops`` / ``compose_flops + dense_apply_flops``
and the model-level ratio, i.e. the number the ``auto`` knob acts on.

A second, per-layer **micro** section times the fused rank-path
primitives against their separate-ops formulations at every unique
layer shape of cnn/resnet/rnn/transformer, per width: the fused conv
rank apply (:mod:`repro.kernels.conv_rank`) vs the unfused basis-conv +
contraction vs compose-then-conv, and the fused compose+apply dense
kernel (``compose_dense_apply``) vs compose-then-matmul.  Same
interleaved median-of-3 protocol; these are the numbers the measured
calibration (:mod:`repro.core.calibration`) generalises from.

Usage:  PYTHONPATH=src python benchmarks/bench_compose.py [--smoke]
Writes BENCH_compose.json next to the repo root (override with --out).
"""

from __future__ import annotations

import argparse
import json
import statistics
import time
from pathlib import Path

import sys

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def flops_table(model_name: str) -> dict:
    """apply vs compose+dense-apply FLOPs per training batch, per width."""
    from repro.core.composition import (apply_flops, compose_flops,
                                        dense_apply_flops)
    from repro.fl.models import MODELS, LayerHint

    model = MODELS[model_name]()
    batch = 16
    out = {}
    for p in (1, 2, 3):
        layers = {}
        rank_total = mat_total = 0
        for name, spec in model.specs.items():
            hint = (model.hints or {}).get(name, LayerHint())
            apps = batch * hint.apps_per_sample
            rank = apply_flops(p, spec, applications=apps,
                               basis_is_gather=hint.basis_gather)
            dense = 0 if hint.dense_apply_free else dense_apply_flops(
                p, spec, applications=apps)
            mat = compose_flops(p, spec) + dense
            if not hint.rank_capable:  # pinned to materialize (scan reuse)
                rank = mat
            layers[name] = {"apply_flops": rank, "materialize_flops": mat}
            rank_total += rank
            mat_total += mat
        out[f"width_{p}"] = {
            "layers": layers,
            "rank_space_flops": rank_total,
            "materialize_flops": mat_total,
            "flops_ratio": mat_total / rank_total,
        }
    return out


def _make_model(name: str):
    from repro.fl.models import MODELS

    if name == "transformer":
        from repro.fl.transformer import make_transformer

        return make_transformer()
    return MODELS[name]()


def _median_interleaved(legs: dict, repeats: int, iters: int) -> dict:
    """Median seconds/call per leg, legs interleaved within each repeat
    (load drift hits every leg equally instead of the last one)."""
    import jax

    for fn in legs.values():  # compile + warm
        jax.block_until_ready(fn())
        jax.block_until_ready(fn())
    times = {k: [] for k in legs}
    for _ in range(repeats):
        for k, fn in legs.items():
            t0 = time.perf_counter()
            for _ in range(iters):
                jax.block_until_ready(fn())
            times[k].append((time.perf_counter() - t0) / iters)
    return {k: statistics.median(v) for k, v in times.items()}


def micro_rank_paths(model_name: str, width: int, repeats: int,
                     iters: int) -> dict:
    """Fused vs separate-ops rank-path primitives at this model's
    unique layer shapes (batch 16, the engine's 8x8 reference images).

    Conv layers: fused ``conv_rank_apply`` vs the unfused basis-conv +
    contraction (``apply_factors(..., fused=False)``) vs
    compose-then-conv.  Dense layers: fused ``compose_dense_apply`` vs
    compose-then-matmul.  Gather layers (embeddings) and
    materialize-pinned layers (scan recurrences) have no fused path and
    are skipped.
    """
    import jax
    import numpy as np
    from repro.core.composition import (apply_factors, compose,
                                        gather_blocks, init_factors)
    from repro.kernels.compose import compose_dense_apply

    model = _make_model(model_name)
    p = width
    dn = ("NHWC", "HWIO", "NHWC")
    cells, seen = {}, {}
    for idx, (name, layer) in enumerate((model.layers or {}).items()):
        spec, hint = layer.spec, layer.hint
        if hint.dense_apply_free or not hint.rank_capable:
            continue
        stride = getattr(layer, "stride", 1)
        g = 1 if spec.mode == "grow_out" else p
        if spec.ksq > 1:
            sig = ("conv", spec.base_in, spec.base_out, spec.rank,
                   spec.mode, stride)
        else:
            sig = ("dense", spec.base_in, spec.base_out, spec.rank,
                   spec.mode, min(hint.apps_per_sample, 32))
        if sig in seen:
            seen[sig]["count"] += 1
            continue
        ks = jax.random.split(jax.random.PRNGKey(idx), 2)
        v, u = init_factors(ks[0], spec)
        red = gather_blocks(u, np.arange(spec.blocks_for_width(p)))
        if spec.ksq > 1:
            k = int(round(spec.ksq ** 0.5))
            x = jax.random.normal(ks[1], (16, 8, 8, g * spec.base_in))
            fused = jax.jit(lambda x, v, u, s=spec, st=stride: apply_factors(
                x, v, u, p, s, "conv", stride=st))
            unf = jax.jit(lambda x, v, u, s=spec, st=stride: apply_factors(
                x, v, u, p, s, "conv", stride=st, fused=False))

            def mat(x, v, u, s=spec, st=stride, k=k):
                w = compose(v, u, p, s)
                w4 = w.reshape(k, k, w.shape[1], w.shape[2])
                return jax.lax.conv_general_dilated(
                    x, w4, (st, st), "SAME", dimension_numbers=dn)

            matf = jax.jit(mat)
            legs = {"fused": lambda: fused(x, v, red),
                    "unfused": lambda: unf(x, v, red),
                    "materialize": lambda: matf(x, v, red)}
            med = _median_interleaved(legs, repeats, iters)
            cell = {"layer": name, "kind": "conv", "count": 1,
                    "fused_s": med["fused"], "unfused_s": med["unfused"],
                    "materialize_s": med["materialize"],
                    "fused_vs_unfused": med["unfused"] / med["fused"],
                    "fused_vs_materialize":
                        med["materialize"] / med["fused"]}
        else:
            M = 16 * max(1, min(hint.apps_per_sample, 32))
            x = jax.random.normal(ks[1], (M, g * spec.base_in))
            fusd = jax.jit(lambda x, v, u, m=spec.mode: compose_dense_apply(
                x, v, u, p, m))
            sep = jax.jit(lambda x, v, u, s=spec: x @ compose(
                v, u, p, s)[0])
            legs = {"fused": lambda: fusd(x, v, red),
                    "separate": lambda: sep(x, v, red)}
            med = _median_interleaved(legs, repeats, iters)
            cell = {"layer": name, "kind": "dense", "count": 1,
                    "rows": M, "fused_s": med["fused"],
                    "separate_s": med["separate"],
                    "fused_vs_separate": med["separate"] / med["fused"]}
        seen[sig] = cell
        cells[name] = cell
    conv = [c for c in cells.values() if c["kind"] == "conv"]
    dense = [c for c in cells.values() if c["kind"] == "dense"]
    out = {"layers": cells}
    if conv:
        tf = sum(c["fused_s"] * c["count"] for c in conv)
        tu = sum(c["unfused_s"] * c["count"] for c in conv)
        tm = sum(c["materialize_s"] * c["count"] for c in conv)
        out["conv"] = {"fused_s": tf, "unfused_s": tu, "materialize_s": tm,
                       "fused_vs_unfused": tu / tf,
                       "fused_vs_materialize": tm / tf}
    if dense:
        tf = sum(c["fused_s"] * c["count"] for c in dense)
        ts = sum(c["separate_s"] * c["count"] for c in dense)
        out["dense"] = {"fused_s": tf, "separate_s": ts,
                        "fused_vs_separate": ts / tf}
    return out


def bench_round(task: str, width: int, forward_impl: str, rounds: int,
                warmup: int) -> float:
    """Per-round cohort time with every client pinned to ``width``."""
    from repro.fl import (FLConfig, build_image_setup, build_runner,
                          build_text_setup)

    if task == "rnn":
        model, px, py, test = build_text_setup(num_clients=10, seed=0)
    else:
        model, px, py, test = build_image_setup(num_clients=10, seed=0)
    cfg = FLConfig(num_clients=10, clients_per_round=10, tau_fixed=10,
                   eval_every=10_000, estimate=False, trainer="cohort",
                   seed=0, forward_impl=forward_impl)
    # flanc assigns width by hardware tier — force a uniform-tier network
    # (TIER_NAMES order: laptop=3, agx_xavier=2, xavier_nx/tx2=1) so the
    # whole cohort trains at the target width
    tier_weights = {3: (1.0, 0.0, 0.0, 0.0), 2: (0.0, 1.0, 0.0, 0.0),
                    1: (0.0, 0.0, 0.0, 1.0)}[width]
    eng = build_runner("flanc", model, px, py, test, cfg=cfg,
                       tier_weights=tier_weights)
    for _ in range(warmup):
        eng.run_round()
    t0 = time.perf_counter()
    for _ in range(rounds):
        eng.run_round()
    return (time.perf_counter() - t0) / rounds


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="1 repeat, fewer rounds (the CI 4-device leg)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    repeats = 1 if args.smoke else 3
    rounds = 2 if args.smoke else 5
    warmup = 2
    widths = (3,) if args.smoke else (1, 2, 3)
    micro_iters = 5 if args.smoke else 50

    results = {}
    for task in ("cnn", "resnet", "rnn", "transformer"):
        results[task] = {"micro": {}}
        for width in widths:
            cell = micro_rank_paths(task, width, repeats, micro_iters)
            results[task]["micro"][f"width_{width}"] = cell
            bits = []
            if "conv" in cell:
                bits.append(f"conv fused vs unfused "
                            f"{cell['conv']['fused_vs_unfused']:.2f}x, "
                            f"vs materialize "
                            f"{cell['conv']['fused_vs_materialize']:.2f}x")
            if "dense" in cell:
                bits.append(f"dense fused vs separate "
                            f"{cell['dense']['fused_vs_separate']:.2f}x")
            print(f"{task} width {width} micro: " + "   ".join(bits))

    for task in ("cnn", "rnn"):
        results[task]["flops"] = flops_table(task)
        for width in widths:
            times = {"materialize": [], "rank_space": []}
            for _ in range(repeats):
                for impl in ("materialize", "rank_space"):  # interleaved
                    # warmup every run: the two impls compile DIFFERENT
                    # cohort steps (forward_impl keys the jit cache), so
                    # round 1 of each fresh engine pays its own compile
                    times[impl].append(
                        bench_round(task, width, impl, rounds, warmup))
            med = {k: statistics.median(v) for k, v in times.items()}
            cell = {
                "materialize_per_round_s": med["materialize"],
                "rank_space_per_round_s": med["rank_space"],
                "speedup": med["materialize"] / med["rank_space"],
                "flops_ratio":
                    results[task]["flops"][f"width_{width}"]["flops_ratio"],
                "rounds_timed": rounds, "repeats": repeats,
            }
            results[task][f"width_{width}"] = cell
            print(f"{task} width {width}: materialize "
                  f"{med['materialize']*1e3:8.1f} ms/round   rank_space "
                  f"{med['rank_space']*1e3:8.1f} ms/round   speedup "
                  f"{cell['speedup']:.2f}x   (flops ratio "
                  f"{cell['flops_ratio']:.2f}x)")

    import common

    out = {
        "benchmark": "compose_rank_space_vs_materialize",
        "setup": {"scheme": "flanc", "num_clients": 10,
                  "clients_per_round": 10, "tau": 10, "batch_size": 16,
                  "trainer": "cohort",
                  "note": "uniform-tier network pins every client to the "
                          "target width; flops tables use the static "
                          "model the auto knob reads; micro cells time "
                          "the fused rank-path primitives vs their "
                          "separate-ops formulations per unique layer "
                          "shape (batch 16, 8x8 reference images)"},
        "provenance": common.provenance(),
        "results": results,
    }
    path = Path(args.out) if args.out else \
        Path(__file__).resolve().parents[1] / "BENCH_compose.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
