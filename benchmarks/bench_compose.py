"""Rank-space vs materialize client compute through the cohort hot loop.

Times full cohort-trainer rounds (the one compiled vmap+scan call per
round) with ``FLConfig.forward_impl`` pinned to ``materialize`` vs
``rank_space`` at widths 1..3 on the cnn and rnn models — every client
in the cohort is forced to the same width so each round isolates one
(model, width, impl) cell.  Same protocol as BENCH_engine: repeats are
*interleaved* (mat, rank, mat, rank, ...) and the per-impl median is
reported, so slow-neighbor noise on shared boxes doesn't land on one
side of the ratio.

Alongside the timings the static FLOPs model is recorded for every
width: per-layer ``apply_flops`` / ``compose_flops + dense_apply_flops``
and the model-level ratio, i.e. the number the ``auto`` knob acts on.

Usage:  PYTHONPATH=src python benchmarks/bench_compose.py [--smoke]
Writes BENCH_compose.json next to the repo root (override with --out).
"""

from __future__ import annotations

import argparse
import json
import statistics
import time
from pathlib import Path

import sys

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def flops_table(model_name: str) -> dict:
    """apply vs compose+dense-apply FLOPs per training batch, per width."""
    from repro.core.composition import (apply_flops, compose_flops,
                                        dense_apply_flops)
    from repro.fl.models import MODELS, LayerHint

    model = MODELS[model_name]()
    batch = 16
    out = {}
    for p in (1, 2, 3):
        layers = {}
        rank_total = mat_total = 0
        for name, spec in model.specs.items():
            hint = (model.hints or {}).get(name, LayerHint())
            apps = batch * hint.apps_per_sample
            rank = apply_flops(p, spec, applications=apps,
                               basis_is_gather=hint.basis_gather)
            dense = 0 if hint.dense_apply_free else dense_apply_flops(
                p, spec, applications=apps)
            mat = compose_flops(p, spec) + dense
            if not hint.rank_capable:  # pinned to materialize (scan reuse)
                rank = mat
            layers[name] = {"apply_flops": rank, "materialize_flops": mat}
            rank_total += rank
            mat_total += mat
        out[f"width_{p}"] = {
            "layers": layers,
            "rank_space_flops": rank_total,
            "materialize_flops": mat_total,
            "flops_ratio": mat_total / rank_total,
        }
    return out


def bench_round(task: str, width: int, forward_impl: str, rounds: int,
                warmup: int) -> float:
    """Per-round cohort time with every client pinned to ``width``."""
    from repro.fl import (FLConfig, build_image_setup, build_runner,
                          build_text_setup)

    if task == "rnn":
        model, px, py, test = build_text_setup(num_clients=10, seed=0)
    else:
        model, px, py, test = build_image_setup(num_clients=10, seed=0)
    cfg = FLConfig(num_clients=10, clients_per_round=10, tau_fixed=10,
                   eval_every=10_000, estimate=False, trainer="cohort",
                   seed=0, forward_impl=forward_impl)
    # flanc assigns width by hardware tier — force a uniform-tier network
    # (TIER_NAMES order: laptop=3, agx_xavier=2, xavier_nx/tx2=1) so the
    # whole cohort trains at the target width
    tier_weights = {3: (1.0, 0.0, 0.0, 0.0), 2: (0.0, 1.0, 0.0, 0.0),
                    1: (0.0, 0.0, 0.0, 1.0)}[width]
    eng = build_runner("flanc", model, px, py, test, cfg=cfg,
                       tier_weights=tier_weights)
    for _ in range(warmup):
        eng.run_round()
    t0 = time.perf_counter()
    for _ in range(rounds):
        eng.run_round()
    return (time.perf_counter() - t0) / rounds


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="1 repeat, fewer rounds (the CI 4-device leg)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    repeats = 1 if args.smoke else 3
    rounds = 2 if args.smoke else 5
    warmup = 2

    results = {}
    for task in ("cnn", "rnn"):
        results[task] = {"flops": flops_table(task)}
        widths = (3,) if args.smoke else (1, 2, 3)
        for width in widths:
            times = {"materialize": [], "rank_space": []}
            for _ in range(repeats):
                for impl in ("materialize", "rank_space"):  # interleaved
                    # warmup every run: the two impls compile DIFFERENT
                    # cohort steps (forward_impl keys the jit cache), so
                    # round 1 of each fresh engine pays its own compile
                    times[impl].append(
                        bench_round(task, width, impl, rounds, warmup))
            med = {k: statistics.median(v) for k, v in times.items()}
            cell = {
                "materialize_per_round_s": med["materialize"],
                "rank_space_per_round_s": med["rank_space"],
                "speedup": med["materialize"] / med["rank_space"],
                "flops_ratio":
                    results[task]["flops"][f"width_{width}"]["flops_ratio"],
                "rounds_timed": rounds, "repeats": repeats,
            }
            results[task][f"width_{width}"] = cell
            print(f"{task} width {width}: materialize "
                  f"{med['materialize']*1e3:8.1f} ms/round   rank_space "
                  f"{med['rank_space']*1e3:8.1f} ms/round   speedup "
                  f"{cell['speedup']:.2f}x   (flops ratio "
                  f"{cell['flops_ratio']:.2f}x)")

    import common

    out = {
        "benchmark": "compose_rank_space_vs_materialize",
        "setup": {"scheme": "flanc", "num_clients": 10,
                  "clients_per_round": 10, "tau": 10, "batch_size": 16,
                  "trainer": "cohort",
                  "note": "uniform-tier network pins every client to the "
                          "target width; flops tables use the static "
                          "model the auto knob reads"},
        "provenance": common.provenance(),
        "results": results,
    }
    path = Path(args.out) if args.out else \
        Path(__file__).resolve().parents[1] / "BENCH_compose.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
