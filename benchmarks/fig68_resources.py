"""Figs. 6/8: resource consumption (traffic + time) to target accuracy."""

from __future__ import annotations

from benchmarks.common import SCHEMES, csv_row, quick_cfg, run_all_schemes
from repro.fl import build_image_setup, time_to_accuracy, traffic_to_accuracy


def run(rounds: int = 40, target: float = 0.6):
    model, px, py, test = build_image_setup(num_clients=20, seed=1)
    cfg = quick_cfg()
    hists = run_all_schemes(model, px, py, test, rounds, cfg)
    rows = []
    tr_h = traffic_to_accuracy(hists["heroes"], target)
    for scheme, hist in hists.items():
        tr = traffic_to_accuracy(hist, target)
        tt = time_to_accuracy(hist, target)
        rows.append(csv_row(f"fig68/{scheme}/traffic_to_{int(target*100)}pct",
                            f"{tr/1e6:.2f}" if tr else "unreached", "MB"))
        rows.append(csv_row(f"fig68/{scheme}/time_to_{int(target*100)}pct",
                            f"{tt:.2f}" if tt else "unreached", "virtual_s"))
    if tr_h:
        dense_saved, all_saved = [], []
        for scheme in SCHEMES:
            if scheme == "heroes":
                continue
            tr = traffic_to_accuracy(hists[scheme], target)
            if tr:
                all_saved.append(1 - tr_h / tr)
                if scheme in ("fedavg", "adp", "heterofl"):
                    dense_saved.append(1 - tr_h / tr)
        if dense_saved:
            rows.append(csv_row(
                "fig68/heroes_traffic_reduction_vs_dense",
                f"{100*sum(dense_saved)/len(dense_saved):.1f}",
                "pct_avg vs FedAvg/ADP/HeteroFL (paper headline: 72%)"))
        if all_saved:
            rows.append(csv_row("fig68/heroes_traffic_reduction_all",
                                f"{100*sum(all_saved)/len(all_saved):.1f}",
                                "pct_avg incl. Flanc"))
    return rows
