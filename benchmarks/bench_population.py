"""Population benchmark: virtual-client scaling vs the resident baseline.

A Heroes round should cost O(cohort), not O(population): the registry
derives profiles/shards/rng streams on demand from ``(seed, client_id)``
and keeps nothing resident per client.  This benchmark runs the same
24-client cohort against a resident 24-client baseline and virtual
populations of 10^3 / 10^4 / 10^5 clients, and records per-round wall
time and peak RSS for each.  Each leg runs in its own subprocess so
``ru_maxrss`` (which only ever grows) is an independent per-leg peak.

Acceptance (ISSUE): at 10^5 virtual clients, per-round wall <= 1.2x and
peak RSS <= 1.5x of the baseline.  Writes ``BENCH_population.json`` next
to the repo root.

Usage:  PYTHONPATH=src python benchmarks/bench_population.py \
            [--smoke] [--rss-mb N] [--out PATH]

``--smoke`` runs only the baseline and the 10^5 leg (CI); ``--rss-mb``
adds a hard ceiling on any leg's peak RSS (the CI leg pins the memory
envelope with it).
"""

from __future__ import annotations

import argparse
import json
import os
import resource
import statistics
import subprocess
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

COHORT = 24


def bench_rounds(population: int, rounds: int, warmup: int) -> dict:
    """Worker body: timed Heroes rounds at one population size.

    ``population == 0`` is the resident baseline (24 materialized
    clients, the pre-population code path); anything else virtualizes.
    """
    from repro.fl import FLConfig, build_runner, build_setup

    t0 = time.perf_counter()
    if population:
        model, px, py, test = build_setup(
            "synthetic_image", seed=0, population=population,
            partition_kw={"samples_per_client": 64})
        num_clients = population
    else:
        model, px, py, test = build_setup("synthetic_image",
                                          num_clients=COHORT, seed=0)
        num_clients = COHORT
    cfg = FLConfig(num_clients=num_clients, clients_per_round=COHORT,
                   tau_fixed=5, eval_every=10_000, estimate=True, seed=0)
    eng = build_runner("heroes", model, px, py, test, cfg=cfg, seed=0)
    setup_s = time.perf_counter() - t0
    for _ in range(warmup):
        eng.run_round()
    times = []
    for _ in range(rounds):
        t0 = time.perf_counter()
        eng.run_round()
        times.append(time.perf_counter() - t0)
    eng.close()
    rss_kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    return {"population": population or COHORT,
            "virtual": bool(population),
            "cohort": COHORT, "rounds": rounds,
            "setup_s": setup_s,
            "per_round_s": statistics.median(times),
            "peak_rss_kb": rss_kb}


def run_leg(population: int, rounds: int, warmup: int) -> dict:
    """Run one population size in a fresh subprocess (independent RSS)."""
    cmd = [sys.executable, __file__, "--_worker",
           "--population", str(population),
           "--rounds", str(rounds), "--warmup", str(warmup)]
    r = subprocess.run(cmd, env=os.environ, capture_output=True, text=True)
    if r.returncode != 0:
        raise RuntimeError(f"population worker (pop={population}) failed:\n"
                           f"{r.stderr[-2000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="baseline + 10^5 leg only (CI)")
    ap.add_argument("--rss-mb", type=float, default=0.0,
                    help="hard ceiling on any leg's peak RSS, in MB")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: repo-root "
                         "BENCH_population.json)")
    ap.add_argument("--_worker", action="store_true", dest="worker",
                    help=argparse.SUPPRESS)
    ap.add_argument("--population", type=int, default=0)
    ap.add_argument("--rounds", type=int, default=0)
    ap.add_argument("--warmup", type=int, default=3)
    args = ap.parse_args()

    if args.worker:
        print(json.dumps(bench_rounds(args.population, args.rounds or 3,
                                      args.warmup)))
        return

    rounds = args.rounds or (3 if args.smoke else 5)
    populations = [0, 10**5] if args.smoke else [0, 10**3, 10**4, 10**5]

    legs = []
    for pop in populations:
        leg = run_leg(pop, rounds, args.warmup)
        legs.append(leg)
        label = ("baseline(resident)" if not leg["virtual"]
                 else f"virtual 10^{len(str(leg['population'])) - 1}")
        print(f"{label:22s} pop {leg['population']:>7d}: "
              f"{leg['per_round_s'] * 1e3:8.1f} ms/round   "
              f"peak RSS {leg['peak_rss_kb'] / 1024:7.1f} MB   "
              f"setup {leg['setup_s']:5.2f} s")

    base = legs[0]
    for leg in legs[1:]:
        leg["wall_ratio_vs_baseline"] = (leg["per_round_s"]
                                         / base["per_round_s"])
        leg["rss_ratio_vs_baseline"] = (leg["peak_rss_kb"]
                                        / base["peak_rss_kb"])
    top = legs[-1]
    print(f"10^5 leg: wall {top['wall_ratio_vs_baseline']:.2f}x, "
          f"RSS {top['rss_ratio_vs_baseline']:.2f}x of baseline "
          f"(targets: <=1.2x wall, <=1.5x RSS)")

    if args.rss_mb:
        worst = max(leg["peak_rss_kb"] for leg in legs) / 1024
        if worst > args.rss_mb:
            raise SystemExit(f"peak RSS {worst:.0f} MB exceeds the "
                             f"--rss-mb {args.rss_mb:.0f} MB ceiling")
        print(f"peak RSS {worst:.0f} MB within the "
              f"{args.rss_mb:.0f} MB ceiling")

    import common

    out = {
        "benchmark": "population_virtual_scaling",
        "setup": {"scheme": "heroes", "task": "synthetic_image",
                  "cohort": COHORT, "tau": 5, "samples_per_client": 64,
                  "rounds_timed": rounds, "warmup_rounds": args.warmup},
        "provenance": common.provenance(),
        "baseline": base,
        "scaling": legs[1:],
    }
    path = Path(args.out) if args.out else \
        Path(__file__).resolve().parents[1] / "BENCH_population.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
