"""Fig. 7: accuracy under different Non-IID levels within a time budget."""

from __future__ import annotations

from benchmarks.common import csv_row, quick_cfg, run_all_schemes
from repro.fl import build_image_setup


def _acc_at_time(history, budget_s):
    best = 0.0
    for h in history:
        if h.wall_time > budget_s:
            break
        if h.accuracy is not None:
            best = max(best, h.accuracy)
    return best


def run(rounds: int = 30, gammas=(20.0, 60.0)):
    rows = []
    for gamma in gammas:
        model, px, py, test = build_image_setup(num_clients=20, gamma=gamma, seed=2)
        cfg = quick_cfg()
        hists = run_all_schemes(model, px, py, test, rounds, cfg,
                                schemes=["fedavg", "heterofl", "flanc", "heroes"])
        budget = hists["fedavg"][-1].wall_time * 0.75
        for scheme, hist in hists.items():
            rows.append(csv_row(
                f"fig7/gamma{int(gamma)}/{scheme}",
                f"{_acc_at_time(hist, budget):.4f}", f"budget={budget:.1f}s"))
    return rows
