"""Federated composed transformer benchmark: round time + decode tokens/s.

Two numbers close the training->serving loop (docs/TRANSFORMERS.md):

  * federated round time — Heroes (factorized) and FedAvg (dense)
    rounds of the transformer ``FLModelDef`` through the engine, timed
    after a jit warmup round;
  * decode tokens/s — per-width weights composed ONCE from the trained
    server state, then token-by-token greedy decode through the Pallas
    decode-attention kernel (``kernels/decode_attention.py``; interpret
    mode on CPU hosts, compiled on TPU) and through the inline XLA
    reference for comparison, timed after a warmup generation.

Writes ``BENCH_transformer.json`` next to the repo root with
``benchmarks/common.provenance()`` stamped.

Usage:  PYTHONPATH=src python benchmarks/bench_transformer.py [--fast|--smoke]
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent))

from common import provenance  # noqa: E402


def bench_rounds(scheme: str, model, px, py, test, cfg, *, warmup: int,
                 rounds: int) -> dict:
    from repro.fl import build_runner

    with build_runner(scheme, model, px, py, test, cfg=cfg, seed=0) as eng:
        for _ in range(warmup):
            eng.run_round()
        t0 = time.perf_counter()
        for _ in range(rounds):
            eng.run_round()
        dt = time.perf_counter() - t0
        return {"scheme": scheme, "rounds": rounds, "total_s": dt,
                "per_round_s": dt / rounds,
                "params": eng.state.params}


def bench_decode(model, params, width: int, backend: str, *, batch: int,
                 steps: int) -> dict:
    import numpy as np

    from repro.fl import greedy_decode, serving_weights

    weights = serving_weights(model, params, width)
    prompt = (np.arange(batch * 8, dtype=np.int32).reshape(batch, 8)
              % model.num_classes)
    greedy_decode(model, weights, width, prompt, steps, backend=backend)
    t0 = time.perf_counter()
    tokens, _ = greedy_decode(model, weights, width, prompt, steps,
                              backend=backend)
    dt = time.perf_counter() - t0
    n = int(tokens.shape[0] * tokens.shape[1])
    return {"width": width, "backend": backend, "batch": batch,
            "steps": steps, "total_s": dt, "tokens_per_s": n / dt}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="minimal shapes (CI 4-device leg)")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out", default=str(
        Path(__file__).resolve().parents[1] / "BENCH_transformer.json"))
    args = ap.parse_args()

    from repro.fl import FLConfig, build_text_setup

    if args.smoke:
        warmup, rounds, batch, steps = 1, 1, 2, 8
    elif args.fast:
        warmup, rounds, batch, steps = 1, 2, 2, 16
    else:
        warmup, rounds, batch, steps = 2, 5, 4, 32

    num_clients = 8
    model, px, py, test = build_text_setup(
        num_clients=num_clients, max_width=3, seed=0,
        model_name="transformer")
    cfg = FLConfig(num_clients=num_clients, clients_per_round=4,
                   batch_size=8, tau_fixed=5, eval_every=10_000,
                   estimate=True, seed=0)

    results = {"round_time": [], "decode": []}
    heroes_params = None
    for scheme in ("heroes", "fedavg"):
        r = bench_rounds(scheme, model, px, py, test, cfg,
                         warmup=warmup, rounds=rounds)
        if scheme == "heroes":
            heroes_params = r.pop("params")
        else:
            r.pop("params")
        print(f"# {scheme}: {r['per_round_s']:.2f}s/round", file=sys.stderr)
        results["round_time"].append(r)

    max_width = model.specs["head"].max_width
    widths = (1, max_width) if args.smoke else tuple(range(1, max_width + 1))
    for width in widths:
        for backend in ("pallas", "xla"):
            d = bench_decode(model, heroes_params, width, backend,
                             batch=batch, steps=steps)
            print(f"# decode w={width} {backend}: "
                  f"{d['tokens_per_s']:.1f} tok/s", file=sys.stderr)
            results["decode"].append(d)

    out = {"provenance": provenance(), "config": {
        "num_clients": num_clients, "batch_size": cfg.batch_size,
        "tau_fixed": cfg.tau_fixed, "mode": (
            "smoke" if args.smoke else "fast" if args.fast else "full")},
        **results}
    Path(args.out).write_text(json.dumps(out, indent=2, sort_keys=True))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
