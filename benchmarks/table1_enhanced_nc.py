"""Table I: training performance within resource constraints.

Enhanced NC (Heroes' composition, fixed tau to isolate the NC effect)
vs original NC (Flanc) vs model pruning (HeteroFL), evaluated at fixed
traffic and fixed wall-time budgets (reduced-scale analogues of the
paper's 30/60 GB and 20k/40k s columns).
"""

from __future__ import annotations

from benchmarks.common import csv_row, quick_cfg, run_all_schemes
from repro.fl import build_image_setup


def _acc_at_traffic(history, budget_bytes):
    best = 0.0
    for h in history:
        if h.traffic_bytes > budget_bytes:
            break
        if h.accuracy is not None:
            best = max(best, h.accuracy)
    return best


def _acc_at_time(history, budget_s):
    best = 0.0
    for h in history:
        if h.wall_time > budget_s:
            break
        if h.accuracy is not None:
            best = max(best, h.accuracy)
    return best


def run(rounds: int = 40):
    model, px, py, test = build_image_setup(num_clients=20, seed=0)
    cfg = quick_cfg()
    # isolate the composition effect: same fixed tau for every scheme
    hists = run_all_schemes(model, px, py, test, rounds, cfg,
                            schemes=["heterofl", "flanc", "heroes"])
    label = {"heterofl": "MP", "flanc": "orig_NC", "heroes": "enhanced_NC"}
    # budgets: half / full of the median scheme's final consumption
    ref = hists["flanc"][-1]
    t_budgets = [ref.wall_time * 0.5, ref.wall_time]
    g_budgets = [ref.traffic_bytes * 0.5, ref.traffic_bytes]
    rows = []
    for scheme, hist in hists.items():
        for i, g in enumerate(g_budgets):
            rows.append(csv_row(
                f"table1/{label[scheme]}/traffic_budget_{i}",
                f"{_acc_at_traffic(hist, g):.4f}",
                f"budget={g/1e6:.1f}MB"))
        for i, t in enumerate(t_budgets):
            rows.append(csv_row(
                f"table1/{label[scheme]}/time_budget_{i}",
                f"{_acc_at_time(hist, t):.4f}",
                f"budget={t:.1f}s"))
    return rows
