"""Fig. 5: average per-round waiting time (client heterogeneity impact)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, quick_cfg, run_all_schemes
from repro.fl import build_image_setup


def run(rounds: int = 12):
    model, px, py, test = build_image_setup(num_clients=20, seed=0)
    cfg = quick_cfg()
    hists = run_all_schemes(model, px, py, test, rounds, cfg)
    rows = []
    for scheme, hist in hists.items():
        waits = [h.avg_wait for h in hist]
        rows.append(csv_row(f"fig5/{scheme}/avg_wait",
                            f"{float(np.mean(waits)):.3f}", "virtual_s"))
        rows.append(csv_row(f"fig5/{scheme}/makespan",
                            f"{float(np.mean([h.makespan for h in hist])):.3f}",
                            "virtual_s"))
    return rows
