"""Fig. 4: training performance (accuracy vs virtual wall time), all schemes."""

from __future__ import annotations

from benchmarks.common import SCHEMES, csv_row, quick_cfg, run_all_schemes
from repro.fl import build_image_setup, time_to_accuracy


def run(rounds: int = 40, target: float = 0.6):
    model, px, py, test = build_image_setup(num_clients=20, seed=0)
    cfg = quick_cfg()
    hists = run_all_schemes(model, px, py, test, rounds, cfg)
    rows = []
    for scheme, hist in hists.items():
        accs = [(h.wall_time, h.accuracy) for h in hist if h.accuracy is not None]
        final = accs[-1][1] if accs else float("nan")
        rows.append(csv_row(f"fig4/{scheme}/final_acc", f"{final:.4f}",
                            f"wall={hist[-1].wall_time:.1f}s"))
        tta = time_to_accuracy(hist, target)
        rows.append(csv_row(
            f"fig4/{scheme}/time_to_{int(target*100)}pct",
            f"{tta:.2f}" if tta else "unreached", "virtual_s"))
    # speedup of heroes vs each baseline
    t_h = time_to_accuracy(hists["heroes"], target)
    if t_h:
        for scheme in SCHEMES:
            if scheme == "heroes":
                continue
            t_b = time_to_accuracy(hists[scheme], target)
            if t_b:
                rows.append(csv_row(f"fig4/speedup_vs_{scheme}",
                                    f"{t_b/t_h:.2f}", "x"))
    return rows
