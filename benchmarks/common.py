"""Shared helpers for the paper-figure benchmarks (reduced-scale CPU runs)."""

from __future__ import annotations

import sys
import time
from pathlib import Path
from typing import Dict, List

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.fl import (FLConfig, build_image_setup, build_text_setup,  # noqa: E402
                      run_scheme, summarize, time_to_accuracy,
                      traffic_to_accuracy)

SCHEMES = ["fedavg", "adp", "heterofl", "flanc", "heroes"]


def quick_cfg(num_clients: int = 20) -> FLConfig:
    return FLConfig(
        num_clients=num_clients, clients_per_round=5, eval_every=2,
        tau_fixed=5, tau_max=25, lr=0.08, batch_size=16, estimate=True,
    )


def run_all_schemes(model, px, py, test, rounds: int, cfg: FLConfig,
                    schemes=None) -> Dict[str, list]:
    out = {}
    for scheme in schemes or SCHEMES:
        t0 = time.time()
        out[scheme] = run_scheme(scheme, model, px, py, test, rounds, cfg)
        print(f"# {scheme}: {time.time()-t0:.1f}s real", file=sys.stderr)
    return out


def csv_row(name: str, value, derived: str = "") -> str:
    return f"{name},{value},{derived}"
