"""Shared helpers for the paper-figure benchmarks (reduced-scale CPU runs)."""

from __future__ import annotations

import sys
import time
from pathlib import Path
from typing import Dict, List

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.fl import (FLConfig, build_image_setup, build_text_setup,  # noqa: E402
                      run_scheme, summarize, time_to_accuracy,
                      traffic_to_accuracy)

SCHEMES = ["fedavg", "adp", "heterofl", "flanc", "heroes"]


def provenance() -> Dict:
    """Environment fingerprint stamped into every ``BENCH_*.json``: a
    recorded number is only comparable to another number from the same
    toolchain/host class, so each entry carries the jax version, device
    kind/count, cpu count and git sha it was measured under.  Delegates
    to :func:`repro.obs.runtime_provenance` (never raises)."""
    from repro.obs import runtime_provenance

    return runtime_provenance()


def quick_cfg(num_clients: int = 20, **overrides) -> FLConfig:
    base = dict(
        num_clients=num_clients, clients_per_round=5, eval_every=2,
        tau_fixed=5, tau_max=25, lr=0.08, batch_size=16, estimate=True,
    )
    base.update(overrides)
    return FLConfig(**base)


def data_setup(task: str = "synthetic_image", num_clients: int = 20,
               seed: int = 0, **kw):
    """Registry-driven setup for figure benches: any registered dataset
    (``synthetic_image``/``cifar10``/``synthetic_text``/``shakespeare``)
    under its default partitioner; kwargs pass through to
    :func:`repro.fl.simulation.build_setup` (``partitioner=``,
    ``data_root=``, ``task_kw=``, ...)."""
    from repro.fl.simulation import build_setup

    return build_setup(task, num_clients=num_clients, seed=seed, **kw)


def run_all_schemes(model, px, py, test, rounds: int, cfg: FLConfig,
                    schemes=None) -> Dict[str, list]:
    out = {}
    for scheme in schemes or SCHEMES:
        t0 = time.time()
        out[scheme] = run_scheme(scheme, model, px, py, test, rounds, cfg)
        print(f"# {scheme}: {time.time()-t0:.1f}s real", file=sys.stderr)
    return out


def csv_row(name: str, value, derived: str = "") -> str:
    return f"{name},{value},{derived}"
