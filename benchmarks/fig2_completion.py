"""Fig. 2: ranked per-client completion time, fixed vs adaptive tau.

The paper observes (a) the fastest client finishes ~4x sooner than the
slowest under fixed identical tau, wasting ~70% of the fast client's
time, and (b) adaptive frequencies flatten the profile.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row
from repro.core import BoundState
from repro.core.composition import CompositionSpec
from repro.core.scheduler import HeroesScheduler, SchedulerConfig
from repro.fl.heterogeneity import HeterogeneityModel
from repro.fl.models import make_cnn


def run(num_clients: int = 20, tau_fixed: int = 10, flops_scale: float = 200.0):
    """flops_scale lifts the toy CNN to the paper's ResNet-18 compute
    regime, where tau*mu is comparable to the upload time nu — the regime
    Fig. 2 is about (with a tiny model, completion is bandwidth-bound and
    no local-update policy can balance it)."""
    het = HeterogeneityModel(num_clients, seed=0,
                             tier_weights=(0.05, 0.15, 0.3, 0.5))
    model = make_cnn(max_width=3)
    flops = lambda p: model.flops_per_sample(p) * 16 * flops_scale
    bytes_p = lambda p: model.factorized_bytes(p)
    het.advance_round()

    # fixed identical tau, width 3 (FedAvg-style)
    t_fixed = {n: tau_fixed * het.iter_time(n, flops(3))
               + het.upload_time(n, bytes_p(3)) for n in range(num_clients)}
    mk = max(t_fixed.values())
    spread = mk / min(t_fixed.values())
    idle = float(np.mean([(mk - t) / mk for t in t_fixed.values()]))

    # Heroes adaptive assignment
    spec = next(s for s in model.specs.values() if s.mode == "square")
    med = float(np.median([het.iter_time(n, flops(1)) for n in range(num_clients)]))
    sched = HeroesScheduler(
        spec, SchedulerConfig(mu_max=10 * med, rho=0.02 * mk, eps=1.0,
                              tau_max=100),
        iter_time_fn=lambda n, p: het.iter_time(n, flops(p)),
        comm_time_fn=lambda n, p: het.upload_time(n, bytes_p(p)),
    )
    state = BoundState(loss0=2.3, smoothness=1.0, grad_sq=1.0, noise_sq=0.3,
                       lr=0.05)
    plan = sched.plan_round(list(range(num_clients)), state)
    t_adap = {n: a.est_completion for n, a in plan.assignments.items()}
    mk2 = max(t_adap.values())
    spread2 = mk2 / min(t_adap.values())
    idle2 = float(np.mean([(mk2 - t) / mk2 for t in t_adap.values()]))

    return [
        csv_row("fig2/fixed_tau/completion_spread", f"{spread:.2f}",
                "max/min (paper: ~4x)"),
        csv_row("fig2/fixed_tau/idle_fraction", f"{idle:.3f}",
                "mean (paper: ~0.7 for the fastest)"),
        csv_row("fig2/adaptive/completion_spread", f"{spread2:.2f}", ""),
        csv_row("fig2/adaptive/idle_fraction", f"{idle2:.3f}", ""),
    ]
