"""Benchmark harness entry: one section per paper table/figure + roofline.

Prints ``name,value,derived`` CSV rows.  Reduced-scale CPU analogues of
the paper's experiments (see DESIGN.md §9 for the mapping).
"""

from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: table1,fig4,fig5,fig68,fig7,fig9,roofline,ablations")
    ap.add_argument("--fast", action="store_true",
                    help="cheap analytic sections only (CI smoke)")
    args = ap.parse_args()

    from benchmarks import (ablations, fig2_completion, fig4_training,
                            fig5_waiting, fig7_noniid, fig9_text,
                            fig68_resources, roofline, table1_enhanced_nc)

    sections = {
        "table1": table1_enhanced_nc.run,
        "fig2": fig2_completion.run,
        "fig4": fig4_training.run,
        "fig5": fig5_waiting.run,
        "fig68": fig68_resources.run,
        "fig7": fig7_noniid.run,
        "fig9": fig9_text.run,
        "roofline": roofline.run,
        "ablations": ablations.run,
    }
    if args.only:
        wanted = args.only.split(",")
    elif args.fast:
        wanted = ["fig2"]  # host-side analytic section, no training
    else:
        wanted = list(sections)

    print("name,value,derived")
    for name in wanted:
        t0 = time.time()
        try:
            rows = sections[name]()
            for row in rows:
                print(row)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,{e!r},")
        print(f"{name}/_elapsed,{time.time()-t0:.1f},seconds", flush=True)


if __name__ == "__main__":
    main()
