"""Ablations beyond the paper's tables.

* rank sweep — the basis/coefficient rank R trades capacity vs traffic
  (the paper fixes R; we expose the knob the technique hinges on).
* rho sweep — the waiting-time bound (Eq. 24) trades straggler slack vs
  per-round tau freedom.
* block-balance ablation — variance-minimising tau search ON vs OFF
  (naive upper-bound tau), isolating the V^h objective's contribution.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, quick_cfg
from repro.fl import (FLConfig, build_image_setup, build_runner, run_scheme,
                      summarize)
from repro.fl.models import make_cnn
from repro.data import SyntheticImageTask, dirichlet_partition
import jax.numpy as jnp


def _setup(rank: int, num_clients: int = 20, seed: int = 0):
    task = SyntheticImageTask(seed=seed, noise=1.2)
    model = make_cnn(max_width=3, rank=rank)
    parts = dirichlet_partition(task.y_train, num_clients, 40.0, seed)
    px = [task.x_train[p] for p in parts]
    py = [task.y_train[p] for p in parts]
    test = {"x": jnp.asarray(task.x_test), "labels": jnp.asarray(task.y_test)}
    return model, px, py, test


def run(rounds: int = 16):
    rows = []
    # --- rank sweep -------------------------------------------------------
    for rank in (4, 8, 16):
        model, px, py, test = _setup(rank)
        hist = run_scheme("heroes", model, px, py, test, rounds,
                          quick_cfg())
        s = summarize(hist)
        rows.append(csv_row(f"ablation/rank{rank}/final_acc",
                            f"{s['final_acc']:.4f}",
                            f"traffic={s['traffic_gb']*1e3:.2f}MB"))
    # --- rho sweep ---------------------------------------------------------
    for rho in (0.05, 0.5, 5.0):
        model, px, py, test = _setup(8, seed=1)
        cfg = quick_cfg()
        cfg.rho = rho
        hist = run_scheme("heroes", model, px, py, test, rounds, cfg)
        s = summarize(hist)
        rows.append(csv_row(f"ablation/rho{rho}/avg_wait",
                            f"{s['avg_wait']:.4f}",
                            f"final_acc={s['final_acc']:.3f}"))
    # --- variance-minimising tau ON vs OFF ---------------------------------
    model, px, py, test = _setup(8, seed=2)
    cfg = quick_cfg()
    for label, patch in (("on", False), ("off", True)):
        runner = build_runner("heroes", model, px, py, test, cfg=cfg, seed=2,
                              tier_weights=(0.05, 0.15, 0.3, 0.5))
        # start from an imbalanced counter state so the search has work
        # to do (fresh counters make tau=hi trivially variance-optimal);
        # the tallies live in the threaded ServerState now
        runner.state.sched.counters[:] = np.arange(9, dtype=np.int64) * 40
        if patch:
            runner.assignment.scheduler._variance_minimising_tau = \
                lambda c, ids, lo, hi: hi
        runner.run(rounds)
        var = runner.assignment.scheduler.counter_variance()
        accs = [h.accuracy for h in runner.history if h.accuracy is not None]
        rows.append(csv_row(f"ablation/vh_search_{label}/counter_variance",
                            f"{var:.1f}", f"final_acc={accs[-1]:.3f}"))
    rows += run_tau_sweep()
    return rows


def run_tau_sweep(rounds: int = 14):
    """Empirical check of the Theorem-1 trade-off: with a fixed time
    budget, accuracy vs fixed tau has an interior optimum (small tau =
    too much sync overhead, large tau = client drift + fewer rounds)."""
    rows = []
    model, px, py, test = _setup(8, seed=3)
    budget = None
    for tau in (1, 5, 15, 40):
        cfg = quick_cfg()
        cfg.tau_fixed = tau
        hist = run_scheme("fedavg", model, px, py, test, rounds, cfg)
        if budget is None:
            budget = hist[-1].wall_time  # anchor on tau=1's total time
        acc = 0.0
        for h in hist:
            if h.wall_time > budget:
                break
            if h.accuracy is not None:
                acc = max(acc, h.accuracy)
        rows.append(csv_row(f"ablation/tau{tau}/acc_at_budget",
                            f"{acc:.4f}", f"budget={budget:.2f}s"))
    return rows
