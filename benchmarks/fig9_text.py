"""Fig. 9: RNN on the text task (Shakespeare stand-in)."""

from __future__ import annotations

from benchmarks.common import csv_row, quick_cfg, run_all_schemes
from repro.fl import build_text_setup, time_to_accuracy, traffic_to_accuracy


def run(rounds: int = 24, target: float = 0.35):
    model, px, py, test = build_text_setup(num_clients=20, seed=3)
    cfg = quick_cfg()
    cfg.lr = 0.2
    hists = run_all_schemes(model, px, py, test, rounds, cfg,
                            schemes=["fedavg", "flanc", "heroes"])
    rows = []
    for scheme, hist in hists.items():
        accs = [h.accuracy for h in hist if h.accuracy is not None]
        rows.append(csv_row(f"fig9/{scheme}/final_acc",
                            f"{accs[-1]:.4f}" if accs else "nan",
                            f"wall={hist[-1].wall_time:.1f}s"))
        tta = time_to_accuracy(hist, target)
        rows.append(csv_row(f"fig9/{scheme}/time_to_{int(target*100)}pct",
                            f"{tta:.2f}" if tta else "unreached", "virtual_s"))
        rows.append(csv_row(f"fig9/{scheme}/traffic",
                            f"{hist[-1].traffic_bytes/1e6:.2f}", "MB"))
    return rows
