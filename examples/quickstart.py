"""Quickstart: the Heroes pipeline end-to-end in 60 seconds on CPU.

1. Factorize a weight into (basis, coefficient blocks)  — Eq. (4)
2. Select the least-trained blocks and compose a p-width weight — Fig. 1
3. Run one federated round (width+frequency assignment, local training,
   block-wise aggregation) on a 10-client simulation — Alg. 1/2
"""

# Run with the package importable: ``pip install -e .`` or ``PYTHONPATH=src``.

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (BoundState, CompositionSpec, HeroesScheduler,
                        SchedulerConfig, compose, gather_blocks, init_factors,
                        select_blocks)
from repro.fl import FLConfig, build_image_setup, run_scheme, summarize


def composition_demo():
    print("== 1. neural composition (paper Eq. 4 / Fig. 1) ==")
    spec = CompositionSpec(max_width=3, rank=8, base_in=16, base_out=12, ksq=9)
    basis, coeff = init_factors(jax.random.PRNGKey(0), spec)
    print(f"basis {basis.shape}  complete coefficient {coeff.shape} "
          f"({spec.num_blocks} blocks)")
    counters = np.array([3, 6, 9, 5, 12, 7, 8, 10, 11])
    ids = select_blocks(counters, p=2, spec=spec)
    print(f"update counters {counters} -> least-trained blocks {ids}")
    w = compose(basis, gather_blocks(coeff, ids), p=2, spec=spec)
    print(f"composed 2-width weight: {w.shape}  "
          f"(vs full {spec.weight_shape(3)})")
    fac = spec.params_factorized(2)
    mat = spec.params_materialized(2)
    print(f"shipped params: factorized {fac} vs materialised {mat} "
          f"({100*(1-fac/mat):.0f}% smaller)\n")


def scheduler_demo():
    print("== 2. adaptive tensor+frequency assignment (Alg. 1) ==")
    spec = CompositionSpec(max_width=3, rank=8, base_in=16, base_out=12)
    sched = HeroesScheduler(
        spec,
        SchedulerConfig(mu_max=0.3, rho=1.0, eps=1.0),
        iter_time_fn=lambda n, p: 0.02 * p * p * (1 + n % 4),  # tiers
        comm_time_fn=lambda n, p: 0.2 + 0.05 * p * p,
    )
    state = BoundState(loss0=2.3, smoothness=0.8, grad_sq=1.5, noise_sq=0.4,
                       lr=0.05)
    plan = sched.plan_round(list(range(6)), state)
    for n, a in sorted(plan.assignments.items()):
        print(f"  client {n}: width p={a.width}  tau={a.tau:3d}  "
              f"blocks={a.block_ids.tolist()}  T={a.est_completion:.2f}s")
    print(f"  pacesetter={plan.pacesetter}  makespan={plan.makespan:.2f}s  "
          f"avg wait={plan.avg_waiting():.2f}s\n")


def federated_round_demo():
    print("== 3. five federated rounds, Heroes vs FedAvg ==")
    model, px, py, test = build_image_setup(num_clients=10, seed=0)
    cfg = FLConfig(num_clients=10, clients_per_round=4, eval_every=5,
                   tau_fixed=5, tau_max=20)
    for scheme in ("heroes", "fedavg"):
        hist = run_scheme(scheme, model, px, py, test, rounds=5, cfg=cfg)
        s = summarize(hist)
        print(f"  {scheme:7s}: acc={s['final_acc']:.3f}  "
              f"virtual time={s['wall_time']:.1f}s  "
              f"traffic={s['traffic_gb']*1e3:.2f}MB  "
              f"avg wait={s['avg_wait']:.2f}s")


if __name__ == "__main__":
    composition_demo()
    scheduler_demo()
    federated_round_demo()
