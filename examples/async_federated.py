"""Semi-asynchronous federated training through the engine's round loop.

Runs Heroes and FedAvg in both round modes on the synthetic image task:

  sync        paper Eq. 19 — every round waits for the slowest client
  semi_async  aggregate the fastest K of M; stragglers merge later with a
              staleness-discounted weight (decay ** staleness)

and prints the accuracy-vs-virtual-time trajectories plus the staleness
events the async loop logged.  The async mode trades per-merge freshness
for never paying the straggler makespan, which is exactly the waiting
time the paper's Fig. 2 shows fixed-tau schemes wasting.
"""

# Run with the package importable: ``pip install -e .`` or ``PYTHONPATH=src``.

from repro.fl import FLConfig, build_image_setup, run_scheme, summarize

ROUNDS = 20


def main():
    model, px, py, test = build_image_setup(num_clients=20, gamma=40.0, seed=0)
    base = dict(num_clients=20, clients_per_round=5, eval_every=2,
                tau_fixed=5, tau_max=25, lr=0.08)

    for scheme in ("heroes", "fedavg"):
        print(f"=== {scheme} ===")
        hists = {
            "sync": run_scheme(scheme, model, px, py, test, rounds=ROUNDS,
                               cfg=FLConfig(**base)),
            "semi_async": run_scheme(
                scheme, model, px, py, test, rounds=ROUNDS,
                cfg=FLConfig(**base, round_mode="semi_async", async_k=2,
                             staleness_decay=0.5)),
        }
        for mode, hist in hists.items():
            s = summarize(hist)
            stale = sum(h.stale for h in hist)
            print(f"  {mode:10s} final_acc={s['final_acc']:.3f} "
                  f"time={s['wall_time']:.0f}s wait={s['avg_wait']:.2f}s "
                  f"stale_merges={stale}")
        print("  trajectories (mode, round, virtual_s, acc, stale):")
        for mode, hist in hists.items():
            for h in hist:
                if h.accuracy is not None:
                    print(f"    {mode},{h.round},{h.wall_time:.1f},"
                          f"{h.accuracy:.4f},{h.stale}")


if __name__ == "__main__":
    main()
