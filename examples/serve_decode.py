"""Serving example: batched autoregressive decode with a KV cache.

Instantiates the reduced gemma-2b variant (full GQA/MQA + GeGLU machinery),
prefills a batch of prompts, then decodes tokens with `serve_step` —
the same function the decode_32k / long_500k dry-run shapes lower.
Also demonstrates the sliding-window (ring-buffer) cache used by the
long_500k variant and the Pallas decode-attention kernel.
"""

# Run with the package importable: ``pip install -e .`` or ``PYTHONPATH=src``.

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.kernels import ops as kops
from repro.models import model


def greedy_decode(cfg, params, prompts, steps: int):
    B, S0 = prompts.shape
    cache = model.init_cache(cfg, B, S0 + steps)
    # prefill token-by-token (simple; production uses the prefill graph)
    tok = prompts[:, :1]
    logits = None
    for t in range(S0 + steps):
        logits, cache = model.serve_step(
            params, cfg, {"tokens": tok}, cache, jnp.int32(t))
        nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        tok = prompts[:, t + 1:t + 2] if t + 1 < S0 else nxt
    return tok, cache


def main():
    cfg = configs.get_smoke("gemma-2b")
    params = model.init(jax.random.PRNGKey(0), cfg)
    B, S0, steps = 4, 8, 8
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S0), 0, cfg.vocab)

    print(f"serving {cfg.arch_id} (reduced): batch={B} prompt_len={S0} "
          f"decode_steps={steps}")
    last_tok, cache = greedy_decode(cfg, params, prompts, steps)
    print("full-cache decode ok; last tokens:", np.asarray(last_tok)[:, 0])

    # sliding-window (ring buffer) variant — the long_500k configuration
    swa = cfg.replace(sliding_window=16)
    params_swa = model.init(jax.random.PRNGKey(0), swa)
    last2, cache2 = greedy_decode(swa, params_swa, prompts, steps)
    print(f"sliding-window decode ok (ring cache len "
          f"{cache2['k'].shape[2]}); last tokens:", np.asarray(last2)[:, 0])

    # the Pallas decode-attention kernel on the final cache state
    kv = cache["k"][0], cache["v"][0]  # layer 0: (B, S, KV, D)
    D = swa.resolved_head_dim
    q = jax.random.normal(jax.random.PRNGKey(2),
                          (B, 1, cfg.num_kv_heads, cfg.q_per_kv, kv[0].shape[-1]))
    lens = jnp.full((B,), S0 + steps, jnp.int32)
    out = kops.decode_attention(q, kv[0], kv[1], lens)
    print("pallas decode-attention kernel over the cache:", out.shape)


if __name__ == "__main__":
    main()
