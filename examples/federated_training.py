"""End-to-end driver: federated training of the paper's CNN with Heroes
and every baseline, a few hundred aggregate local steps on CPU.

Produces the accuracy-vs-time / accuracy-vs-traffic trajectories the
paper plots (Figs. 4/6) on the reduced synthetic CIFAR stand-in, plus a
checkpoint of the final global factors.
"""

# Run with the package importable: ``pip install -e .`` or ``PYTHONPATH=src``.

import pathlib

from repro.fl import (FLConfig, build_image_setup, build_runner, run_scheme,
                      summarize, time_to_accuracy)

ROUNDS = 30  # x 5 clients x ~5-20 local iterations ≈ O(10^3) local steps


def main():
    model, px, py, test = build_image_setup(num_clients=20, gamma=40.0, seed=0)
    cfg = FLConfig(num_clients=20, clients_per_round=5, eval_every=2,
                   tau_fixed=5, tau_max=25, lr=0.08)
    results = {}
    for scheme in ("heroes", "flanc", "heterofl", "adp", "fedavg"):
        hist = run_scheme(scheme, model, px, py, test, rounds=ROUNDS, cfg=cfg)
        results[scheme] = hist
        s = summarize(hist)
        print(f"{scheme:9s} final_acc={s['final_acc']:.3f} "
              f"best={s['best_acc']:.3f} time={s['wall_time']:.0f}s "
              f"traffic={s['traffic_gb']*1e3:.1f}MB wait={s['avg_wait']:.2f}s "
              f"mean_tau={s['mean_tau']:.1f}")

    target = 0.5
    t_heroes = time_to_accuracy(results["heroes"], target)
    print(f"\ntime-to-{target:.0%}:")
    for scheme, hist in results.items():
        t = time_to_accuracy(hist, target)
        note = ""
        if t and t_heroes and scheme != "heroes":
            note = f"  (heroes speedup {t/t_heroes:.2f}x)"
        print(f"  {scheme:9s} {f'{t:.0f}s' if t else 'unreached':>10}{note}")

    print("\ntrajectories (scheme, round, virtual_s, traffic_MB, acc):")
    for scheme, hist in results.items():
        for h in hist:
            if h.accuracy is not None:
                print(f"  {scheme},{h.round},{h.wall_time:.1f},"
                      f"{h.traffic_bytes/1e6:.2f},{h.accuracy:.4f}")

    ckpt_dir = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "ckpt"
    # persist the full ServerState via a fresh short run: the engine
    # checkpoints at round boundaries and resumes bitwise
    print(f"\n(checkpointing demo state to {ckpt_dir})")
    import dataclasses
    ckpt_cfg = dataclasses.replace(cfg, checkpoint_every=1,
                                   checkpoint_dir=str(ckpt_dir))
    runner = build_runner("heroes", model, px, py, test, cfg=ckpt_cfg, seed=0)
    runner.run(3)
    resumed = build_runner("heroes", model, px, py, test, cfg=ckpt_cfg,
                           seed=0)
    assert resumed.restore_latest() and resumed.round == runner.round
    print("done.")


if __name__ == "__main__":
    main()
