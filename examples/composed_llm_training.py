"""Heroes composition applied to a transformer LM — the framework's
first-class integration (CompositionConfig on any assigned arch).

Trains a reduced deepseek-style decoder twice on a synthetic LM task:
  (a) dense parameterisation,
  (b) factorized (Heroes) parameterisation at width p=P,
showing the factorized model trains to comparable loss with a smaller
parameter/traffic footprint — the paper's value proposition applied to a
modern LLM layer stack (DESIGN.md §4).
"""

import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import CompositionConfig
from repro.data import SyntheticTextTask, lm_batches
from repro.launch.steps import make_train_step
from repro.models import model
from repro.models.module import count_params
from repro.optim import make_optimizer

STEPS = 120


def train(cfg, task, tag: str):
    params = model.init(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adamw", 3e-3)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    rng = np.random.default_rng(0)
    t0, losses = time.time(), []
    for i in range(STEPS):
        toks, labels = lm_batches(task.train, 16, rng)
        toks = jnp.asarray(toks % cfg.vocab)
        labels = jnp.asarray(labels % cfg.vocab)
        params, opt_state, metrics = step(params, opt_state,
                                          {"tokens": toks, "labels": labels})
        losses.append(float(metrics["loss"]))
        if i % 30 == 0 or i == STEPS - 1:
            print(f"  [{tag}] step {i:3d} loss {losses[-1]:.3f}")
    print(f"  [{tag}] params={count_params(params):,}  "
          f"{time.time()-t0:.1f}s  final loss {np.mean(losses[-10:]):.3f}")
    return np.mean(losses[-10:])


def main():
    task = SyntheticTextTask(vocab=64, seq_len=32)
    base = configs.get_smoke("deepseek-coder-33b").replace(
        vocab=64, max_seq=64, remat=False)

    print("dense parameterisation:")
    dense_loss = train(base, task, "dense")

    print("factorized (Heroes composition, P=2, rank=d/4):")
    fac = base.replace(composition=CompositionConfig(
        enabled=True, max_width=2, rank=base.d_model // 4))
    fac_loss = train(fac, task, "heroes")

    print(f"\ndense final={dense_loss:.3f}  factorized final={fac_loss:.3f} "
          f"(factorized trains the same task with fewer shipped params)")


if __name__ == "__main__":
    main()
