"""Federated composed transformer: train through the engine, then serve.

Heroes' neural composition IS low-rank adaptation, so the transformer
trains through the *real* federated engine like any other model def:
the ``"transformer"`` registry entry maps decoder blocks onto
``CompositionSpec``s (q/k/v/o and MLP projections as square rank-R
blocks, embedding + LM head anchored — docs/TRANSFORMERS.md), and every
registered scheme / trainer / round mode applies unchanged.

This example
  1. builds the synthetic-text federation with the transformer def,
  2. runs Heroes (factorized, width+frequency assignment) and FedAvg
     (dense) for a few rounds each,
  3. composes the trained factors ONCE per width and serves greedy
     decode through the Pallas decode-attention kernel.
"""

# Run with the package importable: ``pip install -e .`` or ``PYTHONPATH=src``.

import argparse
import time

import numpy as np

from repro.fl import (FLConfig, build_runner, build_text_setup, greedy_decode,
                      run_scheme, serving_weights, summarize)


def train(scheme: str, model, parts_x, parts_y, test_batch, cfg, rounds):
    t0 = time.time()
    history = run_scheme(scheme, model, parts_x, parts_y, test_batch,
                         rounds, cfg=cfg, seed=0)
    s = summarize(history)
    print(f"  [{scheme}] {rounds} rounds in {time.time() - t0:.1f}s wall "
          f"(virtual {s['wall_time']:.1f}s) acc={s['final_acc']:.3f} "
          f"traffic={s['traffic_gb'] * 1e3:.2f} MB")
    return history


def serve(model, params, width: int, steps: int):
    """Compose width-p weights once, then greedy-decode a continuation."""
    weights = serving_weights(model, params, width)
    prompt = np.arange(8, dtype=np.int32)[None, :] % model.num_classes
    t0 = time.time()
    tokens, _ = greedy_decode(model, weights, width, prompt, steps)
    dt = time.time() - t0
    print(f"  [serve] width={width} generated {tokens.shape[1]} tokens "
          f"({tokens.shape[1] / dt:.1f} tok/s incl. compile): "
          f"{tokens[0].tolist()}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="2 rounds, tiny cohort (CI)")
    ap.add_argument("--rounds", type=int, default=10)
    args = ap.parse_args()
    rounds = 2 if args.smoke else args.rounds
    num_clients = 8 if args.smoke else 24

    model, parts_x, parts_y, test_batch = build_text_setup(
        num_clients=num_clients, max_width=3, seed=0,
        model_name="transformer")
    cfg = FLConfig(num_clients=num_clients,
                   clients_per_round=min(4, num_clients),
                   batch_size=8, eval_every=max(rounds // 2, 1), seed=0)

    print("federated transformer (composed rank-R blocks) through the engine:")
    train("heroes", model, parts_x, parts_y, test_batch, cfg, rounds)
    train("fedavg", model, parts_x, parts_y, test_batch, cfg, rounds)

    # Serving: run Heroes once more with the runner held open so the
    # server's factorized state is in hand, compose per-width dense
    # weights once, decode through the Pallas kernel (interpret mode on
    # CPU hosts, compiled on TPU).
    with build_runner("heroes", model, parts_x, parts_y, test_batch,
                      cfg=cfg, seed=0) as runner:
        runner.run(rounds)
        params = runner.state.params
        print("serving the trained model (compose once, decode via Pallas):")
        for width in (1, model.specs["head"].max_width):
            serve(model, params, width, steps=4 if args.smoke else 16)


if __name__ == "__main__":
    main()
