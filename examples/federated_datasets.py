"""The federated dataset subsystem: registries x partitioners x schemes.

1. Load tasks through the dataset registry (synthetic fallbacks here —
   point ``--data-root`` at real CIFAR-10 binaries / a Shakespeare
   corpus to train on files; docs/DATA.md).
2. Compose any dataset with any Non-IID partitioner.
3. Drive schemes — including the FedProx bundle — over streaming
   client shards with ``run_scheme``.

Run:  PYTHONPATH=src python examples/federated_datasets.py [--data-root D]
"""

import argparse
# Run with the package importable: ``pip install -e .`` or ``PYTHONPATH=src``.

import numpy as np

from repro.data import load_dataset, partition_dataset
from repro.fl import FLConfig, build_text_setup, run_scheme, summarize
from repro.fl.simulation import build_setup


def registry_tour(data_root):
    print("== 1. dataset registry ==")
    for task in ("synthetic_image", "cifar10", "synthetic_text",
                 "shakespeare"):
        ds = load_dataset(task, seed=0, data_root=data_root,
                          train_size=512, test_size=128) \
            if task in ("cifar10", "shakespeare") else \
            load_dataset(task, seed=0)
        extra = f" speakers={ds.metadata['num_speakers']}" \
            if "num_speakers" in ds.metadata else ""
        print(f"  {task:16} train={ds.x.shape} source="
              f"{ds.metadata['source']}{extra}")

    print("\n== 2. one dataset x three partitioners ==")
    ds = load_dataset("cifar10", seed=0, data_root=data_root,
                      train_size=512, test_size=128)
    for name, kw in (("iid", {}), ("dirichlet", {"gamma_pct": 80.0}),
                     ("class_skew", {"missing": 4})):
        parts = partition_dataset(ds, name, 8, seed=0, **kw)
        spread = [len(np.unique(ds.y[p])) for p in parts[:4]]
        print(f"  {name:10} {kw or ''} classes-per-client={spread}...")


def train_demo(data_root):
    print("\n== 3. schemes over streaming shards ==")
    cfg = FLConfig(num_clients=12, clients_per_round=4, tau_fixed=4,
                   eval_every=2, trainer="cohort", prox_mu=0.05)
    model, px, py, test = build_text_setup(
        num_clients=12, seed=0, task="shakespeare", max_width=2,
        data_root=data_root, task_kw={"train_size": 960, "test_size": 240})
    for scheme in ("fedavg", "fedprox", "heroes"):
        hist = run_scheme(scheme, model, px, py, test, rounds=4, cfg=cfg)
        s = summarize(hist)
        print(f"  {scheme:8} acc={s['final_acc']:.3f} "
              f"traffic={s['traffic_gb']*1e3:.2f}MB wall={s['wall_time']:.0f}s")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--data-root", default=None,
                    help="directory with real CIFAR-10 / Shakespeare files "
                         "(default: deterministic synthetic fallbacks)")
    args = ap.parse_args()
    registry_tour(args.data_root)
    train_demo(args.data_root)
