"""Population subsystem: virtualization determinism, participation
schedulers, lazy partitioning, hierarchical aggregation, and the
loader/summary satellites."""

import subprocess
import sys
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import masked_block_merge, ordered_sum
from repro.data.streaming import ClientDataLoader, VirtualShardList, make_shards
from repro.fl.heterogeneity import TIERS, HeterogeneityModel, client_profile
from repro.fl.population import (PopulationRegistry, VirtualPartition,
                                 assign_edge_groups, build_scheduler,
                                 grouped_ordered_fold)
from repro.fl.population.hierarchy import HierarchicalMerger, _pad_any
from repro.fl.population.schedulers import (_EXACT_POOL_MAX,
                                            UniformParticipation)
from repro.fl.simulation import (build_runner, build_setup, summarize,
                                 time_to_accuracy, traffic_to_accuracy)
from repro.fl.types import FLConfig

W = (0.05, 0.15, 0.30, 0.50)


def _labels(n=600, classes=10, seed=0):
    return np.random.default_rng(seed).integers(0, classes, n)


# ---------------------------------------------------------------------------
# virtual client state: pure in (seed, client_id), invariant to population
# size, query order, and process
# ---------------------------------------------------------------------------


def test_profile_independent_of_order_and_population():
    a = [client_profile(7, n, W) for n in range(20)]
    b = [client_profile(7, n, W) for n in reversed(range(20))][::-1]
    assert a == b
    # the virtual map resolves through the same function at any size
    small = HeterogeneityModel(10, seed=7, tier_weights=W, virtual=True)
    huge = HeterogeneityModel(10**6, seed=7, tier_weights=W, virtual=True)
    for n in (0, 3, 9):
        assert small.clients[n] == huge.clients[n]
    assert huge.clients[999_999].tier in TIERS


def test_virtual_map_quacks_like_dict():
    het = HeterogeneityModel(50, seed=1, tier_weights=W, virtual=True)
    assert len(het.clients) == 50
    assert 49 in het.clients and 50 not in het.clients
    with pytest.raises(KeyError):
        het.clients[50]
    # the time model consumes virtual profiles unchanged
    assert het.iter_time(11, 1e9) > 0
    assert het.upload_time(11, 1e6) > 0
    assert 0.0 < het.clients[11].availability <= 1.0


def test_registry_state_and_participation():
    labels = _labels()
    vp = VirtualPartition(labels, 1000, seed=3, kind="dirichlet",
                          samples_per_client=32)
    reg = PopulationRegistry(1000, seed=3, tier_weights=W, partition=vp)
    st = reg.state(42, rnd=5)
    assert st.profile == reg.profile(42)
    np.testing.assert_array_equal(st.data_indices, vp.indices(42))
    assert st.last_round is None
    # the rng stream is the engine's sequential contract
    np.testing.assert_array_equal(
        st.rng().integers(0, 100, 8),
        np.random.default_rng((3, 5, 42)).integers(0, 100, 8))
    reg.note_participation([42, 17], rnd=5)
    assert reg.last_participation(42) == 5
    assert reg.state(42, rnd=9).last_round == 5
    assert reg.participants() == 2
    with pytest.raises(IndexError):
        reg.profile(1000)


def test_registry_partition_size_mismatch_rejected():
    vp = VirtualPartition(_labels(), 10, samples_per_client=8)
    with pytest.raises(ValueError):
        PopulationRegistry(20, partition=vp)


def test_virtual_state_identical_across_processes():
    code = (
        "import numpy as np\n"
        "from repro.fl.heterogeneity import client_profile\n"
        "from repro.fl.population import VirtualPartition\n"
        "labels = np.random.default_rng(0).integers(0, 10, 600)\n"
        "vp = VirtualPartition(labels, 5000, seed=3, kind='dirichlet',\n"
        "                      samples_per_client=32)\n"
        "for n in (0, 17, 4999):\n"
        "    p = client_profile(3, n, (0.05, 0.15, 0.30, 0.50))\n"
        "    print(p.tier, round(p.compute_scale, 12), p.seed,\n"
        "          round(p.availability, 12), int(vp.indices(n).sum()))\n"
    )
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, check=True).stdout.strip().splitlines()
    labels = _labels()
    vp = VirtualPartition(labels, 5000, seed=3, kind="dirichlet",
                          samples_per_client=32)
    for line, n in zip(out, (0, 17, 4999)):
        p = client_profile(3, n, W)
        expect = (f"{p.tier} {round(p.compute_scale, 12)} {p.seed} "
                  f"{round(p.availability, 12)} {int(vp.indices(n).sum())}")
        assert line == expect


# ---------------------------------------------------------------------------
# lazy partitioning
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["dirichlet", "class_skew", "iid", "natural"])
def test_virtual_partition_kinds(kind):
    labels = _labels()
    vp = VirtualPartition(labels, 500, seed=1, kind=kind,
                          samples_per_client=40)
    for n in (0, 7, 499):
        idx = vp.indices(n)
        assert idx.shape == (40,)
        assert idx.dtype == np.int64
        assert (0 <= idx).all() and (idx < len(labels)).all()


def test_virtual_partition_pure_in_client_id():
    labels = _labels()
    a = VirtualPartition(labels, 100, seed=2, kind="dirichlet",
                         samples_per_client=24)
    b = VirtualPartition(labels, 100_000, seed=2, kind="dirichlet",
                         samples_per_client=24)
    for n in (0, 5, 99):
        # independent of population size AND of query order (b is
        # queried for other clients first)
        b.indices(50)
        b.indices(n + 1 if n + 1 < 100 else 0)
        np.testing.assert_array_equal(a.indices(n), b.indices(n))


def test_virtual_partition_dirichlet_skew():
    labels = _labels(2000)
    vp = VirtualPartition(labels, 50, seed=0, kind="dirichlet",
                          samples_per_client=100, gamma_pct=80.0)
    idx = vp.indices(3)
    main = int(vp.classes[3 % len(vp.classes)])
    frac = np.mean(labels[idx] == main)
    assert frac >= 0.7  # 80% requested from the main class


def test_virtual_partition_class_skew_lacks_classes():
    labels = _labels(2000)
    vp = VirtualPartition(labels, 50, seed=0, kind="class_skew",
                          samples_per_client=100, missing=3)
    present = np.unique(labels[vp.indices(9)])
    assert len(present) <= len(vp.classes) - 3


def test_virtual_partition_rejects_bad_args():
    with pytest.raises(ValueError):
        VirtualPartition(_labels(), 10, kind="nope")
    with pytest.raises(ValueError):
        VirtualPartition(_labels(), 0)
    with pytest.raises(ValueError):
        VirtualPartition(_labels(), 10, samples_per_client=0)
    vp = VirtualPartition(_labels(), 10, samples_per_client=8)
    with pytest.raises(IndexError):
        vp.indices(10)


def test_make_shards_virtual_path():
    x = np.arange(400, dtype=np.float32).reshape(100, 4)
    y = np.arange(100)
    vp = VirtualPartition(y % 10, 10_000, seed=0, kind="iid",
                          samples_per_client=16)
    px, py = make_shards(x, y, vp)
    assert isinstance(px, VirtualShardList) and len(px) == 10_000
    sx, sy = px[123], py[123]
    assert len(sx) == 16
    np.testing.assert_array_equal(np.asarray(sx), x[vp.indices(123)])
    np.testing.assert_array_equal(np.asarray(sy), y[vp.indices(123)])
    with pytest.raises(IndexError):
        px[10_000]


# ---------------------------------------------------------------------------
# participation schedulers
# ---------------------------------------------------------------------------


class _FakeEng:
    """Just enough runner surface for a scheduler."""

    def __init__(self, pop, seed=0, rnd=3, participation="uniform"):
        from repro.fl.types import ServerState

        self.cfg = FLConfig(num_clients=pop, seed=seed,
                            participation=participation)
        self.state = ServerState(rng=np.random.default_rng(seed),
                                 bound_state=None, round=rnd)
        self.het = HeterogeneityModel(pop, seed=seed, tier_weights=W,
                                      virtual=True)


def _scheduler(eng):
    s = build_scheduler(eng.cfg)
    s.setup(eng)
    return s


def test_uniform_matches_legacy_inline_sampling():
    eng = _FakeEng(100, seed=9)
    s = _scheduler(eng)
    expect = np.random.default_rng(9).choice(100, 10, replace=False)
    assert s.sample(eng.state, 10) == [int(c) for c in expect]
    # semi-async exclude path: legacy pool + choice, same rng stream
    eng2 = _FakeEng(30, seed=4)
    s2 = _scheduler(eng2)
    busy = {1, 5, 9}
    legacy = np.random.default_rng(4)
    pool = np.array([c for c in range(30) if c not in busy])
    expect = legacy.choice(pool, min(7, len(pool)), replace=False)
    assert s2.sample(eng2.state, 7, exclude=busy) == [int(c) for c in expect]


def test_uniform_rejection_path_at_population_scale():
    pop = _EXACT_POOL_MAX + 5_000
    eng = _FakeEng(pop, seed=0)
    s = _scheduler(eng)
    exclude = {0, 1, 2}
    got = s.sample(eng.state, 24, exclude=exclude)
    assert len(got) == 24 and len(set(got)) == 24
    assert not set(got) & exclude
    assert all(0 <= c < pop for c in got)
    # deterministic given the same engine rng state
    eng2 = _FakeEng(pop, seed=0)
    s2 = _scheduler(eng2)
    assert s2.sample(eng2.state, 24, exclude=exclude) == got


def test_uniform_exhausted_pool_returns_empty():
    eng = _FakeEng(4)
    s = _scheduler(eng)
    assert s.sample(eng.state, 3, exclude={0, 1, 2, 3}) == []


@pytest.mark.parametrize("participation", ["availability", "resource_gated"])
def test_gated_schedulers_contract(participation):
    eng = _FakeEng(300, seed=2, participation=participation)
    s = _scheduler(eng)
    got = s.sample(eng.state, 20, exclude={7})
    assert len(got) == len(set(got)) <= 20
    assert 7 not in got
    assert all(0 <= c < 300 for c in got)
    # reproducible: same seeds, same round -> same cohort
    eng2 = _FakeEng(300, seed=2, participation=participation)
    assert _scheduler(eng2).sample(eng2.state, 20, exclude={7}) == got


def test_trace_participation_replays_trace():
    from repro.fl.population import TraceParticipation

    eng = _FakeEng(100, seed=0, rnd=3)
    s = TraceParticipation({3: [5, 9, 12, 40, 41], 4: []})
    s.setup(eng)
    got = s.sample(eng.state, 3)
    assert len(got) == 3 and set(got) <= {5, 9, 12, 40, 41}
    eng.state.round = 4
    assert s.sample(eng.state, 3) == []
    eng.state.round = 7  # round absent from the trace: uniform fallback
    assert len(s.sample(eng.state, 3)) == 3
    # exclusion and out-of-range ids are filtered from the trace pool
    eng.state.round = 3
    assert set(s.sample(eng.state, 5, exclude={5, 9})) == {12, 40, 41}
    s2 = TraceParticipation({0: [999]})
    eng_b = _FakeEng(10, rnd=0)
    s2.setup(eng_b)
    assert s2.sample(eng_b.state, 2) == []


def test_trace_participation_callable_and_missing():
    from repro.fl.population import TraceParticipation

    eng = _FakeEng(50, seed=1, rnd=2)
    s = TraceParticipation(lambda rnd, n: n % 2 == rnd % 2)
    s.setup(eng)
    got = s.sample(eng.state, 10)
    assert len(got) == 10 and all(n % 2 == 0 for n in got)
    bare = TraceParticipation()
    eng_b = _FakeEng(10)
    bare.setup(eng_b)
    with pytest.raises(ValueError, match="no trace"):
        bare.sample(eng_b.state, 2)
    # eng.availability_trace is picked up when none was passed
    eng2 = _FakeEng(20, rnd=0)
    eng2.availability_trace = {0: [1, 2, 3]}
    s3 = TraceParticipation()
    s3.setup(eng2)
    assert set(s3.sample(eng2.state, 5)) == {1, 2, 3}


def test_build_scheduler_rejects_unknown():
    with pytest.raises(ValueError):
        build_scheduler(FLConfig(participation="nope"))


# The two property sweeps below run under hypothesis when it is
# installed (shrinking, edge-case search) and fall back to a seeded
# random sweep when it is not, so the properties are always exercised.

def _sampler_property(pop, seed, k, exclude):
    eng = _FakeEng(pop, seed=seed)
    got = UniformParticipation.sample(_scheduler(eng), eng.state, k,
                                      exclude=exclude)
    # without replacement, correct cardinality, exclusions honoured
    assert len(got) == len(set(got)) == min(k, pop - len(exclude))
    assert not set(got) & exclude


def _invariance_property(labels, seed, n, pop, rnd):
    small = PopulationRegistry(
        100, seed=seed, tier_weights=W,
        partition=VirtualPartition(labels, 100, seed=seed,
                                   samples_per_client=16))
    big = PopulationRegistry(
        pop, seed=seed, tier_weights=W,
        partition=VirtualPartition(labels, pop, seed=seed,
                                   samples_per_client=16))
    a, b = small.state(n, rnd), big.state(n, rnd)
    assert a.profile == b.profile
    np.testing.assert_array_equal(a.data_indices, b.data_indices)
    assert a.rng_key == b.rng_key


def test_sampler_properties():
    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        sweep = np.random.default_rng(0)
        for _ in range(25):
            pop = int(sweep.integers(2, 2000))
            k = int(sweep.integers(1, pop + 1))
            n_excl = int(sweep.integers(0, min(pop - 1, 20) + 1))
            exclude = set(map(int, sweep.choice(pop, n_excl,
                                                replace=False)))
            _sampler_property(pop, int(sweep.integers(0, 2**16)), k,
                              exclude)
        return

    @settings(max_examples=25, deadline=None)
    @given(pop=st.integers(2, 2000), seed=st.integers(0, 2**16),
           data=st.data())
    def prop(pop, seed, data):
        k = data.draw(st.integers(1, pop))
        n_excl = data.draw(st.integers(0, min(pop - 1, 20)))
        exclude = set(data.draw(st.lists(
            st.integers(0, pop - 1), min_size=n_excl, max_size=n_excl,
            unique=True)))
        _sampler_property(pop, seed, k, exclude)

    prop()


def test_virtual_state_invariance():
    labels = _labels()
    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        sweep = np.random.default_rng(1)
        for _ in range(25):
            _invariance_property(labels, int(sweep.integers(0, 2**16)),
                                 int(sweep.integers(0, 100)),
                                 int(sweep.integers(100, 10**6)),
                                 int(sweep.integers(0, 51)))
        return

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**16), n=st.integers(0, 99),
           pop=st.integers(100, 10**6), rnd=st.integers(0, 50))
    def prop(seed, n, pop, rnd):
        _invariance_property(labels, seed, n, pop, rnd)

    prop()


# ---------------------------------------------------------------------------
# hierarchical aggregation
# ---------------------------------------------------------------------------


def test_assign_edge_groups_contiguous_balanced():
    groups = assign_edge_groups(list(range(10)), 3)
    assert groups == [[0, 1, 2, 3], [4, 5, 6, 7], [8, 9]]
    assert assign_edge_groups([1, 2], 5) == [[1], [2]]


@pytest.mark.parametrize("k,groups", [(7, 2), (10, 3), (24, 4), (5, 5),
                                      (6, 1)])
def test_hierarchical_bitwise_vs_flat_masked_block_merge(k, groups):
    rng = np.random.default_rng(k * 31 + groups)
    B, r = 9, 4
    dense = rng.normal(size=(k, B, r, r)).astype(np.float32)
    mask = (rng.random((k, B)) < 0.5).astype(np.float32)
    prev = rng.normal(size=(B, r, r)).astype(np.float32)
    flat = masked_block_merge(jnp.asarray(dense), jnp.asarray(mask),
                              jnp.asarray(prev))
    hm = HierarchicalMerger(edge_groups=groups)
    size, padded = hm._grouping(k)
    td, pd = grouped_ordered_fold(jnp.asarray(_pad_any(dense, padded)), size)
    tm, pm = grouped_ordered_fold(jnp.asarray(_pad_any(mask, padded)), size)
    # carry-chained total == flat ordered fold, bitwise
    assert bool(jnp.all(td == ordered_sum(jnp.asarray(dense))))
    trained = tm > 0
    denom = jnp.where(trained, tm, 1.0)[:, None, None].astype(td.dtype)
    merged = jnp.where(trained[:, None, None], td / denom, jnp.asarray(prev))
    assert bool(jnp.all(merged == flat))
    # the per-group partials (the edge uploads) recombine to the totals
    # to float tolerance (their re-association is what the carry avoids)
    np.testing.assert_allclose(np.asarray(pd).sum(0), np.asarray(td),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(pm).sum(0), np.asarray(tm),
                               rtol=1e-6)


def _mini_setup(num_clients=12, seed=0):
    return build_setup("synthetic_image", num_clients=num_clients, seed=seed)


def _cfg(**kw):
    base = dict(num_clients=12, clients_per_round=6, tau_fixed=2,
                eval_every=1, estimate=True)
    base.update(kw)
    return FLConfig(**base)


def test_engine_hierarchical_heroes_coeff_bitwise():
    m, px, py, tb = _mini_setup()
    flat = build_runner("heroes", m, px, py, tb, cfg=_cfg(), seed=0)
    hier = build_runner("heroes", m, px, py, tb, cfg=_cfg(edge_groups=3),
                        seed=0)
    assert isinstance(hier.merger, HierarchicalMerger)
    flat.run(1)
    hier.run(1)
    for name in flat.params:
        np.testing.assert_array_equal(
            np.asarray(flat.params[name]["coeff"]),
            np.asarray(hier.params[name]["coeff"]))
        np.testing.assert_allclose(
            np.asarray(flat.params[name]["basis"]),
            np.asarray(hier.params[name]["basis"]), rtol=1e-6, atol=1e-6)
    if hier.merger.mesh is None:
        # with a device mesh the mesh IS the edge tier: grouping is a
        # no-op and no host-side partials are produced
        assert hier.merger.last_partials is not None


def test_engine_hierarchical_heterofl_bitwise():
    m, px, py, tb = _mini_setup()
    flat = build_runner("heterofl", m, px, py, tb, cfg=_cfg(), seed=0)
    hier = build_runner("heterofl", m, px, py, tb, cfg=_cfg(edge_groups=2),
                        seed=0)
    flat.run(2)
    hier.run(2)
    for name in flat.params:
        np.testing.assert_array_equal(np.asarray(flat.params[name]),
                                      np.asarray(hier.params[name]))
    assert flat.history[-1].accuracy == hier.history[-1].accuracy


def test_engine_hierarchical_fedavg_close():
    m, px, py, tb = _mini_setup()
    flat = build_runner("fedavg", m, px, py, tb, cfg=_cfg(), seed=0)
    hier = build_runner("fedavg", m, px, py, tb, cfg=_cfg(edge_groups=4),
                        seed=0)
    flat.run(1)
    hier.run(1)
    for name in flat.params:
        np.testing.assert_allclose(np.asarray(flat.params[name]),
                                   np.asarray(hier.params[name]),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# end-to-end virtual population runs
# ---------------------------------------------------------------------------


def test_population_setup_and_sync_run():
    m, px, py, tb = build_setup("synthetic_image", seed=0, population=5000,
                                partition_kw={"samples_per_client": 32})
    assert isinstance(px, VirtualShardList) and len(px) == 5000
    cfg = FLConfig(num_clients=5000, clients_per_round=6, tau_fixed=2,
                   eval_every=2)
    with build_runner("heroes", m, px, py, tb, cfg=cfg, seed=0) as r:
        assert r.population is px.registry
        assert r.het.virtual
        # het profiles and registry profiles are the same pure function
        assert r.het.clients[4321] == r.population.profile(4321)
        h = r.run(2)
    assert len(h) == 2 and h[-1].traffic_bytes > 0
    assert 0 < r.population.participants() <= 12


def test_population_semi_async_run():
    m, px, py, tb = build_setup("synthetic_image", seed=0, population=2000,
                                partition_kw={"samples_per_client": 32})
    cfg = FLConfig(num_clients=2000, clients_per_round=6, tau_fixed=2,
                   eval_every=5, round_mode="semi_async",
                   participation="availability")
    with build_runner("fedavg", m, px, py, tb, cfg=cfg, seed=0) as r:
        h = r.run(3)
    assert len(h) == 3


def test_population_num_clients_mismatch_rejected():
    m, px, py, tb = build_setup("synthetic_image", seed=0, population=1000,
                                partition_kw={"samples_per_client": 16})
    with pytest.raises(ValueError):
        build_runner("fedavg", m, px, py, tb,
                     cfg=FLConfig(num_clients=999), seed=0)


# ---------------------------------------------------------------------------
# satellites: loader close semantics, empty-history summaries
# ---------------------------------------------------------------------------


def _prefetch_threads():
    return [t for t in threading.enumerate()
            if t.name == "client-data-prefetch" and t.is_alive()]


def test_loader_close_releases_abandoned_worker():
    x = np.zeros((64, 2), np.float32)
    parts = [np.arange(64)] * 4
    loader = ClientDataLoader([x[p] for p in parts], [x[p, 0] for p in parts])
    gen = loader.prefetch(list(range(16)), lambda i: np.zeros(32))
    next(gen)  # worker started, will block on the bounded queue
    assert _prefetch_threads()
    # an exception in the round body abandons `gen` without closing it;
    # loader.close() must still release the worker deterministically
    loader.close()
    deadline = time.monotonic() + 5.0
    while _prefetch_threads() and time.monotonic() < deadline:
        time.sleep(0.01)
    assert not _prefetch_threads()


def test_loader_context_manager_closes():
    x = np.zeros((64, 2), np.float32)
    parts = [np.arange(64)] * 2
    with ClientDataLoader([x[p] for p in parts],
                          [x[p, 0] for p in parts]) as loader:
        gen = loader.prefetch(list(range(8)), lambda i: i)
        next(gen)
    assert not _prefetch_threads()
    loader.close()  # idempotent


def test_cohort_trainer_closes_prefetch_on_error(monkeypatch):
    m, px, py, tb = _mini_setup()
    cfg = _cfg(trainer="cohort")
    r = build_runner("heroes", m, px, py, tb, cfg=cfg, seed=0)
    monkeypatch.setattr(type(r.trainer), "_train_group",
                        lambda *a, **k: (_ for _ in ()).throw(
                            RuntimeError("boom")))
    with pytest.raises(RuntimeError, match="boom"):
        r.run_round()
    deadline = time.monotonic() + 5.0
    while _prefetch_threads() and time.monotonic() < deadline:
        time.sleep(0.01)
    assert not _prefetch_threads()
    r.close()


def test_empty_history_summaries():
    assert summarize([]) == {}
    assert time_to_accuracy([], 0.5) is None
    assert traffic_to_accuracy([], 0.5) is None
    assert time_to_accuracy(None, 0.5) is None
    assert traffic_to_accuracy(None, 0.5) is None
