"""Subprocess smoke tests for the CLI launchers (train / serve / dryrun
argument surface)."""

import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
ENV = {**os.environ, "PYTHONPATH": str(ROOT / "src")}


def _run(args, timeout=420):
    return subprocess.run(
        [sys.executable, "-m", *args], cwd=ROOT, env=ENV,
        capture_output=True, text=True, timeout=timeout,
    )


def test_train_launcher_smoke(tmp_path):
    r = _run(["repro.launch.train", "--arch", "xlstm-125m", "--smoke",
              "--steps", "3", "--batch", "2", "--seq", "16",
              "--ckpt-dir", str(tmp_path), "--ckpt-every", "2"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "done." in r.stdout
    assert list(tmp_path.glob("step_*")), "checkpoint not written"


def test_train_launcher_composition():
    r = _run(["repro.launch.train", "--arch", "stablelm-3b", "--smoke",
              "--steps", "2", "--batch", "2", "--seq", "16", "--composition"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "composition=on" in r.stdout


def test_serve_launcher_smoke():
    r = _run(["repro.launch.serve", "--arch", "gemma-2b", "--smoke",
              "--requests", "2", "--batch", "2", "--max-new", "2",
              "--max-len", "32"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "served 2/2" in r.stdout


def test_dryrun_help_surface():
    """The dry-run CLI exposes every perf-variant flag used in §Perf."""
    r = _run(["repro.launch.dryrun", "--help"], timeout=120)
    assert r.returncode == 0
    for flag in ("--both-meshes", "--skip-blocks", "--moe-sorted",
                 "--residual", "--composition", "--compose-matmul",
                 "--attn-qseq", "--no-remat", "--skip-existing"):
        assert flag in r.stdout, flag


def test_dryrun_single_pair_end_to_end(tmp_path):
    """Full dry-run path (512 host devices, lower+compile+analyze) on the
    cheapest (arch, shape) pair."""
    r = _run(["repro.launch.dryrun", "--arch", "xlstm-125m",
              "--shape", "long_500k", "--out", str(tmp_path)], timeout=420)
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-1500:])
    out = list(tmp_path.glob("*.json"))
    assert len(out) == 1
    import json
    rec = json.loads(out[0].read_text())
    assert rec["devices"] == 256 and rec["kind"] == "decode"
    assert rec["loop_scaled"]["dot_flops"] > 0
    assert rec["memory"]["peak_bytes"] > 0
