"""Integration tests for the FL runtime (Heroes + baselines)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl import (FLConfig, build_image_setup, build_runner,
                      build_text_setup, run_scheme, summarize)
from repro.fl.models import make_cnn

PAPER_SCHEMES = ("fedavg", "adp", "heterofl", "flanc", "heroes")


@pytest.fixture(scope="module")
def image_setup():
    return build_image_setup(num_clients=10, seed=0)


def _cfg():
    return FLConfig(num_clients=10, clients_per_round=4, eval_every=2,
                    tau_fixed=4, tau_max=15, estimate=True)


@pytest.mark.parametrize("scheme", PAPER_SCHEMES)
def test_scheme_runs_and_improves(scheme, image_setup):
    model, px, py, test = image_setup
    hist = run_scheme(scheme, model, px, py, test, rounds=6, cfg=_cfg())
    assert len(hist) == 6
    s = summarize(hist)
    assert np.isfinite(s["final_acc"])
    assert s["final_acc"] > 0.10  # better than chance (10 classes)
    assert s["traffic_gb"] > 0 and s["wall_time"] > 0
    # wall time monotone, traffic monotone
    times = [h.wall_time for h in hist]
    assert all(b > a for a, b in zip(times, times[1:]))


def test_heroes_counters_balanced(image_setup):
    """After several rounds the enhanced-NC block counters stay balanced —
    the paper's V^h constraint (Eq. 21)."""
    model, px, py, test = image_setup
    runner = build_runner("heroes", model, px, py, test, cfg=_cfg(), seed=0)
    runner.run(8)
    c = runner.state.sched.counters
    assert c.min() > 0, "some block never trained — starvation (Flanc's flaw)"
    # balance: spread is bounded relative to the mean
    assert c.max() <= 3.0 * max(c.mean(), 1.0)


def test_flanc_starves_large_coefficients(image_setup):
    """Original NC: the largest-width coefficient is only trained by the
    fastest tier — the starvation Heroes fixes (paper Sec. I)."""
    model, px, py, test = image_setup
    cfg = _cfg()
    runner = build_runner("flanc", model, px, py, test, cfg=cfg, seed=0)
    coeffs3 = runner.params["coeffs"][3]
    init3 = {n: np.asarray(coeffs3[n]) for n in coeffs3}
    runner.run(4)
    tiers = {n: runner.het.clients[n].tier for n in range(cfg.num_clients)}
    if not any(t == "laptop" for t in tiers.values()):
        pytest.skip("no full-width client sampled in this seed")


def test_traffic_ordering(image_setup):
    """Factorized schemes ship less than dense full-model schemes."""
    model, px, py, test = image_setup
    cfg = _cfg()
    hists = {s: run_scheme(s, model, px, py, test, rounds=3, cfg=cfg)
             for s in ("heroes", "fedavg")}
    assert (hists["heroes"][-1].traffic_bytes
            < hists["fedavg"][-1].traffic_bytes)


def test_text_task_runs():
    model, px, py, test = build_text_setup(num_clients=8, seed=1)
    cfg = FLConfig(num_clients=8, clients_per_round=3, eval_every=2,
                   tau_fixed=3, tau_max=10, lr=0.2)
    hist = run_scheme("heroes", model, px, py, test, rounds=4, cfg=cfg)
    s = summarize(hist)
    assert np.isfinite(s["final_acc"]) and s["final_acc"] > 0.0


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import load_checkpoint, save_checkpoint
    import jax

    model = make_cnn()
    params = model.init_factorized(jax.random.PRNGKey(0))
    p = save_checkpoint(tmp_path, 7, {"params": params})
    restored = load_checkpoint(p)["params"]
    for name in params:
        np.testing.assert_array_equal(
            np.asarray(params[name]["coeff"]), restored[name]["coeff"])
