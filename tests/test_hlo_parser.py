"""Unit tests for the HLO text parser underpinning the roofline analysis."""

from repro.launch.hlo_analysis import (_parse_op_line, _shape_bytes,
                                       parse_computations)


def test_parse_simple_op():
    op = _parse_op_line("  %dot.1 = f32[64,32]{1,0} dot(%a, %b), "
                        "lhs_contracting_dims={1}, rhs_contracting_dims={0}")
    assert op.opcode == "dot"
    assert op.operands[:2] == ["a", "b"]
    assert "lhs_contracting_dims={1}" in op.attrs


def test_parse_tuple_type_op():
    line = ("  %while.5 = (s32[], f32[64,64]{1,0}, (f32[2]{0}, s32[])) "
            "while(%tuple), condition=%cond.3, body=%body.2")
    op = _parse_op_line(line)
    assert op.opcode == "while"
    assert op.operands == ["tuple"]
    assert "body=%body.2" in op.attrs


def test_parse_nested_parens_in_args():
    line = "  %f = f32[8]{0} fusion(%x, %y), kind=kLoop, calls=%fused_computation.1"
    op = _parse_op_line(line)
    assert op.opcode == "fusion"
    assert op.operands == ["x", "y"]


def test_shape_bytes_tuple():
    assert _shape_bytes("(f32[4], bf16[8], pred[3])") == 16 + 16 + 3
    assert _shape_bytes("s32[]") == 4
    assert _shape_bytes("f32[2,3]{1,0}") == 24


def test_parse_computations_with_nested_tuple_headers():
    hlo = """
HloModule test

%body.2 (arg: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %arg = (s32[], f32[4,4]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[4,4]{1,0} get-tuple-element(%arg), index=1
  %d = f32[4,4]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[4,4]{1,0}) tuple(%i, %d)
}

ENTRY %main.9 (p: f32[4,4]) -> f32[4,4] {
  %p = f32[4,4]{1,0} parameter(0)
  ROOT %c = f32[4,4]{1,0} copy(%p)
}
"""
    comps = parse_computations(hlo)
    assert set(comps) == {"body.2", "main.9"}
    assert any(op.opcode == "dot" for op in comps["body.2"].ops)
