"""Parity suite for the collective aggregation path.

The engine's default merge (repro.fl.engine.collective) stacks dense
zero-padded contributions + masks and merges them in one compiled call;
on a single device it must reproduce the host scatter loops *bitwise*
(weights=None — and, on CPU, the numpy staleness blends match the eager
jax blends bitwise too, which the semi-async test pins down).  On a
multi-device mesh the psum re-associates the client fold, so parity is
to float tolerance.

Multi-device cases run in subprocesses because the host-platform device
count must be configured before jax initialises.
"""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

ROOT = Path(__file__).resolve().parents[1]

SINGLE_DEVICE = len(jax.devices()) == 1


def _leaves_equal(a, b, exact):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        if exact:
            np.testing.assert_array_equal(x, y)
        else:
            np.testing.assert_allclose(x, y, atol=1e-5, rtol=1e-5)


@pytest.fixture(scope="module")
def image_setup():
    from repro.fl import build_image_setup

    return build_image_setup(num_clients=8, seed=0)


def _cfg(**kw):
    from repro.fl import FLConfig

    base = dict(num_clients=8, clients_per_round=3, eval_every=2,
                tau_fixed=2, tau_max=15, estimate=True)
    base.update(kw)
    return FLConfig(**base)


# ---------------------------------------------------------------------------
# engine-level parity: collective (default) vs host backend, all 5 schemes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheme",
                         ["fedavg", "adp", "heterofl", "flanc", "heroes"])
def test_collective_matches_host_backend(scheme, image_setup):
    """Same seed, same rounds: the collective merge must reproduce the
    host scatter loop — bitwise on one device, tol on a mesh."""
    from repro.fl import build_runner

    model, px, py, test = image_setup
    host = build_runner(scheme, model, px, py, test,
                        cfg=_cfg(agg_backend="host"))
    coll = build_runner(scheme, model, px, py, test,
                        cfg=_cfg(agg_backend="collective"))
    assert coll.merger is not None
    for _ in range(2):
        a, b = host.run_round(), coll.run_round()
        assert a.wall_time == b.wall_time
        assert a.traffic_bytes == b.traffic_bytes
    _leaves_equal(host.params, coll.params, exact=SINGLE_DEVICE)


@pytest.mark.parametrize("scheme", ["fedavg", "heroes"])
def test_collective_semi_async_staleness_parity(scheme, image_setup):
    """Stale merges (decay**staleness weights) must blend identically on
    both backends — the collective path folds the blend into the dense
    contribution prep."""
    from repro.fl import build_runner

    model, px, py, test = image_setup
    kw = dict(round_mode="semi_async", async_k=2, eval_every=4)
    host = build_runner(scheme, model, px, py, test,
                        cfg=_cfg(agg_backend="host", **kw))
    coll = build_runner(scheme, model, px, py, test,
                        cfg=_cfg(agg_backend="collective", **kw))
    stale = 0
    for _ in range(5):
        a, b = host.run_round(), coll.run_round()
        assert a.wall_time == b.wall_time
        stale += a.stale
    assert stale > 0, "no staleness events — the weighted path was not hit"
    _leaves_equal(host.params, coll.params, exact=SINGLE_DEVICE)


@pytest.mark.parametrize("scheme", ["fedavg", "heroes"])
def test_collective_sample_weighted_parity(scheme, image_setup):
    """FLConfig.sample_weighted rides the same blend-weights path as the
    staleness discounts — both backends must merge identically."""
    from repro.fl import build_runner

    model, px, py, test = image_setup
    host = build_runner(scheme, model, px, py, test,
                        cfg=_cfg(agg_backend="host", sample_weighted=True))
    coll = build_runner(scheme, model, px, py, test,
                        cfg=_cfg(agg_backend="collective",
                                 sample_weighted=True))
    for _ in range(2):
        a, b = host.run_round(), coll.run_round()
        assert a.wall_time == b.wall_time
    _leaves_equal(host.params, coll.params, exact=SINGLE_DEVICE)


# ---------------------------------------------------------------------------
# core-level properties of the stacked merge
# ---------------------------------------------------------------------------


def test_masked_block_merge_duplicates_and_zero_blocks():
    """Duplicate ids within a client accumulate like the host scatter's
    at[ids].add, and blocks with zero trainers keep the previous value —
    bitwise on one device."""
    from repro.core import (aggregate_coefficient, masked_block_merge,
                            scatter_contributions_host)

    rng = np.random.default_rng(3)
    NB, R, O = 6, 4, 5
    prev = jnp.asarray(rng.normal(size=(NB, R, O)).astype(np.float32))
    # client 0 trains block 1 twice (duplicate id); nobody trains block 5
    ids = [np.array([0, 1, 1]), np.array([2, 3]), np.array([0, 2, 4])]
    blocks = [rng.normal(size=(len(i), R, O)).astype(np.float32)
              for i in ids]
    host = aggregate_coefficient(prev, [jnp.asarray(b) for b in blocks], ids)

    dense, mask = scatter_contributions_host(blocks, ids, NB)
    assert mask[0, 1] == 2.0  # duplicate counted twice
    assert np.all(mask[:, 5] == 0.0)
    merged = jax.jit(masked_block_merge)(jnp.asarray(dense),
                                         jnp.asarray(mask), prev)
    np.testing.assert_array_equal(np.asarray(host), np.asarray(merged))
    # untrained block keeps the previous value bitwise
    np.testing.assert_array_equal(np.asarray(merged[5]), np.asarray(prev[5]))


def test_ordered_sum_matches_sequential_adds():
    from repro.core import ordered_sum

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(9, 5, 7)).astype(np.float32) * 100)
    acc = jnp.zeros_like(x[0])
    for k in range(x.shape[0]):
        acc = acc + x[k]
    np.testing.assert_array_equal(np.asarray(acc),
                                  np.asarray(jax.jit(ordered_sum)(x)))


def test_aggregation_preserves_coeff_dtype():
    """Regression: bf16 coefficients must come back bf16 from both the
    host scatter loop and the collective merge (the counters stay f32
    internally but may not leak into the output dtype)."""
    from repro.core import (aggregate_coefficient, masked_block_merge,
                            scatter_contributions_host)

    rng = np.random.default_rng(1)
    NB, R, O = 4, 3, 3
    for dtype in (jnp.bfloat16, jnp.float16, jnp.float32):
        prev = jnp.asarray(rng.normal(size=(NB, R, O)), dtype=dtype)
        ids = [np.array([0, 2]), np.array([1, 2])]
        blocks = [jnp.asarray(rng.normal(size=(2, R, O)), dtype=dtype)
                  for _ in ids]
        host = aggregate_coefficient(prev, blocks, ids)
        assert host.dtype == dtype
        # weighted path too
        hw = aggregate_coefficient(prev, blocks, ids, weights=[0.5, 1.0])
        assert hw.dtype == dtype
        dense, mask = scatter_contributions_host(
            [np.asarray(b) for b in blocks], ids, NB)
        merged = masked_block_merge(jnp.asarray(dense), jnp.asarray(mask),
                                    prev)
        assert merged.dtype == dtype
        np.testing.assert_allclose(
            np.asarray(host, np.float32), np.asarray(merged, np.float32),
            atol=1e-2)


def test_collective_merger_bf16_roundtrip():
    """The engine merger keeps non-f32 factorized params in their dtype."""
    from repro.fl.engine.collective import CollectiveMerger
    from repro.fl.client import ClientResult

    class Spec:
        mode = "square"

    rng = np.random.default_rng(0)
    NB, R, O = 4, 3, 3
    prev = {"l": {"basis": jnp.asarray(rng.normal(size=(2, R, 4)),
                                       dtype=jnp.bfloat16),
                  "coeff": jnp.asarray(rng.normal(size=(NB, R, O)),
                                       dtype=jnp.bfloat16)}}
    results, assigns = {}, {}
    for n in range(3):
        ids = np.sort(rng.choice(NB, size=2, replace=False))
        results[n] = ClientResult(
            {"l": {"basis": np.asarray(rng.normal(size=(2, R, 4)),
                                       np.float32).astype(jnp.bfloat16),
                   "coeff": np.asarray(rng.normal(size=(2, R, O)),
                                       np.float32).astype(jnp.bfloat16)}},
            {}, 0.0, 0.0)
        assigns[n] = {"hidden_ids": ids}
    merger = CollectiveMerger()
    out = merger.merge_factorized(prev, {"l": Spec()}, results, assigns)
    assert out["l"]["basis"].dtype == jnp.bfloat16
    assert out["l"]["coeff"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# SPMD: real multi-device meshes (subprocess so XLA_FLAGS precede jax init)
# ---------------------------------------------------------------------------

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    from repro.core import (aggregate_coefficient, masked_block_mean,
                            scatter_contribution)

    NB, R, O = 4, 3, 5
    rng = np.random.default_rng(0)
    prev = jnp.asarray(rng.normal(size=(NB, R, O)).astype(np.float32))

    # 8 clients, each training a random subset of blocks
    ids, blocks, dense, masks = [], [], [], []
    for c in range(8):
        take = np.sort(rng.choice(NB, size=rng.integers(1, NB + 1),
                                  replace=False))
        blk = jnp.asarray(rng.normal(size=(len(take), R, O)).astype(np.float32))
        ids.append(take)
        blocks.append(blk)
        d, m = scatter_contribution(blk, jnp.asarray(take), NB)
        dense.append(d)
        masks.append(m)

    host = aggregate_coefficient(prev, blocks, ids)

    mesh = jax.make_mesh((8,), ("clients",))
    dense_all = jnp.stack(dense)  # (8, NB, R, O)
    mask_all = jnp.stack(masks)  # (8, NB)

    @jax.jit
    def agg(dense_all, mask_all, prev):
        f = shard_map(
            lambda d, m, p: masked_block_mean(d[0], m[0], p, "clients"),
            mesh=mesh,
            in_specs=(P("clients"), P("clients"), P()),
            out_specs=P(),
        )
        return f(dense_all, mask_all, prev)

    spmd = agg(dense_all, mask_all, prev)
    np.testing.assert_allclose(np.asarray(host), np.asarray(spmd), atol=1e-5)
    print("SPMD_AGG_OK")
""")


ENGINE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import numpy as np
    assert len(jax.devices()) == 4
    from repro.fl import FLConfig, build_image_setup, build_runner

    model, px, py, test = build_image_setup(num_clients=8, max_width=4,
                                            seed=0)
    base = dict(num_clients=8, clients_per_round=3, eval_every=2,
                tau_fixed=2, tau_max=15, estimate=True)
    for scheme in ("fedavg", "heterofl", "flanc", "heroes"):
        host = build_runner(scheme, model, px, py, test,
                            cfg=FLConfig(**base, agg_backend="host"))
        coll = build_runner(scheme, model, px, py, test,
                            cfg=FLConfig(**base, agg_backend="collective"))
        assert coll.merger is not None and coll.merger.mesh is not None
        for _ in range(2):
            a, b = host.run_round(), coll.run_round()
            assert a.wall_time == b.wall_time
        for x, y in zip(jax.tree_util.tree_leaves(host.params),
                        jax.tree_util.tree_leaves(coll.params)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=1e-5, rtol=1e-5)

    # block-sharded server state: P=4 CNN has 16 hidden / 4 anchored
    # blocks, both divisible by the 4-device mesh
    from jax.sharding import PartitionSpec
    sh = build_runner("heroes", model, px, py, test,
                      cfg=FLConfig(**base, shard_server_state=True))
    for _ in range(2):
        sh.run_round()
    for name, t in sh.params.items():
        assert t["coeff"].sharding.spec == PartitionSpec("cohort"), name
    assert np.isfinite(sh.eval_accuracy())
    print("SPMD_ENGINE_OK")
""")


def _run_subprocess(script: str) -> subprocess.CompletedProcess:
    env = {**os.environ, "PYTHONPATH": str(ROOT / "src")}
    return subprocess.run([sys.executable, "-c", script], env=env, cwd=ROOT,
                          capture_output=True, text=True, timeout=600)


def test_masked_psum_aggregation_spmd():
    r = _run_subprocess(SCRIPT)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SPMD_AGG_OK" in r.stdout


def test_engine_collective_spmd_parity():
    """Full engine rounds on a 4-device mesh: collective == host to float
    tolerance for all factorized/dense schemes, plus block-sharded
    server state staying sharded across rounds."""
    r = _run_subprocess(ENGINE_SCRIPT)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SPMD_ENGINE_OK" in r.stdout
