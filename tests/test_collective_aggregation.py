"""SPMD integration test: the mesh-native block-wise aggregation (Eq. 5)
under shard_map on a real multi-device (host-platform) mesh.

Runs in a subprocess because the 8-device host platform must be
configured before jax initialises.
"""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    from repro.core import (aggregate_coefficient, masked_block_mean,
                            scatter_contribution)

    NB, R, O = 4, 3, 5
    rng = np.random.default_rng(0)
    prev = jnp.asarray(rng.normal(size=(NB, R, O)).astype(np.float32))

    # 8 clients, each training a random subset of blocks
    ids, blocks, dense, masks = [], [], [], []
    for c in range(8):
        take = np.sort(rng.choice(NB, size=rng.integers(1, NB + 1),
                                  replace=False))
        blk = jnp.asarray(rng.normal(size=(len(take), R, O)).astype(np.float32))
        ids.append(take)
        blocks.append(blk)
        d, m = scatter_contribution(blk, jnp.asarray(take), NB)
        dense.append(d)
        masks.append(m)

    host = aggregate_coefficient(prev, blocks, ids)

    mesh = jax.make_mesh((8,), ("clients",))
    dense_all = jnp.stack(dense)  # (8, NB, R, O)
    mask_all = jnp.stack(masks)  # (8, NB)

    @jax.jit
    def agg(dense_all, mask_all, prev):
        f = shard_map(
            lambda d, m, p: masked_block_mean(d[0], m[0], p, "clients"),
            mesh=mesh,
            in_specs=(P("clients"), P("clients"), P()),
            out_specs=P(),
        )
        return f(dense_all, mask_all, prev)

    spmd = agg(dense_all, mask_all, prev)
    np.testing.assert_allclose(np.asarray(host), np.asarray(spmd), atol=1e-5)
    print("SPMD_AGG_OK")
""")


def test_masked_psum_aggregation_spmd():
    env = {**os.environ, "PYTHONPATH": str(ROOT / "src")}
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env, cwd=ROOT,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SPMD_AGG_OK" in r.stdout
