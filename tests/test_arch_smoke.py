"""Per-architecture smoke tests (assignment requirement).

Each assigned architecture instantiates its REDUCED variant (<=2 layers,
d_model<=512, <=4 experts) and runs one forward + one train step on CPU,
asserting output shapes and absence of NaNs.  Decode-capable archs also run
one serve_step against a fresh cache.
"""

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import model
from repro.optim import apply_updates, make_optimizer

B, S = 2, 32


def _batch_for(cfg, key):
    ks = jax.random.split(key, 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
    }
    if cfg.family == "vlm":
        batch["embeddings"] = 0.02 * jax.random.normal(ks[2], (B, S, cfg.d_model))
        pos = jnp.arange(S, dtype=jnp.int32)[None, None, :]
        batch["positions"] = jnp.broadcast_to(pos, (B, 3, S))
    if cfg.family == "audio":
        Se = cfg.encdec.encoder_seq
        batch["enc_embeddings"] = 0.02 * jax.random.normal(ks[3], (B, Se, cfg.d_model))
        batch["enc_mask"] = jnp.ones((B, Se), bool)
    return batch


@pytest.mark.parametrize("arch_id", configs.list_archs())
def test_smoke_forward_and_train_step(arch_id):
    cfg = configs.get_smoke(arch_id)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params = model.init(key, cfg)
    batch = _batch_for(cfg, jax.random.PRNGKey(1))

    logits, aux = model.forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"

    opt = make_optimizer("sgd", 0.01)
    opt_state = opt.init(params)

    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
            params, cfg, batch
        )
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    params2, _, loss = jax.jit(step)(params, opt_state, batch)
    assert bool(jnp.isfinite(loss)), "NaN/inf loss"
    # parameters actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), params, params2
    )
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch_id", configs.list_archs())
def test_smoke_decode_step(arch_id):
    cfg = configs.get_smoke(arch_id)
    key = jax.random.PRNGKey(0)
    params = model.init(key, cfg)
    cache = model.init_cache(cfg, B, 64)
    batch = {"tokens": jnp.ones((B, 1), jnp.int32)}
    if cfg.rope_type == "mrope":
        batch["positions"] = jnp.full((B, 3, 1), 5, jnp.int32)
    logits, new_cache = jax.jit(
        lambda p, b, c: model.serve_step(p, cfg, b, c, jnp.int32(5))
    )(params, batch, cache)
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    # cache structure preserved
    assert jax.tree_util.tree_structure(cache) == jax.tree_util.tree_structure(new_cache)


@pytest.mark.parametrize("arch_id", ["gemma-2b", "zamba2-2.7b"])
def test_smoke_sliding_window_variant(arch_id):
    """SWA variant used by long_500k for full-attention archs."""
    cfg = configs.get_smoke(arch_id).replace(sliding_window=16)
    params = model.init(jax.random.PRNGKey(0), cfg)
    cache = model.init_cache(cfg, B, 1024)
    # ring-buffer cache is bounded by the window
    kv = cache["kv"] if cfg.family == "hybrid" else cache
    assert kv["k"].shape[2] == 16
    logits, _ = model.serve_step(
        params, cfg, {"tokens": jnp.ones((B, 1), jnp.int32)}, cache, jnp.int32(900)
    )
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch_id", ["deepseek-coder-33b", "gemma-2b"])
def test_smoke_int8_kv_cache(arch_id):
    """int8 KV cache decode stays numerically close to the bf16 path."""
    cfg = configs.get_smoke(arch_id)
    params = model.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0, cfg.vocab)

    def decode_all(c):
        cache = model.init_cache(c, B, 16)
        outs = []
        for t in range(8):
            lg, cache = model.serve_step(
                params, c, {"tokens": toks[:, t:t + 1]}, cache, jnp.int32(t))
            outs.append(lg)
        return jnp.concatenate(outs, 1)

    base = decode_all(cfg)
    q8 = decode_all(cfg.replace(kv_cache_quant="int8"))
    rel = float(jnp.abs(base - q8).max() / (jnp.abs(base).max() + 1e-9))
    assert rel < 0.05, f"int8 KV cache error too large: {rel}"
