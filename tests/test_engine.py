"""Tests for the layered FL engine (repro.fl.engine).

Covers: bitwise parity against the golden legacy-history fixtures for
every scheme, the deprecated legacy entry-point shims, the
batched-cohort vs sequential trainer equivalence, the semi-async round
loop, registry extensibility, and the model-identity jit-cache fix.
"""

import dataclasses
import json
from pathlib import Path

import numpy as np
import pytest

from repro.fl import FLConfig, build_image_setup, build_runner, run_scheme
from repro.fl.engine import (CohortTrainer, SchemeBundle, SequentialTrainer,
                             register_scheme)
from repro.fl.engine.registry import SCHEMES
from repro.fl.models import make_cnn

GOLDEN = json.loads(
    (Path(__file__).parent / "fixtures"
     / "golden_legacy_histories.json").read_text())


def _golden_view(hist, fixture):
    """RoundLog dicts restricted to the fields the fixture predates.

    The fixture was captured before RoundLog grew the up/down traffic
    split; every field it *does* record must still match bitwise.
    """
    keys = set(fixture[0])
    return [{k: v for k, v in dataclasses.asdict(h).items() if k in keys}
            for h in hist]


@pytest.fixture(scope="module")
def image_setup():
    return build_image_setup(num_clients=10, seed=0)


def _cfg(**kw):
    # forward_impl pinned: the golden fixtures were captured under the
    # legacy compose-then-apply path ("materialize" reproduces it
    # bitwise); "auto" now consults a measured per-host calibration, so
    # its impl mix is allowed to differ between hosts.
    base = dict(num_clients=10, clients_per_round=4, eval_every=2,
                tau_fixed=4, tau_max=15, estimate=True,
                forward_impl="materialize")
    base.update(kw)
    return FLConfig(**base)


def _assert_history_parity(ha, hb, acc_atol=1e-4):
    assert len(ha) == len(hb)
    for a, b in zip(ha, hb):
        # traffic / virtual clock must match exactly
        assert a.round == b.round
        assert a.wall_time == b.wall_time
        assert a.traffic_bytes == b.traffic_bytes
        assert a.makespan == b.makespan
        assert a.avg_wait == b.avg_wait
        assert a.mean_tau == b.mean_tau
        assert (a.accuracy is None) == (b.accuracy is None)
        if a.accuracy is not None:
            assert abs(a.accuracy - b.accuracy) <= acc_atol


# ---------------------------------------------------------------------------
# bitwise parity: engine histories vs the golden legacy fixtures
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheme", sorted(k for k in GOLDEN if k != "_meta"))
def test_engine_matches_golden_fixture(scheme, image_setup):
    """The engine must reproduce the retired legacy runners' histories
    bitwise (the fixture was captured from the legacy tree before it was
    deleted; JSON round-trips floats exactly)."""
    model, px, py, test = image_setup
    rounds = len(GOLDEN[scheme])
    hist = run_scheme(scheme, model, px, py, test, rounds=rounds, cfg=_cfg())
    assert _golden_view(hist, GOLDEN[scheme]) == GOLDEN[scheme]
    # the new split must reproduce the combined fixture traffic bitwise
    # (traffic_bytes is cumulative; the split is this round's delta)
    prev = 0.0
    for h in hist:
        assert h.up_bytes + h.down_bytes == h.traffic_bytes - prev
        prev = h.traffic_bytes


def test_legacy_shims_resolve_and_warn(image_setup):
    """repro.fl.server.RUNNERS survives as DeprecationWarning shims that
    build the equivalent engine bundle."""
    from repro.fl import RUNNERS as reexported
    from repro.fl.server import RUNNERS

    assert reexported is RUNNERS
    assert set(RUNNERS) == {"fedavg", "adp", "heterofl", "flanc", "heroes"}
    model, px, py, test = image_setup
    cfg = _cfg()
    from repro.fl.heterogeneity import HeterogeneityModel
    het = HeterogeneityModel(cfg.num_clients, seed=0,
                             tier_weights=(0.05, 0.15, 0.30, 0.50))
    with pytest.warns(DeprecationWarning, match="deprecated"):
        runner = RUNNERS["heroes"](model, px, py, test, het, cfg, 3)
    hist = runner.run(2)
    assert len(hist) == 2
    assert _golden_view(hist, GOLDEN["heroes"]) == GOLDEN["heroes"][:2]
    # the Heroes scheduler tallies live in the threaded ServerState
    assert runner.state.sched.counters.sum() > 0
    assert runner.state.sched.anchored.sum() > 0


# ---------------------------------------------------------------------------
# cohort trainer vs sequential trainer
# ---------------------------------------------------------------------------


def test_cohort_trainer_matches_sequential_results(image_setup):
    """Same assignments, same data order: the vmapped cohort step must
    reproduce the per-client sequential updates (up to float assoc)."""
    model, px, py, test = image_setup
    cfg = _cfg()
    eng = build_runner("heroes", model, px, py, test, cfg=cfg)
    _, assigns = eng.assignment.assign(eng.state, list(range(4)))

    seq, coh = SequentialTrainer(), CohortTrainer()
    seq.setup(eng)
    coh.setup(eng)
    r_seq = seq.train_all(eng.state, assigns)
    r_coh = coh.train_all(eng.state, assigns)

    assert list(r_seq) == list(r_coh)
    for n in r_seq:
        a, b = r_seq[n], r_coh[n]
        import jax
        # host_params(): on a multi-device host the cohort backend hands
        # the collective merger device-resident slices
        for la, lb in zip(jax.tree_util.tree_leaves(a.host_params()),
                          jax.tree_util.tree_leaves(b.host_params())):
            np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                       atol=1e-5, rtol=1e-4)
        assert abs(a.loss_before - b.loss_before) < 1e-4
        assert abs(a.loss_after - b.loss_after) < 1e-4
        for k in a.estimates:
            np.testing.assert_allclose(a.estimates[k], b.estimates[k],
                                       atol=1e-3, rtol=1e-2)


def test_cohort_backend_end_to_end(image_setup):
    """Full runs: cohort and sequential backends agree on the virtual
    clock/traffic exactly and on accuracy within tolerance."""
    model, px, py, test = image_setup
    h_seq = run_scheme("fedavg", model, px, py, test, rounds=3, cfg=_cfg())
    h_coh = run_scheme("fedavg", model, px, py, test, rounds=3,
                       cfg=_cfg(trainer="cohort"))
    _assert_history_parity(h_seq, h_coh, acc_atol=1e-3)


# ---------------------------------------------------------------------------
# semi-async round loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheme", ["fedavg", "heroes"])
def test_semi_async_round_mode(scheme, image_setup):
    model, px, py, test = image_setup
    cfg = _cfg(round_mode="semi_async", async_k=2, eval_every=4)
    hist = run_scheme(scheme, model, px, py, test, rounds=8, cfg=cfg)
    assert len(hist) == 8
    walls = [h.wall_time for h in hist]
    assert all(b > a for a, b in zip(walls, walls[1:])), "wall clock not monotone"
    assert all(h.makespan > 0 and h.avg_wait >= 0 for h in hist)
    # with K < M, stragglers must land in later rounds as stale merges
    assert sum(h.stale for h in hist) > 0, "no staleness events logged"
    accs = [h.accuracy for h in hist if h.accuracy is not None]
    assert accs and np.isfinite(accs[-1])
    traffics = [h.traffic_bytes for h in hist]
    assert all(b >= a for a, b in zip(traffics, traffics[1:]))


def test_legacy_backend_warns_and_routes_to_engine(image_setup):
    """build_runner(backend='legacy') is a deprecation shim onto the
    engine now — including configs the legacy tree never supported."""
    model, px, py, test = image_setup
    with pytest.warns(DeprecationWarning, match="legacy"):
        hist = run_scheme("fedavg", model, px, py, test, rounds=1,
                          cfg=_cfg(round_mode="semi_async", async_k=2),
                          backend="legacy")
    assert len(hist) == 1 and hist[0].traffic_bytes > 0
    with pytest.raises(ValueError, match="unknown backend"):
        build_runner("fedavg", model, px, py, test, cfg=_cfg(),
                     backend="nonsense")


# ---------------------------------------------------------------------------
# registry extensibility
# ---------------------------------------------------------------------------


def test_register_custom_scheme(image_setup):
    """A new scheme is a bundle, not a runner subclass."""
    from repro.fl.engine import (DenseMeanAggregator, DensePayload,
                                 TierWidthAssignment)

    @register_scheme("_test_tiered_fedavg")
    def _bundle():
        return SchemeBundle(
            name="_test_tiered_fedavg",
            assignment=TierWidthAssignment,
            payload=lambda: DensePayload(sliced=False),
            aggregator=DenseMeanAggregator,
            factorized=False,
            estimate=lambda cfg: False,
        )

    try:
        model, px, py, test = image_setup
        hist = run_scheme("_test_tiered_fedavg", model, px, py, test,
                          rounds=1, cfg=_cfg())
        assert len(hist) == 1 and hist[0].traffic_bytes > 0
    finally:
        SCHEMES.pop("_test_tiered_fedavg", None)


# ---------------------------------------------------------------------------
# FedProx bundle (scheme-owned local trainer)
# ---------------------------------------------------------------------------


def test_fedprox_mu_zero_matches_fedavg(image_setup):
    """mu = 0 removes the proximal pull: FedProx must reproduce FedAvg's
    history (same assignment/payload/merge, same RNG contract)."""
    model, px, py, test = image_setup
    h_avg = run_scheme("fedavg", model, px, py, test, rounds=3,
                       cfg=_cfg(prox_mu=0.0))
    h_prox = run_scheme("fedprox", model, px, py, test, rounds=3,
                        cfg=_cfg(prox_mu=0.0))
    _assert_history_parity(h_avg, h_prox)


def test_fedprox_proximal_term_pulls_toward_global(image_setup):
    """With a large mu the local updates stay closer to the global model
    than plain FedAvg's."""
    import jax
    from repro.fl import build_runner

    model, px, py, test = image_setup

    def drift(scheme, mu):
        eng = build_runner(scheme, model, px, py, test, cfg=_cfg(prox_mu=mu))
        _, assigns = eng.assignment.assign(eng.state, [0, 1])
        results = eng.trainer.train_all(eng.state, assigns)
        base = jax.tree_util.tree_leaves(eng.params)
        tot = 0.0
        for r in results.values():
            for la, lb in zip(jax.tree_util.tree_leaves(r.params), base):
                tot += float(np.sum((np.asarray(la) - np.asarray(lb)) ** 2))
        return tot

    assert drift("fedprox", mu=5.0) < drift("fedavg", mu=5.0)


def test_fedprox_bundle_trainer_overrides_cfg(image_setup):
    from repro.fl import build_runner
    from repro.fl.engine import ProximalTrainer

    model, px, py, test = image_setup
    eng = build_runner("fedprox", model, px, py, test,
                       cfg=_cfg(trainer="cohort"))
    assert isinstance(eng.trainer, ProximalTrainer)


def test_proximal_trainer_ships_estimates(image_setup):
    """Regression: with an estimate-shipping scheme (ADP/Heroes) the
    FedProx solver must compute (L, sigma^2, G^2) under the same RNG
    contract as the sequential backend — at mu=0 the trajectories agree,
    so the estimates must too."""
    from repro.fl import build_runner
    from repro.fl.engine import ProximalTrainer

    model, px, py, test = image_setup
    e_seq = build_runner("adp", model, px, py, test, cfg=_cfg())
    e_prox = build_runner("adp", model, px, py, test, cfg=_cfg())
    assert e_seq.estimate and e_prox.estimate
    seq, prox = SequentialTrainer(), ProximalTrainer(mu=0.0)
    seq.setup(e_seq)
    prox.setup(e_prox)
    _, a_seq = e_seq.assignment.assign(e_seq.state, [0, 1])
    _, a_prox = e_prox.assignment.assign(e_prox.state, [0, 1])
    r_seq = seq.train_all(e_seq.state, a_seq)
    r_prox = prox.train_all(e_prox.state, a_prox)
    for n in r_seq:
        assert r_prox[n].estimates, "FedProx dropped the estimate signals"
        for k in ("L", "sigma_sq", "grad_sq"):
            np.testing.assert_allclose(r_prox[n].estimates[k],
                                       r_seq[n].estimates[k],
                                       rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# sample-count-weighted aggregation (FLConfig.sample_weighted)
# ---------------------------------------------------------------------------


def test_sample_weighted_matches_manual_weighted_mean():
    """FedAvg with sample_weighted=True must produce exactly
    sum(s_n * u_n) / sum(s_n) — the blend-weights formulation cancels to
    the weighted mean for global-mean rules."""
    import jax

    # 8-client dirichlet partition: known-unbalanced shard sizes
    model, px, py, test = build_image_setup(num_clients=8, seed=0)
    cfg_kw = dict(num_clients=8, clients_per_round=4)
    eng = build_runner("fedavg", model, px, py, test,
                       cfg=_cfg(sample_weighted=True, **cfg_kw))
    # twin engine (same seed) to reconstruct the per-client updates
    twin = build_runner("fedavg", model, px, py, test, cfg=_cfg(**cfg_kw))
    clients = twin.rng.choice(8, 4, replace=False)
    _, assigns = twin.assignment.assign(twin.state, list(map(int, clients)))
    results = twin.trainer.train_all(twin.state, assigns)
    s = np.array([twin.data.num_samples(n) for n in results], np.float64)
    assert len(set(s)) > 1, "partition is balanced; test would be vacuous"
    w = s / s.sum()
    expected = None
    for (n, r), wn in zip(results.items(), w):
        t = jax.tree_util.tree_map(
            lambda u, wn=wn: wn * np.asarray(u, np.float64), r.host_params())
        expected = t if expected is None else \
            jax.tree_util.tree_map(np.add, expected, t)

    eng.run_round()
    for a, b in zip(jax.tree_util.tree_leaves(eng.params),
                    jax.tree_util.tree_leaves(expected)):
        np.testing.assert_allclose(np.asarray(a, np.float64), b, atol=1e-5)


def test_sample_weighted_default_off_keeps_history(image_setup):
    model, px, py, test = image_setup
    h_def = run_scheme("heroes", model, px, py, test, rounds=2, cfg=_cfg())
    h_off = run_scheme("heroes", model, px, py, test, rounds=2,
                       cfg=_cfg(sample_weighted=False))
    _assert_history_parity(h_def, h_off, acc_atol=0.0)


@pytest.mark.parametrize("scheme", ["fedavg", "heroes"])
def test_sample_weighted_runs_all_loops(scheme, image_setup):
    """Weighted merges stay finite in both round loops (semi-async
    multiplies sample weights into the staleness discounts)."""
    model, px, py, test = image_setup
    for kw in (dict(), dict(round_mode="semi_async", async_k=2)):
        hist = run_scheme(scheme, model, px, py, test, rounds=4,
                          cfg=_cfg(sample_weighted=True, eval_every=4, **kw))
        accs = [h.accuracy for h in hist if h.accuracy is not None]
        assert accs and np.isfinite(accs[-1])


# ---------------------------------------------------------------------------
# semi-async empty-pool guard
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheme", ["fedavg", "heroes"])
def test_semi_async_empty_pool_skips_dispatch(scheme, image_setup):
    """clients_per_round > num_clients with every client already in
    flight must aggregate what is there instead of crashing in
    rng.choice / dispatching an empty assignment."""
    model, px, py, test = image_setup
    cfg = _cfg(num_clients=10, clients_per_round=12, round_mode="semi_async",
               async_k=2, eval_every=100)
    eng = build_runner(scheme, model, px, py, test, cfg=cfg)
    # force the saturated state: every client in flight before the round
    eng.state = eng.loop._dispatch(eng.state, list(range(10)))
    assert len(eng.state.in_flight) == 10
    log = eng.run_round()  # need = 2 > 0, pool empty
    assert log.round == 1 and log.makespan > 0
    # and the loop keeps making progress afterwards
    assert eng.run_round().round == 2


# ---------------------------------------------------------------------------
# streaming evaluation (FLConfig.eval_batch_size)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheme", ["heterofl", "heroes"])
def test_streaming_eval_matches_full_batch(scheme, image_setup):
    model, px, py, test = image_setup
    h_full = run_scheme(scheme, model, px, py, test, rounds=2,
                        cfg=_cfg(eval_every=1))
    h_stream = run_scheme(scheme, model, px, py, test, rounds=2,
                          cfg=_cfg(eval_every=1, eval_batch_size=64))
    for a, b in zip(h_full, h_stream):
        assert abs(a.accuracy - b.accuracy) < 1e-5


def test_eval_batches_cover_test_set(image_setup):
    from repro.fl import build_runner

    model, px, py, test = image_setup
    eng = build_runner("fedavg", model, px, py, test,
                       cfg=_cfg(eval_batch_size=32))
    n = int(test["labels"].shape[0])
    batches = list(eng.eval_batches())
    assert sum(int(b["labels"].shape[0]) for b in batches) == n
    assert all(int(b["labels"].shape[0]) <= 32 for b in batches)


# ---------------------------------------------------------------------------
# jit-cache identity fix (repro.fl.client._jitted_fns)
# ---------------------------------------------------------------------------


def test_client_jit_cache_distinguishes_model_kwargs():
    """Two CNNs differing only in a constructor kwarg the old string key
    dropped (in_ch) must not share compiled functions."""
    import jax
    from repro.fl import client as client_lib

    rng = np.random.default_rng(0)
    for in_ch in (3, 1):
        model = make_cnn(max_width=2, in_ch=in_ch)
        params = model.init_factorized(jax.random.PRNGKey(0))
        x = rng.normal(size=(8, 8, 8, in_ch)).astype(np.float32)
        y = rng.integers(0, 10, size=8)
        res = client_lib.local_train(
            model, params, 2, 2, x, y, 0.05,
            np.random.default_rng(1), batch_size=4,
            factorized=True, estimate=False)
        assert np.isfinite(res.loss_after)
