"""Numerical correctness tests for the sequence mixers (vs naive refs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (HybridConfig, ModelConfig, SSMConfig,
                                XLSTMConfig)
from repro.models import ssm, xlstm
from repro.models.attention import (apply_rotary, decode_attention,
                                    flash_attention, mrope_angles,
                                    rope_angles)


def naive_attention(q, k, v, causal=True, window=0):
    B, Sq, KV, G, D = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k) * D**-0.5
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v)


@pytest.mark.parametrize("S,win,qc,kc", [(64, 0, 16, 16), (100, 24, 32, 8),
                                         (31, 0, 8, 8)])
def test_flash_attention_matches_naive(S, win, qc, kc):
    key = jax.random.PRNGKey(S)
    B, KV, G, D = 2, 2, 2, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, KV, G, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    ref = naive_attention(q, k, v, window=win)
    for skip in (False, True):
        out = flash_attention(q, k, v, q_chunk=qc, kv_chunk=kc, window=win,
                              skip_masked_blocks=skip)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)


def test_mrope_reduces_to_rope_on_text():
    """With identical (t,h,w) position ids M-RoPE == plain RoPE."""
    D = 32
    pos = jnp.arange(10, dtype=jnp.int32)[None]
    c1, s1 = rope_angles(pos, D, 10000.0)
    pos3 = jnp.broadcast_to(pos[:, None, :], (1, 3, 10))
    c2, s2 = mrope_angles(pos3, D, 10000.0, (6, 5, 5))
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-6)


def test_rotary_preserves_norm_and_relativity():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 8, 2, 16))
    cos, sin = rope_angles(jnp.arange(8)[None], 16, 10000.0)
    y = apply_rotary(x, cos, sin)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), atol=1e-4)
    # relative property: <R_m q, R_n k> depends only on m-n
    q = jax.random.normal(jax.random.PRNGKey(1), (16,))
    k = jax.random.normal(jax.random.PRNGKey(2), (16,))

    def dot_at(m, n):
        cm, sm = rope_angles(jnp.array([[m]]), 16, 10000.0)
        cn, sn = rope_angles(jnp.array([[n]]), 16, 10000.0)
        qr = apply_rotary(q[None, None, None], cm, sm)[0, 0, 0]
        kr = apply_rotary(k[None, None, None], cn, sn)[0, 0, 0]
        return float(qr @ kr)

    assert abs(dot_at(5, 3) - dot_at(9, 7)) < 1e-3


def test_mamba2_long_chunk_boundary():
    """Chunked SSD must be exact across chunk boundaries (state carry)."""
    cfg = ModelConfig(arch_id="t", family="hybrid", num_layers=1, d_model=16,
                      num_heads=2, num_kv_heads=2, d_ff=0, vocab=16,
                      ssm=SSMConfig(state_dim=4, head_dim=8, chunk=8))
    p = ssm.init_mamba2(jax.random.PRNGKey(0), cfg, jnp.float32)
    u = jax.random.normal(jax.random.PRNGKey(1), (1, 40, 16))
    full = ssm.apply_mamba2(p, cfg, u)
    cfg_big = cfg.replace(ssm=SSMConfig(state_dim=4, head_dim=8, chunk=64))
    whole = ssm.apply_mamba2(p, cfg_big, u)
    np.testing.assert_allclose(np.asarray(full), np.asarray(whole),
                               atol=2e-4, rtol=2e-4)


def test_mlstm_forget_gate_limits():
    """f -> +inf keeps memory; i -> -inf ignores input: sanity on gates."""
    cfg = ModelConfig(arch_id="t", family="ssm", num_layers=1, d_model=16,
                      num_heads=2, num_kv_heads=2, d_ff=0, vocab=16,
                      xlstm=XLSTMConfig())
    B, T = 1, 6
    d_up, H, dqk, dv = xlstm.mlstm_dims(cfg)
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, T, H, dqk))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, H, dqk))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, H, dv))
    # i very negative except t=0: output at t>0 should attend only to t=0
    i_pre = jnp.full((B, T, H), -1e9).at[:, 0].set(0.0)
    f_pre = jnp.full((B, T, H), 1e9)  # keep everything
    h = xlstm.mlstm_parallel(q, k, v, i_pre, f_pre)
    # state frozen after t=0 -> h_t proportional to v_0 direction for all t
    h0 = np.asarray(h[:, 1:])
    v0 = np.asarray(v[:, 0])[:, None]
    cos = (h0 * v0).sum(-1) / (
        np.linalg.norm(h0, axis=-1) * np.linalg.norm(v0, axis=-1) + 1e-9)
    assert np.all(np.abs(cos) > 0.99)


def test_decode_attention_ignores_invalid():
    key = jax.random.PRNGKey(0)
    B, S, KV, G, D = 2, 32, 2, 1, 8
    q = jax.random.normal(key, (B, 1, KV, G, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, D))
    valid = jnp.arange(S)[None, :] < 10
    out1 = decode_attention(q, k, v, jnp.broadcast_to(valid, (B, S)))
    # corrupt the invalid region — output must not change
    k2 = k.at[:, 10:].set(99.0)
    v2 = v.at[:, 10:].set(-99.0)
    out2 = decode_attention(q, k2, v2, jnp.broadcast_to(valid, (B, S)))
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)
