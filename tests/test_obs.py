"""Telemetry tests (repro.obs + engine instrumentation).

Covers: the recorder/sink/schema/trace/coverage/report toolkit units,
telemetry-off bitwise parity against the golden legacy fixtures,
telemetry-on leaving histories bitwise-unchanged for every scheme in
both round modes while the in-memory sink sees at least one span per
sampled client per round, and the generalized recompile-count
regression driven by the new ``trainer.jit_recompiles`` counter.
"""

import dataclasses
import json
from pathlib import Path

import numpy as np
import pytest

from repro.fl import FLConfig, build_image_setup, build_runner, run_scheme
from repro.obs import (NOOP, JsonlSink, MemorySink, NoopRecorder, Recorder,
                       build_recorder, coverage_table, format_coverage,
                       load_events, metric_key, to_trace_events,
                       validate_events)
from repro.obs.report import render_report

GOLDEN = json.loads(
    (Path(__file__).parent / "fixtures"
     / "golden_legacy_histories.json").read_text())
SCHEMES = sorted(k for k in GOLDEN if k != "_meta")


@pytest.fixture(scope="module")
def image_setup():
    return build_image_setup(num_clients=10, seed=0)


def _cfg(**kw):
    # forward_impl pinned: golden fixtures predate the measured rank-path
    # calibration; "auto" choices may differ per host.
    base = dict(num_clients=10, clients_per_round=4, eval_every=2,
                tau_fixed=4, tau_max=15, estimate=True,
                forward_impl="materialize")
    base.update(kw)
    return FLConfig(**base)


# ---------------------------------------------------------------------------
# recorder + metrics registry units
# ---------------------------------------------------------------------------


def test_metric_key_label_folding():
    assert metric_key("traffic.up", {}) == "traffic.up"
    assert metric_key("traffic.up", {"width": 2}) == "traffic.up[width=2]"
    # labels sort, so call-site keyword order never splits a series
    assert (metric_key("x", {"b": 1, "a": 2})
            == metric_key("x", {"a": 2, "b": 1}) == "x[a=2,b=1]")


def test_recorder_registry_and_snapshot():
    rec = Recorder()
    rec.counter_add("c", 2.0)
    rec.counter_add("c", 3.0)
    rec.counter_add("c", 1.0, width=1)
    rec.gauge_set("g", 7.0)
    rec.gauge_set("g", 9.0)
    rec.observe("h", 0.5)
    rec.observe("h", 1.5)
    snap = rec.snapshot()
    assert snap["counters"] == {"c": 5.0, "c[width=1]": 1.0}
    assert snap["gauges"] == {"g": 9.0}
    assert snap["histograms"] == {"h": [0.5, 1.5]}


def test_tally_grows_and_accumulates_repeated_ids():
    rec = Recorder()
    rec.tally_add("cov", [0, 2, 2], 1)
    assert rec.tallies["cov"].tolist() == [1, 0, 2]
    rec.tally_add("cov", [5], 3)  # grows the dense array
    assert rec.tallies["cov"].tolist() == [1, 0, 2, 0, 0, 3]
    rec.tally_add("cov", [0, 1], np.array([10, 20]))  # per-id amounts
    assert rec.tallies["cov"].tolist() == [11, 20, 2, 0, 0, 3]
    rec.tally_add("cov", [])  # empty id list is a no-op
    assert rec.tallies["cov"].tolist() == [11, 20, 2, 0, 0, 3]


def test_span_stream_and_wall_span():
    sink = MemorySink()
    rec = Recorder([sink], meta={"scheme": "heroes"})
    rec.span("client.train", 1.0, 3.5, client=4)
    rec.event("round.aggregate", 3.5, round=0)
    with rec.wall_span("aggregate.merge", clients=4):
        pass
    rec.close()

    assert sink.events[0]["type"] == "meta"
    assert sink.events[0]["scheme"] == "heroes"
    (tr,) = sink.spans("client.train")
    assert tr["clock"] == "virtual" and tr["t0"] == 1.0 and tr["t1"] == 3.5
    assert tr["attrs"] == {"client": 4}
    (ev,) = sink.events_named("round.aggregate")
    assert ev["t"] == 3.5
    (mg,) = sink.spans("aggregate.merge")
    assert mg["clock"] == "wall" and mg["t1"] >= mg["t0"]
    # wall_span also lands a <name>_s histogram entry
    assert len(rec.histograms["aggregate.merge_s"]) == 1
    # close emitted the final metrics snapshot (and is idempotent)
    assert sink.metrics is not None
    n = len(sink.events)
    rec.close()
    assert len(sink.events) == n


def test_noop_recorder_is_inert_singleton():
    assert NOOP.enabled is False
    assert isinstance(NOOP, NoopRecorder)
    NOOP.counter_add("c", 5)
    NOOP.observe("h", 1.0)
    NOOP.tally_add("t", [0, 1])
    NOOP.span("s", 0, 1)
    with NOOP.wall_span("w"):
        pass
    assert NOOP.snapshot() == {"counters": {}, "gauges": {},
                               "histograms": {}, "tallies": {}}
    assert NOOP.counters == {} and NOOP.tallies == {}


def test_build_recorder_modes(tmp_path):
    assert build_recorder(_cfg()) is NOOP
    rec = build_recorder(_cfg(telemetry="memory"), meta={"scheme": "x"})
    assert rec.enabled and isinstance(rec.sinks[0], MemorySink)
    # the meta header always carries an environment fingerprint
    assert "provenance" in rec.sinks[0].events[0]
    with pytest.raises(ValueError, match="telemetry_dir"):
        build_recorder(_cfg(telemetry="jsonl"))
    with pytest.raises(ValueError, match="unknown telemetry"):
        build_recorder(_cfg(telemetry="bogus"))
    rec = build_recorder(_cfg(telemetry="jsonl",
                              telemetry_dir=str(tmp_path)))
    rec.span("s", 0.0, 1.0)
    rec.close()
    assert (tmp_path / "events.jsonl").exists()


# ---------------------------------------------------------------------------
# jsonl round-trip, schema, trace export
# ---------------------------------------------------------------------------


def test_jsonl_roundtrip_schema_and_trace(tmp_path):
    path = tmp_path / "events.jsonl"
    rec = Recorder([JsonlSink(path)], meta={"scheme": "heroes"})
    rec.span("client.train", 0.0, 2.0, client=1, round=0)
    rec.span("aggregate.merge", 0.1, 0.2, clock="wall", clients=4)
    rec.event("round.aggregate", 2.0, round=0)
    rec.counter_add("traffic.up", 100.0, width=2)
    rec.close()

    events = load_events(path)
    validate_events(events)  # raises on any malformed entry
    assert events[0]["type"] == "meta"
    assert events[-1]["type"] == "metrics"
    assert events[-1]["counters"] == {"traffic.up[width=2]": 100.0}

    trace = to_trace_events(events)
    tev = trace["traceEvents"]
    kinds = {t["ph"] for t in tev}
    assert "X" in kinds and "M" in kinds and "i" in kinds
    (tr,) = [t for t in tev if t["ph"] == "X"
             and t["name"] == "client.train"]
    assert tr["dur"] == pytest.approx(2.0 * 1e6)  # seconds -> microseconds
    # virtual spans with a client attr land on per-client tracks under
    # the virtual-clock process; wall spans under the host process
    assert tr["pid"] == 1
    (mg,) = [t for t in tev if t["ph"] == "X"
             and t["name"] == "aggregate.merge"]
    assert mg["pid"] == 2
    json.dumps(trace)  # valid trace_event JSON


def test_load_events_tolerates_torn_tail(tmp_path):
    path = tmp_path / "events.jsonl"
    path.write_text('{"type": "meta", "schema": 1}\n{"type": "spa')
    events = load_events(path)
    assert len(events) == 1 and events[0]["type"] == "meta"


def test_schema_rejects_malformed_events():
    from repro.obs.schema import validate_event

    with pytest.raises(ValueError):
        validate_event({"type": "span", "name": "x"})  # missing t0/t1
    with pytest.raises(ValueError):
        validate_event({"type": "span", "name": "x", "clock": "lunar",
                        "t0": 0.0, "t1": 1.0, "attrs": {}})
    with pytest.raises(ValueError):
        validate_events([{"type": "span", "name": "x", "clock": "wall",
                          "t0": 0.0, "t1": 1.0, "attrs": {}}])  # no meta


# ---------------------------------------------------------------------------
# coverage table + report rendering
# ---------------------------------------------------------------------------


def test_coverage_table_from_tallies():
    metrics = {"counters": {"coverage.events": 4.0},
               "tallies": {"coverage.hidden_rounds": [4, 2, 0],
                           "coverage.hidden_iters": [40, 20, 0]}}
    table = coverage_table(metrics)
    t = table["hidden"]
    assert t["events"] == 4
    assert t["coverage"] == pytest.approx([1.0, 0.5, 0.0])
    assert t["min"] == 0.0 and t["max"] == 1.0
    assert t["iters"] == [40, 20, 0]
    text = format_coverage(table)
    assert "hidden" in text and "100.00%" in text
    assert format_coverage({}).startswith("(no coverage")


def test_report_renders_engine_run(image_setup):
    model, px, py, test = image_setup
    eng = build_runner("heroes", model, px, py, test,
                       cfg=_cfg(telemetry="memory"))
    eng.run(3)
    eng.close()
    text = render_report(eng.obs.sinks[0].events)
    assert "scheme=heroes" in text
    assert "per-block coverage" in text
    assert "-- traffic --" in text and "uplink" in text
    assert "participation by capacity class" in text
    assert "compiled-step cache" in text


# ---------------------------------------------------------------------------
# engine parity: telemetry must be invisible to training
# ---------------------------------------------------------------------------


def test_telemetry_off_matches_golden(image_setup):
    """The default (off) path reproduces the pre-telemetry goldens
    bitwise on the fields the fixture records."""
    model, px, py, test = image_setup
    rounds = len(GOLDEN["heroes"])
    hist = run_scheme("heroes", model, px, py, test, rounds=rounds,
                      cfg=_cfg())
    keys = set(GOLDEN["heroes"][0])
    got = [{k: v for k, v in dataclasses.asdict(h).items() if k in keys}
           for h in hist]
    assert got == GOLDEN["heroes"]


@pytest.mark.parametrize("round_mode", ["sync", "semi_async"])
@pytest.mark.parametrize("scheme", SCHEMES)
def test_telemetry_on_leaves_histories_unchanged(scheme, round_mode,
                                                 image_setup):
    """telemetry='memory' must not perturb training at all — histories
    are compared bitwise against the telemetry-off run — and the sink
    must see >= 1 train span per sampled client per round."""
    model, px, py, test = image_setup
    rounds = 3
    h_off = run_scheme(scheme, model, px, py, test, rounds=rounds,
                       cfg=_cfg(round_mode=round_mode))
    eng = build_runner(scheme, model, px, py, test,
                       cfg=_cfg(round_mode=round_mode, telemetry="memory"))
    h_on = eng.run(rounds)
    eng.close()

    assert ([dataclasses.asdict(h) for h in h_on]
            == [dataclasses.asdict(h) for h in h_off])

    sink = eng.obs.sinks[0]
    trains = sink.spans("client.train")
    uploads = sink.spans("client.upload")
    assert len(uploads) == len(trains)
    # every dispatch of every round shows up (span rounds are 1-indexed):
    # in sync mode that is exactly one span per sampled client per round;
    # semi-async always refills the flight pool, so every event
    # dispatches at least one client too
    by_round = {}
    for s in trains:
        by_round.setdefault(s["attrs"]["round"], []).append(
            s["attrs"]["client"])
    assert set(by_round) == set(range(1, rounds + 1))
    for r, clients in by_round.items():
        assert len(clients) >= 1
        assert len(set(clients)) == len(clients)
        if round_mode == "sync":
            assert len(clients) == 4  # clients_per_round
    # virtual-clock sanity: train precedes upload, both non-negative
    for tr, up in zip(trains, uploads):
        assert tr["t1"] >= tr["t0"] >= 0.0
        assert up["t1"] >= up["t0"] >= tr["t1"]
    # uplink/downlink counters account for the run's traffic bitwise
    snap = sink.metrics
    up = sum(v for k, v in snap["counters"].items()
             if k.startswith("traffic.up"))
    down = sum(v for k, v in snap["counters"].items()
               if k.startswith("traffic.down"))
    assert up + down == pytest.approx(h_on[-1].traffic_bytes)
    if round_mode == "semi_async":
        assert snap["histograms"].get("staleness")


def test_semi_async_staleness_and_split_consistency(image_setup):
    model, px, py, test = image_setup
    eng = build_runner("heroes", model, px, py, test,
                       cfg=_cfg(round_mode="semi_async",
                                telemetry="memory"))
    hist = eng.run(4)
    eng.close()
    prev = 0.0
    for h in hist:
        assert h.up_bytes + h.down_bytes == h.traffic_bytes - prev
        prev = h.traffic_bytes
    stale = eng.obs.sinks[0].metrics["histograms"]["staleness"]
    assert all(s >= 0 for s in stale)


# ---------------------------------------------------------------------------
# recompile accounting (generalizes the semi-async cohort regression)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("round_mode", ["sync", "semi_async"])
@pytest.mark.parametrize("scheme", SCHEMES)
def test_recompiles_bounded_by_distinct_cohort_shapes(scheme, round_mode,
                                                      image_setup):
    """Over 6 rounds, each scheme x round-mode compiles its cohort train
    step at most once per *distinct* padded cohort shape — the counter
    the instrumentation exports is exactly the regression signal the
    old semi-async-only test probed via jit internals."""
    model, px, py, test = image_setup
    eng = build_runner(scheme, model, px, py, test,
                       cfg=_cfg(round_mode=round_mode, trainer="cohort",
                                eval_every=100, telemetry="memory"))
    eng.run(6)
    eng.close()
    counters = eng.obs.sinks[0].metrics["counters"]
    recompiles = sum(v for k, v in counters.items()
                     if k.startswith("trainer.jit_recompiles"))
    shapes = [k for k in counters if k.startswith("trainer.cohort_shape[")]
    assert shapes, counters  # the cohort trainer ran and was observed
    # make_cnn memoizes model instances, so the jitted step cache is
    # shared process-wide: earlier tests may have pre-compiled some
    # shapes (fewer recompiles here), but never the reverse.
    assert recompiles <= len(shapes), (recompiles, shapes)
