"""Tests for the federated dataset subsystem (repro.data).

Covers: partitioner-registry invariants (disjointness / coverage),
loader fallback byte-determinism across processes, the npz cache, shard
views + the streaming RNG contract, registry-loader end-to-end runs for
every scheme, and streaming-vs-materialized history parity.
"""

import hashlib
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.data import (ClientDataLoader, PARTITIONERS, ShardView,
                        load_dataset, make_shards, partition_dataset,
                        round_batch_indices)
from repro.data.cache import cache_key, cache_path, cached, load_arrays
from repro.fl import FLConfig, build_image_setup, build_text_setup, run_scheme
from repro.fl.engine.registry import SCHEMES

# ---------------------------------------------------------------------------
# partitioner properties
# ---------------------------------------------------------------------------

_LABEL_SETS = [
    np.repeat(np.arange(10), 60),            # balanced, divisible
    np.random.default_rng(3).integers(0, 7, 501),  # ragged, odd N
]


def _flat(parts):
    return np.concatenate([p for p in parts if len(p)]) if parts else np.empty(0)


@pytest.mark.parametrize("labels", _LABEL_SETS, ids=["balanced", "ragged"])
@pytest.mark.parametrize("name,kw", [
    ("dirichlet", {"gamma_pct": 60.0}),
    ("class_skew", {"missing": 2}),
    ("iid", {}),
    ("natural", {}),
])
def test_partitions_disjoint_and_in_range(name, kw, labels):
    num_clients = 8
    parts = PARTITIONERS[name](labels, num_clients, seed=0, metadata={}, **kw)
    assert len(parts) == num_clients
    flat = _flat(parts)
    assert len(np.unique(flat)) == len(flat), "an index was assigned twice"
    assert flat.min() >= 0 and flat.max() < len(labels)


@pytest.mark.parametrize("labels", _LABEL_SETS, ids=["balanced", "ragged"])
@pytest.mark.parametrize("name", ["iid", "natural"])
def test_full_coverage_partitioners(name, labels):
    """iid/natural cover every train index exactly once."""
    parts = PARTITIONERS[name](labels, 8, seed=0, metadata={})
    np.testing.assert_array_equal(np.sort(_flat(parts)),
                                  np.arange(len(labels)))


def test_dirichlet_volume_bound_and_skew():
    # Γ caps each client at n_per_client; later clients may under-fill
    # as class pools deplete (documented in repro.data.partition)
    labels = np.repeat(np.arange(10), 120)
    parts = partition_dataset_like(labels, "dirichlet", 10, gamma_pct=80.0)
    n_per_client = len(labels) // 10
    for n, p in enumerate(parts):
        assert 0 < len(p) <= n_per_client
        main = np.bincount(labels[p], minlength=10).max() / len(p)
        assert main >= 0.7, "Γ=80% main-class share not respected"


def test_class_skew_misses_classes():
    labels = np.repeat(np.arange(10), 60)
    parts = partition_dataset_like(labels, "class_skew", 6, missing=3)
    for p in parts:
        present = np.unique(labels[p])
        assert len(present) <= 10 - 3


def partition_dataset_like(labels, name, num_clients, **kw):
    return PARTITIONERS[name](labels, num_clients, seed=0, metadata={}, **kw)


def test_natural_partition_keeps_groups_whole():
    rng = np.random.default_rng(0)
    ids = rng.integers(0, 12, 400)
    parts = PARTITIONERS["natural"](np.zeros(400), 5, seed=0,
                                    metadata={"natural_ids": ids})
    np.testing.assert_array_equal(np.sort(_flat(parts)), np.arange(400))
    owner = {}
    for client, p in enumerate(parts):
        for g in np.unique(ids[p]):
            assert owner.setdefault(g, client) == client, \
                f"group {g} split across clients"


def test_partition_dataset_respects_num_clients_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(num_clients=st.integers(2, 16), seed=st.integers(0, 10),
           gamma=st.floats(10.0, 100.0))
    def run(num_clients, seed, gamma):
        labels = np.repeat(np.arange(5), 40)
        parts = PARTITIONERS["dirichlet"](labels, num_clients, seed=seed,
                                          metadata={}, gamma_pct=gamma)
        assert len(parts) == num_clients
        flat = _flat(parts)
        assert len(np.unique(flat)) == len(flat)

    run()


# ---------------------------------------------------------------------------
# loaders: fallback determinism + cache
# ---------------------------------------------------------------------------


_DIGEST_SRC = """
import hashlib
import numpy as np
from repro.data import load_dataset

def digest(task, kw):
    ds = load_dataset(task, **kw)
    h = hashlib.sha256()
    for split in sorted(ds.splits):
        for arr in ds.splits[split]:
            h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()
"""

_ns = {}
exec(_DIGEST_SRC, _ns)
_digest = _ns["digest"]


@pytest.mark.parametrize("task,kw", [
    ("cifar10", {"seed": 7, "train_size": 128, "test_size": 32}),
    ("shakespeare", {"seed": 7, "train_size": 128, "test_size": 32}),
])
def test_fallback_byte_deterministic_across_processes(task, kw):
    """Synthetic fallbacks are pure functions of their key — a fresh
    interpreter reproduces the same bytes."""
    local = _digest(task, kw)
    repo = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["PYTHONPATH"] = str(repo / "src")
    env.pop("REPRO_DATA_CACHE", None)  # hash generation, not a cache read
    code = _DIGEST_SRC + f"\nprint(digest({task!r}, {kw!r}))\n"
    out = subprocess.run([sys.executable, "-c", code], cwd=repo,
                         capture_output=True, text=True, check=True, env=env)
    assert out.stdout.strip().splitlines()[-1] == local


def test_cache_roundtrip_and_hit(tmp_path):
    calls = []

    def build():
        calls.append(1)
        return {"a": np.arange(5), "b": np.eye(2)}

    a1, hit1 = cached("toy", {"seed": 1}, build, cache_dir=tmp_path)
    a2, hit2 = cached("toy", {"seed": 1}, build, cache_dir=tmp_path)
    assert (not hit1) and hit2 and len(calls) == 1
    np.testing.assert_array_equal(a1["a"], a2["a"])
    # a different key regenerates under a different file
    _, hit3 = cached("toy", {"seed": 2}, build, cache_dir=tmp_path)
    assert not hit3 and len(calls) == 2
    # corrupt entries regenerate silently
    path = cache_path(tmp_path, "toy", cache_key(task="toy", seed=1))
    path.write_bytes(b"not an npz")
    assert load_arrays(path) is None
    _, hit4 = cached("toy", {"seed": 1}, build, cache_dir=tmp_path)
    assert not hit4 and len(calls) == 3


def test_loader_uses_cache_dir(tmp_path):
    kw = dict(seed=3, train_size=64, test_size=16, cache_dir=tmp_path)
    d1 = load_dataset("cifar10", **kw)
    files = list(tmp_path.rglob("*.npz"))
    assert files, "loader did not populate the cache"
    d2 = load_dataset("cifar10", **kw)
    np.testing.assert_array_equal(d1.x, d2.x)
    assert cache_key(a=1, b=2) == cache_key(b=2, a=1)


def test_cifar10_npz_file_reader(tmp_path):
    rng = np.random.default_rng(0)
    arrays = {
        "x_train": rng.integers(0, 255, (48, 32, 32, 3)).astype(np.uint8),
        "y_train": rng.integers(0, 10, 48).astype(np.int32),
        "x_test": rng.integers(0, 255, (16, 32, 32, 3)).astype(np.uint8),
        "y_test": rng.integers(0, 10, 16).astype(np.int32),
    }
    np.savez(tmp_path / "cifar10.npz", **arrays)
    ds = load_dataset("cifar10", data_root=tmp_path)
    assert ds.metadata["source"] == "files"
    assert ds.x.shape == (48, 32, 32, 3) and ds.x.dtype == np.float32
    assert abs(float(ds.x.mean())) < 2.0  # standardized, not raw pixels


def test_cifar10_binary_reader(tmp_path):
    rng = np.random.default_rng(1)

    def write(path, n):
        rec = np.zeros((n, 3073), np.uint8)
        rec[:, 0] = rng.integers(0, 10, n)
        rec[:, 1:] = rng.integers(0, 255, (n, 3072))
        path.write_bytes(rec.tobytes())
        return rec

    recs = [write(tmp_path / f"data_batch_{i}.bin", 20) for i in range(1, 6)]
    write(tmp_path / "test_batch.bin", 8)
    ds = load_dataset("cifar10", data_root=tmp_path, normalize=False)
    assert ds.metadata["source"] == "files"
    labels = np.concatenate([r[:, 0] for r in recs]).astype(np.int32)
    np.testing.assert_array_equal(ds.y, labels)
    # channel-major record bytes land as HWC pixels
    np.testing.assert_array_equal(
        ds.x[0, :, :, 0].ravel(), recs[0][0, 1:1025].astype(np.float32))


def test_cifar10_partial_binary_set_rejected(tmp_path):
    """Some-but-not-all batches is a hard error, not silent partial data."""
    (tmp_path / "data_batch_1.bin").write_bytes(b"\0" * 3073)
    (tmp_path / "test_batch.bin").write_bytes(b"\0" * 3073)
    with pytest.raises(FileNotFoundError, match="incomplete"):
        load_dataset("cifar10", data_root=tmp_path)


def test_cifar10_file_cache_invalidates_on_change(tmp_path):
    import os

    root, cache = tmp_path / "data", tmp_path / "cache"
    root.mkdir()
    rng = np.random.default_rng(2)

    def write_npz(off):
        np.savez(root / "cifar10.npz",
                 x_train=np.full((8, 32, 32, 3), off, np.uint8),
                 y_train=rng.integers(0, 10, 8).astype(np.int32),
                 x_test=np.full((4, 32, 32, 3), off, np.uint8),
                 y_test=rng.integers(0, 10, 4).astype(np.int32))

    write_npz(10)
    d1 = load_dataset("cifar10", data_root=root, cache_dir=cache,
                      normalize=False)
    write_npz(200)
    os.utime(root / "cifar10.npz", ns=(1, 1))  # force a distinct mtime
    d2 = load_dataset("cifar10", data_root=root, cache_dir=cache,
                      normalize=False)
    assert float(d1.x[0, 0, 0, 0]) == 10.0
    assert float(d2.x[0, 0, 0, 0]) == 200.0, "stale cache served"


def test_shakespeare_text_parser(tmp_path):
    lines = []
    for turn in range(30):
        who = ["First Citizen", "Second Citizen", "MENENIUS"][turn % 3]
        lines += [f"{who}:", f"speech {turn} of sufficient length to window.",
                  ""]
    (tmp_path / "shakespeare.txt").write_text("\n".join(lines))
    ds = load_dataset("shakespeare", data_root=tmp_path, seq_len=16)
    assert ds.metadata["source"] == "files"
    assert ds.metadata["num_speakers"] == 3
    ids = ds.metadata["natural_ids"]
    assert len(ids) == len(ds.x)
    assert ds.x.shape[1] == 16 and ds.y.shape == ds.x.shape
    # labels are the next-char shift of the inputs
    np.testing.assert_array_equal(ds.x[0, 1:], ds.y[0, :-1])


# ---------------------------------------------------------------------------
# streaming: shard views + RNG contract + loader
# ---------------------------------------------------------------------------


def test_shard_view_matches_materialized():
    rng = np.random.default_rng(0)
    base = rng.normal(size=(100, 4, 4, 3)).astype(np.float32)
    part = rng.choice(100, 40, replace=False)
    view = ShardView(base, part)
    mat = base[part]
    assert len(view) == 40 and view.shape == mat.shape
    idx1 = rng.integers(0, 40, 16)
    idx2 = rng.integers(0, 40, (5, 8))  # 2-D gather (cohort layout)
    np.testing.assert_array_equal(view[idx1], mat[idx1])
    np.testing.assert_array_equal(view[idx2], mat[idx2])
    np.testing.assert_array_equal(np.asarray(view), mat)


def test_round_batch_indices_matches_sequential_rng_contract():
    """The loader's draws must replicate local_train's stream exactly:
    default_rng((seed, round, n)), tau batch draws then 3 estimate draws."""
    seed, rnd, n, nsamp, tau, bs = 5, 3, 7, 53, 4, 8
    idx, est = round_batch_indices(seed, rnd, n, nsamp, tau, bs,
                                   estimate=True, tau_pad=8)
    rng = np.random.default_rng((seed, rnd, n))
    ref = np.stack([rng.integers(0, nsamp, bs) for _ in range(tau)])
    ref_est = np.stack([rng.integers(0, nsamp, bs) for _ in range(3)])
    np.testing.assert_array_equal(idx[:tau], ref)
    np.testing.assert_array_equal(est, ref_est)
    # padded steps repeat the last real batch (masked no-ops downstream)
    for t in range(tau, 8):
        np.testing.assert_array_equal(idx[t], ref[-1])


def test_client_data_loader_gather_and_prefetch():
    ds = load_dataset("synthetic_image", seed=0)
    parts = partition_dataset(ds, "iid", 6, seed=0)
    loader = ClientDataLoader.from_dataset(ds, parts, streaming=True)
    assert loader.num_clients == 6
    xs, ys, est = loader.draw_round(2, seed=0, rnd=1, tau=3, batch_size=4,
                                    estimate=True)
    assert xs.shape[:2] == (3, 4) and ys.shape == (3, 4)
    assert est[0].shape[:2] == (3, 4)
    # prefetch preserves order and surfaces results identically
    items = list(range(7))
    assert list(loader.prefetch(items, lambda i: i * i)) == [i * i for i in items]
    with pytest.raises(RuntimeError):
        for _ in loader.prefetch(items, lambda i: (_ for _ in ()).throw(
                RuntimeError("boom"))):
            pass


def test_prefetch_abandoned_generator_releases_worker():
    """Breaking out of a prefetch stream must not leak the worker
    thread (it blocks on a bounded queue)."""
    import threading
    import time

    loader = ClientDataLoader([np.zeros(4)], [np.zeros(4)],
                              prefetch_depth=1)
    gen = loader.prefetch(range(50), lambda i: np.zeros((64, 64)) + i)
    next(gen)
    gen.close()  # abandon mid-stream; finally-block must stop the worker
    deadline = time.time() + 10
    while time.time() < deadline:
        if not any(t.name == "client-data-prefetch"
                   for t in threading.enumerate()):
            break
        time.sleep(0.05)
    assert not any(t.name == "client-data-prefetch"
                   for t in threading.enumerate()), "prefetch thread leaked"


# ---------------------------------------------------------------------------
# end-to-end: every scheme x both registry loaders, streaming parity
# ---------------------------------------------------------------------------

_E2E_CFG = FLConfig(num_clients=6, clients_per_round=3, tau_fixed=2,
                    tau_max=6, eval_every=1, batch_size=8, lr=0.1,
                    trainer="cohort")


@pytest.fixture(scope="module")
def cifar_setup():
    return build_image_setup(
        num_clients=6, seed=0, task="cifar10", max_width=2,
        task_kw={"train_size": 240, "test_size": 60, "hw": 8})


@pytest.fixture(scope="module")
def shakespeare_setup():
    return build_text_setup(
        num_clients=6, seed=0, task="shakespeare", max_width=2,
        task_kw={"train_size": 240, "test_size": 60, "num_speakers": 8})


@pytest.mark.parametrize("scheme", sorted(SCHEMES))
def test_all_schemes_on_cifar_loader(scheme, cifar_setup):
    hist = run_scheme(scheme, *cifar_setup, rounds=1, cfg=_E2E_CFG)
    assert len(hist) == 1
    assert hist[0].accuracy is not None and np.isfinite(hist[0].accuracy)
    assert hist[0].traffic_bytes > 0


@pytest.mark.parametrize("scheme", sorted(SCHEMES))
def test_all_schemes_on_shakespeare_loader(scheme, shakespeare_setup):
    hist = run_scheme(scheme, *shakespeare_setup, rounds=1, cfg=_E2E_CFG)
    assert len(hist) == 1
    assert hist[0].accuracy is not None and np.isfinite(hist[0].accuracy)


def test_streaming_matches_materialized_history():
    cfg = FLConfig(num_clients=8, clients_per_round=3, tau_fixed=3,
                   tau_max=10, eval_every=1, estimate=True, trainer="cohort")
    hs = run_scheme("heroes",
                    *build_image_setup(num_clients=8, seed=0, streaming=True),
                    rounds=2, cfg=cfg)
    hm = run_scheme("heroes",
                    *build_image_setup(num_clients=8, seed=0, streaming=False),
                    rounds=2, cfg=cfg)
    for a, b in zip(hs, hm):
        assert a.wall_time == b.wall_time
        assert a.traffic_bytes == b.traffic_bytes
        assert a.accuracy == b.accuracy


def test_text_setup_routes_through_partitioners():
    """Non-IID settings are no longer silently ignored for text."""
    _, px_nat, _, _ = build_text_setup(num_clients=6, seed=1)
    _, px_dir, _, _ = build_text_setup(
        num_clients=6, seed=1, partitioner="dirichlet",
        partition_kw={"gamma_pct": 90.0})
    # natural fallback == the legacy contiguous shards
    ds = load_dataset("synthetic_text", seed=1)
    shards = np.array_split(np.arange(len(ds.x)), 6)
    for view, ref in zip(px_nat, shards):
        np.testing.assert_array_equal(view.indices, ref)
    # the dirichlet split must differ from the contiguous one
    assert any(not np.array_equal(a.indices, b.indices)
               for a, b in zip(px_dir, px_nat))


def test_shakespeare_natural_partition_by_speaker():
    ds = load_dataset("shakespeare", seed=0, train_size=240, test_size=60,
                      num_speakers=8)
    parts = partition_dataset(ds, "natural", 4, seed=0)
    ids = ds.metadata["natural_ids"]
    np.testing.assert_array_equal(np.sort(np.concatenate(parts)),
                                  np.arange(len(ds.x)))
    for p in parts:
        assert len(p), "a client received no speakers"
