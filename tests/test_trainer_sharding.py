"""Mesh-sharded cohort training (repro.fl.engine.trainers + sharding.fl).

The cohort trainer lays its client axis out on the same 1-D device mesh
the collective merge rides (``COHORT_AXIS``).  On one device the code
path is the unchanged single-device cohort step (bitwise); on a mesh the
per-client math is identical, so the parity matrix below holds at float
tolerance and — under the 4-device CI leg — exercises the sharded
train + device-resident hand-off end to end.  Explicit 4-device cases
run in subprocesses (XLA_FLAGS must precede jax init).
"""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.data.streaming import stack_client_shards
from repro.sharding import fl as flsh

ROOT = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------


def test_cohort_mesh_uses_local_devices(monkeypatch):
    """Regression: the mesh must be built over jax.local_devices() —
    under multi-process JAX, jax.devices() lists devices other hosts
    own, which this process cannot address."""
    calls = {"local": 0}
    real_local = jax.local_devices

    def fake_global():  # pragma: no cover - failing is the assertion
        pytest.fail("cohort_mesh consulted jax.devices() (global) "
                    "instead of jax.local_devices()")

    def fake_local():
        calls["local"] += 1
        return real_local()

    monkeypatch.setattr(jax, "devices", fake_global)
    monkeypatch.setattr(jax, "local_devices", fake_local)
    mesh = flsh.cohort_mesh()
    assert calls["local"] == 1
    if len(real_local()) < 2:
        assert mesh is None
    else:
        assert mesh.devices.size == len(real_local())


class _FakeMesh:
    def __init__(self, n):
        self.devices = np.empty((n,), object)


def test_pad_cohort_rounds_to_mesh_multiple():
    assert flsh.pad_cohort(5, None) == 5
    mesh = _FakeMesh(4)
    assert flsh.pad_cohort(1, mesh) == 4
    assert flsh.pad_cohort(4, mesh) == 4
    assert flsh.pad_cohort(9, mesh) == 12


def test_stack_client_shards_matches_monolithic_stack():
    rng = np.random.default_rng(0)
    per_client = [rng.normal(size=(3, 4, 2)).astype(np.float32)
                  for _ in range(8)]
    mono = np.moveaxis(np.stack(per_client), 0, 1)
    # one chunk reproduces the monolithic stack bitwise
    (one,) = stack_client_shards(per_client, 1, step_leading=True)
    np.testing.assert_array_equal(one, mono)
    # four chunks concatenate back to it on the client axis
    four = stack_client_shards(per_client, 4, step_leading=True)
    assert len(four) == 4 and all(s.shape == (3, 2, 4, 2) for s in four)
    np.testing.assert_array_equal(np.concatenate(four, axis=1), mono)
    # non-step-leading keeps the client axis first
    chunks = stack_client_shards(per_client, 2)
    np.testing.assert_array_equal(np.concatenate(chunks, axis=0),
                                  np.stack(per_client))
    with pytest.raises(ValueError):
        stack_client_shards(per_client, 3)


def test_trainer_mesh_devices_cap():
    """trainer_mesh_devices=1 pins the single-device cohort path even on
    a multi-device host; 0 takes every local device."""
    from repro.fl import FLConfig, build_image_setup, build_runner

    model, px, py, test = build_image_setup(num_clients=6, seed=0)
    cfg = dict(num_clients=6, clients_per_round=2, tau_fixed=2,
               trainer="cohort", estimate=False)
    pinned = build_runner("fedavg", model, px, py, test,
                          cfg=FLConfig(**cfg, trainer_mesh_devices=1))
    assert pinned.trainer.mesh is None
    auto = build_runner("fedavg", model, px, py, test, cfg=FLConfig(**cfg))
    ndev = len(jax.local_devices())
    if ndev == 1:
        assert auto.trainer.mesh is None
    else:
        assert auto.trainer.mesh.devices.size == ndev


# ---------------------------------------------------------------------------
# trainer x aggregator parity matrix (sharded under the 4-device CI leg)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def image_setup():
    from repro.fl import build_image_setup

    return build_image_setup(num_clients=8, seed=0)


def _cfg(**kw):
    from repro.fl import FLConfig

    base = dict(num_clients=8, clients_per_round=3, eval_every=2,
                tau_fixed=2, tau_max=15, estimate=True)
    base.update(kw)
    return FLConfig(**base)


@pytest.mark.parametrize("scheme",
                         ["fedavg", "adp", "heterofl", "flanc", "heroes"])
def test_trainer_aggregator_parity_matrix(scheme, image_setup):
    """{sequential, cohort} x {host, collective} must agree on the
    virtual clock exactly and on accuracy to tolerance.  On one device
    every cell is the bitwise single-device path; under the 4-device CI
    leg the cohort cells run the mesh-sharded trainer (and the
    collective cell the device-resident hand-off)."""
    from repro.fl import run_scheme

    model, px, py, test = image_setup
    histories = {}
    for trainer in ("sequential", "cohort"):
        for agg in ("host", "collective"):
            histories[(trainer, agg)] = run_scheme(
                scheme, model, px, py, test, rounds=2,
                cfg=_cfg(trainer=trainer, agg_backend=agg))
    ref = histories[("sequential", "host")]
    for key, hist in histories.items():
        assert len(hist) == len(ref), key
        for a, b in zip(ref, hist):
            assert a.wall_time == b.wall_time, key
            assert a.traffic_bytes == b.traffic_bytes, key
            assert a.mean_tau == b.mean_tau, key
            if a.accuracy is not None:
                assert abs(a.accuracy - b.accuracy) <= 2e-3, key


# ---------------------------------------------------------------------------
# recompile-count regression (semi-async variable cohort sizes)
# ---------------------------------------------------------------------------


def test_semi_async_cohort_recompiles_bounded():
    """Semi-async dispatch sizes vary round to round; the power-of-two /
    mesh-multiple bucketing must keep the compiled cohort-step count at
    the handful of padded shapes, not one per cohort size.

    ``make_cnn`` memoizes model instances, so the jitted cohort step is
    shared process-wide — the regression is therefore on the cache
    *growth* across the variable-size rounds, not its absolute size.
    """
    from repro.fl import build_image_setup, build_runner
    from repro.fl.engine import trainers

    model, px, py, test = build_image_setup(num_clients=12, seed=1)
    cfg = _cfg(num_clients=12, clients_per_round=6, round_mode="semi_async",
               trainer="cohort", estimate=False, eval_every=100)
    eng = build_runner("fedavg", model, px, py, test, cfg=cfg)
    train_fn, _ = trainers._cohort_fns(eng.model, eng.P, eng.factorized,
                                       eng.trainer.mesh)
    if not hasattr(train_fn, "_cache_size"):
        pytest.skip("jit cache size introspection not available")
    before = train_fn._cache_size()
    for _ in range(10):
        eng.run_round()
    # dispatch sizes 1..6 bucket to at most {1, 2, 4, 6(full), 8} padded
    # client counts (mesh rounding can only merge buckets, not add)
    grown = train_fn._cache_size() - before
    assert grown <= 5, grown


# ---------------------------------------------------------------------------
# explicit 4-device SPMD cases (subprocess: XLA_FLAGS before jax init)
# ---------------------------------------------------------------------------

SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import numpy as np
    assert len(jax.devices()) == 4
    from repro.fl import FLConfig, build_image_setup, build_runner, run_scheme
    from repro.fl.engine.collective import CohortSlice

    model, px, py, test = build_image_setup(num_clients=8, seed=0)
    base = dict(num_clients=8, clients_per_round=3, eval_every=2,
                tau_fixed=2, tau_max=15, estimate=True)

    # the trainer mesh engages and hands the merger device-resident slices
    eng = build_runner("heroes", model, px, py, test,
                       cfg=FLConfig(**base, trainer="cohort"))
    assert eng.trainer.mesh is not None
    assert eng.trainer.mesh.devices.size == 4
    _, assigns = eng.assignment.assign(eng.state, [0, 1, 2])
    results = eng.trainer.train_all(eng.state, assigns)
    assert all(isinstance(r.params, CohortSlice) for r in results.values())
    leaves = jax.tree_util.tree_leaves(results[0].host_params())
    assert all(np.isfinite(np.asarray(l)).all() for l in leaves)

    # sharded cohort vs sequential, dense + factorized schemes
    for scheme in ("fedavg", "heroes"):
        h_seq = run_scheme(scheme, model, px, py, test, rounds=2,
                           cfg=FLConfig(**base))
        h_coh = run_scheme(scheme, model, px, py, test, rounds=2,
                           cfg=FLConfig(**base, trainer="cohort"))
        for a, b in zip(h_seq, h_coh):
            assert a.wall_time == b.wall_time
            assert a.traffic_bytes == b.traffic_bytes
            if a.accuracy is not None:
                assert abs(a.accuracy - b.accuracy) <= 2e-3, scheme

    # masked-clone parity: an odd cohort (3 of 8 on 4 devices) must give
    # the same per-client params as the 1-device-capped cohort path
    coh = build_runner("fedavg", model, px, py, test,
                       cfg=FLConfig(**base, trainer="cohort"))
    ref = build_runner("fedavg", model, px, py, test,
                       cfg=FLConfig(**base, trainer="cohort",
                                    trainer_mesh_devices=1))
    assert coh.trainer.mesh is not None and ref.trainer.mesh is None
    _, a4 = coh.assignment.assign(coh.state, [0, 1, 2])
    _, a1 = ref.assignment.assign(ref.state, [0, 1, 2])
    r4 = coh.trainer.train_all(coh.state, a4)
    r1 = ref.trainer.train_all(ref.state, a1)
    for n in r1:
        for x, y in zip(jax.tree_util.tree_leaves(r4[n].host_params()),
                        jax.tree_util.tree_leaves(r1[n].host_params())):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=1e-5, rtol=1e-5)

    # fastest-K semi-async: an all-fresh event merges a strict SUBSET of
    # the trained stack through the weights=None device path — regression
    # for CohortStack.n_real (a stack pass-through must never leak the
    # still-in-flight stragglers' rows into the merge)
    kw = dict(num_clients=10, clients_per_round=4, eval_every=100,
              tau_fixed=3, tau_max=15, estimate=False,
              round_mode="semi_async", async_k=2)
    model, px, py, test = build_image_setup(num_clients=10, seed=0)
    for scheme in ("fedavg", "heroes"):
        host = build_runner(scheme, model, px, py, test,
                            cfg=FLConfig(**kw, agg_backend="host",
                                         trainer="cohort"))
        coll = build_runner(scheme, model, px, py, test,
                            cfg=FLConfig(**kw, agg_backend="collective",
                                         trainer="cohort"))
        for _ in range(4):
            a, b = host.run_round(), coll.run_round()
            assert a.wall_time == b.wall_time
            # stragglers must not pin device-resident stacks across
            # events (they are degraded to the numpy contract)
            assert all(not hasattr(t.result.params, "materialize")
                       for t in coll.state.in_flight)
        for x, y in zip(jax.tree_util.tree_leaves(host.params),
                        jax.tree_util.tree_leaves(coll.params)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=1e-5, rtol=1e-5)
    print("SHARDED_TRAINER_OK")
""")


def _run_subprocess(script: str) -> subprocess.CompletedProcess:
    env = {**os.environ, "PYTHONPATH": str(ROOT / "src")}
    return subprocess.run([sys.executable, "-c", script], env=env, cwd=ROOT,
                          capture_output=True, text=True, timeout=900)


def test_sharded_cohort_trainer_spmd():
    r = _run_subprocess(SHARDED_SCRIPT)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SHARDED_TRAINER_OK" in r.stdout
