"""Unit tests for repro.checkpoint.msgpack_ckpt: dtype-preserving
round-trips (bf16 included), atomic step-directory writes, retention
pruning, and restore_latest step selection."""

import pathlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.msgpack_ckpt import (load_checkpoint, restore_latest,
                                           save_checkpoint)


def test_roundtrip_preserves_dtypes_and_values(tmp_path):
    rng = np.random.default_rng(0)
    state = {
        "f32": rng.normal(size=(3, 4)).astype(np.float32),
        "f64": rng.normal(size=(5,)),
        "i64": rng.integers(-7, 7, size=(2, 3)),
        "u8": rng.integers(0, 255, size=(4,)).astype(np.uint8),
        "nested": {"list": [np.float32(1.5), np.arange(3)],
                   "bool": np.array([True, False])},
        "bf16": jnp.asarray(rng.normal(size=(6,)), jnp.bfloat16),
    }
    p = save_checkpoint(tmp_path, 3, state)
    got = load_checkpoint(p)
    assert np.asarray(got["f32"]).dtype == np.float32
    np.testing.assert_array_equal(got["f32"], state["f32"])
    assert np.asarray(got["f64"]).dtype == np.float64
    np.testing.assert_array_equal(got["f64"], state["f64"])
    assert np.asarray(got["i64"]).dtype == np.int64
    np.testing.assert_array_equal(got["i64"], state["i64"])
    assert np.asarray(got["u8"]).dtype == np.uint8
    np.testing.assert_array_equal(got["u8"], state["u8"])
    np.testing.assert_array_equal(got["nested"]["bool"],
                                  state["nested"]["bool"])
    # lists flatten to string-indexed dict nodes
    np.testing.assert_array_equal(got["nested"]["list"]["1"],
                                  state["nested"]["list"][1])
    # bf16 has no numpy dtype string: compare via the uint16 bit view
    assert np.asarray(got["bf16"]).dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(got["bf16"]).view(np.uint16),
        np.asarray(state["bf16"]).view(np.uint16))


def test_atomic_write_no_partial_step_on_interrupt(tmp_path, monkeypatch):
    state = {"w": np.arange(8, dtype=np.float32)}
    save_checkpoint(tmp_path, 1, state)

    real_write = pathlib.Path.write_bytes

    def boom(self, data):
        raise OSError("disk pulled mid-write")

    monkeypatch.setattr(pathlib.Path, "write_bytes", boom)
    with pytest.raises(OSError, match="disk pulled"):
        save_checkpoint(tmp_path, 2, state)
    monkeypatch.setattr(pathlib.Path, "write_bytes", real_write)

    # the interrupted step left no directory — partial or otherwise
    assert not (tmp_path / "step_00000002").exists()
    assert not list(tmp_path.glob("step_*.tmp.*"))
    # and the previous checkpoint is still the restorable latest
    step, got = restore_latest(tmp_path)
    assert step == 1
    np.testing.assert_array_equal(got["w"], state["w"])
    # a later save on the same directory succeeds normally
    save_checkpoint(tmp_path, 2, {"w": state["w"] + 1})
    step, got = restore_latest(tmp_path)
    assert step == 2
    np.testing.assert_array_equal(got["w"], state["w"] + 1)


def test_restore_latest_picks_highest_step(tmp_path):
    for step in (2, 10, 9):
        save_checkpoint(tmp_path, step, {"s": np.array([step])}, keep=100)
    step, got = restore_latest(tmp_path)
    assert step == 10
    np.testing.assert_array_equal(got["s"], [10])
    # stray non-step entries are never candidates
    (tmp_path / "step_garbage").mkdir()
    (tmp_path / "notes.txt").write_text("x")
    assert restore_latest(tmp_path)[0] == 10


def test_restore_latest_empty_and_missing(tmp_path):
    assert restore_latest(tmp_path) is None
    assert restore_latest(tmp_path / "nope") is None


def test_keep_prunes_oldest(tmp_path):
    for step in range(1, 6):
        save_checkpoint(tmp_path, step, {"s": np.array([step])}, keep=2)
    names = sorted(p.name for p in tmp_path.glob("step_*"))
    assert names == ["step_00000004", "step_00000005"]
