"""Rank-space client compute: factorized application correctness.

Covers the tentpole contracts of the rank-space execution path:

* ``apply_factors`` reproduces compose-then-apply for every spec mode,
  dense and conv, at every width (forward values);
* gradient parity: local SGD under ``forward_impl="rank_space"`` /
  ``"auto"`` tracks the materialize path within float-reassociation
  tolerance for all three models at every width, same seeds;
* ``forward_impl="materialize"`` reproduces the recorded seed histories
  BITWISE (fixtures/golden_materialize_histories.json, captured from
  the pre-rank-space code);
* the out-of-range block-id gather now raises instead of silently
  clamping (regression for the anchored-layer id bug).
"""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.composition import (CompositionPlan, CompositionSpec,
                                    apply_factors, apply_flops, compose,
                                    compose_flops, dense_apply_flops,
                                    gather_blocks, init_factors,
                                    rank_space_wins)
from repro.fl import FLConfig, build_image_setup, run_scheme
from repro.fl.client import _jitted_fns
from repro.fl.models import make_cnn, make_resnet, make_rnn

FIXTURES = Path(__file__).parent / "fixtures"


# ---------------------------------------------------------------------------
# apply_factors vs compose-then-apply
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["square", "grow_out", "grow_in"])
@pytest.mark.parametrize("p", [1, 2, 3])
def test_apply_factors_dense_matches_compose(mode, p):
    spec = CompositionSpec(3, 8, 6, 5, ksq=1, mode=mode)
    v, u = init_factors(jax.random.PRNGKey(0), spec)
    red = gather_blocks(u, np.arange(spec.blocks_for_width(p)))
    w = compose(v, red, p, spec)
    x = jax.random.normal(jax.random.PRNGKey(p), (4, 7, w.shape[1]))
    got = apply_factors(x, v, red, p, spec, "dense")
    np.testing.assert_allclose(np.asarray(x @ w[0]), np.asarray(got),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("mode", ["square", "grow_out", "grow_in"])
@pytest.mark.parametrize("p", [1, 2, 3])
@pytest.mark.parametrize("stride", [1, 2])
def test_apply_factors_conv_matches_compose(mode, p, stride):
    spec = CompositionSpec(3, 8, 6, 5, ksq=9, mode=mode)
    v, u = init_factors(jax.random.PRNGKey(1), spec)
    red = gather_blocks(u, np.arange(spec.blocks_for_width(p)))
    w = compose(v, red, p, spec)
    x = jax.random.normal(jax.random.PRNGKey(p + 10), (2, 8, 8, w.shape[1]))
    wk = w.reshape(3, 3, w.shape[1], w.shape[2])
    want = jax.lax.conv_general_dilated(
        x, wk, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    got = apply_factors(x, v, red, p, spec, "conv", stride=stride)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                               atol=1e-4, rtol=1e-4)


def test_flops_model_orders_paths_sensibly():
    """The static FLOPs model: rank space wins where pI >> R and the
    compose is amortised, loses at width 1 / for gather-style layers."""
    spec = CompositionSpec(3, 8, 8, 8, ksq=9)  # the CNN hidden conv
    apps = 16 * 16  # batch 16, 4x4 output positions
    assert rank_space_wins(3, spec, applications=apps)
    assert rank_space_wins(2, spec, applications=apps)
    assert not rank_space_wins(1, spec, applications=apps)
    # embedding: materialised application is a free gather, and the
    # rank path's basis projection is a gather too (_apply_embed), so
    # the contest is the per-token R->pO contraction vs the one-off
    # vocab-sized compose: rank wins exactly below vocab tokens
    emb = CompositionSpec(3, 8, 64, 16, ksq=1, mode="grow_out")
    assert not rank_space_wins(3, emb, applications=apps,
                               dense_apply_free=True)
    assert rank_space_wins(3, emb, applications=16, dense_apply_free=True,
                           basis_is_gather=True)
    assert not rank_space_wins(3, emb, applications=apps,
                               dense_apply_free=True, basis_is_gather=True)
    assert apply_flops(3, emb, applications=1, basis_is_gather=True) == \
        2 * 3 * emb.rank * emb.base_out  # coefficient contraction only
    # the numbers the benchmark records stay positive and consistent
    for p in (1, 2, 3):
        assert apply_flops(p, spec, applications=2) == \
            2 * apply_flops(p, spec)
        assert dense_apply_flops(p, spec) > 0 and compose_flops(p, spec) > 0


# ---------------------------------------------------------------------------
# gradient parity: materialize vs rank_space local updates
# ---------------------------------------------------------------------------


def _reduced(model, width, key=jax.random.PRNGKey(0)):
    params = model.init_factorized(key)
    sq = next(s for s in model.specs.values() if s.mode == "square")
    return model.reduce(params, width,
                        np.arange(sq.blocks_for_width(width)),
                        np.arange(width))


def _batch(model, key, n=8):
    if model.name == "rnn":
        return {"tokens": jax.random.randint(key, (n, 32), 0, 64),
                "labels": jax.random.randint(key, (n, 32), 0, 64)}
    return {"x": jax.random.normal(key, (n, 8, 8, 3)),
            "labels": jax.random.randint(key, (n,), 0, 10)}


@pytest.mark.parametrize("make", [make_cnn, make_resnet, make_rnn])
@pytest.mark.parametrize("width", [1, 2, 3])
@pytest.mark.parametrize("impl", ["rank_space", "auto"])
def test_gradient_parity_rank_space_vs_materialize(make, width, impl):
    model = make()
    red = _reduced(model, width)
    batch = _batch(model, jax.random.PRNGKey(3))
    _, grad_mat, step_mat = _jitted_fns(model, width, True, "materialize")
    _, grad_rank, step_rank = _jitted_fns(model, width, True, impl)
    g_mat = grad_mat(red, batch)
    g_rank = grad_rank(red, batch)
    for a, b in zip(jax.tree_util.tree_leaves(g_mat),
                    jax.tree_util.tree_leaves(g_rank)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-3)
    # a few SGD steps stay on the same trajectory
    pa, pb = red, red
    for i in range(3):
        b = _batch(model, jax.random.PRNGKey(10 + i))
        pa = step_mat(pa, b, 0.05)
        pb = step_rank(pb, b, 0.05)
    for a, b in zip(jax.tree_util.tree_leaves(pa),
                    jax.tree_util.tree_leaves(pb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-3)


# ---------------------------------------------------------------------------
# bitwise: materialize reproduces the recorded seed histories
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheme", ["heroes", "flanc"])
def test_materialize_reproduces_seed_histories_bitwise(scheme):
    golden = json.loads(
        (FIXTURES / "golden_materialize_histories.json").read_text())[scheme]
    model, px, py, test = build_image_setup(num_clients=10, seed=0)
    cfg = FLConfig(num_clients=10, clients_per_round=4, eval_every=2,
                   tau_fixed=4, tau_max=15, estimate=True,
                   forward_impl="materialize")
    hist = run_scheme(scheme, model, px, py, test, rounds=4, cfg=cfg)
    assert len(hist) == len(golden)
    for h, g in zip(hist, golden):
        assert h.round == g["round"]
        assert h.wall_time == g["wall_time"]
        assert h.traffic_bytes == g["traffic_bytes"]
        assert h.makespan == g["makespan"]
        assert h.avg_wait == g["avg_wait"]
        assert h.mean_tau == g["mean_tau"]
        assert (h.accuracy is None) == (g["accuracy"] is None)
        if h.accuracy is not None:
            assert h.accuracy == g["accuracy"]


# ---------------------------------------------------------------------------
# fused path parity + measured-calibration dispatch
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["square", "grow_out", "grow_in"])
@pytest.mark.parametrize("p", [1, 2, 3])
@pytest.mark.parametrize("stride", [1, 2])
def test_apply_factors_conv_fused_matches_unfused(mode, p, stride):
    """The fused conv rank primitive (production default) vs the kept
    separate-ops reference path inside apply_factors itself."""
    spec = CompositionSpec(3, 8, 6, 5, ksq=9, mode=mode)
    v, u = init_factors(jax.random.PRNGKey(2), spec)
    red = gather_blocks(u, np.arange(spec.blocks_for_width(p)))
    g = 1 if mode == "grow_out" else p
    x = jax.random.normal(jax.random.PRNGKey(p + 20), (2, 8, 8, g * 6))
    fused = apply_factors(x, v, red, p, spec, "conv", stride=stride)
    unfused = apply_factors(x, v, red, p, spec, "conv", stride=stride,
                            fused=False)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               atol=1e-4, rtol=1e-4)


def _cal(ovh, gain):
    from repro.core.calibration import RankPathCalibration
    return RankPathCalibration(conv_rank_overhead=ovh,
                               fused_compose_gain=gain)


def test_layer_impls_calibration_drives_choices():
    """Pinned calibrations make the auto choice deterministic: a cheap
    measured conv rank path enables rank_space on the hidden convs, an
    expensive one disables it; fused_compose_gain < 1 swaps the dense
    head (a materialize-regime layer) to the fused compose+apply."""
    cnn = make_cnn()
    cheap = cnn.layer_impls(3, 16, "auto", calibration=_cal(0.5, 2.0))
    assert cheap["conv2"] == "rank_space"
    assert cheap["conv3"] == "rank_space"
    assert cheap["fc"] == "materialize"  # gain >= 1: no fusion
    dear = cnn.layer_impls(3, 16, "auto", calibration=_cal(30.0, 0.5))
    assert dear["conv1"] == "materialize"
    assert dear["conv2"] == "materialize"
    assert dear["conv3"] == "materialize"
    assert dear["fc"] == "fused_compose"  # ksq == 1, gain < 1
    # the embedding's free-gather apply never fuses, whatever the gain
    rnn = make_rnn()
    auto = rnn.layer_impls(3, 16, "auto", calibration=_cal(1.0, 0.5))
    assert auto["embed"] == "materialize"
    assert auto["wh"] == "materialize"  # rank_capable=False pin holds


def test_calibration_config_pins_and_dispatch_gate():
    """FLConfig overrides pin the calibration without measuring, and
    non-auto configs never trigger the micro-benchmarks at all."""
    from repro.core.calibration import for_dispatch, from_config

    pinned = FLConfig(forward_impl="auto", conv_rank_overhead=1.5,
                      fused_compose_gain=0.8)
    cal = for_dispatch(pinned)
    assert cal is not None and not cal.measured
    assert cal.conv_rank_overhead == 1.5
    assert cal.fused_compose_gain == 0.8
    assert from_config(pinned) == cal
    # materialize / rank_space dispatch short-circuits to None (no
    # measurement, no calibration in the jit-cache key)
    assert for_dispatch(FLConfig(forward_impl="materialize")) is None
    assert for_dispatch(FLConfig(forward_impl="rank_space")) is None


def test_fused_compose_impl_gradient_parity():
    """End-to-end: an auto client whose pinned calibration routes the
    dense head through compose_dense_apply ("fused_compose") computes
    the same gradients as the materialize client."""
    model = make_cnn()
    cal = _cal(30.0, 0.5)
    # width 3 / batch 16: the head sits in the materialize regime (at
    # width 2 / batch 8 its rank path wins FLOPs outright)
    impls = model.layer_impls(3, 16, "auto", calibration=cal)
    assert impls["fc"] == "fused_compose"
    red = _reduced(model, 3)
    batch = _batch(model, jax.random.PRNGKey(5), n=16)
    _, grad_mat, _ = _jitted_fns(model, 3, True, "materialize")
    _, grad_fus, _ = _jitted_fns(model, 3, True, "auto", cal)
    for a, b in zip(jax.tree_util.tree_leaves(grad_mat(red, batch)),
                    jax.tree_util.tree_leaves(grad_fus(red, batch))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-3)


def test_unknown_forward_impl_rejected():
    model = make_cnn()
    with pytest.raises(ValueError, match="forward_impl"):
        model.layer_impls(2, 16, "fused")


def test_layer_impls_pin_scan_recurrence_and_embedding():
    """The scan-carried wh never goes rank-space (composed once, reused
    T times); the embedding's materialised apply is a free gather so
    auto keeps it composed; the input projection wins in rank space."""
    rnn = make_rnn()
    forced = rnn.layer_impls(3, 16, "rank_space")
    assert forced["wh"] == "materialize"
    auto = rnn.layer_impls(3, 16, "auto")
    assert auto["wh"] == "materialize"
    assert auto["embed"] == "materialize"
    assert auto["wx"] == "rank_space"
    cnn = make_cnn()
    assert all(v == "materialize"
               for v in cnn.layer_impls(3, 16, "materialize").values())


# ---------------------------------------------------------------------------
# out-of-range block-id gathers raise (regression: silent jnp.take clamp)
# ---------------------------------------------------------------------------


def test_gather_blocks_rejects_out_of_range_ids():
    spec = CompositionSpec(3, 4, 4, 4, ksq=1, mode="grow_out")  # 3 blocks
    _, u = init_factors(jax.random.PRNGKey(0), spec)
    with pytest.raises(ValueError, match="out of range"):
        gather_blocks(u, np.array([0, 5]))  # 5 >= 3 used to clamp to 2
    with pytest.raises(ValueError, match="out of range"):
        gather_blocks(u, np.array([-1]))
    got = gather_blocks(u, np.array([2, 0]))  # in-range still works
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(u[2]))


def test_composition_plan_reduce_validates_per_layer():
    """Anchored-mode layers hold P blocks; handing them the shared
    P^2-counter ids must raise, not silently gather clamped blocks."""
    plan = CompositionPlan(
        {"hidden": CompositionSpec(3, 4, 4, 4, mode="square"),
         "head": CompositionSpec(3, 4, 4, 4, mode="grow_in")},
        max_width=3)
    params = plan.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="head"):
        plan.reduce(params, np.array([0, 4, 8]))  # valid for P^2=9, not P=3
    out = plan.reduce(params, np.array([0, 1, 2]))  # valid everywhere
    assert out["head"]["coeff"].shape[0] == 3
