"""Config sanity: the full assigned configs match their published sizes."""

import pytest

from repro import configs

# (arch, expected total params, tolerance) — published model-card numbers.
# param_count() is an analytic estimate (attn + ffn + embeddings), so the
# tolerance absorbs biases/norm params and minor structural differences.
EXPECTED = {
    "deepseek-coder-33b": (33.3e9, 0.10),
    "gemma-2b": (2.5e9, 0.15),
    "stablelm-3b": (2.8e9, 0.25),
    "granite-34b": (34e9, 0.10),
    "qwen2-vl-7b": (7.6e9, 0.15),
    "olmoe-1b-7b": (6.9e9, 0.15),
    "xlstm-125m": (125e6, 0.6),  # rough block structure
    "zamba2-2.7b": (2.7e9, 0.35),
    "seamless-m4t-medium": (1.2e9, 0.4),  # medium ~1.2B incl. codec we stub
    "kimi-k2-1t-a32b": (1.03e12, 0.15),
}


@pytest.mark.parametrize("arch_id", configs.list_archs())
def test_param_count_matches_model_card(arch_id):
    cfg = configs.get_config(arch_id)
    n = cfg.param_count()
    want, tol = EXPECTED[arch_id]
    assert abs(n - want) / want < tol, f"{arch_id}: {n/1e9:.2f}B vs {want/1e9:.2f}B"


def test_kimi_active_params():
    cfg = configs.get_config("kimi-k2-1t-a32b")
    active = cfg.param_count(active_only=True)
    # ~32B active per the model card (A32B)
    assert 20e9 < active < 45e9, f"active {active/1e9:.1f}B"


@pytest.mark.parametrize("arch_id", configs.list_archs())
def test_exact_assignment_numbers(arch_id):
    """The headline numbers from the assignment table are exact."""
    cfg = configs.get_config(arch_id)
    table = {
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
    }
    L, d, h, kv, ff, v = table[arch_id]
    assert cfg.num_layers == L and cfg.d_model == d
    assert cfg.num_heads == h and cfg.num_kv_heads == kv
    assert cfg.d_ff == ff and cfg.vocab == v
    if arch_id == "olmoe-1b-7b":
        assert cfg.moe.num_experts == 64 and cfg.moe.top_k == 8
    if arch_id == "kimi-k2-1t-a32b":
        assert cfg.moe.num_experts == 384 and cfg.moe.top_k == 8
    if arch_id == "zamba2-2.7b":
        assert cfg.ssm.state_dim == 64
