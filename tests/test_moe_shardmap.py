"""Equivalence test for the shard_map expert-parallel MoE (subprocess:
needs an 8-device host mesh before jax initialises)."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import ModelConfig, MoEConfig
    from repro.models import moe
    from repro.models.moe_shardmap import apply_moe_shardmap

    # capacity_factor large enough that nothing drops -> exact equivalence
    cfg = ModelConfig(arch_id="t", family="moe", num_layers=1, d_model=32,
                      num_heads=4, num_kv_heads=4, d_ff=16, vocab=64,
                      moe=MoEConfig(num_experts=8, top_k=2, d_expert=16,
                                    capacity_factor=8.0))
    p = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))

    # dense per-token reference (no capacity, exact)
    x2 = x.reshape(-1, 32)
    probs, gates, ids = moe.router_topk(p["router"], x2, cfg)
    all_out = jnp.stack([
        moe.expert_ffn(p, cfg, x2[None])[0] if False else None
        for _ in range(0)
    ]) if False else None
    # compute each expert on all tokens, gather per top-k
    g = jnp.einsum("td,edf->tef", x2, p["gate"])
    u = jnp.einsum("td,edf->tef", x2, p["up"])
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("tef,efd->ted", h, p["down"])  # (T, E, d)
    ref = jnp.zeros_like(x2)
    for k in range(cfg.moe.top_k):
        ref = ref + gates[:, k][:, None] * jnp.take_along_axis(
            ye, ids[:, k][:, None, None].repeat(32, -1), 1)[:, 0]
    ref = ref.reshape(x.shape)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    y = apply_moe_shardmap(p, cfg, x, mesh)
    err = float(jnp.abs(y - ref).max())
    assert err < 2e-5, f"shard_map EP mismatch: {err}"

    # also agree with the pjit GShard formulation at no-drop capacity
    y2, _ = moe.apply_moe(p, cfg, x)
    err2 = float(jnp.abs(y - y2).max())
    assert err2 < 2e-5, f"vs pjit formulation: {err2}"
    print("MOE_SHARDMAP_OK", err, err2)
""")


def test_moe_shardmap_equivalence():
    env = {**os.environ, "PYTHONPATH": str(ROOT / "src")}
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env, cwd=ROOT,
                       capture_output=True, text=True, timeout=420)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MOE_SHARDMAP_OK" in r.stdout
