"""Estimator correctness on functions with known constants (Alg. 2)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimator


def test_smoothness_estimate_exact_on_quadratic():
    """F(x) = 0.5 * a * ||x||^2 has L = a exactly."""
    a = 3.7
    grad = lambda x: a * x
    x0 = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(5,)).astype(np.float32))}
    x1 = {"w": x0["w"] - 0.1 * grad(x0["w"])}
    L = estimator.estimate_smoothness(
        {"w": grad(x1["w"])}, {"w": grad(x0["w"])}, x1, x0)
    assert abs(float(L) - a) < 1e-4


def test_noise_estimate_zero_for_deterministic():
    g = {"w": jnp.ones((4,))}
    sig = estimator.estimate_noise_sq([g, g, g], g)
    assert float(sig) == 0.0


def test_grad_sq_matches_norm():
    g1 = {"w": jnp.asarray([3.0, 4.0])}  # ||g||^2 = 25
    g2 = {"w": jnp.asarray([0.0, 0.0])}
    gsq = estimator.estimate_grad_sq([g1, g2])
    assert abs(float(gsq) - 12.5) < 1e-6


def test_client_estimates_on_noisy_quadratic():
    """Minibatch gradients g = a*x + eps: sigma^2 ~ E||eps||^2, L ~ a."""
    a, noise = 2.0, 0.3
    rng = np.random.default_rng(0)

    def grad_fn(params, batch):
        return {"w": a * params["w"] + batch}

    x0 = {"w": jnp.asarray(rng.normal(size=(50,)).astype(np.float32))}
    batches = [jnp.asarray(noise * rng.normal(size=(50,)).astype(np.float32))
               for _ in range(8)]
    x1 = {"w": x0["w"] * 0.9}
    est = estimator.client_estimates(grad_fn, x0, x1, batches)
    # sigma^2 concentrates near 50 * noise^2 = 4.5
    assert 1.5 < float(est["sigma_sq"]) < 9.0
    assert float(est["grad_sq"]) > 0


def test_aggregate_estimates_means():
    per = [{"L": 1.0, "sigma_sq": 2.0}, {"L": 3.0, "sigma_sq": 4.0}]
    agg = estimator.aggregate_estimates(per)
    assert agg == {"L": 2.0, "sigma_sq": 3.0}
