"""Prefill/decode consistency: teacher-forced token-by-token decode must
produce the same logits as the full-sequence forward pass — catches KV
cache indexing, RoPE position, and recurrent-state bugs in one shot."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model

ARCHS = ["deepseek-coder-33b", "gemma-2b", "olmoe-1b-7b", "zamba2-2.7b",
         "xlstm-125m", "granite-34b"]


@pytest.mark.parametrize("arch_id", ARCHS)
def test_decode_matches_forward(arch_id):
    # fp32 compute: in bf16 the two attention paths round differently and
    # the drift (~0.04 in logits) masks real bugs; fp32 is exact to 1e-5.
    cfg = configs.get_smoke(arch_id).replace(compute_dtype="float32")
    if cfg.moe is not None:
        # capacity drops differ between full-sequence dispatch (tokens
        # compete across S) and one-token decode (they don't) — inherent
        # to capacity-based MoE; test the no-drop regime for exactness.
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = model.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)

    full_logits, _ = model.forward(params, cfg, {"tokens": toks})

    cache = model.init_cache(cfg, B, S + 2)
    step_logits = []
    for t in range(S):
        lg, cache = model.serve_step(
            params, cfg, {"tokens": toks[:, t:t + 1]}, cache, jnp.int32(t))
        step_logits.append(lg)
    dec = jnp.concatenate(step_logits, axis=1)

    np.testing.assert_allclose(
        np.asarray(full_logits, np.float32), np.asarray(dec, np.float32),
        atol=1e-4, rtol=1e-4,
    )


def test_decode_matches_forward_encdec():
    cfg = configs.get_smoke("seamless-m4t-medium").replace(
        compute_dtype="float32")
    params = model.init(jax.random.PRNGKey(0), cfg)
    B, S, Se = 2, 8, cfg.encdec.encoder_seq
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    enc = 0.02 * jax.random.normal(jax.random.PRNGKey(2), (B, Se, cfg.d_model))
    mask = jnp.ones((B, Se), bool)
    batch = {"tokens": toks, "enc_embeddings": enc, "enc_mask": mask}

    full_logits, _ = model.forward(params, cfg, batch)

    cache = model.init_cache(cfg, B, S + 2)
    # populate encoder memory once (prefill path)
    _, cache = model.prefill(params, cfg, batch, cache)
    step_logits = []
    for t in range(S):
        lg, cache = model.serve_step(
            params, cfg, {"tokens": toks[:, t:t + 1]}, cache, jnp.int32(t))
        step_logits.append(lg)
    dec = jnp.concatenate(step_logits, axis=1)
    np.testing.assert_allclose(
        np.asarray(full_logits, np.float32), np.asarray(dec, np.float32),
        atol=1e-4, rtol=1e-4,
    )
