"""Checkpoint/resume semantics: kill a run at round k, restore from the
newest round-boundary checkpoint, continue — the full history must be
bitwise-identical to a never-interrupted run, for every scheme in both
round modes.  The rng stream, Heroes scheduler tallies, participation
bookkeeping and (semi-async) in-flight dispatch records all travel in
the checkpointed ServerState, so nothing drifts across the resume."""

import dataclasses
import json
from pathlib import Path

import pytest

from repro.fl import FLConfig, build_image_setup, build_runner

SCHEMES = ("fedavg", "adp", "heterofl", "flanc", "heroes")
ROUNDS = 5
KILL_AT = 3      # the interrupted run dies here...
CKPT_EVERY = 2   # ...so the newest checkpoint is at round 2

GOLDEN = json.loads(
    (Path(__file__).parent / "fixtures"
     / "golden_legacy_histories.json").read_text())


@pytest.fixture(scope="module")
def image_setup():
    return build_image_setup(num_clients=10, seed=0)


def _cfg(mode, ckpt_dir):
    # forward_impl pinned: the golden fixtures were captured under the
    # legacy (== materialize) path, and "auto" now consults a measured
    # per-host calibration whose impl choices may differ across hosts.
    kw = dict(num_clients=10, clients_per_round=4, eval_every=2,
              tau_fixed=4, tau_max=15, estimate=True, round_mode=mode,
              checkpoint_every=CKPT_EVERY, checkpoint_dir=str(ckpt_dir),
              checkpoint_keep=2, forward_impl="materialize")
    if mode == "semi_async":
        kw.update(async_k=2, eval_every=4)
    return FLConfig(**kw)


def _history(runner):
    return [dataclasses.asdict(h) for h in runner.history]


@pytest.mark.parametrize("mode", ["sync", "semi_async"])
@pytest.mark.parametrize("scheme", SCHEMES)
def test_resume_history_bitwise_identical(scheme, mode, image_setup,
                                          tmp_path):
    model, px, py, test = image_setup

    # uninterrupted reference: the golden fixture pins the sync histories
    # (captured from the retired legacy runners); semi-async runs fresh
    if mode == "sync":
        reference = GOLDEN[scheme][:ROUNDS]
    else:
        ref = build_runner(scheme, model, px, py, test,
                           cfg=_cfg(mode, tmp_path / "ref"), seed=0)
        ref.run(ROUNDS)
        reference = _history(ref)
        ref.close()

    # interrupted run: dies at KILL_AT; the newest checkpoint is the
    # round-CKPT_EVERY boundary
    ckpt = tmp_path / "run"
    interrupted = build_runner(scheme, model, px, py, test,
                               cfg=_cfg(mode, ckpt), seed=0)
    interrupted.run(KILL_AT)
    partial = _history(interrupted)
    interrupted.close()
    del interrupted  # the process is gone; only the checkpoint survives

    resumed = build_runner(scheme, model, px, py, test,
                           cfg=_cfg(mode, ckpt), seed=0)
    assert resumed.restore_latest(), "no checkpoint to resume from"
    assert resumed.round == KILL_AT - KILL_AT % CKPT_EVERY == 2
    # the restored prefix is exactly what the interrupted run logged
    assert _history(resumed) == partial[:resumed.round]
    resumed.run(ROUNDS - resumed.round)
    continued = _history(resumed)
    resumed.close()

    # the golden fixture predates RoundLog's up/down traffic split, so
    # compare on its own fields; the restored-prefix assert above pins
    # the new fields' checkpoint round-trip bitwise (live vs live)
    keys = set(reference[0])
    assert [{k: v for k, v in h.items() if k in keys}
            for h in continued] == reference


def test_restore_latest_false_on_empty_dir(image_setup, tmp_path):
    model, px, py, test = image_setup
    runner = build_runner("fedavg", model, px, py, test,
                          cfg=_cfg("sync", tmp_path / "empty"), seed=0)
    assert runner.restore_latest() is False
    runner.close()


def test_checkpoint_dir_unset_raises(image_setup):
    model, px, py, test = image_setup
    cfg = FLConfig(num_clients=10, clients_per_round=4)
    runner = build_runner("fedavg", model, px, py, test, cfg=cfg, seed=0)
    with pytest.raises(ValueError, match="checkpoint_dir"):
        runner.save_checkpoint()
    with pytest.raises(ValueError, match="checkpoint_dir"):
        runner.restore_latest()
    runner.close()


def test_participation_bookkeeping_survives_resume(tmp_path):
    """Virtual-population runs: the registry shares the ServerState's
    participation dict by identity, so last_participation survives."""
    from repro.fl import build_setup

    m, px, py, tb = build_setup("synthetic_image", seed=0, population=500,
                                partition_kw={"samples_per_client": 16})
    cfg = FLConfig(num_clients=500, clients_per_round=4, tau_fixed=2,
                   eval_every=10, checkpoint_every=1,
                   checkpoint_dir=str(tmp_path / "pop"))
    r1 = build_runner("fedavg", m, px, py, tb, cfg=cfg, seed=0)
    r1.run(2)
    seen = dict(r1.state.participation)
    assert seen and r1.population.participants() == len(seen)
    r1.close()

    r2 = build_runner("fedavg", m, px, py, tb, cfg=cfg, seed=0)
    assert r2.restore_latest()
    assert r2.state.participation == seen
    # the registry reads the restored store by identity
    assert r2.population._last_round is r2.state.participation
    for n, rnd in seen.items():
        assert r2.population.last_participation(n) == rnd
    r2.close()
