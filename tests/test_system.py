"""End-to-end behaviour tests for the paper's system.

Covers: enhanced-NC semantics, factorized forward == compose-then-matmul,
the masked-psum collective aggregation form, scheduler/waiting behaviour,
and the HLO analyzer used by the roofline report.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BoundState, CompositionSpec, compose, gather_blocks,
                        init_factors, scatter_contribution, select_blocks)
from repro.models.module import comp_spec_for, linear


def test_factorized_forward_equals_compose_then_matmul():
    """The framework's factorized forward (x@v@u, DESIGN.md §3) is
    algebraically identical to the paper's compose-then-multiply."""
    key = jax.random.PRNGKey(0)
    P, R, p = 3, 8, 2
    spec = comp_spec_for(24, 36, P, R)
    v, u = init_factors(key, spec)
    ids = select_blocks(np.arange(9), p, spec)
    red = gather_blocks(u, ids)
    w = compose(v, red, p, spec)[0]  # (pI, pO)
    x = jax.random.normal(jax.random.PRNGKey(1), (5, p * spec.base_in))
    direct = x @ w
    fact = linear({"basis": v[0], "coeff": red}, x, width=p)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(fact),
                               atol=1e-5, rtol=1e-5)


def test_masked_psum_aggregation_equals_host_aggregation():
    """The mesh-native masked-sum form of Eq. (5) gives the same result
    as the host-driven list aggregation."""
    from repro.core import aggregate_coefficient

    rng = np.random.default_rng(0)
    nblocks, R, O = 4, 3, 5
    prev = jnp.asarray(rng.normal(size=(nblocks, R, O)).astype(np.float32))
    ids = [np.array([0, 2]), np.array([2, 3])]
    blocks = [jnp.asarray(rng.normal(size=(2, R, O)).astype(np.float32))
              for _ in ids]
    host = aggregate_coefficient(prev, blocks, ids)

    dense, masks = zip(*[
        scatter_contribution(b, jnp.asarray(i), nblocks)
        for b, i in zip(blocks, ids)
    ])
    total = sum(dense)
    count = sum(masks)
    trained = count > 0
    denom = jnp.where(trained, count, 1.0)[:, None, None]
    coll = jnp.where(trained[:, None, None], total / denom, prev)
    np.testing.assert_allclose(np.asarray(host), np.asarray(coll), atol=1e-6)


def test_enhanced_nc_trains_every_block():
    """Heroes' block rotation: every coefficient block receives updates
    even when only weak (p=1) clients participate — the property original
    NC lacks (paper Sec. I)."""
    spec = CompositionSpec(max_width=3, rank=4, base_in=8, base_out=8)
    counters = np.zeros(spec.num_blocks, np.int64)
    for _ in range(18):  # 18 rounds of a single width-1 client
        ids = select_blocks(counters, 1, spec)
        counters[ids] += 5
    assert counters.min() > 0, "enhanced NC must rotate through all blocks"
    assert counters.max() - counters.min() <= 5


def test_hlo_analyzer_scales_nested_scans():
    from repro.launch.hlo_analysis import analyze

    def f(xs, w):
        def outer(c, x):
            def inner(ci, _):
                return ci @ w, None
            c2, _ = jax.lax.scan(inner, c + x, jnp.arange(3))
            return c2, None
        out, _ = jax.lax.scan(outer, xs[0], xs)
        return out

    xs = jax.ShapeDtypeStruct((7, 32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    comp = jax.jit(f).lower(xs, w).compile()
    res = analyze(comp.as_text())
    expect = 2 * 32 * 32 * 32 * 7 * 3
    assert abs(res["dot_flops"] - expect) / expect < 0.05


def test_analyzer_vs_cost_analysis_on_small_model():
    """Loop-scaled FLOPs must be >= XLA's while-undercounting estimate and
    within a small factor of it on a 2-layer model."""
    from repro import configs
    from repro.launch.hlo_analysis import analyze, flat_cost_analysis
    from repro.models import model as model_lib

    cfg = configs.get_smoke("stablelm-3b")

    def fwd(params, batch):
        logits, _ = model_lib.forward(params, cfg, batch)
        return logits

    pshape = jax.eval_shape(lambda: model_lib.init(jax.random.PRNGKey(0), cfg))
    batch = {"tokens": jax.ShapeDtypeStruct((2, 32), jnp.int32)}
    comp = jax.jit(fwd).lower(pshape, batch).compile()
    res = analyze(comp.as_text())
    xla = flat_cost_analysis(comp.cost_analysis())["flops"]
    assert res["dot_flops"] >= 0.5 * xla
    assert res["dot_flops"] <= 4.0 * xla


def test_scheduler_waiting_smaller_than_fixed_tau():
    """Adaptive frequencies reduce average waiting vs fixed tau=10
    (Fig. 2 / Fig. 5 behaviour) under a heterogeneous client pool."""
    from repro.core.scheduler import HeroesScheduler, SchedulerConfig

    rng = np.random.default_rng(1)
    spec = CompositionSpec(max_width=3, rank=4, base_in=8, base_out=8)
    mus = {n: float(rng.uniform(0.02, 0.3)) for n in range(8)}
    nus = {n: float(rng.uniform(0.1, 0.6)) for n in range(8)}
    sched = HeroesScheduler(
        spec, SchedulerConfig(mu_max=2.0, rho=0.5, eps=1.0, tau_max=100),
        iter_time_fn=lambda n, p: mus[n],
        comm_time_fn=lambda n, p: nus[n],
    )
    state = BoundState(loss0=2.0, smoothness=0.5, grad_sq=1.0, noise_sq=0.3,
                       lr=0.05)
    plan = sched.plan_round(list(range(8)), state)
    adaptive_wait = plan.avg_waiting()
    fixed = {n: 10 * mus[n] + nus[n] for n in range(8)}
    fixed_mk = max(fixed.values())
    fixed_wait = float(np.mean([fixed_mk - t for t in fixed.values()]))
    assert adaptive_wait <= fixed_wait + 1e-9


def test_anchored_composition_modes():
    """grow_out / grow_in anchored layers compose to the right shapes and
    stay consistent with their parameter counts."""
    for mode, shape in (("grow_out", (9, 3, 2 * 8)), ("grow_in", (1, 2 * 8, 10))):
        spec = CompositionSpec(
            max_width=3, rank=4,
            base_in=3 if mode == "grow_out" else 8,
            base_out=8 if mode == "grow_out" else 10,
            ksq=9 if mode == "grow_out" else 1, mode=mode)
        v, u = init_factors(jax.random.PRNGKey(0), spec)
        assert u.shape[0] == 3  # P blocks, not P^2
        ids = select_blocks(np.zeros(3), 2, spec)
        w = compose(v, gather_blocks(u, ids), 2, spec)
        assert w.shape == shape == spec.weight_shape(2)
