"""Unit tests for the sharding rules and divisibility fallbacks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch import specs as specs_lib
from repro.sharding import rules


class FakeMesh:
    """Minimal stand-in exposing .shape / .axis_names like jax.Mesh."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
PODMESH = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _specs_for(arch):
    cfg = configs.get_config(arch)
    pshape = specs_lib.params_shape(cfg)
    return pshape, rules.param_specs(pshape, mesh=MESH)


@pytest.mark.parametrize("arch", configs.list_archs())
def test_param_specs_divisible(arch):
    """Every sharded dim must be divisible by its mesh axes."""
    pshape, pspecs = _specs_for(arch)

    def check(path, leaf, spec):
        for dim, axis in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if axis is None:
                continue
            size = rules._axis_size(MESH, axis)
            assert dim % size == 0, f"{path}: {leaf.shape} vs {spec}"

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), pshape, pspecs)


@pytest.mark.parametrize("arch", ["deepseek-coder-33b", "olmoe-1b-7b",
                                  "granite-34b", "zamba2-2.7b"])
def test_big_weights_are_sharded(arch):
    """The dominant tensors must not be fully replicated."""
    pshape, pspecs = _specs_for(arch)
    leaves = jax.tree_util.tree_leaves_with_path(pshape)
    specs = {jax.tree_util.keystr(p): s for p, s in
             jax.tree_util.tree_leaves_with_path(
                 pspecs, is_leaf=lambda x: isinstance(x, P))}
    for path, leaf in leaves:
        if leaf.size >= (1 << 24):  # >= 16M params
            spec = specs[jax.tree_util.keystr(path)]
            assert any(a is not None for a in spec), \
                f"{jax.tree_util.keystr(path)} ({leaf.shape}) replicated"


def test_batch_spec_fallback_for_batch1():
    batch = {"tokens": jax.ShapeDtypeStruct((1, 64), jnp.int32)}
    specs = rules.batch_specs(batch, "data", mesh=MESH)
    assert specs["tokens"] == P(None, None)
    specs2 = rules.batch_specs(batch, ("pod", "data"), mesh=PODMESH)
    assert specs2["tokens"] == P(None, None)


def test_cache_spec_kv_vs_seq_sharding():
    # kv=16 divides the model axis -> shard heads
    cfg16 = configs.get_config("olmoe-1b-7b")
    cache = jax.eval_shape(
        lambda: __import__("repro.models.model", fromlist=["model"]).init_cache(
            cfg16, 32, 128))
    spec = rules.cache_specs(cache, cfg16, "data", mesh=MESH)
    assert spec["k"][3] == "model"
    # kv=1 (MQA) -> shard cache length instead
    cfg1 = configs.get_config("granite-34b")
    cache1 = jax.eval_shape(
        lambda: __import__("repro.models.model", fromlist=["model"]).init_cache(
            cfg1, 32, 256))
    spec1 = rules.cache_specs(cache1, cfg1, "data", mesh=MESH)
    assert spec1["k"][2] == "model" and spec1["k"][3] is None


def test_zero_pod_adds_pod_axis_to_big_tensors():
    cfg = configs.get_config("kimi-k2-1t-a32b")
    pshape = specs_lib.params_shape(cfg)
    pspecs = rules.param_specs(pshape, mesh=PODMESH, zero_pod=True)
    # expert tensors are the ~1T bulk: must carry the pod axis somewhere
    moe_spec = pspecs["stack"]["moe_layers"]["moe"]["gate"]
    assert any(isinstance(a, tuple) and "pod" in a for a in moe_spec), moe_spec


def test_moe_sorted_matches_einsum_dispatch():
    from repro.configs.base import ModelConfig, MoEConfig
    from repro.models import moe

    cfg = ModelConfig(arch_id="t", family="moe", num_layers=1, d_model=32,
                      num_heads=4, num_kv_heads=4, d_ff=16, vocab=64,
                      moe=MoEConfig(num_experts=8, top_k=2, d_expert=16))
    p = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 1024, 32))
    y1, a1 = moe.apply_moe(p, cfg, x)
    y2, a2 = moe.apply_moe_sorted(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)
    np.testing.assert_allclose(float(a1), float(a2), atol=1e-6)
