"""Property-based tests (hypothesis) on the Heroes core invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    BoundState,
    CompositionSpec,
    aggregate_coefficient,
    bound,
    compose,
    decompose,
    gather_blocks,
    init_factors,
    select_blocks,
    solve_rounds,
    tau_star,
)
from repro.core.scheduler import HeroesScheduler, SchedulerConfig

SETTINGS = dict(max_examples=25, deadline=None)


@given(
    p=st.integers(1, 3),
    counters=st.lists(st.integers(0, 1000), min_size=9, max_size=9),
)
@settings(**SETTINGS)
def test_select_blocks_picks_least_trained(p, counters):
    spec = CompositionSpec(max_width=3, rank=4, base_in=8, base_out=8)
    ids = select_blocks(np.asarray(counters), p, spec)
    assert len(ids) == p * p and len(set(ids.tolist())) == p * p
    chosen = sorted(counters[i] for i in ids)
    rest = sorted(counters[i] for i in range(9) if i not in set(ids.tolist()))
    if rest:
        assert chosen[-1] <= rest[0] or chosen[-1] <= max(rest), \
            "a selected block is trained more than an unselected one"
        assert max(chosen) <= min(rest) + 0  # least-trained property


@given(
    p=st.integers(1, 3),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_compose_decompose_roundtrip(p, seed):
    """Any weight composed from the basis decomposes back exactly."""
    spec = CompositionSpec(max_width=3, rank=6, base_in=10, base_out=7, ksq=4)
    v, u = init_factors(jax.random.PRNGKey(seed), spec)
    ids = select_blocks(np.zeros(9), p, spec)
    red = gather_blocks(u, ids)
    w = compose(v, red, p, spec)
    assert w.shape == spec.weight_shape(p)
    red2 = decompose(w, v, p, spec)
    np.testing.assert_allclose(np.asarray(red), np.asarray(red2), atol=1e-4)


@given(seed=st.integers(0, 2**16), nclients=st.integers(1, 5))
@settings(**SETTINGS)
def test_aggregation_mean_and_identity(seed, nclients):
    """Blocks trained by k clients get their mean; untrained stay frozen."""
    rng = np.random.default_rng(seed)
    spec = CompositionSpec(max_width=2, rank=3, base_in=4, base_out=5)
    g = jnp.asarray(rng.normal(size=(4, 3, 5)).astype(np.float32))
    blocks, ids = [], []
    for _ in range(nclients):
        take = rng.choice(4, size=rng.integers(1, 5), replace=False)
        ids.append(np.sort(take))
        blocks.append(jnp.asarray(
            rng.normal(size=(len(take), 3, 5)).astype(np.float32)))
    out = aggregate_coefficient(g, blocks, ids)
    touched = set(int(i) for a in ids for i in a)
    for i in range(4):
        if i not in touched:
            np.testing.assert_array_equal(np.asarray(out[i]), np.asarray(g[i]))
        else:
            contr = [b[list(a).index(i)] for b, a in zip(blocks, ids) if i in a]
            np.testing.assert_allclose(
                np.asarray(out[i]), np.mean([np.asarray(c) for c in contr], 0),
                atol=1e-5)


@given(
    loss0=st.floats(0.1, 10.0),
    L=st.floats(0.05, 20.0),
    gsq=st.floats(0.01, 50.0),
    ssq=st.floats(0.0, 10.0),
    h=st.integers(1, 5000),
)
@settings(**SETTINGS)
def test_tau_star_minimises_bound(loss0, L, gsq, ssq, h):
    """tau* is the argmin of the bound over tau (convexity, Sec. V-B)."""
    state = BoundState(loss0=loss0, smoothness=L, grad_sq=gsq, noise_sq=ssq, lr=0.01)
    t = tau_star(state, h)
    b0 = bound(state, h, t)
    for mult in (0.5, 0.9, 1.1, 2.0):
        assert b0 <= bound(state, h, t * mult) + 1e-9


@given(
    eps=st.floats(0.05, 5.0),
    loss0=st.floats(0.5, 5.0),
)
@settings(**SETTINGS)
def test_solve_rounds_is_minimal(eps, loss0):
    state = BoundState(loss0=loss0, smoothness=1.0, grad_sq=2.0, noise_sq=0.5, lr=0.01)
    h = solve_rounds(state, eps, h_max=200_000)
    if h < 200_000:
        assert bound(state, h, tau_star(state, h)) <= eps
        if h > 1:
            assert bound(state, h - 1, tau_star(state, h - 1)) > eps


@given(seed=st.integers(0, 1000))
@settings(**SETTINGS)
def test_scheduler_respects_waiting_bound(seed):
    """Every client's completion time is within rho of the makespan
    whenever the tau window allows it (Eq. 24)."""
    rng = np.random.default_rng(seed)
    spec = CompositionSpec(max_width=3, rank=4, base_in=8, base_out=8)
    mus = {n: float(rng.uniform(0.01, 0.2)) for n in range(6)}
    nus = {n: float(rng.uniform(0.1, 1.0)) for n in range(6)}
    cfg = SchedulerConfig(mu_max=1.0, rho=1.0, eps=1.0, tau_max=500)
    sched = HeroesScheduler(
        spec, cfg,
        iter_time_fn=lambda n, p: mus[n] * p * p,
        comm_time_fn=lambda n, p: nus[n],
    )
    state = BoundState(loss0=2.0, smoothness=1.0, grad_sq=1.0, noise_sq=0.3, lr=0.05)
    plan = sched.plan_round(list(range(6)), state)
    # Eq. (24) anchors every client to the PACESETTER's completion time
    # (plan.makespan is the max — a slow client can exceed the anchor even
    # at tau=1, which the bound does not constrain).
    anchor = plan.assignments[plan.pacesetter].est_completion
    for n, a in plan.assignments.items():
        lo, hi = sched._tau_window(anchor, a.est_iter_time, a.est_comm_time)
        if lo < hi and anchor >= a.est_comm_time + a.est_iter_time:
            assert anchor - a.est_completion <= cfg.rho + a.est_iter_time + 1e-6


@given(seed=st.integers(0, 500), rounds=st.integers(1, 6))
@settings(max_examples=10, deadline=None)
def test_counter_balance_improves_over_naive(seed, rounds):
    """The variance-minimising tau search keeps block-counter variance no
    worse than always assigning the window's upper bound."""
    rng = np.random.default_rng(seed)
    spec = CompositionSpec(max_width=3, rank=4, base_in=8, base_out=8)
    mus = {n: float(rng.uniform(0.01, 0.1)) for n in range(5)}
    nus = {n: float(rng.uniform(0.05, 0.5)) for n in range(5)}
    mk = lambda: HeroesScheduler(
        spec, SchedulerConfig(mu_max=1.0, rho=2.0, eps=1.0, tau_max=100),
        iter_time_fn=lambda n, p: mus[n] * p * p,
        comm_time_fn=lambda n, p: nus[n],
    )
    state = BoundState(loss0=2.0, smoothness=1.0, grad_sq=1.0, noise_sq=0.3, lr=0.05)
    smart = mk()
    for _ in range(rounds):
        smart.plan_round(list(range(5)), state)
    naive = mk()
    naive._variance_minimising_tau = lambda c, ids, lo, hi: hi
    for _ in range(rounds):
        naive.plan_round(list(range(5)), state)
    assert smart.counter_variance() <= naive.counter_variance() + 1e-9
