"""Tests: sampling utilities + budget-driven FL runs (paper Alg. 1 loop)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.sampling import perplexity, sample_logits


def test_greedy_and_temperature_limits():
    logits = jnp.asarray([[0.0, 5.0, 1.0], [2.0, 0.0, -1.0]])
    assert sample_logits(None, logits, temperature=0.0).tolist() == [1, 0]
    # very low temperature ~ greedy
    out = sample_logits(jax.random.PRNGKey(0), logits, temperature=1e-4)
    assert out.tolist() == [1, 0]


def test_top_k_restricts_support():
    logits = jnp.asarray([[0.0, 5.0, 4.9, -10.0]])
    keys = jax.random.split(jax.random.PRNGKey(0), 200)
    samples = [int(sample_logits(k, logits, temperature=1.0, top_k=2)[0])
               for k in keys]
    assert set(samples) <= {1, 2}


def test_top_p_keeps_nucleus():
    # p(1)=0.9 dominates: top_p=0.5 must keep only token 1
    logits = jnp.log(jnp.asarray([[0.05, 0.9, 0.05]]))
    keys = jax.random.split(jax.random.PRNGKey(1), 100)
    samples = {int(sample_logits(k, logits, temperature=1.0, top_p=0.5)[0])
               for k in keys}
    assert samples == {1}


def test_perplexity_uniform():
    V = 8
    logits = jnp.zeros((2, 5, V))
    labels = jnp.zeros((2, 5), jnp.int32)
    np.testing.assert_allclose(float(perplexity(logits, labels)), V, rtol=1e-5)


def test_run_until_budget_respects_limits():
    from repro.fl import FLConfig, build_image_setup, build_runner

    model, px, py, test = build_image_setup(num_clients=8, seed=0)
    cfg = FLConfig(num_clients=8, clients_per_round=3, eval_every=5,
                   tau_fixed=3, tau_max=10)
    runner = build_runner("heroes", model, px, py, test, cfg=cfg, seed=0)
    hist = runner.run_until_budget(time_budget=0.4)
    # stops within one round of the budget
    assert hist[-1].wall_time >= 0.4 or len(hist) == 10_000
    assert len(hist) >= 1
    before_last = hist[-2].wall_time if len(hist) > 1 else 0.0
    assert before_last < 0.4

    runner2 = build_runner("fedavg", model, px, py, test, cfg=cfg, seed=0)
    hist2 = runner2.run_until_budget(traffic_budget=2e6)
    assert hist2[-1].traffic_bytes >= 2e6
    assert (len(hist2) < 2 or hist2[-2].traffic_bytes < 2e6)
