"""Federated composed transformer + the refactored model layer.

Covers the ComposedLayer/registry refactor and the transformer def:

* registry round-trip (lookup, modality gating, duplicate/unknown
  errors) and ``build_setup`` resolving through it;
* ComposedLayer re-expression is *identical* — cnn/rnn forwards equal
  an inline legacy implementation bitwise, factorized and dense (the
  golden engine-history fixtures in test_engine.py pin the end-to-end
  claim; this pins the layer graphs directly);
* transformer grad-parity matrix (materialize vs rank_space vs auto)
  across widths 1..3, same tolerances as the cnn/resnet/rnn matrix;
* the transformer trains through every registered scheme x both round
  modes with finite metrics and nonzero Heroes block coverage;
* serving: greedy decode through the Pallas kernel matches the inline
  XLA oracle and the full-sequence training forward;
* the rank-aware virtual clock (FLConfig.clock_model) — default stays
  bitwise, "rank_aware" charges the cheaper rank-space FLOPs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl import FLConfig, build_runner, build_setup, run_scheme
from repro.fl.client import _jitted_fns, data_batch
from repro.fl.models import (MODEL_REGISTRY, ComposedLayer, CompositionSpec,
                             LayerHint, _apply_conv, _apply_dense,
                             _apply_embed, _materialized, get_model, make_cnn,
                             make_rnn, register_model)
from repro.fl.transformer import (arch_of, greedy_decode, make_transformer,
                                  serving_weights)


def _reduced(model, width, key=jax.random.PRNGKey(0)):
    params = model.init_factorized(key)
    sq = next(s for s in model.specs.values() if s.mode == "square")
    return model.reduce(params, width,
                        np.arange(sq.blocks_for_width(width)),
                        np.arange(width))


def _text_batch(key, n=8, t=32, vocab=64):
    return {"tokens": jax.random.randint(key, (n, t), 0, vocab),
            "labels": jax.random.randint(key, (n, t), 0, vocab)}


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_roundtrip():
    for name, modality in (("cnn", "image"), ("resnet", "image"),
                           ("rnn", "text"), ("transformer", "text")):
        entry = get_model(name)
        assert entry.name == name and entry.modality == modality
    with pytest.raises(ValueError, match="unknown model"):
        get_model("vit")
    with pytest.raises(ValueError, match="already registered"):
        register_model("cnn")(lambda *a, **k: None)
    assert "vit" not in MODEL_REGISTRY


def test_build_setup_resolves_through_registry():
    model, _, _, _ = build_setup("synthetic_text", "transformer",
                                 num_clients=4, max_width=3, seed=0)
    # memoized factory: the registry hands back the identical instance
    assert model is make_transformer(max_width=3, vocab=64)
    # modality defaults preserved: text -> rnn, image -> cnn
    m_text, _, _, _ = build_setup("synthetic_text", None, num_clients=4,
                                  max_width=3, seed=0)
    assert m_text.name == "rnn"
    m_img, _, _, _ = build_setup("synthetic_image", None, num_clients=4,
                                 max_width=3, seed=0)
    assert m_img.name == "cnn"
    with pytest.raises(ValueError, match="expects image data"):
        build_setup("synthetic_text", "cnn", num_clients=4, seed=0)
    with pytest.raises(ValueError, match="unknown model"):
        build_setup("synthetic_image", "vit", num_clients=4, seed=0)


def test_composed_layer_validation():
    sq = CompositionSpec(3, 8, 4, 4, ksq=1)
    with pytest.raises(ValueError, match="unknown layer kind"):
        ComposedLayer("l", sq, kind="attention")
    with pytest.raises(ValueError, match="requires kind='conv'"):
        ComposedLayer("l", CompositionSpec(3, 8, 4, 4, ksq=9), kind="dense")
    with pytest.raises(ValueError, match="grow_out"):
        ComposedLayer("l", sq, kind="embed")


def test_from_layers_projects_specs_and_hints():
    for model in (make_cnn(), make_rnn(), make_transformer()):
        assert model.layers is not None
        assert list(model.specs) == list(model.layers)
        for name, layer in model.layers.items():
            assert model.specs[name] is layer.spec
            assert model.hints[name] is layer.hint


def test_input_key_drives_batch_assembly():
    x = np.arange(12).reshape(3, 4)
    y = np.arange(3)
    for model, key in ((make_cnn(), "x"), (make_rnn(), "tokens"),
                       (make_transformer(), "tokens")):
        assert model.input_key == key
        assert set(data_batch(model, x, y, np.array([0, 2]))) == {
            key, "labels"}


# ---------------------------------------------------------------------------
# ComposedLayer re-expression is the identical graph (bitwise)
# ---------------------------------------------------------------------------


def test_cnn_forward_bitwise_vs_inline_legacy():
    model = make_cnn()
    specs = model.specs
    batch = {"x": jax.random.normal(jax.random.PRNGKey(5), (4, 8, 8, 3))}

    def legacy_forward(w, width):
        x = batch["x"]
        x = jax.nn.relu(_apply_conv(w["conv1"], x, width, specs["conv1"]))
        x = jax.nn.relu(_apply_conv(w["conv2"], x, width, specs["conv2"],
                                    stride=2))
        x = jax.nn.relu(_apply_conv(w["conv3"], x, width, specs["conv3"],
                                    stride=2))
        x = jnp.mean(x, axis=(1, 2))
        return _apply_dense(w["fc"], x, width, specs["fc"])

    for width in (1, 3):
        red = _reduced(model, width)
        for impl in ("materialize", "rank_space"):
            w = model.prepare_weights(red, width, batch, impl)
            got = np.asarray(model.forward(w, width, batch))
            want = np.asarray(legacy_forward(w, width))
            assert np.array_equal(got, want)


def test_rnn_forward_bitwise_vs_inline_legacy():
    from repro.core.composition import apply_factors

    model = make_rnn()
    specs = model.specs
    batch = _text_batch(jax.random.PRNGKey(6))

    def legacy_forward(w, width):
        tokens = batch["tokens"]
        emb = _apply_embed(w["embed"], tokens, width, specs["embed"])
        wh = _materialized(w["wh"], width, specs["wh"])[0]
        if isinstance(w["wx"], dict):
            xp = apply_factors(emb, w["wx"]["basis"], w["wx"]["coeff"],
                               width, specs["wx"], "dense")

            def step(h, x):
                h = jnp.tanh(x + h @ wh)
                return h, h

            xs = jnp.moveaxis(xp, 1, 0)
        else:
            wx = w["wx"][0]

            def step(h, x):
                h = jnp.tanh(x @ wx + h @ wh)
                return h, h

            xs = jnp.moveaxis(emb, 1, 0)
        h0 = jnp.zeros((emb.shape[0], wh.shape[0]), emb.dtype)
        _, hs = jax.lax.scan(step, h0, xs)
        hs = jnp.moveaxis(hs, 0, 1)
        return _apply_dense(w["out"], hs, width, specs["out"])

    for width in (1, 3):
        red = _reduced(model, width)
        for impl in ("materialize", "rank_space"):
            w = model.prepare_weights(red, width, batch, impl)
            got = np.asarray(model.forward(w, width, batch))
            want = np.asarray(legacy_forward(w, width))
            assert np.array_equal(got, want)


# ---------------------------------------------------------------------------
# transformer grad-parity matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("width", [1, 2, 3])
@pytest.mark.parametrize("impl", ["rank_space", "auto"])
def test_transformer_gradient_parity(width, impl):
    model = make_transformer()
    red = _reduced(model, width)
    batch = _text_batch(jax.random.PRNGKey(3))
    _, grad_mat, step_mat = _jitted_fns(model, width, True, "materialize")
    _, grad_rank, step_rank = _jitted_fns(model, width, True, impl)
    g_mat = grad_mat(red, batch)
    g_rank = grad_rank(red, batch)
    for a, b in zip(jax.tree_util.tree_leaves(g_mat),
                    jax.tree_util.tree_leaves(g_rank)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-3)
    pa, pb = red, red
    for i in range(3):
        b = _text_batch(jax.random.PRNGKey(10 + i))
        pa = step_mat(pa, b, 0.05)
        pb = step_rank(pb, b, 0.05)
    for a, b in zip(jax.tree_util.tree_leaves(pa),
                    jax.tree_util.tree_leaves(pb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-3)


# ---------------------------------------------------------------------------
# the transformer through the engine: every scheme x both round modes
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def text_setup():
    return build_setup("synthetic_text", "transformer", num_clients=8,
                       max_width=3, seed=0)


@pytest.mark.parametrize("scheme", ["fedavg", "adp", "heterofl", "flanc",
                                    "heroes"])
@pytest.mark.parametrize("mode", ["sync", "semi_async"])
def test_transformer_trains_through_engine(text_setup, scheme, mode):
    model, px, py, tb = text_setup
    cfg = FLConfig(num_clients=8, clients_per_round=3, batch_size=8,
                   tau_fixed=2, eval_every=2, round_mode=mode, seed=0)
    hist = run_scheme(scheme, model, px, py, tb, 2, cfg=cfg, seed=0)
    assert len(hist) == 2
    assert np.isfinite(hist[-1].wall_time)
    assert hist[-1].accuracy is not None and np.isfinite(hist[-1].accuracy)


def test_transformer_heroes_coverage_nonzero(text_setup):
    model, px, py, tb = text_setup
    cfg = FLConfig(num_clients=8, clients_per_round=4, batch_size=8,
                   tau_fixed=2, eval_every=10_000, seed=0)
    with build_runner("heroes", model, px, py, tb, cfg=cfg, seed=0) as eng:
        eng.run(3)
        sched = eng.state.sched
    assert np.count_nonzero(sched.counters) == sched.counters.size
    assert np.count_nonzero(sched.anchored) == sched.anchored.size


# ---------------------------------------------------------------------------
# serving: compose once, decode through the Pallas kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("width", [1, 2])
def test_greedy_decode_pallas_matches_xla_and_full_forward(width):
    model = make_transformer()
    params = model.init_factorized(jax.random.PRNGKey(0))
    weights = serving_weights(model, params, width)
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (2, 6),
                                           0, model.num_classes))
    steps = 5
    toks_p, logits_p = greedy_decode(model, weights, width, prompt, steps,
                                     backend="pallas")
    toks_x, logits_x = greedy_decode(model, weights, width, prompt, steps,
                                     backend="xla")
    assert toks_p.shape == (2, steps)
    assert np.array_equal(toks_p, toks_x)
    np.testing.assert_allclose(logits_p, logits_x, atol=1e-4, rtol=1e-4)
    # greedy consistency: the full-sequence training forward (flash
    # attention path) predicts exactly the generated continuation
    seq = jnp.concatenate([jnp.asarray(prompt), jnp.asarray(toks_x)], axis=1)
    full = model.forward(weights, width, {"tokens": seq})
    pred = np.argmax(np.asarray(full), -1)[:, prompt.shape[1] - 1:-1]
    assert np.array_equal(pred, toks_x)


def test_serving_weights_dense_path():
    model = make_transformer()
    dense = model.init_dense(jax.random.PRNGKey(2))
    w = serving_weights(model, dense, 2, factorized=False)
    arch = arch_of(model)
    assert w["embed"].shape == (1, arch.vocab, 2 * arch.d_base)
    toks, _ = greedy_decode(model, w, 2, np.zeros((1, 2), np.int32), 3,
                            backend="xla")
    assert toks.shape == (1, 3)


def test_arch_of_rejects_foreign_models():
    with pytest.raises(ValueError, match="not built by make_transformer"):
        arch_of(make_cnn())


# ---------------------------------------------------------------------------
# rank-aware virtual clock (FLConfig.clock_model)
# ---------------------------------------------------------------------------


def test_clock_model_default_is_bitwise(text_setup):
    model, px, py, tb = text_setup
    kw = dict(num_clients=8, clients_per_round=3, batch_size=8, tau_fixed=2,
              eval_every=2, seed=0)
    h_def = run_scheme("heroes", model, px, py, tb, 2,
                       cfg=FLConfig(**kw), seed=0)
    h_dense = run_scheme("heroes", model, px, py, tb, 2,
                         cfg=FLConfig(clock_model="dense", **kw), seed=0)
    assert [vars(a) for a in h_def] == [vars(b) for b in h_dense]


def test_clock_model_rank_aware_charges_rank_flops(text_setup):
    model, px, py, tb = text_setup
    kw = dict(num_clients=8, clients_per_round=3, batch_size=8, tau_fixed=2,
              eval_every=10_000, seed=0)
    with build_runner("heroes", model, px, py, tb,
                      cfg=FLConfig(clock_model="rank_aware", **kw),
                      seed=0) as eng:
        for p in (1, 2, 3):
            rank = eng.flops_per_iter(p)
            dense = model.flops_per_sample(p) * eng.cfg.batch_size
            assert np.isfinite(rank) and rank > 0
            # the transformer's projections all win in rank space here
            assert rank < dense
        hist = eng.run(2)
    assert np.isfinite(hist[-1].wall_time)
    # dense schemes keep the dense clock regardless of the knob
    with build_runner("fedavg", model, px, py, tb,
                      cfg=FLConfig(clock_model="rank_aware", **kw),
                      seed=0) as eng:
        assert eng.flops_per_iter(3) == model.flops_per_sample(3) * 8


def test_clock_model_validation(text_setup):
    model, px, py, tb = text_setup
    with pytest.raises(ValueError, match="unknown clock_model"):
        build_runner("heroes", model, px, py, tb,
                     cfg=FLConfig(num_clients=8, clock_model="fast"), seed=0)
