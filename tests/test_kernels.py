"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _mk(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("ksq,i,r,m,o", [
    (1, 32, 8, 1, 16),      # tiny dense
    (9, 40, 16, 4, 24),     # conv, p=2 (4 blocks), ragged dims
    (1, 256, 64, 9, 128),   # aligned large, p=3
    (4, 7, 4, 1, 5),        # deliberately unaligned
])
def test_compose_sweep(dtype, ksq, i, r, m, o):
    k1, k2 = jax.random.split(jax.random.PRNGKey(ksq * 1000 + i))
    v = _mk(k1, (ksq, i, r), dtype)
    u = _mk(k2, (m, r, o), dtype)
    got = ops.compose(v, u)
    want = ref.compose_ref(v, u)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["square", "grow_out", "grow_in"])
@pytest.mark.parametrize("p", [1, 2, 3])
def test_compose_pallas_matches_einsum_compose(dtype, mode, p):
    """The kernel path of repro.core.composition.compose (mode reshape
    included) against the einsum reference, all modes x widths x dtypes."""
    from repro.core.composition import (CompositionSpec, compose,
                                        gather_blocks, init_factors)

    spec = CompositionSpec(3, 8, 6, 5, ksq=9, mode=mode)
    v, u = init_factors(jax.random.PRNGKey(p), spec, dtype)
    red = gather_blocks(u, np.arange(spec.blocks_for_width(p)))
    want = compose(v, red, p, spec, backend="einsum")
    got = compose(v, red, p, spec, backend="pallas")
    assert got.shape == spec.weight_shape(p) and got.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


def test_compose_pallas_batched_client_axis():
    """One pallas_call over a leading client axis == per-client calls."""
    from repro.kernels.compose import compose_pallas

    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    C, ksq, I, R, m, O = 5, 9, 8, 6, 4, 5
    vb = jax.random.normal(k1, (C, ksq, I, R), jnp.float32)
    ub = jax.random.normal(k2, (C, m, R, O), jnp.float32)
    got = compose_pallas(vb, ub)
    assert got.shape == (C, ksq, I, m * O)
    for c in range(C):
        np.testing.assert_allclose(
            np.asarray(got[c]), np.asarray(compose_pallas(vb[c], ub[c])),
            atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("mode", ["square", "grow_out", "grow_in"])
@pytest.mark.parametrize("p", [1, 2, 3])
def test_compose_pallas_grads_match_einsum(mode, p):
    """compose() runs inside differentiated losses (every materialize
    layer in prepare_weights, the RNN's scan-carried wh) and defaults
    to the Pallas backend on TPU — jax.grad through it must work and
    match autodiff through the einsum reference (the kernel carries a
    custom_vjp because pallas_call has no transpose rule)."""
    from repro.core.composition import (CompositionSpec, compose,
                                        gather_blocks, init_factors)

    spec = CompositionSpec(3, 8, 6, 5, ksq=9, mode=mode)
    v, u = init_factors(jax.random.PRNGKey(p), spec)
    red = gather_blocks(u, np.arange(spec.blocks_for_width(p)))

    def loss(backend):
        return lambda args: jnp.sum(
            jnp.sin(compose(args[0], args[1], p, spec, backend=backend)))

    np.testing.assert_allclose(float(loss("pallas")((v, red))),
                               float(loss("einsum")((v, red))), rtol=1e-5)
    ga = jax.grad(loss("pallas"))((v, red))
    gb = jax.grad(loss("einsum"))((v, red))
    for a, b in zip(jax.tree_util.tree_leaves(ga),
                    jax.tree_util.tree_leaves(gb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)


def test_compose_pallas_batched_grads_match_einsum():
    """The leading-client-axis (4d) kernel path is differentiable too —
    the cohort trainer's stacked compose sits inside jax.grad."""
    from repro.kernels.compose import compose_pallas

    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    C, ksq, I, R, m, O = 3, 4, 6, 5, 4, 7
    vb = jax.random.normal(k1, (C, ksq, I, R), jnp.float32)
    ub = jax.random.normal(k2, (C, m, R, O), jnp.float32)

    def loss_pallas(args):
        return jnp.sum(jnp.sin(compose_pallas(args[0], args[1])))

    def loss_einsum(args):
        flat = jnp.einsum("ckir,cmro->ckimo", args[0], args[1])
        return jnp.sum(jnp.sin(flat.reshape(C, ksq, I, m * O)))

    np.testing.assert_allclose(float(loss_pallas((vb, ub))),
                               float(loss_einsum((vb, ub))), rtol=1e-5)
    ga = jax.grad(loss_pallas)((vb, ub))
    gb = jax.grad(loss_einsum)((vb, ub))
    for a, b in zip(jax.tree_util.tree_leaves(ga),
                    jax.tree_util.tree_leaves(gb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("mode", ["square", "grow_out", "grow_in"])
@pytest.mark.parametrize("p", [1, 2, 3])
def test_rank_apply_pallas_kernel_body(mode, p):
    """The fused kernel body (interpret mode) vs the einsum reference —
    the TPU-compiled forward path of rank_dense_apply, which CPU CI
    would otherwise never execute."""
    from repro.core.composition import (CompositionSpec, gather_blocks,
                                        init_factors)
    from repro.kernels.compose import (_fwd_math, _u2_layout,
                                       rank_apply_pallas)

    spec = CompositionSpec(3, 8, 6, 5, ksq=1, mode=mode)
    v, u = init_factors(jax.random.PRNGKey(p), spec)
    red = gather_blocks(u, np.arange(spec.blocks_for_width(p)))
    M = 13  # deliberately not a block_m multiple (exercises padding)
    x2 = jax.random.normal(jax.random.PRNGKey(p + 3),
                           (M, spec.weight_shape(p)[1]))
    want, _ = _fwd_math(x2, v[0], red, p, mode)
    g = 1 if mode == "grow_out" else p
    got = rank_apply_pallas(x2.reshape(M, g, -1), v[0],
                            _u2_layout(red, p, mode), block_m=8,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("mode", ["square", "grow_out", "grow_in"])
@pytest.mark.parametrize("p", [1, 2, 3])
def test_rank_dense_apply_custom_vjp(mode, p):
    """Fused rank-space apply: values AND custom_vjp grads match
    autodiff through compose-then-apply; works under vmap (cohort)."""
    from repro.core.composition import (CompositionSpec, compose,
                                        gather_blocks, init_factors)
    from repro.kernels.compose import rank_dense_apply

    spec = CompositionSpec(3, 8, 6, 5, ksq=1, mode=mode)
    v, u = init_factors(jax.random.PRNGKey(p), spec)
    red = gather_blocks(u, np.arange(spec.blocks_for_width(p)))
    x = jax.random.normal(jax.random.PRNGKey(p + 7),
                          (4, 3, spec.weight_shape(p)[1]))

    def loss_rank(args):
        v_, u_, x_ = args
        return jnp.sum(jnp.sin(rank_dense_apply(x_, v_, u_, p, mode)))

    def loss_mat(args):
        v_, u_, x_ = args
        return jnp.sum(jnp.sin(x_ @ compose(v_, u_, p, spec,
                                            backend="einsum")[0]))

    np.testing.assert_allclose(float(loss_rank((v, red, x))),
                               float(loss_mat((v, red, x))), rtol=1e-5)
    ga = jax.grad(loss_rank)((v, red, x))
    gb = jax.grad(loss_mat)((v, red, x))
    for a, b in zip(jax.tree_util.tree_leaves(ga),
                    jax.tree_util.tree_leaves(gb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)
    # vmap over a leading client axis (the cohort trainer's layout)
    vv, uv = jnp.stack([v] * 2), jnp.stack([red] * 2)
    xv = jnp.stack([x] * 2)
    y = jax.vmap(lambda a, b, c: rank_dense_apply(c, a, b, p, mode))(vv, uv, xv)
    assert y.shape[0] == 2


@pytest.mark.parametrize("mode", ["square", "grow_out", "grow_in"])
@pytest.mark.parametrize("p", [1, 2, 3])
def test_rank_dense_fn_kernel_branch_fwd_bwd(mode, p):
    """The use_kernel=True custom_vjp wiring — Pallas forward plus the
    recomputed rank-space residual that feeds bwd — with the kernel
    forced through the interpreter: the exact code TPU runs compiled,
    which rank_dense_apply never selects on CPU CI.  Values and grads
    must match the einsum branch."""
    from repro.core.composition import (CompositionSpec, gather_blocks,
                                        init_factors)
    from repro.kernels.compose import _rank_dense_fn

    spec = CompositionSpec(3, 8, 6, 5, ksq=1, mode=mode)
    v, u = init_factors(jax.random.PRNGKey(p), spec)
    red = gather_blocks(u, np.arange(spec.blocks_for_width(p)))
    M = 13  # not a block_m multiple: the padded-kernel forward
    x2 = jax.random.normal(jax.random.PRNGKey(p + 5),
                           (M, spec.weight_shape(p)[1]))
    fn_kernel = _rank_dense_fn(p, mode, True, kernel_interpret=True)
    fn_einsum = _rank_dense_fn(p, mode, False)

    def loss(fn):
        return lambda args: jnp.sum(jnp.sin(fn(args[0], args[1], args[2])))

    args = (x2, v[0], red)
    np.testing.assert_allclose(float(loss(fn_kernel)(args)),
                               float(loss(fn_einsum)(args)), rtol=1e-5)
    ga = jax.grad(loss(fn_kernel))(args)
    gb = jax.grad(loss(fn_einsum))(args)
    for a, b in zip(jax.tree_util.tree_leaves(ga),
                    jax.tree_util.tree_leaves(gb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# fused conv rank path (repro.kernels.conv_rank)
# ---------------------------------------------------------------------------

# fused-vs-reference tolerances: the fused formulations re-associate the
# accumulation, and bf16 additionally rounds the rank intermediate
FTOL = {jnp.float32: 2e-4, jnp.bfloat16: 6e-2}


def _conv_setup(mode, p, dtype=jnp.float32, key=0):
    from repro.core.composition import (CompositionSpec, gather_blocks,
                                        init_factors)

    spec = CompositionSpec(3, 8, 6, 5, ksq=9, mode=mode)
    v, u = init_factors(jax.random.PRNGKey(key), spec, dtype)
    red = gather_blocks(u, np.arange(spec.blocks_for_width(p)))
    g = 1 if mode == "grow_out" else p
    x = _mk(jax.random.PRNGKey(key + 17), (2, 8, 8, g * 6), dtype)
    return x, v, red


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["square", "grow_out", "grow_in"])
@pytest.mark.parametrize("p", [1, 2, 3])
@pytest.mark.parametrize("stride", [1, 2])
def test_conv_rank_apply_matches_ref(dtype, mode, p, stride):
    """The public fused primitive (CPU fused-math branch) vs the
    compose-then-conv oracle, all modes x widths x strides x dtypes."""
    x, v, red = _conv_setup(mode, p, dtype)
    got = ops.conv_rank_apply(x, v, red, p, mode, stride=stride)
    want = ref.conv_rank_ref(x.astype(jnp.float32), v.astype(jnp.float32),
                             red.astype(jnp.float32), p, mode, stride)
    assert got.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=FTOL[dtype], rtol=FTOL[dtype])


@pytest.mark.parametrize("mode", ["square", "grow_out", "grow_in"])
@pytest.mark.parametrize("p", [1, 2, 3])
@pytest.mark.parametrize("stride", [1, 2])
def test_conv_rank_pallas_kernel_body(mode, p, stride):
    """The Pallas kernel body (interpret mode) vs the oracle — the
    TPU-compiled forward, which CPU CI would otherwise never execute.
    Covers the asymmetric SAME padding at stride 2."""
    from repro.kernels.conv_rank import _u2_conv_layout, conv_rank_pallas

    x, v, red = _conv_setup(mode, p)
    u2 = _u2_conv_layout(red, p, mode)
    got = conv_rank_pallas(x, v, u2, p=p, mode=mode, stride=stride,
                           interpret=True)
    want = ref.conv_rank_ref(x, v, red, p, mode, stride)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["square", "grow_out", "grow_in"])
@pytest.mark.parametrize("p", [1, 2, 3])
def test_conv_rank_apply_grads_match_ref(dtype, mode, p):
    """The rank-space custom_vjp backward (dx, dbasis, du) vs autodiff
    through compose-then-conv, stride 2 (the CNN downsampling shape)."""
    x, v, red = _conv_setup(mode, p, dtype, key=2)

    def loss_fused(args):
        return jnp.sum(jnp.sin(ops.conv_rank_apply(
            args[0], args[1], args[2], p, mode, stride=2)))

    def loss_ref(args):
        return jnp.sum(jnp.sin(ref.conv_rank_ref(
            args[0], args[1], args[2], p, mode, 2)))

    # (scalar loss parity is implied by the per-element value sweep
    # above; a sum of sins can sit near zero, so comparing it directly
    # is noise-dominated at bf16)
    args = (x, v, red)
    ga = jax.grad(loss_fused)(args)
    gb = jax.grad(loss_ref)(args)
    for a, b in zip(jax.tree_util.tree_leaves(ga),
                    jax.tree_util.tree_leaves(gb)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=4 * FTOL[dtype], rtol=4 * FTOL[dtype])


@pytest.mark.parametrize("mode", ["square", "grow_out", "grow_in"])
@pytest.mark.parametrize("p", [1, 2, 3])
@pytest.mark.parametrize("stride", [1, 2])
def test_conv_rank_fn_kernel_branch_fwd_bwd(mode, p, stride):
    """The use_kernel=True custom_vjp wiring — Pallas forward through
    the interpreter (exactly what TPU runs compiled) feeding the
    rank-space backward.  Values and grads must match the fused-math
    branch CPU production uses."""
    from repro.kernels.conv_rank import _conv_rank_fn

    x, v, red = _conv_setup(mode, p, key=3)
    fn_kernel = _conv_rank_fn(p, mode, stride, True, kernel_interpret=True)
    fn_math = _conv_rank_fn(p, mode, stride, False)

    def loss(fn):
        return lambda args: jnp.sum(jnp.sin(fn(args[0], args[1], args[2])))

    args = (x, v, red)
    np.testing.assert_allclose(float(loss(fn_kernel)(args)),
                               float(loss(fn_math)(args)), rtol=1e-5)
    ga = jax.grad(loss(fn_kernel))(args)
    gb = jax.grad(loss(fn_math))(args)
    for a, b in zip(jax.tree_util.tree_leaves(ga),
                    jax.tree_util.tree_leaves(gb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)


def test_conv_rank_apply_vmap_cohort():
    """vmap over a leading client axis (the cohort trainer's layout)
    equals per-client calls."""
    xs, vs, us = [], [], []
    for c in range(3):
        x, v, red = _conv_setup("square", 2, key=c)
        xs.append(x), vs.append(v), us.append(red)
    xb, vb, ub = jnp.stack(xs), jnp.stack(vs), jnp.stack(us)
    got = jax.vmap(lambda a, b, c_: ops.conv_rank_apply(
        a, b, c_, 2, "square", stride=2))(xb, vb, ub)
    for c in range(3):
        want = ops.conv_rank_apply(xs[c], vs[c], us[c], 2, "square",
                                   stride=2)
        np.testing.assert_allclose(np.asarray(got[c]), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# fused compose+apply dense path (repro.kernels.compose.compose_dense_apply)
# ---------------------------------------------------------------------------


def _dense_setup(mode, p, dtype=jnp.float32, key=1):
    from repro.core.composition import (CompositionSpec, gather_blocks,
                                        init_factors)

    spec = CompositionSpec(3, 8, 6, 5, ksq=1, mode=mode)
    v, u = init_factors(jax.random.PRNGKey(key), spec, dtype)
    red = gather_blocks(u, np.arange(spec.blocks_for_width(p)))
    g = 1 if mode == "grow_out" else p
    x = _mk(jax.random.PRNGKey(key + 23), (4, 3, g * 6), dtype)
    return x, v, red


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["square", "grow_out", "grow_in"])
@pytest.mark.parametrize("p", [1, 2, 3])
def test_compose_dense_apply_matches_ref(dtype, mode, p):
    """Fused compose+apply (leading dims preserved) vs compose-then-
    matmul, values AND custom_vjp grads, all modes x widths x dtypes."""
    x, v, red = _dense_setup(mode, p, dtype)
    got = ops.compose_dense_apply(x, v, red, p, mode)
    want = ref.compose_apply_ref(x.astype(jnp.float32),
                                 v.astype(jnp.float32),
                                 red.astype(jnp.float32), p, mode)
    assert got.shape == want.shape
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=FTOL[dtype], rtol=FTOL[dtype])

    def loss_fused(args):
        return jnp.sum(jnp.sin(ops.compose_dense_apply(
            args[0], args[1], args[2], p, mode)))

    def loss_ref(args):
        return jnp.sum(jnp.sin(ref.compose_apply_ref(
            args[0], args[1], args[2], p, mode)))

    args = (x, v, red)
    ga = jax.grad(loss_fused)(args)
    gb = jax.grad(loss_ref)(args)
    for a, b in zip(jax.tree_util.tree_leaves(ga),
                    jax.tree_util.tree_leaves(gb)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=4 * FTOL[dtype], rtol=4 * FTOL[dtype])


@pytest.mark.parametrize("mode", ["square", "grow_out", "grow_in"])
@pytest.mark.parametrize("p", [1, 2, 3])
def test_compose_apply_pallas_kernel_body(mode, p):
    """The kernel body (interpret mode) vs the oracle, with M not a
    block_m multiple so the row padding path is exercised."""
    from repro.kernels.compose import _u2_layout, compose_apply_pallas

    x, v, red = _dense_setup(mode, p, key=4)
    x2 = x.reshape(-1, x.shape[-1])[:11]  # 11 rows, block_m=8: padded
    g = 1 if mode == "grow_out" else p
    xg = x2.reshape(x2.shape[0], g, -1)
    u3 = _u2_layout(red, p, mode).reshape(g, red.shape[-2], -1)
    got = compose_apply_pallas(xg, v[0], u3, block_m=8, interpret=True)
    want = ref.compose_apply_ref(x2, v, red, p, mode)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("mode", ["square", "grow_out", "grow_in"])
@pytest.mark.parametrize("p", [1, 2, 3])
def test_compose_dense_fn_kernel_branch_fwd_bwd(mode, p):
    """use_kernel=True wiring: Pallas-interpret forward + shared
    rank-space backward vs the fused-math branch, values and grads."""
    from repro.kernels.compose import _compose_dense_fn

    x, v, red = _dense_setup(mode, p, key=5)
    x2 = x.reshape(-1, x.shape[-1])[:13]
    fn_kernel = _compose_dense_fn(p, mode, True, kernel_interpret=True)
    fn_math = _compose_dense_fn(p, mode, False)

    def loss(fn):
        return lambda args: jnp.sum(jnp.sin(fn(args[0], args[1], args[2])))

    args = (x2, v[0], red)
    np.testing.assert_allclose(float(loss(fn_kernel)(args)),
                               float(loss(fn_math)(args)), rtol=1e-5)
    ga = jax.grad(loss(fn_kernel))(args)
    gb = jax.grad(loss(fn_math))(args)
    for a, b in zip(jax.tree_util.tree_leaves(ga),
                    jax.tree_util.tree_leaves(gb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)


def test_compose_dense_apply_vmap_cohort():
    """The cohort trainer wraps the fused dense primitive in vmap."""
    xs, vs, us = [], [], []
    for c in range(3):
        x, v, red = _dense_setup("grow_in", 2, key=10 + c)
        xs.append(x), vs.append(v), us.append(red)
    xb, vb, ub = jnp.stack(xs), jnp.stack(vs), jnp.stack(us)
    got = jax.vmap(lambda a, b, c_: ops.compose_dense_apply(
        a, b, c_, 2, "grow_in"))(xb, vb, ub)
    for c in range(3):
        want = ops.compose_dense_apply(xs[c], vs[c], us[c], 2, "grow_in")
        np.testing.assert_allclose(np.asarray(got[c]), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,kv,g,d,window", [
    (1, 64, 1, 1, 32, 0),     # MHA degenerate
    (2, 100, 2, 3, 32, 0),    # GQA, ragged seq
    (1, 128, 4, 1, 64, 32),   # sliding window
    (2, 33, 1, 4, 16, 8),     # MQA + tiny window + ragged
])
def test_flash_attention_sweep(dtype, b, s, kv, g, d, window):
    ks = jax.random.split(jax.random.PRNGKey(s * 7 + d), 3)
    q = _mk(ks[0], (b, s, kv, g, d), dtype)
    k = _mk(ks[1], (b, s, kv, d), dtype)
    v = _mk(ks[2], (b, s, kv, d), dtype)
    got = ops.flash_attention(q, k, v, window=window)
    qf = jnp.transpose(q, (0, 2, 3, 1, 4)).reshape(b * kv * g, s, d)
    kf = jnp.repeat(jnp.transpose(k, (0, 2, 1, 3)).reshape(b * kv, s, d), g, 0)
    vf = jnp.repeat(jnp.transpose(v, (0, 2, 1, 3)).reshape(b * kv, s, d), g, 0)
    want = ref.attention_ref(qf.astype(jnp.float32), kf.astype(jnp.float32),
                             vf.astype(jnp.float32), window=window)
    want = jnp.transpose(want.reshape(b, kv, g, s, d), (0, 3, 1, 2, 4))
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=8 * TOL[dtype], rtol=8 * TOL[dtype],
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,kv,g,d", [
    (2, 64, 2, 2, 32),
    (1, 500, 1, 8, 64),   # MQA long ragged cache
    (4, 33, 4, 1, 16),
])
def test_decode_attention_sweep(dtype, b, s, kv, g, d):
    ks = jax.random.split(jax.random.PRNGKey(s + d), 3)
    q = _mk(ks[0], (b, 1, kv, g, d), dtype)
    k = _mk(ks[1], (b, s, kv, d), dtype)
    v = _mk(ks[2], (b, s, kv, d), dtype)
    lens = jnp.asarray(np.random.default_rng(0).integers(1, s + 1, b), jnp.int32)
    got = ops.decode_attention(q, k, v, lens)
    qf = q[:, 0].reshape(b * kv * g, d)
    kf = jnp.repeat(jnp.transpose(k, (0, 2, 1, 3)).reshape(b * kv, s, d), g, 0)
    vf = jnp.repeat(jnp.transpose(v, (0, 2, 1, 3)).reshape(b * kv, s, d), g, 0)
    want = ref.decode_attention_ref(
        qf.astype(jnp.float32), kf.astype(jnp.float32), vf.astype(jnp.float32),
        jnp.repeat(lens, kv * g),
    ).reshape(b, 1, kv, g, d)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=8 * TOL[dtype], rtol=8 * TOL[dtype],
    )


def test_flash_matches_model_attention():
    """Pallas kernel vs the model's pure-JAX chunked flash attention."""
    from repro.models.attention import flash_attention as model_flash

    key = jax.random.PRNGKey(3)
    B, S, KV, G, D = 2, 96, 2, 2, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, KV, G, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    a = model_flash(q, k, v, q_chunk=32, kv_chunk=16)
    b_ = ops.flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,q,n,p", [(4, 32, 8, 16), (2, 64, 16, 32),
                                     (1, 16, 4, 8), (3, 24, 4, 12)])
def test_ssd_chunk_sweep(dtype, b, q, n, p):
    ks = jax.random.split(jax.random.PRNGKey(q + p), 5)
    cb = _mk(ks[0], (b, q, n), dtype)
    bb = _mk(ks[1], (b, q, n), dtype)
    xw = _mk(ks[2], (b, q, p), dtype)
    # cum (log-decay) stays f32 by contract — bf16 loses the relative
    # decay precision over long chunks
    cum = -jnp.cumsum(jax.nn.softplus(jax.random.normal(ks[3], (b, q))), 1)
    hin = _mk(ks[4], (b, n, p), dtype)
    got = ops.ssd_chunk(cb, bb, xw, cum, hin)
    want = ref.ssd_chunk_ref(cb.astype(jnp.float32), bb.astype(jnp.float32),
                             xw.astype(jnp.float32), cum,
                             hin.astype(jnp.float32))
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=16 * TOL[dtype], rtol=16 * TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 64), (2, 7, 96), (1, 130, 32)])
def test_rmsnorm_sweep(dtype, shape):
    ks = jax.random.split(jax.random.PRNGKey(sum(shape)), 2)
    x = _mk(ks[0], shape, dtype)
    scale = 1.0 + 0.1 * jax.random.normal(ks[1], (shape[-1],), jnp.float32)
    got = ops.rmsnorm(x, scale)
    want = ref.rmsnorm_ref(x.astype(jnp.float32), scale).astype(dtype)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=4 * TOL[dtype], rtol=4 * TOL[dtype])
