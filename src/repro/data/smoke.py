"""Dataset-subsystem smoke: one ``run_scheme`` round per registry loader.

CI leg (offline by construction — loaders fall back to deterministic
synthetic generation) exercising the full path dataset registry ->
partitioner registry -> streaming shards -> engine round -> eval::

    PYTHONPATH=src python -m repro.data.smoke [--cache-dir DIR] [--scheme S]

Exits non-zero on any non-finite accuracy or loader failure.
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--cache-dir", default=None,
                    help="npz cache directory (shared across CI runs)")
    ap.add_argument("--data-root", default=None,
                    help="optional real-data directory (default: fallback)")
    ap.add_argument("--scheme", default="heroes",
                    help="scheme to drive each loader with")
    args = ap.parse_args(argv)

    import numpy as np

    from repro.fl import FLConfig, run_scheme
    from repro.fl.simulation import build_image_setup, build_text_setup

    cfg = FLConfig(num_clients=8, clients_per_round=3, tau_fixed=2,
                   tau_max=6, eval_every=1, batch_size=8, trainer="cohort")
    setups = {
        "synthetic_image": lambda: build_image_setup(
            num_clients=8, seed=0, task="synthetic_image"),
        "cifar10": lambda: build_image_setup(
            num_clients=8, seed=0, task="cifar10", max_width=2,
            data_root=args.data_root, cache_dir=args.cache_dir,
            task_kw={"train_size": 512, "test_size": 128}),
        "synthetic_text": lambda: build_text_setup(
            num_clients=8, seed=0, task="synthetic_text"),
        "shakespeare": lambda: build_text_setup(
            num_clients=8, seed=0, task="shakespeare", max_width=2,
            data_root=args.data_root, cache_dir=args.cache_dir,
            task_kw={"train_size": 512, "test_size": 128}),
    }
    failures = 0
    for name, build in setups.items():
        t0 = time.time()
        try:
            model, px, py, test = build()
            hist = run_scheme(args.scheme, model, px, py, test, rounds=1,
                              cfg=cfg)
            acc = hist[-1].accuracy
            ok = acc is not None and np.isfinite(acc)
        except Exception as e:  # noqa: BLE001 — smoke must report, not die
            print(f"FAIL  {name}: {type(e).__name__}: {e}")
            failures += 1
            continue
        status = "ok" if ok else "FAIL (non-finite accuracy)"
        failures += 0 if ok else 1
        print(f"{status:4}  {name}: acc={acc:.3f} "
              f"clients={len(px)} ({time.time() - t0:.1f}s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
