"""On-disk npz cache for loader outputs.

Layout: ``<cache_dir>/<task>/<sha1-of-key>.npz`` where the key is the
canonical JSON of ``(task, seed, preprocessing...)`` — every field that
changes the produced arrays.  Writes are atomic (tmp file + rename) so
concurrent CI shards can share one directory, and the resolved key is
stored inside the archive (``__key__``) for debuggability.

The cache directory resolves, in order: the explicit ``cache_dir``
argument, the ``REPRO_DATA_CACHE`` environment variable, else caching
is disabled (loaders regenerate from files / the synthetic fallback).
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
from pathlib import Path
from typing import Dict, Optional

import numpy as np

ENV_VAR = "REPRO_DATA_CACHE"


def resolve_cache_dir(cache_dir=None) -> Optional[Path]:
    if cache_dir is not None:
        return Path(cache_dir)
    env = os.environ.get(ENV_VAR)
    return Path(env) if env else None


def cache_key(**fields) -> str:
    """Deterministic hex key from the (task, seed, preprocessing) fields."""
    canon = json.dumps(fields, sort_keys=True, default=str)
    return hashlib.sha1(canon.encode()).hexdigest()


def cache_path(cache_dir, task: str, key: str) -> Path:
    return Path(cache_dir) / task / f"{key}.npz"


def load_arrays(path: Path) -> Optional[Dict[str, np.ndarray]]:
    """Arrays from a cache file, or None when absent/corrupt."""
    path = Path(path)
    if not path.exists():
        return None
    try:
        with np.load(path, allow_pickle=False) as z:
            return {k: z[k] for k in z.files if k != "__key__"}
    except (OSError, ValueError, KeyError):
        return None  # truncated/corrupt entries regenerate silently


def save_arrays(path: Path, arrays: Dict[str, np.ndarray], key: str = "") -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".npz.tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, __key__=np.frombuffer(key.encode(), np.uint8),
                     **arrays)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def cached(task: str, fields: Dict, builder, cache_dir=None):
    """``builder() -> Dict[str, np.ndarray]`` memoized through the cache.

    Returns ``(arrays, hit)``; a disabled cache always rebuilds.
    """
    root = resolve_cache_dir(cache_dir)
    if root is None:
        return builder(), False
    key = cache_key(task=task, **fields)
    path = cache_path(root, task, key)
    arrays = load_arrays(path)
    if arrays is not None:
        return arrays, True
    arrays = builder()
    save_arrays(path, arrays, key)
    return arrays, False
