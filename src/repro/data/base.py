"""FederatedDataset API + dataset registry.

Every task — synthetic stand-in or real on-disk benchmark — is served
behind one container: named ``(x, y)`` splits (at least ``train`` and
``test``) plus a metadata dict describing the modality and how to
partition/model it.  Loaders are registered with
``@register_dataset("name")`` and looked up with :func:`load_dataset`,
so drivers (``build_image_setup`` / ``build_text_setup``,
``benchmarks/``, the CI smoke) select tasks by name instead of
hard-coding constructors.

Metadata keys the rest of the system reads:

  modality          "image" | "text"
  num_classes       image tasks: label count (model output dim)
  vocab             text tasks: token count (model output dim)
  natural_ids       optional (N,) int array: per-train-sample group id
                    (e.g. Shakespeare speaker) consumed by the
                    "natural" partitioner
  partition_labels  optional (N,) labels the label-based partitioners
                    (dirichlet / class_skew) split on; defaults to
                    ``y`` for image tasks
  source            "files" | "synthetic" — whether real data was found
                    under ``data_root`` or the deterministic fallback
                    was generated (CI never touches the network)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import numpy as np


@dataclasses.dataclass
class FederatedDataset:
    """A task as named splits + metadata.

    ``splits[name] = (inputs, targets)``: for image tasks inputs are
    ``(N, H, W, C)`` float32 and targets ``(N,)`` int labels; for text
    tasks inputs are ``(N, T)`` int tokens and targets the ``(N, T)``
    next-token labels (already shifted by the loader).
    """

    name: str
    splits: Dict[str, Tuple[np.ndarray, np.ndarray]]
    metadata: Dict[str, Any]

    def __post_init__(self):
        for required in ("train", "test"):
            if required not in self.splits:
                raise ValueError(
                    f"dataset {self.name!r} is missing the {required!r} split")
        for split, (x, y) in self.splits.items():
            if len(x) != len(y):
                raise ValueError(
                    f"{self.name}/{split}: {len(x)} inputs vs {len(y)} targets")

    # --- train-split accessors (the partition/training surface) ----------
    @property
    def x(self) -> np.ndarray:
        return self.splits["train"][0]

    @property
    def y(self) -> np.ndarray:
        return self.splits["train"][1]

    @property
    def modality(self) -> str:
        return self.metadata["modality"]

    @property
    def partition_labels(self) -> np.ndarray:
        """1-D labels the label-based partitioners operate on."""
        labels = self.metadata.get("partition_labels")
        if labels is not None:
            return np.asarray(labels)
        if self.y.ndim == 1:
            return self.y
        # text: fall back to the speaker id, else the first input token
        ids = self.metadata.get("natural_ids")
        if ids is not None:
            return np.asarray(ids)
        return np.asarray(self.x[:, 0])

    def test_batch(self) -> Dict[str, Any]:
        """The full test split as the batch dict the FL models consume."""
        import jax.numpy as jnp

        tx, ty = self.splits["test"]
        key = "tokens" if self.modality == "text" else "x"
        return {key: jnp.asarray(tx), "labels": jnp.asarray(ty)}


DATASETS: Dict[str, Callable[..., FederatedDataset]] = {}


def register_dataset(name: str):
    """Decorator registering a ``(**kwargs) -> FederatedDataset`` loader."""

    def deco(loader: Callable[..., FederatedDataset]):
        DATASETS[name] = loader
        return loader

    return deco


def load_dataset(name: str, **kwargs) -> FederatedDataset:
    """Look up and invoke a registered loader.

    Common kwargs every loader accepts: ``seed`` (fallback generation
    seed), ``data_root`` (where real files are searched), ``cache_dir``
    (npz cache location, see :mod:`repro.data.cache`).
    """
    if name not in DATASETS:
        raise KeyError(f"unknown dataset {name!r}; have {sorted(DATASETS)}")
    return DATASETS[name](**kwargs)
