"""Non-IID partitioners behind a registry any dataset composes with.

The paper's Γ (dirichlet-style main-class skew) and φ (missing-class)
schemes moved here from :mod:`repro.data.synthetic` (which re-exports
them).  A *partitioner* maps a train split to per-client index lists::

    fn(labels, num_clients, seed, metadata=..., **kw) -> List[np.ndarray]

and is registered under a name so drivers select it per run
(``build_image_setup(partitioner="class_skew", partition_kw=...)``).

Coverage contract:

  * every partitioner returns exactly ``num_clients`` disjoint index
    arrays (no sample is assigned twice);
  * ``iid`` and ``natural`` cover every train index exactly once;
  * ``dirichlet`` / ``class_skew`` keep the paper's equal-volume rule
    ``n_per_client = N // num_clients``, so up to ``N % num_clients``
    (plus skew-induced shortfalls) trailing samples stay unassigned —
    the property tests in tests/test_data.py pin both behaviours.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional

import numpy as np


def dirichlet_partition(labels: np.ndarray, num_clients: int, gamma_pct: float,
                        seed: int = 0) -> List[np.ndarray]:
    """Paper's Γ scheme: Γ% of each client's samples from one class, the
    rest spread evenly.  Γ=1/num_classes*100 ~ IID."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    idx_by_class = {c: list(rng.permutation(np.where(labels == c)[0])) for c in classes}
    n_per_client = len(labels) // num_clients
    frac = gamma_pct / 100.0
    out = []
    for n in range(num_clients):
        main_c = classes[n % len(classes)]
        want_main = int(round(frac * n_per_client))
        take = []
        pool = idx_by_class[main_c]
        take += [pool.pop() for _ in range(min(want_main, len(pool)))]
        rest = n_per_client - len(take)
        others = [c for c in classes]
        for i in range(rest):
            c = others[i % len(others)]
            pool = idx_by_class[c]
            if pool:
                take.append(pool.pop())
        out.append(np.asarray(take, np.int64))
    return out


def class_skew_partition(labels: np.ndarray, num_clients: int, missing: int,
                         seed: int = 0) -> List[np.ndarray]:
    """Paper's φ scheme (ImageNet-100): each client LACKS ``missing``
    classes; equal volume from each present class."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    idx_by_class = {c: list(rng.permutation(np.where(labels == c)[0])) for c in classes}
    n_per_client = len(labels) // num_clients
    out = []
    for n in range(num_clients):
        lacking = set(rng.choice(classes, size=missing, replace=False)) if missing else set()
        present = [c for c in classes if c not in lacking]
        take = []
        per_c = max(1, n_per_client // len(present))
        for c in present:
            pool = idx_by_class[c]
            take += [pool.pop() for _ in range(min(per_c, len(pool)))]
        out.append(np.asarray(take[:n_per_client], np.int64))
    return out


def iid_partition(labels: np.ndarray, num_clients: int,
                  seed: int = 0) -> List[np.ndarray]:
    """Uniform shuffle-and-split; covers every index exactly once."""
    rng = np.random.default_rng(seed)
    return [np.asarray(s, np.int64)
            for s in np.array_split(rng.permutation(len(labels)), num_clients)]


def natural_partition(num_samples: int, num_clients: int,
                      natural_ids: Optional[np.ndarray] = None) -> List[np.ndarray]:
    """Group-by-owner partition (Shakespeare speakers; LEAF-style).

    With per-sample ``natural_ids``, whole groups are greedily packed
    onto the least-loaded client (deterministic: groups visited largest
    first, ties by id).  Without ids — the synthetic corpus — it falls
    back to contiguous ``np.array_split`` shards, byte-identical to the
    pre-registry text path.  Either way every index is covered exactly
    once.
    """
    if natural_ids is None:
        return [np.asarray(s, np.int64)
                for s in np.array_split(np.arange(num_samples), num_clients)]
    ids = np.asarray(natural_ids)
    if len(ids) != num_samples:
        raise ValueError(
            f"natural_ids has {len(ids)} entries for {num_samples} samples")
    uniq, counts = np.unique(ids, return_counts=True)
    if len(uniq) < num_clients:
        # fewer owners than clients: group identity can't be preserved
        return [np.asarray(s, np.int64)
                for s in np.array_split(np.arange(num_samples), num_clients)]
    order = np.lexsort((uniq, -counts))  # largest group first, ties by id
    loads = np.zeros(num_clients, np.int64)
    assigned: List[List[np.ndarray]] = [[] for _ in range(num_clients)]
    for g in order:
        client = int(np.argmin(loads))
        members = np.where(ids == uniq[g])[0]
        assigned[client].append(members)
        loads[client] += len(members)
    return [np.sort(np.concatenate(a)).astype(np.int64) if a
            else np.empty(0, np.int64) for a in assigned]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

Partitioner = Callable[..., List[np.ndarray]]

PARTITIONERS: Dict[str, Partitioner] = {}


def register_partitioner(name: str):
    def deco(fn: Partitioner):
        PARTITIONERS[name] = fn
        return fn

    return deco


@register_partitioner("dirichlet")
def _dirichlet(labels, num_clients, seed=0, *, metadata=None, gamma_pct=40.0):
    return dirichlet_partition(labels, num_clients, gamma_pct, seed)


@register_partitioner("class_skew")
def _class_skew(labels, num_clients, seed=0, *, metadata=None, missing=2):
    return class_skew_partition(labels, num_clients, missing, seed)


@register_partitioner("iid")
def _iid(labels, num_clients, seed=0, *, metadata=None):
    return iid_partition(labels, num_clients, seed)


@register_partitioner("natural")
def _natural(labels, num_clients, seed=0, *, metadata=None):
    ids = (metadata or {}).get("natural_ids")
    return natural_partition(len(labels), num_clients, ids)


def partition_dataset(dataset, partitioner: str, num_clients: int,
                      seed: int = 0, **kw) -> List[np.ndarray]:
    """Split a :class:`~repro.data.base.FederatedDataset`'s train split.

    Label-based partitioners read ``dataset.partition_labels`` (the
    train labels for image tasks, speaker ids / first tokens for text),
    so every registered dataset composes with every partitioner.
    """
    if partitioner not in PARTITIONERS:
        raise KeyError(
            f"unknown partitioner {partitioner!r}; have {sorted(PARTITIONERS)}")
    return PARTITIONERS[partitioner](
        dataset.partition_labels, num_clients, seed,
        metadata=dataset.metadata, **kw)
