from repro.data.synthetic import (  # noqa: F401
    SyntheticImageTask,
    SyntheticTextTask,
    dirichlet_partition,
    class_skew_partition,
    lm_batches,
)
