"""Federated dataset subsystem.

Three registries + a streaming pipeline (docs/DATA.md):

  * datasets      — ``register_dataset`` / ``load_dataset``: synthetic
                    stand-ins and real-format loaders (CIFAR-10 binary/
                    npz, Shakespeare text) behind one
                    :class:`FederatedDataset` container with named
                    splits; loaders fall back to deterministic synthetic
                    generation when files are absent and cache outputs
                    as npz keyed by (task, seed, preprocessing).
  * partitioners  — ``register_partitioner`` / ``partition_dataset``:
                    the paper's Γ / φ schemes plus iid and natural
                    (per-speaker) splits, composable with any dataset.
  * streaming     — :class:`ClientDataLoader` / :class:`ShardView`: per-
                    client minibatch streams under the engine's host RNG
                    contract, gathered lazily from one global array and
                    prefetched ahead of the device step.
"""

from repro.data.base import (  # noqa: F401
    DATASETS,
    FederatedDataset,
    load_dataset,
    register_dataset,
)
from repro.data.partition import (  # noqa: F401
    PARTITIONERS,
    class_skew_partition,
    dirichlet_partition,
    iid_partition,
    natural_partition,
    partition_dataset,
    register_partitioner,
)
from repro.data.streaming import (  # noqa: F401
    ClientDataLoader,
    ShardView,
    VirtualShardList,
    make_shards,
    round_batch_indices,
    stack_client_shards,
)
from repro.data.synthetic import (  # noqa: F401
    SyntheticImageTask,
    SyntheticTextTask,
    lm_batches,
)
from repro.data import cifar10 as _cifar10  # noqa: F401  (registers "cifar10")
from repro.data import shakespeare as _shakespeare  # noqa: F401  ("shakespeare")
