"""Streaming client pipelines: shard views, the host RNG contract, and
a prefetching per-client batch loader.

Instead of materializing every client's full shard
(``x_train[part]`` copies — O(dataset) extra host memory per setup),
:class:`ShardView` keeps ONE global array per split plus per-client
index vectors and gathers only the minibatches a round actually
touches.  Views quack like arrays for everything the FL runtime does
with a shard (``len``, fancy indexing, iteration-free gathers), so the
sequential trainer, the legacy runners and ``local_train`` consume them
unchanged.

:class:`ClientDataLoader` owns the *host RNG contract* shared by every
training backend: client ``n`` in round ``r`` draws from
``np.random.default_rng((seed, r, n))`` — ``tau`` training-batch index
draws of size ``batch``, then 3 estimate-batch draws.  Batches prepared
through the loader are therefore byte-identical to both the sequential
loop and the pre-subsystem cohort path.  ``prefetch`` stages the next
cohort group's host gather on a background thread (numpy only — no jax
calls off the main thread) while the device runs the current group.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Iterable, Iterator, Optional, Sequence, Tuple

import numpy as np


class ShardView:
    """Lazy per-client view: ``view[idx] == base[indices[idx]]``."""

    __slots__ = ("base", "indices")

    def __init__(self, base: np.ndarray, indices: np.ndarray):
        self.base = base
        self.indices = np.asarray(indices, np.int64)

    def __len__(self) -> int:
        return len(self.indices)

    def __getitem__(self, idx):
        return self.base[self.indices[idx]]

    @property
    def shape(self):
        return (len(self.indices),) + self.base.shape[1:]

    @property
    def dtype(self):
        return self.base.dtype

    @property
    def ndim(self) -> int:
        return self.base.ndim

    def materialize(self) -> np.ndarray:
        return self.base[self.indices]

    def __array__(self, dtype=None):
        out = self.materialize()
        return out.astype(dtype) if dtype is not None else out


class VirtualShardList:
    """Population-sized shard sequence backed by a pure index function.

    ``parts[n]`` builds a :class:`ShardView` from ``index_fn(n)`` on
    demand, so a 10^6-client partition costs nothing until a client is
    actually sampled — the O(cohort) stand-in for a materialized
    ``num_clients``-long partition list.  ``index_fn`` must be pure in
    ``n`` (repro.fl.population.VirtualPartition), which is what keeps
    shards identical across processes and independent of the population
    size or query order.  ``registry`` optionally carries the
    :class:`~repro.fl.population.PopulationRegistry` the engine binds
    its heterogeneity model and participation bookkeeping to.
    """

    virtual = True

    def __init__(self, base: np.ndarray, index_fn: Callable[[int], np.ndarray],
                 size: int, registry=None):
        self.base = base
        self.index_fn = index_fn
        self.size = size
        self.registry = registry

    def __len__(self) -> int:
        return self.size

    def __getitem__(self, n) -> ShardView:
        n = int(n)
        if not 0 <= n < self.size:
            raise IndexError(n)
        return ShardView(self.base, self.index_fn(n))

    def __iter__(self):
        return (self[n] for n in range(self.size))


def make_shards(x: np.ndarray, y: np.ndarray, parts,
                streaming: bool = True):
    """Per-client (parts_x, parts_y) from global arrays + index lists.

    ``streaming=True`` returns :class:`ShardView`s over the single
    global array; ``streaming=False`` materializes the legacy per-client
    copies.  Gathered minibatches are byte-identical either way.

    A *lazy* partition — anything exposing ``indices(n)`` and ``len``,
    e.g. :class:`repro.fl.population.VirtualPartition` — yields
    :class:`VirtualShardList`s instead: no per-client index arrays are
    materialized, each sampled client's shard is derived on demand.
    """
    if callable(getattr(parts, "indices", None)):
        size = len(parts)
        return (VirtualShardList(x, parts.indices, size),
                VirtualShardList(y, parts.indices, size))
    if streaming:
        return ([ShardView(x, p) for p in parts],
                [ShardView(y, p) for p in parts])
    return [x[p] for p in parts], [y[p] for p in parts]


def round_batch_indices(seed: int, rnd: int, n: int, num_samples: int,
                        tau: int, batch_size: int, estimate: bool,
                        tau_pad: Optional[int] = None
                        ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """The engine's host RNG contract, in one place.

    Returns ``(idx, est_idx)`` with ``idx`` of shape
    ``(tau_pad or tau, batch_size)`` (padding steps repeat the last real
    batch — they are masked no-ops in the cohort step) and ``est_idx``
    of shape ``(3, batch_size)`` or None.  Draw order matches
    ``local_train``: tau training draws, then 3 estimate draws.
    """
    rng = np.random.default_rng((seed, rnd, n))
    idx = np.stack([rng.integers(0, num_samples, batch_size)
                    for _ in range(tau)])
    pad = (tau_pad or tau) - tau
    if pad > 0:
        idx = np.concatenate([idx, np.broadcast_to(idx[-1], (pad, batch_size))])
    est_idx = None
    if estimate:
        est_idx = np.stack([rng.integers(0, num_samples, batch_size)
                            for _ in range(3)])
    return idx, est_idx


def stack_client_shards(per_client: Sequence[np.ndarray], chunks: int,
                        step_leading: bool = False):
    """Stack per-client batch arrays into ``chunks`` contiguous groups.

    The cohort trainer's device mesh wants *per-device host shards*, not
    one monolithic stacked batch: each chunk is stacked separately (and
    stays a separate numpy array) so the prefetch thread hands the main
    thread exactly the pieces ``device_put`` ships, one per device —
    the full cohort batch never exists contiguously on the host.

    ``step_leading=True`` moves the per-client step axis in front of the
    client axis (``(C/chunks, tau, ...) -> (tau, C/chunks, ...)``), the
    layout the compiled cohort step consumes.  ``chunks=1`` reproduces
    the single-device monolithic stack bitwise.
    """
    n = len(per_client)
    if n % chunks:
        raise ValueError(f"{n} clients not divisible into {chunks} chunks")
    per = n // chunks
    out = []
    for c in range(chunks):
        stk = np.stack(per_client[c * per:(c + 1) * per])
        out.append(np.moveaxis(stk, 0, 1) if step_leading else stk)
    return out


class ClientDataLoader:
    """Per-client minibatch streams over (possibly lazy) shards.

    One instance serves a whole run: the engine binds it to its
    partition set at construction and trainers call :meth:`draw_round`
    for host batches / :meth:`prefetch` to pipeline host gathers ahead
    of device steps.
    """

    def __init__(self, parts_x: Sequence, parts_y: Sequence,
                 prefetch_depth: int = 2):
        if len(parts_x) != len(parts_y):
            raise ValueError(f"{len(parts_x)} x-shards vs {len(parts_y)} y")
        self.parts_x, self.parts_y = parts_x, parts_y
        self.prefetch_depth = max(1, prefetch_depth)
        # telemetry recorder (repro.obs); the engine runner rebinds this
        # to its live recorder — the default no-op keeps standalone
        # loaders uninstrumented at zero cost
        from repro.obs.recorder import NOOP
        self.obs = NOOP
        # live prefetch workers: (stop event, thread) pairs, so close()
        # can release them deterministically even when a round body died
        # before its generator's finally ran
        self._workers: list = []
        self._workers_lock = threading.Lock()

    def close(self) -> None:
        """Release every background prefetch worker this loader started.

        Safe to call repeatedly.  Without it, a generator abandoned by an
        exception in the round body only stops its worker when the GC
        collects the generator — until then the daemon thread sits
        blocked on its bounded queue.
        """
        with self._workers_lock:
            workers, self._workers = self._workers, []
        for stop, _ in workers:
            stop.set()
        for _, t in workers:
            t.join(timeout=5.0)

    def __enter__(self) -> "ClientDataLoader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @classmethod
    def from_dataset(cls, dataset, parts: Sequence[np.ndarray],
                     streaming: bool = True, **kw) -> "ClientDataLoader":
        px, py = make_shards(dataset.x, dataset.y, parts, streaming)
        return cls(px, py, **kw)

    @property
    def num_clients(self) -> int:
        return len(self.parts_x)

    def num_samples(self, n: int) -> int:
        return len(self.parts_y[n])

    def shard(self, n: int):
        return self.parts_x[n], self.parts_y[n]

    def gather(self, n: int, idx: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Host batch arrays for arbitrary (possibly 2-D) sample indices."""
        x, y = self.parts_x[n], self.parts_y[n]
        return x[idx], y[idx]

    def draw_round(self, n: int, *, seed: int, rnd: int, tau: int,
                   batch_size: int, estimate: bool,
                   tau_pad: Optional[int] = None):
        """(xs, ys, est) for one client-round under the RNG contract.

        ``xs``/``ys`` lead with the (padded) step axis; ``est`` is the
        ``(3, batch, ...)`` estimate-batch pair or None.
        """
        idx, est_idx = round_batch_indices(
            seed, rnd, n, self.num_samples(n), tau, batch_size, estimate,
            tau_pad)
        xs, ys = self.gather(n, idx)
        est = self.gather(n, est_idx) if est_idx is not None else None
        return xs, ys, est

    def prefetch(self, items: Iterable[Any],
                 fn: Callable[[Any], Any]) -> Iterator[Any]:
        """Yield ``fn(item)`` in order, computing up to ``prefetch_depth``
        items ahead on a background thread.

        ``fn`` must be host-only (numpy): it runs off the main thread so
        the device step of group *g* overlaps the gather of *g+1*.
        """
        items = list(items)
        if len(items) <= 1:  # nothing to overlap
            for it in items:
                yield fn(it)
            return
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch_depth)
        stop = threading.Event()
        _END, _ERR = object(), object()

        def put(obj) -> bool:
            """Bounded put that gives up when the consumer is gone."""
            while not stop.is_set():
                try:
                    q.put(obj, timeout=0.1)
                    return True
                except queue.Full:
                    continue
            return False

        def worker():
            try:
                for it in items:
                    if stop.is_set() or not put(fn(it)):
                        return
                put(_END)
            except BaseException as e:  # surfaced in the consumer
                put((_ERR, e))

        t = threading.Thread(target=worker, daemon=True,
                             name="client-data-prefetch")
        with self._workers_lock:
            self._workers.append((stop, t))
        t.start()
        obs = self.obs
        try:
            while True:
                if obs.enabled:
                    # stall = consumer time blocked on the staging thread;
                    # depth sampled just before the blocking get
                    obs.observe("data.prefetch_depth", q.qsize())
                    t0 = time.perf_counter()
                    got = q.get()
                    obs.observe("data.prefetch_stall_s",
                                time.perf_counter() - t0)
                else:
                    got = q.get()
                if got is _END:
                    break
                if isinstance(got, tuple) and len(got) == 2 \
                        and got[0] is _ERR:
                    raise got[1]
                yield got
        finally:
            # consumer done or abandoned mid-stream (downstream error /
            # closed generator): release the worker, don't leak it or
            # the staged batches it is blocked on
            stop.set()
            while not q.empty():
                try:
                    q.get_nowait()
                except queue.Empty:
                    break
            t.join(timeout=5.0)
            with self._workers_lock:
                self._workers = [(s, th) for s, th in self._workers
                                 if th is not t and th.is_alive()]
