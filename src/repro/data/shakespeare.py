"""Shakespeare-style char-LM task: text -> token sequences with
per-speaker natural partitions.

Real data is a plain-text corpus of plays under ``data_root`` (a
``shakespeare.txt``, or any single ``*.txt``) in the usual
tinyshakespeare / LEAF layout where a speaker turn starts with a
``Speaker Name:`` line::

    First Citizen:
    Before we proceed any further, hear me speak.

The parser attributes each speech to its speaker, builds a character
vocabulary over the whole corpus, and windows every speaker's stream
into ``seq_len + 1`` chunks (inputs = ``[:-1]``, next-char labels =
``[1:]``).  Per-sequence speaker ids land in ``metadata["natural_ids"]``
so the ``natural`` partitioner reproduces the paper's
one-client-per-speaker regime.

Without files the loader generates a deterministic synthetic corpus:
each synthetic speaker samples from its own sparse bigram transition
table (the base table with rotated columns), so the natural partition
is genuinely non-IID while CI stays offline.  Outputs are cached as npz
keyed by (task, seed, preprocessing) — see :mod:`repro.data.cache`.
"""

from __future__ import annotations

import hashlib
import re
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.data.base import FederatedDataset, register_dataset
from repro.data.cache import cached

_SPEAKER_RE = re.compile(r"^([A-Z][A-Za-z .'-]{0,40}):\s*$")


def _find_corpus(root: Path) -> Optional[Path]:
    named = root / "shakespeare.txt"
    if named.exists():
        return named
    txts = sorted(root.glob("*.txt"))
    return txts[0] if txts else None


def _parse_speakers(text: str) -> List[Tuple[str, str]]:
    """(speaker, speech) turns; prologue text before any speaker is dropped."""
    turns: List[Tuple[str, str]] = []
    speaker, lines = None, []
    for line in text.splitlines():
        m = _SPEAKER_RE.match(line.strip())
        if m:
            if speaker and lines:
                turns.append((speaker, "\n".join(lines)))
            speaker, lines = m.group(1), []
        elif speaker is not None:
            if line.strip():
                lines.append(line.strip())
    if speaker and lines:
        turns.append((speaker, "\n".join(lines)))
    return turns


def _window(stream: np.ndarray, seq_len: int) -> np.ndarray:
    """Non-overlapping (n, seq_len+1) windows of an encoded char stream."""
    step = seq_len + 1
    n = len(stream) // step
    return stream[: n * step].reshape(n, step) if n else \
        np.empty((0, step), np.int32)


def _from_text(text: str, seq_len: int, min_sequences: int,
               holdout: float) -> Dict[str, np.ndarray]:
    chars = sorted(set(text))
    lut = np.zeros(1 << 21, np.int32)  # direct codepoint -> id table
    for i, c in enumerate(chars):
        lut[ord(c)] = i
    turns = _parse_speakers(text)
    by_speaker: Dict[str, List[str]] = {}
    for speaker, speech in turns:
        by_speaker.setdefault(speaker, []).append(speech)

    train, test, ids = [], [], []
    speaker_idx = 0
    for speaker in sorted(by_speaker):
        stream = "\n".join(by_speaker[speaker])
        codes = lut[np.frombuffer(stream.encode("utf-32-le"), np.uint32)]
        seqs = _window(codes.astype(np.int32), seq_len)
        if len(seqs) < min_sequences:
            continue
        n_te = max(1, int(round(holdout * len(seqs)))) if len(seqs) > 1 else 0
        split = len(seqs) - n_te
        train.append(seqs[:split])
        test.append(seqs[split:])
        ids.append(np.full(split, speaker_idx, np.int32))
        speaker_idx += 1
    if not train:
        raise ValueError("no speaker produced enough sequences; "
                         "check the corpus format / seq_len")
    return {"train": np.concatenate(train), "test": np.concatenate(test),
            "natural_ids": np.concatenate(ids),
            "vocab_chars": np.frombuffer(
                "".join(chars).encode("utf-32-le"), np.uint32)}


def _synthetic_fallback(seed: int, seq_len: int, vocab: int,
                        num_speakers: int, train_size: int,
                        test_size: int) -> Dict[str, np.ndarray]:
    """Per-speaker sparse-bigram sequences (vectorized, deterministic)."""
    rng = np.random.default_rng(seed)
    logits = rng.normal(0, 1, (vocab, vocab))
    top = np.argsort(-logits, axis=1)[:, :4]
    base = np.zeros_like(logits)
    rows = np.arange(vocab)[:, None]
    base[rows, top] = [0.55, 0.25, 0.15, 0.05]
    # speaker s speaks from the base dynamics with rotated columns:
    # same sparsity/entropy, different transitions -> natural non-IID
    tables = np.stack([np.roll(base, s, axis=1) for s in range(num_speakers)])
    ctabs = np.cumsum(tables, axis=-1)

    def gen(n_per_speaker: int) -> Tuple[np.ndarray, np.ndarray]:
        n = n_per_speaker * num_speakers
        ids = np.repeat(np.arange(num_speakers, dtype=np.int32),
                        n_per_speaker)
        seqs = np.zeros((n, seq_len + 1), np.int32)
        state = rng.integers(0, vocab, n)
        seqs[:, 0] = state
        for t in range(1, seq_len + 1):
            u = rng.random(n)
            cum = ctabs[ids, state]  # (n, vocab) cumulative rows
            state = np.argmax(u[:, None] < cum, axis=1).astype(np.int64)
            seqs[:, t] = state
        return seqs, ids

    n_tr = max(1, train_size // num_speakers)
    n_te = max(1, test_size // num_speakers)
    train, ids = gen(n_tr)
    test, _ = gen(n_te)
    return {"train": train, "test": test, "natural_ids": ids,
            "vocab_chars": np.arange(vocab, dtype=np.uint32)}


@register_dataset("shakespeare")
def load_shakespeare(data_root=None, cache_dir=None, seed: int = 0,
                     seq_len: int = 32, vocab: int = 64,
                     num_speakers: int = 16, train_size: int = 2000,
                     test_size: int = 400, min_sequences: int = 2,
                     holdout: float = 0.1) -> FederatedDataset:
    """Char-LM corpus (or its stand-in) as a FederatedDataset.

    ``vocab``/``num_speakers``/``train_size``/``test_size`` only shape
    the synthetic fallback; with real files the vocabulary and speaker
    set come from the corpus.
    """
    root = Path(data_root) if data_root else None
    corpus = _find_corpus(root) if root is not None else None
    if corpus is not None:
        text = corpus.read_text(encoding="utf-8", errors="ignore")
        fields = dict(sha1=hashlib.sha1(text.encode()).hexdigest(),
                      seq_len=seq_len, min_sequences=min_sequences,
                      holdout=holdout)
        arrays, _ = cached(
            "shakespeare", fields,
            lambda: _from_text(text, seq_len, min_sequences, holdout),
            cache_dir)
        source = "files"
    else:
        fields = dict(seed=seed, seq_len=seq_len, vocab=vocab,
                      num_speakers=num_speakers, train_size=train_size,
                      test_size=test_size)
        arrays, _ = cached(
            "shakespeare", fields,
            lambda: _synthetic_fallback(seed, seq_len, vocab, num_speakers,
                                        train_size, test_size),
            cache_dir)
        source = "synthetic"
    train, test = arrays["train"], arrays["test"]
    ids = arrays["natural_ids"]
    vocab_size = len(arrays["vocab_chars"])
    return FederatedDataset(
        name="shakespeare",
        splits={"train": (train[:, :-1], train[:, 1:]),
                "test": (test[:, :-1], test[:, 1:])},
        metadata={"modality": "text", "vocab": vocab_size,
                  "seq_len": train.shape[1] - 1, "natural_ids": ids,
                  "partition_labels": ids, "num_speakers": int(ids.max()) + 1,
                  "source": source, "seed": seed},
    )
