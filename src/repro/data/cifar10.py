"""CIFAR-10-format image task: binary/npz reader + deterministic fallback.

Real data is read from ``data_root`` in either of two offline formats:

  * the canonical binary batches (``data_batch_{1..5}.bin`` +
    ``test_batch.bin``, 3073-byte records: 1 label byte + 3072
    channel-major pixel bytes), i.e. an extracted
    ``cifar-10-batches-bin/`` directory, or
  * a single ``cifar10.npz`` with ``x_train/y_train/x_test/y_test``
    (pixels uint8 HWC or float).

When neither is present the loader generates a *deterministic synthetic
fallback* with CIFAR shapes — class-conditional Gaussian images around
fixed random prototypes — so CI and the examples never touch the
network.  Which path was taken is recorded in
``metadata["source"]`` (``"files"`` / ``"synthetic"``).

Preprocessing (scale to [0,1], per-channel standardization with the
usual CIFAR-10 statistics) and the fallback generation are both cached
as npz keyed by (task, seed, preprocessing); see
:mod:`repro.data.cache`.
"""

from __future__ import annotations

from pathlib import Path
from typing import Dict, Optional

import numpy as np

from repro.data.base import FederatedDataset, register_dataset
from repro.data.cache import cached

HW = 32
CHANNELS = 3
NUM_CLASSES = 10
_RECORD = 1 + HW * HW * CHANNELS
# standard CIFAR-10 channel statistics (of the [0,1]-scaled pixels)
_MEAN = np.array([0.4914, 0.4822, 0.4465], np.float32)
_STD = np.array([0.2470, 0.2435, 0.2616], np.float32)


def _binary_files(root: Path) -> Optional[list]:
    """The binary-batch file set, or None when the layout is absent.

    A *partial* set (some of the five train batches missing) is an
    error, not a silent fall-through: training on a fraction of the
    data labeled source="files" would quietly diverge from the paper.
    """
    train = [root / f"data_batch_{i}.bin" for i in range(1, 6)]
    test = root / "test_batch.bin"
    present = [p for p in train if p.exists()]
    if not present and not test.exists():
        return None
    missing = [p.name for p in train if not p.exists()]
    if not test.exists():
        missing.append(test.name)
    if missing:
        raise FileNotFoundError(
            f"incomplete CIFAR-10 binary set under {root}: missing "
            f"{missing}")
    return train + [test]


def _read_binary(files: list) -> Dict[str, np.ndarray]:
    def parse(path: Path):
        raw = np.frombuffer(path.read_bytes(), np.uint8)
        if len(raw) % _RECORD:
            raise ValueError(f"{path} is not a CIFAR-10 binary batch "
                             f"({len(raw)} bytes % {_RECORD} != 0)")
        rec = raw.reshape(-1, _RECORD)
        y = rec[:, 0].astype(np.int32)
        # channel-major (C,H,W) bytes -> HWC
        x = rec[:, 1:].reshape(-1, CHANNELS, HW, HW).transpose(0, 2, 3, 1)
        return x, y

    xs, ys = zip(*(parse(p) for p in files[:-1]))
    x_test, y_test = parse(files[-1])
    return {"x_train": np.concatenate(xs), "y_train": np.concatenate(ys),
            "x_test": x_test, "y_test": y_test}


def _read_npz(root: Path) -> Optional[Dict[str, np.ndarray]]:
    path = root / "cifar10.npz"
    if not path.exists():
        return None
    with np.load(path, allow_pickle=False) as z:
        return {k: z[k] for k in ("x_train", "y_train", "x_test", "y_test")}


def _normalize(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.float32)
    if x.max() > 2.0:  # raw uint8 pixels
        x = x / 255.0
    return (x - _MEAN) / _STD


def _synthetic_fallback(seed: int, train_size: int, test_size: int,
                        hw: int, num_classes: int) -> Dict[str, np.ndarray]:
    """Class-conditional Gaussian images around fixed prototypes.

    Fully vectorized and keyed only on the arguments, so two processes
    with the same seed produce byte-identical arrays.
    """
    rng = np.random.default_rng(seed)
    d = hw * hw * CHANNELS
    protos = rng.normal(0, 1, (num_classes, d)).astype(np.float32)

    def sample(n):
        y = np.arange(n, dtype=np.int32) % num_classes
        x = protos[y] + 1.2 * rng.normal(0, 1, (n, d)).astype(np.float32)
        perm = rng.permutation(n)
        return (x[perm].reshape(n, hw, hw, CHANNELS).astype(np.float32),
                y[perm])

    x_train, y_train = sample(train_size)
    x_test, y_test = sample(test_size)
    return {"x_train": x_train, "y_train": y_train,
            "x_test": x_test, "y_test": y_test}


@register_dataset("cifar10")
def load_cifar10(data_root=None, cache_dir=None, seed: int = 0,
                 normalize: bool = True, train_size: int = 2000,
                 test_size: int = 400, hw: int = HW,
                 num_classes: int = NUM_CLASSES) -> FederatedDataset:
    """CIFAR-10 (or its deterministic stand-in) as a FederatedDataset.

    ``train_size``/``test_size``/``hw``/``num_classes`` only shape the
    synthetic fallback; real files always load in full at 32x32.
    """
    root = Path(data_root) if data_root else None
    source = "synthetic"
    if root is not None:
        bin_files = _binary_files(root)
        npz_file = root / "cifar10.npz" if (root / "cifar10.npz").exists() \
            else None
        src_files = bin_files or ([npz_file] if npz_file else None)
        if src_files is not None:
            source = "files"
            hw, num_classes = HW, NUM_CLASSES

            def build():
                raw = _read_binary(bin_files) if bin_files \
                    else _read_npz(root)
                x_tr = _normalize(raw["x_train"]) if normalize \
                    else raw["x_train"].astype(np.float32)
                x_te = _normalize(raw["x_test"]) if normalize \
                    else raw["x_test"].astype(np.float32)
                return {"x_train": x_tr,
                        "y_train": raw["y_train"].astype(np.int32),
                        "x_test": x_te,
                        "y_test": raw["y_test"].astype(np.int32)}

            # fingerprint the source files (size + mtime) so swapping
            # data under the same root invalidates the cache, and the
            # parse itself only runs on a miss
            stats = [(p.name, p.stat().st_size, p.stat().st_mtime_ns)
                     for p in src_files]
            fields = dict(normalize=normalize, source=str(root),
                          files=stats)
            arrays, _ = cached("cifar10", fields, build, cache_dir)
    if source == "synthetic":
        fields = dict(seed=seed, normalize=normalize, train_size=train_size,
                      test_size=test_size, hw=hw, num_classes=num_classes)
        arrays, _ = cached(
            "cifar10", fields,
            lambda: _synthetic_fallback(seed, train_size, test_size, hw,
                                        num_classes),
            cache_dir)
    return FederatedDataset(
        name="cifar10",
        splits={"train": (arrays["x_train"], arrays["y_train"]),
                "test": (arrays["x_test"], arrays["y_test"])},
        metadata={"modality": "image", "num_classes": num_classes,
                  "hw": arrays["x_train"].shape[1], "channels": CHANNELS,
                  "source": source, "seed": seed},
    )
