"""Synthetic datasets + Non-IID partitioners.

The container is offline, so CIFAR-10 / ImageNet-100 / Shakespeare are
replaced by *learnable* synthetic stand-ins with the same shapes and the
same Non-IID partition machinery the paper uses:

  * SyntheticImageTask — images from class-conditional Gaussians passed
    through a fixed random "teacher" projection: linearly separable enough
    to show convergence curves, noisy enough to be non-trivial.
  * SyntheticTextTask — next-character prediction from a fixed random
    n-gram transition table (Shakespeare stand-in).
  * dirichlet / class-skew partitioners — the paper's Γ / φ schemes.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np


@dataclasses.dataclass
class SyntheticImageTask:
    num_classes: int = 10
    hw: int = 8
    channels: int = 3
    train_per_class: int = 200
    test_per_class: int = 50
    noise: float = 1.2
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        d = self.hw * self.hw * self.channels
        self.prototypes = rng.normal(0, 1, (self.num_classes, d)).astype(np.float32)
        self.x_train, self.y_train = self._sample(rng, self.train_per_class)
        self.x_test, self.y_test = self._sample(rng, self.test_per_class)

    def _sample(self, rng, per_class):
        xs, ys = [], []
        d = self.hw * self.hw * self.channels
        for c in range(self.num_classes):
            x = self.prototypes[c][None] + self.noise * rng.normal(0, 1, (per_class, d))
            xs.append(x.astype(np.float32))
            ys.append(np.full(per_class, c, np.int32))
        x = np.concatenate(xs).reshape(-1, self.hw, self.hw, self.channels)
        y = np.concatenate(ys)
        perm = rng.permutation(len(y))
        return x[perm], y[perm]


@dataclasses.dataclass
class SyntheticTextTask:
    vocab: int = 64
    seq_len: int = 32
    num_train: int = 2000
    num_test: int = 400
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # fixed sparse bigram transition table -> predictable sequences
        logits = rng.normal(0, 1, (self.vocab, self.vocab))
        top = np.argsort(-logits, axis=1)[:, :4]
        probs = np.zeros_like(logits)
        for v in range(self.vocab):
            probs[v, top[v]] = [0.55, 0.25, 0.15, 0.05]
        self.table = probs

        def gen(n):
            seqs = np.zeros((n, self.seq_len + 1), np.int32)
            state = rng.integers(0, self.vocab, n)
            seqs[:, 0] = state
            for t in range(1, self.seq_len + 1):
                nxt = np.array([
                    rng.choice(self.vocab, p=self.table[s]) for s in state
                ])
                seqs[:, t] = nxt
                state = nxt
            return seqs

        self.train = gen(self.num_train)
        self.test = gen(self.num_test)


def dirichlet_partition(labels: np.ndarray, num_clients: int, gamma_pct: float,
                        seed: int = 0) -> List[np.ndarray]:
    """Paper's Γ scheme: Γ% of each client's samples from one class, the
    rest spread evenly.  Γ=1/num_classes*100 ~ IID."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    idx_by_class = {c: list(rng.permutation(np.where(labels == c)[0])) for c in classes}
    n_per_client = len(labels) // num_clients
    frac = gamma_pct / 100.0
    out = []
    for n in range(num_clients):
        main_c = classes[n % len(classes)]
        want_main = int(round(frac * n_per_client))
        take = []
        pool = idx_by_class[main_c]
        take += [pool.pop() for _ in range(min(want_main, len(pool)))]
        rest = n_per_client - len(take)
        others = [c for c in classes]
        for i in range(rest):
            c = others[i % len(others)]
            pool = idx_by_class[c]
            if pool:
                take.append(pool.pop())
        out.append(np.asarray(take, np.int64))
    return out


def class_skew_partition(labels: np.ndarray, num_clients: int, missing: int,
                         seed: int = 0) -> List[np.ndarray]:
    """Paper's φ scheme (ImageNet-100): each client LACKS ``missing``
    classes; equal volume from each present class."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    idx_by_class = {c: list(rng.permutation(np.where(labels == c)[0])) for c in classes}
    n_per_client = len(labels) // num_clients
    out = []
    for n in range(num_clients):
        lacking = set(rng.choice(classes, size=missing, replace=False)) if missing else set()
        present = [c for c in classes if c not in lacking]
        take = []
        per_c = max(1, n_per_client // len(present))
        for c in present:
            pool = idx_by_class[c]
            take += [pool.pop() for _ in range(min(per_c, len(pool)))]
        out.append(np.asarray(take[:n_per_client], np.int64))
    return out


def lm_batches(seqs: np.ndarray, batch: int, rng: np.random.Generator):
    """Yield (tokens, labels) next-token batches from (N, L+1) sequences."""
    idx = rng.integers(0, len(seqs), batch)
    chunk = seqs[idx]
    return chunk[:, :-1], chunk[:, 1:]
