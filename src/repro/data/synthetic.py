"""Synthetic in-memory tasks (the offline stand-ins).

  * SyntheticImageTask — images from class-conditional Gaussians passed
    through a fixed random "teacher" projection: linearly separable enough
    to show convergence curves, noisy enough to be non-trivial.
  * SyntheticTextTask — next-character prediction from a fixed random
    n-gram transition table (Shakespeare stand-in).

Both are registered in the dataset registry (``synthetic_image`` /
``synthetic_text``) so they compose with the same partitioner registry
and streaming pipelines as the real-format loaders in
:mod:`repro.data.cifar10` / :mod:`repro.data.shakespeare`.  The Γ / φ
partitioners that used to live here moved to :mod:`repro.data.partition`
(re-exported below for compatibility).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.data.base import FederatedDataset, register_dataset
from repro.data.partition import (  # noqa: F401  (back-compat re-export)
    class_skew_partition,
    dirichlet_partition,
)


@dataclasses.dataclass
class SyntheticImageTask:
    num_classes: int = 10
    hw: int = 8
    channels: int = 3
    train_per_class: int = 200
    test_per_class: int = 50
    noise: float = 1.2
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        d = self.hw * self.hw * self.channels
        self.prototypes = rng.normal(0, 1, (self.num_classes, d)).astype(np.float32)
        self.x_train, self.y_train = self._sample(rng, self.train_per_class)
        self.x_test, self.y_test = self._sample(rng, self.test_per_class)

    def _sample(self, rng, per_class):
        xs, ys = [], []
        d = self.hw * self.hw * self.channels
        for c in range(self.num_classes):
            x = self.prototypes[c][None] + self.noise * rng.normal(0, 1, (per_class, d))
            xs.append(x.astype(np.float32))
            ys.append(np.full(per_class, c, np.int32))
        x = np.concatenate(xs).reshape(-1, self.hw, self.hw, self.channels)
        y = np.concatenate(ys)
        perm = rng.permutation(len(y))
        return x[perm], y[perm]


@dataclasses.dataclass
class SyntheticTextTask:
    vocab: int = 64
    seq_len: int = 32
    num_train: int = 2000
    num_test: int = 400
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # fixed sparse bigram transition table -> predictable sequences
        logits = rng.normal(0, 1, (self.vocab, self.vocab))
        top = np.argsort(-logits, axis=1)[:, :4]
        probs = np.zeros_like(logits)
        for v in range(self.vocab):
            probs[v, top[v]] = [0.55, 0.25, 0.15, 0.05]
        self.table = probs

        def gen(n):
            seqs = np.zeros((n, self.seq_len + 1), np.int32)
            state = rng.integers(0, self.vocab, n)
            seqs[:, 0] = state
            for t in range(1, self.seq_len + 1):
                nxt = np.array([
                    rng.choice(self.vocab, p=self.table[s]) for s in state
                ])
                seqs[:, t] = nxt
                state = nxt
            return seqs

        self.train = gen(self.num_train)
        self.test = gen(self.num_test)


def lm_batches(seqs: np.ndarray, batch: int, rng: np.random.Generator):
    """Yield (tokens, labels) next-token batches from (N, L+1) sequences."""
    idx = rng.integers(0, len(seqs), batch)
    chunk = seqs[idx]
    return chunk[:, :-1], chunk[:, 1:]


# ---------------------------------------------------------------------------
# registry adapters
# ---------------------------------------------------------------------------


@register_dataset("synthetic_image")
def load_synthetic_image(seed: int = 0, noise: float = 1.2,
                         data_root=None, cache_dir=None,
                         **task_kw) -> FederatedDataset:
    """SyntheticImageTask as a registry dataset (bitwise-stable arrays).

    ``data_root``/``cache_dir`` are accepted for loader-signature parity
    but unused: generation is already in-memory deterministic.
    """
    task = SyntheticImageTask(seed=seed, noise=noise, **task_kw)
    return FederatedDataset(
        name="synthetic_image",
        splits={"train": (task.x_train, task.y_train),
                "test": (task.x_test, task.y_test)},
        metadata={"modality": "image", "num_classes": task.num_classes,
                  "hw": task.hw, "channels": task.channels,
                  "source": "synthetic", "seed": seed},
    )


@register_dataset("synthetic_text")
def load_synthetic_text(seed: int = 0, data_root=None, cache_dir=None,
                        **task_kw) -> FederatedDataset:
    """SyntheticTextTask as a registry dataset.

    No natural ids: the ``natural`` partitioner falls back to the
    contiguous shards the pre-registry text path used, byte-identical.
    """
    task = SyntheticTextTask(seed=seed, **task_kw)
    return FederatedDataset(
        name="synthetic_text",
        splits={"train": (task.train[:, :-1], task.train[:, 1:]),
                "test": (task.test[:, :-1], task.test[:, 1:])},
        metadata={"modality": "text", "vocab": task.vocab,
                  "seq_len": task.seq_len, "source": "synthetic",
                  "seed": seed},
    )
