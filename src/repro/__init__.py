"""Heroes-JAX: lightweight federated learning with neural composition and
adaptive local update (Yan et al., 2023), built as a multi-pod JAX
framework.  See README.md / DESIGN.md."""

__version__ = "1.0.0"
