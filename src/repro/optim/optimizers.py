"""Pure-JAX optimizers (no external deps): SGD(+momentum), AdamW.

Functional API mirroring optax:

    opt = sgd(lr=0.01, momentum=0.9)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params, step)
    params = apply_updates(params, updates)

``momentum_dtype``/``moment_dtype`` allow bf16 optimizer state for the
memory-constrained giant configs (kimi-k2: see EXPERIMENTS.md §Dry-run).
Learning rates may be floats or ``f(step) -> float`` schedules.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Union

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Union[float, Callable[[jax.Array], jax.Array]]


def _lr_at(lr: Schedule, step) -> jax.Array:
    if callable(lr):
        return jnp.asarray(lr(step), jnp.float32)
    return jnp.asarray(lr, jnp.float32)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[..., tuple]  # (grads, state, params, step) -> (updates, state)
    name: str = "custom"


def sgd(lr: Schedule, momentum: float = 0.0, nesterov: bool = False,
        momentum_dtype=None) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {"step": jnp.zeros((), jnp.int32)}
        mk = lambda p: jnp.zeros_like(p, dtype=momentum_dtype or p.dtype)
        return {"step": jnp.zeros((), jnp.int32),
                "mu": jax.tree_util.tree_map(mk, params)}

    def update(grads, state, params=None, step=None):
        s = state["step"]
        eta = _lr_at(lr, s)
        if momentum == 0.0:
            ups = jax.tree_util.tree_map(lambda g: (-eta * g).astype(g.dtype), grads)
            return ups, {"step": s + 1}
        mu = jax.tree_util.tree_map(
            lambda m, g: (momentum * m.astype(jnp.float32) + g).astype(m.dtype),
            state["mu"], grads,
        )
        if nesterov:
            eff = jax.tree_util.tree_map(
                lambda m, g: momentum * m.astype(jnp.float32) + g, mu, grads
            )
        else:
            eff = jax.tree_util.tree_map(lambda m: m.astype(jnp.float32), mu)
        ups = jax.tree_util.tree_map(lambda e, g: (-eta * e).astype(g.dtype), eff, grads)
        return ups, {"step": s + 1, "mu": mu}

    return Optimizer(init, update, "sgd")


def adamw(lr: Schedule, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0, moment_dtype=None) -> Optimizer:
    def init(params):
        mk = lambda p: jnp.zeros_like(p, dtype=moment_dtype or jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree_util.tree_map(mk, params),
            "nu": jax.tree_util.tree_map(mk, params),
        }

    def update(grads, state, params, step=None):
        s = state["step"] + 1
        eta = _lr_at(lr, s)
        mu = jax.tree_util.tree_map(
            lambda m, g: (b1 * m.astype(jnp.float32) + (1 - b1) * g.astype(jnp.float32)).astype(m.dtype),
            state["mu"], grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: (b2 * v.astype(jnp.float32)
                          + (1 - b2) * jnp.square(g.astype(jnp.float32))).astype(v.dtype),
            state["nu"], grads)
        bc1 = 1 - b1 ** s.astype(jnp.float32)
        bc2 = 1 - b2 ** s.astype(jnp.float32)

        def upd(m, v, p):
            mh = m.astype(jnp.float32) / bc1
            vh = v.astype(jnp.float32) / bc2
            step_ = mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                step_ = step_ + weight_decay * p.astype(jnp.float32)
            return (-eta * step_).astype(p.dtype)

        ups = jax.tree_util.tree_map(upd, mu, nu, params)
        return ups, {"step": s, "mu": mu, "nu": nu}

    return Optimizer(init, update, "adamw")


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype), params, updates)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def linear_warmup(base: float, warmup_steps: int) -> Callable:
    def f(step):
        s = jnp.asarray(step, jnp.float32)
        return base * jnp.minimum(1.0, (s + 1) / max(warmup_steps, 1))
    return f


def cosine_schedule(base: float, total_steps: int, warmup_steps: int = 0,
                    final_frac: float = 0.1) -> Callable:
    def f(step):
        s = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, (s + 1) / max(warmup_steps, 1))
        t = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return base * warm * cos
    return f


def make_optimizer(name: str, lr: Schedule, **kw) -> Optimizer:
    if name == "sgd":
        return sgd(lr, **kw)
    if name == "sgdm":
        kw.setdefault("momentum", 0.9)
        return sgd(lr, **kw)
    if name == "sgdm_bf16":
        kw.setdefault("momentum", 0.9)
        kw.setdefault("momentum_dtype", jnp.bfloat16)
        return sgd(lr, **kw)
    if name == "adamw":
        return adamw(lr, **kw)
    raise ValueError(f"unknown optimizer {name}")
