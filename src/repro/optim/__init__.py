from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adamw,
    apply_updates,
    cosine_schedule,
    linear_warmup,
    make_optimizer,
    sgd,
)
