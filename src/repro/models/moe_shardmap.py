"""Expert-parallel MoE under ``shard_map`` with an explicit collective
schedule.

The pjit formulations in :mod:`repro.models.moe` leave collective
placement to GSPMD; this module pins it by hand — the §Perf "future work"
item for the MoE pairs:

  * tokens are sharded over the **data** axis and replicated over the
    **model** axis (the layer's activations already live that way);
  * experts are sharded over the **model** axis (E_loc = E/|model|
    resident per device — weight-stationary: no per-layer FSDP gathers of
    expert weights);
  * each device routes its tokens, runs ONLY its resident experts on the
    (capacity-bounded) subset of tokens that chose them, and a single
    ``psum`` over the model axis combines the per-expert partial outputs.

Communication per layer = one all-reduce of the token activations
(T_loc × d), independent of the expert count and of the expert weights —
vs. the ZeRO formulation's per-layer expert-weight all-gathers.

Validated against a dense per-token reference and the pjit GShard
formulation in ``tests/test_moe_shardmap.py`` on an 8-device host mesh.
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

Array = jax.Array
Params = Dict[str, Any]


def _local_expert_pass(x2, gates, ids, gate_w, up_w, down_w,
                       e_base, E_loc: int, cap: int, activation: str):
    """Run the resident experts [e_base, e_base+E_loc) on their tokens.

    x2 (T, d); gates/ids (T, k); expert weights (E_loc, d, f)/(E_loc, f, d).
    Returns the partial output (T, d) covering only resident experts.
    """
    T, d = x2.shape
    k = ids.shape[1]
    flat_e = ids.reshape(-1)
    flat_gate = gates.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T), k)
    loc = flat_e - e_base
    mine = (loc >= 0) & (loc < E_loc)
    loc = jnp.where(mine, loc, E_loc)  # sink bucket
    # position within local expert by stable order (token-index priority)
    order = jnp.argsort(loc, stable=True)
    sloc, stok, sgate = loc[order], flat_tok[order], flat_gate[order]
    starts = jnp.searchsorted(sloc, jnp.arange(E_loc + 1))
    pos = jnp.arange(T * k) - jnp.take(starts, sloc)
    keep = (sloc < E_loc) & (pos < cap)
    buf = jnp.where(keep, sloc * cap + pos, E_loc * cap)
    xbuf = jnp.zeros((E_loc * cap + 1, d), x2.dtype).at[buf].set(
        jnp.where(keep[:, None], x2[stok], 0))
    xe = xbuf[:-1].reshape(E_loc, cap, d)
    g = jnp.einsum("ecd,edf->ecf", xe, gate_w)
    u = jnp.einsum("ecd,edf->ecf", xe, up_w)
    if activation == "geglu":
        h = jax.nn.gelu(g, approximate=True) * u
    else:
        h = jax.nn.silu(g) * u
    ye = jnp.einsum("ecf,efd->ecd", h, down_w).reshape(E_loc * cap, d)
    contrib = jnp.where(keep[:, None],
                        ye[jnp.minimum(buf, E_loc * cap - 1)]
                        * sgate[:, None].astype(ye.dtype), 0)
    y = jnp.zeros((T, d), x2.dtype).at[
        jnp.where(keep, stok, 0)].add(
            jnp.where(keep[:, None], contrib.astype(x2.dtype), 0))
    return y


def apply_moe_shardmap(params: Params, cfg, x: Array, mesh,
                       data_axis: str = "data",
                       model_axis: str = "model") -> Array:
    """x: (B, S, d) sharded P(data_axis, None, None) (model-replicated).
    Expert tensors (E, d, f) sharded P(model_axis, None, None).
    Returns y with the same layout as x."""
    m = cfg.moe
    E = m.num_experts
    n_model = mesh.shape[model_axis]
    assert E % n_model == 0, "experts must divide the model axis"
    E_loc = E // n_model

    def body(router_w, gate_w, up_w, down_w, xs):
        B_loc, S, d = xs.shape
        x2 = xs.reshape(B_loc * S, d)
        T = x2.shape[0]
        cap = max(4, -(-math.ceil(T * m.top_k * m.capacity_factor / E) // 4) * 4)
        logits = x2.astype(jnp.float32) @ router_w
        probs = jax.nn.softmax(logits, -1)
        gates, ids = jax.lax.top_k(probs, m.top_k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
        e_base = jax.lax.axis_index(model_axis) * E_loc
        y = _local_expert_pass(x2, gates, ids, gate_w, up_w, down_w,
                               e_base, E_loc, cap, cfg.activation)
        y = jax.lax.psum(y, model_axis)  # combine expert partials
        return y.reshape(B_loc, S, d)

    f = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(model_axis, None, None), P(model_axis, None, None),
                  P(model_axis, None, None), P(data_axis, None, None)),
        out_specs=P(data_axis, None, None),
    )
    y = f(params["router"]["w"], params["gate"], params["up"],
          params["down"], x)
    if "shared" in params:
        from repro.models import layers
        y = y + layers.apply_mlp(params["shared"], x, cfg.activation)
    return y
