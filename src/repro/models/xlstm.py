"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory, sequential recurrence)  [arXiv:2405.04517].

mLSTM trains with the stabilized parallel (quadratic) form::

    D[t,s] = sum_{r=s+1..t} log sig(f_r) + i_s          (s <= t)
    m_t    = max_s D[t,s]
    Ctil   = exp(D - m_t) * (q_t . k_s) / sqrt(d)
    h_t    = (Ctil @ v) / max(|sum_s Ctil|, exp(-m_t))

and decodes with the O(1) recurrence carrying (C, n, m).  sLSTM is
inherently sequential — a ``lax.scan`` over time with per-head recurrent
weights (this is the paper's own structure; there is no parallel form).

Block layouts follow the xLSTM paper: mLSTM blocks are pre-LN residual
with an up-projection, causal conv on the q/k path and output gating;
sLSTM blocks are pre-LN residual followed by a gated feed-forward.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers, module

Array = jax.Array
Params = Dict[str, Any]


def mlstm_dims(cfg) -> Tuple[int, int, int, int]:
    """(d_up, n_heads, d_qk per head, d_v per head)."""
    x = cfg.xlstm
    d_up = 2 * cfg.d_model
    H = cfg.num_heads
    dqk = int(d_up * x.qk_dim_factor) // H
    dv = int(d_up * x.v_dim_factor) // H
    return d_up, H, dqk, dv


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg, dtype) -> Params:
    d = cfg.d_model
    d_up, H, dqk, dv = mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "norm": layers.init_norm(d, cfg.norm, dtype),
        "up": module.maybe_factorized(ks[0], d, 2 * d_up, cfg, dtype),
        "conv_w": 0.1 * jax.random.normal(ks[1], (4, d_up), dtype),
        "conv_b": jnp.zeros((d_up,), dtype),
        "wq": module.maybe_factorized(ks[2], d_up, H * dqk, cfg, dtype),
        "wk": module.maybe_factorized(ks[3], d_up, H * dqk, cfg, dtype),
        "wv": module.maybe_factorized(ks[4], d_up, H * dv, cfg, dtype),
        "wif": {"w": 0.1 * jax.random.normal(ks[5], (d_up, 2 * H), jnp.float32)},
        "skip": jnp.ones((d_up,), dtype),
        "out_norm": layers.init_norm(H * dv, "rmsnorm", dtype),
        "down": module.maybe_factorized(ks[6], H * dv, d, cfg, dtype),
    }


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    return sum(xp[:, i : i + x.shape[1]] * w[i][None, None] for i in range(W)) + b


def mlstm_parallel(q: Array, k: Array, v: Array, i_pre: Array, f_pre: Array) -> Array:
    """Stabilized parallel mLSTM.  q/k (B,T,H,dqk), v (B,T,H,dv),
    i_pre/f_pre (B,T,H) pre-activations.  Returns (B,T,H,dv)."""
    B, T, H, dqk = q.shape
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))  # (B,T,H)
    F = jnp.cumsum(logf, axis=1)
    # D[t,s] = F_t - F_s + i_s  for s<=t
    D = F[:, :, None, :] - F[:, None, :, :] + i_pre.astype(jnp.float32)[:, None, :, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    D = jnp.where(mask[None, :, :, None], D, -jnp.inf)
    m = jnp.max(D, axis=2)  # (B,T,H)
    expD = jnp.exp(D - m[:, :, None, :])
    scores = jnp.einsum("bthd,bshd->btsh", q, k) * (dqk ** -0.5)
    C = scores.astype(jnp.float32) * expD
    norm = jnp.maximum(jnp.abs(jnp.sum(C, axis=2)), jnp.exp(-m))  # (B,T,H)
    h = jnp.einsum("btsh,bshd->bthd", C.astype(v.dtype), v)
    return h / norm[..., None].astype(v.dtype)


def apply_mlstm(params: Params, cfg, x: Array) -> Array:
    """Full mLSTM residual block.  x: (B,T,d)."""
    B, T, d = x.shape
    d_up, H, dqk, dv = mlstm_dims(cfg)
    h = layers.apply_norm(params["norm"], x, cfg.norm)
    up = module.linear(params["up"], h)
    a, z = jnp.split(up, [d_up], axis=-1)
    ac = jax.nn.silu(_causal_conv(a, params["conv_w"].astype(x.dtype),
                                  params["conv_b"].astype(x.dtype)))
    q = module.linear(params["wq"], ac).reshape(B, T, H, dqk)
    k = module.linear(params["wk"], ac).reshape(B, T, H, dqk)
    v = module.linear(params["wv"], a).reshape(B, T, H, dv)
    if_pre = a @ params["wif"]["w"].astype(x.dtype)  # (B,T,2H)
    i_pre, f_pre = if_pre[..., :H], if_pre[..., H:]
    ht = mlstm_parallel(q, k, v, i_pre, f_pre)
    ht = ht.reshape(B, T, H * dv) + params["skip"][: H * dv].astype(x.dtype) * ac[
        ..., : H * dv
    ]
    out = layers.apply_norm(params["out_norm"], ht, "rmsnorm")
    out = out * jax.nn.silu(z[..., : H * dv])
    return x + module.linear(params["down"], out)


def init_mlstm_cache(cfg, batch: int, dtype) -> Dict[str, Array]:
    d_up, H, dqk, dv = mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, H, dqk, dv), dtype),
        "n": jnp.zeros((batch, H, dqk), dtype),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, 3, d_up), dtype),
    }


def apply_mlstm_decode(
    params: Params, cfg, x: Array, cache: Dict[str, Array]
) -> Tuple[Array, Dict[str, Array]]:
    """One-token mLSTM step.  x: (B,1,d)."""
    B, _, d = x.shape
    d_up, H, dqk, dv = mlstm_dims(cfg)
    h = layers.apply_norm(params["norm"], x, cfg.norm)
    up = module.linear(params["up"], h)
    a, z = jnp.split(up, [d_up], axis=-1)
    hist = jnp.concatenate([cache["conv"], a], axis=1)  # (B,4,d_up)
    w = params["conv_w"].astype(x.dtype)
    ac = jax.nn.silu(jnp.einsum("bwc,wc->bc", hist, w) + params["conv_b"].astype(x.dtype))[:, None]
    new_conv = hist[:, 1:]
    q = module.linear(params["wq"], ac).reshape(B, H, dqk)
    k = module.linear(params["wk"], ac).reshape(B, H, dqk)
    v = module.linear(params["wv"], a).reshape(B, H, dv)
    if_pre = (a @ params["wif"]["w"].astype(x.dtype))[:, 0]
    i_pre, f_pre = if_pre[..., :H].astype(jnp.float32), if_pre[..., H:].astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + cache["m"], i_pre)
    fg = jnp.exp(logf + cache["m"] - m_new)[..., None]  # (B,H,1)
    ig = jnp.exp(i_pre - m_new)[..., None]
    C = cache["C"] * fg[..., None].astype(cache["C"].dtype) + (
        ig.astype(v.dtype)[..., None] * k[..., None] * v[:, :, None, :]
    )
    n = cache["n"] * fg.astype(cache["n"].dtype) + ig.astype(k.dtype) * k
    qs = q * (dqk ** -0.5)
    num = jnp.einsum("bhd,bhdv->bhv", qs, C)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", qs, n)), jnp.exp(-m_new).astype(qs.dtype)
    )
    ht = (num / den[..., None]).reshape(B, 1, H * dv)
    ht = ht + params["skip"][: H * dv].astype(x.dtype) * ac[..., : H * dv]
    out = layers.apply_norm(params["out_norm"], ht, "rmsnorm")
    out = out * jax.nn.silu(z[..., : H * dv])
    y = x + module.linear(params["down"], out)
    return y, {"C": C, "n": n, "m": m_new, "conv": new_conv}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg, dtype) -> Params:
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    ks = jax.random.split(key, 4)
    px = cfg.xlstm.proj_factor
    d_ff = 2 * int(d * px)  # even so the gated split is exact
    return {
        "norm": layers.init_norm(d, cfg.norm, dtype),
        # input weights for 4 gates (i, f, z, o)
        "wx": {"w": (d ** -0.5) * jax.random.normal(ks[0], (d, 4 * d), dtype)},
        # per-head recurrent weights (H, dh, 4*dh)
        "r": (dh ** -0.5) * jax.random.normal(ks[1], (H, dh, 4 * dh), dtype),
        "bias": jnp.zeros((4 * d,), jnp.float32),
        "gn": layers.init_norm(d, "rmsnorm", dtype),
        "ff_up": module.maybe_factorized(ks[2], d, d_ff, cfg, dtype),
        "ff_down": module.maybe_factorized(ks[3], d_ff // 2, d, cfg, dtype),
    }


def _slstm_cell(params, cfg, xg: Array, state):
    """One time step.  xg: (B, 4d) input-gate preactivations (no recurrent
    part yet).  state: dict(c, n, h, m) each (B, H, dh)."""
    B = xg.shape[0]
    H = cfg.num_heads
    dh = cfg.d_model // H
    rec = jnp.einsum("bhd,hdk->bhk", state["h"], params["r"].astype(xg.dtype))
    pre = xg.reshape(B, H, 4 * dh) + rec + params["bias"].reshape(H, 4 * dh).astype(
        jnp.float32
    ).astype(xg.dtype)
    i_pre, f_pre, z_pre, o_pre = jnp.split(pre, 4, axis=-1)
    i_pre = i_pre.astype(jnp.float32)
    f_pre = f_pre.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + state["m"], i_pre)
    ig = jnp.exp(i_pre - m_new)
    fg = jnp.exp(logf + state["m"] - m_new)
    c = fg * state["c"] + ig * jnp.tanh(z_pre.astype(jnp.float32))
    n = fg * state["n"] + ig
    h = jax.nn.sigmoid(o_pre.astype(jnp.float32)) * c / jnp.maximum(n, 1e-6)
    new = {"c": c, "n": n, "h": h.astype(state["h"].dtype), "m": m_new}
    return new, h


def init_slstm_state(cfg, batch: int, dtype) -> Dict[str, Array]:
    H = cfg.num_heads
    dh = cfg.d_model // H
    z = lambda: jnp.zeros((batch, H, dh), jnp.float32)
    return {"c": z(), "n": z(), "h": jnp.zeros((batch, H, dh), dtype),
            "m": jnp.full((batch, H, dh), -1e30, jnp.float32)}


def apply_slstm(params: Params, cfg, x: Array) -> Array:
    """Full sLSTM residual block (sequential scan over T).  x: (B,T,d)."""
    B, T, d = x.shape
    H = cfg.num_heads
    dh = d // H
    hin = layers.apply_norm(params["norm"], x, cfg.norm)
    xg = hin @ params["wx"]["w"].astype(x.dtype)  # (B,T,4d)

    def step(state, xt):
        new, h = _slstm_cell(params, cfg, xt, state)
        return new, h

    state0 = init_slstm_state(cfg, B, x.dtype)
    _, hs = jax.lax.scan(step, state0, jnp.moveaxis(xg, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1).reshape(B, T, d).astype(x.dtype)
    hs = layers.apply_norm(params["gn"], hs, "rmsnorm")
    up = module.linear(params["ff_up"], hs)
    a, b = jnp.split(up, 2, axis=-1)
    y = module.linear(params["ff_down"], jax.nn.gelu(a, approximate=True) * b)
    return x + y


def apply_slstm_decode(
    params: Params, cfg, x: Array, state: Dict[str, Array]
) -> Tuple[Array, Dict[str, Array]]:
    B, _, d = x.shape
    hin = layers.apply_norm(params["norm"], x, cfg.norm)
    xg = (hin @ params["wx"]["w"].astype(x.dtype))[:, 0]
    new, h = _slstm_cell(params, cfg, xg, state)
    hs = h.reshape(B, 1, d).astype(x.dtype)
    hs = layers.apply_norm(params["gn"], hs, "rmsnorm")
    up = module.linear(params["ff_up"], hs)
    a, b = jnp.split(up, 2, axis=-1)
    y = module.linear(params["ff_down"], jax.nn.gelu(a, approximate=True) * b)
    return x + y, new
