"""Minimal functional module system.

Parameters are plain nested dicts of jax arrays.  Layers are pure
functions ``apply(params, x, ...)``; initialisers are pure functions
``init(key, ...) -> params``.  Stacked-layer models store every layer's
params with a leading ``L`` axis and run :func:`jax.lax.scan` over them so
compile time is depth-independent.

Factorized linears (Heroes neural composition) are supported natively:
a linear's params are either ``{"w": (din, dout)}`` (dense) or
``{"basis": (I, R), "coeff": (m, R, O)}`` (factorized, m = p^2 blocks at
width p).  The factorized *forward* never materialises the composed
weight::

    y[(b,o)] = sum_a (x_a @ v) @ u_{ab}      (see DESIGN.md §3)

which is algebraically identical to composing w_p and multiplying —
validated against :func:`repro.core.composition.compose` in tests — but
costs ``p·I·R + p²·R·O`` MACs/token instead of ``p²·I·O``.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.composition import CompositionSpec, init_factors

Array = jax.Array
Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# dense linear
# ---------------------------------------------------------------------------


def init_linear(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None) -> Params:
    std = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return {"w": std * jax.random.normal(key, (d_in, d_out), dtype)}


def init_embedding(key, vocab: int, d: int, dtype) -> Params:
    # d^-0.5 keeps tied-unembed logits O(1) at init
    return {"table": (d ** -0.5) * jax.random.normal(key, (vocab, d), dtype)}


# ---------------------------------------------------------------------------
# factorized linear (Heroes)
# ---------------------------------------------------------------------------


def comp_spec_for(d_in: int, d_out: int, max_width: int, rank: int) -> CompositionSpec:
    """Spec of a factorized linear whose *full-width* (p=P) weight is
    (d_in, d_out): base_in = d_in / P, base_out = d_out / P."""
    if d_in % max_width or d_out % max_width:
        raise ValueError(f"dims ({d_in},{d_out}) not divisible by P={max_width}")
    return CompositionSpec(
        max_width=max_width, rank=rank, base_in=d_in // max_width,
        base_out=d_out // max_width, ksq=1,
    )


def init_factorized_linear(key, d_in: int, d_out: int, max_width: int,
                           rank: int, width: int, dtype) -> Params:
    """Init at active width ``width`` (p^2 leading blocks; the FL runtime
    re-gathers blocks per round — for the static launcher path the width is
    fixed at construction)."""
    spec = comp_spec_for(d_in, d_out, max_width, rank)
    v, u = init_factors(key, spec, dtype)
    m = width * width
    return {"basis": v[0], "coeff": u[:m]}  # drop ksq=1 axis on basis


# Paper-faithful forward: materialise w_p = compose(v, u) then x @ w_p.
# Default (False) is the beyond-paper factorized forward x@v@u (§Perf).
_COMPOSE_THEN_MATMUL = False


def set_compose_then_matmul(value: bool) -> None:
    global _COMPOSE_THEN_MATMUL
    _COMPOSE_THEN_MATMUL = value


def linear(params: Params, x: Array, width: int = 0) -> Array:
    """Apply dense or factorized linear.  ``x``: (..., d_in)."""
    if "w" in params:
        return x @ params["w"].astype(x.dtype)
    basis, coeff = params["basis"], params["coeff"]
    p = width or int(math.isqrt(coeff.shape[0]))
    assert p * p == coeff.shape[0], "coeff blocks must be a square count"
    I = basis.shape[0]
    R, O = coeff.shape[1], coeff.shape[2]
    *lead, d_in = x.shape
    assert d_in == p * I, f"x dim {d_in} != p*I = {p}*{I}"
    if _COMPOSE_THEN_MATMUL:
        # w[(a,i),(b,o)] = sum_r v[i,r] u[(a,b),r,o]  (paper Fig. 1)
        u = coeff.astype(x.dtype).reshape(p, p, R, O)
        w = jnp.einsum("ir,abro->aibo", basis.astype(x.dtype), u)
        w = w.reshape(p * I, p * O)
        return x @ w
    xa = x.reshape(*lead, p, I)
    z = jnp.einsum("...ai,ir->...ar", xa, basis.astype(x.dtype))
    u = coeff.astype(x.dtype).reshape(p, p, R, O)
    y = jnp.einsum("...ar,abro->...bo", z, u)
    return y.reshape(*lead, p * O)


def linear_out_dim(params: Params, width: int = 0) -> int:
    if "w" in params:
        return params["w"].shape[1]
    p = width or int(math.isqrt(params["coeff"].shape[0]))
    return p * params["coeff"].shape[2]


def maybe_factorized(key, d_in: int, d_out: int, cfg, dtype) -> Params:
    """Init a linear honouring cfg.composition (used by all transformer
    projections so Heroes composition is a first-class switch)."""
    c = cfg.composition
    if not c.enabled:
        return init_linear(key, d_in, d_out, dtype)
    return init_factorized_linear(
        key, d_in, d_out, c.max_width, cfg.comp_rank, cfg.comp_width, dtype
    )


# ---------------------------------------------------------------------------
# stacked init: vmap an initialiser over a leading layer axis
# ---------------------------------------------------------------------------


def stacked_init(init_fn, key, num: int, *args, **kwargs):
    keys = jax.random.split(key, num)
    return jax.vmap(lambda k: init_fn(k, *args, **kwargs))(keys)


def count_params(params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


def param_bytes(params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(params))
