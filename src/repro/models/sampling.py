"""Token sampling utilities for the serving path."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def sample_logits(key, logits: Array, *, temperature: float = 1.0,
                  top_k: int = 0, top_p: float = 0.0) -> Array:
    """Sample token ids from (B, V) logits.

    temperature=0 -> greedy; top_k keeps the k best; top_p keeps the
    smallest nucleus whose probability mass >= top_p.  Filters compose
    (top_k first, then top_p), matching the common serving convention.
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    logits = logits.astype(jnp.float32) / temperature
    if top_k > 0 and top_k < logits.shape[-1]:
        kth = jnp.sort(logits, axis=-1)[..., -top_k][..., None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if top_p > 0.0 and top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # keep tokens until the cumulative mass passes top_p (inclusive)
        keep_sorted = cum - probs < top_p
        cutoff = jnp.max(jnp.where(keep_sorted, sorted_logits, -jnp.inf),
                         axis=-1, keepdims=True)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1)


def perplexity(logits: Array, labels: Array,
               mask: Optional[Array] = None) -> Array:
    """exp(mean token NLL) over (B, S, V) logits / (B, S) labels."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mean = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    else:
        mean = jnp.mean(nll)
    return jnp.exp(mean)
