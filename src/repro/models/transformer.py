"""Stack assembly: decoder layers scanned over stacked params.

Every family's stack is a (short) ``lax.scan`` over layer-stacked params so
HLO size and compile time are depth-independent — an 88-layer granite
lowers as fast as a 2-layer smoke model.  Remat (``jax.checkpoint``) wraps
the scan body when ``cfg.remat``.

Families:
  dense / vlm        scan over identical decoder layers
  moe                unrolled ``first_k_dense`` dense layers + scanned MoE layers
  hybrid (zamba2)    scan over superblocks: ``attn_every`` Mamba2 layers then
                     one *shared* attention+MLP block (captured params — the
                     sharing is the point of the architecture)
  ssm (xlstm)        scan over superblocks: (slstm_every-1) mLSTM + 1 sLSTM
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, layers, moe as moe_lib, module, ssm, xlstm
from repro.sharding.context import constrain_residual

Array = jax.Array
Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# dense decoder layer
# ---------------------------------------------------------------------------


def init_decoder_layer(key, cfg, use_moe: bool) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "ln1": layers.init_norm(cfg.d_model, cfg.norm, cfg.pdtype),
        "attn": attention.init_attention(ks[0], cfg),
        "ln2": layers.init_norm(cfg.d_model, cfg.norm, cfg.pdtype),
    }
    if use_moe:
        p["moe"] = moe_lib.init_moe(ks[1], cfg, cfg.pdtype)
    else:
        p["mlp"] = layers.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.activation, cfg, cfg.pdtype)
    return p


def decoder_layer(params: Params, cfg, x: Array, cos, sin,
                  skip_blocks: bool = False) -> Tuple[Array, Array]:
    """Returns (x, aux_loss)."""
    h = layers.apply_norm(params["ln1"], x, cfg.norm)
    attn_out = attention.self_attention(
        params["attn"], cfg, h, cos, sin, skip_masked_blocks=skip_blocks
    )
    aux = jnp.zeros((), jnp.float32)
    if cfg.parallel_block:
        if "moe" in params:
            ffn_out, aux = moe_lib.apply_moe(params["moe"], cfg, h)
        else:
            ffn_out = layers.apply_mlp(params["mlp"], h, cfg.activation)
        return x + attn_out + ffn_out, aux
    x = x + attn_out
    h2 = layers.apply_norm(params["ln2"], x, cfg.norm)
    if "moe" in params:
        ffn_out, aux = moe_lib.apply_moe(params["moe"], cfg, h2)
    else:
        ffn_out = layers.apply_mlp(params["mlp"], h2, cfg.activation)
    return x + ffn_out, aux


def decoder_layer_decode(params: Params, cfg, x: Array, ck, cv, cache_len,
                         cos, sin, scales=None):
    h = layers.apply_norm(params["ln1"], x, cfg.norm)
    res = attention.decode_self_attention(
        params["attn"], cfg, h, ck, cv, cache_len, cos, sin,
        cache_scales=scales,
    )
    if scales is not None:
        attn_out, ck, cv, scales = res
    else:
        attn_out, ck, cv = res
    if cfg.parallel_block:
        if "moe" in params:
            ffn_out, _ = moe_lib.apply_moe(params["moe"], cfg, h)
        else:
            ffn_out = layers.apply_mlp(params["mlp"], h, cfg.activation)
        out = x + attn_out + ffn_out
    else:
        x = x + attn_out
        h2 = layers.apply_norm(params["ln2"], x, cfg.norm)
        if "moe" in params:
            ffn_out, _ = moe_lib.apply_moe(params["moe"], cfg, h2)
        else:
            ffn_out = layers.apply_mlp(params["mlp"], h2, cfg.activation)
        out = x + ffn_out
    if scales is not None:
        return out, ck, cv, scales
    return out, ck, cv


# ---------------------------------------------------------------------------
# dense / moe stacks
# ---------------------------------------------------------------------------


def init_stack(key, cfg) -> Params:
    if cfg.moe is not None:
        kd, km = jax.random.split(key)
        fkd = cfg.moe.first_k_dense
        p: Params = {}
        if fkd:
            p["dense_layers"] = module.stacked_init(
                lambda k: init_decoder_layer(k, cfg, use_moe=False), kd, fkd
            )
        p["moe_layers"] = module.stacked_init(
            lambda k: init_decoder_layer(k, cfg, use_moe=True), km,
            cfg.num_layers - fkd,
        )
        return p
    return {
        "layers": module.stacked_init(
            lambda k: init_decoder_layer(k, cfg, use_moe=False), key, cfg.num_layers
        )
    }


def _scan_layers(body, x0, stacked_params, cfg):
    if cfg.remat:
        body = jax.checkpoint(body)

    def f(carry, lp):
        x, aux = carry
        x, a = body(lp, x)
        x = constrain_residual(x)  # bounds the remat/scan carry footprint
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(f, (x0, jnp.zeros((), jnp.float32)), stacked_params)
    return x, aux


def apply_stack(params: Params, cfg, x: Array, cos, sin,
                skip_blocks: bool = False) -> Tuple[Array, Array]:
    body = lambda lp, h: decoder_layer(lp, cfg, h, cos, sin, skip_blocks)
    aux_total = jnp.zeros((), jnp.float32)
    if "dense_layers" in params:
        x, aux = _scan_layers(body, x, params["dense_layers"], cfg)
        aux_total += aux
    key = "moe_layers" if cfg.moe is not None else "layers"
    x, aux = _scan_layers(body, x, params[key], cfg)
    return x, aux_total + aux


def decode_stack(params: Params, cfg, x: Array, cache: Dict[str, Array],
                 cache_len, cos, sin) -> Tuple[Array, Dict[str, Array]]:
    """cache: {"k": (L,B,S,KV,D), "v": same} stacked over *all* layers in
    stack order (dense first); int8 variants add "k_scale"/"v_scale"
    (L,B,S,KV)."""
    quant = "k_scale" in cache

    def f(carry, xs):
        h = carry
        if quant:
            lp, ck, cv, ks_, vs_ = xs
            h, ck, cv, (ks_, vs_) = decoder_layer_decode(
                lp, cfg, h, ck, cv, cache_len, cos, sin, scales=(ks_, vs_))
            return h, (ck, cv, ks_, vs_)
        lp, ck, cv = xs
        h, ck, cv = decoder_layer_decode(lp, cfg, h, ck, cv, cache_len, cos, sin)
        return h, (ck, cv)

    parts = []
    if "dense_layers" in params:
        parts.append(params["dense_layers"])
    parts.append(params["moe_layers"] if cfg.moe is not None else params["layers"])
    fkd = cfg.moe.first_k_dense if cfg.moe is not None else 0

    new = {k: [] for k in cache}
    off = 0
    for part, n in zip(parts, ([fkd, cfg.num_layers - fkd] if cfg.moe is not None and fkd
                               else [cfg.num_layers])):
        sl = {k: jax.lax.dynamic_slice_in_dim(cache[k], off, n, axis=0)
              for k in cache}
        if quant:
            x, (ck, cv, ks_, vs_) = jax.lax.scan(
                f, x, (part, sl["k"], sl["v"], sl["k_scale"], sl["v_scale"]))
            outs = {"k": ck, "v": cv, "k_scale": ks_, "v_scale": vs_}
        else:
            x, (ck, cv) = jax.lax.scan(f, x, (part, sl["k"], sl["v"]))
            outs = {"k": ck, "v": cv}
        for k in outs:
            new[k].append(outs[k])
        off += n
    return x, {k: jnp.concatenate(v, 0) for k, v in new.items()}


def init_kv_cache(cfg, batch: int, max_len: int, num_layers: Optional[int] = None,
                  dtype=None) -> Dict[str, Array]:
    n = num_layers if num_layers is not None else cfg.num_layers
    d = cfg.resolved_head_dim
    shape = (n, batch, max_len, cfg.num_kv_heads, d)
    if dtype is None and cfg.kv_cache_quant == "int8":
        sshape = (n, batch, max_len, cfg.num_kv_heads)
        return {"k": jnp.zeros(shape, jnp.int8), "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(sshape, jnp.float32),
                "v_scale": jnp.zeros(sshape, jnp.float32)}
    dt = dtype or cfg.cdtype
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


# ---------------------------------------------------------------------------
# hybrid (zamba2): mamba superblocks + shared attention block
# ---------------------------------------------------------------------------


def init_shared_block(key, cfg) -> Params:
    hb = cfg.hybrid
    d_ff = hb.shared_d_ff or 4 * cfg.d_model
    ks = jax.random.split(key, 2)
    return {
        "ln1": layers.init_norm(cfg.d_model, cfg.norm, cfg.pdtype),
        "attn": attention.init_attention(ks[0], cfg),
        "ln2": layers.init_norm(cfg.d_model, cfg.norm, cfg.pdtype),
        "mlp": layers.init_mlp(ks[1], cfg.d_model, d_ff, cfg.activation, cfg, cfg.pdtype),
    }


def init_hybrid_stack(key, cfg) -> Params:
    hb = cfg.hybrid
    assert cfg.num_layers % hb.attn_every == 0, "layers must tile into superblocks"
    km, ka, kn = jax.random.split(key, 3)
    mamba = module.stacked_init(lambda k: ssm.init_mamba2(k, cfg, cfg.pdtype),
                                km, cfg.num_layers)
    nsuper = cfg.num_layers // hb.attn_every
    # reshape leading axis (L, ...) -> (nsuper, attn_every, ...)
    mamba = jax.tree_util.tree_map(
        lambda a: a.reshape(nsuper, hb.attn_every, *a.shape[1:]), mamba
    )
    return {
        "mamba": mamba,
        "mamba_norms": jax.tree_util.tree_map(
            lambda a: a.reshape(nsuper, hb.attn_every, *a.shape[1:]),
            module.stacked_init(
                lambda k: layers.init_norm(cfg.d_model, cfg.norm, cfg.pdtype),
                kn, cfg.num_layers),
        ),
        "shared": init_shared_block(ka, cfg),
    }


def apply_hybrid(params: Params, cfg, x: Array, cos, sin,
                 skip_blocks: bool = False) -> Tuple[Array, Array]:
    shared = params["shared"]

    def mamba_layer(lp, h):
        norm_p, mp = lp
        return h + ssm.apply_mamba2(mp, cfg, layers.apply_norm(norm_p, h, cfg.norm)), jnp.zeros((), jnp.float32)

    def superblock(carry, xs):
        h, aux = carry
        norms, mps = xs
        h, a = _scan_layers(mamba_layer, h, (norms, mps), cfg)
        # shared attention + MLP block (same params every superblock)
        hs = layers.apply_norm(shared["ln1"], h, cfg.norm)
        h = h + attention.self_attention(shared["attn"], cfg, hs, cos, sin,
                                         skip_masked_blocks=skip_blocks)
        hm = layers.apply_norm(shared["ln2"], h, cfg.norm)
        h = h + layers.apply_mlp(shared["mlp"], hm, cfg.activation)
        return (constrain_residual(h), aux + a), None

    (x, aux), _ = jax.lax.scan(
        superblock, (x, jnp.zeros((), jnp.float32)),
        (params["mamba_norms"], params["mamba"]),
    )
    return x, aux


def init_hybrid_cache(cfg, batch: int, max_len: int) -> Dict[str, Any]:
    hb = cfg.hybrid
    nsuper = cfg.num_layers // hb.attn_every
    mcache = ssm.init_mamba2_cache(cfg, batch, cfg.cdtype)
    # stack (nsuper, attn_every, ...)
    mcache = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (nsuper, hb.attn_every, *a.shape)), mcache
    )
    kv = init_kv_cache(cfg, batch, max_len, num_layers=nsuper)
    return {"mamba": mcache, "kv": kv}


def decode_hybrid(params: Params, cfg, x: Array, cache, cache_len, cos, sin):
    shared = params["shared"]

    def mamba_layer(h, xs):
        (norm_p, mp), mc = xs
        out, mc = ssm.apply_mamba2_decode(mp, cfg, layers.apply_norm(norm_p, h, cfg.norm), mc)
        return h + out, mc

    def superblock(h, xs):
        (norms, mps), mcs, ck, cv = xs
        h, mcs = jax.lax.scan(mamba_layer, h, ((norms, mps), mcs))
        hs = layers.apply_norm(shared["ln1"], h, cfg.norm)
        attn_out, ck, cv = attention.decode_self_attention(
            shared["attn"], cfg, hs, ck, cv, cache_len, cos, sin
        )
        h = h + attn_out
        hm = layers.apply_norm(shared["ln2"], h, cfg.norm)
        h = h + layers.apply_mlp(shared["mlp"], hm, cfg.activation)
        return h, (mcs, ck, cv)

    x, (mcs, ck, cv) = jax.lax.scan(
        superblock, x,
        ((params["mamba_norms"], params["mamba"]), cache["mamba"],
         cache["kv"]["k"], cache["kv"]["v"]),
    )
    return x, {"mamba": mcs, "kv": {"k": ck, "v": cv}}


# ---------------------------------------------------------------------------
# xlstm stack
# ---------------------------------------------------------------------------


def init_xlstm_stack(key, cfg) -> Params:
    xc = cfg.xlstm
    per = xc.slstm_every
    assert cfg.num_layers % per == 0
    nsuper = cfg.num_layers // per
    km, ks_ = jax.random.split(key)
    m = module.stacked_init(lambda k: xlstm.init_mlstm(k, cfg, cfg.pdtype),
                            km, nsuper * (per - 1))
    m = jax.tree_util.tree_map(lambda a: a.reshape(nsuper, per - 1, *a.shape[1:]), m)
    s = module.stacked_init(lambda k: xlstm.init_slstm(k, cfg, cfg.pdtype), ks_, nsuper)
    return {"mlstm": m, "slstm": s}


def apply_xlstm(params: Params, cfg, x: Array) -> Tuple[Array, Array]:
    def mbody(lp, h):
        return xlstm.apply_mlstm(lp, cfg, h), jnp.zeros((), jnp.float32)

    def superblock(carry, xs):
        h, aux = carry
        mls, sl = xs
        h, a = _scan_layers(mbody, h, mls, cfg)
        h = xlstm.apply_slstm(sl, cfg, h)
        return (constrain_residual(h), aux + a), None

    (x, aux), _ = jax.lax.scan(
        superblock, (x, jnp.zeros((), jnp.float32)),
        (params["mlstm"], params["slstm"]),
    )
    return x, aux


def init_xlstm_cache(cfg, batch: int) -> Dict[str, Any]:
    xc = cfg.xlstm
    per = xc.slstm_every
    nsuper = cfg.num_layers // per
    mc = xlstm.init_mlstm_cache(cfg, batch, cfg.cdtype)
    mc = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (nsuper, per - 1, *a.shape)), mc
    )
    sc = xlstm.init_slstm_state(cfg, batch, cfg.cdtype)
    sc = jax.tree_util.tree_map(lambda a: jnp.broadcast_to(a, (nsuper, *a.shape)), sc)
    return {"mlstm": mc, "slstm": sc}


def decode_xlstm(params: Params, cfg, x: Array, cache):
    def mbody(h, xs):
        lp, mc = xs
        h, mc = xlstm.apply_mlstm_decode(lp, cfg, h, mc)
        return h, mc

    def superblock(h, xs):
        (mls, sl), mcs, sc = xs
        h, mcs = jax.lax.scan(mbody, h, (mls, mcs))
        h, sc = xlstm.apply_slstm_decode(sl, cfg, h, sc)
        return h, (mcs, sc)

    x, (mcs, scs) = jax.lax.scan(
        superblock, x,
        ((params["mlstm"], params["slstm"]), cache["mlstm"], cache["slstm"]),
    )
    return x, {"mlstm": mcs, "slstm": scs}
