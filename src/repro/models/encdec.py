"""Encoder-decoder backbone (seamless-m4t-medium style).

The audio codec / mel frontend is a STUB per the assignment carve-out:
the encoder consumes precomputed frame embeddings ``(B, S_enc, d)``.
Decoder layers: causal self-attention + cross-attention over encoder
memory + FFN.  Cross-attention K/V are precomputed once per sequence
(prefill) and are part of the serve cache.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, layers, module, transformer

Array = jax.Array
Params = Dict[str, Any]


def init_encoder_layer(key, cfg) -> Params:
    enc_ff = cfg.encdec.encoder_d_ff or cfg.d_ff
    ks = jax.random.split(key, 2)
    return {
        "ln1": layers.init_norm(cfg.d_model, cfg.norm, cfg.pdtype),
        "attn": attention.init_attention(ks[0], cfg),
        "ln2": layers.init_norm(cfg.d_model, cfg.norm, cfg.pdtype),
        "mlp": layers.init_mlp(ks[1], cfg.d_model, enc_ff, cfg.activation, cfg, cfg.pdtype),
    }


def init_decoder_layer(key, cfg) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "ln1": layers.init_norm(cfg.d_model, cfg.norm, cfg.pdtype),
        "self_attn": attention.init_attention(ks[0], cfg),
        "ln_x": layers.init_norm(cfg.d_model, cfg.norm, cfg.pdtype),
        "cross_attn": attention.init_cross_attention(ks[1], cfg),
        "ln2": layers.init_norm(cfg.d_model, cfg.norm, cfg.pdtype),
        "mlp": layers.init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.activation, cfg, cfg.pdtype),
    }


def init_encdec(key, cfg) -> Params:
    ke, kd = jax.random.split(key)
    return {
        "encoder": module.stacked_init(
            lambda k: init_encoder_layer(k, cfg), ke, cfg.encdec.num_encoder_layers
        ),
        "decoder": module.stacked_init(
            lambda k: init_decoder_layer(k, cfg), kd, cfg.num_layers
        ),
    }


def encode(params: Params, cfg, mem: Array, mem_mask: Optional[Array],
           cos, sin) -> Array:
    """Encoder over stub frame embeddings.  mem: (B, S_enc, d)."""

    def body(lp, h):
        hs = layers.apply_norm(lp["ln1"], h, cfg.norm)
        h = h + attention.self_attention(lp["attn"], cfg, hs, cos, sin, causal=False)
        hm = layers.apply_norm(lp["ln2"], h, cfg.norm)
        h = h + layers.apply_mlp(lp["mlp"], hm, cfg.activation)
        return h, jnp.zeros((), jnp.float32)

    mem, _ = transformer._scan_layers(body, mem, params["encoder"], cfg)
    return mem


def decode_train(params: Params, cfg, x: Array, memory: Array,
                 mem_mask: Optional[Array], cos, sin) -> Array:
    """Teacher-forced decoder over full target sequence."""

    def body(lp, h):
        hs = layers.apply_norm(lp["ln1"], h, cfg.norm)
        h = h + attention.self_attention(lp["self_attn"], cfg, hs, cos, sin)
        hx = layers.apply_norm(lp["ln_x"], h, cfg.norm)
        mk, mv = attention.encode_memory(lp["cross_attn"], cfg, memory)
        h = h + attention.cross_attention(lp["cross_attn"], cfg, hx, mk, mv, mem_mask)
        hm = layers.apply_norm(lp["ln2"], h, cfg.norm)
        h = h + layers.apply_mlp(lp["mlp"], hm, cfg.activation)
        return h, jnp.zeros((), jnp.float32)

    x, _ = transformer._scan_layers(body, x, params["decoder"], cfg)
    return x


def init_encdec_cache(cfg, batch: int, max_len: int) -> Dict[str, Any]:
    """Self-attn KV cache + precomputed cross-attn memory K/V per layer."""
    d = cfg.resolved_head_dim
    L = cfg.num_layers
    Sm = cfg.encdec.encoder_seq
    return {
        "self": transformer.init_kv_cache(cfg, batch, max_len),
        "mem_k": jnp.zeros((L, batch, Sm, cfg.num_kv_heads, d), cfg.cdtype),
        "mem_v": jnp.zeros((L, batch, Sm, cfg.num_kv_heads, d), cfg.cdtype),
        "mem_mask": jnp.zeros((batch, Sm), bool),
    }


def prefill_memory(params: Params, cfg, memory: Array, mem_mask: Array,
                   cache: Dict[str, Any]) -> Dict[str, Any]:
    """Precompute per-layer cross K/V from encoder output into the cache."""

    def body(_, lp):
        mk, mv = attention.encode_memory(lp["cross_attn"], cfg, memory)
        return None, (mk, mv)

    _, (mk, mv) = jax.lax.scan(body, None, params["decoder"])
    return {**cache, "mem_k": mk, "mem_v": mv, "mem_mask": mem_mask}


def decode_step(params: Params, cfg, x: Array, cache: Dict[str, Any],
                cache_len, cos, sin) -> Tuple[Array, Dict[str, Any]]:
    """One decoder token with cached self-attn KV + cross memory K/V."""

    def body(h, xs):
        lp, ck, cv, mk, mv = xs
        hs = layers.apply_norm(lp["ln1"], h, cfg.norm)
        so, ck, cv = attention.decode_self_attention(
            lp["self_attn"], cfg, hs, ck, cv, cache_len, cos, sin
        )
        h = h + so
        hx = layers.apply_norm(lp["ln_x"], h, cfg.norm)
        h = h + attention.cross_attention(
            lp["cross_attn"], cfg, hx, mk, mv, cache["mem_mask"]
        )
        hm = layers.apply_norm(lp["ln2"], h, cfg.norm)
        h = h + layers.apply_mlp(lp["mlp"], hm, cfg.activation)
        return h, (ck, cv)

    x, (ck, cv) = jax.lax.scan(
        body, x,
        (params["decoder"], cache["self"]["k"], cache["self"]["v"],
         cache["mem_k"], cache["mem_v"]),
    )
    new_cache = {**cache, "self": {"k": ck, "v": cv}}
    return x, new_cache
