"""Stub modality frontends ([vlm]/[audio] assignment carve-out).

These produce *precomputed embeddings* of the right shape — they stand in
for a ViT/SigLIP vision tower (qwen2-vl) or a mel+conv audio codec
(seamless-m4t).  The transformer backbone consumes their output; the
towers themselves are explicitly out of scope per the assignment.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def vision_patch_embeddings(key, batch: int, num_patches: int, d_model: int,
                            grid: Tuple[int, int] | None = None,
                            dtype=jnp.float32) -> Dict[str, Array]:
    """Stub ViT output + M-RoPE (t,h,w) position ids for qwen2-vl.

    ``grid``: (h, w) patch grid; defaults to a near-square factorisation.
    """
    if grid is None:
        h = int(num_patches**0.5)
        while num_patches % h:
            h -= 1
        grid = (h, num_patches // h)
    h, w = grid
    emb = jax.random.normal(key, (batch, num_patches, d_model), dtype) * 0.02
    hh, ww = jnp.meshgrid(jnp.arange(h), jnp.arange(w), indexing="ij")
    pos = jnp.stack([jnp.zeros(num_patches, jnp.int32),
                     hh.reshape(-1).astype(jnp.int32),
                     ww.reshape(-1).astype(jnp.int32)])
    pos = jnp.broadcast_to(pos[None], (batch, 3, num_patches))
    return {"embeddings": emb, "positions": pos}


def interleave_text(key, vis: Dict[str, Array], text_tokens: Array,
                    embed_table: Array, dtype=jnp.float32) -> Dict[str, Array]:
    """Concatenate stub vision embeddings with embedded text tokens and
    extend the M-RoPE positions along the temporal axis."""
    B, P, d = vis["embeddings"].shape
    t_emb = jnp.take(embed_table, text_tokens, axis=0).astype(dtype)
    S = text_tokens.shape[1]
    t_pos = jnp.arange(1, S + 1, dtype=jnp.int32)[None, None, :] + jnp.zeros(
        (B, 3, S), jnp.int32
    )
    return {
        "embeddings": jnp.concatenate([vis["embeddings"], t_emb], axis=1),
        "positions": jnp.concatenate([vis["positions"], t_pos], axis=2),
    }


def audio_frame_embeddings(key, batch: int, num_frames: int, d_model: int,
                           valid_frames: Array | None = None,
                           dtype=jnp.float32) -> Dict[str, Array]:
    """Stub conv-codec output for seamless-m4t: frame embeddings + mask."""
    emb = jax.random.normal(key, (batch, num_frames, d_model), dtype) * 0.02
    if valid_frames is None:
        mask = jnp.ones((batch, num_frames), bool)
    else:
        mask = jnp.arange(num_frames)[None, :] < valid_frames[:, None]
    return {"enc_embeddings": emb, "enc_mask": mask}
