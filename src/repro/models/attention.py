"""Attention: GQA/MQA, RoPE / M-RoPE, flash-style chunked softmax, KV cache.

Layouts:
  q           (B, S, KV, G, D)   G = q heads per kv head (GQA groups)
  k, v        (B, S, KV, D)
  kv cache    (B, Smax, KV, D)   keys stored *post-RoPE*

The training/prefill path is a pure-JAX flash attention: an outer scan over
query chunks and an inner scan over KV chunks with streaming max/sum, so the
(S x S) score matrix never materialises — this is what makes prefill_32k
lower within per-device memory.  The Pallas kernel in
``repro.kernels.flash_attention`` implements the same schedule with explicit
VMEM tiling for TPU; this module is the portable reference path used by the
distributed launcher (XLA fuses the scan body into a pipelined loop).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import module

Array = jax.Array
Params = Dict[str, Any]
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def rope_angles(positions: Array, head_dim: int, theta: float) -> Tuple[Array, Array]:
    """cos/sin for plain RoPE.  positions (..., S) int32 -> (..., S, D/2)."""
    half = head_dim // 2
    inv = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def mrope_angles(
    positions: Array, head_dim: int, theta: float, sections: Tuple[int, int, int]
) -> Tuple[Array, Array]:
    """Multimodal RoPE (Qwen2-VL): positions (B, 3, S) — (t, h, w) ids.

    Frequency slot i takes its position id from the section it belongs to.
    sections sum to head_dim//2.
    """
    half = head_dim // 2
    assert sum(sections) == half, f"M-RoPE sections {sections} != head_dim/2 {half}"
    inv = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    sec_id = jnp.concatenate(
        [jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)]
    )  # (half,)
    # gather per-frequency positions: (B, 3, S) -> (B, S, half)
    pos = jnp.take(positions, sec_id, axis=1)  # (B, half, S)
    pos = jnp.swapaxes(pos, -1, -2).astype(jnp.float32)  # (B, S, half)
    ang = pos * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rotary(x: Array, cos: Array, sin: Array) -> Array:
    """Rotate-half convention.  x (B, S, H, D); cos/sin (B|1, S, D/2)."""
    d = x.shape[-1]
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    c = cos[..., None, :].astype(x.dtype)  # (B, S, 1, D/2)
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def default_positions(batch: int, seq: int, offset: Array | int = 0) -> Array:
    return jnp.arange(seq, dtype=jnp.int32)[None, :] + jnp.asarray(offset, jnp.int32)


def angles_for(cfg, positions: Array) -> Tuple[Array, Array]:
    """positions: (B, S) for rope, (B, 3, S) for mrope."""
    d = cfg.resolved_head_dim
    if cfg.rope_type == "mrope":
        return mrope_angles(positions, d, cfg.rope_theta, cfg.mrope_sections)
    return rope_angles(positions, d, cfg.rope_theta)


# ---------------------------------------------------------------------------
# flash attention (chunked streaming softmax, pure JAX)
# ---------------------------------------------------------------------------


def _pad_to(x: Array, size: int, axis: int) -> Array:
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def flash_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 2048,
    kv_chunk: int = 1024,
    valid_len: Optional[Array] = None,
    skip_masked_blocks: bool = False,
) -> Array:
    """Streaming-softmax attention.

    Args:
      q: (B, Sq, KV, G, D);  k/v: (B, Sk, KV, D).
      causal: apply causal mask with q positions aligned to the *end* of k
        (standard self-attention when Sq == Sk).
      window: sliding-window size (0 = full).
      valid_len: optional (B,) — mask out k positions >= valid_len.
      skip_masked_blocks: unroll the outer loop and statically skip KV
        chunks that are entirely masked by causality/window (perf variant —
        identical output, fewer FLOPs; see EXPERIMENTS.md §Perf).

    Returns (B, Sq, KV, G, D).
    """
    B, Sq, KV, G, D = q.shape
    Sk = k.shape[1]
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    qpad = (-Sq) % q_chunk
    kpad = (-Sk) % kv_chunk
    q = _pad_to(q, Sq + qpad, 1)
    k = _pad_to(k, Sk + kpad, 1)
    v = _pad_to(v, Sk + kpad, 1)
    nq, nk = (Sq + qpad) // q_chunk, (Sk + kpad) // kv_chunk
    scale = D ** -0.5
    q_offset = Sk - Sq  # causal alignment (q last token attends to k last)

    kq = jnp.moveaxis(q.reshape(B, nq, q_chunk, KV, G, D), 1, 0)
    kk = jnp.moveaxis(k.reshape(B, nk, kv_chunk, KV, D), 1, 0)
    kv = jnp.moveaxis(v.reshape(B, nk, kv_chunk, KV, D), 1, 0)

    def _one_q_chunk(qc, qi, kk, kv, nk_eff):
        qpos = qi * q_chunk + jnp.arange(q_chunk) + q_offset

        def body(carry, xs):
            m, l, acc = carry
            kc, vc, j = xs
            kpos = j * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum(
                "bqhgd,bkhd->bhgqk", qc, kc, preferred_element_type=jnp.float32
            ) * scale
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window > 0:
                mask &= (qpos[:, None] - kpos[None, :]) < window
            mask &= (kpos < Sk)[None, :]
            maskb = mask[None, None, None]  # (1,1,1,q,k)
            if valid_len is not None:
                vl = valid_len[:, None, None, None, None]
                maskb = maskb & (kpos[None, None, None, None, :] < vl)
            s = jnp.where(maskb, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32,
            )
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_chunk, D), jnp.float32)
        if skip_masked_blocks:
            # static python loop; only blocks intersecting the causal/window
            # band are executed.
            carry = (m0, l0, a0)
            qi_static = int(qi)
            q_lo = qi_static * q_chunk + q_offset
            q_hi = q_lo + q_chunk - 1
            for j in range(nk_eff):
                k_lo, k_hi = j * kv_chunk, (j + 1) * kv_chunk - 1
                if causal and k_lo > q_hi:
                    continue  # entirely in the future
                if window > 0 and (q_lo - k_hi) >= window:
                    continue  # entirely out of the window
                carry, _ = body(carry, (kk[j], kv[j], jnp.int32(j)))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(
                body, (m0, l0, a0), (kk, kv, jnp.arange(nk_eff))
            )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.moveaxis(out, 3, 1)  # (B, q_chunk, KV, G, D)

    if skip_masked_blocks:
        outs = [ _one_q_chunk(kq[i], i, kk, kv, nk) for i in range(nq) ]
        out = jnp.stack(outs, axis=0)
    else:
        out = jax.lax.map(
            lambda xs: _one_q_chunk(xs[0], xs[1], kk, kv, nk),
            (kq, jnp.arange(nq)),
        )
    out = jnp.moveaxis(out, 0, 1).reshape(B, nq * q_chunk, KV, G, D)
    return out[:, :Sq].astype(q.dtype)


def decode_attention(
    q: Array,
    k_cache: Array,
    v_cache: Array,
    valid_mask: Array,
) -> Array:
    """One-token attention over a KV cache.

    q: (B, 1, KV, G, D); caches (B, S, KV, D); valid_mask (B, S) bool.
    Memory-bound — the whole cache streams through once.  The Pallas
    ``decode_attention`` kernel tiles this over KV blocks in VMEM.
    """
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk", q, k_cache, preferred_element_type=jnp.float32
    ) * (q.shape[-1] ** -0.5)
    s = jnp.where(valid_mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhgqk,bkhd->bqhgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block (projections + cache plumbing)
# ---------------------------------------------------------------------------


def init_attention(key, cfg, d_model: Optional[int] = None) -> Params:
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": module.maybe_factorized(ks[0], d, cfg.num_heads * hd, cfg, cfg.pdtype),
        "wk": module.maybe_factorized(ks[1], d, cfg.num_kv_heads * hd, cfg, cfg.pdtype),
        "wv": module.maybe_factorized(ks[2], d, cfg.num_kv_heads * hd, cfg, cfg.pdtype),
        "wo": module.maybe_factorized(ks[3], cfg.num_heads * hd, d, cfg, cfg.pdtype),
    }


def qkv(params: Params, cfg, x: Array) -> Tuple[Array, Array, Array]:
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    KV, G = cfg.num_kv_heads, cfg.q_per_kv
    q = module.linear(params["wq"], x).reshape(B, S, KV, G, hd)
    k = module.linear(params["wk"], x).reshape(B, S, KV, hd)
    v = module.linear(params["wv"], x).reshape(B, S, KV, hd)
    return q, k, v


def self_attention(
    params: Params,
    cfg,
    x: Array,
    cos: Array,
    sin: Array,
    *,
    causal: bool = True,
    skip_masked_blocks: bool = False,
) -> Array:
    """Full-sequence self attention (train / prefill)."""
    B, S, _ = x.shape
    q, k, v = qkv(params, cfg, x)
    if cfg.rope_type != "none":
        qf = q.reshape(B, S, -1, q.shape[-1])
        q = apply_rotary(qf, cos, sin).reshape(q.shape)
        k = apply_rotary(k, cos, sin)
    from repro.sharding.context import constrain_attention_q
    q, k, v = constrain_attention_q(q, k, v)
    out = flash_attention(
        q, k, v,
        causal=causal,
        window=cfg.sliding_window,
        q_chunk=cfg.q_chunk,
        kv_chunk=cfg.kv_chunk,
        skip_masked_blocks=skip_masked_blocks,
    )
    out = out.reshape(B, S, cfg.num_heads * cfg.resolved_head_dim)
    return module.linear(params["wo"], out)


def _quantize_kv(t: Array) -> Tuple[Array, Array]:
    """Per-token-per-head int8 quantization.  t (B, 1, KV, D) ->
    (int8 values, (B, 1, KV) f32 scales)."""
    scale = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def decode_self_attention(
    params: Params,
    cfg,
    x: Array,
    cache_k: Array,
    cache_v: Array,
    cache_len: Array,
    cos: Array,
    sin: Array,
    cache_scales: Optional[Tuple[Array, Array]] = None,
):
    """One-token decode step.

    x: (B, 1, d); caches (B, Smax, KV, D); cache_len scalar int32 —
    number of tokens already in the cache.  With sliding-window configs the
    cache is a ring buffer of size ``window`` and all live entries are
    valid.  When ``cache_scales`` is given the caches are int8 with
    per-token-per-head scales (B, Smax, KV) — the §Perf memory-term
    iteration for decode shapes.

    Returns (out, new_cache_k, new_cache_v[, new_scales]).
    """
    B, _, _ = x.shape
    Smax = cache_k.shape[1]
    q, k, v = qkv(params, cfg, x)
    if cfg.rope_type != "none":
        qf = q.reshape(B, 1, -1, q.shape[-1])
        q = apply_rotary(qf, cos, sin).reshape(q.shape)
        k = apply_rotary(k, cos, sin)
    slot = jnp.where(cfg.sliding_window > 0, cache_len % Smax, cache_len)
    if cache_scales is not None:
        k_scale_c, v_scale_c = cache_scales
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, kq, slot, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, vq, slot, axis=1)
        k_scale_c = jax.lax.dynamic_update_slice_in_dim(k_scale_c, ks, slot, axis=1)
        v_scale_c = jax.lax.dynamic_update_slice_in_dim(v_scale_c, vs, slot, axis=1)
        k_full = cache_k.astype(cfg.cdtype) * k_scale_c[..., None].astype(cfg.cdtype)
        v_full = cache_v.astype(cfg.cdtype) * v_scale_c[..., None].astype(cfg.cdtype)
    else:
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, slot, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, slot, axis=1)
        k_full, v_full = cache_k, cache_v
    pos = jnp.arange(Smax)
    valid = (pos[None, :] <= cache_len) if cfg.sliding_window == 0 else (
        pos[None, :] <= jnp.minimum(cache_len, Smax - 1)
    )
    valid = jnp.broadcast_to(valid, (B, Smax))
    out = decode_attention(q, k_full, v_full, valid)
    out = out.reshape(B, 1, cfg.num_heads * cfg.resolved_head_dim)
    out = module.linear(params["wo"], out)
    if cache_scales is not None:
        return out, cache_k, cache_v, (k_scale_c, v_scale_c)
    return out, cache_k, cache_v


def cross_attention(
    params: Params, cfg, x: Array, mem_k: Array, mem_v: Array,
    mem_mask: Optional[Array] = None,
) -> Array:
    """Decoder cross-attention over precomputed encoder memory K/V.

    mem_k/mem_v: (B, Sm, KV, D).  No RoPE on cross-attention (seamless
    convention).  Uses the decode kernel shape when Sq==1.
    """
    B, Sq, _ = x.shape
    hd = cfg.resolved_head_dim
    KV, G = cfg.num_kv_heads, cfg.q_per_kv
    q = module.linear(params["wq"], x).reshape(B, Sq, KV, G, hd)
    Sm = mem_k.shape[1]
    if mem_mask is None:
        mem_mask = jnp.ones((B, Sm), bool)
    if Sq == 1:
        out = decode_attention(q, mem_k, mem_v, mem_mask)
    else:
        out = flash_attention(
            q, mem_k, mem_v, causal=False,
            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
            valid_len=jnp.sum(mem_mask, -1).astype(jnp.int32),
        )
    out = out.reshape(B, Sq, cfg.num_heads * hd)
    return module.linear(params["wo"], out)


def init_cross_attention(key, cfg) -> Params:
    """Cross-attn projections: q from decoder, k/v precomputed from memory."""
    return init_attention(key, cfg)


def encode_memory(params: Params, cfg, mem: Array) -> Tuple[Array, Array]:
    """Precompute cross-attention K/V from encoder output (B, Sm, d)."""
    B, Sm, _ = mem.shape
    hd = cfg.resolved_head_dim
    k = module.linear(params["wk"], mem).reshape(B, Sm, cfg.num_kv_heads, hd)
    v = module.linear(params["wv"], mem).reshape(B, Sm, cfg.num_kv_heads, hd)
    return k, v
