"""Unified model API over every architecture family.

Pure functions keyed off ``cfg.family``:

  init(key, cfg)                                   -> params
  forward(params, cfg, batch)                      -> (logits, aux_loss)
  loss_fn(params, cfg, batch)                      -> (loss, metrics)
  init_cache(cfg, batch, max_len)                  -> cache
  prefill(params, cfg, batch, cache)               -> (logits, cache)
  serve_step(params, cfg, batch, cache, cache_len) -> (logits, cache)

Batch keys (all optional except labels for training):
  tokens      (B, S) int32
  embeddings  (B, S, d)    — stub frontend output ([vlm]/[audio] carve-out)
  positions   (B, S) or (B, 3, S) for M-RoPE
  enc_embeddings (B, S_enc, d), enc_mask (B, S_enc)  — enc-dec only
  labels      (B, S) int32
  loss_mask   (B, S)
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, encdec, layers, module, transformer
from repro.sharding.context import constrain_residual

Array = jax.Array
Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init(key, cfg) -> Params:
    ke, ks, ko = jax.random.split(key, 3)
    p: Params = {
        "embed": module.init_embedding(ke, cfg.vocab, cfg.d_model, cfg.pdtype),
        "final_norm": layers.init_norm(cfg.d_model, cfg.norm, cfg.pdtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = module.init_embedding(ko, cfg.vocab, cfg.d_model, cfg.pdtype)
    if cfg.family == "audio" or cfg.encdec is not None:
        p["stack"] = encdec.init_encdec(ks, cfg)
    elif cfg.family == "hybrid":
        p["stack"] = transformer.init_hybrid_stack(ks, cfg)
    elif cfg.family == "ssm":
        p["stack"] = transformer.init_xlstm_stack(ks, cfg)
    else:  # dense / moe / vlm
        p["stack"] = transformer.init_stack(ks, cfg)
    return p


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------


def _input_embeddings(params, cfg, batch) -> Array:
    if "embeddings" in batch:
        return batch["embeddings"].astype(cfg.cdtype)
    x = layers.embed(params["embed"], batch["tokens"], cfg.cdtype)
    if cfg.arch_id.startswith("gemma"):  # gemma scales embeddings by sqrt(d)
        x = x * jnp.asarray(cfg.d_model**0.5, cfg.cdtype)
    return x


def _positions(cfg, batch, seq: int, batchsize: int, offset=0):
    if "positions" in batch:
        return batch["positions"]
    if cfg.rope_type == "mrope":
        pos = attention.default_positions(batchsize, seq, offset)
        return jnp.broadcast_to(pos[:, None, :], (pos.shape[0], 3, seq))
    return attention.default_positions(batchsize, seq, offset)


def _unembed(params, cfg, x: Array) -> Array:
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return layers.unembed(table, x, cfg.logit_softcap)


# ---------------------------------------------------------------------------
# forward (train / eval, full sequence)
# ---------------------------------------------------------------------------


def forward(params: Params, cfg, batch: Dict[str, Array],
            skip_blocks: bool = False) -> Tuple[Array, Array]:
    x = constrain_residual(_input_embeddings(params, cfg, batch))
    B, S, _ = x.shape
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "audio" or cfg.encdec is not None:
        mem = batch["enc_embeddings"].astype(cfg.cdtype)
        mem_mask = batch.get("enc_mask")
        enc_pos = attention.default_positions(mem.shape[0], mem.shape[1])
        ecos, esin = attention.angles_for(cfg, enc_pos)
        memory = encdec.encode(params["stack"], cfg, mem, mem_mask, ecos, esin)
        pos = _positions(cfg, batch, S, B)
        cos, sin = attention.angles_for(cfg, pos)
        x = encdec.decode_train(params["stack"], cfg, x, memory, mem_mask, cos, sin)
    elif cfg.family == "ssm":
        x, aux = transformer.apply_xlstm(params["stack"], cfg, x)
    else:
        pos = _positions(cfg, batch, S, B)
        cos, sin = attention.angles_for(cfg, pos)
        if cfg.family == "hybrid":
            x, aux = transformer.apply_hybrid(params["stack"], cfg, x, cos, sin, skip_blocks)
        else:
            x, aux = transformer.apply_stack(params["stack"], cfg, x, cos, sin, skip_blocks)
    x = layers.apply_norm(params["final_norm"], x, cfg.norm)
    return _unembed(params, cfg, x), aux


def loss_fn(params: Params, cfg, batch: Dict[str, Array],
            skip_blocks: bool = False) -> Tuple[Array, Dict[str, Array]]:
    logits, aux = forward(params, cfg, batch, skip_blocks)
    ce = layers.cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


def init_cache(cfg, batch: int, max_len: int) -> Dict[str, Any]:
    if cfg.family == "audio" or cfg.encdec is not None:
        return encdec.init_encdec_cache(cfg, batch, max_len)
    cache_len = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    if cfg.family == "hybrid":
        return transformer.init_hybrid_cache(cfg, batch, cache_len)
    if cfg.family == "ssm":
        return transformer.init_xlstm_cache(cfg, batch)
    return transformer.init_kv_cache(cfg, batch, cache_len)


def prefill(params: Params, cfg, batch: Dict[str, Array],
            cache: Optional[Dict[str, Any]] = None) -> Tuple[Array, Optional[Dict[str, Any]]]:
    """Full-sequence forward; for enc-dec additionally encodes memory into
    the cache.  (KV-cache write-back during prefill is modelled as the
    forward pass — the dry-run shape that matters is the full-sequence
    attention itself.)"""
    if cfg.family == "audio" or cfg.encdec is not None:
        mem = batch["enc_embeddings"].astype(cfg.cdtype)
        mem_mask = batch.get("enc_mask", jnp.ones(mem.shape[:2], bool))
        enc_pos = attention.default_positions(mem.shape[0], mem.shape[1])
        ecos, esin = attention.angles_for(cfg, enc_pos)
        memory = encdec.encode(params["stack"], cfg, mem, mem_mask, ecos, esin)
        if cache is not None:
            cache = encdec.prefill_memory(params["stack"], cfg, memory, mem_mask, cache)
        x = _input_embeddings(params, cfg, batch)
        B, S, _ = x.shape
        pos = _positions(cfg, batch, S, B)
        cos, sin = attention.angles_for(cfg, pos)
        x = encdec.decode_train(params["stack"], cfg, x, memory, mem_mask, cos, sin)
        x = layers.apply_norm(params["final_norm"], x, cfg.norm)
        return _unembed(params, cfg, x), cache
    logits, _ = forward(params, cfg, batch)
    return logits, cache


def serve_step(params: Params, cfg, batch: Dict[str, Array],
               cache: Dict[str, Any], cache_len: Array) -> Tuple[Array, Dict[str, Any]]:
    """One new token given a populated cache.  batch["tokens"]: (B, 1)."""
    x = _input_embeddings(params, cfg, batch)
    B = x.shape[0]
    pos = batch.get("positions")
    if pos is None:
        if cfg.rope_type == "mrope":
            p1 = jnp.broadcast_to(cache_len.astype(jnp.int32), (B, 3, 1))
            pos = p1
        else:
            pos = jnp.broadcast_to(cache_len.astype(jnp.int32), (B, 1))
    cos, sin = attention.angles_for(cfg, pos)
    if cfg.family == "audio" or cfg.encdec is not None:
        x, cache = encdec.decode_step(params["stack"], cfg, x, cache, cache_len, cos, sin)
    elif cfg.family == "hybrid":
        x, cache = transformer.decode_hybrid(params["stack"], cfg, x, cache, cache_len, cos, sin)
    elif cfg.family == "ssm":
        x, cache = transformer.decode_xlstm(params["stack"], cfg, x, cache)
    else:
        x, cache = transformer.decode_stack(params["stack"], cfg, x, cache, cache_len, cos, sin)
    x = layers.apply_norm(params["final_norm"], x, cfg.norm)
    return _unembed(params, cfg, x), cache
