"""Architecture zoo: pure-JAX, stacked params + lax.scan over depth."""

from repro.models import model  # noqa: F401
