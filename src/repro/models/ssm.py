"""Mamba2 (SSD) block — chunked, matmul-dominant formulation for TPU.

State space:  h_t = a_t * h_{t-1} + dt_t * (B_t  x_t^T),   y_t = C_t h_t + D x_t
with a_t = exp(-dt_t * exp(A_log))  (scalar per head), h in R^{N x P}.

The chunked (SSD) algorithm splits the sequence into chunks of length Q:
  * intra-chunk: quadratic-in-Q masked matmul  (MXU-friendly)
  * inter-chunk: a length-T/Q ``lax.scan`` carrying the (H, N, P) state
so the lowered HLO is a short scan over big matmuls — exactly the structure
the Mamba2 paper derives, adapted here to jnp/einsum (no CUDA scan
primitives needed; the TPU analogue of their fused kernel is the chunk
matmul batch, which XLA maps onto the MXU).

Decode: O(1) recurrent step carrying (conv_state, ssm_state).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers, module

Array = jax.Array
Params = Dict[str, Any]


def dims(cfg) -> Tuple[int, int, int, int]:
    """(d_inner, n_heads, head_dim P, state_dim N)."""
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    assert d_inner % s.head_dim == 0
    return d_inner, d_inner // s.head_dim, s.head_dim, s.state_dim


def init_mamba2(key, cfg, dtype) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, H, P, N = dims(cfg)
    conv_ch = d_inner + 2 * N  # x plus B and C go through the conv
    ks = jax.random.split(key, 5)
    in_dim = 2 * d_inner + 2 * N + H  # z, x, B, C, dt
    return {
        "in_proj": module.maybe_factorized(ks[0], d, in_dim, cfg, dtype),
        "conv_w": 0.1 * jax.random.normal(ks[1], (s.conv_width, conv_ch), dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(0.01 * jnp.ones((H,), jnp.float32))),
        "norm": layers.init_norm(d_inner, "rmsnorm", dtype),
        "out_proj": module.maybe_factorized(ks[4], d_inner, d, cfg, dtype),
    }


def _split_proj(zxbcdt: Array, cfg):
    d_inner, H, P, N = dims(cfg)
    z, x, b, c, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1
    )
    return z, x, b, c, dt


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv over (B, T, C) with kernel (W, C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(W)
    )
    return out + b[None, None, :]


def ssd_chunked(
    x: Array, dt: Array, A: Array, Bm: Array, Cm: Array, chunk: int,
    init_state: Array | None = None,
) -> Tuple[Array, Array]:
    """Chunked selective-state-space scan.

    x (B,T,H,P), dt (B,T,H) (post-softplus), A (H,) (positive decay rates),
    Bm/Cm (B,T,N) (single group shared by all heads).
    Returns (y (B,T,H,P), final_state (B,H,N,P)).
    """
    Bsz, T, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, T)
    pad = (-T) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    nc = Tp // Q

    # log-decay per step: la_t = -dt_t * A  (shape B,T,H) — kept f32
    la = (-dt * A[None, None, :]).astype(jnp.float32)
    xw = x * dt[..., None].astype(x.dtype)  # dt-weighted input, model dtype

    def reshape_c(a, extra=()):
        return a.reshape(Bsz, nc, Q, *a.shape[2:])

    xc, lac, bc, cc = reshape_c(xw), reshape_c(la), reshape_c(Bm), reshape_c(Cm)
    cum = jnp.cumsum(lac, axis=2)  # (B,nc,Q,H) cumulative log decay in chunk
    total = cum[:, :, -1]  # (B,nc,H)

    # ---- intra-chunk (quadratic in Q) --------------------------------
    # decay(i,j) = exp(cum_i - cum_j) for i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q_i,Q_j,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)  # (B,nc,Qi,Qj)
    y_intra = jnp.einsum(
        "bcij,bcijh,bcjhp->bcihp", scores, decay.astype(scores.dtype), xc
    )

    # ---- chunk summary states ----------------------------------------
    # S_c = sum_j exp(total - cum_j) B_j (xw_j)^T   -> (B,nc,H,N,P)
    w = jnp.exp(total[:, :, None] - cum)  # (B,nc,Q,H)
    S = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", bc, w.astype(xc.dtype), xc)

    # ---- inter-chunk scan ---------------------------------------------
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, N, P), x.dtype)

    def step(h, inp):
        s_c, tot_c = inp  # (B,H,N,P), (B,H)
        h_new = h * jnp.exp(tot_c)[:, :, None, None].astype(h.dtype) + s_c
        return h_new, h  # emit state *entering* the chunk

    (h_final, h_in) = jax.lax.scan(
        step,
        init_state,
        (jnp.moveaxis(S, 1, 0), jnp.moveaxis(total, 1, 0)),
    )
    h_in = jnp.moveaxis(h_in, 0, 1)  # (B,nc,H,N,P) state entering chunk

    # ---- inter-chunk contribution -------------------------------------
    # y_inter_i = exp(cum_i) * C_i @ h_in
    decay_in = jnp.exp(cum)[..., None, None].astype(x.dtype)  # (B,nc,Q,H,1,1)
    y_inter = jnp.einsum(
        "bcin,bcihnp->bcihp", cc, decay_in * h_in[:, :, None]
    )
    y = (y_intra + y_inter).reshape(Bsz, Tp, H, P)[:, :T]
    return y, h_final


def apply_mamba2(params: Params, cfg, u: Array) -> Array:
    """Full-sequence Mamba2 block.  u: (B, T, d_model)."""
    s = cfg.ssm
    d_inner, H, P, N = dims(cfg)
    zxbcdt = module.linear(params["in_proj"], u)
    z, x, b, c, dt = _split_proj(zxbcdt, cfg)
    xbc = jnp.concatenate([x, b, c], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, params["conv_w"].astype(u.dtype),
                                   params["conv_b"].astype(u.dtype)))
    x, b, c = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None, None, :])
    A = jnp.exp(params["A_log"])
    xh = x.reshape(*x.shape[:2], H, P)
    y, _ = ssd_chunked(xh, dt, A, b.astype(jnp.float32).astype(u.dtype),
                       c.astype(jnp.float32).astype(u.dtype), s.chunk)
    y = y + params["D"].astype(u.dtype)[None, None, :, None] * xh
    y = y.reshape(*u.shape[:2], d_inner)
    y = layers.apply_norm(params["norm"], y, "rmsnorm") * jax.nn.silu(z)
    return module.linear(params["out_proj"], y)


# ---------------------------------------------------------------------------
# decode (single-token recurrent step)
# ---------------------------------------------------------------------------


def init_mamba2_cache(cfg, batch: int, dtype) -> Dict[str, Array]:
    s = cfg.ssm
    d_inner, H, P, N = dims(cfg)
    conv_ch = d_inner + 2 * N
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_ch), dtype),
        "state": jnp.zeros((batch, H, N, P), dtype),
    }


def apply_mamba2_decode(
    params: Params, cfg, u: Array, cache: Dict[str, Array]
) -> Tuple[Array, Dict[str, Array]]:
    """One token.  u: (B, 1, d_model)."""
    s = cfg.ssm
    d_inner, H, P, N = dims(cfg)
    zxbcdt = module.linear(params["in_proj"], u)
    z, x, b, c, dt = _split_proj(zxbcdt, cfg)
    xbc = jnp.concatenate([x, b, c], axis=-1)  # (B,1,conv_ch)
    hist = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B,W,conv_ch)
    w = params["conv_w"].astype(u.dtype)
    out = jnp.einsum("bwc,wc->bc", hist, w) + params["conv_b"].astype(u.dtype)
    xbc1 = jax.nn.silu(out)[:, None, :]
    new_conv = hist[:, 1:]
    x, b, c = jnp.split(xbc1, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None, None, :])
    A = jnp.exp(params["A_log"])
    a = jnp.exp(-dt[:, 0] * A[None, :])  # (B,H)
    xh = x.reshape(x.shape[0], H, P)
    dBx = jnp.einsum("bn,bhp->bhnp", b[:, 0], xh * dt[:, 0][..., None].astype(u.dtype))
    state = cache["state"] * a[:, :, None, None].astype(u.dtype) + dBx
    y = jnp.einsum("bn,bhnp->bhp", c[:, 0], state)
    y = y + params["D"].astype(u.dtype)[None, :, None] * xh
    y = y.reshape(u.shape[0], 1, d_inner)
    y = layers.apply_norm(params["norm"], y, "rmsnorm") * jax.nn.silu(z)
    return module.linear(params["out_proj"], y), {"conv": new_conv, "state": state}
