"""Norms, MLPs, embeddings — shared across all architecture families."""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import module

Array = jax.Array
Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# normalisation
# ---------------------------------------------------------------------------


def init_norm(d: int, kind: str, dtype) -> Params:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(params: Params, x: Array, kind: str, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * params["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (gated + plain)
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, d_ff: int, activation: str, cfg, dtype) -> Params:
    ks = jax.random.split(key, 3)
    p = {"down": module.maybe_factorized(ks[2], d_ff, d, cfg, dtype)}
    if activation in ("swiglu", "geglu"):
        p["gate"] = module.maybe_factorized(ks[0], d, d_ff, cfg, dtype)
        p["up"] = module.maybe_factorized(ks[1], d, d_ff, cfg, dtype)
    else:
        p["up"] = module.maybe_factorized(ks[1], d, d_ff, cfg, dtype)
    return p


def apply_mlp(params: Params, x: Array, activation: str) -> Array:
    if activation == "swiglu":
        g = jax.nn.silu(module.linear(params["gate"], x))
        h = g * module.linear(params["up"], x)
    elif activation == "geglu":
        g = jax.nn.gelu(module.linear(params["gate"], x), approximate=True)
        h = g * module.linear(params["up"], x)
    else:  # gelu
        h = jax.nn.gelu(module.linear(params["up"], x), approximate=True)
    return module.linear(params["down"], h)


def mlp_flops(d: int, d_ff: int, activation: str, tokens: int) -> int:
    n = 3 if activation in ("swiglu", "geglu") else 2
    return 2 * n * d * d_ff * tokens


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------


def embed(params: Params, tokens: Array, compute_dtype) -> Array:
    return jnp.take(params["table"], tokens, axis=0).astype(compute_dtype)


def unembed(params: Params, x: Array, softcap: float = 0.0) -> Array:
    logits = x @ params["table"].T.astype(x.dtype)
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


def cross_entropy(logits: Array, labels: Array, mask: Array | None = None) -> Array:
    """Mean token-level cross-entropy; logits (..., V), labels (...) int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
