"""Mixture-of-Experts FFN: top-k router + capacity-based GShard dispatch.

Baseline dispatch is the classic dense one-hot formulation (GShard /
Switch): a ``(T, E, C)`` combine tensor routes tokens to expert slots via
two einsums.  It is fully shardable under pjit — experts live on the
``model`` mesh axis, the token→expert einsum lowers to an all-to-all — and
is the *baseline* for the roofline; the §Perf log measures the dispatch
overhead and evaluates a sort-based alternative.

Capacity: C = ceil(T * top_k * capacity_factor / E), tokens over capacity
are dropped (residual passes through — standard).  Aux load-balance loss
follows Switch: E * sum_e f_e * p_e.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers, module

Array = jax.Array
Params = Dict[str, Any]


def init_moe(key, cfg, dtype) -> Params:
    m = cfg.moe
    d, de, E = cfg.d_model, m.d_expert, m.num_experts
    ks = jax.random.split(key, 5)
    scale = 1.0 / jnp.sqrt(d)
    p: Params = {
        "router": {"w": scale * jax.random.normal(ks[0], (d, E), jnp.float32)},
        "gate": scale * jax.random.normal(ks[1], (E, d, de), dtype),
        "up": scale * jax.random.normal(ks[2], (E, d, de), dtype),
        "down": (1.0 / jnp.sqrt(de)) * jax.random.normal(ks[3], (E, de, d), dtype),
    }
    if m.num_shared_experts:
        p["shared"] = layers.init_mlp(
            ks[4], d, de * m.num_shared_experts, cfg.activation, cfg, dtype
        )
    return p


def capacity(tokens: int, cfg) -> int:
    import math

    m = cfg.moe
    c = math.ceil(tokens * m.top_k * m.capacity_factor / m.num_experts)
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def router_topk(router_params: Params, x2d: Array, cfg) -> Tuple[Array, Array, Array]:
    """Returns (probs (T,E) f32, topk gate values (T,k), topk ids (T,k))."""
    logits = (x2d.astype(jnp.float32) @ router_params["w"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, cfg.moe.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    return probs, gates, ids


def make_combine(probs: Array, gates: Array, ids: Array, cfg, cap: int) -> Tuple[Array, Array]:
    """GShard combine tensor (T, E, C) and aux loss."""
    T, E = probs.shape
    k = cfg.moe.top_k
    counts = jnp.zeros((E,), jnp.int32)
    combine = jnp.zeros((T, E, cap), jnp.float32)
    for slot in range(k):  # static small loop over top-k slots
        e = ids[:, slot]
        onehot = jax.nn.one_hot(e, E, dtype=jnp.int32)  # (T, E)
        pos = jnp.cumsum(onehot, axis=0) - 1 + counts[None, :]  # (T, E)
        pos_t = jnp.take_along_axis(pos, e[:, None], axis=1)[:, 0]  # (T,)
        keep = pos_t < cap
        posoh = jax.nn.one_hot(pos_t, cap, dtype=jnp.float32) * keep[:, None]
        combine = combine + (
            gates[:, slot][:, None, None]
            * jax.nn.one_hot(e, E, dtype=jnp.float32)[:, :, None]
            * posoh[:, None, :]
        )
        counts = counts + jnp.sum(onehot * keep[:, None].astype(jnp.int32), axis=0)
    # Switch aux loss: E * sum_e (token fraction) * (mean prob)
    top1 = ids[:, 0]
    frac = jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32), axis=0)
    mean_p = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac * mean_p)
    return combine, aux


def expert_ffn(params: Params, cfg, xec: Array) -> Array:
    """Per-expert gated FFN on dispatched tokens.  xec: (E, C, d)."""
    g = jnp.einsum("ecd,edf->ecf", xec, params["gate"].astype(xec.dtype))
    u = jnp.einsum("ecd,edf->ecf", xec, params["up"].astype(xec.dtype))
    if cfg.activation == "geglu":
        h = jax.nn.gelu(g, approximate=True) * u
    else:
        h = jax.nn.silu(g) * u
    return jnp.einsum("ecf,efd->ecd", h, params["down"].astype(xec.dtype))


def _moe_group(params, cfg, xg: Array) -> Tuple[Array, Array]:
    """One dispatch group (GShard 'group').  xg: (T, d)."""
    T, d = xg.shape
    cap = capacity(T, cfg)
    probs, gates, ids = router_topk(params["router"], xg, cfg)
    combine, aux = make_combine(probs, gates, ids, cfg, cap)
    dispatch = (combine > 0).astype(xg.dtype)  # (T, E, C)
    xec = jnp.einsum("tec,td->ecd", dispatch, xg)
    yec = expert_ffn(params, cfg, xec)
    y = jnp.einsum("tec,ecd->td", combine.astype(xg.dtype), yec)
    return y, aux


def apply_moe(params: Params, cfg, x: Array) -> Tuple[Array, Array]:
    """x: (B, S, d) -> (y, aux_loss).

    Dispatch is GROUP-WISE (GShard): each batch row is its own dispatch
    group when the sequence is long, so the one-hot combine tensor is
    (T_g, E, C_g) with T_g = S — NOT (B·S, E, C) over the global token
    set.  The ungrouped form makes dispatch FLOPs scale quadratically in
    tokens and was measured at ~800x overhead for kimi-k2 at train_4k
    (EXPERIMENTS.md §Perf iteration 1).  Groups align with the data-
    parallel batch sharding, so no cross-device dispatch traffic is added.
    """
    B, S, d = x.shape
    from repro.sharding.context import get_context
    ctx = get_context()
    if ctx["moe_shardmap"] and ctx["mesh"] is not None:
        # weight-stationary expert parallelism with an explicit psum
        # schedule (repro.models.moe_shardmap) — §Perf variant.
        from repro.models.moe_shardmap import apply_moe_shardmap
        y = apply_moe_shardmap(params, cfg, x, ctx["mesh"])
        return y, jnp.zeros((), jnp.float32)
    if S >= 512 and B > 1:
        y, aux = jax.vmap(lambda xg: _moe_group(params, cfg, xg))(x)
        aux = jnp.mean(aux)
        y = y.reshape(B, S, d)
    else:
        y, aux = _moe_group(params, cfg, x.reshape(B * S, d))
        y = y.reshape(B, S, d)
    if "shared" in params:
        y = y + layers.apply_mlp(params["shared"], x, cfg.activation)
    return y, aux * cfg.moe.router_aux_weight


# ---------------------------------------------------------------------------
# sort-based dispatch (perf variant — §Perf hillclimb)
# ---------------------------------------------------------------------------


def apply_moe_sorted(params: Params, cfg, x: Array) -> Tuple[Array, Array]:
    """Gather/scatter dispatch: sort token-slots by expert, segment the
    sorted buffer into fixed-capacity expert bins, run the same expert FFN,
    scatter back.  Identical math to :func:`apply_moe` on kept tokens (same
    capacity rule, same priority order = token index), but replaces the two
    ``(T,E,C)`` einsums (2·T·E·C·d FLOPs each) with gathers (0 FLOPs).
    Grouped like :func:`apply_moe`.
    """
    B, S, d = x.shape
    if S >= 512 and B > 1:
        y, aux = jax.vmap(lambda xg: _moe_sorted_group(params, cfg, xg))(x)
        y = y.reshape(B, S, d)
        aux = jnp.mean(aux)
        if "shared" in params:
            y = y + layers.apply_mlp(params["shared"], x, cfg.activation)
        return y, aux * cfg.moe.router_aux_weight
    y, aux = _moe_sorted_group(params, cfg, x.reshape(B * S, d))
    y = y.reshape(B, S, d)
    if "shared" in params:
        y = y + layers.apply_mlp(params["shared"], x, cfg.activation)
    return y, aux * cfg.moe.router_aux_weight


def _moe_sorted_group(params: Params, cfg, x2d: Array) -> Tuple[Array, Array]:
    T, d = x2d.shape
    k = cfg.moe.top_k
    cap = capacity(T, cfg)
    E = cfg.moe.num_experts
    probs, gates, ids = router_topk(params["router"], x2d, cfg)

    flat_e = ids.reshape(-1)  # (T*k,) expert of each slot, slot-major per token
    flat_g = gates.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T), k)
    # priority: lower token index first within an expert, matching GShard's
    # cumsum order; stable sort by expert keeps token order within experts.
    order = jnp.argsort(flat_e, stable=True)
    se, sg, st = flat_e[order], flat_g[order], flat_tok[order]
    # position within expert = rank - start_of_expert
    ranks = jnp.arange(T * k)
    starts = jnp.searchsorted(se, jnp.arange(E), side="left")
    pos = ranks - jnp.take(starts, se)
    keep = pos < cap
    slot_idx = jnp.where(keep, se * cap + pos, E * cap)  # overflow bucket
    xbuf = jnp.zeros((E * cap + 1, d), x2d.dtype).at[slot_idx].set(
        jnp.where(keep[:, None], x2d[st], 0)
    )
    yec = expert_ffn(params, cfg, xbuf[:-1].reshape(E, cap, d))
    ybuf = yec.reshape(E * cap, d)
    contrib = jnp.where(keep[:, None], ybuf[jnp.minimum(slot_idx, E * cap - 1)], 0)
    y = jnp.zeros((T, d), x2d.dtype).at[st].add(
        contrib * sg[:, None].astype(x2d.dtype))
    top1 = ids[:, 0]
    frac = jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=0))
    return y, aux
