"""Federated-learning runtime: Heroes + baselines over a simulated
heterogeneous edge network (paper Sec. III / VI)."""

from repro.fl.engine import SCHEMES, build_engine, register_scheme  # noqa: F401
from repro.fl.heterogeneity import HeterogeneityModel  # noqa: F401
from repro.fl.population import (  # noqa: F401
    SCHEDULERS,
    PopulationRegistry,
    VirtualPartition,
)
from repro.fl.models import MODELS, make_cnn, make_resnet, make_rnn  # noqa: F401
from repro.fl.server import RUNNERS, FLConfig  # noqa: F401
from repro.fl.simulation import (  # noqa: F401
    build_image_setup,
    build_runner,
    build_setup,
    build_text_setup,
    run_scheme,
    summarize,
    time_to_accuracy,
    traffic_to_accuracy,
)
from repro.fl.types import RoundLog  # noqa: F401
