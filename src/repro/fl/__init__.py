"""Federated-learning runtime: Heroes + baselines over a simulated
heterogeneous edge network (paper Sec. III / VI)."""

from repro.fl.engine import (SCHEMES, EngineRunner, ServerState,
                             build_engine, register_scheme)
from repro.fl.heterogeneity import HeterogeneityModel
from repro.fl.models import (MODELS, ComposedLayer, FLModelDef, LayerHint,
                             get_model, make_cnn, make_resnet, make_rnn,
                             register_model)
from repro.fl.transformer import (greedy_decode, make_transformer,
                                  serving_weights)
from repro.fl.population import (
    SCHEDULERS,
    PopulationRegistry,
    VirtualPartition,
)
from repro.fl.server import RUNNERS  # deprecated shims onto the engine
from repro.fl.simulation import (
    build_image_setup,
    build_runner,
    build_setup,
    build_text_setup,
    run_scheme,
    summarize,
    time_to_accuracy,
    traffic_to_accuracy,
)
from repro.fl.types import FLConfig, RoundLog

__all__ = [
    "SCHEMES", "EngineRunner", "ServerState", "build_engine",
    "register_scheme",
    "HeterogeneityModel",
    "MODELS", "ComposedLayer", "FLModelDef", "LayerHint",
    "get_model", "register_model",
    "make_cnn", "make_resnet", "make_rnn",
    "make_transformer", "serving_weights", "greedy_decode",
    "SCHEDULERS", "PopulationRegistry", "VirtualPartition",
    "RUNNERS",
    "build_image_setup", "build_runner", "build_setup", "build_text_setup",
    "run_scheme", "summarize", "time_to_accuracy", "traffic_to_accuracy",
    "FLConfig", "RoundLog",
]
