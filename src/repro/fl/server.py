"""FL servers: Heroes (Alg. 1) and the four baselines of Sec. VI-B.

All runners share a skeleton — per round: sample K clients, assign
(width, tau, tensors), run local training, aggregate, charge virtual
wall-clock (Eq. 19) + traffic — and differ exactly where the paper's
schemes differ:

  FedAvg    full model, fixed identical tau                  [2]
  ADP       full model, *adaptive* identical tau             [31]
  HeteroFL  width-sliced sub-models by tier, fixed tau       [13]
  Flanc     original neural composition: per-width coeffs    [15]
  Heroes    enhanced NC (global block counter, block-wise
            aggregation) + per-client adaptive tau           (this paper)
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, convergence
from repro.fl import client as client_lib
from repro.fl.engine.policies import HeroesAssignment, tier_width  # noqa: F401
from repro.fl.heterogeneity import HeterogeneityModel
from repro.fl.models import FLModelDef
from repro.fl.types import FLConfig, RoundLog  # noqa: F401  (re-exported)


class BaseRunner:
    """Common round skeleton; subclasses implement assign/train/aggregate."""

    scheme = "base"

    def __init__(self, model: FLModelDef, parts_x, parts_y, test_batch,
                 het: HeterogeneityModel, cfg: FLConfig, eval_width: int):
        self.model = model
        self.parts_x, self.parts_y = parts_x, parts_y
        self.test_batch = test_batch
        self.het = het
        self.cfg = cfg
        self.eval_width = eval_width
        self.rng = np.random.default_rng(cfg.seed)
        self.wall = 0.0
        self.traffic = 0.0
        self.history: List[RoundLog] = []
        self.round = 0

    # --- subclass API ----------------------------------------------------
    def assign(self, clients) -> Dict[int, Dict[str, Any]]:
        raise NotImplementedError

    def client_payload_bytes(self, assignment) -> float:
        raise NotImplementedError

    def train_one(self, n: int, assignment) -> client_lib.ClientResult:
        raise NotImplementedError

    def aggregate(self, results: Dict[int, client_lib.ClientResult], assigns):
        raise NotImplementedError

    def eval_accuracy(self) -> float:
        raise NotImplementedError

    # --- shared ------------------------------------------------------------
    def flops_per_iter(self, width: int) -> float:
        return self.model.flops_per_sample(width) * self.cfg.batch_size

    def run_round(self) -> RoundLog:
        cfg = self.cfg
        self.het.advance_round()
        clients = self.rng.choice(cfg.num_clients, cfg.clients_per_round, replace=False)
        assigns = self.assign(list(map(int, clients)))
        results, times = {}, {}
        for n, a in assigns.items():
            res = self.train_one(n, a)
            results[n] = res
            mu = self.het.iter_time(n, self.flops_per_iter(a["width"]))
            nu = self.het.upload_time(n, self.client_payload_bytes(a))
            times[n] = a["tau"] * mu + nu
            self.traffic += 2 * self.client_payload_bytes(a)  # down + up
        self.aggregate(results, assigns)
        makespan = max(times.values())
        wait = float(np.mean([makespan - t for t in times.values()]))
        self.wall += makespan
        self.round += 1
        acc = None
        if self.round % cfg.eval_every == 0 or self.round == 1:
            acc = self.eval_accuracy()
        log = RoundLog(self.round, self.wall, self.traffic, makespan, wait,
                       float(np.mean([a["tau"] for a in assigns.values()])), acc)
        self.history.append(log)
        return log

    def run(self, rounds: int) -> List[RoundLog]:
        for _ in range(rounds):
            self.run_round()
        return self.history

    def run_until_budget(self, time_budget: Optional[float] = None,
                         traffic_budget: Optional[float] = None,
                         max_rounds: int = 10_000) -> List[RoundLog]:
        """Paper Alg. 1 outer loop: train while T <= T^max (and/or a
        traffic budget) — the budget-driven form the paper actually runs."""
        assert time_budget or traffic_budget
        for _ in range(max_rounds):
            if time_budget is not None and self.wall >= time_budget:
                break
            if traffic_budget is not None and self.traffic >= traffic_budget:
                break
            self.run_round()
        return self.history

    def _acc_from_logits(self, logits) -> float:
        labels = self.test_batch["labels"]
        pred = jnp.argmax(logits, -1)
        return float(jnp.mean((pred == labels).astype(jnp.float32)))


# ---------------------------------------------------------------------------
# FedAvg / ADP (dense, full width, identical tau)
# ---------------------------------------------------------------------------


class FedAvgRunner(BaseRunner):
    scheme = "fedavg"
    adaptive_tau = False

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self.params = self.model.init_dense(jax.random.PRNGKey(self.cfg.seed))
        self.P = next(iter(self.model.specs.values())).max_width
        self.est_state = convergence.BoundState(
            loss0=2.3, smoothness=1.0, grad_sq=1.0, noise_sq=0.5, lr=self.cfg.lr)

    def assign(self, clients):
        tau = self.cfg.tau_fixed
        if self.adaptive_tau and self.round > 0:
            t = convergence.tau_star(self.est_state, max(200 - self.round, 1))
            tau = int(np.clip(round(t), 1, self.cfg.tau_max))
        return {n: {"width": self.P, "tau": tau} for n in clients}

    def client_payload_bytes(self, a) -> float:
        return self.model.dense_bytes(self.P)

    def train_one(self, n, a):
        res = client_lib.local_train(
            self.model, self.params, self.P, a["tau"],
            self.parts_x[n], self.parts_y[n], self.cfg.lr,
            np.random.default_rng((self.cfg.seed, self.round, n)),
            self.cfg.batch_size, factorized=False, estimate=self.adaptive_tau,
        )
        return res

    def aggregate(self, results, assigns):
        stacked = [r.params for r in results.values()]
        self.params = jax.tree_util.tree_map(
            lambda *xs: jnp.mean(jnp.stack(xs), 0), *stacked
        )
        ests = [r.estimates for r in results.values() if r.estimates]
        if ests:
            mean = {k: float(np.mean([e[k] for e in ests])) for k in ests[0]}
            self.est_state = convergence.BoundState(
                loss0=float(np.mean([r.loss_after for r in results.values()])),
                smoothness=max(mean.get("L", 1.0), 1e-3),
                grad_sq=mean.get("grad_sq", 1.0),
                noise_sq=mean.get("sigma_sq", 0.5),
                lr=self.cfg.lr,
            )

    def eval_accuracy(self):
        logits = self.model.forward(self.params, self.P, self.test_batch)
        return self._acc_from_logits(logits)


class ADPRunner(FedAvgRunner):
    scheme = "adp"
    adaptive_tau = True


# ---------------------------------------------------------------------------
# HeteroFL (dense slices by tier)
# ---------------------------------------------------------------------------


class HeteroFLRunner(FedAvgRunner):
    scheme = "heterofl"

    def assign(self, clients):
        return {n: {"width": tier_width(self.het, n, self.P),
                    "tau": self.cfg.tau_fixed} for n in clients}

    def client_payload_bytes(self, a) -> float:
        return self.model.dense_bytes(a["width"])

    def train_one(self, n, a):
        sub = self.model.slice_dense(self.params, a["width"])
        return client_lib.local_train(
            self.model, sub, a["width"], a["tau"],
            self.parts_x[n], self.parts_y[n], self.cfg.lr,
            np.random.default_rng((self.cfg.seed, self.round, n)),
            self.cfg.batch_size, factorized=False, estimate=False,
        )

    def aggregate(self, results, assigns):
        # element-wise mean over clients covering each region (HeteroFL)
        new = {}
        for name in self.params:
            full = self.params[name]
            acc = jnp.zeros_like(full)
            cnt = jnp.zeros_like(full)
            for n, r in results.items():
                w = r.params[name]
                pad = [(0, full.shape[i] - w.shape[i]) for i in range(full.ndim)]
                acc = acc + jnp.pad(w, pad)
                cnt = cnt + jnp.pad(jnp.ones_like(w), pad)
            covered = cnt > 0
            new[name] = jnp.where(covered, acc / jnp.maximum(cnt, 1), full)
        self.params = new


# ---------------------------------------------------------------------------
# Flanc (original NC: per-width coefficients, same-shape aggregation)
# ---------------------------------------------------------------------------


class FlancRunner(BaseRunner):
    scheme = "flanc"

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        key = jax.random.PRNGKey(self.cfg.seed)
        self.P = next(iter(self.model.specs.values())).max_width
        full = self.model.init_factorized(key)
        # per-width coefficient sets: width p owns its own copy of the
        # first blocks_for_width(p) blocks (original Flanc: no sharing)
        self.basis = {name: full[name]["basis"] for name in full}
        self.coeffs = {
            p: {name: full[name]["coeff"][: self.model.specs[name].blocks_for_width(p)]
                for name in full}
            for p in range(1, self.P + 1)
        }

    def assign(self, clients):
        return {n: {"width": tier_width(self.het, n, self.P),
                    "tau": self.cfg.tau_fixed} for n in clients}

    def client_payload_bytes(self, a) -> float:
        return self.model.factorized_bytes(a["width"])

    def _client_params(self, p):
        return {name: {"basis": self.basis[name], "coeff": self.coeffs[p][name]}
                for name in self.basis}

    def train_one(self, n, a):
        return client_lib.local_train(
            self.model, self._client_params(a["width"]), a["width"], a["tau"],
            self.parts_x[n], self.parts_y[n], self.cfg.lr,
            np.random.default_rng((self.cfg.seed, self.round, n)),
            self.cfg.batch_size, factorized=True, estimate=False,
            forward_impl=self.cfg.forward_impl,
        )

    def aggregate(self, results, assigns):
        bases = [r.params for r in results.values()]
        self.basis = {
            name: jnp.mean(jnp.stack([b[name]["basis"] for b in bases]), 0)
            for name in self.basis
        }
        by_width: Dict[int, list] = {}
        for n, r in results.items():
            by_width.setdefault(assigns[n]["width"], []).append(r.params)
        for p, plist in by_width.items():
            self.coeffs[p] = {
                name: jnp.mean(jnp.stack([c[name]["coeff"] for c in plist]), 0)
                for name in self.basis
            }

    def eval_accuracy(self):
        params = self._client_params(self.P)
        w = self.model.compose_all(params, self.P)
        return self._acc_from_logits(self.model.forward(w, self.P, self.test_batch))


# ---------------------------------------------------------------------------
# Heroes
# ---------------------------------------------------------------------------


class HeroesRunner(BaseRunner):
    scheme = "heroes"

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        key = jax.random.PRNGKey(self.cfg.seed)
        self.params = self.model.init_factorized(key)
        any_spec = next(iter(self.model.specs.values()))
        self.P = any_spec.max_width
        self.state = convergence.BoundState(
            loss0=2.3, smoothness=1.0, grad_sq=1.0, noise_sq=0.5, lr=self.cfg.lr)
        # assignment (scheduler + block/anchored counters) is shared with
        # the engine: one implementation, two runners
        self._policy = HeroesAssignment()
        self._policy.setup(self)

    # the policy reads ``bound_state``; the legacy runner stores it as
    # ``state`` — alias, so both names stay live.
    @property
    def bound_state(self) -> convergence.BoundState:
        return self.state

    @property
    def scheduler(self):
        return self._policy.scheduler

    @property
    def anchored_counters(self):
        return self._policy.anchored_counters

    def assign(self, clients):
        return self._policy.assign(clients)

    def client_payload_bytes(self, a) -> float:
        return self.model.factorized_bytes(a["width"])

    def train_one(self, n, a):
        reduced = self.model.reduce(self.params, a["width"],
                                    a["hidden_ids"], a["anchored_ids"])
        return client_lib.local_train(
            self.model, reduced, a["width"], a["tau"],
            self.parts_x[n], self.parts_y[n], self.cfg.lr,
            np.random.default_rng((self.cfg.seed, self.round, n)),
            self.cfg.batch_size, factorized=True, estimate=self.cfg.estimate,
            forward_impl=self.cfg.forward_impl,
        )

    def aggregate(self, results, assigns):
        # basis: plain average; coefficient: block-wise (Eq. 5), per layer
        new = {}
        for name, spec in self.model.specs.items():
            ids_key = "hidden_ids" if spec.mode == "square" else "anchored_ids"
            new[name] = {
                "basis": aggregation.aggregate_basis(
                    [r.params[name]["basis"] for r in results.values()]),
                "coeff": aggregation.aggregate_coefficient(
                    self.params[name]["coeff"],
                    [r.params[name]["coeff"] for r in results.values()],
                    [np.asarray(assigns[n][ids_key]) for n in results],
                ),
            }
        self.params = new
        ests = [r.estimates for r in results.values() if r.estimates]
        if ests:
            mean = {k: float(np.mean([e[k] for e in ests])) for k in ests[0]}
            self.state = convergence.BoundState(
                loss0=max(float(np.mean([r.loss_after for r in results.values()])), 1e-3),
                smoothness=float(np.clip(mean.get("L", 1.0), 1e-3, 1e3)),
                grad_sq=mean.get("grad_sq", 1.0),
                noise_sq=mean.get("sigma_sq", 0.5),
                lr=self.cfg.lr,
            )

    def eval_accuracy(self):
        # evaluation composes at full width P and reuses the ONE
        # materialised weight set across the whole (streamed) test set —
        # compose is paid once per eval, not per training step, so this
        # stays the materialize path regardless of cfg.forward_impl (and
        # keeps eval accuracies bitwise across forward_impl settings).
        full_ids = np.arange(self.scheduler.spec.num_blocks)
        anch_ids = np.arange(self.P)
        reduced = self.model.reduce(self.params, self.P, full_ids, anch_ids)
        w = self.model.compose_all(reduced, self.P)
        return self._acc_from_logits(self.model.forward(w, self.P, self.test_batch))


RUNNERS = {
    "fedavg": FedAvgRunner,
    "adp": ADPRunner,
    "heterofl": HeteroFLRunner,
    "flanc": FlancRunner,
    "heroes": HeroesRunner,
}
