"""Deprecated legacy runner surface.

The monolithic per-scheme runner classes that used to live here were
retired in favour of the layered engine (:mod:`repro.fl.engine`): a
scheme is now a bundle of assignment / payload / aggregator / trainer /
loop components threading an explicit
:class:`~repro.fl.types.ServerState`.  The engine reproduces the legacy
histories bitwise (pinned by tests/fixtures/golden_legacy_histories.json).

What remains is the old entry-point shape: ``RUNNERS[scheme](...)``
still resolves and returns a ready-to-run runner, but it is a thin shim
that emits a :class:`DeprecationWarning` and builds the engine bundle.
New code should call :func:`repro.fl.engine.build_engine` (or
:func:`repro.fl.simulation.build_runner`) directly.
"""

from __future__ import annotations

import warnings

from repro.fl.engine.policies import tier_width
from repro.fl.types import FLConfig, RoundLog

__all__ = ["RUNNERS", "FLConfig", "RoundLog", "tier_width"]


class _RunnerShim:
    """Callable standing in for a retired legacy runner class."""

    def __init__(self, scheme: str):
        self.scheme = scheme

    def __call__(self, model, parts_x, parts_y, test_batch, het, cfg,
                 eval_width=None):
        warnings.warn(
            f"repro.fl.server.RUNNERS[{self.scheme!r}] is deprecated: the "
            "legacy runner classes were retired; this shim builds the "
            "equivalent engine bundle (repro.fl.engine.build_engine), "
            "which reproduces the legacy histories bitwise.",
            DeprecationWarning, stacklevel=2)
        from repro.fl.engine import build_engine
        return build_engine(self.scheme, model, parts_x, parts_y, test_batch,
                            het, cfg, eval_width)

    def __repr__(self) -> str:  # keep debugger/driver output readable
        return f"<legacy runner shim for {self.scheme!r} (deprecated)>"


RUNNERS = {s: _RunnerShim(s)
           for s in ("fedavg", "adp", "heterofl", "flanc", "heroes")}
