"""Client heterogeneity model (paper Sec. VI-C).

The paper simulates 100 clients on a workstation and models:
  * one-local-iteration time ~ Gaussian per hardware tier (laptop, Jetson
    TX2, Xavier NX, AGX Xavier time records);
  * download bandwidth fluctuating 10–20 Mb/s, upload 1–5 Mb/s.

We reproduce that model: each client gets a tier (compute scale) and
per-round fluctuating bandwidth.  The *scheduler* consumes (mu, nu)
exactly as Alg. 1 does; the *simulator* charges the same costs to the
virtual wall clock.  (TPU-pod hardware is homogeneous, so wall-time
heterogeneity is modelled — DESIGN.md §3.)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

# (mean seconds per 1 GFLOP of local-iteration work, std fraction) —
# scaled from the paper's tier ordering: laptop fastest, TX2 slowest.
TIERS = {
    "laptop": (0.010, 0.10),
    "agx_xavier": (0.018, 0.12),
    "xavier_nx": (0.035, 0.15),
    "tx2": (0.060, 0.20),
}
TIER_NAMES = list(TIERS)


@dataclasses.dataclass
class ClientResources:
    tier: str
    compute_scale: float  # seconds per GFLOP (per-client mean)
    seed: int


class HeterogeneityModel:
    """Per-client, per-round (mu, nu) sampler."""

    def __init__(self, num_clients: int, seed: int = 0,
                 tier_weights: Tuple[float, ...] = (0.25, 0.25, 0.25, 0.25)):
        rng = np.random.default_rng(seed)
        self.clients: Dict[int, ClientResources] = {}
        for n in range(num_clients):
            tier = rng.choice(TIER_NAMES, p=np.asarray(tier_weights) / sum(tier_weights))
            mean, frac = TIERS[tier]
            scale = float(mean * rng.uniform(0.8, 1.2))
            self.clients[n] = ClientResources(str(tier), scale, int(rng.integers(2**31)))
        self._rng = rng
        self.round = 0

    def advance_round(self) -> None:
        self.round += 1

    def iter_time(self, client: int, flops_per_iter: float) -> float:
        """mu_n^h (Eq. 17): seconds for one local iteration."""
        c = self.clients[client]
        rng = np.random.default_rng((c.seed, self.round))
        _, frac = TIERS[c.tier]
        noise = float(np.clip(rng.normal(1.0, frac), 0.5, 2.0))
        return c.compute_scale * (flops_per_iter / 1e9) * noise

    def upload_time(self, client: int, num_bytes: float) -> float:
        """nu_n^h (Eq. 18): upload seconds at 1–5 Mb/s."""
        c = self.clients[client]
        rng = np.random.default_rng((c.seed, self.round, 7))
        mbps = rng.uniform(1.0, 5.0)
        return float(num_bytes * 8 / (mbps * 1e6))

    def download_time(self, client: int, num_bytes: float) -> float:
        """10–20 Mb/s — the paper treats download as negligible vs upload."""
        c = self.clients[client]
        rng = np.random.default_rng((c.seed, self.round, 13))
        mbps = rng.uniform(10.0, 20.0)
        return float(num_bytes * 8 / (mbps * 1e6))
