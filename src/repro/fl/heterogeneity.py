"""Client heterogeneity model (paper Sec. VI-C).

The paper simulates 100 clients on a workstation and models:
  * one-local-iteration time ~ Gaussian per hardware tier (laptop, Jetson
    TX2, Xavier NX, AGX Xavier time records);
  * download bandwidth fluctuating 10–20 Mb/s, upload 1–5 Mb/s.

We reproduce that model: each client gets a tier (compute scale) and
per-round fluctuating bandwidth.  The *scheduler* consumes (mu, nu)
exactly as Alg. 1 does; the *simulator* charges the same costs to the
virtual wall clock.  (TPU-pod hardware is homogeneous, so wall-time
heterogeneity is modelled — DESIGN.md §3.)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple, Union

import numpy as np

# (mean seconds per 1 GFLOP of local-iteration work, std fraction) —
# scaled from the paper's tier ordering: laptop fastest, TX2 slowest.
TIERS = {
    "laptop": (0.010, 0.10),
    "agx_xavier": (0.018, 0.12),
    "xavier_nx": (0.035, 0.15),
    "tx2": (0.060, 0.20),
}
TIER_NAMES = list(TIERS)

# Keyed-stream tag for per-client capability profiles: profiles must be a
# pure function of (seed, client_id), so the stream is disjoint from the
# engine's (seed, round, client) batch streams and the (client_seed,
# round, tag) time/bandwidth streams.
_PROFILE_TAG = 0x9E3779B9


@dataclasses.dataclass
class ClientResources:
    tier: str
    compute_scale: float  # seconds per GFLOP (per-client mean)
    seed: int
    # fraction of rounds the device is reachable (virtual profiles only;
    # resident models predate the field and default to always-on) —
    # consumed by the availability participation scheduler
    availability: float = 1.0


@functools.lru_cache(maxsize=65536)
def client_profile(seed: int, n: int,
                   tier_weights: Tuple[float, ...]) -> ClientResources:
    """Capability profile of client ``n`` as a pure function of the seed.

    Unlike the resident constructor loop (one shared sequential RNG),
    every client draws from its own keyed stream, so the profile is
    independent of the population size and of the order clients are
    queried in — the property that lets 10^5+ client populations exist
    without a resident list (repro.fl.population).
    """
    rng = np.random.default_rng((seed, _PROFILE_TAG, n))
    w = np.asarray(tier_weights, np.float64)
    w = w / w.sum()
    t = int(min(np.searchsorted(np.cumsum(w), rng.random(), side="right"),
                len(TIER_NAMES) - 1))
    tier = TIER_NAMES[t]
    mean, _ = TIERS[tier]
    scale = float(mean * rng.uniform(0.8, 1.2))
    cseed = int(rng.integers(2**31))
    availability = float(rng.uniform(0.35, 0.95))
    return ClientResources(tier, scale, cseed, availability)


class _VirtualClientMap:
    """Lazily derived profiles quacking like the resident clients dict.

    Supports the accesses the runtime makes (``clients[n]``, ``len``,
    ``in``, iteration) while holding nothing per client — each lookup is
    :func:`client_profile`, cached across the process.
    """

    __slots__ = ("size", "seed", "tier_weights")

    def __init__(self, size: int, seed: int, tier_weights: Tuple[float, ...]):
        self.size = size
        self.seed = seed
        self.tier_weights = tier_weights

    def __len__(self) -> int:
        return self.size

    def __contains__(self, n) -> bool:
        return 0 <= int(n) < self.size

    def __iter__(self):
        return iter(range(self.size))

    def __getitem__(self, n) -> ClientResources:
        n = int(n)
        if not 0 <= n < self.size:
            raise KeyError(n)
        return client_profile(self.seed, n, self.tier_weights)


class HeterogeneityModel:
    """Per-client, per-round (mu, nu) sampler.

    ``virtual=True`` derives profiles on demand through
    :func:`client_profile` instead of materializing the resident dict —
    O(1) memory in the population, identical ``iter_time``/
    ``upload_time``/``download_time`` streams given the same profile.
    The resident constructor keeps its original sequential draws so
    existing seeded histories stay bitwise.
    """

    def __init__(self, num_clients: int, seed: int = 0,
                 tier_weights: Tuple[float, ...] = (0.25, 0.25, 0.25, 0.25),
                 virtual: bool = False):
        self.seed = seed
        self.tier_weights = tuple(float(w) for w in tier_weights)
        self.virtual = virtual
        rng = np.random.default_rng(seed)
        if virtual:
            self.clients: Union[Dict[int, ClientResources], _VirtualClientMap] \
                = _VirtualClientMap(num_clients, seed, self.tier_weights)
        else:
            self.clients = {}
            for n in range(num_clients):
                tier = rng.choice(TIER_NAMES, p=np.asarray(tier_weights) / sum(tier_weights))
                mean, frac = TIERS[tier]
                scale = float(mean * rng.uniform(0.8, 1.2))
                self.clients[n] = ClientResources(str(tier), scale, int(rng.integers(2**31)))
        self._rng = rng
        self.round = 0

    def advance_round(self) -> None:
        self.round += 1

    def iter_time(self, client: int, flops_per_iter: float) -> float:
        """mu_n^h (Eq. 17): seconds for one local iteration."""
        c = self.clients[client]
        rng = np.random.default_rng((c.seed, self.round))
        _, frac = TIERS[c.tier]
        noise = float(np.clip(rng.normal(1.0, frac), 0.5, 2.0))
        return c.compute_scale * (flops_per_iter / 1e9) * noise

    def upload_time(self, client: int, num_bytes: float) -> float:
        """nu_n^h (Eq. 18): upload seconds at 1–5 Mb/s."""
        c = self.clients[client]
        rng = np.random.default_rng((c.seed, self.round, 7))
        mbps = rng.uniform(1.0, 5.0)
        return float(num_bytes * 8 / (mbps * 1e6))

    def download_time(self, client: int, num_bytes: float) -> float:
        """10–20 Mb/s — the paper treats download as negligible vs upload."""
        c = self.clients[client]
        rng = np.random.default_rng((c.seed, self.round, 13))
        mbps = rng.uniform(10.0, 20.0)
        return float(num_bytes * 8 / (mbps * 1e6))
