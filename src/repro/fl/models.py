"""Width-scalable FL models (paper Sec. VI-A: CNN / ResNet-ish / RNN).

Every model is described by an ordered dict of ``CompositionSpec``s:
hidden weights use the paper's "square" mode (p^2 blocks from the shared
P^2 counter); boundary layers (first conv / embedding, classifier) use the
anchored modes with their own P-block counter (Flanc's treatment).

Two parameterisations per model:
  * factorized  — params are (basis, coeff-blocks); used by Heroes/Flanc.
  * dense       — params are materialised width-P weights; used by
                  FedAvg/ADP/HeteroFL (pruning slices sub-weights out).

Forward passes are width-polymorphic AND parameterisation-aware: each
layer entry in the weight dict is either a composed ``(ksq, pI, pO)``
array (applied densely — bit-for-bit the historical path) or the raw
``{"basis", "coeff"}`` factors (applied in *rank space* through
:func:`repro.core.composition.apply_factors`, never materialising the
p-width weight).  :meth:`FLModelDef.prepare_weights` builds that dict
from reduced factors under a ``forward_impl`` knob:

  materialize  compose every layer (exactly ``compose_all`` — the
               bitwise reference the seed histories anchor on);
  rank_space   keep factors for every rank-capable layer;
  auto         pick per (layer, width, batch) by the static FLOPs model
               (``apply_flops`` vs ``compose_flops + dense_apply_flops``),
               with per-layer reuse folded into the application count and
               the measured per-host calibration
               (:mod:`repro.core.calibration`) supplying the overheads
               FLOPs cannot see.  Layers that stay weight-shaped may
               still get the internal ``fused_compose`` impl — the
               compose+apply fusion of ``compose_dense_apply`` — when
               the measured gain says it is cheaper than
               compose-then-matmul.

The per-layer apply/compose/FLOPs/hint bundle is the reusable
:class:`ComposedLayer`; model definitions assemble layers with
:meth:`FLModelDef.from_layers` and register themselves in the model
registry (:func:`register_model` / :func:`get_model`) that
``simulation.build_setup`` resolves ``model_name`` through.  The
transformer definition lives in :mod:`repro.fl.transformer` on the same
abstraction.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.composition import (CompositionSpec, apply_factors,
                                    apply_flops, compose, compose_flops,
                                    conv_rank_overhead, dense_apply_flops,
                                    gather_blocks, init_factors,
                                    rank_space_wins)

Array = jax.Array

FORWARD_IMPLS = ("auto", "materialize", "rank_space")


@dataclasses.dataclass(frozen=True)
class LayerHint:
    """Static per-layer facts feeding the ``auto`` forward-impl choice.

    Attributes:
      apps_per_sample: weight applications per input sample per forward
        — conv output positions, RNN sequence steps, 1 for a head — at
        the model's *reference* input geometry (benchmark tables, or
        when no batch is in scope).  Any *reuse* of one composed weight
        (a scan-carried RNN weight hit T times) is folded in here, so
        the decision correctly amortises the one-off compose against
        the true application count.
      apps_fn: optional ``(data_shape) -> apps_per_sample`` deriving the
        count from the actual traced input shape ``(B, ...)`` (image
        H×W, sequence length), so ``auto`` stays correct when inputs
        differ from the reference geometry.  Preferred over the static
        count whenever a batch is available.
      rank_capable: False pins the layer to materialisation regardless
        of FLOPs — e.g. a scan-carried recurrence weight, which is
        composed once per step and reused T times in the carry loop.
      dense_apply_free: the materialised application costs no FLOPs
        (embedding gathers).
      basis_gather: the rank path's basis projection is also a gather
        (``_apply_embed`` indexes R-length basis rows per token), so
        rank space only pays the R→pO coefficient contraction — it
        beats materialisation exactly when the token count per
        evaluation is below the vocabulary size (``apply_flops``'s
        ``basis_is_gather``).
    """

    apps_per_sample: int = 1
    apps_fn: Optional[Callable[[tuple], int]] = None
    rank_capable: bool = True
    dense_apply_free: bool = False
    basis_gather: bool = False

    def apps(self, data_shape: Optional[tuple] = None) -> int:
        if self.apps_fn is not None and data_shape is not None:
            return max(int(self.apps_fn(data_shape)), 1)
        return self.apps_per_sample


LAYER_KINDS = ("dense", "conv", "embed")


@dataclasses.dataclass(frozen=True)
class ComposedLayer:
    """One width-scalable layer: spec + application kind + auto-impl hint.

    The reusable unit every model definition is assembled from.  A layer
    knows how to *apply* a weight entry — either a composed dense array
    (the bitwise historical op) or raw ``{"basis", "coeff"}`` factors
    (the rank-space contraction) — and carries the static facts
    (``LayerHint``) the auto forward-impl choice and the rank-aware
    clock model consume.

    Kinds:
      dense  ``x @ W`` on the last axis (any leading shape, so sequence
             inputs ``(B, T, pI)`` work unchanged);
      conv   NHWC SAME conv, ``ksq`` taps, optional stride;
      embed  token gather; the rank path gathers R-length basis rows and
             finishes with the coefficient contraction.
    """

    name: str
    spec: CompositionSpec
    kind: str = "dense"
    stride: int = 1
    hint: LayerHint = LayerHint()

    def __post_init__(self):
        if self.kind not in LAYER_KINDS:
            raise ValueError(f"unknown layer kind {self.kind!r} "
                             f"(expected one of {LAYER_KINDS})")
        if self.kind != "conv" and self.spec.ksq != 1:
            raise ValueError(f"layer {self.name!r}: ksq={self.spec.ksq} "
                             f"requires kind='conv'")
        if self.kind == "embed" and self.spec.mode != "grow_out":
            raise ValueError(f"embed layer {self.name!r} must use "
                             f"mode='grow_out' (vocab-anchored input)")

    def apply(self, entry, x: Array, width: int) -> Array:
        if self.kind == "conv":
            return _apply_conv(entry, x, width, self.spec, stride=self.stride)
        if self.kind == "embed":
            return _apply_embed(entry, x, width, self.spec)
        return _apply_dense(entry, x, width, self.spec)

    def materialized(self, entry, width: int) -> Array:
        return _materialized(entry, width, self.spec)


@dataclasses.dataclass(frozen=True, eq=False)
class FLModelDef:
    """A width-scalable FL model.

    ``eq=False`` keeps object-identity hashing: model defs hold dicts and
    closures, and the client/trainer jit caches key on *this exact model
    instance* rather than a lossy string encoding of its constructor args.
    The ``make_*`` factories below are memoized so equal-config models are
    the same instance and still share compiled functions.
    """

    name: str
    specs: Dict[str, CompositionSpec]  # ordered: forward consumption order
    forward: Callable  # (weights: Dict[str, Array|factors], width, batch) -> logits
    flops_per_sample: Callable  # (width) -> flops of fwd+bwd per sample
    num_classes: int
    # static per-layer facts for the auto forward-impl choice; layers
    # without a hint default to LayerHint() (1 application, rank-capable)
    hints: Optional[Dict[str, LayerHint]] = None
    # which batch key carries the input ("x" for images, "tokens" for
    # sequence models) — the engine keys batch assembly off this instead
    # of special-casing model names
    input_key: str = "x"
    # the ComposedLayer dict the forward was assembled from (None for
    # defs built directly on raw specs)
    layers: Optional[Dict[str, ComposedLayer]] = None

    @classmethod
    def from_layers(cls, name: str, layers: Dict[str, ComposedLayer],
                    forward: Callable, flops_per_sample: Callable,
                    num_classes: int, *, input_key: str = "x") -> "FLModelDef":
        """Assemble a def from an ordered ComposedLayer dict: the specs
        and hints tables are projections of the layers, so they can
        never drift apart."""
        specs = {n: layer.spec for n, layer in layers.items()}
        hints = {n: layer.hint for n, layer in layers.items()}
        return cls(name, specs, forward, flops_per_sample, num_classes,
                   hints, input_key=input_key, layers=layers)

    # ---- factorized parameterisation -----------------------------------
    def init_factorized(self, key) -> Dict[str, Dict[str, Array]]:
        out = {}
        for k, (name, spec) in zip(
            jax.random.split(key, len(self.specs)), self.specs.items()
        ):
            v, u = init_factors(k, spec)
            out[name] = {"basis": v, "coeff": u}
        return out

    def reduce(self, params, width: int, hidden_ids, anchored_ids):
        """Ship-to-client factors: gather the assigned blocks per layer."""
        out = {}
        for name, spec in self.specs.items():
            ids = hidden_ids if spec.mode == "square" else anchored_ids
            out[name] = {
                "basis": params[name]["basis"],
                "coeff": gather_blocks(params[name]["coeff"], np.asarray(ids)),
            }
        return out

    def compose_all(self, reduced, width: int) -> Dict[str, Array]:
        return {
            name: compose(reduced[name]["basis"], reduced[name]["coeff"], width, spec)
            for name, spec in self.specs.items()
        }

    def layer_impls(self, width: int, batch_size: int, forward_impl: str,
                    data_shape: Optional[tuple] = None,
                    calibration=None) -> Dict[str, str]:
        """Per-layer materialize/rank_space/fused_compose choice (static,
        per trace).

        ``auto`` compares, per layer, the rank-space application cost
        against compose + dense application over the layer's total
        application count ``batch_size * hint.apps(data_shape)`` — so a
        bigger batch amortises the compose and a reuse-heavy layer
        (scan recurrence) tilts toward materialisation.  ``data_shape``
        (the input array's shape) lets hints derive true application
        counts from the traced geometry instead of the model's
        reference input size.

        The overheads the FLOPs model cannot see come from the measured
        per-process calibration (:mod:`repro.core.calibration`), or the
        ``calibration`` argument when the engine threads an ``FLConfig``
        override through.  Two consequences beyond the binary choice:
        conv layers use the *measured* ``conv_rank_overhead`` (the fused
        :mod:`repro.kernels.conv_rank` path wins on CPU at high
        FLOPs-ratio shapes, so ``auto`` now enables it there), and a
        rank-capable dense layer that still loses to materialisation is
        labelled ``"fused_compose"`` when the measured
        ``fused_compose_gain < 1`` — same math as materialize, but the
        p-width weight is built and consumed inside one kernel
        (``compose_dense_apply``) instead of round-tripping HBM.
        """
        if forward_impl not in FORWARD_IMPLS:
            raise ValueError(f"unknown forward_impl {forward_impl!r} "
                             f"(expected one of {FORWARD_IMPLS})")
        if forward_impl == "materialize":
            return {name: "materialize" for name in self.specs}
        if forward_impl == "auto" and calibration is None:
            from repro.core.calibration import get_calibration

            calibration = get_calibration()
        hints = self.hints or {}
        out = {}
        for name, spec in self.specs.items():
            hint = hints.get(name, LayerHint())
            if not hint.rank_capable:
                out[name] = "materialize"
            elif forward_impl == "rank_space":
                out[name] = "rank_space"
            else:
                apps = max(batch_size, 1) * hint.apps(data_shape)
                ovh = (conv_rank_overhead(calibration)
                       if spec.ksq > 1 else 1.0)
                if rank_space_wins(
                        width, spec, applications=apps,
                        dense_apply_free=hint.dense_apply_free,
                        basis_is_gather=hint.basis_gather,
                        overhead=ovh):
                    out[name] = "rank_space"
                elif (spec.ksq == 1 and not hint.dense_apply_free
                      and calibration.fused_compose_gain < 1.0):
                    out[name] = "fused_compose"
                else:
                    out[name] = "materialize"
        return out

    def prepare_weights(self, reduced, width: int, batch,
                        forward_impl: str = "materialize",
                        calibration=None) -> Dict[str, Any]:
        """The weight dict ``forward`` consumes, per ``forward_impl``.

        ``materialize`` is exactly :meth:`compose_all` (the bitwise
        reference path).  Otherwise rank-space layers pass their raw
        ``{"basis", "coeff"}`` factors through untouched — the forward
        applies them via rank-space contractions — ``fused_compose``
        layers pass the factors with a static ``"fused"`` marker (the
        forward routes them through ``compose_dense_apply``), and the
        rest compose as usual.  The choice keys on static shapes and
        the (hashable) calibration only, so it is jit-cache-stable per
        (width, batch shape, calibration).
        """
        if forward_impl == "materialize":
            return self.compose_all(reduced, width)
        data = (batch.get(self.input_key, batch.get("x", batch.get("tokens")))
                if isinstance(batch, dict) else None)
        shape = tuple(data.shape) if data is not None else None
        batch_size = shape[0] if shape else 1
        impls = self.layer_impls(width, batch_size, forward_impl, shape,
                                 calibration)
        out = {}
        for name, spec in self.specs.items():
            if impls[name] == "rank_space":
                out[name] = reduced[name]
            elif impls[name] == "fused_compose":
                out[name] = {**reduced[name], "fused": True}
            else:
                out[name] = compose(reduced[name]["basis"],
                                    reduced[name]["coeff"], width, spec)
        return out

    def apply_flops_per_sample(self, width: int, batch_size: int,
                               forward_impl: str,
                               data_shape: Optional[tuple] = None,
                               calibration=None) -> float:
        """Per-sample fwd+bwd FLOPs under the per-layer impl the client
        forward actually takes (the ``clock_model="rank_aware"`` time
        model).

        Rank-space layers charge :func:`apply_flops`; materialised
        layers — including ``fused_compose`` ones, whose fusion saves
        memory traffic, not FLOPs — charge their one-off ``compose``
        amortised over the batch plus the dense application (free for
        embedding gathers).  Backward ~ 2x forward, so the total is 3x
        — the same convention the dense ``flops_per_sample`` tables use.
        """
        impls = self.layer_impls(width, batch_size, forward_impl, data_shape,
                                 calibration)
        hints = self.hints or {}
        bs = max(int(batch_size), 1)
        total = 0.0
        for name, spec in self.specs.items():
            hint = hints.get(name, LayerHint())
            apps = hint.apps(data_shape)
            if impls[name] == "rank_space":
                fwd = apply_flops(width, spec, applications=apps,
                                  basis_is_gather=hint.basis_gather)
            else:
                fwd = compose_flops(width, spec) / bs
                if not hint.dense_apply_free:
                    fwd += dense_apply_flops(width, spec, applications=apps)
            total += 3.0 * fwd
        return total

    def factorized_bytes(self, width: int) -> int:
        return 4 * sum(s.params_factorized(width) for s in self.specs.values())

    # ---- dense parameterisation ------------------------------------------
    def init_dense(self, key) -> Dict[str, Array]:
        out = {}
        for k, (name, spec) in zip(
            jax.random.split(key, len(self.specs)), self.specs.items()
        ):
            ksq, i, o = spec.weight_shape(spec.max_width)
            out[name] = (1.0 / math.sqrt(ksq * i)) * jax.random.normal(k, (ksq, i, o))
        return out

    def slice_dense(self, params: Dict[str, Array], width: int) -> Dict[str, Array]:
        """HeteroFL-style sub-model: leading slices of each weight."""
        out = {}
        for name, spec in self.specs.items():
            ksq, i, o = spec.weight_shape(width)
            out[name] = params[name][:, :i, :o]
        return out

    def dense_bytes(self, width: int) -> int:
        return 4 * sum(s.params_materialized(width) for s in self.specs.values())


# ---------------------------------------------------------------------------
# model registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelEntry:
    """Registry row: the builder plus the data modality it expects.

    ``build(max_width, meta, **overrides)`` receives the dataset's
    metadata dict and returns the (memoized) ``FLModelDef``.
    """

    name: str
    modality: str  # "image" | "text"
    build: Callable[..., FLModelDef]


MODEL_REGISTRY: Dict[str, ModelEntry] = {}


def register_model(name: str, *, modality: str = "image"):
    """Decorator registering a ``build(max_width, meta, **kw)`` factory
    under ``name`` so ``simulation.build_setup`` can resolve it."""
    def deco(build):
        if name in MODEL_REGISTRY:
            raise ValueError(f"model {name!r} already registered")
        MODEL_REGISTRY[name] = ModelEntry(name, modality, build)
        return build
    return deco


def get_model(name: str) -> ModelEntry:
    try:
        return MODEL_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown model {name!r}; registered: {sorted(MODEL_REGISTRY)}"
        ) from None


# ---------------------------------------------------------------------------
# forward helpers
# ---------------------------------------------------------------------------


def _conv(x: Array, w3: Array, k: int, stride: int = 1) -> Array:
    """x NHWC, w3 (k*k, I, O) -> conv with SAME padding."""
    kk, i, o = w3.shape
    w = w3.reshape(k, k, i, o)
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


# Parameterisation-aware layer application: a composed array runs the
# exact historical dense op (bitwise); a {"basis","coeff"} factor dict
# runs the rank-space contraction.  The isinstance dispatch is static at
# trace time — the weight dict's pytree structure is fixed per jit.


def _apply_conv(entry, x: Array, width: int, spec: CompositionSpec,
                stride: int = 1) -> Array:
    if isinstance(entry, dict):
        return apply_factors(x, entry["basis"], entry["coeff"], width, spec,
                             "conv", stride=stride)
    return _conv(x, entry, int(round(spec.ksq ** 0.5)), stride=stride)


def _apply_dense(entry, x: Array, width: int, spec: CompositionSpec) -> Array:
    if isinstance(entry, dict):
        if entry.get("fused"):
            # "fused_compose" impl: materialize-path math, but the
            # p-width weight is built and consumed inside one kernel
            # (the marker is a static Python bool prepare_weights sets
            # at trace time, so this branch is trace-static too)
            from repro.kernels.compose import compose_dense_apply

            return compose_dense_apply(x, entry["basis"], entry["coeff"],
                                       width, spec.mode)
        return apply_factors(x, entry["basis"], entry["coeff"], width, spec,
                             "dense")
    return x @ entry[0]


def _apply_embed(entry, tokens: Array, width: int,
                 spec: CompositionSpec) -> Array:
    """Embedding lookup: gather the composed rows, or gather the R-dim
    basis rows and finish with the coefficient contraction."""
    if isinstance(entry, dict):
        emb_r = jnp.take(entry["basis"][0], tokens, axis=0)  # (..., R)
        y = jnp.einsum("...r,bro->...bo", emb_r, entry["coeff"])
        return y.reshape(y.shape[:-2] + (width * spec.base_out,))
    return jnp.take(entry[0], tokens, axis=0)


def _materialized(entry, width: int, spec: CompositionSpec) -> Array:
    """Force-compose a layer the forward needs as a dense array (the
    RNN's scan-carried recurrence weight: composed once per evaluation,
    reused T times in the carry loop)."""
    if isinstance(entry, dict):
        return compose(entry["basis"], entry["coeff"], width, spec)
    return entry


# ---------------------------------------------------------------------------
# CNN (paper's 4-layer CNN, reduced input 8x8)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def make_cnn(max_width: int = 3, base: int = 8, rank: int = 8,
             num_classes: int = 10, in_ch: int = 3) -> FLModelDef:
    layers = {
        "conv1": ComposedLayer(
            "conv1",
            CompositionSpec(max_width, rank, in_ch, base, ksq=9, mode="grow_out"),
            kind="conv",
            hint=LayerHint(64, lambda s: s[1] * s[2])),
        "conv2": ComposedLayer(
            "conv2", CompositionSpec(max_width, rank, base, base, ksq=9),
            kind="conv", stride=2,
            hint=LayerHint(16, lambda s: -(-s[1] // 2) * (-(-s[2] // 2)))),
        "conv3": ComposedLayer(
            "conv3", CompositionSpec(max_width, rank, base, base, ksq=9),
            kind="conv", stride=2,
            hint=LayerHint(4, lambda s: -(-s[1] // 4) * (-(-s[2] // 4)))),
        "fc": ComposedLayer(
            "fc",
            CompositionSpec(max_width, rank, base, num_classes, ksq=1,
                            mode="grow_in"),
            hint=LayerHint(apps_per_sample=1)),
    }

    def forward(w: Dict[str, Any], width: int, batch) -> Array:
        x = batch["x"]
        x = jax.nn.relu(layers["conv1"].apply(w["conv1"], x, width))
        x = jax.nn.relu(layers["conv2"].apply(w["conv2"], x, width))
        x = jax.nn.relu(layers["conv3"].apply(w["conv3"], x, width))
        x = jnp.mean(x, axis=(1, 2))  # GAP
        return layers["fc"].apply(w["fc"], x, width)

    def flops(width: int, hw: int = 8) -> int:
        p = width
        f = 0
        f += 2 * 9 * in_ch * (p * base) * hw * hw
        f += 2 * 9 * (p * base) ** 2 * (hw // 2) ** 2
        f += 2 * 9 * (p * base) ** 2 * (hw // 4) ** 2
        f += 2 * (p * base) * num_classes
        return 3 * f  # fwd + bwd ~ 3x

    return FLModelDef.from_layers("cnn", layers, forward, flops, num_classes)


# ---------------------------------------------------------------------------
# ResNet-ish (reduced stand-in for the paper's ResNet-18)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def make_resnet(max_width: int = 3, base: int = 8, rank: int = 8,
                num_classes: int = 10, in_ch: int = 3) -> FLModelDef:
    conv_hint = LayerHint(64, lambda s: s[1] * s[2])  # stride-1 convs
    layers = {
        "stem": ComposedLayer(
            "stem",
            CompositionSpec(max_width, rank, in_ch, base, ksq=9, mode="grow_out"),
            kind="conv", hint=conv_hint),
        **{name: ComposedLayer(
            name, CompositionSpec(max_width, rank, base, base, ksq=9),
            kind="conv", hint=conv_hint)
           for name in ("b1a", "b1b", "b2a", "b2b")},
        "fc": ComposedLayer(
            "fc",
            CompositionSpec(max_width, rank, base, num_classes, ksq=1,
                            mode="grow_in"),
            hint=LayerHint(apps_per_sample=1)),
    }

    def forward(w, width, batch):
        x = batch["x"]
        x = jax.nn.relu(layers["stem"].apply(w["stem"], x, width))
        h = jax.nn.relu(layers["b1a"].apply(w["b1a"], x, width))
        x = jax.nn.relu(x + layers["b1b"].apply(w["b1b"], h, width))
        h = jax.nn.relu(layers["b2a"].apply(w["b2a"], x, width))
        x = jax.nn.relu(x + layers["b2b"].apply(w["b2b"], h, width))
        x = jnp.mean(x, axis=(1, 2))
        return layers["fc"].apply(w["fc"], x, width)

    def flops(width, hw: int = 8):
        p = width
        f = 2 * 9 * in_ch * (p * base) * hw * hw
        f += 4 * 2 * 9 * (p * base) ** 2 * hw * hw
        f += 2 * (p * base) * num_classes
        return 3 * f

    return FLModelDef.from_layers("resnet", layers, forward, flops,
                                  num_classes)


# ---------------------------------------------------------------------------
# RNN (Shakespeare stand-in: next-token prediction)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def make_rnn(max_width: int = 3, base: int = 16, rank: int = 8,
             vocab: int = 64) -> FLModelDef:
    seq_len = lambda s: s[1]  # noqa: E731 — tokens (B, T)
    layers = {
        # embedding application is a gather on BOTH paths: materialised
        # rows cost ~0, and the rank path gathers R-length basis rows
        # then pays only the coefficient contraction per token
        "embed": ComposedLayer(
            "embed",
            CompositionSpec(max_width, rank, vocab, base, ksq=1,
                            mode="grow_out"),
            kind="embed",
            hint=LayerHint(32, seq_len, dense_apply_free=True,
                           basis_gather=True)),
        "wx": ComposedLayer(
            "wx", CompositionSpec(max_width, rank, base, base, ksq=1),
            hint=LayerHint(32, seq_len)),
        # scan recurrence: composed once, reused T times per evaluation
        "wh": ComposedLayer(
            "wh", CompositionSpec(max_width, rank, base, base, ksq=1),
            hint=LayerHint(32, seq_len, rank_capable=False)),
        "out": ComposedLayer(
            "out",
            CompositionSpec(max_width, rank, base, vocab, ksq=1,
                            mode="grow_in"),
            hint=LayerHint(32, seq_len)),
    }

    def forward(w, width, batch):
        tokens = batch["tokens"]  # (B, T)
        emb = layers["embed"].apply(w["embed"], tokens, width)  # (B,T,pE)
        # the scan-carried recurrence weight is materialised ONCE per
        # evaluation and reused T times in the carry loop — rank-space
        # application would redo two contractions per step for a weight
        # whose compose is amortised T-fold (see LayerHint.rank_capable)
        wh = layers["wh"].materialized(w["wh"], width)[0]

        if isinstance(w["wx"], dict):
            # input projection in rank space, hoisted out of the scan:
            # all T steps contract through R in one shot
            xp = layers["wx"].apply(w["wx"], emb, width)

            def step(h, x):
                h = jnp.tanh(x + h @ wh)
                return h, h

            xs = jnp.moveaxis(xp, 1, 0)
        else:
            wx = w["wx"][0]

            def step(h, x):
                h = jnp.tanh(x @ wx + h @ wh)
                return h, h

            xs = jnp.moveaxis(emb, 1, 0)

        h0 = jnp.zeros((emb.shape[0], wh.shape[0]), emb.dtype)
        _, hs = jax.lax.scan(step, h0, xs)
        hs = jnp.moveaxis(hs, 0, 1)  # (B,T,pH)
        return layers["out"].apply(w["out"], hs, width)  # (B,T,V)

    def flops(width, seq: int = 32):
        p = width
        per_tok = 2 * vocab * (p * base) + 4 * (p * base) ** 2 + 2 * (p * base) * vocab
        return 3 * per_tok * seq

    return FLModelDef.from_layers("rnn", layers, forward, flops, vocab,
                                  input_key="tokens")


MODELS = {"cnn": make_cnn, "resnet": make_resnet, "rnn": make_rnn}


@register_model("cnn", modality="image")
def _build_cnn(max_width: int, meta: Dict[str, Any], **kw) -> FLModelDef:
    return make_cnn(max_width=max_width, num_classes=meta["num_classes"],
                    in_ch=meta["channels"], **kw)


@register_model("resnet", modality="image")
def _build_resnet(max_width: int, meta: Dict[str, Any], **kw) -> FLModelDef:
    return make_resnet(max_width=max_width, num_classes=meta["num_classes"],
                       in_ch=meta["channels"], **kw)


@register_model("rnn", modality="text")
def _build_rnn(max_width: int, meta: Dict[str, Any], **kw) -> FLModelDef:
    return make_rnn(max_width=max_width, vocab=meta["vocab"], **kw)
