"""Width-scalable FL models (paper Sec. VI-A: CNN / ResNet-ish / RNN).

Every model is described by an ordered dict of ``CompositionSpec``s:
hidden weights use the paper's "square" mode (p^2 blocks from the shared
P^2 counter); boundary layers (first conv / embedding, classifier) use the
anchored modes with their own P-block counter (Flanc's treatment).

Two parameterisations per model:
  * factorized  — params are (basis, coeff-blocks); used by Heroes/Flanc.
  * dense       — params are materialised width-P weights; used by
                  FedAvg/ADP/HeteroFL (pruning slices sub-weights out).

Forward passes are width-polymorphic AND parameterisation-aware: each
layer entry in the weight dict is either a composed ``(ksq, pI, pO)``
array (applied densely — bit-for-bit the historical path) or the raw
``{"basis", "coeff"}`` factors (applied in *rank space* through
:func:`repro.core.composition.apply_factors`, never materialising the
p-width weight).  :meth:`FLModelDef.prepare_weights` builds that dict
from reduced factors under a ``forward_impl`` knob:

  materialize  compose every layer (exactly ``compose_all`` — the
               bitwise reference the seed histories anchor on);
  rank_space   keep factors for every rank-capable layer;
  auto         pick per (layer, width, batch) by the static FLOPs model
               (``apply_flops`` vs ``compose_flops + dense_apply_flops``),
               with per-layer reuse folded into the application count.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.composition import (CompositionSpec, apply_factors, compose,
                                    conv_rank_overhead, gather_blocks,
                                    init_factors, rank_space_wins)

Array = jax.Array

FORWARD_IMPLS = ("auto", "materialize", "rank_space")


@dataclasses.dataclass(frozen=True)
class LayerHint:
    """Static per-layer facts feeding the ``auto`` forward-impl choice.

    Attributes:
      apps_per_sample: weight applications per input sample per forward
        — conv output positions, RNN sequence steps, 1 for a head — at
        the model's *reference* input geometry (benchmark tables, or
        when no batch is in scope).  Any *reuse* of one composed weight
        (a scan-carried RNN weight hit T times) is folded in here, so
        the decision correctly amortises the one-off compose against
        the true application count.
      apps_fn: optional ``(data_shape) -> apps_per_sample`` deriving the
        count from the actual traced input shape ``(B, ...)`` (image
        H×W, sequence length), so ``auto`` stays correct when inputs
        differ from the reference geometry.  Preferred over the static
        count whenever a batch is available.
      rank_capable: False pins the layer to materialisation regardless
        of FLOPs — e.g. a scan-carried recurrence weight, which is
        composed once per step and reused T times in the carry loop.
      dense_apply_free: the materialised application costs no FLOPs
        (embedding gathers).
      basis_gather: the rank path's basis projection is also a gather
        (``_apply_embed`` indexes R-length basis rows per token), so
        rank space only pays the R→pO coefficient contraction — it
        beats materialisation exactly when the token count per
        evaluation is below the vocabulary size (``apply_flops``'s
        ``basis_is_gather``).
    """

    apps_per_sample: int = 1
    apps_fn: Optional[Callable[[tuple], int]] = None
    rank_capable: bool = True
    dense_apply_free: bool = False
    basis_gather: bool = False

    def apps(self, data_shape: Optional[tuple] = None) -> int:
        if self.apps_fn is not None and data_shape is not None:
            return max(int(self.apps_fn(data_shape)), 1)
        return self.apps_per_sample


@dataclasses.dataclass(frozen=True, eq=False)
class FLModelDef:
    """A width-scalable FL model.

    ``eq=False`` keeps object-identity hashing: model defs hold dicts and
    closures, and the client/trainer jit caches key on *this exact model
    instance* rather than a lossy string encoding of its constructor args.
    The ``make_*`` factories below are memoized so equal-config models are
    the same instance and still share compiled functions.
    """

    name: str
    specs: Dict[str, CompositionSpec]  # ordered: forward consumption order
    forward: Callable  # (weights: Dict[str, Array|factors], width, batch) -> logits
    flops_per_sample: Callable  # (width) -> flops of fwd+bwd per sample
    num_classes: int
    # static per-layer facts for the auto forward-impl choice; layers
    # without a hint default to LayerHint() (1 application, rank-capable)
    hints: Optional[Dict[str, LayerHint]] = None

    # ---- factorized parameterisation -----------------------------------
    def init_factorized(self, key) -> Dict[str, Dict[str, Array]]:
        out = {}
        for k, (name, spec) in zip(
            jax.random.split(key, len(self.specs)), self.specs.items()
        ):
            v, u = init_factors(k, spec)
            out[name] = {"basis": v, "coeff": u}
        return out

    def reduce(self, params, width: int, hidden_ids, anchored_ids):
        """Ship-to-client factors: gather the assigned blocks per layer."""
        out = {}
        for name, spec in self.specs.items():
            ids = hidden_ids if spec.mode == "square" else anchored_ids
            out[name] = {
                "basis": params[name]["basis"],
                "coeff": gather_blocks(params[name]["coeff"], np.asarray(ids)),
            }
        return out

    def compose_all(self, reduced, width: int) -> Dict[str, Array]:
        return {
            name: compose(reduced[name]["basis"], reduced[name]["coeff"], width, spec)
            for name, spec in self.specs.items()
        }

    def layer_impls(self, width: int, batch_size: int, forward_impl: str,
                    data_shape: Optional[tuple] = None) -> Dict[str, str]:
        """Per-layer materialize/rank_space choice (static, per trace).

        ``auto`` compares, per layer, the rank-space application cost
        against compose + dense application over the layer's total
        application count ``batch_size * hint.apps(data_shape)`` — so a
        bigger batch amortises the compose and a reuse-heavy layer
        (scan recurrence) tilts toward materialisation.  ``data_shape``
        (the input array's shape) lets hints derive true application
        counts from the traced geometry instead of the model's
        reference input size.
        """
        if forward_impl not in FORWARD_IMPLS:
            raise ValueError(f"unknown forward_impl {forward_impl!r} "
                             f"(expected one of {FORWARD_IMPLS})")
        if forward_impl == "materialize":
            return {name: "materialize" for name in self.specs}
        hints = self.hints or {}
        out = {}
        for name, spec in self.specs.items():
            hint = hints.get(name, LayerHint())
            if not hint.rank_capable:
                out[name] = "materialize"
            elif forward_impl == "rank_space":
                out[name] = "rank_space"
            else:
                apps = max(batch_size, 1) * hint.apps(data_shape)
                # conv layers pay platform-dependent overhead beyond
                # their FLOPs count (group-batched conv + second
                # contraction) — on CPU hosts that eats a ~2x FLOPs
                # advantage, on accelerators it doesn't
                ovh = conv_rank_overhead() if spec.ksq > 1 else 1.0
                out[name] = "rank_space" if rank_space_wins(
                    width, spec, applications=apps,
                    dense_apply_free=hint.dense_apply_free,
                    basis_is_gather=hint.basis_gather,
                    overhead=ovh) else "materialize"
        return out

    def prepare_weights(self, reduced, width: int, batch,
                        forward_impl: str = "materialize") -> Dict[str, Any]:
        """The weight dict ``forward`` consumes, per ``forward_impl``.

        ``materialize`` is exactly :meth:`compose_all` (the bitwise
        reference path).  Otherwise rank-space layers pass their raw
        ``{"basis", "coeff"}`` factors through untouched — the forward
        applies them via rank-space contractions — and the rest compose
        as usual.  The choice keys on static shapes only, so it is
        jit-cache-stable per (width, batch shape).
        """
        if forward_impl == "materialize":
            return self.compose_all(reduced, width)
        data = (batch.get("x", batch.get("tokens"))
                if isinstance(batch, dict) else None)
        shape = tuple(data.shape) if data is not None else None
        batch_size = shape[0] if shape else 1
        impls = self.layer_impls(width, batch_size, forward_impl, shape)
        return {
            name: (reduced[name] if impls[name] == "rank_space" else
                   compose(reduced[name]["basis"], reduced[name]["coeff"],
                           width, spec))
            for name, spec in self.specs.items()
        }

    def factorized_bytes(self, width: int) -> int:
        return 4 * sum(s.params_factorized(width) for s in self.specs.values())

    # ---- dense parameterisation ------------------------------------------
    def init_dense(self, key) -> Dict[str, Array]:
        out = {}
        for k, (name, spec) in zip(
            jax.random.split(key, len(self.specs)), self.specs.items()
        ):
            ksq, i, o = spec.weight_shape(spec.max_width)
            out[name] = (1.0 / math.sqrt(ksq * i)) * jax.random.normal(k, (ksq, i, o))
        return out

    def slice_dense(self, params: Dict[str, Array], width: int) -> Dict[str, Array]:
        """HeteroFL-style sub-model: leading slices of each weight."""
        out = {}
        for name, spec in self.specs.items():
            ksq, i, o = spec.weight_shape(width)
            out[name] = params[name][:, :i, :o]
        return out

    def dense_bytes(self, width: int) -> int:
        return 4 * sum(s.params_materialized(width) for s in self.specs.values())


# ---------------------------------------------------------------------------
# forward helpers
# ---------------------------------------------------------------------------


def _conv(x: Array, w3: Array, k: int, stride: int = 1) -> Array:
    """x NHWC, w3 (k*k, I, O) -> conv with SAME padding."""
    kk, i, o = w3.shape
    w = w3.reshape(k, k, i, o)
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


# Parameterisation-aware layer application: a composed array runs the
# exact historical dense op (bitwise); a {"basis","coeff"} factor dict
# runs the rank-space contraction.  The isinstance dispatch is static at
# trace time — the weight dict's pytree structure is fixed per jit.


def _apply_conv(entry, x: Array, width: int, spec: CompositionSpec,
                stride: int = 1) -> Array:
    if isinstance(entry, dict):
        return apply_factors(x, entry["basis"], entry["coeff"], width, spec,
                             "conv", stride=stride)
    return _conv(x, entry, int(round(spec.ksq ** 0.5)), stride=stride)


def _apply_dense(entry, x: Array, width: int, spec: CompositionSpec) -> Array:
    if isinstance(entry, dict):
        return apply_factors(x, entry["basis"], entry["coeff"], width, spec,
                             "dense")
    return x @ entry[0]


def _apply_embed(entry, tokens: Array, width: int,
                 spec: CompositionSpec) -> Array:
    """Embedding lookup: gather the composed rows, or gather the R-dim
    basis rows and finish with the coefficient contraction."""
    if isinstance(entry, dict):
        emb_r = jnp.take(entry["basis"][0], tokens, axis=0)  # (..., R)
        y = jnp.einsum("...r,bro->...bo", emb_r, entry["coeff"])
        return y.reshape(y.shape[:-2] + (width * spec.base_out,))
    return jnp.take(entry[0], tokens, axis=0)


def _materialized(entry, width: int, spec: CompositionSpec) -> Array:
    """Force-compose a layer the forward needs as a dense array (the
    RNN's scan-carried recurrence weight: composed once per evaluation,
    reused T times in the carry loop)."""
    if isinstance(entry, dict):
        return compose(entry["basis"], entry["coeff"], width, spec)
    return entry


# ---------------------------------------------------------------------------
# CNN (paper's 4-layer CNN, reduced input 8x8)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def make_cnn(max_width: int = 3, base: int = 8, rank: int = 8,
             num_classes: int = 10, in_ch: int = 3) -> FLModelDef:
    specs = {
        "conv1": CompositionSpec(max_width, rank, in_ch, base, ksq=9, mode="grow_out"),
        "conv2": CompositionSpec(max_width, rank, base, base, ksq=9),
        "conv3": CompositionSpec(max_width, rank, base, base, ksq=9),
        "fc": CompositionSpec(max_width, rank, base, num_classes, ksq=1, mode="grow_in"),
    }

    def forward(w: Dict[str, Any], width: int, batch) -> Array:
        x = batch["x"]
        x = jax.nn.relu(_apply_conv(w["conv1"], x, width, specs["conv1"]))
        x = jax.nn.relu(_apply_conv(w["conv2"], x, width, specs["conv2"],
                                    stride=2))
        x = jax.nn.relu(_apply_conv(w["conv3"], x, width, specs["conv3"],
                                    stride=2))
        x = jnp.mean(x, axis=(1, 2))  # GAP
        return _apply_dense(w["fc"], x, width, specs["fc"])

    def flops(width: int, hw: int = 8) -> int:
        p = width
        f = 0
        f += 2 * 9 * in_ch * (p * base) * hw * hw
        f += 2 * 9 * (p * base) ** 2 * (hw // 2) ** 2
        f += 2 * 9 * (p * base) ** 2 * (hw // 4) ** 2
        f += 2 * (p * base) * num_classes
        return 3 * f  # fwd + bwd ~ 3x

    hints = {  # conv output positions (strides 1, 2, 2); reference 8x8
        "conv1": LayerHint(64, lambda s: s[1] * s[2]),
        "conv2": LayerHint(16, lambda s: -(-s[1] // 2) * (-(-s[2] // 2))),
        "conv3": LayerHint(4, lambda s: -(-s[1] // 4) * (-(-s[2] // 4))),
        "fc": LayerHint(apps_per_sample=1),
    }
    return FLModelDef("cnn", specs, forward, flops, num_classes, hints)


# ---------------------------------------------------------------------------
# ResNet-ish (reduced stand-in for the paper's ResNet-18)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def make_resnet(max_width: int = 3, base: int = 8, rank: int = 8,
                num_classes: int = 10, in_ch: int = 3) -> FLModelDef:
    specs = {
        "stem": CompositionSpec(max_width, rank, in_ch, base, ksq=9, mode="grow_out"),
        "b1a": CompositionSpec(max_width, rank, base, base, ksq=9),
        "b1b": CompositionSpec(max_width, rank, base, base, ksq=9),
        "b2a": CompositionSpec(max_width, rank, base, base, ksq=9),
        "b2b": CompositionSpec(max_width, rank, base, base, ksq=9),
        "fc": CompositionSpec(max_width, rank, base, num_classes, ksq=1, mode="grow_in"),
    }

    def forward(w, width, batch):
        x = batch["x"]
        x = jax.nn.relu(_apply_conv(w["stem"], x, width, specs["stem"]))
        h = jax.nn.relu(_apply_conv(w["b1a"], x, width, specs["b1a"]))
        x = jax.nn.relu(x + _apply_conv(w["b1b"], h, width, specs["b1b"]))
        h = jax.nn.relu(_apply_conv(w["b2a"], x, width, specs["b2a"]))
        x = jax.nn.relu(x + _apply_conv(w["b2b"], h, width, specs["b2b"]))
        x = jnp.mean(x, axis=(1, 2))
        return _apply_dense(w["fc"], x, width, specs["fc"])

    def flops(width, hw: int = 8):
        p = width
        f = 2 * 9 * in_ch * (p * base) * hw * hw
        f += 4 * 2 * 9 * (p * base) ** 2 * hw * hw
        f += 2 * (p * base) * num_classes
        return 3 * f

    hints = {name: LayerHint(64, lambda s: s[1] * s[2])  # stride-1 convs
             for name in ("stem", "b1a", "b1b", "b2a", "b2b")}
    hints["fc"] = LayerHint(apps_per_sample=1)
    return FLModelDef("resnet", specs, forward, flops, num_classes, hints)


# ---------------------------------------------------------------------------
# RNN (Shakespeare stand-in: next-token prediction)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def make_rnn(max_width: int = 3, base: int = 16, rank: int = 8,
             vocab: int = 64) -> FLModelDef:
    specs = {
        "embed": CompositionSpec(max_width, rank, vocab, base, ksq=1, mode="grow_out"),
        "wx": CompositionSpec(max_width, rank, base, base, ksq=1),
        "wh": CompositionSpec(max_width, rank, base, base, ksq=1),
        "out": CompositionSpec(max_width, rank, base, vocab, ksq=1, mode="grow_in"),
    }

    def forward(w, width, batch):
        tokens = batch["tokens"]  # (B, T)
        emb = _apply_embed(w["embed"], tokens, width, specs["embed"])  # (B,T,pE)
        # the scan-carried recurrence weight is materialised ONCE per
        # evaluation and reused T times in the carry loop — rank-space
        # application would redo two contractions per step for a weight
        # whose compose is amortised T-fold (see LayerHint.rank_capable)
        wh = _materialized(w["wh"], width, specs["wh"])[0]

        if isinstance(w["wx"], dict):
            # input projection in rank space, hoisted out of the scan:
            # all T steps contract through R in one shot
            xp = apply_factors(emb, w["wx"]["basis"], w["wx"]["coeff"],
                               width, specs["wx"], "dense")

            def step(h, x):
                h = jnp.tanh(x + h @ wh)
                return h, h

            xs = jnp.moveaxis(xp, 1, 0)
        else:
            wx = w["wx"][0]

            def step(h, x):
                h = jnp.tanh(x @ wx + h @ wh)
                return h, h

            xs = jnp.moveaxis(emb, 1, 0)

        h0 = jnp.zeros((emb.shape[0], wh.shape[0]), emb.dtype)
        _, hs = jax.lax.scan(step, h0, xs)
        hs = jnp.moveaxis(hs, 0, 1)  # (B,T,pH)
        return _apply_dense(w["out"], hs, width, specs["out"])  # (B,T,V)

    def flops(width, seq: int = 32):
        p = width
        per_tok = 2 * vocab * (p * base) + 4 * (p * base) ** 2 + 2 * (p * base) * vocab
        return 3 * per_tok * seq

    seq_len = lambda s: s[1]  # noqa: E731 — tokens (B, T)
    hints = {
        # embedding application is a gather on BOTH paths: materialised
        # rows cost ~0, and the rank path gathers R-length basis rows
        # then pays only the coefficient contraction per token
        "embed": LayerHint(32, seq_len, dense_apply_free=True,
                           basis_gather=True),
        "wx": LayerHint(32, seq_len),
        # scan recurrence: composed once, reused T times per evaluation
        "wh": LayerHint(32, seq_len, rank_capable=False),
        "out": LayerHint(32, seq_len),
    }
    return FLModelDef("rnn", specs, forward, flops, vocab, hints)


MODELS = {"cnn": make_cnn, "resnet": make_resnet, "rnn": make_rnn}
