"""Width-scalable FL models (paper Sec. VI-A: CNN / ResNet-ish / RNN).

Every model is described by an ordered dict of ``CompositionSpec``s:
hidden weights use the paper's "square" mode (p^2 blocks from the shared
P^2 counter); boundary layers (first conv / embedding, classifier) use the
anchored modes with their own P-block counter (Flanc's treatment).

Two parameterisations per model:
  * factorized  — params are (basis, coeff-blocks); used by Heroes/Flanc.
  * dense       — params are materialised width-P weights; used by
                  FedAvg/ADP/HeteroFL (pruning slices sub-weights out).

Forward passes are width-polymorphic: they take the *composed* weight
list, so the same network code serves both parameterisations.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.composition import CompositionSpec, compose, gather_blocks, init_factors

Array = jax.Array


@dataclasses.dataclass(frozen=True, eq=False)
class FLModelDef:
    """A width-scalable FL model.

    ``eq=False`` keeps object-identity hashing: model defs hold dicts and
    closures, and the client/trainer jit caches key on *this exact model
    instance* rather than a lossy string encoding of its constructor args.
    The ``make_*`` factories below are memoized so equal-config models are
    the same instance and still share compiled functions.
    """

    name: str
    specs: Dict[str, CompositionSpec]  # ordered: forward consumption order
    forward: Callable  # (weights: Dict[str, Array], width, batch) -> logits
    flops_per_sample: Callable  # (width) -> flops of fwd+bwd per sample
    num_classes: int

    # ---- factorized parameterisation -----------------------------------
    def init_factorized(self, key) -> Dict[str, Dict[str, Array]]:
        out = {}
        for k, (name, spec) in zip(
            jax.random.split(key, len(self.specs)), self.specs.items()
        ):
            v, u = init_factors(k, spec)
            out[name] = {"basis": v, "coeff": u}
        return out

    def reduce(self, params, width: int, hidden_ids, anchored_ids):
        """Ship-to-client factors: gather the assigned blocks per layer."""
        out = {}
        for name, spec in self.specs.items():
            ids = hidden_ids if spec.mode == "square" else anchored_ids
            out[name] = {
                "basis": params[name]["basis"],
                "coeff": gather_blocks(params[name]["coeff"], np.asarray(ids)),
            }
        return out

    def compose_all(self, reduced, width: int) -> Dict[str, Array]:
        return {
            name: compose(reduced[name]["basis"], reduced[name]["coeff"], width, spec)
            for name, spec in self.specs.items()
        }

    def factorized_bytes(self, width: int) -> int:
        return 4 * sum(s.params_factorized(width) for s in self.specs.values())

    # ---- dense parameterisation ------------------------------------------
    def init_dense(self, key) -> Dict[str, Array]:
        out = {}
        for k, (name, spec) in zip(
            jax.random.split(key, len(self.specs)), self.specs.items()
        ):
            ksq, i, o = spec.weight_shape(spec.max_width)
            out[name] = (1.0 / math.sqrt(ksq * i)) * jax.random.normal(k, (ksq, i, o))
        return out

    def slice_dense(self, params: Dict[str, Array], width: int) -> Dict[str, Array]:
        """HeteroFL-style sub-model: leading slices of each weight."""
        out = {}
        for name, spec in self.specs.items():
            ksq, i, o = spec.weight_shape(width)
            out[name] = params[name][:, :i, :o]
        return out

    def dense_bytes(self, width: int) -> int:
        return 4 * sum(s.params_materialized(width) for s in self.specs.values())


# ---------------------------------------------------------------------------
# forward helpers
# ---------------------------------------------------------------------------


def _conv(x: Array, w3: Array, k: int, stride: int = 1) -> Array:
    """x NHWC, w3 (k*k, I, O) -> conv with SAME padding."""
    kk, i, o = w3.shape
    w = w3.reshape(k, k, i, o)
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


# ---------------------------------------------------------------------------
# CNN (paper's 4-layer CNN, reduced input 8x8)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def make_cnn(max_width: int = 3, base: int = 8, rank: int = 8,
             num_classes: int = 10, in_ch: int = 3) -> FLModelDef:
    specs = {
        "conv1": CompositionSpec(max_width, rank, in_ch, base, ksq=9, mode="grow_out"),
        "conv2": CompositionSpec(max_width, rank, base, base, ksq=9),
        "conv3": CompositionSpec(max_width, rank, base, base, ksq=9),
        "fc": CompositionSpec(max_width, rank, base, num_classes, ksq=1, mode="grow_in"),
    }

    def forward(w: Dict[str, Array], width: int, batch) -> Array:
        x = batch["x"]
        x = jax.nn.relu(_conv(x, w["conv1"], 3, stride=1))
        x = jax.nn.relu(_conv(x, w["conv2"], 3, stride=2))
        x = jax.nn.relu(_conv(x, w["conv3"], 3, stride=2))
        x = jnp.mean(x, axis=(1, 2))  # GAP
        return x @ w["fc"][0]

    def flops(width: int, hw: int = 8) -> int:
        p = width
        f = 0
        f += 2 * 9 * in_ch * (p * base) * hw * hw
        f += 2 * 9 * (p * base) ** 2 * (hw // 2) ** 2
        f += 2 * 9 * (p * base) ** 2 * (hw // 4) ** 2
        f += 2 * (p * base) * num_classes
        return 3 * f  # fwd + bwd ~ 3x

    return FLModelDef("cnn", specs, forward, flops, num_classes)


# ---------------------------------------------------------------------------
# ResNet-ish (reduced stand-in for the paper's ResNet-18)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def make_resnet(max_width: int = 3, base: int = 8, rank: int = 8,
                num_classes: int = 10, in_ch: int = 3) -> FLModelDef:
    specs = {
        "stem": CompositionSpec(max_width, rank, in_ch, base, ksq=9, mode="grow_out"),
        "b1a": CompositionSpec(max_width, rank, base, base, ksq=9),
        "b1b": CompositionSpec(max_width, rank, base, base, ksq=9),
        "b2a": CompositionSpec(max_width, rank, base, base, ksq=9),
        "b2b": CompositionSpec(max_width, rank, base, base, ksq=9),
        "fc": CompositionSpec(max_width, rank, base, num_classes, ksq=1, mode="grow_in"),
    }

    def forward(w, width, batch):
        x = batch["x"]
        x = jax.nn.relu(_conv(x, w["stem"], 3))
        h = jax.nn.relu(_conv(x, w["b1a"], 3))
        x = jax.nn.relu(x + _conv(h, w["b1b"], 3))
        h = jax.nn.relu(_conv(x, w["b2a"], 3))
        x = jax.nn.relu(x + _conv(h, w["b2b"], 3))
        x = jnp.mean(x, axis=(1, 2))
        return x @ w["fc"][0]

    def flops(width, hw: int = 8):
        p = width
        f = 2 * 9 * in_ch * (p * base) * hw * hw
        f += 4 * 2 * 9 * (p * base) ** 2 * hw * hw
        f += 2 * (p * base) * num_classes
        return 3 * f

    return FLModelDef("resnet", specs, forward, flops, num_classes)


# ---------------------------------------------------------------------------
# RNN (Shakespeare stand-in: next-token prediction)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def make_rnn(max_width: int = 3, base: int = 16, rank: int = 8,
             vocab: int = 64) -> FLModelDef:
    specs = {
        "embed": CompositionSpec(max_width, rank, vocab, base, ksq=1, mode="grow_out"),
        "wx": CompositionSpec(max_width, rank, base, base, ksq=1),
        "wh": CompositionSpec(max_width, rank, base, base, ksq=1),
        "out": CompositionSpec(max_width, rank, base, vocab, ksq=1, mode="grow_in"),
    }

    def forward(w, width, batch):
        tokens = batch["tokens"]  # (B, T)
        emb = jnp.take(w["embed"][0], tokens, axis=0)  # (B,T,pE)
        wx, wh = w["wx"][0], w["wh"][0]

        def step(h, x):
            h = jnp.tanh(x @ wx + h @ wh)
            return h, h

        h0 = jnp.zeros((emb.shape[0], wh.shape[0]), emb.dtype)
        _, hs = jax.lax.scan(step, h0, jnp.moveaxis(emb, 1, 0))
        hs = jnp.moveaxis(hs, 0, 1)  # (B,T,pH)
        return hs @ w["out"][0]  # (B,T,V)

    def flops(width, seq: int = 32):
        p = width
        per_tok = 2 * vocab * (p * base) + 4 * (p * base) ** 2 + 2 * (p * base) * vocab
        return 3 * per_tok * seq

    return FLModelDef("rnn", specs, forward, flops, vocab)


MODELS = {"cnn": make_cnn, "resnet": make_resnet, "rnn": make_rnn}
