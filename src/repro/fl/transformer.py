"""Federated composed transformer: the LLM stack as an ``FLModelDef``.

Heroes' neural composition *is* low-rank adaptation — every weight is a
sum of shared rank-R basis tensors and per-width coefficient blocks — so
a decoder-only transformer maps onto :class:`~repro.fl.models.ComposedLayer`
directly (FedHM's factorized-LM premise, on the Heroes block structure):

  =================  =========  =======================================
  layer              spec mode  shape at width p
  =================  =========  =======================================
  embed              grow_out   (vocab, p*d_base) — vocab-anchored
  l{i}.wq/wk/wv/wo   square     (p*d_base, p*d_base), p^2 blocks
  l{i}.up            square     (p*d_base, p*ff_base)
  l{i}.down          square     (p*ff_base, p*d_base)
  head               grow_in    (p*d_base, vocab) — vocab-anchored
  =================  =========  =======================================

Width p scales the model dimension (``d_p = p * d_base``) by scaling the
*head count* (``H_p = p * heads_base``) at fixed head_dim, so RoPE angles
and the attention kernels are width-independent.  Attention runs through
the existing flash kernel (:func:`repro.models.attention.flash_attention`,
streaming softmax, differentiable); norms are parameter-free RMSNorm so
the entire parameter set lives in composition specs and every FL scheme
(dense slicing included) applies unchanged.

Serving closes the loop production-style: :func:`serving_weights`
composes the per-width dense weights ONCE, then :func:`greedy_decode`
runs token-by-token greedy decode with a per-layer KV cache through the
Pallas decode kernel (:func:`repro.kernels.decode_attention.
decode_attention_pallas`) — benchmarked as tokens/s by
``benchmarks/bench_transformer.py``.  See docs/TRANSFORMERS.md.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.models import (ComposedLayer, CompositionSpec, FLModelDef,
                             LayerHint, register_model)
from repro.kernels.decode_attention import decode_attention_pallas
from repro.models.attention import apply_rotary, flash_attention, rope_angles

Array = jax.Array

ROPE_THETA = 10000.0
RMS_EPS = 1e-6


class TransformerArch(NamedTuple):
    """Static geometry the decode path needs back out of a model def."""

    d_base: int
    heads_base: int
    head_dim: int
    n_layers: int
    ff_base: int
    vocab: int
    seq_ref: int


# keyed by model identity (FLModelDef hashes by identity and the
# factories are memoized, so instances persist for the process lifetime)
_ARCH: Dict[FLModelDef, TransformerArch] = {}


def arch_of(model: FLModelDef) -> TransformerArch:
    try:
        return _ARCH[model]
    except KeyError:
        raise ValueError(
            f"model {model.name!r} was not built by make_transformer") from None


def _rms(x: Array) -> Array:
    """Parameter-free RMSNorm (keeps all params inside composition specs)."""
    return x * jax.lax.rsqrt(
        jnp.mean(jnp.square(x), axis=-1, keepdims=True) + RMS_EPS)


@functools.lru_cache(maxsize=None)
def make_transformer(max_width: int = 3, d_base: int = 16,
                     heads_base: int = 2, n_layers: int = 2,
                     ff_mult: int = 2, rank: int = 8, vocab: int = 64,
                     seq_ref: int = 32) -> FLModelDef:
    """Decoder-only transformer as composed rank-R blocks.

    ``head_dim = d_base // heads_base`` must be even (RoPE rotates
    half-pairs); width scales heads, not head_dim.
    """
    if d_base % heads_base != 0:
        raise ValueError(f"d_base={d_base} not divisible by heads_base={heads_base}")
    head_dim = d_base // heads_base
    if head_dim % 2 != 0:
        raise ValueError(f"head_dim={head_dim} must be even for RoPE")
    ff_base = ff_mult * d_base

    seq_len = lambda s: s[1]  # noqa: E731 — tokens (B, T)
    proj_hint = LayerHint(seq_ref, seq_len)

    layers: Dict[str, ComposedLayer] = {
        "embed": ComposedLayer(
            "embed",
            CompositionSpec(max_width, rank, vocab, d_base, ksq=1,
                            mode="grow_out"),
            kind="embed",
            hint=LayerHint(seq_ref, seq_len, dense_apply_free=True,
                           basis_gather=True)),
    }
    for i in range(n_layers):
        for proj in ("wq", "wk", "wv", "wo"):
            layers[f"l{i}.{proj}"] = ComposedLayer(
                f"l{i}.{proj}",
                CompositionSpec(max_width, rank, d_base, d_base, ksq=1),
                hint=proj_hint)
        layers[f"l{i}.up"] = ComposedLayer(
            f"l{i}.up",
            CompositionSpec(max_width, rank, d_base, ff_base, ksq=1),
            hint=proj_hint)
        layers[f"l{i}.down"] = ComposedLayer(
            f"l{i}.down",
            CompositionSpec(max_width, rank, ff_base, d_base, ksq=1),
            hint=proj_hint)
    layers["head"] = ComposedLayer(
        "head",
        CompositionSpec(max_width, rank, d_base, vocab, ksq=1,
                        mode="grow_in"),
        hint=proj_hint)

    def forward(w: Dict[str, Any], width: int, batch) -> Array:
        tokens = batch["tokens"]  # (B, T)
        B, T = tokens.shape
        heads = width * heads_base
        x = layers["embed"].apply(w["embed"], tokens, width)  # (B,T,pD)
        pos = jnp.arange(T, dtype=jnp.int32)[None, :]
        cos, sin = rope_angles(pos, head_dim, ROPE_THETA)
        for i in range(n_layers):
            h = _rms(x)
            q = layers[f"l{i}.wq"].apply(w[f"l{i}.wq"], h, width)
            k = layers[f"l{i}.wk"].apply(w[f"l{i}.wk"], h, width)
            v = layers[f"l{i}.wv"].apply(w[f"l{i}.wv"], h, width)
            q = apply_rotary(q.reshape(B, T, heads, head_dim), cos, sin)
            k = apply_rotary(k.reshape(B, T, heads, head_dim), cos, sin)
            v = v.reshape(B, T, heads, head_dim)
            # flash layout (B, S, KV, G, D) with one query head per KV head
            att = flash_attention(q[:, :, :, None, :], k, v, causal=True)
            att = att.reshape(B, T, heads * head_dim)
            x = x + layers[f"l{i}.wo"].apply(w[f"l{i}.wo"], att, width)
            h2 = _rms(x)
            u = jax.nn.gelu(layers[f"l{i}.up"].apply(w[f"l{i}.up"], h2, width))
            x = x + layers[f"l{i}.down"].apply(w[f"l{i}.down"], u, width)
        x = _rms(x)
        return layers["head"].apply(w["head"], x, width)  # (B,T,V)

    def flops(width: int, seq: int = seq_ref) -> int:
        p = width
        d, ff = p * d_base, p * ff_base
        # per token: 4 square attn projections + QK^T/AV over the
        # sequence + MLP up/down + LM head (embedding is a gather)
        per_tok = n_layers * (8 * d * d + 4 * seq * d + 4 * d * ff)
        per_tok += 2 * d * vocab
        return 3 * per_tok * seq

    model = FLModelDef.from_layers("transformer", layers, forward, flops,
                                   vocab, input_key="tokens")
    _ARCH[model] = TransformerArch(d_base, heads_base, head_dim, n_layers,
                                   ff_base, vocab, seq_ref)
    return model


@register_model("transformer", modality="text")
def _build_transformer(max_width: int, meta: Dict[str, Any], **kw) -> FLModelDef:
    return make_transformer(max_width=max_width, vocab=meta["vocab"], **kw)


# ---------------------------------------------------------------------------
# serving: compose once, decode through the Pallas kernel
# ---------------------------------------------------------------------------


def serving_weights(model: FLModelDef, params, width: int, *,
                    factorized: bool = True) -> Dict[str, Array]:
    """Per-width dense weights for serving, composed ONCE.

    ``factorized=True`` takes server-side (basis, coeff) params — the
    Heroes/Flanc state — reduces the width-p leading blocks (the same
    ids the aggregators evaluate with) and composes every layer.
    ``factorized=False`` takes dense params and slices the width-p
    sub-model (HeteroFL-style).
    """
    if not factorized:
        return model.slice_dense(params, width)
    square = next(s for s in model.specs.values() if s.mode == "square")
    hidden = np.arange(square.blocks_for_width(width))
    anchored = np.arange(min(width, square.max_width))
    reduced = model.reduce(params, width, hidden, anchored)
    return model.compose_all(reduced, width)


@functools.partial(jax.jit,
                   static_argnames=("model", "width", "backend", "interpret"))
def _decode_step(weights, ck, cv, tok, t, *, model: FLModelDef, width: int,
                 backend: str, interpret: bool):
    """One greedy decode step.

    tok (B,) int32, t scalar int32 (tokens already cached); caches are
    per-layer (B*H, Smax, head_dim) in the Pallas kernel's layout.
    Returns (next_token (B,), logits (B, V), new_ck, new_cv).
    """
    arch = _ARCH[model]
    B = tok.shape[0]
    heads = width * arch.heads_base
    hd = arch.head_dim
    x = jnp.take(weights["embed"][0], tok, axis=0)[:, None, :]  # (B,1,pD)
    pos = jnp.full((1, 1), t, dtype=jnp.int32)
    cos, sin = rope_angles(pos, hd, ROPE_THETA)
    new_ck, new_cv = [], []
    for i in range(arch.n_layers):
        h = _rms(x)
        q = (h @ weights[f"l{i}.wq"][0]).reshape(B, 1, heads, hd)
        k = (h @ weights[f"l{i}.wk"][0]).reshape(B, 1, heads, hd)
        v = (h @ weights[f"l{i}.wv"][0]).reshape(B, 1, heads, hd)
        q = apply_rotary(q, cos, sin)
        k = apply_rotary(k, cos, sin)
        # cache layout (B*H, S, D): batch-of-heads rows, matching the
        # kernel's grid axis
        k_row = jnp.swapaxes(k, 1, 2).reshape(B * heads, 1, hd)
        v_row = jnp.swapaxes(v, 1, 2).reshape(B * heads, 1, hd)
        ck_i = jax.lax.dynamic_update_slice(ck[i], k_row, (0, t, 0))
        cv_i = jax.lax.dynamic_update_slice(cv[i], v_row, (0, t, 0))
        new_ck.append(ck_i)
        new_cv.append(cv_i)
        q_row = jnp.swapaxes(q, 1, 2).reshape(B * heads, hd)
        lengths = jnp.full((B * heads,), t + 1, dtype=jnp.int32)
        if backend == "pallas":
            att = decode_attention_pallas(q_row, ck_i, cv_i, lengths,
                                          interpret=interpret)
        else:  # inline XLA reference (parity oracle for the kernel)
            s = jnp.einsum("bd,bsd->bs", q_row, ck_i,
                           preferred_element_type=jnp.float32) * (hd ** -0.5)
            smax = ck_i.shape[1]
            valid = jnp.arange(smax)[None, :] < lengths[:, None]
            s = jnp.where(valid, s, -1e30)
            p = jax.nn.softmax(s, axis=-1)
            att = jnp.einsum("bs,bsd->bd", p.astype(cv_i.dtype), cv_i,
                             preferred_element_type=jnp.float32)
        att = att.astype(x.dtype).reshape(B, heads, 1, hd)
        att = jnp.swapaxes(att, 1, 2).reshape(B, 1, heads * hd)
        x = x + att @ weights[f"l{i}.wo"][0]
        h2 = _rms(x)
        u = jax.nn.gelu(h2 @ weights[f"l{i}.up"][0])
        x = x + u @ weights[f"l{i}.down"][0]
    x = _rms(x)
    logits = (x @ weights["head"][0])[:, 0, :]  # (B, V)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), logits, new_ck, new_cv


def greedy_decode(model: FLModelDef, weights: Dict[str, Array], width: int,
                  prompt, steps: int, *, backend: str = "pallas",
                  interpret: bool | None = None,
                  max_len: int | None = None) -> Tuple[np.ndarray, np.ndarray]:
    """Token-by-token greedy decode over composed width-p weights.

    prompt (B, T0) int32; generates ``steps`` tokens.  ``backend``
    selects the attention kernel: ``"pallas"`` streams the KV cache
    through :func:`decode_attention_pallas` (interpret mode on CPU
    hosts, compiled on TPU), ``"xla"`` is the inline reference used as
    the parity oracle.  The prompt is prefilled through the same decode
    step, so the kernel serves every position.

    Returns ``(tokens (B, steps), last_logits (B, V))``.
    """
    if backend not in ("pallas", "xla"):
        raise ValueError(f"unknown decode backend {backend!r}")
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    arch = arch_of(model)
    prompt = jnp.asarray(prompt, dtype=jnp.int32)
    B, t0 = prompt.shape
    if t0 < 1:
        raise ValueError("prompt must hold at least one token")
    total = t0 + steps
    smax = max_len or total
    if smax < total:
        raise ValueError(f"max_len={smax} < prompt+steps={total}")
    heads = width * arch.heads_base
    ck = [jnp.zeros((B * heads, smax, arch.head_dim), jnp.float32)
          for _ in range(arch.n_layers)]
    cv = [jnp.zeros((B * heads, smax, arch.head_dim), jnp.float32)
          for _ in range(arch.n_layers)]
    out = []
    logits = None
    nxt = prompt[:, 0]
    for t in range(total - 1):
        tok = prompt[:, t] if t < t0 else nxt
        nxt, logits, ck, cv = _decode_step(
            weights, ck, cv, tok, jnp.int32(t), model=model, width=width,
            backend=backend, interpret=bool(interpret))
        if t >= t0 - 1:
            out.append(nxt)
    return (np.stack([np.asarray(o) for o in out], axis=1),
            np.asarray(logits))
