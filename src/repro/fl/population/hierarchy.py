"""Two-level hierarchical aggregation over the collective backend.

Topology (``FLConfig.edge_groups``): the round's cohort is split into
contiguous *edge groups* in merge order (``assign_edge_groups``).  Each
edge aggregator folds its members' dense zero-padded contributions and
masks into ONE partial (sum, count) pair — the only payload it ships
upstream — and the server combines the G partials and divides once
(Eq. 5).  The partials of the last merge are kept on the merger
(``last_partials``) for inspection and tests.

Bitwise contract (single device): the server combine CONTINUES the
client-order fold *through* the groups — the carry leaving group ``g``
seeds group ``g+1``'s fold — instead of re-associating over the
partials.  The addition sequence is therefore identical to the flat
``ordered_sum``, so the merged coefficient is bitwise-equal to the flat
``masked_block_merge`` (the same contract the collective backend keeps
vs the host scatter loop).  The per-group partials are additionally
computed from a zero seed, because they are what the edge tier uploads;
they recombine to the flat totals to float tolerance only (that
re-association is exactly what the carry chain avoids for the merged
state).  Basis/dense means divide the carried ordered total by K, so
they match the flat path's ``jnp.mean`` to float tolerance.

On a multi-device mesh the hierarchy IS the mesh: each device is an
edge aggregator for its contiguous client shard (ordered local fold)
and the server combine is the ``psum`` tree — the existing mesh merge
path, float-tolerance across devices like every psum.  The merger
therefore defers to the flat mesh implementation there.
"""

from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.engine.collective import CollectiveMerger


def assign_edge_groups(clients: List[int], num_groups: int) -> List[List[int]]:
    """Contiguous balanced split of the cohort (merge order) into
    ``num_groups`` edge groups; trailing groups may run one short."""
    k = len(clients)
    g = max(min(int(num_groups), k), 1)
    size = -(-k // g)
    return [list(clients[i:i + size]) for i in range(0, k, size)]


def grouped_ordered_fold(stacked, group_size: int):
    """Carry-chained per-group fold over the leading (client) axis.

    Returns ``(total, partials)`` where ``total`` adds the rows in the
    exact left-to-right order of ``aggregation.ordered_sum`` (each
    group's inner fold starts from the previous group's carry — bitwise
    equal to the flat fold) and ``partials[g]`` is group ``g``'s own
    zero-seeded fold (the edge upload).  The row count must divide into
    groups of ``group_size`` (zero-pad first; zero rows are IEEE
    no-ops for the total).
    """
    rows = stacked.shape[0]
    if rows % group_size:
        raise ValueError(f"{rows} rows not divisible into groups of "
                         f"{group_size}")
    num_groups = rows // group_size
    grouped = jnp.reshape(jnp.asarray(stacked),
                          (num_groups, group_size) + stacked.shape[1:])

    def add(acc, x):
        return acc + x, None

    def one_group(carry, g_rows):
        total = jax.lax.scan(add, carry, g_rows)[0]
        partial = jax.lax.scan(add, jnp.zeros_like(carry), g_rows)[0]
        return total, partial

    init = jnp.zeros(stacked.shape[1:], stacked.dtype)
    return jax.lax.scan(one_group, init, grouped)


def _pad_any(stack, rows: int):
    """Zero-pad the leading axis to ``rows`` (numpy or jax input)."""
    if stack.shape[0] == rows:
        return stack
    pad = [(0, rows - stack.shape[0])] + [(0, 0)] * (stack.ndim - 1)
    mod = np if isinstance(stack, np.ndarray) else jnp
    return mod.pad(stack, pad)


@functools.partial(jax.jit, static_argnames=("group_size",))
def _hier_fact_1d(stacked, k, *, group_size):
    """Hierarchical Heroes merge; mirrors ``_fact_1d`` op-for-op on the
    coefficient path (division/where identical element-wise, totals
    bitwise via the carry chain)."""
    merged, partials = {}, {}
    for name, t in stacked.items():
        total_b, part_b = grouped_ordered_fold(t["bases"], group_size)
        total_d, part_d = grouped_ordered_fold(t["dense"], group_size)
        total_m, part_m = grouped_ordered_fold(t["mask"], group_size)
        trained = total_m > 0
        denom = jnp.where(trained, total_m,
                          1.0)[:, None, None].astype(total_d.dtype)
        merged[name] = {
            "basis": total_b / k.astype(total_b.dtype),
            "coeff": jnp.where(trained[:, None, None], total_d / denom,
                               t["prev"]),
        }
        partials[name] = {"bases": part_b, "dense": part_d, "mask": part_m}
    return merged, partials


@functools.partial(jax.jit, static_argnames=("group_size",))
def _hier_mean_1d(stacked, k, *, group_size):
    """Hierarchical dense mean (FedAvg/ADP): ordered total / K."""
    merged = jax.tree_util.tree_map(
        lambda x: grouped_ordered_fold(x, group_size)[0] / k.astype(x.dtype),
        stacked)
    partials = jax.tree_util.tree_map(
        lambda x: grouped_ordered_fold(x, group_size)[1], stacked)
    return merged, partials


@functools.partial(jax.jit, static_argnames=("group_size",))
def _hier_masked_1d(stacked, *, group_size):
    """Hierarchical HeteroFL merge; mirrors ``_masked_1d`` op-for-op."""
    merged, partials = {}, {}
    for name, t in stacked.items():
        acc, part_a = grouped_ordered_fold(t["padded"], group_size)
        cnt, part_c = grouped_ordered_fold(t["cnt"], group_size)
        merged[name] = jnp.where(cnt > 0, acc / jnp.maximum(cnt, 1),
                                 t["prev"])
        partials[name] = {"padded": part_a, "cnt": part_c}
    return merged, partials


class HierarchicalMerger(CollectiveMerger):
    """Collective merger with a two-level edge/server fold.

    Single-device: carry-chained grouped folds (bitwise vs the flat
    merge, see module docstring), with per-group partials exposed as
    ``last_partials`` after every merge.  Multi-device mesh: defers to
    the flat mesh path — the devices already form the edge tier.  The
    flanc per-width rule keeps the flat merge (its per-width selection
    does not decompose into uniform groups).
    """

    def __init__(self, mesh=None, shard_blocks: bool = False,
                 edge_groups: int = 2):
        super().__init__(mesh, shard_blocks=shard_blocks)
        self.edge_groups = max(int(edge_groups), 1)
        self.last_partials = None

    def _grouping(self, rows: int):
        """(group_size, padded_rows) for this cohort height."""
        groups = max(min(self.edge_groups, rows), 1)
        size = -(-rows // groups)
        padded = -(-rows // size) * size
        return size, padded

    # -- finish-stage overrides (see CollectiveMerger._finish_*) ----------

    def _finish_fact(self, stacked, k: int, shard_names):
        if self.mesh is not None:
            return super()._finish_fact(stacked, k, shard_names)
        rows = next(iter(stacked.values()))["dense"].shape[0]
        size, padded = self._grouping(rows)
        stacked = {
            name: {"bases": _pad_any(t["bases"], padded),
                   "dense": _pad_any(t["dense"], padded),
                   "mask": _pad_any(t["mask"], padded),
                   "prev": t["prev"]}
            for name, t in stacked.items()
        }
        merged, partials = _hier_fact_1d(stacked, jnp.float32(k),
                                         group_size=size)
        self.last_partials = partials
        return merged

    def _finish_mean(self, stacked, k: int):
        if self.mesh is not None:
            return super()._finish_mean(stacked, k)
        rows = jax.tree_util.tree_leaves(stacked)[0].shape[0]
        size, padded = self._grouping(rows)
        stacked = jax.tree_util.tree_map(lambda x: _pad_any(x, padded),
                                         stacked)
        merged, partials = _hier_mean_1d(stacked, jnp.float32(k),
                                         group_size=size)
        self.last_partials = partials
        return merged

    def _finish_masked(self, stacked):
        if self.mesh is not None:
            return super()._finish_masked(stacked)
        rows = next(iter(stacked.values()))["padded"].shape[0]
        size, padded = self._grouping(rows)
        stacked = {
            name: {"padded": _pad_any(t["padded"], padded),
                   "cnt": _pad_any(t["cnt"], padded),
                   "prev": t["prev"]}
            for name, t in stacked.items()
        }
        merged, partials = _hier_masked_1d(stacked, group_size=size)
        self.last_partials = partials
        return merged
