"""Population-scale client simulation: 10^4–10^6 clients, O(cohort) rounds.

Four pieces (docs/POPULATION.md):

  * registry    — :class:`PopulationRegistry`: per-client state (RNG
                  stream, shard indices, capability profile, last
                  participation) derived on demand from
                  ``(seed, client_id, round)``; nothing resident.
  * partition   — :class:`VirtualPartition`: the Γ/φ/iid/natural
                  partitions as pure index functions, consumed lazily
                  through ``make_shards`` →
                  :class:`~repro.data.streaming.VirtualShardList`.
  * schedulers  — :class:`~repro.fl.engine.base.ParticipationScheduler`
                  implementations (uniform / availability /
                  resource_gated / trace) + the ``SCHEDULERS`` registry
                  feeding cohorts to the round loops via
                  ``FLConfig.participation``.
  * hierarchy   — :class:`HierarchicalMerger`: two-level edge/server
                  aggregation (``FLConfig.edge_groups``) whose
                  single-device merge stays bitwise-equal to the flat
                  ``masked_block_merge``.
"""

from repro.fl.population.hierarchy import (HierarchicalMerger,
                                           assign_edge_groups,
                                           grouped_ordered_fold)
from repro.fl.population.partition import VirtualPartition
from repro.fl.population.registry import (DEFAULT_TIER_WEIGHTS,
                                          PopulationRegistry,
                                          VirtualClientState)
from repro.fl.population.schedulers import (SCHEDULERS,
                                            AvailabilityParticipation,
                                            ResourceGatedParticipation,
                                            TraceParticipation,
                                            UniformParticipation,
                                            build_scheduler,
                                            register_scheduler)

__all__ = [
    "HierarchicalMerger", "assign_edge_groups", "grouped_ordered_fold",
    "VirtualPartition",
    "DEFAULT_TIER_WEIGHTS", "PopulationRegistry", "VirtualClientState",
    "SCHEDULERS", "AvailabilityParticipation", "ResourceGatedParticipation",
    "TraceParticipation", "UniformParticipation", "build_scheduler",
    "register_scheduler",
]
