"""Stateless client virtualization: per-client state derived on demand.

A :class:`PopulationRegistry` makes the client population a *keyspace*,
not a data structure.  Everything a round needs about client ``n`` is a
pure function of ``(seed, client_id[, round])``:

  * RNG stream    — ``default_rng((seed, round, n))``, the engine's
                    existing sequential-RNG contract (minibatch draws);
  * data shard    — ``partition.indices(n)`` through the lazy
                    :class:`~repro.fl.population.VirtualPartition`;
  * resource      — :func:`repro.fl.heterogeneity.client_profile`
    profile          (tier, compute scale, time-stream seed,
                     availability), the same function the virtual
                    :class:`~repro.fl.heterogeneity.HeterogeneityModel`
                    resolves ``het.clients[n]`` through;
  * last round    — the ONE piece of accumulated state, a compact dict
    participated     keyed only by clients that actually participated
                    (bounded by rounds x cohort, never the population).
                    When bound to an engine (``bind_participation``) the
                    dict IS the engine's ``ServerState.participation``,
                    so it checkpoints and resumes with the run.

Nothing else is resident between rounds, which is what lets 10^4–10^6
client simulations run in the memory footprint of their cohort.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Tuple

import numpy as np

from repro.fl.heterogeneity import (ClientResources, HeterogeneityModel,
                                    client_profile)

DEFAULT_TIER_WEIGHTS = (0.05, 0.15, 0.30, 0.50)


@dataclasses.dataclass(frozen=True)
class VirtualClientState:
    """Snapshot of one client's derived state for one round."""

    client_id: int
    round: int
    profile: ClientResources
    data_indices: Optional[np.ndarray]  # None when no partition is bound
    last_round: Optional[int]  # previous participation, None if never
    rng_key: Tuple[int, int, int]  # (seed, round, client_id)

    def rng(self) -> np.random.Generator:
        """The engine's sequential-RNG stream for this client-round."""
        return np.random.default_rng(self.rng_key)


class PopulationRegistry:
    """Derives per-client state on demand; holds nothing per client.

    ``partition`` is an optional lazy partition
    (:class:`~repro.fl.population.VirtualPartition`); without it,
    ``data_indices`` is None and the registry still serves profiles and
    RNG streams (e.g. for pure scheduling experiments).
    """

    def __init__(self, size: int, seed: int = 0,
                 tier_weights: Tuple[float, ...] = DEFAULT_TIER_WEIGHTS,
                 partition=None):
        if size <= 0:
            raise ValueError(f"population size must be positive, got {size}")
        if partition is not None and len(partition) != size:
            raise ValueError(f"partition covers {len(partition)} clients, "
                             f"registry covers {size}")
        self.size = int(size)
        self.seed = int(seed)
        self.tier_weights = tuple(float(w) for w in tier_weights)
        self.partition = partition
        # participation bookkeeping: participants only, never O(population)
        self._last_round: dict = {}

    def __len__(self) -> int:
        return self.size

    def _check(self, n: int) -> int:
        n = int(n)
        if not 0 <= n < self.size:
            raise IndexError(f"client {n} outside population of {self.size}")
        return n

    # -- derived state ------------------------------------------------------

    def profile(self, n: int) -> ClientResources:
        return client_profile(self.seed, self._check(n), self.tier_weights)

    def data_indices(self, n: int) -> Optional[np.ndarray]:
        if self.partition is None:
            return None
        return self.partition.indices(self._check(n))

    def rng_stream(self, n: int, rnd: int) -> np.random.Generator:
        return np.random.default_rng((self.seed, int(rnd), self._check(n)))

    def state(self, n: int, rnd: int) -> VirtualClientState:
        n = self._check(n)
        return VirtualClientState(
            client_id=n,
            round=int(rnd),
            profile=self.profile(n),
            data_indices=self.data_indices(n),
            last_round=self.last_participation(n),
            rng_key=(self.seed, int(rnd), n),
        )

    # -- participation bookkeeping -----------------------------------------

    def note_participation(self, clients: Iterable[int], rnd: int) -> None:
        for n in clients:
            self._last_round[int(n)] = int(rnd)

    def bind_participation(self, store: dict) -> dict:
        """Adopt ``store`` (the engine ``ServerState.participation``
        dict) as THE bookkeeping store, shared by identity.

        The engine records cohorts into its state — which is what gets
        checkpointed and restored — and the registry reads the same
        object, so ``last_participation`` survives a resume without a
        second copy.  Notes accumulated before binding are folded in
        (entries already in ``store``, e.g. from a restored checkpoint,
        win)."""
        for n, rnd in self._last_round.items():
            store.setdefault(n, rnd)
        self._last_round = store
        return store

    def last_participation(self, n: int) -> Optional[int]:
        return self._last_round.get(int(n))

    def participants(self) -> int:
        """Distinct clients that have participated so far."""
        return len(self._last_round)

    # -- engine binding -----------------------------------------------------

    def heterogeneity(self, seed: Optional[int] = None,
                      tier_weights: Optional[Tuple[float, ...]] = None
                      ) -> HeterogeneityModel:
        """A virtual heterogeneity model over this population.

        ``seed``/``tier_weights`` (when given) re-bind the registry's
        profile stream so ``registry.profile(n)`` and the returned
        model's ``clients[n]`` resolve through the identical pure
        function — one source of truth for the capability profile.
        """
        if seed is not None:
            self.seed = int(seed)
        if tier_weights is not None:
            self.tier_weights = tuple(float(w) for w in tier_weights)
        return HeterogeneityModel(self.size, seed=self.seed,
                                  tier_weights=self.tier_weights,
                                  virtual=True)
