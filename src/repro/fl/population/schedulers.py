"""Participation schedulers: who is *offered* each round.

The round loops used to inline uniform sampling (``eng.rng.choice``).
At population scale participation itself becomes a policy — devices are
intermittently reachable, resource-constrained, or simply too numerous
to enumerate — so sampling moves behind the
:class:`~repro.fl.engine.base.ParticipationScheduler` contract with a
registry (mirroring the scheme/trainer/loop registries):

  uniform         ``clients_per_round`` drawn uniformly without
                  replacement (the LEAF / FLGo exemplar policy) —
                  bitwise-identical to the legacy inline sampling at
                  resident scale, rejection sampling beyond
                  ``_EXACT_POOL_MAX`` so no O(population) pool is built.
  availability    each client is reachable this round with probability
                  ``profile.availability`` (an optional diurnal period
                  modulates it); gates are per-``(seed, round, client)``
                  keyed Bernoulli draws, evaluated only for candidates.
  resource_gated  per-tier duty-cycle gates: slow tiers rarely have
                  spare cycles, so cohorts skew toward capable devices.
  trace           replay an explicit availability trace (a mapping
                  ``round -> available client ids`` or a callable
                  ``(round, client_id) -> bool``), for experiments
                  driven by recorded device-uptime logs.

All schedulers draw their *selection* randomness from ``state.rng``
(the sequential seeded stream carried by the engine's ServerState —
checkpointed and restored with the run) and their *gate* randomness from
keyed streams, so cohorts are reproducible, resumable, and gates are
independent of population size and query order.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Set

import numpy as np

from repro.fl.engine.base import ParticipationScheduler
from repro.fl.heterogeneity import client_profile

# Below this population the uniform policy materializes the legacy pool
# (bitwise with the old inline sampling, including the semi-async
# exclude path); above it, rejection sampling keeps rounds O(cohort).
_EXACT_POOL_MAX = 1 << 17

_AVAIL_TAG = 0xA11AB1E  # availability gate stream
_GATE_TAG = 0x6A7ED  # resource gate stream


def _rejection_sample(rng: np.random.Generator, pop: int, k: int,
                      exclude, gate=None,
                      max_draws: Optional[int] = None) -> List[int]:
    """Distinct uniform draws from ``range(pop)`` minus ``exclude``,
    keeping only those passing ``gate`` — expected O(k / pass-rate)
    draws when ``k << pop``, never an O(pop) pool."""
    avail = pop - len(exclude)
    k = min(k, avail)
    if k <= 0:
        return []
    budget = max_draws if max_draws is not None else max(256 * k, 8192)
    chosen: List[int] = []
    seen: Set[int] = set(int(e) for e in exclude)
    while len(chosen) < k and budget > 0:
        want = min(max(2 * (k - len(chosen)), 32), budget)
        draws = rng.integers(0, pop, size=want)
        budget -= want
        for d in draws:
            d = int(d)
            if d in seen:
                continue
            seen.add(d)
            if gate is None or gate(d):
                chosen.append(d)
                if len(chosen) == k:
                    break
    return chosen


class UniformParticipation(ParticipationScheduler):
    """Uniform without-replacement sampling (the legacy inline policy)."""

    def sample(self, state, k: int, exclude=frozenset()) -> List[int]:
        pop = self.eng.cfg.num_clients
        if pop <= _EXACT_POOL_MAX:
            if not exclude:
                # the SyncRoundLoop legacy draw, verbatim (bitwise)
                return [int(c) for c in
                        state.rng.choice(pop, k, replace=False)]
            # the SemiAsyncRoundLoop legacy pool + draw, verbatim
            pool = np.array([c for c in range(pop) if c not in exclude])
            if not len(pool):
                return []
            return [int(c) for c in
                    state.rng.choice(pool, min(k, len(pool)), replace=False)]
        return _rejection_sample(state.rng, pop, k, exclude)


class _GatedParticipation(ParticipationScheduler):
    """Shared skeleton: uniform candidates filtered by a per-client,
    per-round Bernoulli gate.  Subclasses define the gate probability."""

    # gated pool enumeration is O(pop * gate); keep the exact path small
    _exact_max = 1 << 13

    def _gate_prob(self, n: int, rnd: int) -> float:
        raise NotImplementedError

    def _gate(self, n: int, rnd: int) -> bool:
        p = self._gate_prob(n, rnd)
        if p >= 1.0:
            return True
        u = np.random.default_rng(
            (self.eng.cfg.seed, self._tag, int(rnd), int(n))).random()
        return bool(u < p)

    def sample(self, state, k: int, exclude=frozenset()) -> List[int]:
        pop, rnd = self.eng.cfg.num_clients, state.round
        if pop <= self._exact_max:
            pool = np.array([c for c in range(pop)
                             if c not in exclude and self._gate(c, rnd)])
            if not len(pool):
                return []
            return [int(c) for c in
                    state.rng.choice(pool, min(k, len(pool)), replace=False)]
        return _rejection_sample(state.rng, pop, k, exclude,
                                 gate=lambda n: self._gate(n, rnd))


class AvailabilityParticipation(_GatedParticipation):
    """Clients are reachable with their profile's availability rate.

    ``period > 0`` adds a diurnal trace: the rate is modulated by a
    cosine of that period (in rounds) with a per-client phase, so
    different slices of the population come online in different rounds.
    """

    _tag = _AVAIL_TAG

    def __init__(self, period: int = 0):
        self.period = int(period)

    def _gate_prob(self, n: int, rnd: int) -> float:
        het = self.eng.het
        prof = client_profile(het.seed, int(n), het.tier_weights)
        p = prof.availability
        if self.period > 0:
            phase = (prof.seed % 997) / 997.0
            p = p * (0.5 + 0.5 * np.cos(
                2.0 * np.pi * (rnd / self.period + phase)))
        return float(p)


class ResourceGatedParticipation(_GatedParticipation):
    """Per-tier duty-cycle gates: capable devices participate more."""

    _tag = _GATE_TAG

    DEFAULT_TIER_PROB = {"laptop": 0.95, "agx_xavier": 0.80,
                         "xavier_nx": 0.55, "tx2": 0.30}

    def __init__(self, tier_prob: Optional[Dict[str, float]] = None):
        self.tier_prob = dict(tier_prob or self.DEFAULT_TIER_PROB)

    def _gate_prob(self, n: int, rnd: int) -> float:
        tier = self.eng.het.clients[int(n)].tier
        return float(self.tier_prob.get(tier, 1.0))


class TraceParticipation(ParticipationScheduler):
    """Replay an explicit availability trace.

    ``trace`` is either a mapping ``round -> iterable of available
    client ids`` (rounds absent from the mapping mean *everyone* is
    available — the uniform fallback) or a callable ``(round,
    client_id) -> bool``.  Pass an instance via the engine's
    ``sampler=`` hook, or set ``eng.availability_trace`` before the
    first round when selecting ``participation="trace"`` by name (the
    registry instantiates schedulers without arguments).
    """

    def __init__(self, trace=None):
        self.trace = trace

    def setup(self, eng) -> None:
        super().setup(eng)
        if self.trace is None:
            self.trace = getattr(eng, "availability_trace", None)

    def _require_trace(self):
        if self.trace is None:
            raise ValueError(
                "TraceParticipation has no trace: pass "
                "TraceParticipation(trace) via the engine's sampler= "
                "hook or set eng.availability_trace")
        return self.trace

    def sample(self, state, k: int, exclude=frozenset()) -> List[int]:
        trace = self._require_trace()
        pop, rnd = self.eng.cfg.num_clients, state.round
        if not callable(trace):
            avail = trace.get(int(rnd))
            if avail is None:  # round not in the trace: all reachable
                return UniformParticipation.sample(self, state, k, exclude)
            pool = np.array(sorted(int(c) for c in avail
                                   if 0 <= int(c) < pop
                                   and int(c) not in exclude))
            if not len(pool):
                return []
            return [int(c) for c in
                    state.rng.choice(pool, min(k, len(pool)), replace=False)]
        if pop <= _GatedParticipation._exact_max:
            pool = np.array([c for c in range(pop)
                             if c not in exclude and trace(rnd, c)])
            if not len(pool):
                return []
            return [int(c) for c in
                    state.rng.choice(pool, min(k, len(pool)), replace=False)]
        return _rejection_sample(state.rng, pop, k, exclude,
                                 gate=lambda n: trace(rnd, n))


SCHEDULERS: Dict[str, type] = {
    "uniform": UniformParticipation,
    "availability": AvailabilityParticipation,
    "resource_gated": ResourceGatedParticipation,
    "trace": TraceParticipation,
}


def register_scheduler(name: str):
    """Decorator registering a ParticipationScheduler class."""

    def deco(cls):
        SCHEDULERS[name] = cls
        return cls

    return deco


def build_scheduler(cfg) -> ParticipationScheduler:
    """Scheduler per ``FLConfig.participation`` (default: uniform)."""
    name = getattr(cfg, "participation", "uniform") or "uniform"
    if name not in SCHEDULERS:
        raise ValueError(f"unknown participation scheduler {name!r}; "
                         f"have {sorted(SCHEDULERS)}")
    return SCHEDULERS[name]()
