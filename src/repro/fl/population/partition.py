"""Lazy population-scale partitioning: the partition as a pure function.

The eager partitioners (repro.data.partition) materialize one index
array per client and, for the paper's Γ/φ schemes, pop samples from
shared per-class pools *sequentially* — both O(population) in time and
memory, and each client's shard depends on every client before it.
Neither survives 10^5+ clients.

:class:`VirtualPartition` replaces the list with a pure index function:
``indices(n)`` draws client ``n``'s sample indices from the keyed
stream ``default_rng((seed, _PARTITION_TAG, n))``, touching only the
per-class index pools built once from the labels.  Consequences:

  * O(cohort) work per round, O(dataset) setup, nothing per client;
  * shards are identical across processes and independent of the
    population size and of the order clients are queried in (the
    property the population determinism tests pin down);
  * clients sample *with overlap* from the class pools — at population
    scale clients outnumber samples, so the eager schemes' exactly-once
    coverage cannot hold anyway; volume lives in ``samples_per_client``
    (a fixed default, NOT dataset_size/num_clients, which would couple
    shards to the population size).

Kinds mirror the eager registry: ``dirichlet`` (Γ% from a main class,
rest spread over the others), ``class_skew`` (φ: each client lacks
``missing`` classes), ``iid``, and ``natural`` (contiguous wrap-around
windows — the synthetic-text fallback).
"""

from __future__ import annotations

import functools
from typing import Dict

import numpy as np

_PARTITION_TAG = 0x5A17ED

KINDS = ("dirichlet", "class_skew", "iid", "natural")


def _draw(rng: np.random.Generator, pool: np.ndarray, size: int) -> np.ndarray:
    """Draw ``size`` indices from ``pool`` — without replacement while
    the pool allows it, with replacement once a client wants more than
    the pool holds (population >> dataset regime)."""
    if size <= 0:
        return np.empty(0, np.int64)
    return np.asarray(
        rng.choice(pool, size=size, replace=len(pool) < size), np.int64)


class VirtualPartition:
    """Pure-function partition over ``labels`` for ``num_clients``.

    Exposes the lazy-partition protocol ``make_shards`` dispatches on:
    ``len(parts)`` (the population size) and ``parts.indices(n)`` (the
    client's sample indices, lru-cached at cohort scale).
    """

    def __init__(self, labels: np.ndarray, num_clients: int, seed: int = 0,
                 kind: str = "dirichlet", samples_per_client: int = 64,
                 gamma_pct: float = 40.0, missing: int = 2):
        if kind not in KINDS:
            raise ValueError(f"unknown virtual partition kind {kind!r}; "
                             f"have {KINDS}")
        if num_clients <= 0:
            raise ValueError(f"num_clients must be positive, got {num_clients}")
        if samples_per_client <= 0:
            raise ValueError("samples_per_client must be positive")
        labels = np.asarray(labels).reshape(-1)
        self.num_samples = int(labels.shape[0])
        self.num_clients = int(num_clients)
        self.seed = int(seed)
        self.kind = kind
        self.samples_per_client = int(samples_per_client)
        self.gamma_pct = float(gamma_pct)
        self.missing = int(missing)
        self.classes = np.unique(labels)
        # per-class index pools: the only O(dataset) state, built once
        self._pools: Dict[int, np.ndarray] = {
            int(c): np.flatnonzero(labels == c).astype(np.int64)
            for c in self.classes
        }
        self._others: Dict[int, np.ndarray] = {}  # complements, lazily
        self._all: np.ndarray = None  # full index range (iid), lazily
        if self.kind == "class_skew" and self.missing >= len(self.classes):
            raise ValueError(
                f"missing={self.missing} >= {len(self.classes)} classes")
        # cohort-scale cache: the engine re-reads a sampled client's
        # shard a handful of times per round (x, y, num_samples)
        self.indices = functools.lru_cache(maxsize=1024)(self._indices)

    def __len__(self) -> int:
        return self.num_clients

    def _rng(self, n: int) -> np.random.Generator:
        return np.random.default_rng((self.seed, _PARTITION_TAG, n))

    def _other_pool(self, main: int) -> np.ndarray:
        if main not in self._others:
            self._others[main] = np.concatenate(
                [p for c, p in self._pools.items() if c != main])
        return self._others[main]

    def _indices(self, n: int) -> np.ndarray:
        n = int(n)
        if not 0 <= n < self.num_clients:
            raise IndexError(n)
        m = self.samples_per_client
        if self.kind == "natural":
            # contiguous wrap-around window — pure in n by construction
            start = (n * m) % self.num_samples
            return (start + np.arange(m, dtype=np.int64)) % self.num_samples
        rng = self._rng(n)
        if self.kind == "iid":
            if self._all is None:
                self._all = np.arange(self.num_samples, dtype=np.int64)
            return _draw(rng, self._all, m)
        if self.kind == "dirichlet":
            # Γ scheme: main class by client id, Γ% of volume from it
            main = int(self.classes[n % len(self.classes)])
            n_main = int(round(m * self.gamma_pct / 100.0))
            n_main = min(max(n_main, 0), m)
            return np.concatenate([
                _draw(rng, self._pools[main], n_main),
                _draw(rng, self._other_pool(main), m - n_main),
            ])
        # class_skew (φ): drop `missing` classes, equal volume from the rest
        lacking = set(
            int(c) for c in rng.choice(self.classes, self.missing,
                                       replace=False))
        present = [int(c) for c in self.classes if int(c) not in lacking]
        per, extra = divmod(m, len(present))
        return np.concatenate([
            _draw(rng, self._pools[c], per + (1 if i < extra else 0))
            for i, c in enumerate(present)
        ])
