"""Shared FL runtime types.

``FLConfig`` and ``RoundLog`` are the engine's data model; ``ServerState``
is the explicit, checkpointable round state that
``RoundLoop.run_round(state) -> (state', RoundLog)`` threads through the
``AssignmentPolicy`` / ``LocalTrainer`` / ``Aggregator`` contracts.
They live here (below :mod:`repro.fl.engine`) so policy modules can share
the data model without import cycles.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class RoundLog:
    round: int
    wall_time: float  # cumulative virtual seconds
    traffic_bytes: float  # cumulative
    makespan: float  # this round's T^h
    avg_wait: float  # this round's W^h
    mean_tau: float
    accuracy: Optional[float] = None
    stale: int = 0  # results merged with staleness >= 1 (semi-async only)
    # Directional traffic split of this round's contribution to
    # ``traffic_bytes`` (uplink = client->server results, downlink =
    # server->client payloads).  Their sum equals the round's traffic
    # delta bitwise (2*b == b+b in IEEE); summaries report them apart.
    up_bytes: float = 0.0
    down_bytes: float = 0.0


@dataclasses.dataclass
class SchedState:
    """Heroes scheduler bookkeeping (per-block training-iteration tallies).

    Owned by :class:`ServerState` so it is checkpointed with the run; the
    ``HeroesScheduler`` instance itself is a stateless planner whose
    ``counters`` scratch is synced from here on every ``assign``.
    """

    counters: np.ndarray  # (num_blocks,) int64 — hidden-layer tallies
    anchored: np.ndarray  # (P,) int64 — anchored (first/last) layer tallies


@dataclasses.dataclass
class InFlight:
    """One dispatched-but-unmerged semi-async client update."""

    client: int
    assign: Dict[str, Any]  # the assignment the client trained under
    result: Any  # repro.fl.client.ClientResult
    finish: float  # virtual completion time (train + upload)
    dispatched: int  # round index at dispatch (staleness anchor)


@dataclasses.dataclass
class ServerState:
    """Everything the server carries between rounds, in one place.

    ``RoundLoop.run_round(state)`` returns a NEW instance (via
    ``dataclasses.replace``) rather than mutating engine attributes, so a
    round boundary is a value that can be checkpointed, diffed, or handed
    to another aggregator.  Two fields advance in place by design:
    ``rng`` (a live numpy Generator — its ``bit_generator.state`` is what
    gets checkpointed) and ``participation`` (shared by identity with
    ``PopulationRegistry`` as the single bookkeeping store).
    """

    rng: np.random.Generator
    bound_state: Any  # repro.core.convergence.BoundState
    params: Any = None  # scheme-shaped global model pytree
    round: int = 0  # completed rounds
    wall: float = 0.0  # cumulative virtual seconds
    traffic: float = 0.0  # cumulative bytes (up + down)
    traffic_up: float = 0.0  # cumulative uplink bytes
    traffic_down: float = 0.0  # cumulative downlink bytes
    sched: Optional[SchedState] = None  # Heroes only
    participation: Dict[int, int] = dataclasses.field(default_factory=dict)
    in_flight: Tuple[InFlight, ...] = ()  # semi-async dispatch records
    history: Tuple[RoundLog, ...] = ()


@dataclasses.dataclass
class FLConfig:
    num_clients: int = 100
    clients_per_round: int = 10
    lr: float = 0.05
    batch_size: int = 16
    tau_fixed: int = 10
    eval_every: int = 5
    seed: int = 0
    # Heroes scheduler knobs.  eps is the convergence threshold on the
    # mean-square-gradient bound (Eq. 22) — it lives on the scale of
    # G^2 + 18 sigma^2, so O(1) values are the useful regime.
    mu_max: float = 0.0  # <=0 => auto (10x median width-1 iter time)
    rho: float = 2.0
    eps: float = 1.0
    tau_max: int = 50
    estimate: bool = True
    # --- engine knobs (repro.fl.engine) ---------------------------------
    # Local-training backend: "sequential" (one jit dispatch per client,
    # bitwise-identical to the legacy runners) or "cohort" (clients with
    # the same (width, batch) stacked into one vmap+scan compiled step).
    trainer: str = "sequential"
    # Round event loop: "sync" (paper Eq. 19 makespan round) or
    # "semi_async" (aggregate the fastest K of M; stragglers merge later
    # with a staleness-discounted weight).
    round_mode: str = "sync"
    async_k: int = 0  # K for semi_async; 0 => max(1, clients_per_round // 2)
    staleness_decay: float = 0.5  # weight = decay ** staleness
    # FedProx proximal coefficient (the "fedprox" bundle's local solver:
    # every SGD step adds mu * (w - w_global); 0 reproduces FedAvg).
    prox_mu: float = 0.01
    # Engine evaluation streams the test set in slices of this many
    # samples; <= 0 evaluates the full test batch in one forward (the
    # legacy behaviour, bitwise-identical histories).  The legacy
    # backend ignores this knob and always evaluates full-batch.
    eval_batch_size: int = 0
    # Aggregation backend: "collective" (default — dense zero-padded
    # contributions + masks merged in ONE compiled call; clients laid out
    # on a device axis via shard_map/psum when >1 device is visible;
    # bitwise-equal to the host rule on a single device) or "host" (the
    # legacy per-client eager scatter loop, kept as the parity reference).
    agg_backend: str = "collective"
    agg_devices: int = 0  # cap the cohort mesh; 0 => all local devices
    # Cohort-trainer device mesh: the "cohort" trainer shards its client
    # axis over the same 1-D local-device mesh the collective merge
    # rides, so one round's local updates run data-parallel and land
    # already laid out for aggregation.  Mirrors ``agg_devices``:
    # 0 = all local devices, 1 = force the single-device path, N = cap
    # the mesh at N devices.  With one visible device the single-device
    # cohort path runs unchanged (bitwise-identical results).
    trainer_mesh_devices: int = 0
    # Sample-count-weighted aggregation: weight every client's merge
    # contribution by its shard size (K * s_n / sum(s) through the
    # aggregators' existing blend-weights path), so unbalanced
    # natural/dirichlet partitions average per *sample* instead of per
    # client.  Exact for global-mean rules (FedAvg/ADP/basis means),
    # where the blend residuals cancel over the cohort.  Note that the
    # weights are normalized over the WHOLE cohort and can exceed 1
    # (sample-heavy clients): partitioned rules (Heroes blocks, HeteroFL
    # regions, Flanc per-width sets) average blends over each covering
    # subset, where the residuals do not cancel — a lone sample-heavy
    # cover of a block extrapolates past its update (w*u + (1-w)*g with
    # w > 1) rather than computing a per-block sample-weighted mean.
    # Intended for the dense/global-mean schemes; use with care under
    # extreme skew elsewhere.  Default off keeps seed histories bitwise.
    sample_weighted: bool = False
    # Factorized (Heroes-style) client compute path: how each layer's
    # weight is applied inside local updates.
    #   "auto"        (default) per (layer, width, batch): rank-space
    #                 application — (x·v)·û, never materialising the
    #                 p-width weight — where the static FLOPs model says
    #                 it wins (apply_flops vs compose_flops +
    #                 dense_apply_flops), composed weights elsewhere.
    #   "materialize" compose every layer first — the historical path,
    #                 bitwise-identical seed histories on the platform
    #                 they were recorded on (the CPU reference
    #                 container, where compose stays the einsum; on TPU
    #                 compose routes through the Pallas kernel and there
    #                 is no prior-history baseline to match).
    #   "rank_space"  force the factorized contraction for every
    #                 rank-capable layer (scan-carried RNN recurrence
    #                 weights stay materialised).
    # Dense schemes (FedAvg/ADP/HeteroFL) are unaffected.
    forward_impl: str = "auto"
    # Rank-path cost-model calibration overrides (forward_impl="auto"
    # and clock_model="rank_aware" only).  0.0 (default) = measure once
    # per process (repro.core.calibration micro-benchmarks the fused
    # kernels at representative engine shapes); > 0 pins the knob —
    # deterministic CI, cross-host reproducibility, what-if studies.
    #   conv_rank_overhead  effective cost multiplier of the fused conv
    #                       rank path relative to its FLOPs count
    #   fused_compose_gain  fused compose+apply time over separate
    #                       compose-then-matmul; < 1 lets "auto" route
    #                       weight-shaped dense layers through the
    #                       fused kernel
    conv_rank_overhead: float = 0.0
    fused_compose_gain: float = 0.0
    # Virtual-clock client time model: what FLOPs count a simulated
    # device is charged per local iteration.
    #   "dense"       (default) the materialised width-p forward+backward
    #                 (flops_per_sample) — the historical accounting;
    #                 keeps every recorded history bitwise.
    #   "rank_aware"  factorized schemes charge the per-layer impl the
    #                 client forward actually takes under forward_impl
    #                 (apply_flops for rank-space layers, amortised
    #                 compose + dense application otherwise) — see
    #                 FLModelDef.apply_flops_per_sample.  Affects
    #                 iter-time, tau planning and the Heroes mu_max
    #                 probe; histories are versioned, not comparable to
    #                 "dense" runs.
    clock_model: str = "dense"
    # --- population knobs (repro.fl.population) -------------------------
    # Participation scheduler drawing each round's cohort from the
    # population: "uniform" (the legacy inline sampling, bitwise at
    # resident scale; rejection sampling beyond ~1e5 clients),
    # "availability" (per-client reachability rates from the virtual
    # profile, optional diurnal period) or "resource_gated" (per-tier
    # duty-cycle gates).  Registered in repro.fl.population.schedulers.
    participation: str = "uniform"
    # Two-level hierarchical aggregation: split the cohort into this
    # many contiguous edge groups, fold each group's contributions into
    # one partial (sum, count) upload, combine the partials at the
    # server with a carry-chained fold (single device: bitwise-equal to
    # the flat merge) or the psum tree (mesh).  0/1 = flat merge.
    edge_groups: int = 0
    # Factorized (Heroes-style) schemes only: keep merged coefficient
    # tensors sharded over their block axis, per tensor, when the block
    # count divides the mesh (server state scales past one device).
    # Dense/per-width scheme states have no block axis and stay
    # replicated.  Only meaningful with a multi-device mesh.
    shard_server_state: bool = False
    # --- checkpoint/resume (repro.checkpoint.msgpack_ckpt) --------------
    # Save the full ServerState every N completed rounds at the round
    # boundary (0 disables).  ``checkpoint_dir`` must be set when
    # enabled.  ``EngineRunner.restore_latest()`` resumes a run whose
    # continued history is bitwise-identical to an uninterrupted one
    # (rng stream, scheduler counters and semi-async in-flight
    # dispatches included) for every scheme x round mode.
    checkpoint_every: int = 0
    checkpoint_dir: Optional[str] = None
    checkpoint_keep: int = 3
    # --- telemetry (repro.obs) ------------------------------------------
    # "off" (default): the shared no-op recorder — zero overhead, and the
    # instrumented code paths stay bitwise-identical to the golden
    # histories.  "memory": in-process MemorySink (tests/notebooks).
    # "jsonl": append every span/event to
    # ``<telemetry_dir>/events.jsonl`` with a final metrics snapshot at
    # close; render with ``python -m repro.obs.report``.
    telemetry: str = "off"
    telemetry_dir: Optional[str] = None
