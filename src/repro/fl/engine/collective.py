"""Collective aggregation backend: one compiled merge per round.

The host aggregators merge a cohort with a Python loop of per-client
eager scatters — O(K) dispatches per layer, and the server state can
never leave one device.  This backend makes the paper's block-wise merge
(Eq. 5) the mesh-native ``masked_block_mean`` path end to end:

  1. *prep* (host, numpy): every client result is turned into a dense
     zero-padded contribution + mask (``scatter_contributions_host``) —
     the contract from ``repro.core.aggregation``.  Staleness weights
     (semi-async) are blended here, client-side, exactly as the host
     rule does: ``w * update + (1 - w) * global``.  When the
     mesh-sharded cohort trainer hands over *device-resident* stacks
     (:class:`CohortStack` / :class:`CohortSlice`) and no weights are in
     play, prep stays on device instead: rows are gathered from the
     stacks and the dense contributions come from the compiled
     from-device scatter — no host round-trip between train and merge.
  2. *merge* (device, compiled): ONE jit call per round folds the
     stacked contributions with a fixed left-to-right ``ordered_sum``
     and divides by the mask counts.  On a multi-device mesh the client
     axis is laid out on ``sharding.fl.COHORT_AXIS`` via ``shard_map``
     and the partial sums meet in a ``jax.lax.psum``; merged
     coefficient tensors can stay *sharded over their block axis*
     (``shard_blocks``, per tensor where the block count divides the
     mesh) so the server state scales past one device.

Bitwise contract: on a single device the merged state is bitwise-equal
to the host aggregators with ``weights=None`` — the ordered fold adds
the same values in the same order (zero rows are IEEE no-ops), the
basis/dense means lower to the identical ``jnp.mean`` reduce, and all
staleness blends run in numpy float32 (same correctly-rounded ops the
host's eager blend uses).  Across devices the psum re-associates the
fold, so multi-device parity is to float tolerance.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, FrozenSet, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map

from repro.core import aggregation
from repro.obs.recorder import NOOP
from repro.sharding import fl as flsh


# ---------------------------------------------------------------------------
# device-resident trainer -> merger hand-off
# ---------------------------------------------------------------------------


class CohortStack:
    """Device-resident stacked cohort results (leading client axis).

    The mesh-sharded cohort trainer produces one stack per trained
    group: a params pytree whose leaves carry the padded client axis,
    sharded over ``COHORT_AXIS``.  ``n_real`` counts the leading rows
    holding real clients — everything after is a zeroed masked-clone
    row.  ``host()`` gathers the whole stack to numpy once, lazily, and
    caches it — the fallback cost is the single ``device_get`` the
    trainer used to pay eagerly.
    """

    __slots__ = ("tree", "n_real", "_host")

    def __init__(self, tree: Any, n_real: int):
        self.tree = tree
        self.n_real = n_real
        self._host = None

    def host(self):
        if self._host is None:
            self._host = jax.device_get(self.tree)
        return self._host


class CohortSlice:
    """One client's params view into a :class:`CohortStack` row.

    This is what ``ClientResult.params`` holds when the mesh-sharded
    trainer hands results to the collective backend: the merger consumes
    whole stacks device-side (no gather/rescatter between train and
    aggregate), and anything that needs the plain numpy tree calls
    :meth:`materialize` (or ``ClientResult.host_params()``).
    """

    __slots__ = ("stack", "index")

    def __init__(self, stack: CohortStack, index: int):
        self.stack = stack
        self.index = index

    def materialize(self):
        return jax.tree_util.tree_map(lambda v: v[self.index],
                                      self.stack.host())


def _host_results(results: Dict[int, Any]) -> Dict[int, Any]:
    """Materialize device-resident params back to the numpy contract."""
    out = {}
    for n, r in results.items():
        if isinstance(r.params, CohortSlice):
            r = dataclasses.replace(r, params=r.params.materialize())
        out[n] = r
    return out


def _device_groups(results: Dict[int, Any]):
    """Cohort-stack groups ``(stack, rows, positions, clients)`` in
    first-appearance order, or ``None`` unless *every* result is a
    :class:`CohortSlice` (mixed cohorts fall back to the host prep)."""
    groups: Dict[int, list] = {}
    order: List[int] = []
    for pos, (n, r) in enumerate(results.items()):
        if not isinstance(r.params, CohortSlice):
            return None
        key = id(r.params.stack)
        if key not in groups:
            groups[key] = [r.params.stack, [], [], []]
            order.append(key)
        g = groups[key]
        g[1].append(r.params.index)
        g[2].append(pos)
        g[3].append(n)
    return [groups[k] for k in order]


def _rows_in_results_order(parts: List[Any], positions: List[np.ndarray],
                           k_pad: int):
    """Concatenate per-group row stacks back into results order and
    zero-pad the client axis to ``k_pad`` — all jnp ops, leaf-wise."""
    perm = np.argsort(np.concatenate([np.asarray(p) for p in positions]))

    def leafwise(*leaves):
        cat = leaves[0] if len(leaves) == 1 else jnp.concatenate(leaves, 0)
        if not np.array_equal(perm, np.arange(perm.size)):
            cat = jnp.take(cat, jnp.asarray(perm), 0)
        if k_pad > cat.shape[0]:
            pad = jnp.zeros((k_pad - cat.shape[0],) + cat.shape[1:],
                            cat.dtype)
            cat = jnp.concatenate([cat, pad], 0)
        return cat

    return jax.tree_util.tree_map(leafwise, *parts)


def _np_blend(update, w: float, prev):
    """Numpy mirror of the host blend ``w * update + (1 - w) * prev``.

    Scalars are cast to the update dtype first (matching jax weak-typed
    promotion) and ``1 - w`` is rounded from the python double exactly
    like the host's eager ``(1.0 - w) * prev``.
    """
    update = np.asarray(update)
    dt = update.dtype.type
    return dt(w) * update + dt(1.0 - w) * np.asarray(prev, update.dtype)


def _weight_of(weights: Optional[Dict[int, float]], n: int) -> Optional[float]:
    if weights is None:
        return None
    return float(weights.get(n, 1.0))


def _pad_rows(stack: np.ndarray, k_pad: int) -> np.ndarray:
    """Zero-pad the leading client axis to ``k_pad`` rows."""
    if stack.shape[0] == k_pad:
        return stack
    pad = [(0, k_pad - stack.shape[0])] + [(0, 0)] * (stack.ndim - 1)
    return np.pad(stack, pad)


# ---------------------------------------------------------------------------
# single-device compiled merges (bitwise vs the host loops), jitted once at
# module level so every merger shares one trace cache
# ---------------------------------------------------------------------------


@jax.jit
def _fact_1d(stacked):
    """{name: {bases, dense, mask, prev}} -> {name: {basis, coeff}}."""
    return {
        name: {
            "basis": jnp.mean(t["bases"], 0),
            "coeff": aggregation.masked_block_merge(
                t["dense"], t["mask"], t["prev"]),
        }
        for name, t in stacked.items()
    }


@jax.jit
def _mean_1d(stacked):
    """Plain mean over the client axis, leaf-wise (FedAvg/ADP)."""
    return jax.tree_util.tree_map(lambda x: jnp.mean(x, 0), stacked)


@jax.jit
def _masked_1d(stacked):
    """{name: {padded, cnt, prev}} -> {name: merged} (HeteroFL)."""
    out = {}
    for name, t in stacked.items():
        acc = aggregation.ordered_sum(t["padded"])
        cnt = aggregation.ordered_sum(t["cnt"])
        out[name] = jnp.where(cnt > 0, acc / jnp.maximum(cnt, 1), t["prev"])
    return out


@jax.jit
def _flanc_1d(stacked):
    """Basis mean over all clients + per-width coefficient means."""
    basis = {name: jnp.mean(b, 0) for name, b in stacked["bases"].items()}
    coeffs = {
        p: {name: jnp.mean(c, 0) for name, c in group.items()}
        for p, group in stacked["groups"].items()
    }
    return basis, coeffs


class CollectiveMerger:
    """Owns the compiled merge functions for one engine instance.

    ``mesh=None`` is the single-device fallback (bitwise vs the host
    path); with a mesh, clients ride the ``COHORT_AXIS`` and merges run
    under ``shard_map`` + ``psum``.  ``shard_blocks=True`` keeps merged
    coefficient tensors sharded over their block axis, per tensor,
    wherever the block count divides the mesh.
    """

    def __init__(self, mesh=None, shard_blocks: bool = False):
        self.mesh = mesh
        self.shard_blocks = shard_blocks and mesh is not None
        # mesh merge fns, built lazily per variant; a plain instance dict
        # (not lru_cache-on-method, which would pin the merger + its
        # executables in a class-level cache for the process lifetime)
        self._mesh_fns: Dict[Any, Any] = {}
        # telemetry recorder (rebound by the engine runner); merge
        # *latency* is spanned at the loop level ("aggregate.merge"),
        # the merger itself counts per-rule compiled-merge invocations
        self.obs = NOOP

    def _count(self, rule: str) -> None:
        if self.obs.enabled:
            self.obs.counter_add("aggregate.collective_calls", rule=rule)

    # -- finish stage: dispatch the prepped stacks to a compiled merge.
    # Split out so subclasses can reroute the reduction topology (the
    # hierarchical edge-group merger in repro.fl.population.hierarchy)
    # without touching the prep contracts.

    def _finish_fact(self, stacked, k: int, shard_names: FrozenSet[str]):
        if self.mesh is None:
            return _fact_1d(stacked)
        return self._mesh_fact_fn(shard_names)(stacked, jnp.float32(k))

    def _finish_mean(self, stacked, k: int):
        if self.mesh is None:
            return _mean_1d(stacked)
        return self._mesh_mean_fn()(stacked, jnp.float32(k))

    def _finish_masked(self, stacked):
        if self.mesh is None:
            return _masked_1d(stacked)
        return self._mesh_masked_fn()(stacked)

    # -- mesh (shard_map) merge builders -----------------------------------

    def _mesh_fact_fn(self, shard_names: FrozenSet[str]):
        key = ("fact", shard_names)
        if key in self._mesh_fns:
            return self._mesh_fns[key]
        mesh, axis = self.mesh, flsh.COHORT_AXIS
        ndev = mesh.devices.size
        contrib, repl = flsh.contribution_spec(), flsh.replicated_spec()

        def per_device(stacked, k_real):
            out = {}
            for name, t in stacked.items():
                bsum = jax.lax.psum(aggregation.ordered_sum(t["bases"]), axis)
                basis = bsum / k_real.astype(bsum.dtype)
                coeff = aggregation.masked_block_merge(
                    t["dense"], t["mask"], t["prev"], axis_name=axis)
                if name in shard_names:
                    per = coeff.shape[0] // ndev
                    idx = jax.lax.axis_index(axis)
                    coeff = jax.lax.dynamic_slice_in_dim(
                        coeff, idx * per, per, axis=0)
                out[name] = {"basis": basis, "coeff": coeff}
            return out

        per_name_in = {"bases": contrib, "dense": contrib, "mask": contrib,
                       "prev": repl}

        def merge(stacked, k_real):
            f = shard_map(
                per_device, mesh=mesh,
                in_specs=({n: per_name_in for n in stacked}, repl),
                out_specs={n: {"basis": repl,
                               "coeff": flsh.block_spec()
                               if n in shard_names else repl}
                           for n in stacked})
            return f(stacked, k_real)

        fn = jax.jit(merge)
        self._mesh_fns[key] = fn
        return fn

    def _mesh_mean_fn(self):
        if "mean" in self._mesh_fns:
            return self._mesh_fns["mean"]
        mesh, axis = self.mesh, flsh.COHORT_AXIS
        contrib, repl = flsh.contribution_spec(), flsh.replicated_spec()

        def per_device(stacked, k_real):
            return jax.tree_util.tree_map(
                lambda x: jax.lax.psum(aggregation.ordered_sum(x), axis)
                / k_real.astype(x.dtype), stacked)

        def merge(stacked, k_real):
            f = shard_map(
                per_device, mesh=mesh,
                in_specs=(jax.tree_util.tree_map(lambda _: contrib, stacked),
                          repl),
                out_specs=jax.tree_util.tree_map(lambda _: repl, stacked))
            return f(stacked, k_real)

        fn = jax.jit(merge)
        self._mesh_fns["mean"] = fn
        return fn

    def _mesh_masked_fn(self):
        if "masked" in self._mesh_fns:
            return self._mesh_fns["masked"]
        mesh, axis = self.mesh, flsh.COHORT_AXIS
        contrib, repl = flsh.contribution_spec(), flsh.replicated_spec()

        def per_device(stacked):
            out = {}
            for name, t in stacked.items():
                acc = jax.lax.psum(aggregation.ordered_sum(t["padded"]), axis)
                cnt = jax.lax.psum(aggregation.ordered_sum(t["cnt"]), axis)
                out[name] = jnp.where(cnt > 0, acc / jnp.maximum(cnt, 1),
                                      t["prev"])
            return out

        def merge(stacked):
            per_in = {"padded": contrib, "cnt": contrib, "prev": repl}
            f = shard_map(per_device, mesh=mesh,
                          in_specs=({n: per_in for n in stacked},),
                          out_specs={n: repl for n in stacked})
            return f(stacked)

        fn = jax.jit(merge)
        self._mesh_fns["masked"] = fn
        return fn

    def _mesh_flanc_fn(self):
        if "flanc" in self._mesh_fns:
            return self._mesh_fns["flanc"]
        mesh, axis = self.mesh, flsh.COHORT_AXIS
        contrib, repl = flsh.contribution_spec(), flsh.replicated_spec()

        def per_device(stacked, k_real):
            basis = {
                name: jax.lax.psum(aggregation.ordered_sum(b), axis)
                / k_real.astype(b.dtype)
                for name, b in stacked["bases"].items()
            }
            onehot = stacked["onehot"]  # (K_local, P)
            coeffs = {}
            for p, group in stacked["prevs"].items():
                sel = jax.lax.psum(jnp.sum(onehot[:, p - 1]), axis)
                coeffs[p] = {}
                for name, prev in group.items():
                    total = jax.lax.psum(
                        jnp.einsum("k,k...->...", onehot[:, p - 1],
                                   stacked["dense"][name]), axis)
                    nb = prev.shape[0]
                    mean = total[:nb] / jnp.maximum(sel, 1).astype(total.dtype)
                    coeffs[p][name] = jnp.where(sel > 0, mean, prev)
            return basis, coeffs

        def merge(stacked, k_real):
            in_specs = ({
                "bases": {n: contrib for n in stacked["bases"]},
                "onehot": contrib,
                "dense": {n: contrib for n in stacked["dense"]},
                "prevs": {p: {n: repl for n in g}
                          for p, g in stacked["prevs"].items()},
            }, repl)
            out_specs = ({n: repl for n in stacked["bases"]},
                         {p: {n: repl for n in g}
                          for p, g in stacked["prevs"].items()})
            f = shard_map(per_device, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs)
            return f(stacked, k_real)

        fn = jax.jit(merge)
        self._mesh_fns["flanc"] = fn
        return fn

    # -- device-resident prep (mesh-sharded trainer hand-off) --------------

    def _device_stacked(self, groups, k_pad: int):
        """Client rows stacked in results order, zero-padded to ``k_pad``
        — all device-side.  When the trainer's stack already matches
        (one group consuming *every* real row in trained order, same
        padded height — so the rows beyond are zeroed clones) the
        stack's tree passes through untouched: the params trained on
        the cohort axis feed the merge with no data movement at all."""
        if len(groups) == 1:
            stack, rows, _, _ = groups[0]
            nrows = jax.tree_util.tree_leaves(stack.tree)[0].shape[0]
            if rows == list(range(stack.n_real)) and nrows == k_pad:
                return stack.tree
        parts = [jax.tree_util.tree_map(
            lambda v, r=np.asarray(g[1]): jnp.take(v, jnp.asarray(r), 0),
            g[0].tree) for g in groups]
        return _rows_in_results_order(parts, [g[2] for g in groups], k_pad)

    def _merge_factorized_device(self, prev_params, specs, groups, k: int,
                                 k_pad: int, assigns):
        """Factorized merge fed straight from device-resident stacks:
        coefficient rows become dense contributions through the compiled
        from-device scatter (one vmapped call per group/layer), bases
        are row-gathers — the host never sees the trained params."""
        shard_names: FrozenSet[str] = frozenset()
        if self.shard_blocks:
            shard_names = frozenset(
                n for n, t in prev_params.items()
                if flsh.can_shard_blocks(t["coeff"].shape[0], self.mesh))
        stacked: Dict[str, Dict[str, Any]] = {}
        positions = [g[2] for g in groups]
        for name, spec in specs.items():
            ids_key = "hidden_ids" if spec.mode == "square" else "anchored_ids"
            prev_c = prev_params[name]["coeff"]
            bases, dense, mask = [], [], []
            for stack, rows, _, ns in groups:
                sub = stack.tree[name]
                r = jnp.asarray(np.asarray(rows))
                bases.append(jnp.take(sub["basis"], r, 0))
                ids = np.stack([np.asarray(assigns[n][ids_key]) for n in ns])
                d, m = aggregation.scatter_contributions_host(
                    jnp.take(sub["coeff"], r, 0), jnp.asarray(ids),
                    num_blocks=prev_c.shape[0])
                dense.append(d)
                mask.append(m)
            stacked[name] = {
                "bases": _rows_in_results_order(bases, positions, k_pad),
                "dense": _rows_in_results_order(dense, positions, k_pad),
                "mask": _rows_in_results_order(mask, positions, k_pad),
                "prev": prev_c,
            }
        return self._finish_fact(stacked, k, shard_names)

    # -- prep + dispatch ----------------------------------------------------

    def merge_factorized(self, prev_params, specs, results, assigns,
                         weights=None):
        """Heroes merge: basis mean + Eq. 5 block-wise coefficient merge."""
        self._count("factorized")
        k = len(results)
        k_pad = flsh.pad_cohort(k, self.mesh)
        if weights is None:
            groups = _device_groups(results)
            if groups is not None:
                return self._merge_factorized_device(
                    prev_params, specs, groups, k, k_pad, assigns)
        results = _host_results(results)
        stacked: Dict[str, Dict[str, Any]] = {}
        for name, spec in specs.items():
            ids_key = "hidden_ids" if spec.mode == "square" else "anchored_ids"
            prev_c = prev_params[name]["coeff"]
            prev_c_np = prev_b_np = None
            bases, blocks, ids = [], [], []
            for n, r in results.items():
                b = np.asarray(r.params[name]["basis"])
                c = np.asarray(r.params[name]["coeff"])
                i = np.asarray(assigns[n][ids_key])
                w = _weight_of(weights, n)
                if w is not None:
                    if prev_c_np is None:
                        prev_c_np = np.asarray(prev_c)
                        prev_b_np = np.asarray(prev_params[name]["basis"])
                    b = _np_blend(b, w, prev_b_np)
                    c = _np_blend(c, w, prev_c_np[i])
                bases.append(b)
                blocks.append(c)
                ids.append(i)
            dense, mask = aggregation.scatter_contributions_host(
                blocks, ids, num_blocks=prev_c.shape[0])
            stacked[name] = {
                "bases": _pad_rows(np.stack(bases), k_pad),
                "dense": _pad_rows(dense, k_pad),
                "mask": _pad_rows(mask, k_pad),
                "prev": prev_c,
            }
        shard_names: FrozenSet[str] = frozenset()
        if self.shard_blocks:
            shard_names = frozenset(
                n for n, t in stacked.items()
                if flsh.can_shard_blocks(t["prev"].shape[0], self.mesh))
        return self._finish_fact(stacked, k, shard_names)

    def merge_dense_mean(self, prev_params, results, weights=None):
        """FedAvg/ADP: plain parameter mean over the cohort."""
        self._count("dense_mean")
        k = len(results)
        k_pad = flsh.pad_cohort(k, self.mesh)
        if weights is None:
            groups = _device_groups(results)
            if groups is not None:
                stacked = self._device_stacked(groups, k_pad)
                return self._finish_mean(stacked, k)
        results = _host_results(results)
        prev_np = None
        trees = []
        for n, r in results.items():
            w = _weight_of(weights, n)
            if w is None:
                trees.append(jax.tree_util.tree_map(np.asarray, r.params))
            else:
                if prev_np is None:
                    prev_np = jax.tree_util.tree_map(np.asarray, prev_params)
                trees.append(jax.tree_util.tree_map(
                    lambda u, g, w=w: _np_blend(u, w, g), r.params, prev_np))
        stacked = jax.tree_util.tree_map(
            lambda *xs: _pad_rows(np.stack(xs), k_pad), *trees)
        return self._finish_mean(stacked, k)

    def merge_masked_dense(self, prev_params, results, weights=None):
        """HeteroFL: element-wise mean over the covering clients."""
        self._count("masked_dense")
        results = _host_results(results)
        k_pad = flsh.pad_cohort(len(results), self.mesh)
        stacked = {}
        for name, full in prev_params.items():
            full_np = None
            pads, cnts = [], []
            for n, r in results.items():
                wv = np.asarray(r.params[name])
                w = _weight_of(weights, n)
                if w is not None:
                    if full_np is None:
                        full_np = np.asarray(full)
                    region = full_np[tuple(slice(0, s) for s in wv.shape)]
                    wv = _np_blend(wv, w, region)
                pad = [(0, full.shape[i] - wv.shape[i])
                       for i in range(wv.ndim)]
                pads.append(np.pad(wv, pad))
                cnts.append(np.pad(np.ones_like(wv), pad))
            stacked[name] = {"padded": _pad_rows(np.stack(pads), k_pad),
                             "cnt": _pad_rows(np.stack(cnts), k_pad),
                             "prev": full}
        return self._finish_masked(stacked)

    def merge_flanc(self, basis, coeffs, results, widths, weights=None):
        """Flanc: shared basis mean + per-width coefficient means.

        ``widths`` maps client -> assigned width (which coefficient set
        the client trained).  Returns ``(new_basis, new_coeffs)`` where
        widths nobody trained keep their previous coefficients.
        """
        self._count("flanc")
        results = _host_results(results)
        k = len(results)
        names = list(basis)
        max_width = max(coeffs)
        bases = {name: [] for name in names}
        for n, r in results.items():
            w = _weight_of(weights, n)
            for name in names:
                b = np.asarray(r.params[name]["basis"])
                if w is not None:
                    b = _np_blend(b, w, np.asarray(basis[name]))
                bases[name].append(b)

        if self.mesh is None:
            by_width: Dict[int, List[int]] = {}
            for n in results:
                by_width.setdefault(widths[n], []).append(n)
            groups = {}
            for p, ns in by_width.items():
                groups[p] = {}
                for name in names:
                    rows = []
                    for n in ns:
                        c = np.asarray(results[n].params[name]["coeff"])
                        w = _weight_of(weights, n)
                        if w is not None:
                            c = _np_blend(c, w, np.asarray(coeffs[p][name]))
                        rows.append(c)
                    groups[p][name] = np.stack(rows)
            new_basis, merged = _flanc_1d(
                {"bases": {n: np.stack(b) for n, b in bases.items()},
                 "groups": groups})
            new_coeffs = dict(coeffs)
            for p, g in merged.items():
                new_coeffs[p] = g
            return new_basis, new_coeffs

        # mesh path: every client contributes ONE zero-padded dense coeff
        # (padded to the width-P block count) plus a one-hot width row;
        # per-width means select rows through the one-hot.
        k_pad = flsh.pad_cohort(k, self.mesh)
        onehot = np.zeros((k_pad, max_width), np.float32)
        dense = {name: [] for name in names}
        for j, n in enumerate(results):
            p = widths[n]
            onehot[j, p - 1] = 1.0
            for name in names:
                c = np.asarray(results[n].params[name]["coeff"])
                w = _weight_of(weights, n)
                if w is not None:
                    c = _np_blend(c, w, np.asarray(coeffs[p][name]))
                nb_max = coeffs[max_width][name].shape[0]
                pad = [(0, nb_max - c.shape[0])] + [(0, 0)] * (c.ndim - 1)
                dense[name].append(np.pad(c, pad))
        stacked = {
            "bases": {n: _pad_rows(np.stack(b), k_pad)
                      for n, b in bases.items()},
            "onehot": onehot,
            "dense": {n: _pad_rows(np.stack(rows), k_pad)
                      for n, rows in dense.items()},
            "prevs": {p: {n: coeffs[p][n] for n in names} for p in coeffs},
        }
        return self._mesh_flanc_fn()(stacked, jnp.float32(k))


def build_merger(cfg) -> CollectiveMerger:
    """Merger per the engine config: mesh when >1 device is visible;
    hierarchical edge-group reduction when ``cfg.edge_groups > 1``."""
    mesh = flsh.cohort_mesh(getattr(cfg, "agg_devices", 0))
    shard = getattr(cfg, "shard_server_state", False)
    groups = getattr(cfg, "edge_groups", 0)
    if groups and groups > 1:
        # population layers on the engine; import here to avoid a cycle
        from repro.fl.population.hierarchy import HierarchicalMerger
        return HierarchicalMerger(mesh, shard_blocks=shard,
                                  edge_groups=groups)
    return CollectiveMerger(mesh, shard_blocks=shard)
