"""Layered FL engine: schemes as policy bundles over a shared core.

See :mod:`repro.fl.engine.base` for the component contracts and
:mod:`repro.fl.engine.registry` for the five paper schemes expressed as
bundles.  ``build_engine`` is the main entry point; ``run_scheme`` in
:mod:`repro.fl.simulation` routes through it by default.
"""

from repro.fl.engine.aggregators import (DenseMeanAggregator,  # noqa: F401
                                         FlancAggregator, HeroesAggregator,
                                         MaskedDenseAggregator)
from repro.fl.engine.collective import (CohortSlice, CohortStack,  # noqa: F401
                                        CollectiveMerger, build_merger)
from repro.fl.engine.base import (Aggregator, AssignmentPolicy,  # noqa: F401
                                  LocalTrainer, ParticipationScheduler,
                                  PayloadModel, RoundLoop)
from repro.fl.engine.loops import SemiAsyncRoundLoop, SyncRoundLoop  # noqa: F401
from repro.fl.engine.payload import DensePayload, FactorizedPayload  # noqa: F401
from repro.fl.engine.policies import (FullWidthAssignment,  # noqa: F401
                                      HeroesAssignment, TierWidthAssignment,
                                      tier_width)
from repro.fl.engine.registry import (SCHEMES, SchemeBundle,  # noqa: F401
                                      build_engine, register_scheme)
from repro.fl.engine.runner import EngineRunner  # noqa: F401
from repro.fl.engine.trainers import (CohortTrainer,  # noqa: F401
                                      ProximalTrainer, SequentialTrainer)
