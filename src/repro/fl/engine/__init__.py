"""Layered FL engine: schemes as policy bundles over a shared core.

See :mod:`repro.fl.engine.base` for the component contracts (threaded
through an explicit :class:`~repro.fl.types.ServerState`) and
:mod:`repro.fl.engine.registry` for the five paper schemes expressed as
bundles.  ``build_engine`` is the main entry point; ``run_scheme`` in
:mod:`repro.fl.simulation` routes through it by default.
"""

from repro.fl.engine.aggregators import (DenseMeanAggregator,
                                         FlancAggregator, HeroesAggregator,
                                         MaskedDenseAggregator)
from repro.fl.engine.base import (Aggregator, AssignmentPolicy,
                                  LocalTrainer, ParticipationScheduler,
                                  PayloadModel, RoundLoop)
from repro.fl.engine.collective import (CohortSlice, CohortStack,
                                        CollectiveMerger, build_merger)
from repro.fl.engine.loops import SemiAsyncRoundLoop, SyncRoundLoop
from repro.fl.engine.payload import DensePayload, FactorizedPayload
from repro.fl.engine.policies import (FullWidthAssignment,
                                      HeroesAssignment, TierWidthAssignment,
                                      tier_width)
from repro.fl.engine.registry import (SCHEMES, SchemeBundle,
                                      build_engine, register_scheme)
from repro.fl.engine.runner import EngineRunner
from repro.fl.engine.state import payload_to_state, state_to_payload
from repro.fl.engine.trainers import (CohortTrainer,
                                      ProximalTrainer, SequentialTrainer)
from repro.fl.types import InFlight, SchedState, ServerState

__all__ = [
    "Aggregator", "AssignmentPolicy", "LocalTrainer",
    "ParticipationScheduler", "PayloadModel", "RoundLoop",
    "DenseMeanAggregator", "FlancAggregator", "HeroesAggregator",
    "MaskedDenseAggregator",
    "CohortSlice", "CohortStack", "CollectiveMerger", "build_merger",
    "SemiAsyncRoundLoop", "SyncRoundLoop",
    "DensePayload", "FactorizedPayload",
    "FullWidthAssignment", "HeroesAssignment", "TierWidthAssignment",
    "tier_width",
    "SCHEMES", "SchemeBundle", "build_engine", "register_scheme",
    "EngineRunner",
    "payload_to_state", "state_to_payload",
    "InFlight", "SchedState", "ServerState",
    "CohortTrainer", "ProximalTrainer", "SequentialTrainer",
]
