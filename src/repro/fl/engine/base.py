"""Component contracts of the layered FL engine.

A *scheme* (FedAvg, ADP, HeteroFL, Flanc, Heroes, ...) is a bundle of
five independently testable components wired to a shared
:class:`~repro.fl.engine.runner.EngineRunner`:

  AssignmentPolicy        who trains what: (width, tau, block ids) per client
  PayloadModel            traffic accounting: bytes shipped per assignment
  Aggregator              global-state owner: init / client view / merge / eval
  LocalTrainer            client-update backend: sequential or batched cohort
  RoundLoop               virtual-clock event loop: synchronous or semi-async
  ParticipationScheduler  who is offered the round: cohort sampling policy
                          (implementations + registry live in
                          repro.fl.population.schedulers)

Each component is bound to the runner with :meth:`setup` for its *static*
collaborators (model, heterogeneity profile, config, merger).  All
*round* state — params, BoundState, rng, wall/traffic/round counters,
scheduler tallies, participation bookkeeping, in-flight dispatches —
lives in one explicit :class:`~repro.fl.types.ServerState` value that
``RoundLoop.run_round(state) -> (state', RoundLog)`` threads state-in /
state-out through every contract below.  Components never stash round
state on themselves or the runner, which is what makes a round boundary
checkpointable (``FLConfig.checkpoint_every``) and resumable bitwise.
The contract deliberately mirrors where the paper's five schemes
actually differ (Sec. VI-B), so a new scheme is a policy bundle, not a
runner subclass.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Any, Dict, Optional, Sequence, Tuple

from repro.fl.client import ClientResult
from repro.fl.types import RoundLog, ServerState
from repro.obs.recorder import NOOP

if TYPE_CHECKING:  # pragma: no cover
    from repro.fl.engine.runner import EngineRunner

Assignment = Dict[str, Any]  # {"width": int, "tau": int, [block-id keys]}


class Component:
    """Base: every engine component is bound to one runner."""

    eng: "EngineRunner"

    def setup(self, eng: "EngineRunner") -> None:
        self.eng = eng

    @property
    def obs(self):
        """The bound runner's telemetry recorder (:mod:`repro.obs`);
        the shared no-op before :meth:`setup` binds a runner."""
        return getattr(getattr(self, "eng", None), "obs", NOOP)


class AssignmentPolicy(Component):
    """Decides (width, tau, tensor blocks) for a set of sampled clients.

    ``assign`` returns ``(state', assigns)``: any control state the
    policy advances (Heroes' per-block counters) is carried in
    ``state.sched``, never on the policy instance.  The returned dict's
    insertion order is the order every downstream consumer iterates in,
    which keeps histories reproducible.
    """

    def init_state(self, state: ServerState) -> ServerState:
        """Attach policy-owned fields to a fresh state (default: none)."""
        return state

    def assign(self, state: ServerState, clients: Sequence[int],
               ) -> Tuple[ServerState, Dict[int, Assignment]]:
        raise NotImplementedError


class PayloadModel(Component):
    """Bytes shipped one way for one client's assignment."""

    def bytes(self, assignment: Assignment) -> float:
        raise NotImplementedError


class Aggregator(Component):
    """Owns the global model layout: init, per-client view, merge, eval.

    The model itself lives in ``state.params`` (scheme-shaped pytree);
    ``init_global``/``aggregate`` return updated states rather than
    assigning runner attributes.

    ``aggregate`` accepts optional per-client ``weights`` in [0, 1] used
    by asynchronous loops for staleness discounting: a client's
    contribution is blended as ``w * update + (1 - w) * current_global``
    before the scheme's own merge rule runs, so ``weights=None`` (or all
    ones) reproduces the synchronous rule bitwise.

    Merges run through the engine's collective backend (``eng.merger``,
    one compiled call per round, clients on a device axis when a mesh is
    present — staleness weights included) unless
    ``FLConfig(agg_backend="host")`` selects the per-client eager
    scatter loop kept as the parity reference.
    """

    def init_global(self, state: ServerState) -> ServerState:
        raise NotImplementedError

    def client_params(self, state: ServerState, n: int,
                      assignment: Assignment) -> Any:
        """The parameter view shipped to client ``n`` this round."""
        raise NotImplementedError

    def aggregate(
        self,
        state: ServerState,
        results: Dict[int, ClientResult],
        assigns: Dict[int, Assignment],
        weights: Optional[Dict[int, float]] = None,
    ) -> ServerState:
        raise NotImplementedError

    def evaluate(self, state: ServerState) -> float:
        raise NotImplementedError


class LocalTrainer(Component):
    """Runs the local updates for every assigned client of one dispatch.

    Reads the global view through ``aggregator.client_params(state, ...)``
    and the round index from ``state.round`` (the per-client data/rng
    streams are keyed ``(seed, round, client)``).  Returned
    ``ClientResult.params`` trees are host-resident (numpy): the
    collective aggregation backend scatters them into dense zero-padded
    contributions + masks on the host and ships the stacked cohort to
    the device in one transfer per round.
    """

    def train_all(self, state: ServerState,
                  assigns: Dict[int, Assignment]) -> Dict[int, ClientResult]:
        raise NotImplementedError


class RoundLoop(Component):
    """Advances the virtual clock by one aggregation event.

    ``run_round(state)`` returns ``(state', log)`` where ``state'`` is a
    new :class:`~repro.fl.types.ServerState` (``dataclasses.replace``)
    with the log appended to ``state'.history`` — the runner only
    installs the returned value and decides whether to checkpoint it.
    """

    def run_round(self, state: ServerState,
                  ) -> Tuple[ServerState, RoundLog]:
        raise NotImplementedError


class ParticipationScheduler(Component):
    """Samples one round's cohort from the client population.

    Contract for ``sample(state, k, exclude)``:

      * returns distinct client ids (draws WITHOUT replacement), none of
        them in ``exclude`` (clients already in flight, semi-async);
      * returns at most ``k`` ids; fewer only when the eligible pool is
        smaller (availability/resource gates, or everyone excluded);
      * consumes ``state.rng`` — the sequential round RNG carried by the
        server state — for the cohort selection, so schedulers sit
        *inside* the seeded history contract (the default uniform policy
        reproduces the loops' legacy inline sampling bitwise) and resume
        exactly from a checkpointed rng state;
      * does O(cohort) expected work: per-client gates are derived from
        keyed hash streams and the population profile, never from
        resident per-client state.

    Round loops call :meth:`~repro.fl.engine.runner.EngineRunner.sample_clients`,
    which delegates here and records participation in
    ``state.participation`` (shared by identity with the population
    registry when one is bound).  Implementations + the ``SCHEDULERS``
    registry live in :mod:`repro.fl.population.schedulers`.
    """

    def sample(self, state: ServerState, k: int, exclude=frozenset()) -> list:
        raise NotImplementedError
