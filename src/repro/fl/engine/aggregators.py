"""Aggregators: global-state owners + merge rules per scheme.

Each aggregator reproduces the legacy runner merge bitwise when
``weights is None`` (the synchronous path — pinned by the golden
fixtures in tests/fixtures/golden_legacy_histories.json).  With
per-client ``weights`` (semi-async staleness discounting) every client
contribution is first blended toward the *current* global state::

    contrib_n = w_n * update_n + (1 - w_n) * global

so a fully fresh client (w=1) merges exactly as in the synchronous rule
and an infinitely stale one (w=0) is a no-op.

The global model is ``state.params`` — aggregators hold no tensors of
their own; ``init_global``/``aggregate`` return updated
:class:`~repro.fl.types.ServerState` values (params + BoundState), which
is what lets a round boundary checkpoint and resume bitwise.

Two merge backends share each rule: the default *collective* path
(``eng.merger``, repro.fl.engine.collective) stacks the cohort's dense
zero-padded contributions and merges them in ONE compiled call —
sharded over a client device axis when a mesh is present — and the
*host* path (``_aggregate_host``, selected with
``FLConfig(agg_backend="host")``) keeps the original per-client eager
scatter loops as the independent parity reference.  On one device the
two are bitwise-identical with ``weights=None``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, convergence
from repro.fl.client import ClientResult
from repro.fl.engine.base import Aggregator, Assignment
from repro.fl.types import ServerState


def _weight_list(results: Dict[int, ClientResult],
                 weights: Optional[Dict[int, float]]):
    if weights is None:
        return None
    return [float(weights.get(n, 1.0)) for n in results]


def _mean_bound(state: ServerState, results, lr: float,
                clip: bool) -> Any:
    """BoundState from the cohort's (L, G^2, sigma^2) estimates; the
    incoming bound when nobody shipped estimates."""
    ests = [r.estimates for r in results.values() if r.estimates]
    if not ests:
        return state.bound_state
    mean = {k: float(np.mean([e[k] for e in ests])) for k in ests[0]}
    loss0 = float(np.mean([r.loss_after for r in results.values()]))
    if clip:
        return convergence.BoundState(
            loss0=max(loss0, 1e-3),
            smoothness=float(np.clip(mean.get("L", 1.0), 1e-3, 1e3)),
            grad_sq=mean.get("grad_sq", 1.0),
            noise_sq=mean.get("sigma_sq", 0.5), lr=lr)
    return convergence.BoundState(
        loss0=loss0, smoothness=max(mean.get("L", 1.0), 1e-3),
        grad_sq=mean.get("grad_sq", 1.0),
        noise_sq=mean.get("sigma_sq", 0.5), lr=lr)


class DenseMeanAggregator(Aggregator):
    """FedAvg/ADP: plain parameter mean over the cohort."""

    def init_global(self, state: ServerState) -> ServerState:
        eng = self.eng
        return dataclasses.replace(
            state, params=eng.model.init_dense(
                jax.random.PRNGKey(eng.cfg.seed)))

    def client_params(self, state: ServerState, n: int,
                      assignment: Assignment) -> Any:
        return state.params

    def aggregate(self, state, results, assigns, weights=None) -> ServerState:
        eng = self.eng
        if eng.merger is not None:
            params = eng.merger.merge_dense_mean(state.params, results,
                                                 weights)
        else:
            params = self._aggregate_host(state, results, weights)
        return dataclasses.replace(
            state, params=params,
            bound_state=_mean_bound(state, results, eng.cfg.lr, clip=False))

    def _aggregate_host(self, state, results, weights):
        ws = _weight_list(results, weights)
        if ws is None:
            stacked = [r.params for r in results.values()]
        else:
            stacked = [
                jax.tree_util.tree_map(lambda u, g, w=w: w * u + (1.0 - w) * g,
                                       r.params, state.params)
                for r, w in zip(results.values(), ws)
            ]
        return jax.tree_util.tree_map(
            lambda *xs: jnp.mean(jnp.stack(xs), 0), *stacked)

    def evaluate(self, state: ServerState) -> float:
        eng = self.eng
        ew = eng.eval_width
        params = state.params if ew == eng.P else eng.model.slice_dense(
            state.params, ew)
        # streamed over cfg.eval_batch_size slices (full batch when <= 0)
        return eng.acc_streaming(
            lambda batch: eng.model.forward(params, ew, batch))


class MaskedDenseAggregator(DenseMeanAggregator):
    """HeteroFL: element-wise mean over the clients covering each region."""

    def client_params(self, state: ServerState, n: int,
                      assignment: Assignment) -> Any:
        return self.eng.model.slice_dense(state.params, assignment["width"])

    def aggregate(self, state, results, assigns, weights=None) -> ServerState:
        eng = self.eng
        if eng.merger is not None:
            params = eng.merger.merge_masked_dense(state.params, results,
                                                   weights)
        else:
            params = self._aggregate_host(state, results, weights)
        return dataclasses.replace(
            state, params=params,
            bound_state=_mean_bound(state, results, eng.cfg.lr, clip=False))

    def _aggregate_host(self, state, results, weights):
        new = {}
        for name in state.params:
            full = state.params[name]
            acc = jnp.zeros_like(full)
            cnt = jnp.zeros_like(full)
            for n, r in results.items():
                w = r.params[name]
                if weights is not None:
                    wn = float(weights.get(n, 1.0))
                    region = full[tuple(slice(0, s) for s in w.shape)]
                    w = wn * w + (1.0 - wn) * region
                pad = [(0, full.shape[i] - w.shape[i]) for i in range(full.ndim)]
                acc = acc + jnp.pad(w, pad)
                cnt = cnt + jnp.pad(jnp.ones_like(w), pad)
            covered = cnt > 0
            new[name] = jnp.where(covered, acc / jnp.maximum(cnt, 1), full)
        return new


class FlancAggregator(Aggregator):
    """Original NC: shared basis average + per-width coefficient average.

    ``state.params`` is ``{"basis": {layer: basis}, "coeffs": {width p:
    {layer: coeff}}}`` — width p owns its own copy of the first
    ``blocks_for_width(p)`` blocks (original Flanc: no sharing).
    """

    def init_global(self, state: ServerState) -> ServerState:
        eng = self.eng
        full = eng.model.init_factorized(jax.random.PRNGKey(eng.cfg.seed))
        basis = {name: full[name]["basis"] for name in full}
        coeffs = {
            p: {name: full[name]["coeff"][: eng.model.specs[name].blocks_for_width(p)]
                for name in full}
            for p in range(1, eng.P + 1)
        }
        return dataclasses.replace(state,
                                   params={"basis": basis, "coeffs": coeffs})

    def client_params(self, state: ServerState, n: int,
                      assignment: Assignment) -> Any:
        return self._width_params(state.params, assignment["width"])

    def _width_params(self, params, p: int):
        return {name: {"basis": params["basis"][name],
                       "coeff": params["coeffs"][p][name]}
                for name in params["basis"]}

    def aggregate(self, state, results, assigns, weights=None) -> ServerState:
        eng = self.eng
        basis, coeffs = state.params["basis"], state.params["coeffs"]
        if eng.merger is not None:
            widths = {n: assigns[n]["width"] for n in results}
            basis, coeffs = eng.merger.merge_flanc(
                basis, coeffs, results, widths, weights)
        else:
            basis, coeffs = self._aggregate_host(basis, coeffs, results,
                                                 assigns, weights)
        return dataclasses.replace(state,
                                   params={"basis": basis, "coeffs": coeffs})

    def _aggregate_host(self, basis, coeffs, results, assigns, weights):
        def blend(n, name, key, prev):
            v = results[n].params[name][key]
            if weights is None:
                return v
            w = float(weights.get(n, 1.0))
            return w * v + (1.0 - w) * prev

        new_basis = {
            name: jnp.mean(jnp.stack(
                [blend(n, name, "basis", basis[name]) for n in results]), 0)
            for name in basis
        }
        by_width: Dict[int, list] = {}
        for n in results:
            by_width.setdefault(assigns[n]["width"], []).append(n)
        new_coeffs = dict(coeffs)
        for p, ns in by_width.items():
            new_coeffs[p] = {
                name: jnp.mean(jnp.stack(
                    [blend(n, name, "coeff", coeffs[p][name]) for n in ns]), 0)
                for name in basis
            }
        return new_basis, new_coeffs

    def evaluate(self, state: ServerState) -> float:
        eng = self.eng
        ew = eng.eval_width
        params = self._width_params(state.params, ew)
        w = eng.model.compose_all(params, ew)
        return eng.acc_streaming(
            lambda batch: eng.model.forward(w, ew, batch))


class HeroesAggregator(Aggregator):
    """Enhanced NC: basis average + block-wise coefficient merge (Eq. 5)."""

    def init_global(self, state: ServerState) -> ServerState:
        eng = self.eng
        return dataclasses.replace(
            state, params=eng.model.init_factorized(
                jax.random.PRNGKey(eng.cfg.seed)))

    def client_params(self, state: ServerState, n: int,
                      assignment: Assignment) -> Any:
        return self.eng.model.reduce(
            state.params, assignment["width"],
            assignment["hidden_ids"], assignment["anchored_ids"])

    def aggregate(self, state, results, assigns, weights=None) -> ServerState:
        eng = self.eng
        if eng.merger is not None:
            params = eng.merger.merge_factorized(
                state.params, eng.model.specs, results, assigns, weights)
        else:
            params = self._aggregate_host(state, results, assigns, weights)
        return dataclasses.replace(
            state, params=params,
            bound_state=_mean_bound(state, results, eng.cfg.lr, clip=True))

    def _aggregate_host(self, state, results, assigns, weights):
        eng = self.eng
        ws = _weight_list(results, weights)
        new = {}
        for name, spec in eng.model.specs.items():
            ids_key = "hidden_ids" if spec.mode == "square" else "anchored_ids"
            new[name] = {
                "basis": aggregation.aggregate_basis(
                    [r.params[name]["basis"] for r in results.values()],
                    weights=ws, prev=state.params[name]["basis"]),
                "coeff": aggregation.aggregate_coefficient(
                    state.params[name]["coeff"],
                    [r.params[name]["coeff"] for r in results.values()],
                    [np.asarray(assigns[n][ids_key]) for n in results],
                    weights=ws,
                ),
            }
        return new

    def evaluate(self, state: ServerState) -> float:
        # evaluate the width-``eval_width`` sub-model built from the first
        # blocks (the full set when eval_width == P, the usual case).
        # Evaluation always materialises (compose_all): the weights are
        # composed ONCE per eval and reused across every streamed test
        # slice, and keeping eval on the materialize path makes reported
        # accuracies independent of cfg.forward_impl.
        eng = self.eng
        ew = eng.eval_width
        square_spec = next(
            s for s in eng.model.specs.values() if s.mode == "square")
        hidden_ids = np.arange(square_spec.blocks_for_width(ew))
        anch_ids = np.arange(min(ew, eng.P))
        reduced = eng.model.reduce(state.params, ew, hidden_ids, anch_ids)
        w = eng.model.compose_all(reduced, ew)
        return eng.acc_streaming(
            lambda batch: eng.model.forward(w, ew, batch))
