"""Aggregators: global-state owners + merge rules per scheme.

Each aggregator reproduces its legacy runner's merge bitwise when
``weights is None`` (the synchronous path).  With per-client ``weights``
(semi-async staleness discounting) every client contribution is first
blended toward the *current* global state::

    contrib_n = w_n * update_n + (1 - w_n) * global

so a fully fresh client (w=1) merges exactly as in the synchronous rule
and an infinitely stale one (w=0) is a no-op.

Two merge backends share each rule: the default *collective* path
(``eng.merger``, repro.fl.engine.collective) stacks the cohort's dense
zero-padded contributions and merges them in ONE compiled call —
sharded over a client device axis when a mesh is present — and the
*host* path (``_aggregate_host``, selected with
``FLConfig(agg_backend="host")``) keeps the original per-client eager
scatter loops as the independent parity reference.  On one device the
two are bitwise-identical with ``weights=None``.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, convergence
from repro.fl.client import ClientResult
from repro.fl.engine.base import Aggregator, Assignment


def _weight_list(results: Dict[int, ClientResult],
                 weights: Optional[Dict[int, float]]):
    if weights is None:
        return None
    return [float(weights.get(n, 1.0)) for n in results]


class DenseMeanAggregator(Aggregator):
    """FedAvg/ADP: plain parameter mean over the cohort."""

    def init_global(self) -> None:
        eng = self.eng
        eng.params = eng.model.init_dense(jax.random.PRNGKey(eng.cfg.seed))

    def client_params(self, n: int, assignment: Assignment) -> Any:
        return self.eng.params

    def aggregate(self, results, assigns, weights=None) -> None:
        eng = self.eng
        if eng.merger is not None:
            eng.params = eng.merger.merge_dense_mean(eng.params, results,
                                                     weights)
        else:
            self._aggregate_host(results, weights)
        self._update_bound(results)

    def _aggregate_host(self, results, weights) -> None:
        eng = self.eng
        ws = _weight_list(results, weights)
        if ws is None:
            stacked = [r.params for r in results.values()]
        else:
            stacked = [
                jax.tree_util.tree_map(lambda u, g, w=w: w * u + (1.0 - w) * g,
                                       r.params, eng.params)
                for r, w in zip(results.values(), ws)
            ]
        eng.params = jax.tree_util.tree_map(
            lambda *xs: jnp.mean(jnp.stack(xs), 0), *stacked
        )

    def _update_bound(self, results) -> None:
        eng = self.eng
        ests = [r.estimates for r in results.values() if r.estimates]
        if ests:
            mean = {k: float(np.mean([e[k] for e in ests])) for k in ests[0]}
            eng.bound_state = convergence.BoundState(
                loss0=float(np.mean([r.loss_after for r in results.values()])),
                smoothness=max(mean.get("L", 1.0), 1e-3),
                grad_sq=mean.get("grad_sq", 1.0),
                noise_sq=mean.get("sigma_sq", 0.5),
                lr=eng.cfg.lr,
            )

    def evaluate(self) -> float:
        eng = self.eng
        ew = eng.eval_width
        params = eng.params if ew == eng.P else eng.model.slice_dense(
            eng.params, ew)
        # streamed over cfg.eval_batch_size slices (full batch when <= 0)
        return eng.acc_streaming(
            lambda batch: eng.model.forward(params, ew, batch))


class MaskedDenseAggregator(DenseMeanAggregator):
    """HeteroFL: element-wise mean over the clients covering each region."""

    def client_params(self, n: int, assignment: Assignment) -> Any:
        return self.eng.model.slice_dense(self.eng.params, assignment["width"])

    def aggregate(self, results, assigns, weights=None) -> None:
        eng = self.eng
        if eng.merger is not None:
            eng.params = eng.merger.merge_masked_dense(eng.params, results,
                                                       weights)
        else:
            self._aggregate_host(results, weights)
        self._update_bound(results)

    def _aggregate_host(self, results, weights) -> None:
        eng = self.eng
        new = {}
        for name in eng.params:
            full = eng.params[name]
            acc = jnp.zeros_like(full)
            cnt = jnp.zeros_like(full)
            for n, r in results.items():
                w = r.params[name]
                if weights is not None:
                    wn = float(weights.get(n, 1.0))
                    region = full[tuple(slice(0, s) for s in w.shape)]
                    w = wn * w + (1.0 - wn) * region
                pad = [(0, full.shape[i] - w.shape[i]) for i in range(full.ndim)]
                acc = acc + jnp.pad(w, pad)
                cnt = cnt + jnp.pad(jnp.ones_like(w), pad)
            covered = cnt > 0
            new[name] = jnp.where(covered, acc / jnp.maximum(cnt, 1), full)
        eng.params = new


class FlancAggregator(Aggregator):
    """Original NC: shared basis average + per-width coefficient average."""

    def init_global(self) -> None:
        eng = self.eng
        full = eng.model.init_factorized(jax.random.PRNGKey(eng.cfg.seed))
        # per-width coefficient sets: width p owns its own copy of the
        # first blocks_for_width(p) blocks (original Flanc: no sharing)
        self.basis = {name: full[name]["basis"] for name in full}
        self.coeffs = {
            p: {name: full[name]["coeff"][: eng.model.specs[name].blocks_for_width(p)]
                for name in full}
            for p in range(1, eng.P + 1)
        }
        eng.params = {"basis": self.basis, "coeffs": self.coeffs}

    def client_params(self, n: int, assignment: Assignment) -> Any:
        return self._width_params(assignment["width"])

    def _width_params(self, p: int):
        return {name: {"basis": self.basis[name], "coeff": self.coeffs[p][name]}
                for name in self.basis}

    def aggregate(self, results, assigns, weights=None) -> None:
        eng = self.eng
        if eng.merger is not None:
            widths = {n: assigns[n]["width"] for n in results}
            self.basis, self.coeffs = eng.merger.merge_flanc(
                self.basis, self.coeffs, results, widths, weights)
            eng.params = {"basis": self.basis, "coeffs": self.coeffs}
            return
        self._aggregate_host(results, assigns, weights)

    def _aggregate_host(self, results, assigns, weights) -> None:
        def blend(n, name, key, prev):
            v = results[n].params[name][key]
            if weights is None:
                return v
            w = float(weights.get(n, 1.0))
            return w * v + (1.0 - w) * prev

        self.basis = {
            name: jnp.mean(jnp.stack(
                [blend(n, name, "basis", self.basis[name]) for n in results]), 0)
            for name in self.basis
        }
        by_width: Dict[int, list] = {}
        for n in results:
            by_width.setdefault(assigns[n]["width"], []).append(n)
        for p, ns in by_width.items():
            self.coeffs[p] = {
                name: jnp.mean(jnp.stack(
                    [blend(n, name, "coeff", self.coeffs[p][name]) for n in ns]), 0)
                for name in self.basis
            }
        self.eng.params = {"basis": self.basis, "coeffs": self.coeffs}

    def evaluate(self) -> float:
        eng = self.eng
        ew = eng.eval_width
        params = self._width_params(ew)
        w = eng.model.compose_all(params, ew)
        return eng.acc_streaming(
            lambda batch: eng.model.forward(w, ew, batch))


class HeroesAggregator(Aggregator):
    """Enhanced NC: basis average + block-wise coefficient merge (Eq. 5)."""

    def init_global(self) -> None:
        eng = self.eng
        eng.params = eng.model.init_factorized(jax.random.PRNGKey(eng.cfg.seed))

    def client_params(self, n: int, assignment: Assignment) -> Any:
        return self.eng.model.reduce(
            self.eng.params, assignment["width"],
            assignment["hidden_ids"], assignment["anchored_ids"])

    def aggregate(self, results, assigns, weights=None) -> None:
        eng = self.eng
        if eng.merger is not None:
            eng.params = eng.merger.merge_factorized(
                eng.params, eng.model.specs, results, assigns, weights)
        else:
            self._aggregate_host(results, assigns, weights)
        ests = [r.estimates for r in results.values() if r.estimates]
        if ests:
            mean = {k: float(np.mean([e[k] for e in ests])) for k in ests[0]}
            eng.bound_state = convergence.BoundState(
                loss0=max(float(np.mean(
                    [r.loss_after for r in results.values()])), 1e-3),
                smoothness=float(np.clip(mean.get("L", 1.0), 1e-3, 1e3)),
                grad_sq=mean.get("grad_sq", 1.0),
                noise_sq=mean.get("sigma_sq", 0.5),
                lr=eng.cfg.lr,
            )

    def _aggregate_host(self, results, assigns, weights) -> None:
        eng = self.eng
        ws = _weight_list(results, weights)
        new = {}
        for name, spec in eng.model.specs.items():
            ids_key = "hidden_ids" if spec.mode == "square" else "anchored_ids"
            new[name] = {
                "basis": aggregation.aggregate_basis(
                    [r.params[name]["basis"] for r in results.values()],
                    weights=ws, prev=eng.params[name]["basis"]),
                "coeff": aggregation.aggregate_coefficient(
                    eng.params[name]["coeff"],
                    [r.params[name]["coeff"] for r in results.values()],
                    [np.asarray(assigns[n][ids_key]) for n in results],
                    weights=ws,
                ),
            }
        eng.params = new

    def evaluate(self) -> float:
        # evaluate the width-``eval_width`` sub-model built from the first
        # blocks (the full set when eval_width == P, the usual case).
        # Evaluation always materialises (compose_all): the weights are
        # composed ONCE per eval and reused across every streamed test
        # slice, and keeping eval on the materialize path makes reported
        # accuracies independent of cfg.forward_impl.
        eng = self.eng
        ew = eng.eval_width
        square_spec = next(
            s for s in eng.model.specs.values() if s.mode == "square")
        hidden_ids = np.arange(square_spec.blocks_for_width(ew))
        anch_ids = np.arange(min(ew, eng.P))
        reduced = eng.model.reduce(eng.params, ew, hidden_ids, anch_ids)
        w = eng.model.compose_all(reduced, ew)
        return eng.acc_streaming(
            lambda batch: eng.model.forward(w, ew, batch))
