"""Round event loops over the virtual clock.

``SyncRoundLoop`` is the paper's round (Alg. 1 / Eq. 19): sample K
clients, train all, aggregate, charge the makespan ``max_n (tau mu + nu)``
to the wall clock.  Histories are pinned bitwise by the golden legacy
fixtures (tests/fixtures/golden_legacy_histories.json).

``SemiAsyncRoundLoop`` keeps up to M clients in flight and aggregates as
soon as the fastest K of them finish.  Stragglers stay in flight across
aggregation events and merge later with a staleness-discounted weight
``decay ** staleness`` (their update was computed against an older
global model), the FedAsync/FedBuff-style rule adapted to every
scheme's aggregator.  The wall clock advances event-by-event to the
K-th completion, so fast clients stop paying for slow ones.

Both loops are pure state transitions: ``run_round(state)`` returns
``(state', log)`` built with ``dataclasses.replace`` — the wall/traffic
counters, params, bound, Heroes tallies and (semi-async) the in-flight
dispatch records all travel inside the :class:`~repro.fl.types.ServerState`,
which is exactly what makes a round boundary checkpointable.  The time
model's per-round noise streams are keyed by ``het.round``; the loops
*derive* it from the state (``het.round = state.round + 1`` while round
``state.round`` runs) instead of advancing a hidden counter, so a
restored state replays identical times.

Both loops hand the same ``weights`` dict to ``aggregator.aggregate``;
with the collective backend the staleness blend is folded into the
dense contribution prep, so semi-async events use the identical
compiled merge as synchronous rounds (no separate weighted path).

``FLConfig.sample_weighted`` rides that same path: per-client sample
counts become blend weights ``K * s_n / sum(s)``, which turns the
cohort mean into the sample-count-weighted mean — exactly — for the
global-mean rules.  The weights can exceed 1, so partitioned rules
(per-block / per-region / per-width subsets, where the blend residuals
do not cancel) see an extrapolated weighting rather than a per-subset
weighted mean; see ``FLConfig.sample_weighted``.  Semi-async
multiplies the weights into the staleness discounts.  Off by default —
seed histories stay bitwise.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.fl.engine.base import RoundLoop
from repro.fl.types import InFlight, RoundLog, ServerState


def _sample_weights(eng, clients) -> Dict[int, float]:
    """Sample-count weights ``K * s_n / sum(s)`` for one merge cohort.

    Routed through the aggregators' blend-weights path
    (``w * update + (1 - w) * global`` before the scheme's mean), this
    reduces the plain cohort mean to ``sum(s_n * u_n) / sum(s_n)`` —
    the FedAvg paper's sample-weighted objective — because the blend
    residuals ``(1 - w_n)`` cancel over the cohort.  Weights are NOT
    clamped to [0, 1]: sample-heavy clients carry w > 1, which is what
    makes the global mean exact but turns per-subset rules into an
    extrapolation (see ``FLConfig.sample_weighted``).
    """
    s = np.array([eng.data.num_samples(n) for n in clients], np.float64)
    w = s * (len(clients) / s.sum())
    return {n: float(wn) for n, wn in zip(clients, w)}


class SyncRoundLoop(RoundLoop):
    """Synchronous makespan round (paper Eq. 19)."""

    def run_round(self, state: ServerState) -> Tuple[ServerState, RoundLog]:
        eng = self.eng
        cfg = eng.cfg
        eng.het.round = state.round + 1  # per-round time-noise stream key
        # cohort via the participation scheduler (uniform default is the
        # legacy eng.rng.choice draw, bitwise)
        clients = eng.sample_clients(state, cfg.clients_per_round)
        if not clients:
            raise RuntimeError(
                "participation scheduler returned an empty cohort "
                f"(scheduler={type(eng.sampler).__name__}, "
                f"num_clients={cfg.num_clients})")
        state, assigns = eng.assignment.assign(state, clients)
        results = eng.trainer.train_all(state, assigns)
        obs = eng.obs
        times = {}
        traffic = state.traffic
        up = 0.0
        for n, a in assigns.items():
            mu = eng.het.iter_time(n, eng.flops_per_iter(a["width"]))
            b = eng.payload.bytes(a)
            nu = eng.het.upload_time(n, b)
            times[n] = a["tau"] * mu + nu
            traffic += 2 * b  # down + up
            up += b  # symmetric payloads: uplink == downlink == b
            if obs.enabled:
                t0 = state.wall
                t_train = t0 + a["tau"] * mu
                obs.span("client.train", t0, t_train, client=int(n),
                         width=int(a["width"]), tau=int(a["tau"]),
                         round=state.round + 1)
                obs.span("client.upload", t_train, t_train + nu,
                         client=int(n), bytes=b, round=state.round + 1)
                obs.counter_add("traffic.up", b, width=int(a["width"]))
                obs.counter_add("traffic.down", b, width=int(a["width"]))
        weights = (_sample_weights(eng, list(results))
                   if cfg.sample_weighted else None)
        with obs.wall_span("aggregate.merge", clients=len(results)):
            state = eng.aggregator.aggregate(
                dataclasses.replace(state, traffic=traffic,
                                    traffic_up=state.traffic_up + up,
                                    traffic_down=state.traffic_down + up),
                results, assigns, weights=weights)
        makespan = max(times.values())
        wait = float(np.mean([makespan - t for t in times.values()]))
        state = dataclasses.replace(state, wall=state.wall + makespan,
                                    round=state.round + 1)
        acc = None
        if state.round % cfg.eval_every == 0 or state.round == 1:
            acc = eng.aggregator.evaluate(state)
        if obs.enabled:
            obs.observe("round.makespan", makespan)
            obs.observe("round.wait", wait)
            obs.event("round.aggregate", state.wall, round=state.round,
                      clients=len(results))
        log = RoundLog(state.round, state.wall, state.traffic, makespan, wait,
                       float(np.mean([a["tau"] for a in assigns.values()])),
                       acc, up_bytes=up, down_bytes=up)
        state = dataclasses.replace(state, history=state.history + (log,))
        return state, log


class SemiAsyncRoundLoop(RoundLoop):
    """Aggregate the fastest K of M in-flight clients per event.

    One ``run_round`` call = one aggregation event.  Training results are
    computed eagerly at dispatch against the then-current global state —
    exactly what a straggler's update would contain when it finally
    lands — and merged with weight ``staleness_decay ** staleness``.
    Dispatch records live in ``state.in_flight`` (host-resident numpy
    param trees), so an event boundary checkpoints stragglers and all.
    """

    def __init__(self, k: Optional[int] = None,
                 staleness_decay: Optional[float] = None):
        self._k_override = k
        self._decay_override = staleness_decay

    def setup(self, eng) -> None:
        super().setup(eng)
        cfg = eng.cfg
        self.k = self._k_override or cfg.async_k \
            or max(1, cfg.clients_per_round // 2)
        self.decay = (self._decay_override if self._decay_override is not None
                      else cfg.staleness_decay)

    def _dispatch(self, state: ServerState,
                  clients: List[int]) -> ServerState:
        eng = self.eng
        state, assigns = eng.assignment.assign(state, clients)
        results = eng.trainer.train_all(state, assigns)
        obs = eng.obs
        traffic = state.traffic
        up = 0.0
        new = []
        for n, a in assigns.items():
            mu = eng.het.iter_time(n, eng.flops_per_iter(a["width"]))
            b = eng.payload.bytes(a)
            nu = eng.het.upload_time(n, b)
            traffic += 2 * b
            up += b
            finish = state.wall + a["tau"] * mu + nu
            new.append(InFlight(n, a, results[n], finish, state.round))
            if obs.enabled:
                t_train = state.wall + a["tau"] * mu
                obs.span("client.train", state.wall, t_train, client=int(n),
                         width=int(a["width"]), tau=int(a["tau"]),
                         round=state.round + 1)
                obs.span("client.upload", t_train, finish, client=int(n),
                         bytes=b, round=state.round + 1)
                obs.counter_add("traffic.up", b, width=int(a["width"]))
                obs.counter_add("traffic.down", b, width=int(a["width"]))
        return dataclasses.replace(state, traffic=traffic,
                                   traffic_up=state.traffic_up + up,
                                   traffic_down=state.traffic_down + up,
                                   in_flight=state.in_flight + tuple(new))

    def run_round(self, state: ServerState) -> Tuple[ServerState, RoundLog]:
        eng = self.eng
        cfg = eng.cfg
        obs = eng.obs
        eng.het.round = state.round + 1
        up0, down0 = state.traffic_up, state.traffic_down
        busy = {t.client for t in state.in_flight}
        need = cfg.clients_per_round - len(state.in_flight)
        if need > 0:
            # the eligible pool can be empty (clients_per_round >
            # num_clients, every client already in flight, or no client
            # passes its participation gate): skip the dispatch instead
            # of spuriously advancing assignment-policy state on [].
            newly = eng.sample_clients(state, need, exclude=busy)
            if newly:
                state = self._dispatch(state, newly)
        if not state.in_flight:
            raise RuntimeError(
                "semi-async round with no dispatchable clients "
                f"(num_clients={cfg.num_clients}, "
                f"clients_per_round={cfg.clients_per_round})")

        # stable sort: ties keep dispatch order, like the legacy in-place
        # list sort, so event composition is reproducible
        flight = sorted(state.in_flight, key=lambda t: t.finish)
        k = min(self.k, len(flight))
        t_k = flight[k - 1].finish
        done = [t for t in flight if t.finish <= t_k]
        remaining = [t for t in flight if t.finish > t_k]

        results = {t.client: t.result for t in done}
        assigns = {t.client: t.assign for t in done}
        stale = sum(1 for t in done if state.round > t.dispatched)
        # all-fresh events take the cheap synchronous merge path
        weights = None if stale == 0 else {
            t.client: self.decay ** (state.round - t.dispatched)
            for t in done}
        if cfg.sample_weighted:
            sw = _sample_weights(eng, list(results))
            weights = sw if weights is None else \
                {n: sw[n] * weights[n] for n in sw}
        if obs.enabled:
            for t in done:
                obs.observe("staleness", float(state.round - t.dispatched))
        with obs.wall_span("aggregate.merge", clients=len(results),
                           stale=stale):
            state = eng.aggregator.aggregate(state, results, assigns,
                                             weights=weights)
        # stragglers must not pin device-resident cohort stacks (and
        # their host caches) across events: degrade their results to the
        # plain numpy contract now, so each stack dies with its event —
        # which also keeps in-flight records checkpointable as-is
        remaining = tuple(
            dataclasses.replace(
                t, result=dataclasses.replace(
                    t.result, params=t.result.host_params()))
            for t in remaining)

        makespan = t_k - state.wall  # time since the previous aggregation
        wait = float(np.mean([t_k - t.finish for t in done]))
        state = dataclasses.replace(state, wall=t_k, round=state.round + 1,
                                    in_flight=remaining)
        acc = None
        if state.round % cfg.eval_every == 0 or state.round == 1:
            acc = eng.aggregator.evaluate(state)
        if obs.enabled:
            obs.observe("round.makespan", makespan)
            obs.observe("round.wait", wait)
            obs.event("round.aggregate", state.wall, round=state.round,
                      clients=len(results), stale=stale,
                      in_flight=len(remaining))
            obs.gauge_set("loop.in_flight", len(remaining))
        log = RoundLog(state.round, state.wall, state.traffic, makespan, wait,
                       float(np.mean([a["tau"] for a in assigns.values()])),
                       acc, stale=stale,
                       up_bytes=state.traffic_up - up0,
                       down_bytes=state.traffic_down - down0)
        state = dataclasses.replace(state, history=state.history + (log,))
        return state, log
