"""Round event loops over the virtual clock.

``SyncRoundLoop`` is the paper's round (Alg. 1 / Eq. 19): sample K
clients, train all, aggregate, charge the makespan ``max_n (tau mu + nu)``
to the wall clock.  Bitwise-identical histories to the legacy
``BaseRunner.run_round``.

``SemiAsyncRoundLoop`` keeps up to M clients in flight and aggregates as
soon as the fastest K of them finish.  Stragglers stay in flight across
aggregation events and merge later with a staleness-discounted weight
``decay ** staleness`` (their update was computed against an older
global model), the FedAsync/FedBuff-style rule adapted to every
scheme's aggregator.  The wall clock advances event-by-event to the
K-th completion, so fast clients stop paying for slow ones.

Both loops hand the same ``weights`` dict to ``aggregator.aggregate``;
with the collective backend the staleness blend is folded into the
dense contribution prep, so semi-async events use the identical
compiled merge as synchronous rounds (no separate weighted path).

``FLConfig.sample_weighted`` rides that same path: per-client sample
counts become blend weights ``K * s_n / sum(s)``, which turns the
cohort mean into the sample-count-weighted mean — exactly — for the
global-mean rules.  The weights can exceed 1, so partitioned rules
(per-block / per-region / per-width subsets, where the blend residuals
do not cancel) see an extrapolated weighting rather than a per-subset
weighted mean; see ``FLConfig.sample_weighted``.  Semi-async
multiplies the weights into the staleness discounts.  Off by default —
seed histories stay bitwise.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

from repro.fl.client import ClientResult
from repro.fl.engine.base import Assignment, RoundLoop
from repro.fl.types import RoundLog


def _sample_weights(eng, clients) -> Dict[int, float]:
    """Sample-count weights ``K * s_n / sum(s)`` for one merge cohort.

    Routed through the aggregators' blend-weights path
    (``w * update + (1 - w) * global`` before the scheme's mean), this
    reduces the plain cohort mean to ``sum(s_n * u_n) / sum(s_n)`` —
    the FedAvg paper's sample-weighted objective — because the blend
    residuals ``(1 - w_n)`` cancel over the cohort.  Weights are NOT
    clamped to [0, 1]: sample-heavy clients carry w > 1, which is what
    makes the global mean exact but turns per-subset rules into an
    extrapolation (see ``FLConfig.sample_weighted``).
    """
    s = np.array([eng.data.num_samples(n) for n in clients], np.float64)
    w = s * (len(clients) / s.sum())
    return {n: float(wn) for n, wn in zip(clients, w)}


class SyncRoundLoop(RoundLoop):
    """Synchronous makespan round (paper Eq. 19)."""

    def run_round(self) -> RoundLog:
        eng = self.eng
        cfg = eng.cfg
        eng.het.advance_round()
        # cohort via the participation scheduler (uniform default is the
        # legacy eng.rng.choice draw, bitwise)
        clients = eng.sample_clients(cfg.clients_per_round)
        if not clients:
            raise RuntimeError(
                "participation scheduler returned an empty cohort "
                f"(scheduler={type(eng.sampler).__name__}, "
                f"num_clients={cfg.num_clients})")
        assigns = eng.assignment.assign(clients)
        results = eng.trainer.train_all(assigns)
        times = {}
        for n, a in assigns.items():
            mu = eng.het.iter_time(n, eng.flops_per_iter(a["width"]))
            nu = eng.het.upload_time(n, eng.payload.bytes(a))
            times[n] = a["tau"] * mu + nu
            eng.traffic += 2 * eng.payload.bytes(a)  # down + up
        weights = (_sample_weights(eng, list(results))
                   if cfg.sample_weighted else None)
        eng.aggregator.aggregate(results, assigns, weights=weights)
        makespan = max(times.values())
        wait = float(np.mean([makespan - t for t in times.values()]))
        eng.wall += makespan
        eng.round += 1
        acc = None
        if eng.round % cfg.eval_every == 0 or eng.round == 1:
            acc = eng.aggregator.evaluate()
        log = RoundLog(eng.round, eng.wall, eng.traffic, makespan, wait,
                       float(np.mean([a["tau"] for a in assigns.values()])), acc)
        eng.history.append(log)
        return log


@dataclasses.dataclass
class _InFlight:
    client: int
    assign: Assignment
    result: ClientResult
    finish: float  # absolute virtual time the upload lands at the PS
    dispatched: int  # eng.round at dispatch (staleness = now - dispatched)


class SemiAsyncRoundLoop(RoundLoop):
    """Aggregate the fastest K of M in-flight clients per event.

    One ``run_round`` call = one aggregation event.  Training results are
    computed eagerly at dispatch against the then-current global state —
    exactly what a straggler's update would contain when it finally
    lands — and merged with weight ``staleness_decay ** staleness``.
    """

    def __init__(self, k: Optional[int] = None,
                 staleness_decay: Optional[float] = None):
        self._k_override = k
        self._decay_override = staleness_decay

    def setup(self, eng) -> None:
        super().setup(eng)
        cfg = eng.cfg
        self.k = self._k_override or cfg.async_k \
            or max(1, cfg.clients_per_round // 2)
        self.decay = (self._decay_override if self._decay_override is not None
                      else cfg.staleness_decay)
        self.in_flight: List[_InFlight] = []

    def _dispatch(self, clients: List[int]) -> None:
        eng = self.eng
        assigns = eng.assignment.assign(clients)
        results = eng.trainer.train_all(assigns)
        for n, a in assigns.items():
            mu = eng.het.iter_time(n, eng.flops_per_iter(a["width"]))
            nu = eng.het.upload_time(n, eng.payload.bytes(a))
            eng.traffic += 2 * eng.payload.bytes(a)
            self.in_flight.append(_InFlight(
                n, a, results[n], eng.wall + a["tau"] * mu + nu, eng.round))

    def run_round(self) -> RoundLog:
        eng = self.eng
        cfg = eng.cfg
        eng.het.advance_round()
        busy = {t.client for t in self.in_flight}
        need = cfg.clients_per_round - len(self.in_flight)
        if need > 0:
            # the eligible pool can be empty (clients_per_round >
            # num_clients, every client already in flight, or no client
            # passes its participation gate): skip the dispatch instead
            # of spuriously advancing assignment-policy state on [].
            newly = eng.sample_clients(need, exclude=busy)
            if newly:
                self._dispatch(newly)
        if not self.in_flight:
            raise RuntimeError(
                "semi-async round with no dispatchable clients "
                f"(num_clients={cfg.num_clients}, "
                f"clients_per_round={cfg.clients_per_round})")

        self.in_flight.sort(key=lambda t: t.finish)
        k = min(self.k, len(self.in_flight))
        t_k = self.in_flight[k - 1].finish
        done = [t for t in self.in_flight if t.finish <= t_k]
        self.in_flight = [t for t in self.in_flight if t.finish > t_k]

        results = {t.client: t.result for t in done}
        assigns = {t.client: t.assign for t in done}
        stale = sum(1 for t in done if eng.round > t.dispatched)
        # all-fresh events take the cheap synchronous merge path
        weights = None if stale == 0 else {
            t.client: self.decay ** (eng.round - t.dispatched) for t in done}
        if cfg.sample_weighted:
            sw = _sample_weights(eng, list(results))
            weights = sw if weights is None else \
                {n: sw[n] * weights[n] for n in sw}
        eng.aggregator.aggregate(results, assigns, weights=weights)
        # stragglers must not pin device-resident cohort stacks (and
        # their host caches) across events: degrade their results to the
        # plain numpy contract now, so each stack dies with its event
        for t in self.in_flight:
            t.result = dataclasses.replace(t.result,
                                           params=t.result.host_params())

        makespan = t_k - eng.wall  # time since the previous aggregation
        wait = float(np.mean([t_k - t.finish for t in done]))
        eng.wall = t_k
        eng.round += 1
        acc = None
        if eng.round % cfg.eval_every == 0 or eng.round == 1:
            acc = eng.aggregator.evaluate()
        log = RoundLog(eng.round, eng.wall, eng.traffic, makespan, wait,
                       float(np.mean([a["tau"] for a in assigns.values()])),
                       acc, stale=stale)
        eng.history.append(log)
        return log
