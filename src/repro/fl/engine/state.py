"""ServerState <-> checkpoint payload codec.

A checkpoint is one msgpack pytree (written atomically by
:mod:`repro.checkpoint.msgpack_ckpt`) with two branches:

``arrays``
    Every tensor in the state — the scheme-shaped global params, the
    Heroes scheduler counters, and the params pytree of each semi-async
    in-flight result — stored bit-exactly per leaf (dtype + raw bytes).

``meta``
    One JSON document (stored as a uint8 leaf so it rides the same
    writer) holding the scalars: round/wall/traffic, the BoundState
    fields, the numpy ``bit_generator.state`` (PCG64's 128-bit integers
    are exact in JSON, and Python floats round-trip exactly through
    ``repr``-based JSON), participation bookkeeping, the full RoundLog
    history, and the scalar half of each in-flight dispatch record.

Restoring needs a *template* params pytree from a freshly constructed
runner: the msgpack flattener stringifies dict keys, and Flanc's
``coeffs`` branch is keyed by integer width, so restored keys are
re-matched to the template's key types (:func:`_rekey_like`).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict

import numpy as np

from repro.core import convergence
from repro.fl.client import ClientResult
from repro.fl.types import InFlight, RoundLog, SchedState, ServerState


def _enc_obj(x: Any) -> Any:
    """JSON-encodable view of small scalar/array structures (assignment
    dicts: widths, taus, block-id index arrays)."""
    if isinstance(x, np.ndarray):
        return {"__nd__": [str(x.dtype), list(x.shape),
                           x.reshape(-1).tolist()]}
    if isinstance(x, np.generic):
        return x.item()
    if isinstance(x, dict):
        return {k: _enc_obj(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_enc_obj(v) for v in x]
    return x


def _dec_obj(x: Any) -> Any:
    if isinstance(x, dict):
        if set(x) == {"__nd__"}:
            dtype, shape, data = x["__nd__"]
            return np.asarray(data, dtype=np.dtype(dtype)).reshape(shape)
        return {k: _dec_obj(v) for k, v in x.items()}
    if isinstance(x, list):
        return [_dec_obj(v) for v in x]
    return x


def _rekey_like(template: Any, restored: Any) -> Any:
    """Re-match restored dict keys to the template's key types.

    msgpack flattening joins keys into string paths, so non-string keys
    (Flanc's per-width integer coeff keys) come back stringified."""
    if isinstance(template, dict):
        return {k: _rekey_like(template[k], restored[str(k)])
                for k in template}
    return restored


def state_to_payload(state: ServerState) -> Dict[str, Any]:
    arrays: Dict[str, Any] = {"params": state.params}
    if state.sched is not None:
        arrays["sched"] = {"counters": state.sched.counters,
                           "anchored": state.sched.anchored}
    flights = []
    for i, t in enumerate(state.in_flight):
        arrays[f"inflight_{i}"] = t.result.host_params()
        flights.append({
            "client": int(t.client),
            "finish": float(t.finish),
            "dispatched": int(t.dispatched),
            "assign": _enc_obj(t.assign),
            "estimates": {k: float(v)
                          for k, v in (t.result.estimates or {}).items()},
            "loss_before": float(t.result.loss_before),
            "loss_after": float(t.result.loss_after),
        })
    meta = {
        "round": int(state.round),
        "wall": float(state.wall),
        "traffic": float(state.traffic),
        "traffic_up": float(state.traffic_up),
        "traffic_down": float(state.traffic_down),
        "bound_state": dataclasses.asdict(state.bound_state),
        "rng_state": state.rng.bit_generator.state,
        "participation": {str(k): int(v)
                          for k, v in state.participation.items()},
        "history": [dataclasses.asdict(h) for h in state.history],
        "in_flight": flights,
        "has_sched": state.sched is not None,
    }
    meta_bytes = json.dumps(meta).encode("utf-8")
    return {"arrays": arrays,
            "meta": np.frombuffer(meta_bytes, np.uint8).copy()}


def payload_to_state(payload: Dict[str, Any],
                     template_params: Any) -> ServerState:
    meta = json.loads(np.asarray(payload["meta"], np.uint8)
                      .tobytes().decode("utf-8"))
    arrays = payload["arrays"]
    rng = np.random.default_rng()
    rng.bit_generator.state = meta["rng_state"]
    sched = None
    if meta["has_sched"]:
        sched = SchedState(
            counters=np.array(arrays["sched"]["counters"], dtype=np.int64),
            anchored=np.array(arrays["sched"]["anchored"], dtype=np.int64))
    flights = []
    for i, f in enumerate(meta["in_flight"]):
        result = ClientResult(
            params=arrays[f"inflight_{i}"],
            estimates={k: float(v) for k, v in f["estimates"].items()},
            loss_before=f["loss_before"], loss_after=f["loss_after"])
        flights.append(InFlight(client=f["client"],
                                assign=_dec_obj(f["assign"]),
                                result=result, finish=f["finish"],
                                dispatched=f["dispatched"]))
    return ServerState(
        rng=rng,
        bound_state=convergence.BoundState(**meta["bound_state"]),
        params=_rekey_like(template_params, arrays["params"]),
        round=meta["round"], wall=meta["wall"], traffic=meta["traffic"],
        # .get: pre-telemetry checkpoints carry no directional split
        traffic_up=float(meta.get("traffic_up", 0.0)),
        traffic_down=float(meta.get("traffic_down", 0.0)),
        sched=sched,
        participation={int(k): int(v)
                       for k, v in meta["participation"].items()},
        in_flight=tuple(flights),
        history=tuple(RoundLog(**h) for h in meta["history"]))
