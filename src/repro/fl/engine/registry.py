"""Scheme registry: a paper scheme is a policy bundle, not a subclass.

``@register_scheme("name")`` registers a factory returning a
:class:`SchemeBundle` — the five-component recipe for that scheme.
``build_engine`` instantiates the bundle into an
:class:`~repro.fl.engine.runner.EngineRunner`, picking the trainer and
round loop from ``FLConfig`` (``cfg.trainer`` / ``cfg.round_mode``)
unless explicit instances are passed.

Adding a scheme::

    @register_scheme("my_scheme")
    def _my_scheme() -> SchemeBundle:
        return SchemeBundle(
            name="my_scheme",
            assignment=lambda: MyAssignment(),
            payload=lambda: FactorizedPayload(),
            aggregator=lambda: MyAggregator(),
            factorized=True,
            estimate=lambda cfg: cfg.estimate,
        )
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

from repro.fl.engine.aggregators import (Aggregator, DenseMeanAggregator,
                                         FlancAggregator, HeroesAggregator,
                                         MaskedDenseAggregator)
from repro.fl.engine.base import (AssignmentPolicy, LocalTrainer,
                                  ParticipationScheduler, PayloadModel,
                                  RoundLoop)
from repro.fl.engine.loops import SemiAsyncRoundLoop, SyncRoundLoop
from repro.fl.engine.payload import DensePayload, FactorizedPayload
from repro.fl.engine.policies import (FullWidthAssignment, HeroesAssignment,
                                      TierWidthAssignment)
from repro.fl.engine.runner import EngineRunner
from repro.fl.engine.trainers import (CohortTrainer, ProximalTrainer,
                                      SequentialTrainer)
from repro.fl.types import FLConfig


@dataclasses.dataclass(frozen=True)
class SchemeBundle:
    """Per-scheme component recipe (factories, so bundles are reusable)."""

    name: str
    assignment: Callable[[], AssignmentPolicy]
    payload: Callable[[], PayloadModel]
    aggregator: Callable[[], Aggregator]
    factorized: bool  # clients train (basis, coeff) factors vs dense weights
    estimate: Callable[[FLConfig], bool]  # ship (L, sigma^2, G^2) estimates?
    # Optional scheme-owned local solver (e.g. FedProx's proximal SGD).
    # When set it overrides ``cfg.trainer``; explicit ``build_engine``
    # trainer instances still win.
    trainer: Optional[Callable[[FLConfig], LocalTrainer]] = None


SCHEMES: Dict[str, Callable[[], SchemeBundle]] = {}


def register_scheme(name: str):
    """Decorator registering a ``() -> SchemeBundle`` factory."""

    def deco(factory: Callable[[], SchemeBundle]):
        SCHEMES[name] = factory
        return factory

    return deco


# "cohort" additionally shards its client axis over the local-device
# cohort mesh (FLConfig.trainer_mesh_devices; same axis the collective
# merge rides) whenever more than one device is visible — on one device
# it is the bitwise single-device batched path.
TRAINERS: Dict[str, Callable[[], LocalTrainer]] = {
    "sequential": SequentialTrainer,
    "cohort": CohortTrainer,
}

ROUND_MODES: Dict[str, Callable[[], RoundLoop]] = {
    "sync": SyncRoundLoop,
    "semi_async": SemiAsyncRoundLoop,
}


def build_engine(scheme: str, model, parts_x, parts_y, test_batch, het,
                 cfg: FLConfig, eval_width: Optional[int] = None, *,
                 trainer: Optional[LocalTrainer] = None,
                 loop: Optional[RoundLoop] = None,
                 sampler: Optional[ParticipationScheduler] = None
                 ) -> EngineRunner:
    """Instantiate a registered scheme into a ready-to-run engine.

    ``sampler`` overrides the participation scheduler the runner would
    build from ``cfg.participation`` (repro.fl.population.schedulers).
    """
    if scheme not in SCHEMES:
        raise KeyError(f"unknown scheme {scheme!r}; have {sorted(SCHEMES)}")
    bundle = SCHEMES[scheme]()
    if trainer is None:
        if bundle.trainer is not None:
            trainer = bundle.trainer(cfg)
        else:
            if cfg.trainer not in TRAINERS:
                raise ValueError(f"unknown trainer {cfg.trainer!r}")
            trainer = TRAINERS[cfg.trainer]()
    if loop is None:
        if cfg.round_mode not in ROUND_MODES:
            raise ValueError(f"unknown round_mode {cfg.round_mode!r}")
        loop = ROUND_MODES[cfg.round_mode]()
    if eval_width is None:
        eval_width = next(iter(model.specs.values())).max_width
    return EngineRunner(
        bundle.name, model, parts_x, parts_y, test_batch, het, cfg,
        eval_width,
        assignment=bundle.assignment(),
        payload=bundle.payload(),
        aggregator=bundle.aggregator(),
        trainer=trainer,
        loop=loop,
        factorized=bundle.factorized,
        estimate=bundle.estimate(cfg),
        sampler=sampler,
    )


# --------------------------------------------------------------------------
# The paper's five schemes as policy bundles (Sec. VI-B)
# --------------------------------------------------------------------------


@register_scheme("fedavg")
def _fedavg() -> SchemeBundle:
    return SchemeBundle(
        name="fedavg",
        assignment=lambda: FullWidthAssignment(adaptive_tau=False),
        payload=lambda: DensePayload(sliced=False),
        aggregator=DenseMeanAggregator,
        factorized=False,
        estimate=lambda cfg: False,
    )


@register_scheme("adp")
def _adp() -> SchemeBundle:
    return SchemeBundle(
        name="adp",
        assignment=lambda: FullWidthAssignment(adaptive_tau=True),
        payload=lambda: DensePayload(sliced=False),
        aggregator=DenseMeanAggregator,
        factorized=False,
        estimate=lambda cfg: True,
    )


@register_scheme("heterofl")
def _heterofl() -> SchemeBundle:
    return SchemeBundle(
        name="heterofl",
        assignment=TierWidthAssignment,
        payload=lambda: DensePayload(sliced=True),
        aggregator=MaskedDenseAggregator,
        factorized=False,
        estimate=lambda cfg: False,
    )


@register_scheme("flanc")
def _flanc() -> SchemeBundle:
    return SchemeBundle(
        name="flanc",
        assignment=TierWidthAssignment,
        payload=FactorizedPayload,
        aggregator=FlancAggregator,
        factorized=True,
        estimate=lambda cfg: False,
    )


@register_scheme("fedprox")
def _fedprox() -> SchemeBundle:
    """FedProx (Li et al.): FedAvg's assignment/payload/merge with a
    proximal local solver — validates that a scheme needing a custom
    LocalTrainer still drops in as a bundle (ROADMAP "More schemes as
    bundles").  ``FLConfig.prox_mu`` sets the proximal coefficient."""
    return SchemeBundle(
        name="fedprox",
        assignment=lambda: FullWidthAssignment(adaptive_tau=False),
        payload=lambda: DensePayload(sliced=False),
        aggregator=DenseMeanAggregator,
        factorized=False,
        estimate=lambda cfg: False,
        trainer=lambda cfg: ProximalTrainer(),
    )


@register_scheme("heroes")
def _heroes() -> SchemeBundle:
    return SchemeBundle(
        name="heroes",
        assignment=HeroesAssignment,
        payload=FactorizedPayload,
        aggregator=HeroesAggregator,
        factorized=True,
        estimate=lambda cfg: cfg.estimate,
    )
