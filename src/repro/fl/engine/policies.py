"""Assignment policies: who trains which width / tau / blocks.

These encode exactly the per-scheme differences of paper Sec. VI-B:

  FullWidthAssignment   FedAvg / ADP — everyone at width P, identical tau
                        (optionally the adaptive tau* of Eq. 26)
  TierWidthAssignment   HeteroFL / Flanc — width by hardware tier,
                        fixed tau
  HeroesAssignment      Alg. 1 — greedy width growth, pacesetter tau*,
                        variance-minimising tau, least-trained blocks

All policies are pure with respect to round state: ``assign(state,
clients)`` returns ``(state', assigns)``, and the Heroes block/anchored
tallies live in ``state.sched`` (a :class:`~repro.fl.types.SchedState`)
so they checkpoint and resume with the run.  The ``HeroesScheduler``
instance is a stateless planner whose ``counters`` scratch is synced
from the state on every call.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core import convergence
from repro.core.composition import select_blocks
from repro.core.scheduler import HeroesScheduler, SchedulerConfig
from repro.fl.engine.base import Assignment, AssignmentPolicy
from repro.fl.heterogeneity import HeterogeneityModel
from repro.fl.types import SchedState, ServerState

# auto-mu_max probes at most this many clients (exact below, an evenly
# spaced sample above — population-scale setup stays O(1) in the pop)
_MU_PROBE = 1024


def _record_coverage(obs, assigns: Dict[int, Assignment]) -> None:
    """Per-tensor coverage telemetry for one assignment event.

    Tallies ``coverage.{hidden,anchored}_rounds`` (+1 for every block
    included in at least one client's assignment this event — divided by
    ``coverage.events`` this is the paper-Fig.-2 coverage fraction) and
    ``coverage.{hidden,anchored}_iters`` (tau-weighted per-block
    training iterations, the Heroes scheduler's own counter signal).
    Reads only the assignment dicts the policy already built.
    """
    if not obs.enabled:
        return
    obs.counter_add("coverage.events")
    unions = {"hidden": set(), "anchored": set()}
    for a in assigns.values():
        tau = int(a["tau"])
        for fam in unions:
            ids = a.get(f"{fam}_ids")
            if ids is None or len(ids) == 0:
                continue
            obs.tally_add(f"coverage.{fam}_iters", ids, tau)
            unions[fam].update(int(i) for i in np.atleast_1d(ids))
    for fam, union in unions.items():
        if union:
            obs.tally_add(f"coverage.{fam}_rounds", sorted(union))


def tier_width(het: HeterogeneityModel, n: int, max_width: int) -> int:
    """Static width by hardware tier (HeteroFL / Flanc assignment rule)."""
    order = {"laptop": max_width, "agx_xavier": max(max_width - 1, 1),
             "xavier_nx": max(max_width - 2, 1), "tx2": 1}
    return min(order[het.clients[n].tier], max_width)


class FullWidthAssignment(AssignmentPolicy):
    """Everyone trains the full-width model with one shared tau."""

    def __init__(self, adaptive_tau: bool = False):
        self.adaptive_tau = adaptive_tau

    def assign(self, state: ServerState, clients: Sequence[int],
               ) -> Tuple[ServerState, Dict[int, Assignment]]:
        eng = self.eng
        tau = eng.cfg.tau_fixed
        if self.adaptive_tau and state.round > 0:
            t = convergence.tau_star(state.bound_state,
                                     max(200 - state.round, 1))
            tau = int(np.clip(round(t), 1, eng.cfg.tau_max))
        return state, {n: {"width": eng.P, "tau": tau} for n in clients}


class TierWidthAssignment(AssignmentPolicy):
    """Width by hardware tier, fixed identical tau."""

    def assign(self, state: ServerState, clients: Sequence[int],
               ) -> Tuple[ServerState, Dict[int, Assignment]]:
        eng = self.eng
        return state, {n: {"width": tier_width(eng.het, n, eng.P),
                           "tau": eng.cfg.tau_fixed} for n in clients}


class HeroesAssignment(AssignmentPolicy):
    """Heroes Alg. 1: scheduler-driven width/tau + least-trained blocks.

    The hidden-layer P^2 counter and the anchored-layer P-block counter
    shared by the boundary layers (DESIGN.md §5) live in ``state.sched``;
    ``assign`` copies them, charges the copies, and returns a state with
    the fresh tallies.
    """

    def setup(self, eng) -> None:
        super().setup(eng)
        model, cfg = eng.model, eng.cfg
        self.P = next(iter(model.specs.values())).max_width
        square_spec = next(s for s in model.specs.values() if s.mode == "square")
        self._anch_spec = next(
            (s for s in model.specs.values() if s.mode != "square"), None)
        mu_max = cfg.mu_max
        if mu_max <= 0:
            # auto: ~10x the median width-1 iteration time, so width
            # assignments spread across tiers at any model scale.  At
            # population scale (> _MU_PROBE clients) the median comes
            # from an evenly-spaced deterministic probe — setup must not
            # enumerate the population; below it, every client is probed
            # exactly as before (identical medians, seeded histories
            # stay bitwise).  The probe reads the round-0 time model, so
            # a resumed run reconstructs the identical mu_max.
            ns = range(cfg.num_clients)
            if cfg.num_clients > _MU_PROBE:
                ns = np.linspace(0, cfg.num_clients - 1,
                                 _MU_PROBE).round().astype(np.int64)
            med = float(np.median([
                eng.het.iter_time(int(n), eng.flops_per_iter(1))
                for n in ns]))
            mu_max = 10.0 * med
        self.scheduler = HeroesScheduler(
            square_spec,
            SchedulerConfig(mu_max=mu_max, rho=cfg.rho,
                            eps=cfg.eps, tau_max=cfg.tau_max),
            iter_time_fn=lambda n, p: eng.het.iter_time(n, eng.flops_per_iter(p)),
            comm_time_fn=lambda n, p: eng.het.upload_time(
                n, eng.model.factorized_bytes(p)),
        )
        self.last_plan = None
        if eng.obs.enabled:
            # pre-size the coverage tallies to the model's block counts
            # so never-trained blocks still render as 0% rows
            nb = self.scheduler.spec.num_blocks
            for name in ("coverage.hidden_rounds", "coverage.hidden_iters"):
                eng.obs.tally_add(name, [nb - 1], 0)
            if self._anch_spec is not None:
                for name in ("coverage.anchored_rounds",
                             "coverage.anchored_iters"):
                    eng.obs.tally_add(name, [self.P - 1], 0)

    def init_state(self, state: ServerState) -> ServerState:
        return dataclasses.replace(state, sched=SchedState(
            counters=np.zeros(self.scheduler.spec.num_blocks, np.int64),
            anchored=np.zeros(self.P, np.int64)))

    # -- shared block/anchored bookkeeping ---------------------------------
    def _charge(self, anchored: np.ndarray, width: int, tau: int,
                hidden_ids: np.ndarray, predefined: bool) -> Assignment:
        """Charge the anchored counter and build one client's assignment.

        ``predefined`` is the round-0 rule (Alg. 1 h=0): anchored layers
        take the first ``width`` blocks.  Planned rounds select the
        least-trained anchored blocks, mirroring the hidden-layer rule.
        """
        if predefined:
            anch_ids: Optional[np.ndarray] = np.arange(min(width, self.P))
        elif self._anch_spec is not None:
            anch_ids = select_blocks(anchored, width, self._anch_spec)
        else:
            anch_ids = None
        if anch_ids is not None:
            anchored[anch_ids] += tau
        return {"width": width, "tau": tau,
                "hidden_ids": hidden_ids, "anchored_ids": anch_ids}

    def assign(self, state: ServerState, clients: Sequence[int],
               ) -> Tuple[ServerState, Dict[int, Assignment]]:
        eng = self.eng
        counters = np.array(state.sched.counters, dtype=np.int64)
        anchored = np.array(state.sched.anchored, dtype=np.int64)
        if state.round == 0:
            # h=0: identical predefined frequency, no estimates yet (Alg. 1)
            tau = eng.cfg.tau_fixed
            out = {}
            for n in clients:
                width = self.scheduler.assign_width(n)
                ids = select_blocks(counters, width, self.scheduler.spec)
                counters[ids] += tau
                out[n] = self._charge(anchored, width, tau, ids,
                                      predefined=True)
        else:
            self.scheduler.counters = counters
            plan = self.scheduler.plan_round(clients, state.bound_state)
            self.last_plan = plan
            counters = self.scheduler.counters
            out = {n: self._charge(anchored, a.width, a.tau, a.block_ids,
                                   predefined=False)
                   for n, a in plan.assignments.items()}
        # keep the planner's scratch mirroring the authoritative tallies
        # (counter_variance() readers see the post-round state)
        self.scheduler.counters = counters
        _record_coverage(eng.obs, out)
        return (dataclasses.replace(state,
                                    sched=SchedState(counters, anchored)),
                out)
