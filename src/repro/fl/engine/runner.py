"""The engine runner: component wiring around an explicit ServerState.

The runner owns the *static* collaborators — model, data partitions,
heterogeneity model, collective merger, the five scheme components —
and exactly ONE mutable slot: ``self.state``, the current
:class:`~repro.fl.types.ServerState`.  Each ``run_round`` installs the
state returned by the loop and (when ``FLConfig.checkpoint_every`` is
set) saves it at the round boundary through
:mod:`repro.checkpoint.msgpack_ckpt`; ``restore_latest`` rebuilds the
state from the newest checkpoint so the continued run is
bitwise-identical to an uninterrupted one.  Public surface matches the
retired legacy ``BaseRunner`` (``run``, ``run_round``,
``run_until_budget``, ``history``, ``eval_accuracy``; round counters as
read-only properties over the state) so drivers swap backends without
changes.
"""

from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import msgpack_ckpt
from repro.obs import build_recorder
from repro.core import convergence
from repro.data.streaming import ClientDataLoader
from repro.fl.engine import collective
from repro.fl.engine import state as state_lib
from repro.fl.engine.base import (Aggregator, AssignmentPolicy, LocalTrainer,
                                  ParticipationScheduler, PayloadModel,
                                  RoundLoop)
from repro.fl.heterogeneity import HeterogeneityModel
from repro.fl.models import FLModelDef
from repro.fl.types import FLConfig, RoundLog, ServerState


class EngineRunner:
    """A scheme = five components threading one ServerState."""

    def __init__(self, scheme: str, model: FLModelDef, parts_x, parts_y,
                 test_batch, het: HeterogeneityModel, cfg: FLConfig,
                 eval_width: int, *, assignment: AssignmentPolicy,
                 payload: PayloadModel, aggregator: Aggregator,
                 trainer: LocalTrainer, loop: RoundLoop,
                 factorized: bool, estimate: bool,
                 sampler: Optional[ParticipationScheduler] = None):
        self.scheme = scheme
        self.model = model
        self.parts_x, self.parts_y = parts_x, parts_y
        # telemetry recorder (repro.obs); cfg.telemetry="off" resolves to
        # the shared no-op singleton, so instrumented paths stay
        # bitwise-identical to the golden histories.  Built first so
        # every component (data loader included) can bind to it.
        self.obs = build_recorder(cfg, meta={
            "scheme": scheme, "config": dataclasses.asdict(cfg)})
        # per-client minibatch streams (host RNG contract + prefetch);
        # shards may be lazy ShardViews or a population-scale
        # VirtualShardList — see repro.data.streaming
        self.data = ClientDataLoader(parts_x, parts_y)
        self.data.obs = self.obs
        # population registry (virtual setups): adopts the state's
        # participation dict as its bookkeeping store (below)
        self.population = getattr(parts_x, "registry", None)
        self.test_batch = test_batch
        self.het = het
        self.cfg = cfg
        self.eval_width = eval_width
        self.P = next(iter(model.specs.values())).max_width
        if cfg.clock_model not in ("dense", "rank_aware"):
            raise ValueError(f"unknown clock_model {cfg.clock_model!r} "
                             f"(expected 'dense' or 'rank_aware')")
        self.factorized = factorized
        self.estimate = estimate
        # collective merge backend (one compiled call per round; clients
        # on a device axis when a mesh is available) — aggregators fall
        # back to their host scatter loops when cfg.agg_backend == "host".
        self.merger = None
        if cfg.agg_backend == "collective":
            self.merger = collective.build_merger(cfg)
            self.merger.obs = self.obs
        elif cfg.agg_backend != "host":
            raise ValueError(f"unknown agg_backend {cfg.agg_backend!r}")

        self.assignment = assignment
        self.payload = payload
        self.aggregator = aggregator
        self.trainer = trainer
        self.loop = loop
        if sampler is None:
            # population layers on the engine; import here, not at module
            # scope, to keep engine -> population one-directional lazy
            from repro.fl.population.schedulers import build_scheduler
            sampler = build_scheduler(cfg)
        self.sampler = sampler
        for comp in (assignment, payload, aggregator, trainer, loop,
                     self.sampler):
            comp.setup(self)

        self.state = ServerState(
            rng=np.random.default_rng(cfg.seed),
            bound_state=convergence.BoundState(
                loss0=2.3, smoothness=1.0, grad_sq=1.0, noise_sq=0.5,
                lr=cfg.lr))
        self.state = aggregator.init_global(self.state)
        self.state = assignment.init_state(self.state)
        self._bind_population()

    def _bind_population(self) -> None:
        if self.population is not None:
            self.population.bind_participation(self.state.participation)

    # --- state views (legacy-compatible read surface) ---------------------
    @property
    def round(self) -> int:
        return self.state.round

    @property
    def wall(self) -> float:
        return self.state.wall

    @property
    def traffic(self) -> float:
        return self.state.traffic

    @property
    def params(self):
        return self.state.params

    @property
    def bound_state(self):
        return self.state.bound_state

    @property
    def rng(self) -> np.random.Generator:
        return self.state.rng

    @property
    def history(self) -> List[RoundLog]:
        return list(self.state.history)

    # --- shared helpers ---------------------------------------------------
    def sample_clients(self, state: ServerState, k: int,
                       exclude=frozenset()) -> List[int]:
        """One round's cohort via the participation scheduler; records
        participation in ``state.participation`` (the store the
        population registry shares by identity when one is bound)."""
        clients = self.sampler.sample(state, k, exclude)
        for n in clients:
            state.participation[int(n)] = state.round
        if self.obs.enabled:
            for n in clients:
                self.obs.counter_add("participation.tier",
                                     tier=self.het.clients[int(n)].tier)
        return clients

    def close(self) -> None:
        """Release background resources (prefetch workers) and flush the
        telemetry recorder (final metrics snapshot)."""
        self.data.close()
        self.obs.close()

    def __enter__(self) -> "EngineRunner":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def flops_per_iter(self, width: int) -> float:
        """Per-iteration FLOPs the virtual clock charges a client.

        ``cfg.clock_model="dense"`` (default, bitwise-history path)
        charges the materialised forward+backward regardless of how the
        client actually computes.  ``"rank_aware"`` charges factorized
        schemes the per-layer impl mix ``forward_impl`` selects — a
        rank-space layer costs its factor contractions, a materialised
        one its amortised compose plus dense application — so simulated
        edge devices speed up exactly where the rank path wins.  Both
        round loops AND the Heroes mu_max probe route through here.
        """
        if self.cfg.clock_model == "rank_aware" and self.factorized:
            from repro.core.calibration import for_dispatch

            per_sample = self.model.apply_flops_per_sample(
                width, self.cfg.batch_size, self.cfg.forward_impl,
                calibration=for_dispatch(self.cfg))
            return per_sample * self.cfg.batch_size
        return self.model.flops_per_sample(width) * self.cfg.batch_size

    def acc_from_logits(self, logits) -> float:
        labels = self.test_batch["labels"]
        pred = jnp.argmax(logits, -1)
        return float(jnp.mean((pred == labels).astype(jnp.float32)))

    def eval_batches(self):
        """The test split in ``cfg.eval_batch_size`` slices (one full
        batch when <= 0 — the bitwise-parity default)."""
        tb = self.test_batch
        n = int(tb["labels"].shape[0])
        bs = self.cfg.eval_batch_size
        if bs <= 0 or bs >= n:
            yield tb
            return
        for i in range(0, n, bs):
            yield {k: v[i:i + bs] for k, v in tb.items()}

    def acc_streaming(self, logits_fn) -> float:
        """Accuracy of ``logits_fn(batch)`` streamed over the test set.

        With ``eval_batch_size <= 0`` this is exactly the legacy
        full-batch ``acc_from_logits`` computation; otherwise correct
        predictions are accumulated slice-by-slice so evaluation memory
        stays O(eval_batch_size) instead of O(test set).
        """
        bs = self.cfg.eval_batch_size
        n = int(self.test_batch["labels"].shape[0])
        if bs <= 0 or bs >= n:
            return self.acc_from_logits(logits_fn(self.test_batch))
        correct, total = 0.0, 0
        for batch in self.eval_batches():
            pred = jnp.argmax(logits_fn(batch), -1)
            correct += float(jnp.sum((pred == batch["labels"])
                                     .astype(jnp.float32)))
            total += int(np.prod(batch["labels"].shape))
        return correct / total

    def eval_accuracy(self) -> float:
        return self.aggregator.evaluate(self.state)

    # --- checkpoint / resume ----------------------------------------------
    def save_checkpoint(self) -> Path:
        """Write the current ServerState under ``cfg.checkpoint_dir``."""
        if not self.cfg.checkpoint_dir:
            raise ValueError("FLConfig.checkpoint_dir is not set")
        with self.obs.wall_span("checkpoint.save", round=self.state.round):
            payload = state_lib.state_to_payload(self.state)
            path = msgpack_ckpt.save_checkpoint(
                self.cfg.checkpoint_dir, self.state.round, payload,
                keep=self.cfg.checkpoint_keep)
        if self.obs.enabled:
            self.obs.counter_add("checkpoint.saves")
            self.obs.counter_add("checkpoint.bytes",
                                 float(Path(path).stat().st_size))
        return path

    def restore_latest(self) -> bool:
        """Adopt the newest checkpoint under ``cfg.checkpoint_dir``.

        Returns False when there is none (fresh start).  The freshly
        initialised params serve as the key-type template for the
        restored pytree; afterwards the continued history — rng stream,
        scheduler tallies and in-flight dispatches included — is
        bitwise-identical to a never-interrupted run.
        """
        if not self.cfg.checkpoint_dir:
            raise ValueError("FLConfig.checkpoint_dir is not set")
        got = msgpack_ckpt.restore_latest(self.cfg.checkpoint_dir)
        if got is None:
            return False
        _, payload = got
        self.state = state_lib.payload_to_state(payload, self.state.params)
        self._bind_population()
        return True

    def _maybe_checkpoint(self) -> None:
        cfg = self.cfg
        if (cfg.checkpoint_every > 0 and cfg.checkpoint_dir
                and self.state.round % cfg.checkpoint_every == 0):
            self.save_checkpoint()

    # --- driving ----------------------------------------------------------
    def run_round(self) -> RoundLog:
        self.state, log = self.loop.run_round(self.state)
        self._maybe_checkpoint()
        return log

    def run(self, rounds: int) -> List[RoundLog]:
        for _ in range(rounds):
            self.run_round()
        return self.history

    def run_until_budget(self, time_budget: Optional[float] = None,
                         traffic_budget: Optional[float] = None,
                         max_rounds: int = 10_000) -> List[RoundLog]:
        """Paper Alg. 1 outer loop: train while T <= T^max (and/or a
        traffic budget)."""
        assert time_budget or traffic_budget
        for _ in range(max_rounds):
            if time_budget is not None and self.wall >= time_budget:
                break
            if traffic_budget is not None and self.traffic >= traffic_budget:
                break
            self.run_round()
        return self.history
