"""Payload models: bytes shipped per client assignment (traffic account).

The loop charges ``2 * bytes(a)`` per dispatch (download + upload), the
same accounting the legacy runners used.
"""

from __future__ import annotations

from repro.fl.engine.base import Assignment, PayloadModel


class DensePayload(PayloadModel):
    """Materialised weights.

    ``sliced=False`` ships the full width-P model regardless of the
    assignment (FedAvg/ADP); ``sliced=True`` ships the width-p sub-model
    (HeteroFL).
    """

    def __init__(self, sliced: bool = False):
        self.sliced = sliced

    def bytes(self, assignment: Assignment) -> float:
        width = assignment["width"] if self.sliced else self.eng.P
        return self.eng.model.dense_bytes(width)


class FactorizedPayload(PayloadModel):
    """Neural-composition factors: basis + width-p coefficient blocks."""

    def bytes(self, assignment: Assignment) -> float:
        return self.eng.model.factorized_bytes(assignment["width"])
