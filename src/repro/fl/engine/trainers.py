"""Local-training backends.

``SequentialTrainer`` reproduces the legacy per-client loop bitwise: one
:func:`repro.fl.client.local_train` call per client, one jit dispatch per
SGD step.

``CohortTrainer`` is the batched backend: clients sharing a cohort
signature ``(width, effective batch size)`` are stacked on a leading
client axis and trained in ONE compiled ``jax.vmap``-over-clients +
``jax.lax.scan``-over-tau step.  Clients with different tau inside a
cohort are padded to the cohort max and masked (a padded step is a
no-op), so the per-client math is identical to the sequential loop up to
float re-association — the dispatch count per round drops from
``sum_n tau_n`` to one call per cohort.

Minibatch indices are drawn on the host through the engine's
:class:`~repro.data.ClientDataLoader` (``eng.data``) under the exact
per-client RNG stream the sequential path uses
(``default_rng((seed, round, n))``, tau draws then 3 estimate draws),
so the two backends see the same data order.  Shards may be lazy
:class:`~repro.data.ShardView`s — only the touched minibatches are
gathered — and the cohort backend prefetches the next group's host
batches on a background thread while the device runs the current one.

``ProximalTrainer`` is the FedProx local solver: the same sequential
contract with the proximal pull ``mu * (w - w_global)`` added to every
SGD step, so FedProx drops in as a scheme bundle without core changes.

All backends return *host-resident* (numpy) result params: the
collective aggregation backend (repro.fl.engine.collective) scatters
them into dense zero-padded contributions in one numpy pass and ships
the stacked cohort to the device once, instead of K round-trips.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimator
from repro.data.streaming import round_batch_indices
from repro.fl import client as client_lib
from repro.fl.client import ClientResult
from repro.fl.engine.base import Assignment, LocalTrainer
from repro.fl.models import FLModelDef


class SequentialTrainer(LocalTrainer):
    """One ``local_train`` call per client (legacy-equivalent backend).

    Result params are pulled to the host (numpy) — the contract shared
    with :class:`CohortTrainer` — so the collective aggregation prep can
    build its dense zero-padded contributions in one numpy pass instead
    of K per-client device round-trips.
    """

    def train_all(self, assigns: Dict[int, Assignment]) -> Dict[int, ClientResult]:
        eng = self.eng
        out = {}
        for n, a in assigns.items():
            params = eng.aggregator.client_params(n, a)
            res = client_lib.local_train(
                eng.model, params, a["width"], a["tau"],
                eng.parts_x[n], eng.parts_y[n], eng.cfg.lr,
                np.random.default_rng((eng.cfg.seed, eng.round, n)),
                eng.cfg.batch_size, factorized=eng.factorized,
                estimate=eng.estimate,
            )
            out[n] = ClientResult(jax.device_get(res.params), res.estimates,
                                  res.loss_before, res.loss_after)
        return out


@functools.lru_cache(maxsize=32)
def _cohort_fns(model: FLModelDef, width: int, factorized: bool):
    """Compiled cohort functions, keyed on the model instance identity."""

    def loss_fn(params, batch):
        w = (model.compose_all(params, width) if factorized
             else {k: v for k, v in params.items()})
        logits = model.forward(w, width, batch)
        return client_lib._ce(logits, batch["labels"])

    grad_fn = jax.grad(loss_fn)

    def sgd_step(params, batch, lr):
        g = grad_fn(params, batch)
        return jax.tree_util.tree_map(lambda p, gg: p - lr * gg, params, g)

    def train(stacked, batches, taus, lr):
        """Unrolled tau steps, vmap over the client axis — one compiled call.

        stacked: params pytree with leading client axis C.
        batches: batch pytree with leading (tau_pad, C, B, ...).
        taus:    (C,) — steps beyond a client's tau keep its params.

        ``unroll=True`` emits straight-line code instead of an XLA while
        loop: on CPU, ops inside a while body lose intra-op thread
        parallelism, which measures ~2.5x slower per step.  Also returns
        the first-batch loss before/after so a round needs no extra
        dispatches.
        """

        def body(params, xs):
            t, batch = xs
            new = jax.vmap(lambda p, b: sgd_step(p, b, lr))(params, batch)
            keep = t < taus
            params = jax.tree_util.tree_map(
                lambda nw, old: jnp.where(
                    keep.reshape(keep.shape + (1,) * (nw.ndim - 1)), nw, old),
                new, params)
            return params, None

        tau_pad = jax.tree_util.tree_leaves(batches)[0].shape[0]
        final, _ = jax.lax.scan(body, stacked, (jnp.arange(tau_pad), batches),
                                unroll=True)
        first = jax.tree_util.tree_map(lambda v: v[0], batches)
        loss_b = jax.vmap(loss_fn)(stacked, first)
        loss_a = jax.vmap(loss_fn)(final, first)
        return final, loss_b, loss_a

    def estimates(params0, params_t, est_batches):
        """(L, sigma^2, G^2) per client; est_batches leading (C, 3, B, ...)."""

        def per_client(p0, pt, eb):
            bs = [jax.tree_util.tree_map(lambda x, i=i: x[i], eb)
                  for i in range(3)]
            return estimator.client_estimates(grad_fn, p0, pt, bs)

        return jax.vmap(per_client)(params0, params_t, est_batches)

    return jax.jit(train), jax.jit(estimates)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class CohortTrainer(LocalTrainer):
    """Batched cohort backend: vmap over clients, unrolled tau steps.

    Shape bucketing keeps recompilation bounded when assignments vary
    round-to-round (Heroes): the client count is padded to the next power
    of two with masked clones (unless the group is the recurring
    full-cohort shape) and tau is padded to the next power of two when
    clients disagree (padded steps are masked no-ops).
    """

    def train_all(self, assigns: Dict[int, Assignment]) -> Dict[int, ClientResult]:
        eng = self.eng
        groups: Dict[tuple, List[int]] = {}
        for n, a in assigns.items():
            b_eff = min(eng.cfg.batch_size, eng.data.num_samples(n))
            groups.setdefault((a["width"], b_eff), []).append(n)
        # host batch prep streams through the loader one group ahead of
        # the device step (numpy-only on the worker thread)
        specs = list(groups.items())
        prepared = eng.data.prefetch(
            specs, lambda s: self._prepare_group(s[0][1], s[1], assigns))
        results: Dict[int, ClientResult] = {}
        for ((width, b_eff), ns), prep in zip(specs, prepared):
            results.update(self._train_group(width, ns, assigns, prep))
        return {n: results[n] for n in assigns}

    def _prepare_group(self, b_eff: int, ns: List[int],
                       assigns: Dict[int, Assignment]):
        """Host-side batch staging for one cohort group (numpy only —
        safe to run on the prefetch thread)."""
        eng, cfg = self.eng, self.eng.cfg
        taus = [max(assigns[n]["tau"], 1) for n in ns]
        # bucketed padding (bounded recompiles under varying assignments)
        tau_pad = taus[0] if len(set(taus)) == 1 else _next_pow2(max(taus))
        n_real = len(ns)
        c_pad = n_real if n_real == cfg.clients_per_round \
            else _next_pow2(n_real)

        xs_steps, ys_steps, xs_est, ys_est = [], [], [], []
        for n, tau in zip(ns, taus):
            # same draw order as the sequential path: tau training
            # batches, then 3 estimate batches (padding steps reuse the
            # last batch — they are masked no-ops in the scan)
            xs, ys, est = eng.data.draw_round(
                n, seed=cfg.seed, rnd=eng.round, tau=tau, batch_size=b_eff,
                estimate=eng.estimate, tau_pad=tau_pad)
            xs_steps.append(xs)
            ys_steps.append(ys)
            if est is not None:
                xs_est.append(est[0])
                ys_est.append(est[1])
        for _ in range(c_pad - n_real):  # masked clone clients
            xs_steps.append(xs_steps[0])
            ys_steps.append(ys_steps[0])
            if eng.estimate:
                xs_est.append(xs_est[0])
                ys_est.append(ys_est[0])
        taus_arr = np.zeros((c_pad,), np.int32)
        taus_arr[:n_real] = taus

        xkey = "tokens" if eng.model.name == "rnn" else "x"
        batches = {  # (C, tau_pad, B, ...) -> (tau_pad, C, B, ...)
            xkey: np.moveaxis(np.stack(xs_steps), 0, 1),
            "labels": np.moveaxis(np.stack(ys_steps), 0, 1),
        }
        est_batches = None
        if eng.estimate:
            est_batches = {xkey: np.stack(xs_est), "labels": np.stack(ys_est)}
        return batches, est_batches, taus_arr, c_pad

    def _train_group(self, width: int, ns: List[int],
                     assigns: Dict[int, Assignment],
                     prep) -> Dict[int, ClientResult]:
        eng, model, cfg = self.eng, self.eng.model, self.eng.cfg
        batches_np, est_np, taus_arr, c_pad = prep

        client_params = [eng.aggregator.client_params(n, assigns[n])
                         for n in ns]
        client_params += [client_params[0]] * (c_pad - len(ns))
        stacked = jax.tree_util.tree_map(
            lambda *leaves: jnp.stack(leaves), *client_params)
        batches = {k: jnp.asarray(v) for k, v in batches_np.items()}

        train_fn, est_fn = _cohort_fns(model, width, eng.factorized)
        final, loss_b, loss_a = train_fn(stacked, batches,
                                         jnp.asarray(taus_arr), cfg.lr)
        ests = None
        if est_np is not None:
            est_batches = {k: jnp.asarray(v) for k, v in est_np.items()}
            ests = est_fn(stacked, final, est_batches)
            ests = {k: np.asarray(v) for k, v in ests.items()}

        final = jax.device_get(final)  # one transfer; slice per client below
        loss_b, loss_a = np.asarray(loss_b), np.asarray(loss_a)
        out = {}
        for j, n in enumerate(ns):
            params = jax.tree_util.tree_map(lambda v, j=j: v[j], final)
            est = {k: float(v[j]) for k, v in ests.items()} if ests else {}
            out[n] = ClientResult(params, est, float(loss_b[j]), float(loss_a[j]))
        return out


@functools.lru_cache(maxsize=32)
def _prox_fns(model: FLModelDef, width: int, factorized: bool):
    """Compiled FedProx step/loss, keyed on the model instance."""

    def loss_fn(params, batch):
        w = (model.compose_all(params, width) if factorized
             else {k: v for k, v in params.items()})
        logits = model.forward(w, width, batch)
        return client_lib._ce(logits, batch["labels"])

    grad_fn = jax.grad(loss_fn)

    @jax.jit
    def prox_step(params, anchor, batch, lr, mu):
        g = grad_fn(params, batch)
        return jax.tree_util.tree_map(
            lambda p, a, gg: p - lr * (gg + mu * (p - a)), params, anchor, g)

    return jax.jit(loss_fn), prox_step


class ProximalTrainer(LocalTrainer):
    """FedProx local solver: SGD on ``f(w) + (mu/2) ||w - w_global||^2``.

    Identical dispatch/RNG contract to :class:`SequentialTrainer`
    (minibatch indices come from the same ``round_batch_indices``
    stream), with the proximal pull toward the received global view
    added to every step — ``mu = 0`` reproduces FedAvg's local updates
    bitwise.  ``mu`` defaults to ``FLConfig.prox_mu``.
    """

    def __init__(self, mu: Optional[float] = None):
        self._mu = mu

    def train_all(self, assigns: Dict[int, Assignment]) -> Dict[int, ClientResult]:
        eng, cfg = self.eng, self.eng.cfg
        mu = cfg.prox_mu if self._mu is None else self._mu
        xkey = "tokens" if eng.model.name == "rnn" else "x"
        out: Dict[int, ClientResult] = {}
        for n, a in assigns.items():
            loss_fn, prox_step = _prox_fns(eng.model, a["width"],
                                           eng.factorized)
            anchor = eng.aggregator.client_params(n, a)
            nsamp = eng.data.num_samples(n)
            b_eff = min(cfg.batch_size, nsamp)
            tau = max(a["tau"], 1)
            idx, _ = round_batch_indices(cfg.seed, eng.round, n, nsamp,
                                         tau, b_eff, estimate=False)
            params, first = anchor, None
            for t in range(tau):
                xb, yb = eng.data.gather(n, idx[t])
                batch = {xkey: jnp.asarray(xb), "labels": jnp.asarray(yb)}
                if first is None:
                    first = batch
                params = prox_step(params, anchor, batch, cfg.lr, mu)
            out[n] = ClientResult(jax.device_get(params), {},
                                  float(loss_fn(anchor, first)),
                                  float(loss_fn(params, first)))
        return out
