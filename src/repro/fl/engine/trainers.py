"""Local-training backends.

``SequentialTrainer`` reproduces the legacy per-client loop bitwise: one
:func:`repro.fl.client.local_train` call per client, one jit dispatch per
SGD step.

``CohortTrainer`` is the batched backend: clients sharing a cohort
signature ``(width, effective batch size)`` are stacked on a leading
client axis and trained in ONE compiled ``jax.vmap``-over-clients +
``jax.lax.scan``-over-tau step.  Clients with different tau inside a
cohort are padded to the cohort max and masked (a padded step is a
no-op), so the per-client math is identical to the sequential loop up to
float re-association — the dispatch count per round drops from
``sum_n tau_n`` to one call per cohort.

Minibatch indices are drawn on the host through the engine's
:class:`~repro.data.ClientDataLoader` (``eng.data``) under the exact
per-client RNG stream the sequential path uses
(``default_rng((seed, round, n))``, tau draws then 3 estimate draws),
so the two backends see the same data order.  Shards may be lazy
:class:`~repro.data.ShardView`s — only the touched minibatches are
gathered — and the cohort backend prefetches the next group's host
batches on a background thread while the device runs the current one.

``ProximalTrainer`` is the FedProx local solver: the same sequential
contract with the proximal pull ``mu * (w - w_global)`` added to every
SGD step, so FedProx drops in as a scheme bundle without core changes.

Result-params contract: backends return *host-resident* (numpy) param
trees — the collective aggregation backend (repro.fl.engine.collective)
scatters them into dense zero-padded contributions in one numpy pass and
ships the stacked cohort to the device once, instead of K round-trips.
The one exception is the mesh-sharded cohort path feeding the collective
backend: there the trained stack stays *device-resident* on the cohort
axis (``ClientResult.params`` is a lazy
:class:`~repro.fl.engine.collective.CohortSlice``) and the merge
consumes it without a gather/rescatter; ``ClientResult.host_params()``
recovers the numpy tree everywhere else.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding

from repro.core import estimator
from repro.core.calibration import for_dispatch
from repro.data.streaming import round_batch_indices, stack_client_shards
from repro.fl import client as client_lib
from repro.fl.client import ClientResult
from repro.fl.engine.base import Assignment, LocalTrainer
from repro.fl.engine.collective import CohortSlice, CohortStack
from repro.fl.models import FLModelDef
from repro.sharding import fl as flsh


def _cache_size(fn) -> Optional[int]:
    """Compiled-signature count of a ``jax.jit`` wrapper, when this jax
    exposes it (None otherwise — telemetry then skips recompile
    accounting instead of guessing)."""
    try:
        return int(fn._cache_size())
    except Exception:
        return None


def _count_recompiles(obs, fn, before: Optional[int], **labels) -> None:
    """Credit ``trainer.jit_recompiles`` with the cache growth of ``fn``
    since ``before`` (a ``_cache_size`` snapshot taken pre-call)."""
    if before is None:
        return
    after = _cache_size(fn)
    if after is not None and after > before:
        obs.counter_add("trainer.jit_recompiles", after - before, **labels)


class SequentialTrainer(LocalTrainer):
    """One ``local_train`` call per client (legacy-equivalent backend).

    Result params are pulled to the host (numpy) — the contract shared
    with :class:`CohortTrainer` — so the collective aggregation prep can
    build its dense zero-padded contributions in one numpy pass instead
    of K per-client device round-trips.
    """

    def train_all(self, state, assigns: Dict[int, Assignment],
                  ) -> Dict[int, ClientResult]:
        eng = self.eng
        obs = eng.obs
        cal = for_dispatch(eng.cfg)
        out = {}
        for n, a in assigns.items():
            params = eng.aggregator.client_params(state, n, a)
            before = None
            if obs.enabled:
                # the per-step jits live in client._jitted_fns (lru
                # cached — this lookup is the one local_train makes)
                _, _, sgd_step = client_lib._jitted_fns(
                    eng.model, a["width"], eng.factorized,
                    eng.cfg.forward_impl, cal)
                before = _cache_size(sgd_step)
            with obs.wall_span("trainer.local_train", client=int(n),
                               width=int(a["width"]), tau=int(a["tau"])):
                res = client_lib.local_train(
                    eng.model, params, a["width"], a["tau"],
                    eng.parts_x[n], eng.parts_y[n], eng.cfg.lr,
                    np.random.default_rng((eng.cfg.seed, state.round, n)),
                    eng.cfg.batch_size, factorized=eng.factorized,
                    estimate=eng.estimate,
                    forward_impl=eng.cfg.forward_impl,
                    calibration=cal,
                )
            if obs.enabled:
                _count_recompiles(obs, sgd_step, before,
                                  trainer="sequential",
                                  width=int(a["width"]))
            out[n] = ClientResult(jax.device_get(res.params), res.estimates,
                                  res.loss_before, res.loss_after)
        return out


@functools.lru_cache(maxsize=32)
def _cohort_fns(model: FLModelDef, width: int, factorized: bool, mesh=None,
                forward_impl: str = "auto", calibration=None):
    """Compiled cohort functions, keyed on the model instance identity.

    With ``mesh`` (a 1-D cohort mesh from :func:`repro.sharding.fl.
    cohort_mesh`) the vmap+scan step runs under ``shard_map`` with the
    client axis laid out on ``COHORT_AXIS``: every device trains its
    contiguous client shard independently (local updates need no
    collectives), so per-client math is identical to the single-device
    form and the trained params come back sharded over the same axis the
    collective merge consumes.

    ``forward_impl`` selects the factorized client compute path
    (``FLConfig.forward_impl``): with ``"auto"``/``"rank_space"`` the
    per-client loss applies factors in rank space — under the client
    vmap the rank contractions batch over the cohort axis exactly like
    the dense ops, so the whole stacked cohort shares the cheaper
    path in the ONE compiled call."""

    def loss_fn(params, batch):
        w = (model.prepare_weights(params, width, batch, forward_impl,
                                   calibration)
             if factorized else {k: v for k, v in params.items()})
        logits = model.forward(w, width, batch)
        return client_lib._ce(logits, batch["labels"])

    grad_fn = jax.grad(loss_fn)

    def sgd_step(params, batch, lr):
        g = grad_fn(params, batch)
        return jax.tree_util.tree_map(lambda p, gg: p - lr * gg, params, g)

    def train(stacked, batches, taus, lr):
        """Unrolled tau steps, vmap over the client axis — one compiled call.

        stacked: params pytree with leading client axis C.
        batches: batch pytree with leading (tau_pad, C, B, ...).
        taus:    (C,) — steps beyond a client's tau keep its params.

        ``unroll=True`` emits straight-line code instead of an XLA while
        loop: on CPU, ops inside a while body lose intra-op thread
        parallelism, which measures ~2.5x slower per step.  Also returns
        the first-batch loss before/after so a round needs no extra
        dispatches.
        """

        def body(params, xs):
            t, batch = xs
            new = jax.vmap(lambda p, b: sgd_step(p, b, lr))(params, batch)
            keep = t < taus
            params = jax.tree_util.tree_map(
                lambda nw, old: jnp.where(
                    keep.reshape(keep.shape + (1,) * (nw.ndim - 1)), nw, old),
                new, params)
            return params, None

        tau_pad = jax.tree_util.tree_leaves(batches)[0].shape[0]
        final, _ = jax.lax.scan(body, stacked, (jnp.arange(tau_pad), batches),
                                unroll=True)
        # zero the masked-clone rows (tau == 0): nobody consumes them
        # per-client, and zero rows are exactly the client-axis padding
        # the collective merge expects — so a device-resident stack can
        # feed the merge unchanged.  Real rows pass through bitwise.
        live = taus > 0
        final = jax.tree_util.tree_map(
            lambda v: jnp.where(
                live.reshape(live.shape + (1,) * (v.ndim - 1)), v, 0), final)
        first = jax.tree_util.tree_map(lambda v: v[0], batches)
        loss_b = jax.vmap(loss_fn)(stacked, first)
        loss_a = jax.vmap(loss_fn)(final, first)
        return final, loss_b, loss_a

    def estimates(params0, params_t, est_batches):
        """(L, sigma^2, G^2) per client; est_batches leading (C, 3, B, ...)."""

        def per_client(p0, pt, eb):
            bs = [jax.tree_util.tree_map(lambda x, i=i: x[i], eb)
                  for i in range(3)]
            return estimator.client_estimates(grad_fn, p0, pt, bs)

        return jax.vmap(per_client)(params0, params_t, est_batches)

    if mesh is None:
        return jax.jit(train), jax.jit(estimates)

    # mesh variant: clients sharded P(COHORT_AXIS), lr replicated, the
    # batch pytree sharded on its client axis (position 1: (tau, C, B)).
    # Specs are pytree prefixes, so one spec covers each whole subtree.
    cs, rs = flsh.contribution_spec(), flsh.replicated_spec()
    bs = flsh.client_axis_spec(1)
    train_sh = shard_map(train, mesh=mesh, in_specs=(cs, bs, cs, rs),
                         out_specs=(cs, cs, cs))
    est_sh = shard_map(estimates, mesh=mesh, in_specs=(cs, cs, cs),
                       out_specs=cs)
    return jax.jit(train_sh), jax.jit(est_sh)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class CohortTrainer(LocalTrainer):
    """Batched cohort backend: vmap over clients, unrolled tau steps.

    Shape bucketing keeps recompilation bounded when assignments vary
    round-to-round (Heroes): the client count is padded to the next power
    of two with masked clones (unless the group is the recurring
    full-cohort shape) and tau is padded to the next power of two when
    clients disagree (padded steps are masked no-ops).

    On a multi-device host the client axis is sharded over the 1-D
    cohort mesh (``FLConfig.trainer_mesh_devices``; the same axis the
    collective merge rides): batches are staged as per-device host
    shards, every device trains its contiguous client slice in the one
    compiled call, and — when the collective aggregation backend is
    active — the trained params stay device-resident
    (:class:`~repro.fl.engine.collective.CohortSlice`) so the merge
    consumes them without a gather/rescatter round-trip.
    """

    def setup(self, eng) -> None:
        super().setup(eng)
        self.mesh = flsh.cohort_mesh(
            getattr(eng.cfg, "trainer_mesh_devices", 0))

    def train_all(self, state, assigns: Dict[int, Assignment],
                  ) -> Dict[int, ClientResult]:
        eng = self.eng
        groups: Dict[tuple, List[int]] = {}
        for n, a in assigns.items():
            b_eff = min(eng.cfg.batch_size, eng.data.num_samples(n))
            groups.setdefault((a["width"], b_eff), []).append(n)
        # host batch prep streams through the loader one group ahead of
        # the device step (numpy-only on the worker thread)
        specs = list(groups.items())
        prepared = eng.data.prefetch(
            specs, lambda s: self._prepare_group(state, s[0][1], s[1], assigns))
        results: Dict[int, ClientResult] = {}
        try:
            for ((width, b_eff), ns), prep in zip(specs, prepared):
                results.update(
                    self._train_group(state, width, ns, assigns, prep))
        finally:
            # a failing device step must not abandon the generator with
            # its prefetch worker blocked on the queue (thread leak) —
            # closing it runs the generator's cleanup deterministically
            prepared.close()
        return {n: results[n] for n in assigns}

    def _prepare_group(self, state, b_eff: int, ns: List[int],
                       assigns: Dict[int, Assignment]):
        """Host-side batch staging for one cohort group (numpy only —
        safe to run on the prefetch thread).

        Returns per-device host shard *lists* (one chunk per mesh
        device; a single chunk without a mesh) so the main thread ships
        each chunk straight to its device — the monolithic stacked
        batch never exists when the cohort is sharded.
        """
        # spans land from the prefetch worker thread; the recorder's
        # lock makes that safe
        with self.eng.obs.wall_span("trainer.host_stage", clients=len(ns),
                                    batch=int(b_eff)):
            return self._prepare_group_inner(state, b_eff, ns, assigns)

    def _prepare_group_inner(self, state, b_eff: int, ns: List[int],
                             assigns: Dict[int, Assignment]):
        eng, cfg = self.eng, self.eng.cfg
        taus = [max(assigns[n]["tau"], 1) for n in ns]
        # bucketed padding (bounded recompiles under varying assignments)
        tau_pad = taus[0] if len(set(taus)) == 1 else _next_pow2(max(taus))
        n_real = len(ns)
        c_pad = n_real if n_real == cfg.clients_per_round \
            else _next_pow2(n_real)
        # reconcile the power-of-two bucket with the mesh: the client
        # axis must split evenly over the devices (extra rows are the
        # same masked clones the bucketing already uses)
        c_pad = flsh.pad_cohort(c_pad, self.mesh)
        chunks = self.mesh.devices.size if self.mesh is not None else 1

        xs_steps, ys_steps, xs_est, ys_est = [], [], [], []
        for n, tau in zip(ns, taus):
            # same draw order as the sequential path: tau training
            # batches, then 3 estimate batches (padding steps reuse the
            # last batch — they are masked no-ops in the scan)
            xs, ys, est = eng.data.draw_round(
                n, seed=cfg.seed, rnd=state.round, tau=tau, batch_size=b_eff,
                estimate=eng.estimate, tau_pad=tau_pad)
            xs_steps.append(xs)
            ys_steps.append(ys)
            if est is not None:
                xs_est.append(est[0])
                ys_est.append(est[1])
        for _ in range(c_pad - n_real):  # masked clone clients
            xs_steps.append(xs_steps[0])
            ys_steps.append(ys_steps[0])
            if eng.estimate:
                xs_est.append(xs_est[0])
                ys_est.append(ys_est[0])
        taus_arr = np.zeros((c_pad,), np.int32)
        taus_arr[:n_real] = taus

        xkey = eng.model.input_key
        batches = {  # per chunk: (C', tau_pad, B, ...) -> (tau_pad, C', B, ...)
            xkey: stack_client_shards(xs_steps, chunks, step_leading=True),
            "labels": stack_client_shards(ys_steps, chunks, step_leading=True),
        }
        est_batches = None
        if eng.estimate:
            est_batches = {xkey: stack_client_shards(xs_est, chunks),
                           "labels": stack_client_shards(ys_est, chunks)}
        return batches, est_batches, taus_arr, c_pad

    def _train_group(self, state, width: int, ns: List[int],
                     assigns: Dict[int, Assignment],
                     prep) -> Dict[int, ClientResult]:
        eng, model, cfg = self.eng, self.eng.model, self.eng.cfg
        mesh = self.mesh
        batches_np, est_np, taus_arr, c_pad = prep

        client_params = [eng.aggregator.client_params(state, n, assigns[n])
                         for n in ns]
        client_params += [client_params[0]] * (c_pad - len(ns))
        stacked = jax.tree_util.tree_map(
            lambda *leaves: jnp.stack(leaves), *client_params)
        if mesh is None:
            batches = {k: jnp.asarray(v[0]) for k, v in batches_np.items()}
            taus = jnp.asarray(taus_arr)
        else:
            # per-device host shards -> one sharded array per leaf, the
            # client axis on COHORT_AXIS (batch pytree has it at axis 1)
            cs = NamedSharding(mesh, flsh.contribution_spec())
            stacked = jax.device_put(stacked, cs)
            batches = {k: flsh.assemble_from_host_shards(v, mesh, axis=1)
                       for k, v in batches_np.items()}
            taus = jax.device_put(taus_arr, cs)

        train_fn, est_fn = _cohort_fns(
            model, width, eng.factorized, mesh,
            cfg.forward_impl, for_dispatch(cfg))
        obs = eng.obs
        before = _cache_size(train_fn) if obs.enabled else None
        # (tau_pad, C', B, ...) per host chunk — the compiled signature
        lead = batches_np[next(iter(batches_np))][0].shape
        with obs.wall_span("trainer.device_step", clients=c_pad,
                           width=int(width), tau_pad=int(lead[0])):
            final, loss_b, loss_a = train_fn(stacked, batches, taus, cfg.lr)
            if obs.enabled:
                # make the span cover the device work, not just dispatch;
                # only when telemetry is on (no-op path stays untouched)
                jax.block_until_ready(loss_a)
        if obs.enabled:
            _count_recompiles(obs, train_fn, before, trainer="cohort",
                              width=int(width))
            # distinct compiled signatures are keyed by the cohort shape
            obs.counter_add("trainer.cohort_shape", width=int(width),
                            clients=c_pad, tau_pad=int(lead[0]),
                            batch=int(lead[2]))
        ests = None
        if est_np is not None:
            if mesh is None:
                est_batches = {k: jnp.asarray(v[0])
                               for k, v in est_np.items()}
            else:
                est_batches = {k: flsh.assemble_from_host_shards(v, mesh)
                               for k, v in est_np.items()}
            ests = est_fn(stacked, final, est_batches)
            ests = {k: np.asarray(v) for k, v in ests.items()}

        loss_b, loss_a = np.asarray(loss_b), np.asarray(loss_a)
        out = {}
        if mesh is not None and eng.merger is not None:
            # device-resident hand-off: the trained stack stays sharded
            # on the cohort axis; the collective merge consumes it with
            # no gather/rescatter (CohortSlice materializes lazily for
            # every other consumer).
            stack = CohortStack(final, n_real=len(ns))
            for j, n in enumerate(ns):
                est = {k: float(v[j]) for k, v in ests.items()} if ests else {}
                out[n] = ClientResult(CohortSlice(stack, j), est,
                                      float(loss_b[j]), float(loss_a[j]))
            return out
        final = jax.device_get(final)  # one transfer; slice per client below
        for j, n in enumerate(ns):
            params = jax.tree_util.tree_map(lambda v, j=j: v[j], final)
            est = {k: float(v[j]) for k, v in ests.items()} if ests else {}
            out[n] = ClientResult(params, est, float(loss_b[j]), float(loss_a[j]))
        return out


@functools.lru_cache(maxsize=32)
def _prox_fns(model: FLModelDef, width: int, factorized: bool,
              forward_impl: str = "auto", calibration=None):
    """Compiled FedProx step/loss/grad, keyed on the model instance."""

    def loss_fn(params, batch):
        w = (model.prepare_weights(params, width, batch, forward_impl,
                                   calibration)
             if factorized else {k: v for k, v in params.items()})
        logits = model.forward(w, width, batch)
        return client_lib._ce(logits, batch["labels"])

    grad_fn = jax.grad(loss_fn)

    @jax.jit
    def prox_step(params, anchor, batch, lr, mu):
        g = grad_fn(params, batch)
        return jax.tree_util.tree_map(
            lambda p, a, gg: p - lr * (gg + mu * (p - a)), params, anchor, g)

    return jax.jit(loss_fn), jax.jit(grad_fn), prox_step


class ProximalTrainer(LocalTrainer):
    """FedProx local solver: SGD on ``f(w) + (mu/2) ||w - w_global||^2``.

    Identical dispatch/RNG contract to :class:`SequentialTrainer`
    (minibatch indices come from the same ``round_batch_indices``
    stream: tau training draws, then — when the scheme ships estimates —
    3 estimate draws), with the proximal pull toward the received global
    view added to every step — ``mu = 0`` reproduces FedAvg's local
    updates bitwise.  ``mu`` defaults to ``FLConfig.prox_mu``.

    When ``eng.estimate`` is set (Heroes/ADP adaptive policies using
    FedProx as the local solver) the (L, sigma^2, G^2) estimates are
    computed over the 3 estimate batches exactly as the sequential
    backend does, so adaptive tau keeps its signals.
    """

    def __init__(self, mu: Optional[float] = None):
        self._mu = mu

    def train_all(self, state, assigns: Dict[int, Assignment],
                  ) -> Dict[int, ClientResult]:
        eng, cfg = self.eng, self.eng.cfg
        obs = eng.obs
        mu = cfg.prox_mu if self._mu is None else self._mu
        xkey = eng.model.input_key
        out: Dict[int, ClientResult] = {}
        cal = for_dispatch(cfg)
        for n, a in assigns.items():
            loss_fn, grad_fn, prox_step = _prox_fns(
                eng.model, a["width"], eng.factorized,
                cfg.forward_impl, cal)
            before = _cache_size(prox_step) if obs.enabled else None
            with obs.wall_span("trainer.local_train", client=int(n),
                               width=int(a["width"]), tau=int(a["tau"])):
                anchor = eng.aggregator.client_params(state, n, a)
                nsamp = eng.data.num_samples(n)
                b_eff = min(cfg.batch_size, nsamp)
                tau = max(a["tau"], 1)
                idx, est_idx = round_batch_indices(cfg.seed, state.round, n,
                                                   nsamp, tau, b_eff,
                                                   estimate=eng.estimate)
                params, first = anchor, None
                for t in range(tau):
                    xb, yb = eng.data.gather(n, idx[t])
                    batch = {xkey: jnp.asarray(xb), "labels": jnp.asarray(yb)}
                    if first is None:
                        first = batch
                    params = prox_step(params, anchor, batch, cfg.lr, mu)
                est: Dict[str, float] = {}
                if est_idx is not None:
                    ebs = []
                    for i in range(3):
                        xb, yb = eng.data.gather(n, est_idx[i])
                        ebs.append({xkey: jnp.asarray(xb),
                                    "labels": jnp.asarray(yb)})
                    est = estimator.client_estimates(grad_fn, anchor, params,
                                                     ebs)
                    est = {k: float(v) for k, v in est.items()}
                out[n] = ClientResult(jax.device_get(params), est,
                                      float(loss_fn(anchor, first)),
                                      float(loss_fn(params, first)))
            if obs.enabled:
                _count_recompiles(obs, prox_step, before, trainer="proximal",
                                  width=int(a["width"]))
        return out
