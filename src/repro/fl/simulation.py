"""End-to-end FL simulation driver (paper Sec. VI setup, reduced scale)."""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.data import (SyntheticImageTask, SyntheticTextTask,
                        class_skew_partition, dirichlet_partition)
from repro.fl.engine import build_engine
from repro.fl.heterogeneity import HeterogeneityModel
from repro.fl.models import MODELS, FLModelDef, make_cnn, make_resnet, make_rnn
from repro.fl.server import RUNNERS, FLConfig, RoundLog


def build_image_setup(model_name: str = "cnn", num_clients: int = 100,
                      gamma: float = 40.0, max_width: int = 3, seed: int = 0,
                      noise: float = 1.2):
    task = SyntheticImageTask(seed=seed, noise=noise)
    if model_name == "cnn":
        model = make_cnn(max_width=max_width)
    else:
        model = make_resnet(max_width=max_width)
    parts = dirichlet_partition(task.y_train, num_clients, gamma, seed)
    parts_x = [task.x_train[p] for p in parts]
    parts_y = [task.y_train[p] for p in parts]
    test_batch = {"x": jnp.asarray(task.x_test), "labels": jnp.asarray(task.y_test)}
    return model, parts_x, parts_y, test_batch


def build_text_setup(num_clients: int = 100, max_width: int = 3, seed: int = 0):
    task = SyntheticTextTask(seed=seed)
    model = make_rnn(max_width=max_width, vocab=task.vocab)
    # natural partition: contiguous shards (Shakespeare speaker analogue)
    shards = np.array_split(np.arange(len(task.train)), num_clients)
    parts_x = [task.train[s][:, :-1] for s in shards]
    parts_y = [task.train[s][:, 1:] for s in shards]
    test_batch = {
        "tokens": jnp.asarray(task.test[:, :-1]),
        "labels": jnp.asarray(task.test[:, 1:]),
    }
    return model, parts_x, parts_y, test_batch


def build_runner(scheme: str, model: FLModelDef, parts_x, parts_y, test_batch,
                 cfg: Optional[FLConfig] = None, seed: int = 0,
                 tier_weights=(0.05, 0.15, 0.30, 0.50),
                 backend: str = "engine"):
    """Construct a ready-to-run runner for ``scheme``.

    ``backend="engine"`` routes through the layered engine registry
    (:mod:`repro.fl.engine`), which honours the ``FLConfig`` engine knobs
    (``trainer``, ``round_mode``).  ``backend="legacy"`` uses the original
    monolithic runner classes in :mod:`repro.fl.server`; the two produce
    identical histories for the synchronous sequential configuration.
    """
    cfg = cfg or FLConfig(num_clients=len(parts_x), seed=seed)
    het = HeterogeneityModel(cfg.num_clients, seed=seed, tier_weights=tier_weights)
    eval_width = next(iter(model.specs.values())).max_width
    if backend == "legacy":
        if cfg.round_mode != "sync" or cfg.trainer != "sequential":
            raise ValueError(
                "the legacy backend only supports round_mode='sync' and "
                "trainer='sequential'; use backend='engine'")
        return RUNNERS[scheme](model, parts_x, parts_y, test_batch, het, cfg,
                               eval_width)
    if backend != "engine":
        raise ValueError(f"unknown backend {backend!r}")
    return build_engine(scheme, model, parts_x, parts_y, test_batch, het, cfg,
                        eval_width)


def run_scheme(scheme: str, model: FLModelDef, parts_x, parts_y, test_batch,
               rounds: int, cfg: Optional[FLConfig] = None,
               seed: int = 0,
               tier_weights=(0.05, 0.15, 0.30, 0.50),
               backend: str = "engine") -> List[RoundLog]:
    """tier_weights follow the paper's premise: high-performance clients
    (laptops) are a small fraction of the edge fleet — this is exactly the
    regime where original NC starves the largest coefficient (Sec. I)."""
    runner = build_runner(scheme, model, parts_x, parts_y, test_batch,
                          cfg=cfg, seed=seed, tier_weights=tier_weights,
                          backend=backend)
    return runner.run(rounds)


def summarize(history: List[RoundLog]) -> Dict[str, float]:
    accs = [h.accuracy for h in history if h.accuracy is not None]
    return {
        "final_acc": accs[-1] if accs else float("nan"),
        "best_acc": max(accs) if accs else float("nan"),
        "wall_time": history[-1].wall_time,
        "traffic_gb": history[-1].traffic_bytes / 1e9,
        "avg_wait": float(np.mean([h.avg_wait for h in history])),
        "mean_tau": float(np.mean([h.mean_tau for h in history])),
    }


def time_to_accuracy(history: List[RoundLog], target: float) -> Optional[float]:
    for h in history:
        if h.accuracy is not None and h.accuracy >= target:
            return h.wall_time
    return None


def traffic_to_accuracy(history: List[RoundLog], target: float) -> Optional[float]:
    for h in history:
        if h.accuracy is not None and h.accuracy >= target:
            return h.traffic_bytes
    return None
