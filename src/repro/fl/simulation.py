"""End-to-end FL simulation driver (paper Sec. VI setup, reduced scale)."""

from __future__ import annotations

import warnings
from typing import Dict, List, Optional

import numpy as np

from repro.data import load_dataset, make_shards, partition_dataset
from repro.fl.engine import build_engine
from repro.fl.heterogeneity import HeterogeneityModel
from repro.fl.models import FLModelDef, get_model
from repro.fl.transformer import make_transformer  # noqa: F401 — registers "transformer"
from repro.fl.types import FLConfig, RoundLog


def build_setup(task: str, model_name: Optional[str] = None,
                num_clients: int = 100, max_width: int = 3, seed: int = 0, *,
                partitioner: Optional[str] = None, partition_kw=None,
                data_root=None, cache_dir=None, streaming: bool = True,
                task_kw=None, population: Optional[int] = None,
                model_kw=None):
    """Registry-driven setup: any dataset x any partitioner x any model.

    Returns the ``(model, parts_x, parts_y, test_batch)`` tuple every
    driver feeds :func:`run_scheme`.  ``streaming=True`` (default) hands
    out :class:`~repro.data.ShardView`s over one global array instead of
    per-client copies; gathered batches are byte-identical either way.

    ``population=N`` virtualizes the client set (10^4–10^6 clients):
    instead of materializing N index arrays, the partition becomes a
    pure index function (:class:`~repro.fl.population.VirtualPartition`)
    evaluated per *sampled* client, the shard lists are O(1)-resident
    :class:`~repro.data.streaming.VirtualShardList`s, and they carry a
    :class:`~repro.fl.population.PopulationRegistry` that
    :func:`build_runner` binds the heterogeneity model and participation
    bookkeeping to.  ``num_clients`` is ignored in favour of ``N``;
    ``partition_kw`` feeds the virtual partition (``samples_per_client``,
    ``gamma_pct``, ``missing``).
    """
    ds = load_dataset(task, seed=seed, data_root=data_root,
                      cache_dir=cache_dir, **(task_kw or {}))
    if partitioner is None:
        partitioner = "natural" if ds.modality == "text" else "dirichlet"
    if population is not None:
        from repro.fl.population import PopulationRegistry, VirtualPartition
        vp = VirtualPartition(ds.partition_labels, int(population),
                              seed=seed, kind=partitioner,
                              **(partition_kw or {}))
        parts_x, parts_y = make_shards(ds.x, ds.y, vp, streaming=True)
        registry = PopulationRegistry(int(population), seed=seed,
                                      partition=vp)
        parts_x.registry = registry
        parts_y.registry = registry
    else:
        parts = partition_dataset(ds, partitioner, num_clients, seed,
                                  **(partition_kw or {}))
        parts_x, parts_y = make_shards(ds.x, ds.y, parts, streaming)
    meta = ds.metadata
    # model registry lookup (repro.fl.models): model_name=None resolves
    # to the modality default — the historical rnn-for-text /
    # cnn-for-image behaviour
    if model_name is None:
        model_name = "rnn" if ds.modality == "text" else "cnn"
    entry = get_model(model_name)
    if entry.modality != ds.modality:
        raise ValueError(
            f"model {model_name!r} expects {entry.modality} data but "
            f"dataset {task!r} is {ds.modality}")
    model = entry.build(max_width, meta, **(model_kw or {}))
    return model, parts_x, parts_y, ds.test_batch()


def build_image_setup(model_name: str = "cnn", num_clients: int = 100,
                      gamma: float = 40.0, max_width: int = 3, seed: int = 0,
                      noise: float = 1.2, *, task: str = "synthetic_image",
                      partitioner: str = "dirichlet", partition_kw=None,
                      data_root=None, cache_dir=None, streaming: bool = True,
                      task_kw=None):
    """Image-task setup as a registry lookup (default: the synthetic
    stand-in under the paper's Γ partition, same histories as ever)."""
    task_kw = dict(task_kw or {})
    if task == "synthetic_image":
        task_kw.setdefault("noise", noise)
    partition_kw = dict(partition_kw or {})
    if partitioner == "dirichlet":
        partition_kw.setdefault("gamma_pct", gamma)
    return build_setup(task, model_name, num_clients, max_width, seed,
                       partitioner=partitioner, partition_kw=partition_kw,
                       data_root=data_root, cache_dir=cache_dir,
                       streaming=streaming, task_kw=task_kw)


def build_text_setup(num_clients: int = 100, max_width: int = 3, seed: int = 0,
                     *, task: str = "synthetic_text",
                     model_name: Optional[str] = None,
                     partitioner: str = "natural", partition_kw=None,
                     data_root=None, cache_dir=None, streaming: bool = True,
                     task_kw=None, model_kw=None):
    """Char-LM setup as a registry lookup.

    The default ``natural`` partitioner groups by speaker when the
    dataset carries ids (Shakespeare) and falls back to the contiguous
    shards of the synthetic corpus — but any registered partitioner
    (``dirichlet``, ``class_skew``, ``iid``) now applies to text too.
    ``model_name`` picks any registered text model (``"rnn"`` default,
    ``"transformer"`` for the composed-LLM path).
    """
    return build_setup(task, model_name, num_clients, max_width, seed,
                       partitioner=partitioner, partition_kw=partition_kw,
                       data_root=data_root, cache_dir=cache_dir,
                       streaming=streaming, task_kw=task_kw,
                       model_kw=model_kw)


def build_runner(scheme: str, model: FLModelDef, parts_x, parts_y, test_batch,
                 cfg: Optional[FLConfig] = None, seed: int = 0,
                 tier_weights=(0.05, 0.15, 0.30, 0.50),
                 backend: str = "engine"):
    """Construct a ready-to-run runner for ``scheme``.

    ``backend="engine"`` routes through the layered engine registry
    (:mod:`repro.fl.engine`), which honours the ``FLConfig`` engine knobs
    (``trainer``, ``round_mode``, the ``agg_*``/``trainer_mesh_devices``
    device-mesh knobs and ``sample_weighted``).  ``backend="legacy"``
    is deprecated: the monolithic runner classes were retired, and the
    flag now warns and routes to the engine — which reproduces the
    legacy histories bitwise (golden fixtures pin this).
    """
    cfg = cfg or FLConfig(num_clients=len(parts_x), seed=seed)
    registry = getattr(parts_x, "registry", None)
    if registry is not None:
        # virtual population: profiles resolve on demand through the
        # registry's pure profile function — no resident client list
        if cfg.num_clients != len(registry):
            raise ValueError(
                f"cfg.num_clients={cfg.num_clients} does not match the "
                f"virtual population of {len(registry)} clients")
        het = registry.heterogeneity(seed=seed, tier_weights=tier_weights)
    else:
        het = HeterogeneityModel(cfg.num_clients, seed=seed,
                                 tier_weights=tier_weights)
    eval_width = next(iter(model.specs.values())).max_width
    if backend == "legacy":
        warnings.warn(
            "build_runner(backend='legacy') is deprecated: the legacy "
            "runner classes were retired; routing to the engine, which "
            "reproduces the legacy histories bitwise.",
            DeprecationWarning, stacklevel=2)
        backend = "engine"
    if backend != "engine":
        raise ValueError(f"unknown backend {backend!r}")
    return build_engine(scheme, model, parts_x, parts_y, test_batch, het, cfg,
                        eval_width)


def run_scheme(scheme: str, model: FLModelDef, parts_x, parts_y, test_batch,
               rounds: int, cfg: Optional[FLConfig] = None,
               seed: int = 0,
               tier_weights=(0.05, 0.15, 0.30, 0.50),
               backend: str = "engine") -> List[RoundLog]:
    """tier_weights follow the paper's premise: high-performance clients
    (laptops) are a small fraction of the edge fleet — this is exactly the
    regime where original NC starves the largest coefficient (Sec. I)."""
    runner = build_runner(scheme, model, parts_x, parts_y, test_batch,
                          cfg=cfg, seed=seed, tier_weights=tier_weights,
                          backend=backend)
    return runner.run(rounds)


def summarize(history: List[RoundLog]) -> Dict[str, float]:
    """Run summary; an empty history yields an empty dict (no crash).

    ``traffic_gb`` stays the combined (up + down) figure every existing
    consumer reads; ``traffic_up_gb``/``traffic_down_gb`` split it by
    direction from the per-round deltas the loops now record.
    """
    if not history:
        return {}
    accs = [h.accuracy for h in history if h.accuracy is not None]
    return {
        "final_acc": accs[-1] if accs else float("nan"),
        "best_acc": max(accs) if accs else float("nan"),
        "wall_time": history[-1].wall_time,
        "traffic_gb": history[-1].traffic_bytes / 1e9,
        "traffic_up_gb": float(sum(h.up_bytes for h in history)) / 1e9,
        "traffic_down_gb": float(sum(h.down_bytes for h in history)) / 1e9,
        "avg_wait": float(np.mean([h.avg_wait for h in history])),
        "mean_tau": float(np.mean([h.mean_tau for h in history])),
    }


def time_to_accuracy(history: List[RoundLog], target: float) -> Optional[float]:
    """Wall time at which ``target`` accuracy was first reached, or
    ``None`` (including on an empty history)."""
    for h in history or []:
        if h.accuracy is not None and h.accuracy >= target:
            return h.wall_time
    return None


def traffic_to_accuracy(history: List[RoundLog], target: float) -> Optional[float]:
    """Traffic at which ``target`` accuracy was first reached, or
    ``None`` (including on an empty history)."""
    for h in history or []:
        if h.accuracy is not None and h.accuracy >= target:
            return h.traffic_bytes
    return None
