"""Client-side procedure (paper Alg. 2).

A client receives (basis, reduced coefficient, tau), runs tau local SGD
iterations over its data directly on the factors, estimates
(L, sigma^2, G^2) and returns updated tensors + estimates.  How each
layer weight is *applied* inside the loss is the ``forward_impl`` knob:
composed first (``materialize`` — the historical bitwise path) or
contracted in rank space without ever building the p-width weight
(``rank_space`` / the FLOPs-driven ``auto`` default); see
``FLModelDef.prepare_weights`` and docs/ENGINE.md "Rank-space client
compute".
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimator
from repro.fl.models import FLModelDef

Array = jax.Array


def data_batch(model: FLModelDef, x, y, idx) -> Dict[str, Array]:
    return {model.input_key: jnp.asarray(x[idx]),
            "labels": jnp.asarray(y[idx])}


def _ce(logits: Array, labels: Array) -> Array:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    return jnp.mean(logz - gold)


@functools.lru_cache(maxsize=64)
def _jitted_fns(model: FLModelDef, width: int, factorized: bool,
                forward_impl: str = "auto", calibration=None):
    # Keyed on the model *instance* (FLModelDef hashes by identity): the
    # old string registry key dropped constructor kwargs that are not part
    # of the encoding (e.g. ``in_ch``), silently training the wrong model.
    # ``calibration`` (a frozen RankPathCalibration, or None = the
    # per-process measurement) joins the key so two configs with
    # different cost-model overrides never share impl choices.

    def loss_fn(params, batch):
        w = (model.prepare_weights(params, width, batch, forward_impl,
                                   calibration)
             if factorized else {k: v for k, v in params.items()})
        logits = model.forward(w, width, batch)
        return _ce(logits, batch["labels"])

    grad_fn = jax.jit(jax.grad(loss_fn))
    loss_jit = jax.jit(loss_fn)

    @jax.jit
    def sgd_step(params, batch, lr):
        g = jax.grad(loss_fn)(params, batch)
        return jax.tree_util.tree_map(lambda p, gg: p - lr * gg, params, g)

    return loss_jit, grad_fn, sgd_step


@dataclasses.dataclass
class ClientResult:
    params: Any  # updated reduced factors (or dense sub-weights)
    estimates: Dict[str, float]
    loss_before: float
    loss_after: float

    def host_params(self) -> Any:
        """Params as a host pytree.

        Usually ``params`` itself (the numpy contract); a mesh-sharded
        cohort trainer hands the collective backend lazy device-resident
        slices instead, and this materializes them.
        """
        mat = getattr(self.params, "materialize", None)
        return mat() if mat is not None else self.params


def local_train(
    model: FLModelDef,
    reduced_params: Any,
    width: int,
    tau: int,
    x, y,
    lr: float,
    rng: np.random.Generator,
    batch_size: int = 16,
    factorized: bool = True,
    estimate: bool = True,
    forward_impl: str = "auto",
    calibration=None,
) -> ClientResult:
    """tau local SGD iterations (Alg. 2 lines 4-9).

    ``forward_impl`` selects the factorized compute path (see
    ``FLConfig.forward_impl``): ``"materialize"`` reproduces the
    historical compose-then-apply updates bitwise; ``"auto"`` (default)
    applies factors in rank space wherever the measured cost model says
    it is cheaper (``calibration`` carries an FLConfig override; None =
    the per-process measurement).  Ignored when ``factorized=False``.
    """
    loss_jit, grad_fn, sgd_step = _jitted_fns(model, width, factorized,
                                              forward_impl, calibration)
    params0 = reduced_params
    params = params0
    n = len(y)
    first_batch = None
    for _ in range(max(tau, 1)):
        idx = rng.integers(0, n, min(batch_size, n))
        batch = data_batch(model, x, y, idx)
        if first_batch is None:
            first_batch = batch
        params = sgd_step(params, batch, lr)

    est = {}
    loss_b = float(loss_jit(params0, first_batch))
    loss_a = float(loss_jit(params, first_batch))
    if estimate:
        batches = [
            data_batch(model, x, y, rng.integers(0, n, min(batch_size, n)))
            for _ in range(3)
        ]
        est = estimator.client_estimates(
            lambda p, b: grad_fn(p, b), params0, params, batches
        )
        est = {k: float(v) for k, v in est.items()}
    return ClientResult(params, est, loss_b, loss_a)
