"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS for 512 host devices before any jax import; tests and benches
see the 1 real CPU device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: one pod = (data=16, model=16) = 256 chips;
    multi-pod adds a leading pod axis (2, 16, 16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh for CPU tests/examples."""
    return jax.make_mesh((1, 1), ("data", "model"))
