"""Batched serving launcher: continuous-batch decode against a KV cache.

``python -m repro.launch.serve --arch gemma-2b --smoke --requests 8``

Maintains a fixed decode batch; finished requests (EOS or length) are
replaced from the queue — a miniature continuous-batching loop over
``serve_step``, the same function the decode dry-run shapes lower.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=configs.list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get_config(args.arch)
    if cfg.family == "audio":
        print("enc-dec serving: decoder-side continuous batching with a "
              "fixed encoder memory per request (stub embeddings)")
    params = model.init(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(0)
    queue = [rng.integers(0, cfg.vocab, rng.integers(4, 12)).tolist()
             for _ in range(args.requests)]
    B = args.batch

    cache = model.init_cache(cfg, B, args.max_len)
    serve = jax.jit(lambda p, b, c, l: model.serve_step(p, cfg, b, c, l))

    # slot state
    active = [None] * B  # (request_id, remaining_prompt, generated)
    next_req = 0
    done = 0
    lens = np.zeros(B, np.int64)
    t0 = time.time()
    steps = 0
    tokens_out = 0
    # NOTE: per-slot cache_len differs; for simplicity this demo advances a
    # shared position (prompts are left-aligned and padded by generation).
    pos = 0
    cur = np.zeros((B, 1), np.int32)
    while done < args.requests and pos < args.max_len - 1:
        for s in range(B):
            if active[s] is None and next_req < len(queue):
                active[s] = [next_req, list(queue[next_req]), 0]
                next_req += 1
        batch = {"tokens": jnp.asarray(cur)}
        if cfg.rope_type == "mrope":
            batch["positions"] = jnp.full((B, 3, 1), pos, jnp.int32)
        if cfg.family == "audio":
            se = min(cfg.encdec.encoder_seq, 32)
            batch["enc_embeddings"] = jnp.zeros((B, se, cfg.d_model))
            batch["enc_mask"] = jnp.ones((B, se), bool)
        logits, cache = serve(params, batch, cache, jnp.int32(pos))
        from repro.models.sampling import sample_logits
        nxt = np.asarray(sample_logits(
            jax.random.PRNGKey(pos), logits[:, -1],
            temperature=args.temperature, top_k=args.top_k), np.int32)
        for s in range(B):
            if active[s] is None:
                continue
            rid, prompt, gen = active[s]
            if prompt:
                cur[s, 0] = prompt.pop(0)  # teacher-force remaining prompt
            else:
                cur[s, 0] = nxt[s]
                active[s][2] += 1
                tokens_out += 1
                if active[s][2] >= args.max_new:
                    done += 1
                    active[s] = None
        pos += 1
        steps += 1
    dt = time.time() - t0
    print(f"served {done}/{args.requests} requests, {tokens_out} tokens in "
          f"{steps} steps, {dt:.1f}s ({tokens_out/max(dt,1e-9):.1f} tok/s "
          f"on CPU-interpret scale)")


if __name__ == "__main__":
    main()
