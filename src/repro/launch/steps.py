"""Step functions lowered by the launcher / dry-run.

  train_step  — fwd + bwd + optimizer update (train_4k)
  prefill     — full-sequence forward          (prefill_32k)
  serve_step  — one token against a cache      (decode_32k / long_500k)
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import model
from repro.optim import apply_updates


def make_train_step(cfg, optimizer, skip_blocks: bool = False) -> Callable:
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model.loss_fn(p, cfg, batch, skip_blocks), has_aux=True
        )(params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["grad_norm"] = jnp.sqrt(
            sum(jnp.vdot(g, g).real for g in jax.tree_util.tree_leaves(grads))
        ).astype(jnp.float32)
        return params, opt_state, metrics

    return train_step


def make_prefill(cfg, skip_blocks: bool = False) -> Callable:
    def prefill(params, batch):
        if cfg.family == "audio" or cfg.encdec is not None:
            logits, _ = model.prefill(params, cfg, batch, cache=None)
        else:
            logits, _ = model.forward(params, cfg, batch, skip_blocks)
        return logits

    return prefill


def make_serve_step(cfg) -> Callable:
    def serve_step(params, batch, cache, cache_len):
        logits, cache = model.serve_step(params, cfg, batch, cache, cache_len)
        return logits, cache

    return serve_step
