"""Distributed training launcher.

``python -m repro.launch.train --arch gemma-2b --smoke --steps 20``

On real hardware the same entry point drives the production mesh
(``--mesh pod`` / ``--mesh multipod``); on this CPU container use
``--smoke`` (reduced config, 1-device mesh) — same code path, same
sharding rules, degenerate mesh.  Supports Heroes composition as a
first-class switch (``--composition``) and checkpoint/resume.
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.checkpoint import restore_latest, save_checkpoint
from repro.configs.base import CompositionConfig
from repro.data import SyntheticTextTask, lm_batches
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_train_step
from repro.models import model
from repro.models.module import count_params
from repro.optim import cosine_schedule, make_optimizer
from repro.sharding import rules
from repro.sharding.context import set_context


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=configs.list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on the host mesh (CPU)")
    ap.add_argument("--mesh", default="host", choices=["host", "pod", "multipod"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--composition", action="store_true",
                    help="train the Heroes-factorized parameterisation")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get_config(args.arch)
    if args.composition:
        cfg = cfg.replace(composition=CompositionConfig(
            enabled=True, max_width=2, rank=cfg.d_model // 4))
    if cfg.family in ("vlm", "audio"):
        print(f"note: {args.arch} uses stub frontends; training on synthetic "
              "token streams with stub embeddings")

    mesh = {"host": make_host_mesh,
            "pod": lambda: make_production_mesh(multi_pod=False),
            "multipod": lambda: make_production_mesh(multi_pod=True)}[args.mesh]()
    dp = rules.dp_axes_for(mesh)
    set_context(mesh, dp)

    key = jax.random.PRNGKey(0)
    params = model.init(key, cfg)
    print(f"{cfg.arch_id}: {count_params(params):,} params "
          f"(composition={'on' if args.composition else 'off'}), "
          f"mesh={mesh.shape}")

    opt = make_optimizer(args.optimizer, cosine_schedule(args.lr, args.steps, 5))
    opt_state = opt.init(params)

    start = 0
    if args.ckpt_dir:
        restored = restore_latest(args.ckpt_dir)
        if restored:
            start, state = restored
            params, opt_state = state["params"], state["opt"]
            print(f"resumed from step {start}")

    pspecs = rules.param_specs(jax.eval_shape(lambda: params), mesh=mesh)
    shard = lambda tree, specs: jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs)
    params = shard(params, pspecs)

    step_fn = jax.jit(make_train_step(cfg, opt))
    task = SyntheticTextTask(vocab=min(cfg.vocab, 512), seq_len=args.seq)
    rng = np.random.default_rng(0)

    t0 = time.time()
    for i in range(start, args.steps):
        toks, labels = lm_batches(task.train, args.batch, rng)
        batch = {"tokens": jnp.asarray(toks % cfg.vocab),
                 "labels": jnp.asarray(labels % cfg.vocab)}
        if cfg.family == "vlm":
            emb = model._input_embeddings(params, cfg, batch)
            pos = jnp.broadcast_to(
                jnp.arange(args.seq, dtype=jnp.int32)[None, None, :],
                (args.batch, 3, args.seq))
            batch = {"embeddings": emb, "positions": pos, "labels": batch["labels"]}
        if cfg.family == "audio":
            se = min(cfg.encdec.encoder_seq, 64)
            batch["enc_embeddings"] = 0.02 * jax.random.normal(
                jax.random.PRNGKey(i), (args.batch, se, cfg.d_model))
            batch["enc_mask"] = jnp.ones((args.batch, se), bool)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(metrics['loss']):.4f}  "
                  f"grad_norm {float(metrics['grad_norm']):.3f}  "
                  f"{(time.time()-t0):.1f}s")
        if args.ckpt_dir and args.ckpt_every and (i + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, i + 1,
                            {"params": params, "opt": opt_state})
    print("done.")


if __name__ == "__main__":
    main()
