"""ShapeDtypeStruct input specs for every (architecture x input shape).

``input_specs(cfg, shape)`` returns the exact pytree the lowered step
function consumes — weak-type-correct, shardable, and never allocated.
Train/prefill shapes produce token batches (or stub embeddings for
[vlm]/[audio] per the carve-out); decode shapes produce a one-token batch
plus the populated-cache stand-in and a cache_len scalar.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models import model

SDS = jax.ShapeDtypeStruct


def _token_batch(cfg: ModelConfig, B: int, S: int, with_labels: bool) -> Dict[str, Any]:
    batch: Dict[str, Any] = {}
    if cfg.family == "vlm":
        batch["embeddings"] = SDS((B, S, cfg.d_model), cfg.cdtype)
        batch["positions"] = SDS((B, 3, S), jnp.int32)
        if with_labels:
            batch["labels"] = SDS((B, S), jnp.int32)
        return batch
    batch["tokens"] = SDS((B, S), jnp.int32)
    if cfg.family == "audio":
        Se = cfg.encdec.encoder_seq
        batch["enc_embeddings"] = SDS((B, Se, cfg.d_model), cfg.cdtype)
        batch["enc_mask"] = SDS((B, Se), jnp.bool_)
    if with_labels:
        batch["labels"] = SDS((B, S), jnp.int32)
    return batch


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"batch": _token_batch(cfg, B, S, with_labels=True)}
    if shape.kind == "prefill":
        return {"batch": _token_batch(cfg, B, S, with_labels=False)}
    # decode: one token + cache populated to seq_len
    batch: Dict[str, Any] = {"tokens": SDS((B, 1), jnp.int32)}
    if cfg.rope_type == "mrope":
        batch["positions"] = SDS((B, 3, 1), jnp.int32)
    cache = jax.eval_shape(lambda: model.init_cache(cfg, B, S))
    # eval_shape returns SDS pytree already
    return {
        "batch": batch,
        "cache": cache,
        "cache_len": SDS((), jnp.int32),
    }


def params_shape(cfg: ModelConfig):
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0), cfg))


def opt_state_shape(cfg: ModelConfig, optimizer):
    p = params_shape(cfg)
    return jax.eval_shape(optimizer.init, p)
