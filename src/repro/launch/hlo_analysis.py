"""Loop-aware analysis of compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers model is undercounted by ~num_layers.  This module parses
``compiled.as_text()`` (post-optimization HLO), reconstructs the
computation call graph, extracts static trip counts from loop conditions
(jax scans lower to ``i < C`` with a literal constant) and produces
loop-scaled totals:

  * dot_flops          — 2*M*N*K summed over every ``dot``/``convolution``
  * traffic_bytes      — HBM traffic model: operand+result bytes of every
                         *fusion-level* op (ops inside fusion computations
                         are register/VMEM-internal and excluded)
  * collective_bytes   — result bytes of all-gather / all-reduce /
                         reduce-scatter / all-to-all / collective-permute,
                         by type

All totals are per-device (the HLO is the per-device SPMD program).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}
_ARR_RE = re.compile(r"\b(pred|s4|u4|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_TRAFFIC = {
    "parameter", "get-tuple-element", "tuple", "constant", "bitcast",
    "after-all", "add-dependency", "iota", "partition-id", "replica-id",
}


def _shape_bytes(type_str: str) -> int:
    """Total bytes of all arrays in a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _ARR_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _ARR_RE.search(type_str)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    is_fusion_target: bool = False  # called via fusion `calls=`


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"^([\w\-]+)\((.*)$", re.S)


def _matching_paren(s: str, start: int = 0) -> int:
    """Index of the close bracket matching s[start] (must be an opener)."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] in "([{":
            depth += 1
        elif s[i] in ")]}":
            depth -= 1
            if depth == 0:
                return i
    return len(s) - 1


def _parse_op_line(line: str) -> Optional[Op]:
    m = _NAME_RE.match(line)
    if not m:
        return None
    name, rhs = m.groups()
    rhs = rhs.strip()
    if rhs.startswith("("):  # tuple type
        end = _matching_paren(rhs, 0)
        type_str, rest = rhs[: end + 1], rhs[end + 1 :].strip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        type_str, rest = rhs[:sp], rhs[sp + 1 :].strip()
    m2 = _OPCODE_RE.match(rest)
    if not m2:
        return None
    opcode, tail = m2.group(1), m2.group(2)
    end = _matching_paren("(" + tail, 0) - 1  # match the opcode's paren
    args, attrs = tail[:end], tail[end + 1 :]
    operands = [a.strip().lstrip("%") for a in _split_args(args)]
    return Op(name, type_str, opcode, operands, attrs)


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            # computation headers start at column 0, contain '->' and end '{'
            if line and not line[0].isspace() and "->" in line and line.rstrip().endswith("{"):
                m = _COMP_HDR.match(line.strip())
                if m:
                    cur = Computation(m.group(1), [])
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        op = _parse_op_line(line)
        if op is not None:
            cur.ops.append(op)
    return comps


def _split_args(args: str) -> List[str]:
    """Split top-level commas (operand lists may contain nested brackets)."""
    out, depth, cur = [], 0, []
    for ch in args:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            tok = "".join(cur).strip()
            if tok:
                out.append(tok)
            cur = []
        else:
            cur.append(ch)
    tok = "".join(cur).strip()
    if tok:
        out.append(tok)
    # Current XLA prints each operand with its type ("f32[32,32]{1,0}
    # %dot.0"); older dumps printed the bare %ref.  Keep the trailing
    # %ref field of each token, dropping attribute tokens (dims=...).
    refs = []
    for t in out:
        t = t.split()[-1]
        if t.startswith("%") or re.match(r"^[\w.\-]+$", t):
            refs.append(t.lstrip("%"))
    return refs


def flat_cost_analysis(cost) -> Dict[str, float]:
    """Normalize ``compiled.cost_analysis()`` across jax versions.

    Older jax returned a dict of properties; current jax returns a
    one-element list of that dict (one entry per executable module).
    Always returns a plain dict ({} for an empty analysis).
    """
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def _called_computations(op: Op) -> List[str]:
    names = []
    for key in ("body=", "condition=", "calls=", "to_apply=", "branch_computations="):
        for m in re.finditer(re.escape(key) + r"\{?([%\w.\-, ]+)\}?", op.attrs):
            for nm in m.group(1).split(","):
                nm = nm.strip().lstrip("%")
                if nm:
                    names.append(nm)
    return names


def _trip_count(cond: Computation, comps: Dict[str, Computation]) -> Optional[int]:
    """Extract `i < C` bound from a loop condition computation."""
    consts: Dict[str, int] = {}
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", op.attrs) or re.search(
                r"\((-?\d+)\)", op.type_str
            )
            # constant value printed as constant(7) in the args position —
            # our regex put it in operands; try attrs then operands
            if not m:
                continue
            consts[op.name] = int(m.group(1))
        # jax prints e.g. %constant.6 = s32[] constant(7)
    # constants may also appear with the value inside the parsed "operands"
    for op in cond.ops:
        if op.opcode == "constant" and op.name not in consts and op.operands:
            try:
                consts[op.name] = int(op.operands[0])
            except ValueError:
                pass
    candidates = []
    for op in cond.ops:
        if op.opcode == "compare":
            for o in op.operands:
                if o in consts:
                    candidates.append(consts[o])
        if op.opcode == "fusion":
            # wrapped compare: operands include the constant
            for o in op.operands:
                if o in consts:
                    candidates.append(consts[o])
            for sub in _called_computations(op):
                subc = comps.get(sub)
                if subc and any(o.opcode == "compare" for o in subc.ops):
                    candidates.extend(consts.values())
    if candidates:
        return max(candidates)
    if consts:
        return max(consts.values())
    return None


def build_multipliers(comps: Dict[str, Computation]) -> Tuple[Dict[str, float], Dict[str, bool]]:
    """(multiplier per computation, fusion-internal flag per computation)."""
    entry = None
    for name, c in comps.items():
        if name.startswith("main") or ".main" in name or entry is None:
            if entry is None:
                entry = name
        if name.startswith("main"):
            entry = name
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    fusion_internal: Dict[str, bool] = {name: False for name in comps}

    def visit(name: str, m: float, via_fusion: bool):
        if name not in comps:
            return
        mult[name] += m
        if via_fusion:
            fusion_internal[name] = True
        c = comps[name]
        for op in c.ops:
            called = _called_computations(op)
            if op.opcode == "while":
                body_cond = called
                trips = None
                for sub in body_cond:
                    if "cond" in sub or "region_1" in sub:
                        pass
                # identify condition via attr keys directly
                mb = re.search(r"body=%?([\w.\-]+)", op.attrs)
                mc = re.search(r"condition=%?([\w.\-]+)", op.attrs)
                body = mb.group(1) if mb else None
                cond = mc.group(1) if mc else None
                if cond and cond in comps:
                    trips = _trip_count(comps[cond], comps)
                trips = trips if trips and trips > 0 else 1
                if body:
                    visit(body, m * trips, via_fusion)
                if cond:
                    visit(cond, m * (trips + 1), via_fusion)
            elif op.opcode == "fusion":
                for sub in called:
                    visit(sub, m, True)
            elif op.opcode in ("call", "conditional", "all-reduce",
                               "reduce", "reduce-scatter", "reduce-window",
                               "scatter", "sort", "map", "custom-call"):
                for sub in called:
                    visit(sub, m, True)  # applied computations: cheap, mark internal
            else:
                for sub in called:
                    visit(sub, m, via_fusion)

    if entry is not None:
        visit(entry, 1.0, False)
    return mult, fusion_internal


def analyze(hlo: str) -> Dict[str, object]:
    comps = parse_computations(hlo)
    mult, fusion_internal = build_multipliers(comps)

    dot_flops = 0.0
    traffic = 0.0
    coll = {c: 0.0 for c in _COLLECTIVES}
    coll_counts = {c: 0.0 for c in _COLLECTIVES}

    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        shapes: Dict[str, str] = {op.name: op.type_str for op in comp.ops}
        for op in comp.ops:
            # ---- FLOPs from contractions (counted even inside fusions)
            if op.opcode == "dot":
                out = _shape_dims(op.type_str)
                lhs = _shape_dims(shapes.get(op.operands[0], "")) if op.operands else None
                if out and lhs:
                    k = 1
                    mdim = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
                    if mdim and mdim.group(1):
                        for d in mdim.group(1).split(","):
                            di = int(d)
                            if di < len(lhs[1]):
                                k *= lhs[1][di]
                    n_out = 1
                    for d in out[1]:
                        n_out *= d
                    dot_flops += m * 2.0 * n_out * k
            elif op.opcode == "convolution":
                out = _shape_dims(op.type_str)
                if out:
                    n_out = 1
                    for d in out[1]:
                        n_out *= d
                    # conservative: 2 * out_elems * (guess K from rhs)
                    rhs = _shape_dims(shapes.get(op.operands[1], "")) if len(op.operands) > 1 else None
                    k = 1
                    if rhs:
                        for d in rhs[1][:-1]:
                            k *= d
                    dot_flops += m * 2.0 * n_out * k
            # ---- collectives
            base = None
            for c in _COLLECTIVES:
                if op.opcode == c or op.opcode.startswith(c + "-"):
                    base = c
                    break
            if base and not op.opcode.endswith("-done"):
                coll[base] += m * _shape_bytes(op.type_str)
                coll_counts[base] += m
            # ---- HBM traffic (fusion-level only)
            if not fusion_internal.get(name, False) and op.opcode not in _SKIP_TRAFFIC:
                b = _shape_bytes(op.type_str)
                for o in op.operands:
                    b += _shape_bytes(shapes.get(o, ""))
                traffic += m * b

    return {
        "dot_flops": dot_flops,
        "traffic_bytes": traffic,
        "collective_bytes": {**{k: v for k, v in coll.items()},
                             "total": sum(coll.values())},
        "collective_counts": coll_counts,
        "num_computations": len(comps),
    }
