import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and record memory/cost/collective analysis.

MUST be executed as its own process (``python -m repro.launch.dryrun``) —
the XLA_FLAGS line above runs before any jax import and gives the host
platform 512 placeholder devices so ``jax.make_mesh`` can build the
16x16 (single-pod) and 2x16x16 (multi-pod) production meshes.

Per pair we lower the shape-appropriate step (train_step / prefill /
serve_step) with full in/out shardings, compile, and dump:
  * memory_analysis (per-device argument/output/temp/peak bytes)
  * cost_analysis   (per-device HLO FLOPs + bytes accessed)
  * collective operand bytes by type (parsed from the compiled HLO)
into experiments/dryrun/<arch>__<shape>__<mesh>.json — the roofline
report (benchmarks/roofline.py, EXPERIMENTS.md §Roofline) reads these.
"""

import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.shapes import SHAPES
from repro.launch import specs as specs_lib
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh
from repro.optim import make_optimizer
from repro.sharding import rules

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}
_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64)\[([0-9,]*)\]")


def _arr_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op, by type."""
    out = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$", s)
        if not m:
            continue
        rhs = m.group(1)
        opm = re.search(r"\b([a-z0-9\-]+)\(", rhs)
        if not opm:
            continue
        op = opm.group(1)
        base = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-"):  # e.g. all-gather-start
                base = c
                break
        if base is None or op.endswith("-done"):
            continue
        shapes_part = rhs[: opm.start()]
        total = sum(_arr_bytes(d, dims) for d, dims in _SHAPE_RE.findall(shapes_part))
        out[base] += total
        counts[base] += 1
    out["counts"] = counts
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


def _peak_bytes(mem):
    """Peak per-device bytes across jax versions.

    Older jaxlib exposed ``peak_memory_in_bytes``; current
    ``CompiledMemoryStats`` dropped it, so fall back to the standard
    estimate argument + output + temp - alias.
    """
    peak = getattr(mem, "peak_memory_in_bytes", None)
    if peak:
        return peak
    parts = [getattr(mem, a, None) for a in
             ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes")]
    if all(p is None for p in parts):
        return None
    total = sum(p or 0 for p in parts)
    total -= getattr(mem, "alias_size_in_bytes", 0) or 0
    return max(total, 0)


def _shardings(tree_specs, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def _logits_sharding(mesh, dp, B: int, V: int, S: int = 1):
    """Logits (B, S, V) sharding.  Prefer vocab over 'model'; when the
    vocab doesn't divide (seamless: 256206) shard the sequence instead —
    otherwise the full-vocab logits dominate peak memory (measured
    31.4 GiB/device for seamless prefill_32k)."""
    spec = rules._fit_to_shape(P(dp, None, "model"), (B, S, V), mesh)
    if spec[2] is None and S > 1:
        spec2 = rules._fit_to_shape(P(dp, "model", None), (B, S, V), mesh)
        if spec2[1] is not None:
            spec = spec2
    return NamedSharding(mesh, spec)


def optimizer_for(arch_id: str):
    if arch_id == "kimi-k2-1t-a32b":
        return make_optimizer("sgdm_bf16", 1e-3), "sgdm_bf16"
    return make_optimizer("adamw", 1e-3), "adamw"


def lower_pair(arch_id: str, shape_name: str, multi_pod: bool,
               skip_blocks: bool = False, moe_sorted: bool = False,
               residual: str = "d_sharded", composition: bool = False,
               compose_matmul: bool = False, attn_qseq: bool = False,
               no_remat: bool = False, kv_int8: bool = False,
               moe_shardmap: bool = False):
    """Lower+compile one (arch, shape, mesh) and return the analysis dict."""
    shape = SHAPES[shape_name]
    cfg = configs.config_for_shape(arch_id, shape_name)
    if no_remat:
        cfg = cfg.replace(remat=False)
    if kv_int8:
        cfg = cfg.replace(kv_cache_quant="int8")
    if composition:
        from repro.configs.base import CompositionConfig
        from repro.models.module import set_compose_then_matmul
        cfg = cfg.replace(composition=CompositionConfig(
            enabled=True, max_width=2, rank=cfg.d_model // 4,
            factorized_forward=not compose_matmul))
        set_compose_then_matmul(compose_matmul)
    if moe_sorted:
        from repro.models import moe as moe_mod  # perf variant toggle
        moe_mod.apply_moe, moe_mod._apply_moe_orig = (
            moe_mod.apply_moe_sorted, moe_mod.apply_moe)
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = rules.dp_axes_for(mesh)
    zero_pod = multi_pod and arch_id == "kimi-k2-1t-a32b"
    from repro.sharding.context import set_context
    set_context(mesh, dp, residual, attn_qseq=attn_qseq,
                moe_shardmap=moe_shardmap)

    pshape = specs_lib.params_shape(cfg)
    pspecs = rules.param_specs(pshape, mesh=mesh, zero_pod=zero_pod,
                               moe_ep=moe_shardmap)
    ins = specs_lib.input_specs(cfg, shape)

    t0 = time.time()
    if shape.kind == "train":
        opt, opt_name = optimizer_for(arch_id)
        oshape = jax.eval_shape(opt.init, pshape)
        ospecs = rules.param_specs(oshape, mesh=mesh, zero_pod=zero_pod)
        bspecs = rules.batch_specs(ins["batch"], dp, mesh=mesh)
        step = steps_lib.make_train_step(cfg, opt, skip_blocks=skip_blocks)
        jitted = jax.jit(
            step,
            in_shardings=(_shardings(pspecs, mesh), _shardings(ospecs, mesh),
                          _shardings(bspecs, mesh)),
            out_shardings=(_shardings(pspecs, mesh), _shardings(ospecs, mesh),
                           None),
        )
        lowered = jitted.lower(pshape, oshape, ins["batch"])
    elif shape.kind == "prefill":
        bspecs = rules.batch_specs(ins["batch"], dp, mesh=mesh)
        step = steps_lib.make_prefill(cfg, skip_blocks=skip_blocks)
        jitted = jax.jit(
            step,
            in_shardings=(_shardings(pspecs, mesh), _shardings(bspecs, mesh)),
            out_shardings=_logits_sharding(mesh, dp, shape.global_batch,
                                           cfg.vocab, shape.seq_len),
        )
        lowered = jitted.lower(pshape, ins["batch"])
    else:  # decode
        bspecs = rules.batch_specs(ins["batch"], dp, mesh=mesh)
        cspecs = rules.cache_specs(ins["cache"], cfg, dp, mesh=mesh)
        step = steps_lib.make_serve_step(cfg)
        jitted = jax.jit(
            step,
            in_shardings=(_shardings(pspecs, mesh), _shardings(bspecs, mesh),
                          _shardings(cspecs, mesh), None),
            out_shardings=(_logits_sharding(mesh, dp, shape.global_batch,
                                            cfg.vocab),
                           _shardings(cspecs, mesh)),
        )
        lowered = jitted.lower(pshape, ins["batch"], ins["cache"],
                               jnp.int32(shape.seq_len - 1))
        opt_name = None
    lower_s = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    from repro.launch import hlo_analysis
    # cost_analysis() returns [dict] on current jax, dict on older — the
    # shared helper normalizes (same one tests/test_system.py uses)
    cost = hlo_analysis.flat_cost_analysis(compiled.cost_analysis())
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    loop_scaled = hlo_analysis.analyze(hlo)

    result = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": 512 if multi_pod else 256,
        "kind": shape.kind,
        "optimizer": opt_name if shape.kind == "train" else None,
        "skip_blocks": skip_blocks,
        "residual": residual,
        "composition": composition,
        "compose_matmul": compose_matmul,
        "lower_s": round(lower_s, 1),
        "compile_s": round(compile_s, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": _peak_bytes(mem),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        },
        "cost": {
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
        },
        "collectives": coll,  # raw text scan (while bodies counted once)
        "loop_scaled": loop_scaled,  # trip-count-corrected (see hlo_analysis)
        "_hlo_text": hlo,  # persisted compressed by the driver, not in JSON
        "params": int(sum(
            x.size for x in jax.tree_util.tree_leaves(pshape))),
    }
    if moe_sorted:
        from repro.models import moe as moe_mod
        moe_mod.apply_moe = moe_mod._apply_moe_orig
    return result


def pairs_to_run():
    out = []
    for arch in configs.list_archs():
        for shape in SHAPES:
            if shape == "long_500k" and arch in configs.LONG_CONTEXT_SKIP:
                continue
            out.append((arch, shape))
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--skip-blocks", action="store_true",
                    help="perf variant: statically skip fully-masked attention blocks")
    ap.add_argument("--moe-sorted", action="store_true",
                    help="perf variant: sort-based MoE dispatch")
    ap.add_argument("--residual", default="d_sharded",
                    choices=["d_sharded", "seq_sharded", "replicated"],
                    help="residual-stream activation layout")
    ap.add_argument("--composition", action="store_true",
                    help="Heroes-factorized parameterisation (P=2, rank=d/4)")
    ap.add_argument("--compose-matmul", action="store_true",
                    help="paper-faithful compose-then-matmul forward")
    ap.add_argument("--attn-qseq", action="store_true",
                    help="context-parallel attention (q-seq over model axis)")
    ap.add_argument("--no-remat", action="store_true",
                    help="disable per-layer activation checkpointing")
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8 KV cache with per-token scales (decode)")
    ap.add_argument("--moe-shardmap", action="store_true",
                    help="weight-stationary expert parallelism via shard_map")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    if args.all:
        pairs = pairs_to_run()
    else:
        assert args.arch and args.shape, "--arch and --shape or --all"
        pairs = [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch, shape in pairs:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
            if args.skip_blocks:
                tag += "__skipblocks"
            if args.moe_sorted:
                tag += "__moesorted"
            if args.residual != "d_sharded":
                tag += f"__{args.residual}"
            if args.composition:
                tag += "__composed" + ("_matmul" if args.compose_matmul else "_ff")
            if args.attn_qseq:
                tag += "__attnqseq"
            if args.no_remat:
                tag += "__noremat"
            if args.kv_int8:
                tag += "__kvint8"
            if args.moe_shardmap:
                tag += "__moeshardmap"
            path = outdir / f"{tag}.json"
            if args.skip_existing and path.exists():
                print(f"[skip] {tag}")
                continue
            print(f"[lower+compile] {tag} ...", flush=True)
            try:
                res = lower_pair(arch, shape, mp, skip_blocks=args.skip_blocks,
                                 moe_sorted=args.moe_sorted,
                                 residual=args.residual,
                                 composition=args.composition,
                                 compose_matmul=args.compose_matmul,
                                 attn_qseq=args.attn_qseq,
                                 no_remat=args.no_remat,
                                 kv_int8=args.kv_int8,
                                 moe_shardmap=args.moe_shardmap)
                hlo_txt = res.pop("_hlo_text", None)
                if hlo_txt is not None:
                    hdir = outdir / "hlo"
                    hdir.mkdir(exist_ok=True)
                    try:
                        import zstandard
                        (hdir / f"{tag}.hlo.zst").write_bytes(
                            zstandard.compress(hlo_txt.encode()))
                    except ImportError:  # optional dep; stdlib fallback
                        import gzip
                        (hdir / f"{tag}.hlo.gz").write_bytes(
                            gzip.compress(hlo_txt.encode()))
                path.write_text(json.dumps(res, indent=1))
                m = res["memory"]
                print(
                    f"  ok: compile {res['compile_s']}s  "
                    f"peak/device {(m['peak_bytes'] or 0)/2**30:.2f} GiB  "
                    f"flops {res['cost']['flops']:.3e}  "
                    f"coll {res['collectives']['total']/2**20:.1f} MiB",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001 — record and continue
                failures.append((tag, repr(e)))
                print(f"  FAIL {tag}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(f"  {t}: {e}")
        return 1
    print("\nAll dry-runs compiled OK.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
