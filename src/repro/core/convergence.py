"""Convergence bound machinery (Heroes, Sec. IV–V.B).

Implements the approximated bound Eq. (23)::

    G(H, tau) = 4 F(x0) / (H eta tau) + L eta tau / 3 * (G^2 + 18 sigma^2)
                + 6 L^2 beta^2

its minimiser over tau (Sec. V-B)::

    tau* = sqrt( 12 F(x0) / (eta^2 H L (G^2 + 18 sigma^2)) )

and the per-client total-completion-time objective Eq. (27)::

    T_n(H) = H * ( tau*(H) * mu_n + nu_n )

The PS uses :func:`solve_rounds` to find the smallest H whose bound reaches
the convergence threshold eps, then :func:`total_time` ranks clients to find
the fastest one (Alg. 1 lines 12–14).
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass
class BoundState:
    """Aggregated estimates driving the bound (PS side, Alg. 1 line 25)."""

    loss0: float  # F(x^h) — current global loss, used as F(x0) in Eq. 27
    smoothness: float  # L
    grad_sq: float  # G^2
    noise_sq: float  # sigma^2
    beta_sq: float = 0.0  # upper bound on coefficient reducing error alpha
    lr: float = 0.01  # eta

    def noise_term(self) -> float:
        return self.grad_sq + 18.0 * self.noise_sq


def bound(state: BoundState, rounds: int, tau: float) -> float:
    """Eq. (23).  Guard against degenerate inputs (early rounds).

    Note: ``tau`` is the *real-valued* theory variable here — integer
    clamping happens only when the scheduler assigns frequencies, otherwise
    the tau >= 1 floor would make the bound non-decreasing in H and
    ``solve_rounds`` could never terminate below h_max.
    """
    h = max(int(rounds), 1)
    t = max(float(tau), 1e-9)
    term1 = 4.0 * state.loss0 / (h * state.lr * t)
    term2 = state.smoothness * state.lr * t / 3.0 * state.noise_term()
    term3 = 6.0 * state.smoothness**2 * state.beta_sq
    return term1 + term2 + term3


def tau_star(state: BoundState, rounds: int) -> float:
    """Convergence-optimal local update frequency (Sec. V-B)."""
    h = max(int(rounds), 1)
    denom = state.lr**2 * h * state.smoothness * state.noise_term()
    if denom <= 0:
        return 1.0
    return math.sqrt(12.0 * state.loss0 / denom)


def solve_rounds(state: BoundState, eps: float, h_max: int = 100_000) -> int:
    """Smallest H with bound(H, tau*(H)) <= eps (bisection; bound is
    monotone decreasing in H at tau*).  Returns h_max if eps is below the
    6 L^2 beta^2 floor."""
    lo, hi = 1, h_max
    if bound(state, hi, tau_star(state, hi)) > eps:
        return h_max
    while lo < hi:
        mid = (lo + hi) // 2
        if bound(state, mid, tau_star(state, mid)) <= eps:
            hi = mid
        else:
            lo = mid + 1
    return lo


def total_time(state: BoundState, rounds: int, mu: float, nu: float) -> float:
    """Eq. (27): projected completion time if this client is the pacesetter."""
    t = tau_star(state, rounds)
    return rounds * (t * mu + nu)
