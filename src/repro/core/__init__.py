"""Heroes core: enhanced neural composition + adaptive local update."""

from repro.core.composition import (  # noqa: F401
    CompositionPlan,
    CompositionSpec,
    apply_factors,
    apply_flops,
    compose,
    compose_flops,
    decompose,
    dense_apply_flops,
    gather_blocks,
    init_factors,
    rank_space_wins,
    select_blocks,
)
from repro.core.aggregation import (  # noqa: F401
    aggregate_basis,
    aggregate_coefficient,
    aggregate_factorized,
    masked_block_mean,
    masked_block_merge,
    ordered_sum,
    scatter_contribution,
    scatter_contributions_host,
)
from repro.core.convergence import BoundState, bound, solve_rounds, tau_star, total_time  # noqa: F401
from repro.core.scheduler import (  # noqa: F401
    ClientAssignment,
    HeroesScheduler,
    RoundPlan,
    SchedulerConfig,
)
