"""Measured one-shot calibration of the rank-path dispatch model.

The ``auto`` forward-impl choice compares FLOPs (``apply_flops`` vs
``compose_flops + dense_apply_flops``), but FLOPs alone mispredict on
op-overhead-bound hosts: the conv rank path splits one conv into a
basis conv plus a contraction, and a CPU pays per-op dispatch the FLOPs
model cannot see.  Earlier revisions hardcoded that as a platform
constant (``conv_rank_overhead() == 3.0`` on CPU), which disabled the
conv rank path everywhere on CPU — including shapes where the fused
formulation actually wins.

This module replaces the constant with a **measured** calibration: once
per process (cached), two micro-benchmarks time the real production
paths at representative engine shapes and convert the ratio into the
two numbers the cost model consumes:

``conv_rank_overhead``
    effective cost multiplier of the fused conv rank path relative to
    its FLOPs count, measured as ``(t_rank / t_mat) / (f_rank /
    f_mat)`` at the square hidden-conv shape.  With this definition the
    dispatch inequality ``overhead * rank_flops < compose + mat_flops``
    reduces to *measured-faster at the calibration shape* and
    extrapolates by FLOPs elsewhere.

``fused_compose_gain``
    ``t_fused / t_separate`` for the fused compose+apply dense kernel
    vs compose-then-matmul at the classifier-head shape; values below
    1.0 let ``auto`` swap materialize-path dense layers to the fused
    primitive (the p-width weight then lives only in registers/VMEM).

Both numbers are overridable through ``FLConfig`` (``conv_rank_overhead``
/ ``fused_compose_gain`` > 0 pin them; see :func:`from_config`) — the
override participates in the client/trainer jit-cache keys, so two
engines with different pins never share stale impl choices.

The measurement costs a few jit compiles (~1-2 s) the first time an
``auto`` dispatch needs it; ``materialize`` / ``rank_space`` runs never
trigger it.  Within a process the cached result keeps every trace's
impl choice stable.
"""

from __future__ import annotations

import dataclasses
import functools
import time

import jax

__all__ = ["RankPathCalibration", "measure", "get_calibration",
           "from_config", "for_dispatch"]


@dataclasses.dataclass(frozen=True)
class RankPathCalibration:
    """The two measured knobs the auto cost model consumes.

    Frozen + hashable on purpose: instances ride in the client/trainer
    jit-cache keys (``client._jitted_fns``, ``trainers._cohort_fns``),
    so a config override can never reuse a cache entry compiled under a
    different calibration.
    """

    conv_rank_overhead: float
    fused_compose_gain: float
    platform: str = "cpu"
    measured: bool = False


# Representative engine shapes: the square hidden conv every image model
# repeats (resnet blocks / cnn conv2), and the grow_in classifier head.
_CONV_SHAPE = dict(p=2, n=16, hw=8, base=8, rank=8, k=3, stride=1)
_DENSE_SHAPE = dict(p=2, m=32, base_in=8, base_out=10, rank=8)

# sanity clips: a wildly skewed measurement (loaded box, timer glitch)
# degrades to a conservative gate instead of poisoning every dispatch
_OVERHEAD_CLIP = (0.25, 32.0)
_GAIN_CLIP = (0.25, 4.0)


def _best_times(fns, args, reps: int = 30, warmup: int = 5) -> list[float]:
    """Min-of-reps wall time per fn, legs interleaved within each rep.

    Min is the least-interference estimate of an op's cost (medians
    drag scheduler noise into the ratio on a shared CI/edge host), and
    interleaving means load drift hits every leg equally instead of
    biasing whichever ran last.
    """
    for fn in fns:
        for _ in range(warmup):
            jax.block_until_ready(fn(*args))
    best = [float("inf")] * len(fns)
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            best[i] = min(best[i], time.perf_counter() - t0)
    return best


def _measure_conv_overhead() -> float:
    from repro.core.composition import (CompositionSpec, apply_factors,
                                        apply_flops, compose, compose_flops,
                                        dense_apply_flops)

    c = _CONV_SHAPE
    p, n, hw, base, rank, k, stride = (c["p"], c["n"], c["hw"], c["base"],
                                       c["rank"], c["k"], c["stride"])
    spec = CompositionSpec(p, rank, base, base, ksq=k * k, mode="square")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(ks[0], (n, hw, hw, p * base))
    v = 0.1 * jax.random.normal(ks[1], spec.basis_shape())
    u = 0.1 * jax.random.normal(ks[2], spec.coefficient_shape())

    rank_fn = jax.jit(lambda x, v, u: apply_factors(
        x, v, u, p, spec, "conv", stride=stride))

    def mat(x, v, u):
        w = compose(v, u, p, spec, backend="einsum")
        w4 = w.reshape(k, k, w.shape[1], w.shape[2])
        return jax.lax.conv_general_dilated(
            x, w4, (stride, stride), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))

    mat_fn = jax.jit(mat)
    t_rank, t_mat = _best_times([rank_fn, mat_fn], (x, v, u))

    apps = n * hw * hw  # stride-1 SAME conv: every pixel is an application
    f_rank = apply_flops(p, spec, applications=apps)
    f_mat = compose_flops(p, spec) + dense_apply_flops(
        p, spec, applications=apps)
    overhead = (t_rank / t_mat) / (f_rank / f_mat)
    return float(min(max(overhead, _OVERHEAD_CLIP[0]), _OVERHEAD_CLIP[1]))


def _measure_fused_compose_gain() -> float:
    from repro.core.composition import CompositionSpec, compose
    from repro.kernels.compose import compose_dense_apply

    d = _DENSE_SHAPE
    p, m, bi, bo, rank = (d["p"], d["m"], d["base_in"], d["base_out"],
                          d["rank"])
    spec = CompositionSpec(p, rank, bi, bo, ksq=1, mode="grow_in")
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    # vmap over a cohort of independent (x, v, u) triples: a single head
    # apply is sub-µs and dispatch jitter swamps it — K clients in one
    # call keep the 1:1 compose:apply ratio while amortising dispatch,
    # matching how the ops actually run (inside one jitted client loss).
    K = 32
    x = jax.random.normal(ks[0], (K, m, p * bi))
    v = 0.1 * jax.random.normal(ks[1], (K,) + spec.basis_shape())
    u = 0.1 * jax.random.normal(ks[2], (K,) + spec.coefficient_shape())

    sep_fn = jax.jit(jax.vmap(
        lambda x, v, u: x @ compose(v, u, p, spec, backend="einsum")[0]))
    fus_fn = jax.jit(jax.vmap(
        lambda x, v, u: compose_dense_apply(x, v, u, p, "grow_in")))
    t_sep, t_fus = _best_times([sep_fn, fus_fn], (x, v, u))
    gain = t_fus / t_sep
    return float(min(max(gain, _GAIN_CLIP[0]), _GAIN_CLIP[1]))


def measure() -> RankPathCalibration:
    """Run both micro-benchmarks (uncached — callers want
    :func:`get_calibration`)."""
    return RankPathCalibration(
        conv_rank_overhead=_measure_conv_overhead(),
        fused_compose_gain=_measure_fused_compose_gain(),
        platform=jax.default_backend(),
        measured=True,
    )


@functools.lru_cache(maxsize=1)
def get_calibration() -> RankPathCalibration:
    """The per-process calibration (measured once, then cached — every
    trace in the process sees the same numbers, keeping the auto impl
    choice jit-cache-stable)."""
    return measure()


def from_config(cfg) -> RankPathCalibration:
    """Resolve a calibration from ``FLConfig`` overrides.

    ``cfg.conv_rank_overhead`` / ``cfg.fused_compose_gain`` pin the
    respective knob when > 0; 0 (the default) means *measure*.  Fully
    pinned configs never trigger the micro-benchmarks.
    """
    ovh = float(getattr(cfg, "conv_rank_overhead", 0.0) or 0.0)
    gain = float(getattr(cfg, "fused_compose_gain", 0.0) or 0.0)
    if ovh > 0.0 and gain > 0.0:
        return RankPathCalibration(ovh, gain, jax.default_backend(),
                                   measured=False)
    base = get_calibration()
    if ovh <= 0.0 and gain <= 0.0:
        return base
    return dataclasses.replace(
        base,
        conv_rank_overhead=ovh if ovh > 0.0 else base.conv_rank_overhead,
        fused_compose_gain=gain if gain > 0.0 else base.fused_compose_gain,
    )


def for_dispatch(cfg):
    """The calibration an engine should thread through, or ``None`` when
    the config's dispatch never consults the cost model (non-``auto``
    ``forward_impl``) — materialize / rank_space runs must not trigger
    the micro-benchmarks."""
    if getattr(cfg, "forward_impl", "auto") != "auto":
        return None
    return from_config(cfg)
