"""Client-side estimators for L, sigma^2, G^2 (Heroes Alg. 2 lines 7-9).

All operate on parameter/gradient pytrees.  The estimators use the
*composed local model* trajectory exactly as in the paper:

  L_n      = ||grad F_n(x_bar) - grad F_n(x_hat)|| / ||x_bar - x_hat||
  sigma^2  = E_xi ||grad F_n(x_hat; xi) - grad F_n(x_hat)||^2
  G^2      = E_xi ||grad F_n(x_hat; xi)||^2

where x_hat is the model before local training and x_bar after.  The PS
aggregates client estimates by simple averaging (Alg. 1 line 25).
"""

from __future__ import annotations

from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

PyTree = Any


def tree_sq_norm(t: PyTree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(t)
    return sum(jnp.vdot(x, x).real for x in leaves)


def tree_norm(t: PyTree) -> jax.Array:
    return jnp.sqrt(tree_sq_norm(t))


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda x, y: x - y, a, b)


def estimate_smoothness(grad_after: PyTree, grad_before: PyTree,
                        params_after: PyTree, params_before: PyTree,
                        eps: float = 1e-12) -> jax.Array:
    """L_n (Alg. 2 line 7)."""
    dg = tree_norm(tree_sub(grad_after, grad_before))
    dx = tree_norm(tree_sub(params_after, params_before))
    return dg / jnp.maximum(dx, eps)


def estimate_noise_sq(stoch_grads: Sequence[PyTree], full_grad: PyTree) -> jax.Array:
    """sigma_n^2 (Alg. 2 line 8): variance of minibatch grads around mean."""
    diffs = [tree_sq_norm(tree_sub(g, full_grad)) for g in stoch_grads]
    return jnp.mean(jnp.stack(diffs))


def estimate_grad_sq(stoch_grads: Sequence[PyTree]) -> jax.Array:
    """G_n^2 (Alg. 2 line 9): second moment of minibatch grads."""
    return jnp.mean(jnp.stack([tree_sq_norm(g) for g in stoch_grads]))


def client_estimates(
    grad_fn: Callable[[PyTree, Any], PyTree],
    params_before: PyTree,
    params_after: PyTree,
    batches: Sequence[Any],
) -> dict:
    """Convenience wrapper producing the (L, sigma^2, G^2) triple.

    ``grad_fn(params, batch)`` returns the gradient pytree.  Full gradient is
    approximated by the mean over ``batches`` (paper uses the same
    minibatch-expectation approximation).
    """
    stoch = [grad_fn(params_before, b) for b in batches]
    full = jax.tree_util.tree_map(lambda *xs: jnp.mean(jnp.stack(xs), 0), *stoch)
    grad_after = grad_fn(params_after, batches[0])
    return {
        "L": estimate_smoothness(grad_after, stoch[0], params_after, params_before),
        "sigma_sq": estimate_noise_sq(stoch, full),
        "grad_sq": estimate_grad_sq(stoch),
    }


def aggregate_estimates(per_client: Sequence[dict]) -> dict:
    """PS aggregation (Alg. 1 line 25): average each scalar over clients."""
    keys = per_client[0].keys()
    return {k: float(jnp.mean(jnp.stack([jnp.asarray(c[k]) for c in per_client])))
            for k in keys}
