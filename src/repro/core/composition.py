"""Enhanced neural composition (Heroes, Sec. II-B / III).

Every layer weight ``w_p`` of width multiplier ``p`` is approximated as the
product of a shared *neural basis* ``v`` and a per-width *coefficient*
``u_p`` (Eq. 4 of the paper)::

    w_p ~= v . u_p       v in R^{k^2 x I x R},  u_p in R^{R x (p * pO)}

The *complete* coefficient ``u in R^{R x (P^2 O)}`` is partitioned into
``P^2`` blocks of shape ``R x O``.  A ``p``-width model takes ``p^2`` blocks
(the *least trained* ones, per the paper's enhancement), composes them with
the basis into an intermediate ``k^2 x I x (p^2 O)`` tensor and reshapes it
to the p-width weight ``k^2 x pI x pO`` (Fig. 1).

We store the complete coefficient as ``(P^2, R, O)`` so blocks are a leading
index — selection is a gather, block-wise aggregation (Eq. 5) is a segment
mean, both shardable.

Design notes
------------
* ``compose`` is a single einsum — on TPU this is an MXU matmul.  The
  Pallas kernel in :mod:`repro.kernels.compose` implements the same
  contraction with explicit VMEM tiling; this module is the reference /
  CPU path and the place where shapes are defined.
* Training operates directly on the factors (gradients flow through
  ``compose``), so no per-round decomposition is needed.  ``decompose``
  (least-squares projection) is provided for parity with the paper's
  materialised formulation and for the HeteroFL-style baselines.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CompositionSpec:
    """Static description of one factorized weight.

    Attributes:
      max_width: ``P`` — the maximum width multiplier.  The complete
        coefficient holds ``P**2`` blocks (``P`` for anchored modes).
      rank: ``R`` — the low-rank dimension shared by basis and coefficient.
      base_in: ``I`` — input channels of the width-1 weight.
      base_out: ``O`` — output channels of the width-1 weight.
      ksq: ``k^2`` — spatial size for convolutions; 1 for dense layers.
      mode: how the weight scales with width p —
        "square"   hidden weight, (pI x pO), p^2 blocks (paper Fig. 1);
        "grow_out" input-anchored (first conv / embedding): (I x pO),
                   p blocks;
        "grow_in"  output-anchored (classifier): (pI x O), p blocks.
        The anchored modes are the Flanc treatment of boundary layers.
    """

    max_width: int
    rank: int
    base_in: int
    base_out: int
    ksq: int = 1
    mode: str = "square"

    @property
    def num_blocks(self) -> int:
        p = self.max_width
        return p * p if self.mode == "square" else p

    def blocks_for_width(self, p: int) -> int:
        if not 1 <= p <= self.max_width:
            raise ValueError(f"width {p} outside [1, {self.max_width}]")
        return p * p if self.mode == "square" else p

    def basis_shape(self) -> Tuple[int, int, int]:
        return (self.ksq, self.base_in, self.rank)

    def coefficient_shape(self) -> Tuple[int, int, int]:
        return (self.num_blocks, self.rank, self.base_out)

    def weight_shape(self, p: int) -> Tuple[int, int, int]:
        pi = p if self.mode in ("square", "grow_in") else 1
        po = p if self.mode in ("square", "grow_out") else 1
        return (self.ksq, pi * self.base_in, po * self.base_out)

    def params_factorized(self, p: int) -> int:
        """Parameter count shipped to a width-``p`` client (basis + blocks)."""
        basis = self.ksq * self.base_in * self.rank
        coeff = self.blocks_for_width(p) * self.rank * self.base_out
        return basis + coeff

    def params_materialized(self, p: int) -> int:
        _, pi, po = self.weight_shape(p)
        return self.ksq * pi * po


def init_factors(
    key: Array, spec: CompositionSpec, dtype: Any = jnp.float32
) -> Tuple[Array, Array]:
    """Initialise (basis, coefficient) so the composed weight has
    fan-in-scaled variance (LeCun-style) at every width.

    var(w) = var(v)*var(u)*R  — we split the target variance evenly between
    the two factors.
    """
    kb, kc = jax.random.split(key)
    fan_in = spec.ksq * spec.base_in
    target_var = 1.0 / float(fan_in)
    # var(v) * var(u) * R = target_var ; choose var(v)=var(u)=sqrt(target/R)
    factor_std = (target_var / spec.rank) ** 0.25
    basis = factor_std * jax.random.normal(kb, spec.basis_shape(), dtype)
    coeff = factor_std * jax.random.normal(kc, spec.coefficient_shape(), dtype)
    return basis, coeff


def select_blocks(counters: Array | np.ndarray, p: int, spec: CompositionSpec) -> np.ndarray:
    """Indices of the ``p^2`` *least trained* blocks (paper Sec. II-B).

    ``counters[i]`` is the total number of local iterations block ``i`` has
    received since round 1.  Ties break on the lower index for determinism.
    Host-side (numpy) — this is PS control logic, not a traced computation.
    """
    c = np.asarray(counters)
    if c.shape != (spec.num_blocks,):
        raise ValueError(f"counters shape {c.shape} != ({spec.num_blocks},)")
    k = spec.blocks_for_width(p)
    # stable argsort => deterministic tie-break on block index
    order = np.argsort(c, kind="stable")
    return np.sort(order[:k])


def gather_blocks(coefficient: Array, block_ids) -> Array:
    """Reduced coefficient ``û``: gather ``(m, R, O)`` from ``(P^2, R, O)``."""
    return jnp.take(coefficient, jnp.asarray(block_ids), axis=0)


def compose(basis: Array, reduced_coeff: Array, p: int, spec: CompositionSpec) -> Array:
    """Compose the p-width weight:  v · û  →  reshape  (Fig. 1).

    Args:
      basis: ``(ksq, I, R)``.
      reduced_coeff: ``(m, R, O)`` — the gathered blocks (m = p^2 for
        "square" mode, p for anchored modes).
      p: target width.

    Returns:
      the ``spec.weight_shape(p)`` weight.  For "square" the intermediate
      ``(ksq, I, p^2·O)`` tensor is viewed as ``(ksq, I, p, p·O)`` and the
      first ``p`` axis merges with ``I`` (the paper's reshape).
    """
    m = spec.blocks_for_width(p)
    if reduced_coeff.shape[0] != m:
        raise ValueError(f"expected {m} blocks, got {reduced_coeff.shape[0]}")
    # (ksq, I, R) x (m, R, O) -> (ksq, I, m, O)
    inter = jnp.einsum("kir,mro->kimo", basis, reduced_coeff)
    ksq, I, _, O = inter.shape
    if spec.mode == "grow_out":
        return inter.reshape(ksq, I, m * O)
    if spec.mode == "grow_in":
        return jnp.transpose(inter, (0, 2, 1, 3)).reshape(ksq, m * I, O)
    # (ksq, I, p, p, O) -> (ksq, p, I, p, O) -> (ksq, pI, pO)
    inter = inter.reshape(ksq, I, p, p, O)
    w = jnp.transpose(inter, (0, 2, 1, 3, 4)).reshape(ksq, p * I, p * O)
    return w


def compose_flops(p: int, spec: CompositionSpec) -> int:
    """MACs*2 for the compose contraction at width p."""
    m = spec.blocks_for_width(p)
    return 2 * spec.ksq * spec.base_in * spec.rank * m * spec.base_out


def decompose(
    weight: Array, basis: Array, p: int, spec: CompositionSpec
) -> Array:
    """Least-squares projection of a materialised p-width weight back onto
    the span of ``basis``:  û* = argmin_û ‖v·û − w‖²  (per ksq slice).

    Used only by parity experiments / materialised baselines — the default
    factorized training path never needs it (paper Alg. 2 line 10 is an
    identity there because the factors *are* the parameters).

    Returns ``(p^2, R, O)`` reduced-coefficient blocks.
    """
    ksq, pI, pO = weight.shape
    I, O = spec.base_in, spec.base_out
    if (pI, pO) != (p * I, p * O):
        raise ValueError("weight shape inconsistent with width/spec")
    # invert the compose reshape: (ksq, p, I, p, O) -> (ksq, I, p*p, O)
    w = weight.reshape(ksq, p, I, p, O).transpose(0, 2, 1, 3, 4)
    w = w.reshape(ksq, I, p * p * O)
    # flatten basis over (ksq, I): A (ksq*I, R), B (ksq*I, m*O)
    A = basis.reshape(ksq * I, spec.rank)
    B = w.reshape(ksq * I, p * p * O)
    sol, *_ = jnp.linalg.lstsq(A, B)
    # (R, p*p*O) -> (p*p, R, O)
    return sol.reshape(spec.rank, p * p, O).transpose(1, 0, 2)


# ---------------------------------------------------------------------------
# Model-level composition plans
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """One factorized weight inside a model: its spec and parameter names."""

    name: str
    spec: CompositionSpec


class CompositionPlan:
    """The set of factorized weights in a model plus shared block counters.

    Heroes tracks one update-times counter vector per factorized weight; all
    weights in a model share the *same* width assignment ``p_n`` per client,
    so we keep a single global counter (the paper's ``c_i``) of size ``P^2``
    and reuse the block indices for every layer.  This matches Fig. 1/3
    where block selection is described once for the whole model.
    """

    def __init__(self, layers: Dict[str, CompositionSpec], max_width: int):
        ps = {s.max_width for s in layers.values()}
        if ps != {max_width}:
            raise ValueError(f"all layer specs must share max_width={max_width}, got {ps}")
        self.layers = dict(layers)
        self.max_width = max_width
        self.num_blocks = max_width * max_width

    def init(self, key: Array, dtype: Any = jnp.float32) -> Dict[str, Dict[str, Array]]:
        params = {}
        keys = jax.random.split(key, len(self.layers))
        for k, (name, spec) in zip(keys, sorted(self.layers.items())):
            v, u = init_factors(k, spec, dtype)
            params[name] = {"basis": v, "coeff": u}
        return params

    def reduce(self, params, block_ids) -> Dict[str, Dict[str, Array]]:
        """Ship-to-client view: full basis + gathered coefficient blocks."""
        out = {}
        for name in self.layers:
            out[name] = {
                "basis": params[name]["basis"],
                "coeff": gather_blocks(params[name]["coeff"], block_ids),
            }
        return out

    def compose_all(self, reduced_params, p: int) -> Dict[str, Array]:
        """Materialise every layer weight at width p from reduced factors."""
        return {
            name: compose(reduced_params[name]["basis"], reduced_params[name]["coeff"], p, spec)
            for name, spec in self.layers.items()
        }

    def traffic_bytes(self, p: int, bytes_per_param: int = 4) -> int:
        """Upload/download payload for a width-p client (basis + blocks)."""
        return bytes_per_param * sum(
            spec.params_factorized(p) for spec in self.layers.values()
        )

    def materialized_bytes(self, p: int, bytes_per_param: int = 4) -> int:
        return bytes_per_param * sum(
            spec.params_materialized(p) for spec in self.layers.values()
        )
