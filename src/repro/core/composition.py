"""Enhanced neural composition (Heroes, Sec. II-B / III).

Every layer weight ``w_p`` of width multiplier ``p`` is approximated as the
product of a shared *neural basis* ``v`` and a per-width *coefficient*
``u_p`` (Eq. 4 of the paper)::

    w_p ~= v . u_p       v in R^{k^2 x I x R},  u_p in R^{R x (p * pO)}

The *complete* coefficient ``u in R^{R x (P^2 O)}`` is partitioned into
``P^2`` blocks of shape ``R x O``.  A ``p``-width model takes ``p^2`` blocks
(the *least trained* ones, per the paper's enhancement), composes them with
the basis into an intermediate ``k^2 x I x (p^2 O)`` tensor and reshapes it
to the p-width weight ``k^2 x pI x pO`` (Fig. 1).

We store the complete coefficient as ``(P^2, R, O)`` so blocks are a leading
index — selection is a gather, block-wise aggregation (Eq. 5) is a segment
mean, both shardable.

Design notes
------------
* ``compose`` is a single einsum — on TPU this is an MXU matmul.  The
  Pallas kernel in :mod:`repro.kernels.compose` implements the same
  contraction with explicit VMEM tiling; this module is the reference /
  CPU path and the place where shapes are defined.
* Training operates directly on the factors (gradients flow through
  ``compose``), so no per-round decomposition is needed.  ``decompose``
  (least-squares projection) is provided for parity with the paper's
  materialised formulation and for the HeteroFL-style baselines.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CompositionSpec:
    """Static description of one factorized weight.

    Attributes:
      max_width: ``P`` — the maximum width multiplier.  The complete
        coefficient holds ``P**2`` blocks (``P`` for anchored modes).
      rank: ``R`` — the low-rank dimension shared by basis and coefficient.
      base_in: ``I`` — input channels of the width-1 weight.
      base_out: ``O`` — output channels of the width-1 weight.
      ksq: ``k^2`` — spatial size for convolutions; 1 for dense layers.
      mode: how the weight scales with width p —
        "square"   hidden weight, (pI x pO), p^2 blocks (paper Fig. 1);
        "grow_out" input-anchored (first conv / embedding): (I x pO),
                   p blocks;
        "grow_in"  output-anchored (classifier): (pI x O), p blocks.
        The anchored modes are the Flanc treatment of boundary layers.
    """

    max_width: int
    rank: int
    base_in: int
    base_out: int
    ksq: int = 1
    mode: str = "square"

    @property
    def num_blocks(self) -> int:
        p = self.max_width
        return p * p if self.mode == "square" else p

    def blocks_for_width(self, p: int) -> int:
        if not 1 <= p <= self.max_width:
            raise ValueError(f"width {p} outside [1, {self.max_width}]")
        return p * p if self.mode == "square" else p

    def basis_shape(self) -> Tuple[int, int, int]:
        return (self.ksq, self.base_in, self.rank)

    def coefficient_shape(self) -> Tuple[int, int, int]:
        return (self.num_blocks, self.rank, self.base_out)

    def weight_shape(self, p: int) -> Tuple[int, int, int]:
        pi = p if self.mode in ("square", "grow_in") else 1
        po = p if self.mode in ("square", "grow_out") else 1
        return (self.ksq, pi * self.base_in, po * self.base_out)

    def params_factorized(self, p: int) -> int:
        """Parameter count shipped to a width-``p`` client (basis + blocks)."""
        basis = self.ksq * self.base_in * self.rank
        coeff = self.blocks_for_width(p) * self.rank * self.base_out
        return basis + coeff

    def params_materialized(self, p: int) -> int:
        _, pi, po = self.weight_shape(p)
        return self.ksq * pi * po


def init_factors(
    key: Array, spec: CompositionSpec, dtype: Any = jnp.float32
) -> Tuple[Array, Array]:
    """Initialise (basis, coefficient) so the composed weight has
    fan-in-scaled variance (LeCun-style) at every width.

    var(w) = var(v)*var(u)*R  — we split the target variance evenly between
    the two factors.
    """
    kb, kc = jax.random.split(key)
    fan_in = spec.ksq * spec.base_in
    target_var = 1.0 / float(fan_in)
    # var(v) * var(u) * R = target_var ; choose var(v)=var(u)=sqrt(target/R)
    factor_std = (target_var / spec.rank) ** 0.25
    basis = factor_std * jax.random.normal(kb, spec.basis_shape(), dtype)
    coeff = factor_std * jax.random.normal(kc, spec.coefficient_shape(), dtype)
    return basis, coeff


def select_blocks(counters: Array | np.ndarray, p: int, spec: CompositionSpec) -> np.ndarray:
    """Indices of the ``p^2`` *least trained* blocks (paper Sec. II-B).

    ``counters[i]`` is the total number of local iterations block ``i`` has
    received since round 1.  Ties break on the lower index for determinism.
    Host-side (numpy) — this is PS control logic, not a traced computation.
    """
    c = np.asarray(counters)
    if c.shape != (spec.num_blocks,):
        raise ValueError(f"counters shape {c.shape} != ({spec.num_blocks},)")
    k = spec.blocks_for_width(p)
    # stable argsort => deterministic tie-break on block index
    order = np.argsort(c, kind="stable")
    return np.sort(order[:k])


def gather_blocks(coefficient: Array, block_ids) -> Array:
    """Reduced coefficient ``û``: gather ``(m, R, O)`` from ``(P^2, R, O)``.

    ``block_ids`` are host-side control indices (PS logic, never traced),
    so they are validated eagerly: ``jnp.take`` clamps out-of-range
    indices silently, which turns an id-bookkeeping bug (e.g. handing an
    anchored ``P``-block layer the shared ``P^2``-counter ids) into a
    wrong-but-plausible gather instead of an error.
    """
    ids = np.asarray(block_ids)
    n = coefficient.shape[0]
    if ids.size and (ids.min() < 0 or ids.max() >= n):
        raise ValueError(
            f"block ids out of range: got ids in [{ids.min()}, {ids.max()}] "
            f"for a coefficient with {n} blocks")
    return jnp.take(coefficient, jnp.asarray(ids), axis=0)


def _pallas_compose_default() -> bool:
    """Route compose through the Pallas kernel only where it compiles
    to the platform's matrix unit; einsum (XLA) everywhere else — the
    CPU einsum is also the bitwise reference path the parity tests and
    seed histories anchor on.  The platform gate is owned by
    :func:`repro.kernels.compose.default_interpret` so the kernel and
    this router can never disagree."""
    from repro.kernels.compose import default_interpret

    return not default_interpret()


def compose(basis: Array, reduced_coeff: Array, p: int, spec: CompositionSpec,
            *, backend: str | None = None) -> Array:
    """Compose the p-width weight:  v · û  →  reshape  (Fig. 1).

    Args:
      basis: ``(ksq, I, R)``.
      reduced_coeff: ``(m, R, O)`` — the gathered blocks (m = p^2 for
        "square" mode, p for anchored modes).
      p: target width.
      backend: ``"einsum"`` (reference), ``"pallas"`` (the
        :mod:`repro.kernels.compose` kernel, interpret-gated per
        platform), or ``None`` — pallas on TPU, einsum elsewhere.

    Returns:
      the ``spec.weight_shape(p)`` weight.  For "square" the intermediate
      ``(ksq, I, p^2·O)`` tensor is viewed as ``(ksq, I, p, p·O)`` and the
      first ``p`` axis merges with ``I`` (the paper's reshape).
    """
    m = spec.blocks_for_width(p)
    if reduced_coeff.shape[0] != m:
        raise ValueError(f"expected {m} blocks, got {reduced_coeff.shape[0]}")
    if backend is None:
        backend = "pallas" if _pallas_compose_default() else "einsum"
    if backend == "pallas":
        from repro.kernels.compose import compose_pallas

        flat = compose_pallas(basis, reduced_coeff)  # (ksq, I, m*O)
        inter = flat.reshape(flat.shape[0], flat.shape[1], m, -1)
    elif backend == "einsum":
        # (ksq, I, R) x (m, R, O) -> (ksq, I, m, O)
        inter = jnp.einsum("kir,mro->kimo", basis, reduced_coeff)
    else:
        raise ValueError(f"unknown compose backend {backend!r}")
    ksq, I, _, O = inter.shape
    if spec.mode == "grow_out":
        return inter.reshape(ksq, I, m * O)
    if spec.mode == "grow_in":
        return jnp.transpose(inter, (0, 2, 1, 3)).reshape(ksq, m * I, O)
    # (ksq, I, p, p, O) -> (ksq, p, I, p, O) -> (ksq, pI, pO)
    inter = inter.reshape(ksq, I, p, p, O)
    w = jnp.transpose(inter, (0, 2, 1, 3, 4)).reshape(ksq, p * I, p * O)
    return w


def compose_flops(p: int, spec: CompositionSpec) -> int:
    """MACs*2 for the compose contraction at width p."""
    m = spec.blocks_for_width(p)
    return 2 * spec.ksq * spec.base_in * spec.rank * m * spec.base_out


# ---------------------------------------------------------------------------
# Rank-space application: y = x · (v·û) computed as (x·v)·û
# ---------------------------------------------------------------------------


def _coeff_blocks(reduced_coeff: Array, p: int, spec: CompositionSpec) -> Array:
    m = spec.blocks_for_width(p)
    if reduced_coeff.shape[-3] != m:
        raise ValueError(f"expected {m} blocks, got {reduced_coeff.shape[-3]}")
    if spec.mode == "square":
        # block a*p+b: a = input-group, b = output-group (the compose
        # reshape in :func:`compose`) -> (p, p, R, O)
        return reduced_coeff.reshape(
            reduced_coeff.shape[:-3] + (p, p) + reduced_coeff.shape[-2:])
    return reduced_coeff


def apply_factors(x: Array, basis: Array, reduced_coeff: Array, p: int,
                  spec: CompositionSpec, mode: str = "dense", *,
                  stride: int = 1, fused: bool = True) -> Array:
    """Apply the factorized weight to ``x`` *without materialising it*.

    Exploits ``w = v·û``: instead of composing the ``(ksq, pI, pO)``
    weight and paying a dense-width contraction, the input is projected
    into rank space through the basis (I → R per input group) and the
    cheap coefficient contraction finishes the job (R → pO).  With
    R below the composed channel widths this cuts the per-application
    FLOPs roughly ``pI/R``-fold — the low-rank trick dense-slice
    width scaling (HeteroFL/AnycostFL) cannot exploit.

    Args:
      x: ``mode="dense"``: ``(..., pI_total)`` row vectors (``pI_total``
        is ``weight_shape(p)[1]``).  ``mode="conv"``: ``(N, H, W, C)``
        NHWC activations with ``C = weight_shape(p)[1]``.
      basis: ``(ksq, I, R)``.
      reduced_coeff: ``(m, R, O)`` gathered blocks.
      p: target width.
      spec: the layer's :class:`CompositionSpec`.
      mode: how the weight is applied — ``"dense"`` (matmul, requires
        ``spec.ksq == 1``) or ``"conv"`` (k×k SAME conv: a basis conv
        I→R per input group followed by a 1×1 coefficient contraction
        R→pO, the paper's block reshape folded into the contraction).
      stride: conv stride (``mode="conv"`` only).
      fused: ``mode="conv"`` only — route through the fused
        :func:`repro.kernels.conv_rank.conv_rank_apply` primitive (one
        kernel/formulation, rank intermediate never in HBM, rank-space
        backward).  ``False`` keeps the unfused separate-ops XLA body
        below, retained as the benchmark/parity reference.

    Returns:
      exactly what ``x @ compose(...)`` / ``conv(x, compose(...))``
      returns, up to float re-association.
    """
    if mode == "dense":
        if spec.ksq != 1:
            raise ValueError("dense apply requires ksq == 1")
        _coeff_blocks(reduced_coeff, p, spec)  # validates the block count
        # the fused custom_vjp primitive: Pallas forward on compiled
        # backends, einsum reference elsewhere; backward stays in rank
        # space either way (kernels/compose.py).
        from repro.kernels.compose import rank_dense_apply

        return rank_dense_apply(x, basis, reduced_coeff, p, spec.mode)
    if mode != "conv":
        raise ValueError(f"unknown apply mode {mode!r}")
    k = int(round(spec.ksq ** 0.5))
    if k * k != spec.ksq:
        raise ValueError(f"conv apply needs square ksq, got {spec.ksq}")
    if fused:
        _coeff_blocks(reduced_coeff, p, spec)  # validates the block count
        from repro.kernels.conv_rank import conv_rank_apply

        return conv_rank_apply(x, basis, reduced_coeff, p, spec.mode,
                               stride=stride)
    # Unfused separate-ops reference: basis conv, then an einsum
    # contraction over the (N, g, Ho, Wo, R) rank intermediate.
    u = _coeff_blocks(reduced_coeff, p, spec)
    vk = basis.reshape(k, k, spec.base_in, spec.rank)
    dn = ("NHWC", "HWIO", "NHWC")
    if spec.mode == "grow_out":
        t = jax.lax.conv_general_dilated(
            x, vk, (stride, stride), "SAME", dimension_numbers=dn)
        y = jnp.einsum("nhwr,bro->nhwbo", t, u)
        return y.reshape(y.shape[:-2] + (p * spec.base_out,))
    # square / grow_in: p input groups share the basis — fold the group
    # axis into the batch so ONE dense conv (N*p, H, W, I) -> R serves
    # every group, then contract groups in rank space.
    N, H, W, _ = x.shape
    xg = x.reshape(N, H, W, p, spec.base_in)
    xg = jnp.transpose(xg, (0, 3, 1, 2, 4)).reshape(N * p, H, W, spec.base_in)
    t = jax.lax.conv_general_dilated(
        xg, vk, (stride, stride), "SAME", dimension_numbers=dn)
    Ho, Wo = t.shape[1], t.shape[2]
    t = t.reshape(N, p, Ho, Wo, spec.rank)
    if spec.mode == "grow_in":
        return jnp.einsum("nahwr,aro->nhwo", t, u)
    y = jnp.einsum("nahwr,abro->nhwbo", t, u)
    return y.reshape(N, Ho, Wo, p * spec.base_out)


def apply_flops(p: int, spec: CompositionSpec, *, applications: int = 1,
                basis_is_gather: bool = False) -> int:
    """MACs*2 of the *rank-space* application per ``applications`` output
    positions (dense row-vectors, or conv output pixels).

    Basis projection: every input group (p for square/grow_in, 1 for
    grow_out) pays ``ksq·I·R``; coefficient contraction: every block
    pays ``R·O``.  ``basis_is_gather`` marks layers whose rank-space
    basis projection is an index lookup rather than a contraction
    (token embeddings gather an R-length basis row per token —
    ``_apply_embed``), costing no MACs: only the R→pO coefficient
    contraction is charged.
    """
    groups = 1 if spec.mode == "grow_out" else p
    basis = 0 if basis_is_gather else (
        spec.ksq * groups * spec.base_in * spec.rank)
    coeff = spec.blocks_for_width(p) * spec.rank * spec.base_out
    return 2 * applications * (basis + coeff)


def dense_apply_flops(p: int, spec: CompositionSpec, *,
                      applications: int = 1) -> int:
    """MACs*2 of applying the *materialised* p-width weight per
    ``applications`` output positions."""
    _, pi, po = spec.weight_shape(p)
    return 2 * applications * spec.ksq * pi * po


def rank_space_wins(p: int, spec: CompositionSpec, *, applications: int,
                    dense_apply_free: bool = False,
                    basis_is_gather: bool = False,
                    overhead: float = 1.0) -> bool:
    """Static FLOPs decision: does rank-space application beat
    materialise-then-apply for one evaluation of the layer?

    ``applications`` is the TOTAL application count per evaluation —
    batch × output positions × any weight *reuse* (a scan-carried RNN
    weight applied T times counts T applications, amortising the one
    compose) — so reuse-heavy layers correctly tilt toward
    materialisation.  ``dense_apply_free`` marks gather-style layers
    (embeddings) whose materialised application costs no FLOPs;
    ``basis_is_gather`` marks the same layers' rank path, whose basis
    projection is also a gather (see :func:`apply_flops`) — for an
    embedding both hold, and the contest reduces to the R→pO
    coefficient contraction per token vs the one-off vocab-sized
    compose, so rank space wins exactly when the token count is below
    the vocabulary size.

    ``overhead`` scales the rank-space side: callers fold in measured
    per-platform costs the FLOPs model cannot see (the conv rank path's
    extra group-batched conv + contraction ops, which dominate on
    op-overhead-bound CPU hosts — see ``conv_rank_overhead``).
    """
    dense = 0 if dense_apply_free else dense_apply_flops(
        p, spec, applications=applications)
    rank = apply_flops(p, spec, applications=applications,
                       basis_is_gather=basis_is_gather)
    return overhead * rank < compose_flops(p, spec) + dense


def conv_rank_overhead(calibration=None) -> float:
    """Effective cost multiplier of the conv rank path on this host.

    Formerly a hardcoded platform constant (3.0 on CPU — calibrated
    against the *unfused* separate-ops rank path, which disabled the
    conv rank path everywhere on CPU including shapes where it wins).
    Now the fused :mod:`repro.kernels.conv_rank` primitive is measured
    directly: the value comes from the per-process micro-calibration in
    :mod:`repro.core.calibration` (or an ``FLConfig`` override threaded
    through as ``calibration``), so ``auto`` enables the conv rank path
    exactly where this host's measurement says it is faster,
    extrapolated by FLOPs elsewhere.
    """
    if calibration is not None:
        return float(calibration.conv_rank_overhead)
    from repro.core.calibration import get_calibration

    return float(get_calibration().conv_rank_overhead)


def decompose(
    weight: Array, basis: Array, p: int, spec: CompositionSpec
) -> Array:
    """Least-squares projection of a materialised p-width weight back onto
    the span of ``basis``:  û* = argmin_û ‖v·û − w‖²  (per ksq slice).

    Used only by parity experiments / materialised baselines — the default
    factorized training path never needs it (paper Alg. 2 line 10 is an
    identity there because the factors *are* the parameters).

    Returns ``(p^2, R, O)`` reduced-coefficient blocks.
    """
    ksq, pI, pO = weight.shape
    I, O = spec.base_in, spec.base_out
    if (pI, pO) != (p * I, p * O):
        raise ValueError("weight shape inconsistent with width/spec")
    # invert the compose reshape: (ksq, p, I, p, O) -> (ksq, I, p*p, O)
    w = weight.reshape(ksq, p, I, p, O).transpose(0, 2, 1, 3, 4)
    w = w.reshape(ksq, I, p * p * O)
    # flatten basis over (ksq, I): A (ksq*I, R), B (ksq*I, m*O)
    A = basis.reshape(ksq * I, spec.rank)
    B = w.reshape(ksq * I, p * p * O)
    sol, *_ = jnp.linalg.lstsq(A, B)
    # (R, p*p*O) -> (p*p, R, O)
    return sol.reshape(spec.rank, p * p, O).transpose(1, 0, 2)


# ---------------------------------------------------------------------------
# Model-level composition plans
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """One factorized weight inside a model: its spec and parameter names."""

    name: str
    spec: CompositionSpec


class CompositionPlan:
    """The set of factorized weights in a model plus shared block counters.

    Heroes tracks one update-times counter vector per factorized weight; all
    weights in a model share the *same* width assignment ``p_n`` per client,
    so we keep a single global counter (the paper's ``c_i``) of size ``P^2``
    and reuse the block indices for every layer.  This matches Fig. 1/3
    where block selection is described once for the whole model.
    """

    def __init__(self, layers: Dict[str, CompositionSpec], max_width: int):
        ps = {s.max_width for s in layers.values()}
        if ps != {max_width}:
            raise ValueError(f"all layer specs must share max_width={max_width}, got {ps}")
        self.layers = dict(layers)
        self.max_width = max_width
        self.num_blocks = max_width * max_width

    def init(self, key: Array, dtype: Any = jnp.float32) -> Dict[str, Dict[str, Array]]:
        params = {}
        keys = jax.random.split(key, len(self.layers))
        for k, (name, spec) in zip(keys, sorted(self.layers.items())):
            v, u = init_factors(k, spec, dtype)
            params[name] = {"basis": v, "coeff": u}
        return params

    def reduce(self, params, block_ids) -> Dict[str, Dict[str, Array]]:
        """Ship-to-client view: full basis + gathered coefficient blocks.

        ``block_ids`` come from the shared ``P^2`` counter, so they are
        only valid for "square" layers; anchored-mode layers hold ``P``
        blocks and need their own id set.  Ids are validated against
        each layer's ``spec.num_blocks`` — ``jnp.take`` would otherwise
        clamp out-of-range ids silently and gather the wrong block.
        """
        ids = np.asarray(block_ids)
        out = {}
        for name, spec in self.layers.items():
            if ids.size and (ids.min() < 0 or ids.max() >= spec.num_blocks):
                raise ValueError(
                    f"layer {name!r} ({spec.mode}) has {spec.num_blocks} "
                    f"blocks but got ids in [{ids.min()}, {ids.max()}] — "
                    "anchored layers need their own id set, not the "
                    "shared P^2-counter ids")
            out[name] = {
                "basis": params[name]["basis"],
                "coeff": gather_blocks(params[name]["coeff"], ids),
            }
        return out

    def compose_all(self, reduced_params, p: int) -> Dict[str, Array]:
        """Materialise every layer weight at width p from reduced factors."""
        return {
            name: compose(reduced_params[name]["basis"], reduced_params[name]["coeff"], p, spec)
            for name, spec in self.layers.items()
        }

    def traffic_bytes(self, p: int, bytes_per_param: int = 4) -> int:
        """Upload/download payload for a width-p client (basis + blocks)."""
        return bytes_per_param * sum(
            spec.params_factorized(p) for spec in self.layers.values()
        )

    def materialized_bytes(self, p: int, bytes_per_param: int = 4) -> int:
        return bytes_per_param * sum(
            spec.params_materialized(p) for spec in self.layers.values()
        )
