"""Greedy tensor + local-update-frequency assignment (Heroes Alg. 1).

The PS-side control loop of a round:

1. Width assignment (lines 6-11): greedily grow each client's width ``p``
   while one-iteration time stays under ``mu_max``.
2. Pacesetter selection (lines 12-14): for every client, solve the
   univariate problem Eq. (26)/(27) — smallest H meeting the convergence
   threshold, then projected total time; pick the minimiser l.
3. Frequency assignment (lines 15-19): tau_l = tau*(H); every other client
   searches tau in the window [tau_a, tau_b] given by the waiting-time
   bound rho (Eq. 24) to minimise the block-counter variance V^h (Eq. 21).
4. Block selection (line 20): the (p_n)^2 least-trained blocks.

This module is pure control logic on host scalars/numpy — it consumes the
heterogeneity model's (mu, nu) estimates and the aggregated bound state,
and emits per-client assignments.  No jax tracing here.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence

import numpy as np

from repro.core import convergence
from repro.core.composition import CompositionSpec, select_blocks


@dataclasses.dataclass
class ClientAssignment:
    client: int
    width: int
    tau: int
    block_ids: np.ndarray
    est_iter_time: float  # mu_n^h
    est_comm_time: float  # nu_n^h

    @property
    def est_completion(self) -> float:
        return self.tau * self.est_iter_time + self.est_comm_time


@dataclasses.dataclass
class RoundPlan:
    assignments: Dict[int, ClientAssignment]
    pacesetter: int
    rounds_to_go: int
    makespan: float  # T^h (Eq. 19) as estimated

    def avg_waiting(self) -> float:
        """Estimated W^h (Eq. 20)."""
        t = [a.est_completion for a in self.assignments.values()]
        return float(np.mean([self.makespan - x for x in t]))


@dataclasses.dataclass
class SchedulerConfig:
    mu_max: float  # max time budget for one local iteration (width growth stop)
    rho: float  # waiting-time bound (Eq. 24)
    eps: float = 0.05  # convergence threshold on the bound
    tau_min: int = 1
    tau_max: int = 200
    h_max: int = 100_000


class HeroesScheduler:
    """Stateful PS scheduler: owns the block counters ``c_i``."""

    def __init__(
        self,
        spec: CompositionSpec,
        config: SchedulerConfig,
        iter_time_fn: Callable[[int, int], float],
        comm_time_fn: Callable[[int, int], float],
    ):
        """
        Args:
          spec: composition spec (global counter size = spec.num_blocks).
          iter_time_fn(client, width) -> mu_n^h   (seconds / local iteration)
          comm_time_fn(client, width) -> nu_n^h   (upload seconds)
        """
        self.spec = spec
        self.config = config
        self.iter_time = iter_time_fn
        self.comm_time = comm_time_fn
        self.counters = np.zeros(spec.num_blocks, dtype=np.int64)

    # -- Alg.1 lines 6-11 ---------------------------------------------------
    def assign_width(self, client: int) -> int:
        p = 1
        while p < self.spec.max_width:
            if self.iter_time(client, p + 1) >= self.config.mu_max:
                break
            p += 1
        return p

    # -- Alg.1 lines 12-14 --------------------------------------------------
    def _pacesetter(
        self, clients: Sequence[int], widths: Dict[int, int], state: convergence.BoundState
    ) -> tuple[int, int, int]:
        """Returns (pacesetter, H, tau_l)."""
        rounds = convergence.solve_rounds(state, self.config.eps, self.config.h_max)
        best, best_T = None, float("inf")
        for n in clients:
            mu = self.iter_time(n, widths[n])
            nu = self.comm_time(n, widths[n])
            T = convergence.total_time(state, rounds, mu, nu)
            if T < best_T:
                best, best_T = n, T
        tau_l = int(np.clip(round(convergence.tau_star(state, rounds)),
                            self.config.tau_min, self.config.tau_max))
        return best, rounds, tau_l

    # -- Alg.1 lines 15-19 --------------------------------------------------
    def _tau_window(self, makespan: float, mu: float, nu: float) -> tuple[int, int]:
        """Eq. (24): 0 <= T_l - (tau mu + nu) <= rho."""
        hi = int(np.floor((makespan - nu) / max(mu, 1e-9)))
        lo = int(np.ceil((makespan - self.config.rho - nu) / max(mu, 1e-9)))
        lo = max(lo, self.config.tau_min)
        hi = max(min(hi, self.config.tau_max), lo)
        return lo, hi

    def _variance_minimising_tau(
        self, counters: np.ndarray, block_ids: np.ndarray, lo: int, hi: int
    ) -> int:
        """Search tau in [lo, hi] minimising Var(c + tau * 1_blocks) (Eq. 21)."""
        best_tau, best_var = lo, float("inf")
        base = counters.astype(np.float64)
        mask = np.zeros_like(base)
        mask[block_ids] = 1.0
        for tau in range(lo, hi + 1):
            c = base + tau * mask
            var = float(np.var(c))
            if var < best_var:
                best_var, best_tau = var, tau
        return best_tau

    # -- full round ----------------------------------------------------------
    def plan_round(
        self,
        clients: Sequence[int],
        state: convergence.BoundState,
        widths: Optional[Dict[int, int]] = None,
    ) -> RoundPlan:
        if widths is None:
            widths = {n: self.assign_width(n) for n in clients}
        pacesetter, rounds, tau_l = self._pacesetter(clients, widths, state)

        assignments: Dict[int, ClientAssignment] = {}
        # pacesetter first — its completion time anchors everyone else
        mu_l = self.iter_time(pacesetter, widths[pacesetter])
        nu_l = self.comm_time(pacesetter, widths[pacesetter])
        makespan = tau_l * mu_l + nu_l

        # temp counter copy: assignments in this round feed later clients'
        # variance search (Alg.1 line 22 updates c_i inside the loop)
        counters = self.counters.copy()

        ids_l = select_blocks(counters, widths[pacesetter], self.spec)
        counters[ids_l] += tau_l
        assignments[pacesetter] = ClientAssignment(
            pacesetter, widths[pacesetter], tau_l, ids_l, mu_l, nu_l
        )

        for n in clients:
            if n == pacesetter:
                continue
            mu, nu = self.iter_time(n, widths[n]), self.comm_time(n, widths[n])
            lo, hi = self._tau_window(makespan, mu, nu)
            ids = select_blocks(counters, widths[n], self.spec)
            tau = self._variance_minimising_tau(counters, ids, lo, hi)
            counters[ids] += tau
            assignments[n] = ClientAssignment(n, widths[n], tau, ids, mu, nu)

        self.counters = counters
        # Eq. (19): the round is paced by the slowest client.  The
        # pacesetter anchors the tau windows, but a wide/slow client can
        # exceed its anchor even at tau=1 — the true makespan is the max.
        makespan = max(a.est_completion for a in assignments.values())
        return RoundPlan(assignments, pacesetter, rounds, makespan)

    # -- bookkeeping -----------------------------------------------------------
    def counter_variance(self) -> float:
        """V^h (Eq. 21) over the live counters."""
        return float(np.var(self.counters.astype(np.float64)))
