"""Global aggregation (Heroes, Sec. III phase 3).

* Neural basis: plain average over the K participating clients.
* Coefficient: *block-wise* aggregation (Eq. 5) — block ``i`` is averaged
  over exactly the clients that trained it this round; blocks nobody
  trained keep their previous value.

Two implementations:

``aggregate_*``           — host-driven, list-of-client-pytrees (FL runtime).
``masked_block_mean``     — collective form: every client contributes a
                            dense ``(P^2, R, O)`` tensor with zeros at
                            untrained blocks plus a 0/1 mask; aggregation is
                            ``psum(contrib)/psum(mask)``.  This is the
                            mesh-native formulation used by the distributed
                            launcher (identical math, shardable on the data
                            axis).
``masked_block_merge``    — stacked form of the same rule: contributions
                            laid out on a leading client axis, accumulated
                            with a fixed left-to-right ``ordered_sum`` so a
                            single compiled call reproduces the host scatter
                            loop *bitwise*, optionally followed by a
                            ``psum`` when the client axis is sharded over a
                            device mesh (``axis_name``).

Bitwise contract: floating-point addition is not associative, so any
reduction that wants to reproduce the host loop exactly must add client
contributions in the same order the host loop did.  ``ordered_sum`` is
that reduction (a ``lax.scan`` fold — XLA's ``reduce`` is free to
re-associate and measurably does on CPU); zero-padded rows are exact
no-ops under IEEE addition, which is what makes the dense zero-padded
contribution form equivalent to the sparse scatter form.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def ordered_sum(stacked: Array) -> Array:
    """Sum over the leading axis with fixed left-to-right association.

    Bitwise-identical to the eager loop ``acc = acc + stacked[k]`` (and,
    with zero-padded contributions, to ``acc.at[ids].add(blocks)`` host
    scatters in the same client order) — unlike ``jnp.sum``, whose
    reduce order XLA may re-associate.
    """
    init = jnp.zeros_like(stacked[0])
    return jax.lax.scan(lambda acc, x: (acc + x, None), init, stacked)[0]


def aggregate_basis(
    client_bases: Sequence[Array],
    weights: Optional[Sequence[float]] = None,
    prev: Optional[Array] = None,
) -> Array:
    """v^{h+1} = (1/K) sum_n v̄_n^h.

    With ``weights`` (semi-async staleness discount), each client's basis
    is first blended toward ``prev`` (the current global basis) as
    ``w * v̄_n + (1 - w) * prev`` — all-ones weights reduce to the plain
    mean bitwise.
    """
    if weights is None:
        return jnp.mean(jnp.stack(client_bases, axis=0), axis=0)
    if prev is None:
        raise ValueError("weighted aggregation needs the previous basis")
    blended = [w * b + (1.0 - w) * prev for b, w in zip(client_bases, weights)]
    return jnp.mean(jnp.stack(blended, axis=0), axis=0)


def aggregate_coefficient(
    global_coeff: Array,
    client_blocks: Sequence[Array],
    client_block_ids: Sequence[np.ndarray],
    weights: Optional[Sequence[float]] = None,
) -> Array:
    """Block-wise aggregation, Eq. (5).

    Args:
      global_coeff: previous round's complete coefficient ``(P^2, R, O)``.
      client_blocks: per client, updated reduced coefficient ``(m_n, R, O)``.
      client_block_ids: per client, the block indices (length ``m_n``)
        those rows correspond to.
      weights: optional per-client staleness weights in [0, 1]; a client's
        blocks are blended toward the current global blocks as
        ``w * blocks + (1 - w) * global[ids]`` before the block mean.

    Returns:
      New complete coefficient in ``global_coeff.dtype`` (the per-block
      counters are kept in float32 — exact for any realistic cohort — and
      cast to the coefficient dtype only for the division, so bf16/f16
      coefficients are not silently upcast); untrained blocks unchanged.
    """
    num_blocks = global_coeff.shape[0]
    acc = jnp.zeros_like(global_coeff)
    cnt = jnp.zeros((num_blocks,), dtype=jnp.float32)
    if weights is None:
        weights = [None] * len(client_blocks)
    for blocks, ids, w in zip(client_blocks, client_block_ids, weights):
        ids = jnp.asarray(np.asarray(ids))
        blocks = blocks.astype(acc.dtype)
        if w is not None:
            blocks = w * blocks + (1.0 - w) * global_coeff[ids]
        acc = acc.at[ids].add(blocks)
        cnt = cnt.at[ids].add(1.0)
    trained = cnt > 0
    denom = jnp.where(trained, cnt, 1.0)[:, None, None].astype(acc.dtype)
    mean = acc / denom
    return jnp.where(trained[:, None, None], mean, global_coeff)


def aggregate_factorized(
    global_params: Dict[str, Dict[str, Array]],
    client_params: Sequence[Dict[str, Dict[str, Array]]],
    client_block_ids: Sequence[np.ndarray],
) -> Dict[str, Dict[str, Array]]:
    """Aggregate a whole CompositionPlan param tree (basis + coeff per layer)."""
    out: Dict[str, Dict[str, Array]] = {}
    for name, gp in global_params.items():
        out[name] = {
            "basis": aggregate_basis([cp[name]["basis"] for cp in client_params]),
            "coeff": aggregate_coefficient(
                gp["coeff"],
                [cp[name]["coeff"] for cp in client_params],
                client_block_ids,
            ),
        }
    return out


# ---------------------------------------------------------------------------
# Mesh-native (collective) formulation
# ---------------------------------------------------------------------------


def scatter_contribution(
    updated_blocks: Array, block_ids: Array, num_blocks: int
) -> tuple[Array, Array]:
    """Client-side: dense zero-padded contribution + mask for masked psum.

    ``block_ids`` with duplicates contribute additively (matching the
    host path's ``at[ids].add``): the dense row receives the sum of the
    duplicate rows and the mask counts each occurrence.
    """
    r, o = updated_blocks.shape[-2:]
    dense = jnp.zeros((num_blocks, r, o), updated_blocks.dtype).at[block_ids].add(
        updated_blocks
    )
    mask = jnp.zeros((num_blocks,), jnp.float32).at[block_ids].add(1.0)
    return dense, mask


@functools.partial(jax.jit, static_argnames="num_blocks")
def _scatter_contributions_device(
    blocks: Array, block_ids: Array, num_blocks: int
) -> Tuple[Array, Array]:
    """Compiled stacked form of :func:`scatter_contribution`: blocks
    ``(K, m, R, O)`` + ids ``(K, m)`` -> dense ``(K, num_blocks, R, O)``
    + mask ``(K, num_blocks)``, vmapped over the client axis."""
    return jax.vmap(
        lambda b, i: scatter_contribution(b, i, num_blocks))(blocks, block_ids)


def scatter_contributions_host(
    client_blocks,
    client_block_ids,
    num_blocks: int,
    dtype=None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Stack per-client dense contributions + masks on the host.

    One numpy pass instead of ``2K`` eager device scatters; the result is
    shipped to the device once and merged in a single compiled call.
    Duplicate ids within a client accumulate (``np.add.at``), matching
    the host scatter loop.

    From-device path: when ``client_blocks`` is a stacked ``jax.Array``
    (``(K, m, R, O)``, with ``client_block_ids`` ``(K, m)``) the scatter
    runs as one compiled vmapped call and the dense contributions stay
    device-resident — the path the mesh-sharded cohort trainer uses to
    hand results to the collective merge without a host round-trip.
    ``dtype`` is ignored there (contributions keep the blocks' dtype).
    """
    if isinstance(client_blocks, jax.Array):
        return _scatter_contributions_device(
            client_blocks, jnp.asarray(client_block_ids), num_blocks)
    k = len(client_blocks)
    first = np.asarray(client_blocks[0])
    r, o = first.shape[-2:]
    dense = np.zeros((k, num_blocks, r, o),
                     dtype or first.dtype)
    mask = np.zeros((k, num_blocks), np.float32)
    for j, (blocks, ids) in enumerate(zip(client_blocks, client_block_ids)):
        ids = np.asarray(ids)
        np.add.at(dense[j], ids, np.asarray(blocks, dtype=dense.dtype))
        np.add.at(mask[j], ids, 1.0)
    return dense, mask


def masked_block_mean(
    dense_contrib: Array, mask: Array, prev_coeff: Array, axis_name: str
) -> Array:
    """Collective Eq. (5): psum dense contributions / psum masks.

    Runs inside ``shard_map`` with clients laid out on ``axis_name``.
    """
    total = jax.lax.psum(dense_contrib, axis_name)
    count = jax.lax.psum(mask, axis_name)
    trained = count > 0
    denom = jnp.where(trained, count, 1.0)[:, None, None].astype(total.dtype)
    return jnp.where(trained[:, None, None], total / denom, prev_coeff)


def masked_block_merge(
    dense_stack: Array, mask_stack: Array, prev_coeff: Array,
    axis_name: Optional[str] = None,
) -> Array:
    """Eq. (5) over a stacked client axis: ordered local fold, then psum.

    ``dense_stack``/``mask_stack`` carry the (local shard of the) client
    axis in front.  Without ``axis_name`` this is the single-device form
    and reproduces :func:`aggregate_coefficient` with ``weights=None``
    *bitwise* (same left-to-right addition order; zero-padded rows are
    exact no-ops).  With ``axis_name`` the local partial sums are
    combined with ``psum`` — clients sharded over a mesh axis — which
    re-associates across devices (parity to float tolerance).

    Returns the merged coefficient in ``prev_coeff.dtype``.
    """
    total = ordered_sum(dense_stack)
    count = ordered_sum(mask_stack)
    if axis_name is not None:
        total = jax.lax.psum(total, axis_name)
        count = jax.lax.psum(count, axis_name)
    trained = count > 0
    denom = jnp.where(trained, count, 1.0)[:, None, None].astype(total.dtype)
    mean = total / denom
    return jnp.where(trained[:, None, None], mean, prev_coeff)
