"""olmoe-1b-7b [moe] — arXiv:2409.02060.

16L, d_model=2048, 16 heads (kv=16), expert d_ff=1024, vocab=50304,
64 experts top-8 (all layers MoE, no shared expert).
"""

from repro.configs.base import ModelConfig, MoEConfig

ARCH_ID = "olmoe-1b-7b"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="moe",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1024,
        vocab=50304,
        activation="swiglu",
        norm="rmsnorm",
        max_seq=4096,
        moe=MoEConfig(num_experts=64, top_k=8, d_expert=1024),
    )


def smoke() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, d_ff=64,
        vocab=512, max_seq=128, q_chunk=32, kv_chunk=32, remat=False,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64),
    )
