"""granite-34b [dense, code] — arXiv:2405.04324.

88L, d_model=6144, 48 heads, MQA (kv=1), d_ff=24576, vocab=49152.
"""

from repro.configs.base import ModelConfig

ARCH_ID = "granite-34b"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="dense",
        num_layers=88,
        d_model=6144,
        num_heads=48,
        num_kv_heads=1,
        head_dim=128,
        d_ff=24576,
        vocab=49152,
        activation="gelu",
        norm="layernorm",
        max_seq=8192,
    )


def smoke() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=1, head_dim=32,
        d_ff=512, vocab=512, max_seq=128, q_chunk=32, kv_chunk=32, remat=False,
    )
