"""Assigned-architecture registry (public-literature pool, see DESIGN.md §5)."""

from __future__ import annotations

from typing import Dict, List

from repro.configs import (
    deepseek_coder_33b,
    gemma_2b,
    granite_34b,
    kimi_k2_1t_a32b,
    olmoe_1b_7b,
    qwen2_vl_7b,
    seamless_m4t_medium,
    stablelm_3b,
    xlstm_125m,
    zamba2_2_7b,
)
from repro.configs.base import ModelConfig
from repro.configs.shapes import SHAPES, InputShape

_MODULES = [
    deepseek_coder_33b,
    olmoe_1b_7b,
    qwen2_vl_7b,
    seamless_m4t_medium,
    gemma_2b,
    stablelm_3b,
    zamba2_2_7b,
    xlstm_125m,
    kimi_k2_1t_a32b,
    granite_34b,
]

ARCHS: Dict[str, object] = {m.ARCH_ID: m for m in _MODULES}


def list_archs() -> List[str]:
    return list(ARCHS.keys())


def get_config(arch_id: str) -> ModelConfig:
    return ARCHS[arch_id].config()


def get_smoke(arch_id: str) -> ModelConfig:
    return ARCHS[arch_id].smoke()


# archs whose attention is full/quadratic: long_500k runs via a
# sliding-window variant (DESIGN.md §5); seamless skips long_500k entirely.
FULL_ATTENTION_ARCHS = {
    "deepseek-coder-33b", "olmoe-1b-7b", "qwen2-vl-7b", "gemma-2b",
    "stablelm-3b", "kimi-k2-1t-a32b", "granite-34b",
}
LONG_CONTEXT_SKIP = {"seamless-m4t-medium"}
LONG_CONTEXT_WINDOW = 4096


def config_for_shape(arch_id: str, shape_name: str) -> ModelConfig:
    """Resolve the config actually lowered for (arch, shape) — applies the
    sliding-window variant for full-attention archs on long_500k."""
    cfg = get_config(arch_id)
    if shape_name == "long_500k":
        if arch_id in LONG_CONTEXT_SKIP:
            raise ValueError(f"{arch_id} skips long_500k (DESIGN.md §5)")
        if arch_id in FULL_ATTENTION_ARCHS:
            cfg = cfg.replace(sliding_window=LONG_CONTEXT_WINDOW)
    return cfg
