"""stablelm-3b [dense] — hf:stabilityai/stablelm-2-1_6b family.

32L, d_model=2560, 32 heads (kv=32), d_ff=6912, vocab=50304.
LayerNorm + SwiGLU (stablelm-2 uses partial rotary 25%; we apply full
rotary — noted as an approximation in DESIGN.md).
"""

from repro.configs.base import ModelConfig

ARCH_ID = "stablelm-3b"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="dense",
        num_layers=32,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        d_ff=6912,
        vocab=50304,
        activation="swiglu",
        norm="layernorm",
        max_seq=4096,
    )


def smoke() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=8,
        d_ff=512, vocab=512, max_seq=128, q_chunk=32, kv_chunk=32, remat=False,
    )
