"""gemma-2b [dense] — arXiv:2403.08295.

18L, d_model=2048, 8 heads, MQA (kv=1), GeGLU d_ff=16384, head_dim=256,
vocab=256000, tied embeddings, embeddings scaled by sqrt(d).
"""

from repro.configs.base import ModelConfig

ARCH_ID = "gemma-2b"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="dense",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab=256000,
        activation="geglu",
        norm="rmsnorm",
        tie_embeddings=True,
        max_seq=8192,
    )


def smoke() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=1, head_dim=64,
        d_ff=512, vocab=512, max_seq=128, q_chunk=32, kv_chunk=32, remat=False,
    )
