"""Unified model configuration covering all assigned architecture families.

One dataclass describes dense / MoE / SSM / hybrid / enc-dec / VLM / audio
backbones; family-specific sections are optional sub-configs.  Every
assigned architecture in ``src/repro/configs/<id>.py`` instantiates this
with the exact numbers from the assignment table and also provides a
``smoke()`` reduced variant (<=2 layers, d_model<=512, <=4 experts).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int  # per-expert FFN hidden size
    capacity_factor: float = 1.25
    num_shared_experts: int = 0  # always-on shared expert(s) (kimi-style)
    first_k_dense: int = 0  # leading dense layers before MoE starts
    router_aux_weight: float = 0.01  # load-balance loss weight


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64  # N — per-channel state size (Mamba2)
    head_dim: int = 64  # P — channels per SSM head
    expand: int = 2  # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 256  # SSD chunk length


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 4  # every k-th block is sLSTM, rest mLSTM
    qk_dim_factor: float = 0.5
    v_dim_factor: float = 1.0
    proj_factor: float = 1.3334  # sLSTM post-MLP expansion


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: blocks of SSM layers with a shared attention block."""

    attn_every: int = 6  # one shared attn+MLP block per this many SSM layers
    shared_d_ff: int = 0  # hidden of the shared block's MLP (0 => 4*d_model)


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    num_encoder_layers: int = 12
    encoder_seq: int = 4096  # max encoder memory length (frames)
    encoder_d_ff: int = 0  # 0 => same as decoder d_ff


@dataclasses.dataclass(frozen=True)
class CompositionConfig:
    """Heroes neural-composition settings for factorized training."""

    enabled: bool = False
    max_width: int = 2  # P — full model corresponds to width P
    rank: int = 0  # R; 0 => d_model // 4
    width: int = 0  # active width p for this instantiation; 0 => max_width
    factorized_forward: bool = True  # x@v@u (ours) vs compose-then-matmul (paper)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str  # dense | moe | vlm | audio | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 => d_model // num_heads
    activation: str = "swiglu"  # swiglu | geglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    rope_type: str = "rope"  # rope | mrope | none
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w for qwen2-vl
    max_seq: int = 8192
    sliding_window: int = 0  # 0 => full attention; >0 => SWA window
    tie_embeddings: bool = False
    parallel_block: bool = False  # stablelm/gpt-neox parallel attn+FFN
    logit_softcap: float = 0.0
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True  # checkpoint each layer in the scan
    # attention chunking (flash-style streaming softmax in pure JAX)
    q_chunk: int = 2048
    kv_chunk: int = 1024
    # KV-cache storage dtype for decode: "compute" (= compute_dtype) or
    # "int8" (per-token-per-head scales; §Perf memory-term iteration)
    kv_cache_quant: str = "compute"
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    composition: CompositionConfig = dataclasses.field(default_factory=CompositionConfig)
    # frontend stub: 'none' | 'vision' | 'audio' — input is embeddings
    frontend: str = "none"

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        assert self.num_heads % max(self.num_kv_heads, 1) == 0
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def comp_rank(self) -> int:
        c = self.composition
        return c.rank or max(self.d_model // 4, 8)

    @property
    def comp_width(self) -> int:
        c = self.composition
        return c.width or c.max_width

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # rough parameter count (for roofline MODEL_FLOPS = 6 N D)
    def param_count(self, active_only: bool = False) -> int:
        d, f, L = self.d_model, self.d_ff, self.num_layers
        hd = self.resolved_head_dim
        attn = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd + self.num_heads * hd * d
        if self.family in ("ssm",):
            attn = 0
        n_glu = 3 if self.activation in ("swiglu", "geglu") else 2
        if self.moe is not None:
            e = self.moe.top_k if active_only else self.moe.num_experts
            dense_layers = self.moe.first_k_dense
            moe_layers = L - dense_layers
            ffn = moe_layers * (n_glu * d * self.moe.d_expert * (e + self.moe.num_shared_experts)
                                + d * self.moe.num_experts)
            ffn += dense_layers * n_glu * d * f
            per_layer = attn
            total = L * per_layer + ffn
        elif self.family == "ssm":
            x = self.xlstm or XLSTMConfig()
            dqk = int(d * x.qk_dim_factor)
            per_layer = d * (2 * dqk + 2 * d) + 2 * d * d  # rough mLSTM proj
            total = L * per_layer
        elif self.family == "hybrid":
            s = self.ssm or SSMConfig()
            d_in = s.expand * d
            per_layer = 2 * d * d_in + d_in * d  # in/out proj (rough)
            hb = self.hybrid or HybridConfig()
            shared = attn + n_glu * d * (hb.shared_d_ff or 4 * d)
            total = L * per_layer + shared
        else:
            per_layer = attn + n_glu * d * f
            total = L * per_layer
        if self.encdec is not None:
            enc_f = self.encdec.encoder_d_ff or f
            enc_layer = attn + n_glu * d * enc_f
            cross = attn  # cross-attention per decoder layer
            total += self.encdec.num_encoder_layers * enc_layer + L * cross
        total += self.vocab * d * (1 if self.tie_embeddings else 2)
        return int(total)
