"""seamless-m4t-medium [audio, enc-dec] — arXiv:2308.11596.

12 decoder layers (+12 encoder layers), d_model=1024, 16 heads (kv=16),
d_ff=4096, vocab=256206.  The mel/conv audio codec is a STUB — the encoder
consumes precomputed frame embeddings.

long_500k is SKIPPED for this arch (see DESIGN.md §5): an enc-dec speech
model has no sliding-window form for cross-attention and a 512k-token
decode is outside the family's operating regime.
"""

from repro.configs.base import EncDecConfig, ModelConfig

ARCH_ID = "seamless-m4t-medium"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="audio",
        num_layers=12,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        vocab=256206,
        activation="gelu",
        norm="layernorm",
        max_seq=4096,
        frontend="audio",
        encdec=EncDecConfig(num_encoder_layers=12, encoder_seq=4096),
    )


def smoke() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, d_ff=256,
        vocab=512, max_seq=128, q_chunk=32, kv_chunk=32, remat=False,
        encdec=EncDecConfig(num_encoder_layers=2, encoder_seq=64),
    )
