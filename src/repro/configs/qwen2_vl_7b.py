"""qwen2-vl-7b [vlm] — arXiv:2409.12191.

28L, d_model=3584, 28 heads (GQA kv=4), d_ff=18944, vocab=152064.
M-RoPE (t/h/w sections), dynamic resolution.  Vision tower is a STUB —
``input_specs`` supplies precomputed patch embeddings + 3D positions.
"""

from repro.configs.base import ModelConfig

ARCH_ID = "qwen2-vl-7b"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="vlm",
        num_layers=28,
        d_model=3584,
        num_heads=28,
        num_kv_heads=4,
        head_dim=128,
        d_ff=18944,
        vocab=152064,
        activation="swiglu",
        norm="rmsnorm",
        rope_type="mrope",
        mrope_sections=(16, 24, 24),
        rope_theta=1_000_000.0,
        max_seq=32_768,
        frontend="vision",
    )


def smoke() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2, head_dim=32,
        d_ff=512, vocab=512, max_seq=128, q_chunk=32, kv_chunk=32, remat=False,
        mrope_sections=(8, 4, 4),
    )
