"""deepseek-coder-33b [dense, llama-arch]  — arXiv:2401.14196.

62L, d_model=7168, 56 heads (GQA kv=8), d_ff=19200, vocab=32256.
"""

from repro.configs.base import ModelConfig

ARCH_ID = "deepseek-coder-33b"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="dense",
        num_layers=62,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        head_dim=128,
        d_ff=19200,
        vocab=32256,
        activation="swiglu",
        norm="rmsnorm",
        rope_theta=100_000.0,
        max_seq=16_384,
    )


def smoke() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2, head_dim=32,
        d_ff=512, vocab=512, max_seq=128, q_chunk=32, kv_chunk=32, remat=False,
    )
