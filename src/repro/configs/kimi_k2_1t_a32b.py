"""kimi-k2-1t-a32b [moe] — trillion-param MoE (paper table) arXiv:2501.kimi2.

61L, d_model=7168, 64 heads (GQA kv=8, head_dim=128), expert d_ff=2048,
vocab=163840, MoE 384 experts top-8 + 1 shared expert, first layer dense.

Memory note: ~1T params cannot hold fp32+Adam on 512 v5e chips
(16 GB HBM each).  This config uses bf16 params and the ``sgdm_bf16``
optimizer in the launcher (2+2+2 bytes/param fully sharded ≈ 11.7 GB/chip)
— see EXPERIMENTS.md §Dry-run.
"""

from repro.configs.base import ModelConfig, MoEConfig

ARCH_ID = "kimi-k2-1t-a32b"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="moe",
        num_layers=61,
        d_model=7168,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=2048,
        vocab=163840,
        activation="swiglu",
        norm="rmsnorm",
        max_seq=131_072,
        param_dtype="bfloat16",
        moe=MoEConfig(
            num_experts=384, top_k=8, d_expert=2048,
            num_shared_experts=1, first_k_dense=1,
        ),
    )


def smoke() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=64, vocab=512, max_seq=128, q_chunk=32, kv_chunk=32, remat=False,
        param_dtype="float32",
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                      num_shared_experts=1, first_k_dense=1),
    )
