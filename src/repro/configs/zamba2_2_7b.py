"""zamba2-2.7b [hybrid] — arXiv:2411.15242.

54 Mamba2 layers, d_model=2560, ssm_state=64, with a SHARED attention+MLP
block (32 heads kv=32, d_ff=10240) applied every 6 layers (9 superblocks).
The shared block reuses the same parameters at every application — that
weight sharing is the architecture's defining trait.  (Real Zamba2 adds
per-invocation LoRA adapters on the shared block; omitted — see DESIGN.md.)

long_500k runs natively: decode state is O(1) for the Mamba2 layers and
O(window) per shared-attn invocation.
"""

from repro.configs.base import HybridConfig, ModelConfig, SSMConfig

ARCH_ID = "zamba2-2.7b"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="hybrid",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        d_ff=10240,
        vocab=32000,
        activation="swiglu",
        norm="rmsnorm",
        max_seq=4096,
        ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk=256),
        hybrid=HybridConfig(attn_every=6, shared_d_ff=10240),
    )


def smoke() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, d_ff=256,
        vocab=512, max_seq=128, q_chunk=32, kv_chunk=32, remat=False,
        ssm=SSMConfig(state_dim=8, head_dim=16, expand=2, chunk=32),
        hybrid=HybridConfig(attn_every=2, shared_d_ff=256),
    )
