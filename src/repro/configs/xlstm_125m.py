"""xlstm-125m [ssm] — arXiv:2405.04517.

12L, d_model=768, 4 heads, sLSTM + mLSTM blocks (every 4th block sLSTM),
no separate FFN (d_ff=0; blocks carry their own projections), vocab=50304.

Recurrent decode state is O(1) — long_500k runs natively.
"""

from repro.configs.base import ModelConfig, XLSTMConfig

ARCH_ID = "xlstm-125m"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="ssm",
        num_layers=12,
        d_model=768,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab=50304,
        rope_type="none",
        norm="layernorm",
        max_seq=2048,
        xlstm=XLSTMConfig(slstm_every=4),
    )


def smoke() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        vocab=512, max_seq=128, remat=False,
        xlstm=XLSTMConfig(slstm_every=2),
    )
