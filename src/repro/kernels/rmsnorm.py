"""Pallas TPU kernel for RMSNorm (the per-layer normalisation).

Row-tiled: each grid cell normalises a (rows, d) tile in VMEM with fp32
statistics — the canonical fused-normalisation pattern (one HBM read, one
write, no f32 materialisation of the full activation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "eps", "interpret"))
def rmsnorm_pallas(x, scale, *, block_rows: int = 128, eps: float = 1e-6,
                   interpret: bool = True):
    """x (..., d), scale (d,) -> same shape/dtype as x."""
    orig_shape = x.shape
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    T = x2.shape[0]
    br = min(block_rows, T)
    Tp = -(-T // br) * br
    xp = jnp.pad(x2, ((0, Tp - T), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(Tp // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Tp, d), x.dtype),
        interpret=interpret,
    )(xp, scale)
    return out[:T].reshape(orig_shape)
