"""Pallas TPU decode attention: one query over a long KV cache.

The decode_32k / long_500k hot-spot: memory-bound streaming of the cache
through VMEM with an online-softmax accumulator.  Grid (BH, nk); the KV
axis is sequential so (m, l, acc) scratch carries across tiles.  Valid
lengths arrive via scalar prefetch (SMEM) so ragged batches mask exactly.

Servers of freshly-federated models also decode through here: the
composed-transformer serving path (``repro.fl.transformer.greedy_decode``,
docs/TRANSFORMERS.md) keeps its per-layer KV caches in this kernel's
(B*H, S, D) layout and calls it once per generated token.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, scale: float, kv_block: int, nk: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # (1, D)
    k = k_ref[0]  # (kb, D)
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (1, kb)
    kpos = j * kv_block + jax.lax.broadcasted_iota(jnp.int32, (1, kv_block), 1)
    valid = kpos < len_ref[b]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )[0]
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _fin():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )[None]


@functools.partial(
    jax.jit, static_argnames=("kv_block", "q_per_kv", "interpret")
)
def decode_attention_pallas(q, k, v, lengths, *, kv_block: int = 512,
                            q_per_kv: int = 1, interpret: bool = True):
    """q (BH, D); k/v (BKV, S, D); lengths (BH,) int32 -> (BH, D)."""
    BH, D = q.shape
    BKV, S, _ = k.shape
    assert BH == BKV * q_per_kv
    kb = min(kv_block, S)
    Sp = -(-S // kb) * kb
    kp = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0)))
    nk = Sp // kb
    g = q_per_kv

    kernel = functools.partial(_decode_kernel, scale=D ** -0.5, kv_block=kb,
                               nk=nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BH, nk),
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda b, j, lens: (b, 0, 0)),
            pl.BlockSpec((1, kb, D), lambda b, j, lens: (b // g, j, 0)),
            pl.BlockSpec((1, kb, D), lambda b, j, lens: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, j, lens: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((), jnp.float32),
            pltpu.VMEM((), jnp.float32),
            pltpu.VMEM((D,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BH, 1, D), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q[:, None, :], kp, vp)
    return out[:, 0]
