"""Jitted public wrappers over the Pallas kernels.

Each op accepts the model-layer layouts used by :mod:`repro.models` and
dispatches to the Pallas kernel (``interpret=True`` on CPU — the kernel
body executes in Python; on TPU set ``interpret=False``).  Oracles live
in :mod:`repro.kernels.ref`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.compose import compose_pallas
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas

Array = jax.Array


def compose(basis: Array, coeff: Array, *, interpret: bool | None = None) -> Array:
    """Neural-composition product: (ksq, I, R) x (m, R, O) -> (ksq, I, m·O).

    Also accepts a leading client axis ((C, ksq, I, R) x (C, m, R, O))
    — one pallas_call composes the whole cohort stack.  ``interpret``
    defaults to the platform gate (compiled on TPU, interpret
    elsewhere); see :func:`repro.kernels.compose.default_interpret`.
    """
    return compose_pallas(basis, coeff, interpret=interpret)


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int = 0, interpret: bool = True) -> Array:
    """Model layout: q (B, S, KV, G, D), k/v (B, S, KV, D)."""
    B, S, KV, G, D = q.shape
    qf = jnp.transpose(q, (0, 2, 3, 1, 4)).reshape(B * KV * G, S, D)
    kf = jnp.transpose(k, (0, 2, 1, 3)).reshape(B * KV, S, D)
    vf = jnp.transpose(v, (0, 2, 1, 3)).reshape(B * KV, S, D)
    out = flash_attention_pallas(qf, kf, vf, causal=causal, window=window,
                                 q_per_kv=G, interpret=interpret)
    return jnp.transpose(out.reshape(B, KV, G, S, D), (0, 3, 1, 2, 4))


def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     lengths: Array, *, interpret: bool = True) -> Array:
    """Model layout: q (B, 1, KV, G, D), caches (B, S, KV, D), lengths (B,)."""
    B, _, KV, G, D = q.shape
    S = k_cache.shape[1]
    qf = q[:, 0].reshape(B * KV * G, D)
    kf = jnp.transpose(k_cache, (0, 2, 1, 3)).reshape(B * KV, S, D)
    vf = jnp.transpose(v_cache, (0, 2, 1, 3)).reshape(B * KV, S, D)
    lens = jnp.repeat(lengths.astype(jnp.int32), KV * G)
    out = decode_attention_pallas(qf, kf, vf, lens, q_per_kv=G,
                                  interpret=interpret)
    return out.reshape(B, 1, KV, G, D)


def ssd_chunk(cb: Array, bb: Array, xw: Array, cum: Array, h_in: Array,
              *, interpret: bool = True) -> Array:
    """Mamba2 SSD intra-chunk block (see kernels/ssd_chunk.py)."""
    from repro.kernels.ssd_chunk import ssd_chunk_pallas

    return ssd_chunk_pallas(cb, bb, xw, cum, h_in, interpret=interpret)


def rmsnorm(x: Array, scale: Array, *, eps: float = 1e-6,
            interpret: bool = True) -> Array:
    """Fused RMSNorm (see kernels/rmsnorm.py)."""
    from repro.kernels.rmsnorm import rmsnorm_pallas

    return rmsnorm_pallas(x, scale, eps=eps, interpret=interpret)
