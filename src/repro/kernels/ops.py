"""Jitted public wrappers over the Pallas kernels.

Each op accepts the model-layer layouts used by :mod:`repro.models` /
:mod:`repro.fl.models` and dispatches to the Pallas kernel.  Every
``interpret`` argument defaults to ``None`` and resolves through the
platform gate (:func:`repro.kernels.compose.default_interpret`:
compiled on TPU, interpret elsewhere).  Oracles live in
:mod:`repro.kernels.ref`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.compose import (compose_dense_apply, compose_pallas,
                                   default_interpret, rank_dense_apply)
from repro.kernels.conv_rank import conv_rank_apply
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas

__all__ = [
    "compose", "rank_dense_apply", "conv_rank_apply", "compose_dense_apply",
    "flash_attention", "decode_attention", "ssd_chunk", "rmsnorm",
]

Array = jax.Array


def compose(basis: Array, coeff: Array, *, interpret: bool | None = None) -> Array:
    """Neural-composition product: (ksq, I, R) x (m, R, O) -> (ksq, I, m·O).

    Also accepts a leading client axis ((C, ksq, I, R) x (C, m, R, O))
    — one pallas_call composes the whole cohort stack.  ``interpret``
    defaults to the platform gate (compiled on TPU, interpret
    elsewhere); see :func:`repro.kernels.compose.default_interpret`.
    """
    return compose_pallas(basis, coeff, interpret=interpret)


# rank_dense_apply / conv_rank_apply / compose_dense_apply are re-exported
# directly: their public signatures already speak the model-layer layout
# (basis (ksq, I, R), gathered coefficient blocks (m, R, O)) and carry
# their own custom_vjp + platform gating.


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int = 0, interpret: bool | None = None) -> Array:
    """Model layout: q (B, S, KV, G, D), k/v (B, S, KV, D)."""
    if interpret is None:
        interpret = default_interpret()
    B, S, KV, G, D = q.shape
    qf = jnp.transpose(q, (0, 2, 3, 1, 4)).reshape(B * KV * G, S, D)
    kf = jnp.transpose(k, (0, 2, 1, 3)).reshape(B * KV, S, D)
    vf = jnp.transpose(v, (0, 2, 1, 3)).reshape(B * KV, S, D)
    out = flash_attention_pallas(qf, kf, vf, causal=causal, window=window,
                                 q_per_kv=G, interpret=interpret)
    return jnp.transpose(out.reshape(B, KV, G, S, D), (0, 3, 1, 2, 4))


def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     lengths: Array, *, interpret: bool | None = None) -> Array:
    """Model layout: q (B, 1, KV, G, D), caches (B, S, KV, D), lengths (B,)."""
    if interpret is None:
        interpret = default_interpret()
    B, _, KV, G, D = q.shape
    S = k_cache.shape[1]
    qf = q[:, 0].reshape(B * KV * G, D)
    kf = jnp.transpose(k_cache, (0, 2, 1, 3)).reshape(B * KV, S, D)
    vf = jnp.transpose(v_cache, (0, 2, 1, 3)).reshape(B * KV, S, D)
    lens = jnp.repeat(lengths.astype(jnp.int32), KV * G)
    out = decode_attention_pallas(qf, kf, vf, lens, q_per_kv=G,
                                  interpret=interpret)
    return out.reshape(B, 1, KV, G, D)


def ssd_chunk(cb: Array, bb: Array, xw: Array, cum: Array, h_in: Array,
              *, interpret: bool | None = None) -> Array:
    """Mamba2 SSD intra-chunk block (see kernels/ssd_chunk.py)."""
    from repro.kernels.ssd_chunk import ssd_chunk_pallas

    if interpret is None:
        interpret = default_interpret()
    return ssd_chunk_pallas(cb, bb, xw, cum, h_in, interpret=interpret)


def rmsnorm(x: Array, scale: Array, *, eps: float = 1e-6,
            interpret: bool | None = None) -> Array:
    """Fused RMSNorm (see kernels/rmsnorm.py)."""
    from repro.kernels.rmsnorm import rmsnorm_pallas

    if interpret is None:
        interpret = default_interpret()
    return rmsnorm_pallas(x, scale, eps=eps, interpret=interpret)
