"""Pallas TPU kernel for the Mamba2 SSD intra-chunk block.

Computes, for one chunk of length Q (grid cell = one (batch, chunk, head)
triple):

    y[i] = sum_{j<=i} (C_i . B_j) * exp(cum[i] - cum[j]) * xw[j]
         + (C_i . h_in) * exp(cum[i])            (inter-chunk carry-in)

which is the matmul-dominant inner block of the chunked selective-state-
space scan (repro.models.ssm.ssd_chunked) — scores (Q x Q) on the MXU, the
decay mask applied in VMEM, fp32 accumulation.  The outer (cheap) chunk
recurrence stays in jnp.

Layouts:
  cb     (BCH, Q, N)   C for the chunk (per head-group; replicated per head)
  bb     (BCH, Q, N)   B
  xw     (BCH, Q, P)   dt-weighted inputs
  cum    (BCH, Q)      cumulative log-decay within the chunk
  h_in   (BCH, N, P)   state entering the chunk
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(cb_ref, bb_ref, xw_ref, cum_ref, hin_ref, o_ref):
    cb = cb_ref[0]  # (Q, N)
    bb = bb_ref[0]
    xw = xw_ref[0]  # (Q, P)
    cum = cum_ref[0]  # (Q,)
    hin = hin_ref[0]  # (N, P)
    q = cb.shape[0]
    scores = jnp.dot(cb, bb.T, preferred_element_type=jnp.float32)  # (Q, Q)
    diff = cum[:, None] - cum[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    decay = jnp.where(mask, jnp.exp(diff), 0.0)
    w = scores * decay
    y_intra = jnp.dot(w.astype(xw.dtype), xw, preferred_element_type=jnp.float32)
    carry = jnp.dot(cb, hin, preferred_element_type=jnp.float32)  # (Q, P)
    y = y_intra + jnp.exp(cum)[:, None] * carry
    o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_pallas(cb, bb, xw, cum, h_in, *, interpret: bool = True):
    """cb/bb (BCH, Q, N), xw (BCH, Q, P), cum (BCH, Q), h_in (BCH, N, P)
    -> y (BCH, Q, P)."""
    BCH, Q, N = cb.shape
    P_ = xw.shape[-1]
    return pl.pallas_call(
        _ssd_kernel,
        grid=(BCH,),
        in_specs=[
            pl.BlockSpec((1, Q, N), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, Q, P_), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, Q), lambda b: (b, 0)),
            pl.BlockSpec((1, N, P_), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, P_), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BCH, Q, P_), xw.dtype),
        interpret=interpret,
    )(cb, bb, xw, cum, h_in)
