"""Pallas kernels for the compute hot-spots.  ``interpret`` defaults are
platform-gated (compiled on TPU, interpret where Pallas lacks a
compiled lowering for these kernel bodies — see
``repro.kernels.compose.default_interpret``):

  compose           the paper's neural-composition product (Eq. 4),
                    batched over an optional leading client axis
  rank_dense_apply  fused rank-space factor application with a
                    rank-space custom_vjp backward
  flash_attention   blockwise streaming-softmax attention (prefill/train)
  decode_attention  one-token GQA over a long KV cache (decode shapes)
  ssd_chunk         Mamba2 SSD intra-chunk block (SSM/hybrid archs)
  rmsnorm           fused row-tiled normalisation

``ops`` holds the jit'd public wrappers; ``ref`` the pure-jnp oracles the
sweep tests assert against (tests/test_kernels.py).
"""

from repro.kernels import ops, ref  # noqa: F401
