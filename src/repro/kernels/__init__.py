"""Pallas kernels for the compute hot-spots.  ``interpret`` defaults are
platform-gated (compiled on TPU, interpret where Pallas lacks a
compiled lowering for these kernel bodies — see
``repro.kernels.compose.default_interpret``):

  compose             the paper's neural-composition product (Eq. 4),
                      batched over an optional leading client axis
  rank_dense_apply    fused rank-space factor application with a
                      rank-space custom_vjp backward
  conv_rank_apply     fused conv rank path: basis conv (I→R) +
                      coefficient contraction (R→pO) in one kernel,
                      rank-space backward; on CPU/GPU the forward is an
                      equivalent fused XLA formulation
  compose_dense_apply compose+apply fusion for materialize-path dense
                      layers — the p-width weight is built in
                      VMEM/registers and consumed in the same kernel
  flash_attention     blockwise streaming-softmax attention (prefill/train)
  decode_attention    one-token GQA over a long KV cache (decode shapes)
  ssd_chunk           Mamba2 SSD intra-chunk block (SSM/hybrid archs)
  rmsnorm             fused row-tiled normalisation

``ops`` holds the jit'd public wrappers; ``ref`` the pure-jnp oracles the
sweep tests assert against (tests/test_kernels.py).

Audit note: every kernel above is either on an engine hot path
(compose / rank_dense_apply / conv_rank_apply / compose_dense_apply via
``forward_impl`` dispatch, flash/decode attention via the transformer
train + serve stacks) or a tested reference implementation kept for the
model zoo (ssd_chunk, rmsnorm — ``repro.models`` currently uses plain
jnp formulations at its small shapes; the kernels stay oracle-verified
so swapping them in is a one-line change when shapes grow).
"""

from repro.kernels import ops, ref  # noqa: F401
