"""Pallas TPU kernels for the compute hot-spots (validated interpret=True
on CPU; set interpret=False on real TPUs):

  compose           the paper's neural-composition product (Eq. 4)
  flash_attention   blockwise streaming-softmax attention (prefill/train)
  decode_attention  one-token GQA over a long KV cache (decode shapes)
  ssd_chunk         Mamba2 SSD intra-chunk block (SSM/hybrid archs)
  rmsnorm           fused row-tiled normalisation

``ops`` holds the jit'd public wrappers; ``ref`` the pure-jnp oracles the
sweep tests assert against (tests/test_kernels.py).
"""

from repro.kernels import ops, ref  # noqa: F401
