"""Pallas kernels for the neural-composition hot path (paper Eq. 4).

Two primitives back the factorized client compute:

``compose_pallas``
    ``w[k] = basis[k] @ coeff_flat`` for every spatial slice ``k`` — the
    compose step that materialises a p-width weight from the shared
    basis and the gathered coefficient blocks.  Accepts an optional
    *leading client axis* (``basis (C, ksq, I, R)``, ``coeff (C, m, R,
    O)``) so ONE ``pallas_call`` serves a whole stacked cohort.  Each
    (bi x bj) output tile is an MXU matmul accumulated in fp32.
    Wrapped in a :func:`jax.custom_vjp` with an einsum backward:
    ``compose`` runs inside differentiated losses (every
    materialize-path layer in ``prepare_weights``, the RNN's
    scan-carried recurrence weight), and ``pallas_call`` has no
    transpose rule, so the kernel forward must carry its own VJP for
    ``jax.grad`` to work on compiled backends.

``rank_dense_apply``
    the fused rank-space application ``y = (x·v)·û`` for dense layers,
    wrapped in a :func:`jax.custom_vjp` whose backward ALSO stays in
    rank space — neither direction ever materialises the p-width
    weight.  The einsum formulation is the reference implementation and
    the CPU path; on compiled-Pallas backends the forward runs as one
    fused kernel (the rank-R intermediate lives in VMEM, never HBM).

``compose_dense_apply``
    compose+apply fusion for layers the cost model keeps on the
    *materialize* path (rank-space loses when ``R ≥ O/p``, e.g. the
    classifier heads): the per-group weights ``W_a = v · û_a`` are
    built inside the kernel (VMEM/registers) and contracted against the
    matching input group in the same invocation, so the p-width weight
    never reaches HBM even though the math is weight-shaped.  Shares
    the rank-space custom_vjp backward with ``rank_dense_apply`` — the
    two primitives compute the same function, they just associate the
    forward differently.

The conv-path sibling (fused basis conv + coefficient contraction)
lives in :mod:`repro.kernels.conv_rank`.

Platform gating: kernels compile on TPU and fall back to
``interpret=True`` everywhere Pallas lacks a compiled lowering for
*these* kernels — CPU hosts, and (for now) GPU: the block shapes and
in-kernel reshapes here are Mosaic/TPU idioms the Triton lowering does
not accept, so GPU hosts take the interpret/einsum reference paths
until a Triton-friendly variant lands.  See :func:`default_interpret`;
every ``interpret`` argument below defaults to that gate when left as
``None``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

_COMPILED_BACKENDS = ("tpu",)


def default_interpret() -> bool:
    """True where these kernels have no compiled lowering (everything
    but TPU — the kernel bodies use Mosaic idioms Triton rejects)."""
    return jax.default_backend() not in _COMPILED_BACKENDS


def _resolve(interpret) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


# ---------------------------------------------------------------------------
# compose: v · û  (materialisation)
# ---------------------------------------------------------------------------


def _compose_kernel(v_ref, u_ref, o_ref):
    # v_ref: (1, bi, R)  u_ref: (R, bj)  o_ref: (1, bi, bj)
    acc = jnp.dot(
        v_ref[0], u_ref[...], preferred_element_type=jnp.float32
    )
    o_ref[0] = acc.astype(o_ref.dtype)


def _compose_kernel_batched(v_ref, u_ref, o_ref):
    # v_ref: (1, 1, bi, R)  u_ref: (1, R, bj)  o_ref: (1, 1, bi, bj)
    acc = jnp.dot(
        v_ref[0, 0], u_ref[0], preferred_element_type=jnp.float32
    )
    o_ref[0, 0] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_i", "block_j", "interpret"))
def _compose_pallas_3d(basis: Array, coeff: Array, *, block_i: int,
                       block_j: int, interpret: bool) -> Array:
    ksq, I, R = basis.shape
    m, R2, O = coeff.shape
    assert R == R2
    MO = m * O
    u_flat = jnp.transpose(coeff, (1, 0, 2)).reshape(R, MO)
    bi = min(block_i, I)
    bj = min(block_j, MO)
    # pad to tile multiples
    Ip = -(-I // bi) * bi
    Jp = -(-MO // bj) * bj
    vp = jnp.pad(basis, ((0, 0), (0, Ip - I), (0, 0)))
    up = jnp.pad(u_flat, ((0, 0), (0, Jp - MO)))

    out = pl.pallas_call(
        _compose_kernel,
        grid=(ksq, Ip // bi, Jp // bj),
        in_specs=[
            pl.BlockSpec((1, bi, R), lambda k, i, j: (k, i, 0)),
            pl.BlockSpec((R, bj), lambda k, i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, bi, bj), lambda k, i, j: (k, i, j)),
        out_shape=jax.ShapeDtypeStruct((ksq, Ip, Jp), basis.dtype),
        interpret=interpret,
    )(vp, up)
    return out[:, :I, :MO]


@functools.partial(jax.jit, static_argnames=("block_i", "block_j", "interpret"))
def _compose_pallas_4d(basis: Array, coeff: Array, *, block_i: int,
                       block_j: int, interpret: bool) -> Array:
    C, ksq, I, R = basis.shape
    C2, m, R2, O = coeff.shape
    assert R == R2 and C == C2
    MO = m * O
    u_flat = jnp.transpose(coeff, (0, 2, 1, 3)).reshape(C, R, MO)
    bi = min(block_i, I)
    bj = min(block_j, MO)
    Ip = -(-I // bi) * bi
    Jp = -(-MO // bj) * bj
    vp = jnp.pad(basis, ((0, 0), (0, 0), (0, Ip - I), (0, 0)))
    up = jnp.pad(u_flat, ((0, 0), (0, 0), (0, Jp - MO)))

    out = pl.pallas_call(
        _compose_kernel_batched,
        grid=(C, ksq, Ip // bi, Jp // bj),
        in_specs=[
            pl.BlockSpec((1, 1, bi, R), lambda c, k, i, j: (c, k, i, 0)),
            pl.BlockSpec((1, R, bj), lambda c, k, i, j: (c, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, bi, bj),
                               lambda c, k, i, j: (c, k, i, j)),
        out_shape=jax.ShapeDtypeStruct((C, ksq, Ip, Jp), basis.dtype),
        interpret=interpret,
    )(vp, up)
    return out[:, :, :I, :MO]


def _compose_dispatch(basis: Array, coeff: Array, block_i: int,
                      block_j: int, interpret: bool) -> Array:
    if basis.ndim == 4:
        return _compose_pallas_4d(basis, coeff, block_i=block_i,
                                  block_j=block_j, interpret=interpret)
    return _compose_pallas_3d(basis, coeff, block_i=block_i,
                              block_j=block_j, interpret=interpret)


@functools.lru_cache(maxsize=None)
def _compose_vjp_fn(block_i: int, block_j: int, interpret: bool):
    """custom_vjp around the compose kernel, cached per tiling/backend.

    ``pallas_call`` has no transpose rule, but ``compose`` is evaluated
    inside ``jax.grad`` whenever a materialize-path layer sits in a
    client loss (``prepare_weights``; the RNN's scan-carried ``wh``) —
    so the kernel forward pairs with an einsum backward.  The backward
    contracts through the rank-R bottleneck only (``dv: (ksq·I)×(mO)
    @ u^T``, ``du: v^T @ (ksq·I)×(mO)``), never wider than the forward.
    """

    @jax.custom_vjp
    def apply(basis, coeff):
        return _compose_dispatch(basis, coeff, block_i, block_j, interpret)

    def fwd(basis, coeff):
        return apply(basis, coeff), (basis, coeff)

    def bwd(res, g):
        basis, coeff = res
        m, O = coeff.shape[-3], coeff.shape[-1]
        g = g.reshape(g.shape[:-1] + (m, O))  # (..., ksq, I, m, O)
        if basis.ndim == 4:
            dv = jnp.einsum("ckimo,cmro->ckir", g, coeff)
            du = jnp.einsum("ckir,ckimo->cmro", basis, g)
        else:
            dv = jnp.einsum("kimo,mro->kir", g, coeff)
            du = jnp.einsum("kir,kimo->mro", basis, g)
        return dv.astype(basis.dtype), du.astype(coeff.dtype)

    apply.defvjp(fwd, bwd)
    return apply


def compose_pallas(basis: Array, coeff: Array, *, block_i: int = 128,
                   block_j: int = 128, interpret: bool | None = None) -> Array:
    """basis (ksq, I, R), coeff (m, R, O) -> (ksq, I, m*O).

    With a leading client axis — basis (C, ksq, I, R), coeff (C, m, R,
    O) — one ``pallas_call`` composes the whole cohort stack and the
    result gains the same leading axis.  The (m, R, O) coefficient
    blocks are flattened to (R, m*O): the column-blocked layout of the
    complete coefficient in the paper.

    Differentiable: the call routes through a ``jax.custom_vjp`` whose
    backward is the einsum transpose (see :func:`_compose_vjp_fn`), so
    ``jax.grad`` through ``compose(backend="pallas")`` works even
    though the Pallas forward has no automatic transpose.

    ``interpret=None`` resolves via :func:`default_interpret` (compiled
    on TPU, interpret elsewhere).
    """
    return _compose_vjp_fn(block_i, block_j, _resolve(interpret))(basis, coeff)


# ---------------------------------------------------------------------------
# fused rank-space dense apply: y = (x·v)·û
# ---------------------------------------------------------------------------


def _rank_apply_kernel(x_ref, v_ref, u_ref, o_ref):
    # x_ref (bm, g, I), v_ref (I, R), u_ref (g*R, D) -> o_ref (bm, D)
    bm, g, I = x_ref.shape
    t = jnp.dot(x_ref[...].reshape(bm * g, I), v_ref[...],
                preferred_element_type=jnp.float32)
    t = t.reshape(bm, g * v_ref.shape[1]).astype(x_ref.dtype)
    y = jnp.dot(t, u_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def rank_apply_pallas(xg: Array, v2: Array, u2: Array, *,
                      block_m: int = 256, interpret: bool | None = None
                      ) -> Array:
    """Fused two-stage contraction: xg (M, g, I) x v2 (I, R) x u2 (g*R, D)
    -> (M, D); the (M, g*R) rank intermediate stays in VMEM."""
    interpret = _resolve(interpret)
    M, g, I = xg.shape
    D = u2.shape[1]
    bm = min(block_m, M)
    Mp = -(-M // bm) * bm
    xp = jnp.pad(xg, ((0, Mp - M), (0, 0), (0, 0)))
    out = pl.pallas_call(
        _rank_apply_kernel,
        grid=(Mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, g, I), lambda i: (i, 0, 0)),
            pl.BlockSpec((I, v2.shape[1]), lambda i: (0, 0)),
            pl.BlockSpec(u2.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, D), xg.dtype),
        interpret=interpret,
    )(xp, v2, u2)
    return out[:M]


def _fwd_math(x2: Array, v2: Array, u: Array, p: int, mode: str):
    """Reference einsum forward on flattened rows: returns (y, t)."""
    R, O = u.shape[-2], u.shape[-1]
    if mode == "grow_out":
        t = x2 @ v2  # (M, R)
        y = jnp.einsum("mr,bro->mbo", t, u).reshape(x2.shape[0], p * O)
        return y, t
    xr = x2.reshape(x2.shape[0], p, -1)
    t = jnp.einsum("mai,ir->mar", xr, v2)  # (M, p, R)
    if mode == "grow_in":
        return jnp.einsum("mar,aro->mo", t, u), t
    u4 = u.reshape(p, p, R, O)
    y = jnp.einsum("mar,abro->mbo", t, u4).reshape(x2.shape[0], p * O)
    return y, t


def _u2_layout(u: Array, p: int, mode: str) -> Array:
    """Coefficient blocks as the (g*R, D) matrix the fused kernel eats."""
    R, O = u.shape[-2], u.shape[-1]
    if mode == "grow_out":
        return jnp.transpose(u, (1, 0, 2)).reshape(R, p * O)
    if mode == "grow_in":
        return u.reshape(p * R, O)
    u4 = u.reshape(p, p, R, O)
    return jnp.transpose(u4, (0, 2, 1, 3)).reshape(p * R, p * O)


def _rank_space_bwd(p: int, mode: str, res, dy):
    """Shared rank-space backward for ``rank_dense_apply`` and
    ``compose_dense_apply`` (same function, different forward
    associations).  Residual: ``(x2, v2, u, t)`` with ``t`` the rank
    intermediate; every contraction routes through the R bottleneck, so
    neither primitive's backward builds the p-width weight."""
    x2, v2, u, t = res
    R, O = u.shape[-2], u.shape[-1]
    if mode == "grow_out":
        dyr = dy.reshape(dy.shape[0], p, O)
        dt = jnp.einsum("mbo,bro->mr", dyr, u)
        dx = dt @ v2.T
        dv2 = x2.T @ dt
        du = jnp.einsum("mr,mbo->bro", t, dyr)
        return dx, dv2, du
    xr = x2.reshape(x2.shape[0], p, -1)
    if mode == "grow_in":
        dt = jnp.einsum("mo,aro->mar", dy, u)
        du = jnp.einsum("mar,mo->aro", t, dy)
    else:
        u4 = u.reshape(p, p, R, O)
        dyr = dy.reshape(dy.shape[0], p, O)
        dt = jnp.einsum("mbo,abro->mar", dyr, u4)
        du = jnp.einsum("mar,mbo->abro", t, dyr).reshape(p * p, R, O)
    dx = jnp.einsum("mar,ir->mai", dt, v2).reshape(x2.shape)
    dv2 = jnp.einsum("mai,mar->ir", xr, dt)
    return dx, dv2, du


@functools.lru_cache(maxsize=None)
def _rank_dense_fn(p: int, mode: str, use_kernel: bool,
                   kernel_interpret: bool = False):
    """custom_vjp rank-space dense apply, cached per (width, mode).

    Forward: the fused Pallas kernel on compiled backends, einsums
    elsewhere.  Backward: rank-space einsums in both cases — the
    transposed contractions route through the same R-dimensional
    bottleneck, so the backward pass never materialises the p-width
    weight either (this is the custom_vjp contract the Pallas forward
    relies on: Pallas kernels have no automatic transpose).

    ``kernel_interpret`` forces the ``use_kernel=True`` branch through
    the Pallas interpreter — how CPU CI exercises the exact fwd+bwd
    wiring (kernel forward + recomputed rank residual) that TPU runs
    compiled.
    """

    def _kernel_fwd(x2, v2, u):
        g = 1 if mode == "grow_out" else p
        xg = x2.reshape(x2.shape[0], g, -1)
        return rank_apply_pallas(xg, v2, _u2_layout(u, p, mode),
                                 interpret=kernel_interpret)

    @jax.custom_vjp
    def apply(x2, v2, u):
        # the primal runs on undifferentiated forwards (loss-only
        # evaluations) — it must take the same kernel branch as fwd or
        # compiled backends silently fall back to the einsum there
        if use_kernel:
            return _kernel_fwd(x2, v2, u)
        return _fwd_math(x2, v2, u, p, mode)[0]

    def fwd(x2, v2, u):
        if use_kernel:
            g = 1 if mode == "grow_out" else p
            xg = x2.reshape(x2.shape[0], g, -1)
            y = _kernel_fwd(x2, v2, u)
            # rank-space residual, recomputed cheaply (M·g·I·R MACs)
            t = jnp.einsum("mgi,ir->mgr", xg, v2)
            t = t[:, 0] if mode == "grow_out" else t
        else:
            y, t = _fwd_math(x2, v2, u, p, mode)
        return y, (x2, v2, u, t)

    def bwd(res, dy):
        return _rank_space_bwd(p, mode, res, dy)

    apply.defvjp(fwd, bwd)
    return apply


def rank_dense_apply(x: Array, basis: Array, reduced_coeff: Array, p: int,
                     mode: str = "square") -> Array:
    """Rank-space dense application with a rank-space backward.

    Args:
      x: ``(..., pI_total)`` row vectors.
      basis: ``(1, I, R)`` (dense layers have ``ksq == 1``).
      reduced_coeff: ``(m, R, O)`` gathered blocks.
      p: target width; ``mode``: the spec's square/grow_out/grow_in.

    Returns ``(..., pO_total)`` — what ``x @ compose(...)`` returns, up
    to float re-association, at ``O(R)`` instead of ``O(pI)`` cost per
    output, with the same guarantee through the backward pass.
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    fn = _rank_dense_fn(p, mode, not default_interpret())
    y2 = fn(x2, basis[0], reduced_coeff)
    return y2.reshape(lead + (y2.shape[-1],))


# ---------------------------------------------------------------------------
# fused compose+apply: y = x · (v · û), weight built in VMEM
# ---------------------------------------------------------------------------


def _compose_apply_kernel(x_ref, v_ref, u_ref, o_ref):
    # x_ref (bm, g, I), v_ref (I, R), u_ref (g, R, D) -> o_ref (bm, D)
    bm, g, I = x_ref.shape
    D = u_ref.shape[2]
    acc = jnp.zeros((bm, D), jnp.float32)
    for a in range(g):
        w = jnp.dot(v_ref[...], u_ref[a],
                    preferred_element_type=jnp.float32).astype(x_ref.dtype)
        acc = acc + jnp.dot(x_ref[:, a, :], w,
                            preferred_element_type=jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def compose_apply_pallas(xg: Array, v2: Array, u3: Array, *,
                         block_m: int = 256,
                         interpret: bool | None = None) -> Array:
    """Fused compose+apply: xg (M, g, I) x v2 (I, R) x u3 (g, R, D)
    -> (M, D).

    Per input group ``a`` the kernel builds ``W_a = v2 @ u3[a]`` (an
    ``(I, D)`` tile, VMEM-resident) and accumulates ``xg[:, a] @ W_a``
    — the composed p-width weight exists only one group-slice at a
    time, on-chip.  ``u3`` is the :func:`_u2_layout` matrix reshaped to
    ``(g, R, D)``.  ``interpret=None`` resolves via
    :func:`default_interpret`.
    """
    interpret = _resolve(interpret)
    M, g, I = xg.shape
    D = u3.shape[2]
    bm = min(block_m, M)
    Mp = -(-M // bm) * bm
    xp = jnp.pad(xg, ((0, Mp - M), (0, 0), (0, 0)))
    out = pl.pallas_call(
        _compose_apply_kernel,
        grid=(Mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, g, I), lambda i: (i, 0, 0)),
            pl.BlockSpec((I, v2.shape[1]), lambda i: (0, 0)),
            pl.BlockSpec(u3.shape, lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, D), xg.dtype),
        interpret=interpret,
    )(xp, v2, u3)
    return out[:M]


def _compose_apply_math(x2: Array, v2: Array, u: Array, p: int,
                        mode: str) -> Array:
    """Fused XLA formulation: per-group weights as one batched einsum,
    then one grouped contraction — the CPU/GPU production forward
    (measured faster than compose-then-matmul at engine head shapes)."""
    g = 1 if mode == "grow_out" else p
    u3 = _u2_layout(u, p, mode).reshape(g, u.shape[-2], -1)
    w = jnp.einsum("ir,arj->aij", v2, u3)
    xg = x2.reshape(x2.shape[0], g, -1)
    return jnp.einsum("nai,aij->nj", xg, w)


@functools.lru_cache(maxsize=None)
def _compose_dense_fn(p: int, mode: str, use_kernel: bool,
                      kernel_interpret: bool = False):
    """custom_vjp fused compose+apply, cached per (width, mode).

    Same function as ``_rank_dense_fn`` with the forward associated the
    other way: ``x · (v·û)`` instead of ``(x·v)·û`` — the right
    association when the layer applies its weight to few rows (the cost
    model's materialize regime).  The backward is the identical shared
    rank-space VJP (:func:`_rank_space_bwd`): gradients don't care
    which way the forward associated, and rank space is always the
    cheaper side there.
    """

    def _run(x2, v2, u):
        if use_kernel:
            g = 1 if mode == "grow_out" else p
            xg = x2.reshape(x2.shape[0], g, -1)
            u3 = _u2_layout(u, p, mode).reshape(g, u.shape[-2], -1)
            return compose_apply_pallas(xg, v2, u3,
                                        interpret=kernel_interpret)
        return _compose_apply_math(x2, v2, u, p, mode)

    @jax.custom_vjp
    def apply(x2, v2, u):
        return _run(x2, v2, u)

    def fwd(x2, v2, u):
        y = _run(x2, v2, u)
        g = 1 if mode == "grow_out" else p
        xg = x2.reshape(x2.shape[0], g, -1)
        # rank-space residual for the shared backward, recomputed
        # cheaply (M·g·I·R MACs) — never the composed weight
        t = jnp.einsum("mgi,ir->mgr", xg, v2)
        t = t[:, 0] if mode == "grow_out" else t
        return y, (x2, v2, u, t)

    def bwd(res, dy):
        return _rank_space_bwd(p, mode, res, dy)

    apply.defvjp(fwd, bwd)
    return apply


def compose_dense_apply(x: Array, basis: Array, reduced_coeff: Array,
                        p: int, mode: str = "square") -> Array:
    """Fused compose+apply dense application (materialize-path fusion).

    Args:
      x: ``(..., pI_total)`` row vectors.
      basis: ``(1, I, R)`` (dense layers have ``ksq == 1``).
      reduced_coeff: ``(m, R, O)`` gathered blocks.
      p: target width; ``mode``: the spec's square/grow_out/grow_in.

    Returns ``(..., pO_total)`` — exactly what ``x @ compose(...)``
    returns up to float re-association, with the composed weight living
    only in VMEM/registers in the forward and a rank-space backward.
    Used by ``auto`` dispatch when the measured
    ``fused_compose_gain < 1`` (see :mod:`repro.core.calibration`).
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    fn = _compose_dense_fn(p, mode, not default_interpret())
    y2 = fn(x2, basis[0], reduced_coeff)
    return y2.reshape(lead + (y2.shape[-1],))
