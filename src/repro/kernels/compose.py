"""Pallas TPU kernel for the neural-composition product (paper Eq. 4).

Computes ``w[k] = basis[k] @ coeff_flat`` for every spatial slice k —
the compose step that materialises a p-width weight from the shared basis
and the gathered coefficient blocks.  On TPU this is the paper's compute
primitive; each (bi x bj) output tile is an MXU matmul accumulated in
fp32 VMEM scratch over R-chunks.

Grid: (ksq, I/bi, MO/bj).  Block shapes are MXU-aligned (multiples of
128 where the problem allows).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _compose_kernel(v_ref, u_ref, o_ref):
    # v_ref: (1, bi, R)  u_ref: (R, bj)  o_ref: (1, bi, bj)
    acc = jnp.dot(
        v_ref[0], u_ref[...], preferred_element_type=jnp.float32
    )
    o_ref[0] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_i", "block_j", "interpret"))
def compose_pallas(basis: Array, coeff: Array, *, block_i: int = 128,
                   block_j: int = 128, interpret: bool = True) -> Array:
    """basis (ksq, I, R), coeff (m, R, O) -> (ksq, I, m*O).

    The (m, R, O) coefficient blocks are flattened to (R, m*O) — the
    column-blocked layout of the complete coefficient in the paper.
    """
    ksq, I, R = basis.shape
    m, R2, O = coeff.shape
    assert R == R2
    MO = m * O
    u_flat = jnp.transpose(coeff, (1, 0, 2)).reshape(R, MO)
    bi = min(block_i, I)
    bj = min(block_j, MO)
    # pad to tile multiples
    Ip = -(-I // bi) * bi
    Jp = -(-MO // bj) * bj
    vp = jnp.pad(basis, ((0, 0), (0, Ip - I), (0, 0)))
    up = jnp.pad(u_flat, ((0, 0), (0, Jp - MO)))

    out = pl.pallas_call(
        _compose_kernel,
        grid=(ksq, Ip // bi, Jp // bj),
        in_specs=[
            pl.BlockSpec((1, bi, R), lambda k, i, j: (k, i, 0)),
            pl.BlockSpec((R, bj), lambda k, i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, bi, bj), lambda k, i, j: (k, i, j)),
        out_shape=jax.ShapeDtypeStruct((ksq, Ip, Jp), basis.dtype),
        interpret=interpret,
    )(vp, up)
    return out[:, :I, :MO]
