"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are deliberately naive (no chunking, no streaming) — the simplest
correct formulation of each op, used by the per-kernel sweep tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def compose_ref(basis: Array, coeff: Array) -> Array:
    """Neural-composition product (paper Eq. 4, pre-reshape).

    basis (ksq, I, R) x coeff (m, R, O) -> (ksq, I, m*O)
    """
    inter = jnp.einsum("kir,mro->kimo", basis, coeff)
    ksq, I, m, O = inter.shape
    return inter.reshape(ksq, I, m * O)


def attention_ref(q: Array, k: Array, v: Array, causal: bool = True,
                  window: int = 0) -> Array:
    """q (BH, Sq, D), k/v (BH, Sk, D) -> (BH, Sq, D), fp32 softmax."""
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * (D ** -0.5)
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v)


def decode_attention_ref(q: Array, k: Array, v: Array, lengths: Array) -> Array:
    """q (BH, D), k/v (BH, S, D), lengths (BH,) -> (BH, D)."""
    BH, S, D = k.shape
    s = jnp.einsum("bd,bkd->bk", q, k).astype(jnp.float32) * (D ** -0.5)
    mask = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bk,bkd->bd", p.astype(v.dtype), v)


def rmsnorm_ref(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), -1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def ssd_chunk_ref(cb: Array, bb: Array, xw: Array, cum: Array,
                  h_in: Array) -> Array:
    """Intra-chunk SSD block + carry-in (oracle for ssd_chunk_pallas).

    cb/bb (B, Q, N), xw (B, Q, P), cum (B, Q), h_in (B, N, P) -> (B, Q, P).
    """
    Q = cb.shape[1]
    scores = jnp.einsum("bin,bjn->bij", cb, bb)
    diff = cum[:, :, None] - cum[:, None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    w = scores * jnp.where(mask[None], jnp.exp(diff), 0.0)
    y_intra = jnp.einsum("bij,bjp->bip", w, xw)
    carry = jnp.einsum("bin,bnp->bip", cb, h_in)
    return y_intra + jnp.exp(cum)[:, :, None] * carry
