"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are deliberately naive (no chunking, no streaming) — the simplest
correct formulation of each op, used by the per-kernel sweep tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def compose_ref(basis: Array, coeff: Array) -> Array:
    """Neural-composition product (paper Eq. 4, pre-reshape).

    basis (ksq, I, R) x coeff (m, R, O) -> (ksq, I, m*O)
    """
    inter = jnp.einsum("kir,mro->kimo", basis, coeff)
    ksq, I, m, O = inter.shape
    return inter.reshape(ksq, I, m * O)


def _composed_weight(basis: Array, coeff: Array, p: int, mode: str) -> Array:
    """Composed weight with the paper's block reshape: (ksq, gI, D)."""
    inter = jnp.einsum("kir,mro->kimo", basis, coeff)
    ksq, I, m, O = inter.shape
    if mode == "grow_out":
        return inter.reshape(ksq, I, m * O)
    if mode == "grow_in":
        return jnp.transpose(inter, (0, 2, 1, 3)).reshape(ksq, p * I, O)
    inter = inter.reshape(ksq, I, p, p, O)
    return jnp.transpose(inter, (0, 2, 1, 3, 4)).reshape(ksq, p * I, p * O)


def conv_rank_ref(x: Array, basis: Array, coeff: Array, p: int,
                  mode: str = "square", stride: int = 1) -> Array:
    """Oracle for the fused conv rank path: compose, then one SAME conv.

    x (N, H, W, gI) x basis (ksq, I, R) x coeff (m, R, O)
    -> (N, Ho, Wo, D).
    """
    w = _composed_weight(basis, coeff, p, mode)
    k = int(round(w.shape[0] ** 0.5))
    w4 = w.reshape(k, k, w.shape[1], w.shape[2])
    return jax.lax.conv_general_dilated(
        x, w4, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def compose_apply_ref(x: Array, basis: Array, coeff: Array, p: int,
                      mode: str = "square") -> Array:
    """Oracle for the fused compose+apply dense path: compose, then matmul.

    x (..., gI) x basis (1, I, R) x coeff (m, R, O) -> (..., D).
    Also the oracle for ``rank_dense_apply`` — the two fused primitives
    compute this same function with different associations.
    """
    return x @ _composed_weight(basis, coeff, p, mode)[0]


def attention_ref(q: Array, k: Array, v: Array, causal: bool = True,
                  window: int = 0) -> Array:
    """q (BH, Sq, D), k/v (BH, Sk, D) -> (BH, Sq, D), fp32 softmax."""
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * (D ** -0.5)
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v)


def decode_attention_ref(q: Array, k: Array, v: Array, lengths: Array) -> Array:
    """q (BH, D), k/v (BH, S, D), lengths (BH,) -> (BH, D)."""
    BH, S, D = k.shape
    s = jnp.einsum("bd,bkd->bk", q, k).astype(jnp.float32) * (D ** -0.5)
    mask = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bk,bkd->bd", p.astype(v.dtype), v)


def rmsnorm_ref(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), -1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def ssd_chunk_ref(cb: Array, bb: Array, xw: Array, cum: Array,
                  h_in: Array) -> Array:
    """Intra-chunk SSD block + carry-in (oracle for ssd_chunk_pallas).

    cb/bb (B, Q, N), xw (B, Q, P), cum (B, Q), h_in (B, N, P) -> (B, Q, P).
    """
    Q = cb.shape[1]
    scores = jnp.einsum("bin,bjn->bij", cb, bb)
    diff = cum[:, :, None] - cum[:, None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    w = scores * jnp.where(mask[None], jnp.exp(diff), 0.0)
    y_intra = jnp.einsum("bij,bjp->bip", w, xw)
    carry = jnp.einsum("bin,bnp->bip", cb, h_in)
    return y_intra + jnp.exp(cum)[:, :, None] * carry
