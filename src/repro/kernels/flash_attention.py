"""Pallas TPU flash attention (prefill / train hot-spot).

Blockwise streaming-softmax over KV tiles with fp32 running (m, l, acc)
scratch in VMEM.  Grid (BH, nq, nk) — the KV dimension is the innermost
(sequential) grid axis, so scratch persists across the j-loop for a fixed
(b, i) and the output tile is written on the last j step.

Masks: causal and sliding-window, computed from program ids — no mask
tensors are materialised.  GQA is handled via the k/v index maps
(kv row = head // q_per_kv), so kv tensors are NOT repeated in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, q_block: int,
                  kv_block: int, sk: int, q_offset: int, nk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    i = pl.program_id(1)
    q = q_ref[0]  # (qb, D)
    k = k_ref[0]  # (kb, D)
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qpos = i * q_block + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 0) \
        + q_offset
    kpos = j * kv_block + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 1)
    mask = kpos < sk
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _fin():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_block", "kv_block", "q_per_kv",
                     "interpret"),
)
def flash_attention_pallas(
    q, k, v, *, causal: bool = True, window: int = 0, q_block: int = 128,
    kv_block: int = 128, q_per_kv: int = 1, interpret: bool = True,
):
    """q (BH, Sq, D); k/v (BKV, Sk, D) with BH = BKV * q_per_kv.

    Returns (BH, Sq, D)."""
    BH, Sq, D = q.shape
    BKV, Sk, _ = k.shape
    assert BH == BKV * q_per_kv
    qb = min(q_block, Sq)
    kb = min(kv_block, Sk)
    Sqp = -(-Sq // qb) * qb
    Skp = -(-Sk // kb) * kb
    qp = jnp.pad(q, ((0, 0), (0, Sqp - Sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Skp - Sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Skp - Sk), (0, 0)))
    nq, nk = Sqp // qb, Skp // kb
    g = q_per_kv

    kernel = functools.partial(
        _flash_kernel, scale=D ** -0.5, causal=causal, window=window,
        q_block=qb, kv_block=kb, sk=Sk, q_offset=Sk - Sq, nk=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qb, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kb, D), lambda b, i, j: (b // g, j, 0)),
            pl.BlockSpec((1, kb, D), lambda b, i, j: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sqp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb, D), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :Sq]
