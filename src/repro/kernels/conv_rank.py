"""Fused conv rank-path primitive: basis conv + coefficient contraction.

The conv rank path applies a factorized k×k weight without materialising
it: a group-batched basis conv projects every input group into rank
space (I → R) and a 1×1 coefficient contraction finishes the job
(R → pO, the paper's block reshape folded into the coefficient layout).
Run as separate XLA ops the rank-R intermediate ``t`` round-trips
through HBM and each op pays its own dispatch — historically that
overhead forced a hardcoded CPU gate that kept ``forward_impl="auto"``
off the conv rank path entirely.  This module fuses the two stages:

``conv_rank_pallas``
    one Pallas kernel per batch image: the basis conv runs as k²
    shifted matmuls over the padded image held in VMEM, the rank
    intermediate never leaves VMEM, and the same kernel invocation
    contracts it against the ``(g·R, D)`` coefficient matrix.  Grid is
    the batch dimension; compiled on TPU, ``interpret=True`` elsewhere
    (``interpret=None`` resolves through
    :func:`repro.kernels.compose.default_interpret`).

``conv_rank_apply``
    the public ``jax.custom_vjp`` primitive.  Forward: the Pallas
    kernel on compiled backends; on CPU/GPU an equivalent fused XLA
    formulation (the same k²-shifted-matmul math for group-batched
    modes, XLA's native conv + the native-layout contraction for
    ``grow_out``) — measured faster than both the separate-ops rank
    path and the Pallas interpreter there.  Backward: **stays in rank space** — the
    coefficient gradients are einsums through the R bottleneck, and
    the input/basis gradients ride ``jax.vjp`` of the basis conv alone
    (recomputing ``t``, the cheap I→R half), so no direction ever
    builds the ``(ksq, pI, pO)`` weight.

Padding follows XLA's asymmetric ``"SAME"`` convention (low = total//2)
so every formulation samples the exact positions
``lax.conv_general_dilated`` does and parity with the materialized conv
holds at any stride.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compose import _resolve, default_interpret

Array = jax.Array

CONV_MODES = ("square", "grow_out", "grow_in")


def _same_pads(size: int, k: int, stride: int) -> tuple[int, tuple[int, int]]:
    """Output size and (lo, hi) padding of XLA "SAME" for one dim."""
    out = -(-size // stride)
    total = max((out - 1) * stride + k - size, 0)
    return out, (total // 2, total - total // 2)


def _u2_conv_layout(u: Array, p: int, mode: str) -> Array:
    """Coefficient blocks (m, R, O) as the (g·R, D) contraction matrix.

    Row block ``a`` holds the R coefficients of input group ``a``; the
    column layout bakes in the compose block reshape, so ``t2 @ u2``
    lands directly in the composed output-channel order.
    """
    R, O = u.shape[-2], u.shape[-1]
    if mode == "grow_out":
        return jnp.transpose(u, (1, 0, 2)).reshape(R, p * O)
    if mode == "grow_in":
        return u.reshape(p * R, O)
    u4 = u.reshape(p, p, R, O)
    return jnp.transpose(u4, (0, 2, 1, 3)).reshape(p * R, p * O)


def _u2_conv_unlayout(du2: Array, p: int, R: int, O: int, mode: str) -> Array:
    """Inverse of :func:`_u2_conv_layout` for the coefficient gradient."""
    if mode == "grow_out":
        return jnp.transpose(du2.reshape(R, p, O), (1, 0, 2))
    if mode == "grow_in":
        return du2.reshape(p, R, O)
    du4 = jnp.transpose(du2.reshape(p, R, p, O), (0, 2, 1, 3))
    return du4.reshape(p * p, R, O)


def _basis_conv(x: Array, basis: Array, p: int, mode: str,
                stride: int) -> Array:
    """Group-batched basis conv: x (N, H, W, g·I) -> t2 (N, Ho, Wo, g·R).

    The linear map whose ``jax.vjp`` carries the input/basis gradients
    of the fused primitive — one XLA conv, groups folded into the
    batch.  Also the forward's first stage in the ``grow_out`` fused
    math path (g == 1: no fold, no transpose).
    """
    ksq, I, R = basis.shape
    k = int(round(ksq ** 0.5))
    vk = basis.reshape(k, k, I, R)
    dn = ("NHWC", "HWIO", "NHWC")
    g = 1 if mode == "grow_out" else p
    N, H, W, _ = x.shape
    if g == 1:
        return jax.lax.conv_general_dilated(x, vk, (stride, stride), "SAME",
                                            dimension_numbers=dn)
    xg = jnp.transpose(x.reshape(N, H, W, g, I), (0, 3, 1, 2, 4))
    xg = xg.reshape(N * g, H, W, I)
    t = jax.lax.conv_general_dilated(xg, vk, (stride, stride), "SAME",
                                     dimension_numbers=dn)
    Ho, Wo = t.shape[1], t.shape[2]
    t2 = jnp.transpose(t.reshape(N, g, Ho, Wo, R), (0, 2, 3, 1, 4))
    return t2.reshape(N, Ho, Wo, g * R)


def _fused_math(x: Array, basis: Array, u: Array, p: int, mode: str,
                stride: int) -> Array:
    """Fused XLA formulation — the CPU/GPU production forward.

    Group-batched modes run the basis conv as k² shifted matmuls over
    the SAME-padded image (the exact math of the Pallas kernel body:
    no group fold/unfold transposes, and the contraction is one flat
    matmul straight off the accumulator).  ``grow_out`` (a single
    group) has no inter-op traffic to fuse away: XLA's native conv for
    the I→R half plus the coefficient contraction in ``u``'s native
    ``(b, r, o)`` layout is the measured-fastest form, so the fused
    primitive's grow_out forward matches the separate-ops math exactly
    and its win there is the rank-space backward, not the forward.
    """
    ksq, I, R = basis.shape
    k = int(round(ksq ** 0.5))
    g = 1 if mode == "grow_out" else p
    if g == 1:
        t2 = _basis_conv(x, basis, p, mode, stride)
        y = jnp.einsum("nhwr,bro->nhwbo", t2, u)
        return y.reshape(y.shape[:3] + (y.shape[3] * y.shape[4],))
    u2 = _u2_conv_layout(u, p, mode)
    N, H, W, _ = x.shape
    Ho, (ph_lo, ph_hi) = _same_pads(H, k, stride)
    Wo, (pw_lo, pw_hi) = _same_pads(W, k, stride)
    xp = jnp.pad(x, ((0, 0), (ph_lo, ph_hi), (pw_lo, pw_hi), (0, 0)))
    xg = xp.reshape(N, xp.shape[1], xp.shape[2], g, I)
    acc = jnp.zeros((N, Ho, Wo, g, R), jnp.float32)
    for ky in range(k):
        for kx in range(k):
            win = xg[:, ky:ky + stride * (Ho - 1) + 1:stride,
                     kx:kx + stride * (Wo - 1) + 1:stride]
            acc = acc + jnp.einsum("nhwai,ir->nhwar", win,
                                   basis[ky * k + kx])
    t2 = acc.astype(x.dtype).reshape(N, Ho, Wo, g * R)
    return t2 @ u2


def _conv_rank_kernel(x_ref, v_ref, u_ref, o_ref, *, k, stride, g, Ho, Wo):
    """Per-image fused body: k² shifted matmuls (I→R) + contraction.

    x_ref (1, Hp, Wp, g·I) — the SAME-padded image; v_ref (ksq, I, R);
    u_ref (g·R, D); o_ref (1, Ho, Wo, D).  The (Ho·Wo, g·R) rank
    intermediate lives only in VMEM/registers.
    """
    xp = x_ref[0]
    Hp, Wp, _ = xp.shape
    I, R = v_ref.shape[1], v_ref.shape[2]
    xg = xp.reshape(Hp, Wp, g, I)
    acc = jnp.zeros((Ho * Wo * g, R), jnp.float32)
    for ky in range(k):
        for kx in range(k):
            win = jax.lax.slice(
                xg, (ky, kx, 0, 0),
                (ky + stride * (Ho - 1) + 1, kx + stride * (Wo - 1) + 1,
                 g, I),
                (stride, stride, 1, 1))
            acc = acc + jnp.dot(win.reshape(Ho * Wo * g, I),
                                v_ref[ky * k + kx],
                                preferred_element_type=jnp.float32)
    t = acc.reshape(Ho * Wo, g * R).astype(x_ref.dtype)
    y = jnp.dot(t, u_ref[...], preferred_element_type=jnp.float32)
    o_ref[0] = y.reshape(Ho, Wo, u_ref.shape[1]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("p", "mode", "stride", "interpret"))
def conv_rank_pallas(x: Array, basis: Array, u2: Array, *, p: int,
                     mode: str = "square", stride: int = 1,
                     interpret: bool | None = None) -> Array:
    """Fused conv rank kernel: x (N, H, W, g·I) × basis (ksq, I, R) ×
    u2 (g·R, D) -> (N, Ho, Wo, D).

    One grid step per batch image; the whole padded image plus both
    factor operands sit in VMEM (the engine's model shapes are a few KB
    per image — far under the VMEM budget).  ``interpret=None``
    resolves via :func:`default_interpret` (compiled on TPU, interpret
    elsewhere; the interpret path is CI's parity harness, not a
    production path — CPU production uses :func:`_fused_math`).
    """
    interpret = _resolve(interpret)
    ksq, I, R = basis.shape
    k = int(round(ksq ** 0.5))
    g = 1 if mode == "grow_out" else p
    N, H, W, C = x.shape
    D = u2.shape[1]
    Ho, (ph_lo, ph_hi) = _same_pads(H, k, stride)
    Wo, (pw_lo, pw_hi) = _same_pads(W, k, stride)
    xp = jnp.pad(x, ((0, 0), (ph_lo, ph_hi), (pw_lo, pw_hi), (0, 0)))
    Hp, Wp = xp.shape[1], xp.shape[2]
    kern = functools.partial(_conv_rank_kernel, k=k, stride=stride, g=g,
                             Ho=Ho, Wo=Wo)
    return pl.pallas_call(
        kern,
        grid=(N,),
        in_specs=[
            pl.BlockSpec((1, Hp, Wp, C), lambda n: (n, 0, 0, 0)),
            pl.BlockSpec(basis.shape, lambda n: (0, 0, 0)),
            pl.BlockSpec(u2.shape, lambda n: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Ho, Wo, D), lambda n: (n, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, Ho, Wo, D), x.dtype),
        interpret=interpret,
    )(xp, basis, u2)


@functools.lru_cache(maxsize=None)
def _conv_rank_fn(p: int, mode: str, stride: int, use_kernel: bool,
                  kernel_interpret: bool = False):
    """custom_vjp fused conv rank apply, cached per (width, mode, stride).

    Forward: the Pallas kernel when ``use_kernel`` (compiled on TPU;
    ``kernel_interpret=True`` forces the same branch through the
    interpreter so CPU CI exercises the exact wiring), the fused XLA
    formulation otherwise.  Backward: rank-space only — ``du``/``dt``
    are einsums through R, and ``dx``/``dbasis`` come from ``jax.vjp``
    of the basis conv (one cheap I→R recompute; the residual is just
    the primal operands, never the rank intermediate or the weight).
    """
    if mode not in CONV_MODES:
        raise ValueError(f"unknown conv mode {mode!r} "
                         f"(expected one of {CONV_MODES})")

    @jax.custom_vjp
    def apply(x, basis, u):
        if use_kernel:
            u2 = _u2_conv_layout(u, p, mode)
            return conv_rank_pallas(x, basis, u2, p=p, mode=mode,
                                    stride=stride,
                                    interpret=kernel_interpret)
        return _fused_math(x, basis, u, p, mode, stride)

    def fwd(x, basis, u):
        return apply(x, basis, u), (x, basis, u)

    def bwd(res, dy):
        x, basis, u = res
        R, O = u.shape[-2], u.shape[-1]
        t2, pull = jax.vjp(
            lambda x_, v_: _basis_conv(x_, v_, p, mode, stride), x, basis)
        u2 = _u2_conv_layout(u, p, mode)
        du2 = jnp.einsum("nhwk,nhwd->kd", t2, dy)
        dt2 = jnp.einsum("nhwd,kd->nhwk", dy, u2).astype(t2.dtype)
        dx, dbasis = pull(dt2)
        du = _u2_conv_unlayout(du2, p, R, O, mode).astype(u.dtype)
        return dx.astype(x.dtype), dbasis.astype(basis.dtype), du

    apply.defvjp(fwd, bwd)
    return apply


def conv_rank_apply(x: Array, basis: Array, reduced_coeff: Array, p: int,
                    mode: str = "square", *, stride: int = 1,
                    use_kernel: bool | None = None,
                    kernel_interpret: bool = False) -> Array:
    """Rank-space conv application with a rank-space backward.

    Args:
      x: ``(N, H, W, C)`` NHWC activations, ``C = g·I`` (``g = p`` for
        square/grow_in, 1 for grow_out).
      basis: ``(ksq, I, R)``; ``reduced_coeff``: ``(m, R, O)`` gathered
        blocks; ``p``: target width; ``mode``: the spec's mode.
      stride: SAME-conv stride.
      use_kernel: ``None`` routes by platform (Pallas kernel on TPU,
        fused XLA formulation elsewhere — :func:`default_interpret`).
      kernel_interpret: with ``use_kernel=True``, run the kernel branch
        through the Pallas interpreter (the CPU CI parity harness).

    Returns exactly what ``conv(x, compose(...))`` returns, up to float
    re-association, without materialising the ``(ksq, pI, pO)`` weight
    in either direction.
    """
    if use_kernel is None:
        use_kernel = not default_interpret()
    fn = _conv_rank_fn(p, mode, stride, use_kernel, kernel_interpret)
    return fn(x, basis, reduced_coeff)
